"""jit'd public wrappers around the kernels.

``expert_mlp_op`` picks the Pallas kernel when it is profitable/available
and falls back to the jnp reference otherwise; both share the oracle
semantics in ref.py.  The Fiddler orchestrator calls these for fast-tier
expert execution; ``host_expert.HostExpert`` is the slow-tier path.
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

from repro.kernels import ref
from repro.kernels.expert_mlp import expert_mlp
from repro.kernels.moe_gmm import moe_gmm

# On this container Pallas runs in interpret mode (Python) — correct but
# slow, so the jitted reference is the default execution path and the
# Pallas kernels are exercised by tests/benchmarks.  On a TPU runtime flip
# USE_PALLAS=True / INTERPRET=False.
USE_PALLAS = False
INTERPRET = True


@jax.jit
def _expert_mlp_jnp(x, w_gate, w_up, w_down):
    return ref.expert_mlp_ref(x, w_gate, w_up, w_down)


def expert_mlp_op(x: jnp.ndarray, w_gate: jnp.ndarray, w_up: jnp.ndarray,
                  w_down: jnp.ndarray, *, use_pallas: Optional[bool] = None
                  ) -> jnp.ndarray:
    """Fast-tier single-expert gated MLP. x: (s, d) → (s, d)."""
    if use_pallas is None:
        use_pallas = USE_PALLAS
    if use_pallas:
        return expert_mlp(x, w_gate, w_up, w_down, interpret=INTERPRET)
    return _expert_mlp_jnp(x, w_gate, w_up, w_down)


@jax.jit
def _moe_gmm_jnp(xs, ws, counts):
    return ref.moe_gmm_ref(xs, ws, counts)


def moe_gmm_op(xs: jnp.ndarray, ws: jnp.ndarray, counts: jnp.ndarray, *,
               use_pallas: Optional[bool] = None) -> jnp.ndarray:
    """Grouped per-expert matmul over capacity buckets."""
    if use_pallas is None:
        use_pallas = USE_PALLAS
    if use_pallas:
        return moe_gmm(xs, ws, counts, interpret=INTERPRET)
    return _moe_gmm_jnp(xs, ws, counts)
