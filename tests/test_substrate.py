"""Substrate tests: data pipeline, tokenizer, checkpointing, optimizer."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from conftest import reduced_model
from repro.configs import get_config
from repro.data.pipeline import (
    TokenStream,
    make_batch_iter,
    sample_prompts,
    synthetic_conversations,
)
from repro.data.tokenizer import BOS_ID, ByteTokenizer
from repro.training.checkpoint import load_checkpoint, save_checkpoint
from repro.training.optimizer import AdamWConfig, adamw_update, init_opt_state


@given(st.text(max_size=200))
@settings(max_examples=100, deadline=None)
def test_tokenizer_roundtrip(text):
    tok = ByteTokenizer(50304)
    ids = tok.encode(text)
    assert ids[0] == BOS_ID
    assert tok.decode(ids) == text
    assert all(0 <= i < tok.vocab_size for i in ids)


def test_stream_shapes_and_determinism():
    cfg = get_config("qwen3-0.6b").reduced()
    a = list(next(TokenStream(cfg, 64, 4, seed=7)).items())
    b = list(next(TokenStream(cfg, 64, 4, seed=7)).items())
    for (ka, va), (kb, vb) in zip(a, b):
        assert ka == kb
        np.testing.assert_array_equal(va, vb)
    batch = next(TokenStream(cfg, 64, 4, seed=7))
    assert batch["tokens"].shape == (4, 64)
    assert batch["labels"].shape == (4, 64)
    # labels are next-token shifted
    np.testing.assert_array_equal(batch["tokens"][:, 1:], batch["labels"][:, :-1])


def test_vlm_batch_masks_image_positions():
    cfg = get_config("internvl2-76b").reduced()
    b = next(iter(make_batch_iter(cfg, 32, 2)))
    n_img = cfg.vlm.n_image_tokens
    assert b["image_embeds"].shape == (2, n_img, cfg.d_model)
    assert (b["labels"][:, :n_img] == -100).all()
    assert b["labels"].shape[1] == 32 + n_img


def test_sample_prompts_length():
    cfg = get_config("qwen3-0.6b").reduced()
    p = sample_prompts(cfg, n=3, min_tokens=128)
    assert p.shape == (3, 128)
    assert (p >= 0).all() and (p < cfg.vocab_size).all()


def test_dataset_flavours_differ():
    a = next(synthetic_conversations(1, seed=0, dataset="sharegpt"))
    b = next(synthetic_conversations(1, seed=0, dataset="lmsys"))
    assert a["text"] != b["text"]


def test_checkpoint_roundtrip(tmp_path):
    cfg, model, params = reduced_model("qwen3-0.6b")
    opt = init_opt_state(params)
    save_checkpoint(str(tmp_path / "ck"), params, opt, step=17)
    like = {"params": params, "opt": opt}
    loaded, step = load_checkpoint(str(tmp_path / "ck"), like=like)
    assert step == 17
    jax.tree.map(lambda a, b: np.testing.assert_array_equal(
        np.asarray(a), np.asarray(b)), like, loaded)


def test_checkpoint_chunking(tmp_path):
    big = {"w": jnp.arange(2 ** 16, dtype=jnp.float32).reshape(256, 256)}
    save_checkpoint(str(tmp_path / "ck"), big, max_chunk_bytes=1 << 12)
    loaded, _ = load_checkpoint(str(tmp_path / "ck"), like={"params": big})
    np.testing.assert_array_equal(np.asarray(loaded["params"]["w"]),
                                  np.asarray(big["w"]))


def test_adamw_descends_quadratic():
    params = {"w": jnp.array([5.0, -3.0])}
    state = init_opt_state(params)
    cfg = AdamWConfig(lr=0.2, weight_decay=0.0, warmup_steps=1)
    for _ in range(200):
        grads = {"w": params["w"]}  # d/dw (w²/2)
        params, state, _ = adamw_update(params, grads, state, cfg)
    assert float(jnp.abs(params["w"]).max()) < 0.1


def test_adamw_grad_clip():
    params = {"w": jnp.zeros(4)}
    state = init_opt_state(params)
    cfg = AdamWConfig(lr=1e-3, grad_clip=1.0, warmup_steps=1)
    _, _, stats = adamw_update(params, {"w": jnp.full(4, 100.0)}, state, cfg)
    assert float(stats["grad_norm"]) == pytest.approx(200.0)
