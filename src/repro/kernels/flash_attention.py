"""Pallas TPU kernel: causal/windowed flash attention.

The §Roofline analysis shows the pure-JAX chunked attention writes its
(…, kv_chunk) score/probability blocks through HBM every scan step — the
dominant HBM term for the train/prefill shapes.  This kernel keeps the
online-softmax state and score tiles resident in VMEM (the standard
flash-attention structure, tiled for the MXU):

  grid = (B·H, Sq/block_q, Skv/block_k); the kv axis is the sequential
  inner loop so the (block_q, d)/fp32 (m, l, acc) scratch stays live.
  Causal/window masking is positional, so fully-masked kv tiles are
  skipped via ``pl.when`` (no MXU work for the upper triangle / outside
  the sliding window).

Validated in interpret mode against ref.flash_attention_ref; on a real
TPU runtime it replaces chunked_attention for train/prefill.
"""
from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

try:  # pragma: no cover
    from jax.experimental.pallas import tpu as pltpu
    _HAS_PLTPU = True
except ImportError:  # pragma: no cover
    import warnings

    _HAS_PLTPU = False
    warnings.warn(
        "jax.experimental.pallas.tpu unavailable; flash-attention kernels "
        "fall back to interpret-safe scratch allocation",
        RuntimeWarning, stacklevel=2)

NEG_INF = -1e30


def _scratch(shape):
    if _HAS_PLTPU:
        return pltpu.VMEM(shape, jnp.float32)
    raise RuntimeError("pallas TPU backend unavailable")


def _flash_kernel(q_ref, k_ref, v_ref, o_ref, m_ref, l_ref, acc_ref, *,
                  scale: float, causal: bool, window, block_q: int,
                  block_k: int, softcap):
    iq = pl.program_id(1)
    ik = pl.program_id(2)

    @pl.when(ik == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    q_start = iq * block_q
    k_start = ik * block_k

    # tile-level skip: no work if the whole kv tile is masked out
    tile_relevant = True
    if causal:
        tile_relevant = k_start <= q_start + block_q - 1
    if window is not None:
        # newest q in tile attends back `window`; skip tiles fully older
        tile_relevant = jnp.logical_and(
            tile_relevant, k_start + block_k - 1 > q_start - window)

    @pl.when(tile_relevant)
    def _work():
        q = q_ref[0].astype(jnp.float32)          # (bq, d)
        k = k_ref[0].astype(jnp.float32)          # (bk, d)
        v = v_ref[0].astype(jnp.float32)          # (bk, d)
        s = jnp.dot(q, k.T, preferred_element_type=jnp.float32) * scale
        if softcap is not None:
            s = softcap * jnp.tanh(s / softcap)
        qpos = q_start + jax.lax.broadcasted_iota(jnp.int32, s.shape, 0)
        kpos = k_start + jax.lax.broadcasted_iota(jnp.int32, s.shape, 1)
        mask = jnp.ones_like(s, dtype=jnp.bool_)
        if causal:
            mask &= kpos <= qpos
        if window is not None:
            mask &= kpos > qpos - window
        s = jnp.where(mask, s, NEG_INF)

        m_prev = m_ref[...]
        m_new = jnp.maximum(m_prev, jnp.max(s, axis=-1, keepdims=True))
        m_safe = jnp.where(m_new <= NEG_INF / 2, 0.0, m_new)
        p = jnp.exp(s - m_safe)
        p = jnp.where(mask, p, 0.0)
        corr = jnp.exp(jnp.where(m_prev <= NEG_INF / 2, NEG_INF, m_prev)
                       - m_safe)
        corr = jnp.where(m_prev <= NEG_INF / 2, 0.0, corr)
        l_ref[...] = l_ref[...] * corr + jnp.sum(p, axis=-1, keepdims=True)
        acc_ref[...] = (acc_ref[...] * corr
                        + jnp.dot(p, v, preferred_element_type=jnp.float32))
        m_ref[...] = m_new

    @pl.when(ik == pl.num_programs(2) - 1)
    def _done():
        o_ref[0] = (acc_ref[...] / jnp.maximum(l_ref[...], 1e-20)
                    ).astype(o_ref.dtype)


@functools.partial(
    jax.jit, static_argnames=("causal", "window", "attn_softcap",
                              "block_q", "block_k", "interpret"))
def flash_attention(q: jnp.ndarray, k: jnp.ndarray, v: jnp.ndarray, *,
                    causal: bool = True, window: int | None = None,
                    attn_softcap: float | None = None, block_q: int = 128,
                    block_k: int = 128, interpret: bool = True) -> jnp.ndarray:
    """q/k/v: (B, S, H, hd) (same head count — broadcast GQA outside).

    Returns (B, S, H, hd).  Sq must equal Skv (self-attention).
    """
    B, S, H, hd = q.shape
    scale = 1.0 / math.sqrt(hd)
    block_q = min(block_q, S)
    block_k = min(block_k, S)
    pad_q = (-S) % block_q
    pad_k = (-S) % block_k
    pad = max(pad_q, pad_k)
    # use one padded length so q and kv grids stay aligned
    Sp = S + ((-S) % max(block_q, block_k)) if pad else S
    if Sp != S:
        padw = ((0, 0), (0, Sp - S), (0, 0), (0, 0))
        q = jnp.pad(q, padw)
        k = jnp.pad(k, padw)
        v = jnp.pad(v, padw)

    # (B, S, H, hd) → (B·H, S, hd)
    def to_bh(a):
        return a.transpose(0, 2, 1, 3).reshape(B * H, Sp, hd)

    qb, kb, vb = to_bh(q), to_bh(k), to_bh(v)
    grid = (B * H, Sp // block_q, Sp // block_k)

    kern = functools.partial(
        _flash_kernel, scale=scale, causal=causal, window=window,
        block_q=block_q, block_k=block_k, softcap=attn_softcap)
    out = pl.pallas_call(
        kern,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, block_q, hd), lambda b, iq, ik: (b, iq, 0)),
            pl.BlockSpec((1, block_k, hd), lambda b, iq, ik: (b, ik, 0)),
            pl.BlockSpec((1, block_k, hd), lambda b, iq, ik: (b, ik, 0)),
        ],
        out_specs=pl.BlockSpec((1, block_q, hd), lambda b, iq, ik: (b, iq, 0)),
        out_shape=jax.ShapeDtypeStruct((B * H, Sp, hd), q.dtype),
        scratch_shapes=[
            _scratch((block_q, 1)),   # m
            _scratch((block_q, 1)),   # l
            _scratch((block_q, hd)),  # acc
        ],
        interpret=interpret,
    )(qb, kb, vb)
    out = out.reshape(B, H, Sp, hd).transpose(0, 2, 1, 3)
    return out[:, :S]
