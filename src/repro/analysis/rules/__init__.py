"""Rule registry.  A rule is ``(project, config) -> List[Finding]``."""
from __future__ import annotations

from typing import Callable, Iterable, List

from repro.analysis.config import FiddlintConfig
from repro.analysis.core import Finding
from repro.analysis.project import Project
from repro.analysis.rules.fid001_host_sync import check_host_sync
from repro.analysis.rules.fid002_jit_cache import check_jit_cache
from repro.analysis.rules.fid003_refcount import check_refcount
from repro.analysis.rules.fid004_ledger import check_ledger
from repro.analysis.rules.fid005_threads import check_threads
from repro.analysis.rules.fid006_watchdog import check_watchdog
from repro.analysis.rules.fid007_mesh_dispatch import check_mesh_dispatch

Rule = Callable[[Project, FiddlintConfig], List[Finding]]

RULES = {
    "FID001": check_host_sync,
    "FID002": check_jit_cache,
    "FID003": check_refcount,
    "FID004": check_ledger,
    "FID005": check_threads,
    "FID006": check_watchdog,
    "FID007": check_mesh_dispatch,
}


def get_rules(select: Iterable[str]) -> List[Rule]:
    return [RULES[r] for r in RULES if r in set(select)]
