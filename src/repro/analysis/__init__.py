"""fiddlint — repo-specific static analysis for the Fiddler hot-path
invariants.

The compiler cannot see the properties Fiddler's speedups rest on: the
CPU-GPU overlap path must never implicitly sync the device inside the
step loop, jit caches must stay bounded under arbitrary routing, paged
KV blocks must be released on every exit path, every latency source must
be charged to the ledger, and host-pool shared state needs locks.  Each
of those is a FID rule here (see docs/invariants.md):

  FID001  host-sync-in-hot-path
  FID002  jit-cache-explosion
  FID003  block-refcount-escape
  FID004  ledger-charge-completeness
  FID005  unsynchronized-host-pool-state

Run the suite with ``python -m repro.analysis.lint [paths...]``; config
lives in ``[tool.fiddlint]`` in pyproject.toml, grandfathered findings
in the committed baseline file, and inline suppressions use
``# fiddlint: ignore[FID00N] reason``.

The package is deliberately pure-stdlib (ast/json/argparse) so the CLI
and the tier-1 gate test run without importing jax.
"""
from repro.analysis.config import FiddlintConfig, load_config
from repro.analysis.core import Baseline, Finding, run_lint
from repro.analysis.project import Project

__all__ = [
    "Baseline",
    "FiddlintConfig",
    "Finding",
    "Project",
    "load_config",
    "run_lint",
]
