"""Compiled-artifact analysis: roofline terms from the dry-run.

No wall-clock measurement happens here (the container is CPU-only; TPU v5e
is the *target*).  The three roofline terms are derived from the compiled
executable:

  compute    = HLO_FLOPs / peak_FLOPs            (per device)
  memory     = HLO_bytes / HBM_bw                (per device)
  collective = collective_bytes / ICI link bw    (per device)

``cost_analysis()`` provides flops/bytes of the partitioned per-device
module; collective bytes are NOT in cost_analysis, so we parse the
optimized HLO and sum result-shape bytes of every collective op.
"""
from __future__ import annotations

import re
import warnings
from dataclasses import dataclass, field
from typing import Dict, Optional

# hardware constants given by the assignment (TPU v5e-class)
PEAK_FLOPS = 197e12      # bf16 per chip
HBM_BW = 819e9           # bytes/s per chip
ICI_BW = 50e9            # bytes/s per link

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "bf16": 2, "f16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8, "c64": 8,
    "c128": 16, "f8e4m3fn": 1, "f8e5m2": 1,
}

_COLLECTIVES = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
                "collective-permute")

# one result shape, e.g. f32[16,128]{1,0} or bf16[]
_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")


def _shape_bytes(dtype: str, dims: str) -> int:
    b = _DTYPE_BYTES.get(dtype)
    if b is None:
        return 0
    n = 1
    if dims:
        for d in dims.split(","):
            n *= int(d)
    return n * b


def collective_bytes(hlo_text: str) -> Dict[str, float]:
    """Sum result-shape bytes per collective kind from optimized HLO."""
    out: Dict[str, float] = {k: 0.0 for k in _COLLECTIVES}
    for line in hlo_text.splitlines():
        stripped = line.strip()
        # result = SHAPE op-name(...)    (also tuple results)
        m = re.match(r"%?[\w.\-]+\s*=\s*(\(?.*?\)?)\s+([\w\-]+)", stripped)
        if not m:
            continue
        shapes_str, op = m.groups()
        kind = None
        for c in _COLLECTIVES:
            if op == c or op.startswith(c + "-start") or op.startswith(c + "."):
                kind = c
                break
        if kind is None:
            continue
        total = sum(_shape_bytes(dt, dims)
                    for dt, dims in _SHAPE_RE.findall(shapes_str))
        out[kind] += float(total)
    out["total"] = sum(out[k] for k in _COLLECTIVES)
    return out


@dataclass
class Roofline:
    arch: str
    shape: str
    mesh: str
    flops: float                  # per-device HLO flops
    hbm_bytes: float              # per-device HLO bytes accessed
    coll_bytes: float             # per-device collective bytes
    coll_breakdown: Dict[str, float] = field(default_factory=dict)
    peak_memory: Optional[float] = None  # bytes per device (memory_analysis)
    model_flops: float = 0.0      # 6·N_active·D analytic

    @property
    def t_compute(self) -> float:
        return self.flops / PEAK_FLOPS

    @property
    def t_memory(self) -> float:
        return self.hbm_bytes / HBM_BW

    @property
    def t_collective(self) -> float:
        return self.coll_bytes / ICI_BW

    @property
    def bottleneck(self) -> str:
        terms = {"compute": self.t_compute, "memory": self.t_memory,
                 "collective": self.t_collective}
        return max(terms, key=terms.get)

    @property
    def useful_flops_ratio(self) -> float:
        """MODEL_FLOPS / HLO_FLOPs (per-device model share) — catches
        remat/dispatch waste; >1 means XLA did less than the analytic
        count (e.g. skipped work), <1 means redundancy."""
        return self.model_flops / self.flops if self.flops else 0.0

    def row(self) -> Dict[str, object]:
        return {
            "arch": self.arch, "shape": self.shape, "mesh": self.mesh,
            "t_compute_s": self.t_compute, "t_memory_s": self.t_memory,
            "t_collective_s": self.t_collective,
            "bottleneck": self.bottleneck,
            "flops": self.flops, "hbm_bytes": self.hbm_bytes,
            "coll_bytes": self.coll_bytes,
            "peak_memory_GiB": (self.peak_memory or 0) / 2**30,
            "useful_ratio": self.useful_flops_ratio,
        }


def analyze_compiled(compiled, arch: str, shape: str, mesh_name: str,
                     n_devices: int, model_flops_total: float) -> Roofline:
    ca = compiled.cost_analysis() or {}
    if isinstance(ca, list):  # some jax versions return [dict]
        ca = ca[0] if ca else {}
    flops = float(ca.get("flops", 0.0))
    hbm = float(ca.get("bytes accessed", 0.0))
    try:
        hlo = compiled.as_text()
    except (NotImplementedError, RuntimeError, AttributeError) as e:
        # some backends/jax versions can't render the optimized HLO —
        # collective bytes then read as 0, which must not pass silently
        warnings.warn(
            f"compiled.as_text() unavailable ({type(e).__name__}: {e}); "
            f"collective-bytes roofline term will be 0",
            RuntimeWarning, stacklevel=2)
        hlo = ""
    coll = collective_bytes(hlo)
    mem = None
    try:
        ma = compiled.memory_analysis()
        mem = (getattr(ma, "temp_size_in_bytes", 0)
               + getattr(ma, "argument_size_in_bytes", 0)
               + getattr(ma, "output_size_in_bytes", 0)
               - getattr(ma, "alias_size_in_bytes", 0))
    except (NotImplementedError, RuntimeError, AttributeError) as e:
        warnings.warn(
            f"compiled.memory_analysis() unavailable "
            f"({type(e).__name__}: {e}); peak_memory will be absent",
            RuntimeWarning, stacklevel=2)
    return Roofline(
        arch=arch, shape=shape, mesh=mesh_name,
        flops=flops, hbm_bytes=hbm,
        coll_bytes=coll["total"], coll_breakdown=coll,
        peak_memory=mem,
        model_flops=model_flops_total / n_devices,
    )


def model_flops(cfg, shape_kind: str, n_tokens: int) -> float:
    """Analytic MODEL_FLOPS: 6·N_active·D for training, 2·N_active·D for
    inference forward (decode counts one new token per sequence)."""
    n_active = cfg.active_param_count()
    mult = 6.0 if shape_kind == "train" else 2.0
    return mult * n_active * n_tokens
