"""AdamW in pure JAX (no optax dependency).

Moments are fp32 regardless of parameter dtype; the state pytree mirrors
the parameter pytree so the same sharding rules apply (and can additionally
be ZeRO-sharded over the data axes — see distributed/sharding.py).
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Any, Dict, Tuple

import jax
import jax.numpy as jnp


@dataclass(frozen=True)
class AdamWConfig:
    lr: float = 3e-4
    beta1: float = 0.9
    beta2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    grad_clip: float = 1.0
    warmup_steps: int = 100


def init_opt_state(params) -> Dict[str, Any]:
    def zeros(p):
        return jnp.zeros(p.shape, jnp.float32)
    return {
        "m": jax.tree.map(zeros, params),
        "v": jax.tree.map(zeros, params),
        "step": jnp.zeros((), jnp.int32),
    }


def _schedule(cfg: AdamWConfig, step: jnp.ndarray) -> jnp.ndarray:
    warm = jnp.minimum(step.astype(jnp.float32) / max(cfg.warmup_steps, 1), 1.0)
    return cfg.lr * warm


def global_norm(tree) -> jnp.ndarray:
    leaves = jax.tree.leaves(tree)
    return jnp.sqrt(sum(jnp.sum(jnp.square(l.astype(jnp.float32)))
                        for l in leaves))


def adamw_update(params, grads, state, cfg: AdamWConfig
                 ) -> Tuple[Any, Dict[str, Any], Dict[str, jnp.ndarray]]:
    step = state["step"] + 1
    lr = _schedule(cfg, step)
    gnorm = global_norm(grads)
    scale = jnp.minimum(1.0, cfg.grad_clip / jnp.maximum(gnorm, 1e-12))

    b1, b2 = cfg.beta1, cfg.beta2
    c1 = 1.0 - b1 ** step.astype(jnp.float32)
    c2 = 1.0 - b2 ** step.astype(jnp.float32)

    def upd(p, g, m, v):
        g = g.astype(jnp.float32) * scale
        m_new = b1 * m + (1 - b1) * g
        v_new = b2 * v + (1 - b2) * jnp.square(g)
        mh = m_new / c1
        vh = v_new / c2
        delta = mh / (jnp.sqrt(vh) + cfg.eps)
        if p.ndim >= 2:  # decoupled weight decay on matrices only
            delta = delta + cfg.weight_decay * p.astype(jnp.float32)
        return (p.astype(jnp.float32) - lr * delta).astype(p.dtype), m_new, v_new

    flat_p, treedef = jax.tree.flatten(params)
    flat_g = treedef.flatten_up_to(grads)
    flat_m = treedef.flatten_up_to(state["m"])
    flat_v = treedef.flatten_up_to(state["v"])
    out = [upd(p, g, m, v) for p, g, m, v in zip(flat_p, flat_g, flat_m, flat_v)]
    new_p = treedef.unflatten([o[0] for o in out])
    new_m = treedef.unflatten([o[1] for o in out])
    new_v = treedef.unflatten([o[2] for o in out])
    return new_p, {"m": new_m, "v": new_v, "step": step}, {
        "grad_norm": gnorm, "lr": lr}
