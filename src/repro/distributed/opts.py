"""Beyond-paper distribution optimization knobs (EXPERIMENTS.md §Perf).

Defaults are the paper-faithful / first-working baseline; the perf pass
flips them per experiment and records before/after.  Env override:
``REPRO_OPTS=fsdp_experts,seq_shard_acts,split_ssm_proj``.
"""
import os

# FSDP-style expert weights: shard the per-expert d_ff (ep mode) or d_model
# (tp mode) dimension over the data axes in addition to the expert/model
# sharding; all-gather one layer's experts inside the shard_map body.
# Cuts resident expert bytes by the data-axis size (kimi decode:
# 125 GB/dev → ~8 GB/dev) at the cost of a per-layer all-gather.
FSDP_EXPERTS = False

# Megatron-style sequence parallelism for the residual stream: activations
# (and the scan's layer-input remat carries) are sharded over `model` on
# the sequence axis between blocks.  Cuts train activation memory by the
# model-axis size; SPMD inserts gather/reduce-scatter pairs around qkv.
SEQ_SHARD_ACTS = False

# Store the Mamba2 input projection as three separate matrices (z / xBC /
# dt) instead of one fused (d, 2·inner+2·g·st+nh) matrix whose column
# split straddles shard boundaries and forces resharding collectives.
SPLIT_SSM_PROJ = False


# Keep K/V tiles and the post-softmax probabilities of chunked attention
# in bf16 (fp32 max/sum statistics and accumulator are kept): roughly
# halves the dominant (…, kv_chunk) HBM traffic of the train/prefill
# shapes at bf16-level numerics.
BF16_ATTN_SCORES = False


def apply_env() -> None:
    opts = os.environ.get("REPRO_OPTS", "")
    g = globals()
    for name in opts.split(","):
        name = name.strip().upper()
        if name and name in g:
            g[name] = True


apply_env()
