"""Batched serving engine: request queue → grouped prefill + decode.

Requests are grouped into static batches (padded prompts), prefilled once,
then decoded until EOS/max-tokens.  Execution goes through the common
``ServingBackend`` protocol (see serving/backend.py): the monolithic
jitted ``Model`` (capacity-sufficient regime) or the ``FiddlerEngine``
orchestrator (fast/slow-tier regime — the paper's setting).  Per-request
TTFT/ITL are recorded from the backend's clock — the engine's simulated
seconds when orchestrated, wall-clock otherwise.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import List, Optional

import jax
import numpy as np

from repro.data.tokenizer import EOS_ID, PAD_ID
from repro.serving.backend import ServingBackend, as_backend
from repro.serving.sampler import greedy, sample


@dataclass
class Request:
    rid: str
    prompt: List[int]
    max_new_tokens: int = 32
    temperature: float = 0.0
    arrival: Optional[float] = None     # backend-clock submit/arrival time
    # outputs
    output: List[int] = field(default_factory=list)
    token_times: List[float] = field(default_factory=list)
    ttft: Optional[float] = None
    latency: Optional[float] = None

    @property
    def itl(self) -> Optional[float]:
        """Mean inter-token latency (backend-clock seconds/token)."""
        if len(self.token_times) < 2:
            return None
        return float(self.token_times[-1] - self.token_times[0]) \
            / (len(self.token_times) - 1)


class ServingEngine:
    def __init__(self, backend, *, mode: Optional[str] = None, params=None,
                 max_batch: int = 8, max_seq: int = 512, seed: int = 0):
        """``backend``: a ``ServingBackend``, a ``Model`` (with ``params``;
        mode="model") or a ``FiddlerEngine`` (mode="fiddler")."""
        assert mode in (None, "model", "fiddler")
        self.raw_backend = backend
        self._backend: ServingBackend = as_backend(
            backend, params=params, mode=mode, max_seq=max_seq)
        from repro.serving.backend import FiddlerBackend

        self.mode = ("fiddler" if isinstance(self._backend, FiddlerBackend)
                     else "model")
        self.max_batch = max_batch
        self.max_seq = max_seq
        self.queue: List[Request] = []
        self.key = jax.random.PRNGKey(seed)

    @property
    def backend(self):
        """The execution engine as passed in (back-compat: launchers read
        ``engine.backend.ledger`` for the orchestrated path)."""
        return self.raw_backend

    def submit(self, req: Request) -> None:
        if req.arrival is None:
            req.arrival = self._backend.clock()
        self.queue.append(req)

    # ------------------------------------------------------------------
    def _clock(self) -> float:
        return self._backend.clock()

    def _run_group(self, group: List[Request]) -> None:
        B = len(group)
        S = max(len(r.prompt) for r in group)
        prompts = np.full((B, S), PAD_ID, np.int32)
        for i, r in enumerate(group):
            prompts[i, S - len(r.prompt):] = r.prompt  # left-pad
        logits, cache = self._backend.prefill_group(prompts)
        t_first = self._clock()
        for r in group:
            r.ttft = t_first - r.arrival

        done = np.zeros(B, bool)
        n_steps = min(max(r.max_new_tokens for r in group),
                      self.max_seq - S)
        for step in range(n_steps):
            if group[0].temperature > 0:
                self.key, sub = jax.random.split(self.key)
                tok = sample(logits, sub, group[0].temperature)
            else:
                tok = greedy(logits)
            now = self._clock()
            for i, r in enumerate(group):
                if not done[i]:
                    r.output.append(int(tok[i]))
                    r.token_times.append(now)
                    if tok[i] == EOS_ID or len(r.output) >= r.max_new_tokens:
                        done[i] = True
            if done.all():
                break
            pos = S + step
            logits, cache = self._backend.decode_group(cache, tok, pos)
        t_end = self._clock()
        for r in group:
            r.latency = t_end - r.arrival

    def run(self) -> List[Request]:
        """Drain the queue in static batches of ≤ max_batch."""
        finished: List[Request] = []
        while self.queue:
            group = self.queue[: self.max_batch]
            self.queue = self.queue[self.max_batch:]
            # a batch can only start once its last member has arrived
            latest = max(r.arrival for r in group if r.arrival is not None)
            if latest > self._backend.clock():
                self._backend.wait_until(latest)
            self._run_group(group)
            finished.extend(group)
        return finished
