"""Whisper large-v3 [arXiv:2212.04356] — enc-dec, conv frontend STUBBED.

Decoder backbone: 32L d_model=1280 20H (kv=20, MHA) d_ff=5120 vocab=51866.
Encoder: 32L same width; the mel-spectrogram + conv feature extractor is a
stub — input_specs() provides precomputed frame embeddings (1500, d_model).
"""
from repro.configs.base import EncDecConfig, ModelConfig, register


@register("whisper-large-v3")
def whisper_large_v3() -> ModelConfig:
    return ModelConfig(
        name="whisper-large-v3",
        arch_type="audio",
        n_layers=32,
        d_model=1280,
        n_heads=20,
        n_kv_heads=20,
        head_dim=64,
        d_ff=5120,
        vocab_size=51866,
        act="gelu",
        encdec=EncDecConfig(n_encoder_layers=32, n_audio_frames=1500),
        tie_embeddings=True,
        citation="[arXiv:2212.04356] Robust Speech Recognition (Whisper)",
    )
