"""Common layers: norms, RoPE, gated MLP, softcap, initializers.

Everything is pure-functional: ``init_*`` returns a param pytree,
``apply`` functions take (params, inputs) and are jit/pjit friendly.
"""
from __future__ import annotations

import math
from typing import Any, Dict, Optional

import jax
import jax.numpy as jnp

Params = Dict[str, Any]


# ---------------------------------------------------------------------------
# Initializers
# ---------------------------------------------------------------------------


def dense_init(key, shape, in_axis: int = 0, dtype=jnp.float32):
    """Truncated-normal fan-in init (matches common LLM practice)."""
    fan_in = shape[in_axis]
    std = 1.0 / math.sqrt(fan_in)
    return (jax.random.truncated_normal(key, -2.0, 2.0, shape, jnp.float32) * std).astype(dtype)


def embed_init(key, shape, dtype=jnp.float32):
    return (jax.random.normal(key, shape, jnp.float32) * 0.02).astype(dtype)


# ---------------------------------------------------------------------------
# Norms
# ---------------------------------------------------------------------------


def init_rmsnorm(d: int, dtype=jnp.float32) -> Params:
    return {"scale": jnp.ones((d,), dtype)}


def rmsnorm(params: Params, x: jnp.ndarray, eps: float = 1e-6) -> jnp.ndarray:
    dtype = x.dtype
    x = x.astype(jnp.float32)
    var = jnp.mean(jnp.square(x), axis=-1, keepdims=True)
    x = x * jax.lax.rsqrt(var + eps)
    return (x * params["scale"].astype(jnp.float32)).astype(dtype)


def init_layernorm(d: int, dtype=jnp.float32) -> Params:
    return {"scale": jnp.ones((d,), dtype), "bias": jnp.zeros((d,), dtype)}


def layernorm(params: Params, x: jnp.ndarray, eps: float = 1e-5) -> jnp.ndarray:
    dtype = x.dtype
    x = x.astype(jnp.float32)
    mu = jnp.mean(x, axis=-1, keepdims=True)
    var = jnp.mean(jnp.square(x - mu), axis=-1, keepdims=True)
    y = (x - mu) * jax.lax.rsqrt(var + eps)
    return (y * params["scale"] + params["bias"]).astype(dtype)


# ---------------------------------------------------------------------------
# Softcap (gemma2)
# ---------------------------------------------------------------------------


def softcap(x: jnp.ndarray, cap: Optional[float]) -> jnp.ndarray:
    if cap is None:
        return x
    return cap * jnp.tanh(x / cap)


# ---------------------------------------------------------------------------
# Activations
# ---------------------------------------------------------------------------


def activation(name: str):
    if name == "silu":
        return jax.nn.silu
    if name == "gelu":
        return lambda x: jax.nn.gelu(x, approximate=True)
    raise ValueError(f"unknown activation {name}")


# ---------------------------------------------------------------------------
# Rotary position embedding
# ---------------------------------------------------------------------------


def rope_freqs(head_dim: int, theta: float) -> jnp.ndarray:
    return 1.0 / (theta ** (jnp.arange(0, head_dim, 2, dtype=jnp.float32) / head_dim))


def apply_rope(x: jnp.ndarray, positions: jnp.ndarray, theta: float) -> jnp.ndarray:
    """x: (..., seq, heads, head_dim); positions: (..., seq)."""
    head_dim = x.shape[-1]
    freqs = rope_freqs(head_dim, theta)  # (hd/2,)
    angles = positions[..., :, None].astype(jnp.float32) * freqs  # (..., seq, hd/2)
    cos = jnp.cos(angles)[..., :, None, :]  # (..., seq, 1, hd/2)
    sin = jnp.sin(angles)[..., :, None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return out.astype(x.dtype)


# ---------------------------------------------------------------------------
# Gated MLP (dense FFN / a single expert)
# ---------------------------------------------------------------------------


def init_gated_mlp(key, d_model: int, d_ff: int, dtype=jnp.float32) -> Params:
    k1, k2, k3 = jax.random.split(key, 3)
    return {
        "w_gate": dense_init(k1, (d_model, d_ff), 0, dtype),
        "w_up": dense_init(k2, (d_model, d_ff), 0, dtype),
        "w_down": dense_init(k3, (d_ff, d_model), 0, dtype),
    }


def gated_mlp(params: Params, x: jnp.ndarray, act: str = "silu") -> jnp.ndarray:
    a = activation(act)
    h = a(x @ params["w_gate"]) * (x @ params["w_up"])
    return h @ params["w_down"]
