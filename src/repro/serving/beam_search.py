"""Beam-search decoding (paper scenario ⓒ).

The beams form a decode batch of width W; per MoE layer the router sees
W tokens, so per-expert input sizes grow with the width — exactly the
regime where Fiddler's planner beats llama.cpp-style static splits (the
paper's 11.57× result).  Works over either the monolithic ``Model`` or the
``FiddlerEngine`` orchestrator (same decode-step signature shape).
"""
from __future__ import annotations

from dataclasses import dataclass

import jax
import jax.numpy as jnp
import numpy as np

from repro.serving.sampler import log_softmax


@dataclass
class BeamResult:
    tokens: np.ndarray      # (width, n_new)
    scores: np.ndarray      # (width,)


def _gather_cache(cache, idx: np.ndarray):
    """Reorder the batch dimension of every cache leaf after beam reshuffle."""
    arr = jnp.asarray(idx)

    def g(leaf):
        return jnp.take(leaf, arr, axis=0) if hasattr(leaf, "ndim") and leaf.ndim else leaf

    return jax.tree.map(g, cache)


def beam_search_model(model, params, prompt: np.ndarray, width: int,
                      n_new: int, max_seq: int) -> BeamResult:
    """prompt: (1, S) int32.  Standard length-normalised beam search."""
    S = prompt.shape[1]
    prompts = np.repeat(prompt, width, axis=0)  # (W, S)
    prefill = jax.jit(lambda p, t: model.prefill(p, t, max_seq))
    decode = jax.jit(lambda p, c, t, pos: model.decode_step(p, c, t, pos, max_seq))

    logits, cache = prefill(params, jnp.asarray(prompts))
    logp = np.asarray(log_softmax(logits))  # (W, V)
    V = logp.shape[-1]
    # first step: distinct top-W continuations of beam 0
    first = np.argsort(-logp[0])[:width]
    scores = logp[0, first]
    tokens = first[:, None].astype(np.int32)  # (W, 1)

    for step in range(1, n_new):
        pos = S + step - 1
        logits, cache = decode(params, cache,
                               jnp.asarray(tokens[:, -1:]), jnp.int32(pos))
        lp = np.asarray(log_softmax(logits))  # (W, V)
        cand = scores[:, None] + lp           # (W, V)
        flat = cand.reshape(-1)
        top = np.argsort(-flat)[:width]
        beam_idx, tok_idx = np.divmod(top, V)
        scores = flat[top]
        tokens = np.concatenate(
            [tokens[beam_idx], tok_idx[:, None].astype(np.int32)], axis=1)
        cache = model.reorder_cache(cache, beam_idx)
    return BeamResult(tokens=tokens, scores=scores)


def beam_search_fiddler(engine, prompt: np.ndarray, width: int, n_new: int,
                        max_seq: int) -> BeamResult:
    """Beam search through the Fiddler orchestrator (real numerics +
    simulated-latency ledger)."""
    S = prompt.shape[1]
    prompts = np.repeat(prompt, width, axis=0)
    logits, caches = engine.prefill(jnp.asarray(prompts), max_seq)
    logp = np.asarray(log_softmax(logits))
    V = logp.shape[-1]
    first = np.argsort(-logp[0])[:width]
    scores = logp[0, first]
    tokens = first[:, None].astype(np.int32)

    for step in range(1, n_new):
        pos = S + step - 1
        logits, caches = engine.decode_step(
            caches, jnp.asarray(tokens[:, -1:]), pos, max_seq)
        lp = np.asarray(log_softmax(logits))
        cand = scores[:, None] + lp
        flat = cand.reshape(-1)
        top = np.argsort(-flat)[:width]
        beam_idx, tok_idx = np.divmod(top, V)
        scores = flat[top]
        tokens = np.concatenate(
            [tokens[beam_idx], tok_idx[:, None].astype(np.int32)], axis=1)
        caches = [_gather_cache(c, beam_idx) for c in caches]
    return BeamResult(tokens=tokens, scores=scores)
