"""Attention: GQA/MHA with RoPE, qk-norm, sliding windows, softcap.

Two execution paths share one mask convention:

* ``chunked_attention`` — training / prefill.  A lax.scan over KV chunks
  with an online-softmax accumulator (flash-attention recurrence in pure
  JAX), so the (Sq, Skv) score matrix is never materialised.  This is what
  the multi-pod dry-run lowers; the Pallas kernel in
  ``repro.kernels.flash_attention`` is the TPU-optimised equivalent and is
  validated against the same reference.

* ``decode_attention`` — single-query decode against a (ring-buffer) KV
  cache.  Direct einsum; memory is O(B·H·W) which is small for Sq == 1, and
  the KV-sequence axis can be sharded (sequence-parallel decode — XLA SPMD
  partitions the softmax reductions).
"""
from __future__ import annotations

import math
from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig
from repro.models import kv_cache as kvc
from repro.models.layers import Params, apply_rope, dense_init, init_rmsnorm, rmsnorm, softcap
from repro.models.paged_kv import PagedLayerCache, PagedSlotStage

NEG_INF = -1e30

# Default KV chunk for the online-softmax scan.  The roofline analysis mode
# raises this to a single trip so XLA's cost_analysis (which counts a while
# body once) sees the exact FLOPs/bytes; production lowering keeps chunks
# small for activation memory.
KV_CHUNK_DEFAULT = 1024


# ---------------------------------------------------------------------------
# Params
# ---------------------------------------------------------------------------


def init_attention(key, cfg: ModelConfig, dtype=jnp.float32) -> Params:
    k1, k2, k3, k4 = jax.random.split(key, 4)
    p = {
        "wq": dense_init(k1, (cfg.d_model, cfg.n_heads * cfg.head_dim), 0, dtype),
        "wk": dense_init(k2, (cfg.d_model, cfg.n_kv_heads * cfg.head_dim), 0, dtype),
        "wv": dense_init(k3, (cfg.d_model, cfg.n_kv_heads * cfg.head_dim), 0, dtype),
        "wo": dense_init(k4, (cfg.n_heads * cfg.head_dim, cfg.d_model), 0, dtype),
    }
    if cfg.qk_norm:
        p["q_norm"] = init_rmsnorm(cfg.head_dim, dtype)
        p["k_norm"] = init_rmsnorm(cfg.head_dim, dtype)
    return p


def qkv_proj(params: Params, x: jnp.ndarray, cfg: ModelConfig,
             positions: jnp.ndarray, rope: bool = True
             ) -> Tuple[jnp.ndarray, jnp.ndarray, jnp.ndarray]:
    """x: (B, S, d) → q (B,S,H,hd), k/v (B,S,KV,hd) with RoPE + qk-norm."""
    B, S, _ = x.shape
    q = (x @ params["wq"]).reshape(B, S, cfg.n_heads, cfg.head_dim)
    k = (x @ params["wk"]).reshape(B, S, cfg.n_kv_heads, cfg.head_dim)
    v = (x @ params["wv"]).reshape(B, S, cfg.n_kv_heads, cfg.head_dim)
    if cfg.qk_norm:
        q = rmsnorm(params["q_norm"], q, cfg.norm_eps)
        k = rmsnorm(params["k_norm"], k, cfg.norm_eps)
    if rope:
        q = apply_rope(q, positions, cfg.rope_theta)
        k = apply_rope(k, positions, cfg.rope_theta)
    return q, k, v


# ---------------------------------------------------------------------------
# Chunked (flash-style) attention — train / prefill
# ---------------------------------------------------------------------------


def chunked_attention(
    q: jnp.ndarray,               # (B, Sq, H, hd)
    k: jnp.ndarray,               # (B, Skv, KV, hd)
    v: jnp.ndarray,               # (B, Skv, KV, hd)
    q_positions: jnp.ndarray,     # (B, Sq)
    kv_positions: jnp.ndarray,    # (B, Skv)
    *,
    causal: bool = True,
    window: Optional[int] = None,
    attn_softcap: Optional[float] = None,
    kv_chunk: Optional[int] = None,
) -> jnp.ndarray:
    """Online-softmax attention over KV chunks; never builds (Sq, Skv)."""
    if kv_chunk is None:
        kv_chunk = KV_CHUNK_DEFAULT
    B, Sq, H, hd = q.shape
    Skv, KV = k.shape[1], k.shape[2]
    G = H // KV
    scale = 1.0 / math.sqrt(hd)

    kv_chunk = min(kv_chunk, Skv)
    pad = (-Skv) % kv_chunk
    if pad:
        k = jnp.pad(k, ((0, 0), (0, pad), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, pad), (0, 0), (0, 0)))
        kv_positions = jnp.pad(kv_positions, ((0, 0), (0, pad)), constant_values=-1)
    n_chunks = (Skv + pad) // kv_chunk

    from repro.distributed import opts

    io_dtype = jnp.bfloat16 if opts.BF16_ATTN_SCORES else jnp.float32
    qf = q.astype(io_dtype).reshape(B, Sq, KV, G, hd)
    kc = k.astype(io_dtype).reshape(B, n_chunks, kv_chunk, KV, hd)
    vc = v.astype(io_dtype).reshape(B, n_chunks, kv_chunk, KV, hd)
    pc = kv_positions.reshape(B, n_chunks, kv_chunk)

    def body(carry, inp):
        m, l, acc = carry
        k_i, v_i, p_i = inp  # (B, C, KV, hd), (B, C, KV, hd), (B, C)
        # scores: (B, Sq, KV, G, C) — fp32 statistics regardless of io dtype
        s = jnp.einsum("bqkgh,bckh->bqkgc", qf, k_i,
                       preferred_element_type=jnp.float32) * scale
        if attn_softcap is not None:
            s = softcap(s, attn_softcap)
        valid = p_i[:, None, :] >= 0  # (B, 1, C)
        mask = valid
        if causal:
            mask = mask & (p_i[:, None, :] <= q_positions[:, :, None])
        if window is not None:
            mask = mask & (p_i[:, None, :] > q_positions[:, :, None] - window)
        s = jnp.where(mask[:, :, None, None, :], s, NEG_INF)
        m_i = jnp.max(s, axis=-1)  # (B, Sq, KV, G)
        m_new = jnp.maximum(m, m_i)
        # guard fully-masked rows (m_new == NEG_INF)
        m_safe = jnp.where(m_new <= NEG_INF / 2, 0.0, m_new)
        p = jnp.exp(s - m_safe[..., None])
        p = jnp.where(mask[:, :, None, None, :], p, 0.0)
        corr = jnp.exp(jnp.where(m <= NEG_INF / 2, NEG_INF, m) - m_safe)
        corr = jnp.where(m <= NEG_INF / 2, 0.0, corr)
        l_new = l * corr + jnp.sum(p, axis=-1)
        acc_new = acc * corr[..., None] + jnp.einsum(
            "bqkgc,bckh->bqkgh", p.astype(io_dtype), v_i,
            preferred_element_type=jnp.float32)
        return (m_new, l_new, acc_new), None

    m0 = jnp.full((B, Sq, KV, G), NEG_INF, jnp.float32)
    l0 = jnp.zeros((B, Sq, KV, G), jnp.float32)
    acc0 = jnp.zeros((B, Sq, KV, G, hd), jnp.float32)
    # remat per KV chunk: the (…, C) score/probability blocks are
    # recomputed in the backward instead of being saved for every chunk —
    # the flash-attention memory property, in pure JAX.
    (m, l, acc), _ = jax.lax.scan(
        jax.checkpoint(body, prevent_cse=False), (m0, l0, acc0),
        (kc.swapaxes(0, 1), vc.swapaxes(0, 1), pc.swapaxes(0, 1)),
    )
    out = acc / jnp.maximum(l, 1e-20)[..., None]
    return out.reshape(B, Sq, H, hd).astype(q.dtype)


# ---------------------------------------------------------------------------
# Decode attention — single query vs KV cache
# ---------------------------------------------------------------------------


def decode_attention(
    q: jnp.ndarray,              # (B, 1, H, hd)
    cache: Dict[str, jnp.ndarray],
    q_positions: jnp.ndarray,    # (B, 1)
    *,
    window: Optional[int] = None,
    attn_softcap: Optional[float] = None,
) -> jnp.ndarray:
    from repro.distributed import opts

    B, Sq, H, hd = q.shape
    KV = cache["k"].shape[2]
    G = H // KV
    scale = 1.0 / math.sqrt(hd)
    io_dtype = jnp.bfloat16 if opts.BF16_ATTN_SCORES else jnp.float32
    qf = q.astype(io_dtype).reshape(B, Sq, KV, G, hd)
    kf = cache["k"].astype(io_dtype)
    vf = cache["v"].astype(io_dtype)
    # scores in fp32 (stable softmax stats) from io-dtype operands
    s = jnp.einsum("bqkgh,bwkh->bqkgw", qf, kf,
                   preferred_element_type=jnp.float32) * scale
    if attn_softcap is not None:
        s = softcap(s, attn_softcap)
    pos = cache["pos"]  # (B, W)
    mask = (pos[:, None, :] >= 0) & (pos[:, None, :] <= q_positions[:, :, None])
    if window is not None:
        mask = mask & (pos[:, None, :] > q_positions[:, :, None] - window)
    s = jnp.where(mask[:, :, None, None, :], s, NEG_INF)
    p = jax.nn.softmax(s, axis=-1)
    out = jnp.einsum("bqkgw,bwkh->bqkgh", p.astype(io_dtype), vf,
                     preferred_element_type=jnp.float32)
    return out.reshape(B, Sq, H, hd).astype(q.dtype)


# ---------------------------------------------------------------------------
# Full attention block ops used by model.py
# ---------------------------------------------------------------------------


def attention_block(
    params: Params,
    x: jnp.ndarray,
    positions: jnp.ndarray,
    cfg: ModelConfig,
    layer_idx: int,
    *,
    mode: str = "train",            # "train" | "prefill" | "decode"
    cache: Optional[Dict[str, jnp.ndarray]] = None,
    max_seq: Optional[int] = None,
    causal: bool = True,
    rope: bool = True,
    kv_chunk: Optional[int] = None,
    active: Optional[np.ndarray] = None,
) -> Tuple[jnp.ndarray, Optional[Dict[str, jnp.ndarray]]]:
    """Self-attention with optional cache. Returns (out, new_cache).

    * train   → pure forward, no cache.
    * prefill → fresh prompt at positions 0..S-1, writes the ring buffer.
    * decode  → one token; ``positions`` is (B, 1) with identical scalar
                value per row (static-batched decode).

    ``cache`` is either the dense ring-buffer pytree (kv_cache.py — the
    jit-traceable layout) or a :class:`PagedLayerCache` (paged_kv.py):
    paged caches write through their block table (copy-on-write on shared
    blocks) and attention reads the gathered dense view, which keeps the
    two layouts bit-identical on fp32.  ``active`` (host bool mask, paged
    only) skips writes of padding rows so idle serving slots never
    allocate blocks; the dense layout keeps its write-everything scatter
    (idle rows are unread padding there).
    """
    B, S, _ = x.shape
    window = None
    eff_max = max_seq if max_seq is not None else S
    w = kvc.layer_window(cfg, layer_idx, eff_max)
    if w < eff_max:
        window = w

    q, k, v = qkv_proj(params, x, cfg, positions, rope=rope)
    paged = isinstance(cache, (PagedLayerCache, PagedSlotStage))

    if mode == "train":
        out = chunked_attention(
            q, k, v, positions, positions, causal=causal, window=window,
            attn_softcap=cfg.attn_softcap, kv_chunk=kv_chunk)
        new_cache = None
    elif mode == "prefill":
        assert cache is not None
        if paged:
            cache.write_prefill(k, v)
            new_cache = cache
        else:
            new_cache = kvc.write_prefill(cache, k, v)
        out = chunked_attention(
            q, k, v, positions, positions, causal=causal, window=window,
            attn_softcap=cfg.attn_softcap, kv_chunk=kv_chunk)
    elif mode == "prefill_chunk":
        # chunked prefill: append this chunk at ``positions`` (B, S), then
        # attend against the whole cache (earlier chunks + this one; intra-
        # chunk causality falls out of the position mask)
        assert cache is not None
        if paged:
            # fiddlint: ignore[FID001] positions arrive host-resident from
            # the scheduler (asarray is a no-op view); block-table writes
            # are host metadata by design
            cache.write_prefill_chunk(k, v, np.asarray(positions), active)
            new_cache, kv_read = cache, cache.view()
        else:
            new_cache = kvc.write_prefill_chunk(cache, k, v, positions)
            kv_read = new_cache
        out = decode_attention(
            q, kv_read, positions, window=window,
            attn_softcap=cfg.attn_softcap)
    elif mode == "decode":
        assert cache is not None and S == 1
        if paged:
            # fiddlint: ignore[FID001] positions are host ints from the scheduler; asarray does not touch the device
            cache.write_decode(k, v, np.asarray(positions[:, 0]), active)
            new_cache, kv_read = cache, cache.view()
        else:
            new_cache = kvc.write_decode(cache, k, v, positions[0, 0])
            kv_read = new_cache
        out = decode_attention(
            q, kv_read, positions, window=window,
            attn_softcap=cfg.attn_softcap)
    elif mode == "decode_multi":
        # continuous batching: every row at its own position
        assert cache is not None and S == 1
        if paged:
            # fiddlint: ignore[FID001] positions are host ints from the scheduler; asarray does not touch the device
            cache.write_decode(k, v, np.asarray(positions[:, 0]), active)
            new_cache, kv_read = cache, cache.view()
        else:
            new_cache = kvc.write_decode_multi(cache, k, v, positions[:, 0])
            kv_read = new_cache
        out = decode_attention(
            q, kv_read, positions, window=window,
            attn_softcap=cfg.attn_softcap)
    else:
        raise ValueError(mode)
    y = out.reshape(B, S, cfg.n_heads * cfg.head_dim) @ params["wo"]
    return y, new_cache


# ---------------------------------------------------------------------------
# Cross-attention (whisper decoder)
# ---------------------------------------------------------------------------


def init_cross_attention(key, cfg: ModelConfig, dtype=jnp.float32) -> Params:
    return init_attention(key, cfg, dtype)


def cross_attention_block(
    params: Params,
    x: jnp.ndarray,                 # (B, S, d) decoder stream
    enc_kv: Tuple[jnp.ndarray, jnp.ndarray],  # precomputed (k, v): (B, Se, KV, hd)
    cfg: ModelConfig,
) -> jnp.ndarray:
    B, S, _ = x.shape
    q = (x @ params["wq"]).reshape(B, S, cfg.n_heads, cfg.head_dim)
    if cfg.qk_norm:
        q = rmsnorm(params["q_norm"], q, cfg.norm_eps)
    k, v = enc_kv
    Se = k.shape[1]
    pos_q = jnp.broadcast_to(jnp.arange(S)[None], (B, S))
    pos_kv = jnp.broadcast_to(jnp.arange(Se)[None], (B, Se))
    out = chunked_attention(q, k, v, pos_q, pos_kv, causal=False,
                            attn_softcap=cfg.attn_softcap)
    return out.reshape(B, S, cfg.n_heads * cfg.head_dim) @ params["wo"]


def encode_cross_kv(params: Params, enc_out: jnp.ndarray, cfg: ModelConfig
                    ) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Project encoder output once into cross-attention K/V."""
    B, Se, _ = enc_out.shape
    k = (enc_out @ params["wk"]).reshape(B, Se, cfg.n_kv_heads, cfg.head_dim)
    v = (enc_out @ params["wv"]).reshape(B, Se, cfg.n_kv_heads, cfg.head_dim)
    if cfg.qk_norm:
        k = rmsnorm(params["k_norm"], k, cfg.norm_eps)
    return k, v
