"""End-to-end behaviour tests for the paper's system.

1. A short training run on a reduced MoE model must reduce the loss.
2. Train → checkpoint → serve through the Fiddler orchestrator: the full
   production path, numerics identical to the monolithic model.
3. The dry-run harness works end-to-end on a tiny mesh (subprocess so the
   forced device count doesn't leak into this process).
"""
import subprocess
import sys

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from conftest import reduced_model
from repro.configs import get_config
from repro.core import FiddlerEngine
from repro.data.pipeline import make_batch_iter
from repro.models import Model
from repro.training.checkpoint import load_checkpoint, save_checkpoint
from repro.training.optimizer import AdamWConfig
from repro.training.train_loop import train


def test_training_reduces_loss():
    cfg = get_config("mixtral-8x7b").reduced()
    model = Model(cfg, param_dtype=jnp.float32)
    params = model.init(jax.random.PRNGKey(0))
    data = make_batch_iter(cfg, seq_len=32, batch=4, seed=0)
    params, opt, hist = train(model, params, iter(data), n_steps=30,
                              opt_cfg=AdamWConfig(lr=3e-3, warmup_steps=5),
                              log_every=29)
    first, last = hist[0]["loss"], hist[-1]["loss"]
    assert np.isfinite(last)
    assert last < first - 0.5, (first, last)


def test_train_checkpoint_serve_roundtrip(tmp_path):
    cfg, model, params = reduced_model("mixtral-8x7b")
    save_checkpoint(str(tmp_path / "ck"), params, step=1)
    loaded, _ = load_checkpoint(str(tmp_path / "ck"),
                                like={"params": params})
    restored = loaded["params"]
    tokens = jax.random.randint(jax.random.PRNGKey(3), (1, 8), 3,
                                cfg.vocab_size)
    ref, _ = model.prefill(params, tokens, max_seq=16,
                           cache_dtype=jnp.float32)
    eng = FiddlerEngine(cfg, restored, policy="fiddler", expert_budget=20,
                        host_precision="fp32")
    got, _ = eng.prefill(tokens, max_seq=16)
    np.testing.assert_allclose(np.asarray(got), np.asarray(ref), rtol=3e-4,
                               atol=3e-4)


@pytest.mark.slow
def test_dryrun_small_mesh_subprocess():
    """launch/dryrun on a 2×4 mesh in a subprocess (own XLA_FLAGS)."""
    code = (
        "import os; os.environ['XLA_FLAGS']="
        "'--xla_force_host_platform_device_count=8'\n"
        "import jax\n"
        "from repro.launch.mesh import make_debug_mesh\n"
        "mesh = make_debug_mesh(model=4, data=2)\n"
        "from repro.launch.dryrun import dryrun_one\n"
        "r = dryrun_one('qwen3-0.6b', 'decode_32k', mesh=mesh, verbose=False)\n"
        "assert r['ok'], r\n"
        "print('DRYRUN_OK', r['bottleneck'])\n"
    )
    out = subprocess.run([sys.executable, "-c", code], capture_output=True,
                         text=True, timeout=600,
                         env={**__import__('os').environ,
                              "PYTHONPATH": "src"},
                         cwd=__import__('os').path.join(
                             __import__('os').path.dirname(__file__), ".."))
    assert "DRYRUN_OK" in out.stdout, out.stderr[-2000:]


def test_single_device_visible():
    """Smoke tests must see exactly one device (dry-run flags must not
    leak — system prompt requirement)."""
    assert len(jax.devices()) == 1
