"""Project model for fiddlint: parsed modules, an import map, a function
index, and an over-approximate call graph.

Resolution is deliberately name-based (a linter, not a type checker):

* plain calls resolve through the module's ``from``-imports and its own
  top-level functions;
* attribute calls rooted at a project-module alias (``kvc.init_attn_cache``)
  resolve into that module;
* other attribute calls (``self.backend.prefill(...)``) resolve to *every*
  project method with that name — an over-approximation, which is the safe
  direction for reachability-based rules like FID001 (missing a hot-path
  edge would silently un-lint real hot code).

Nested function/lambda bodies are treated as part of their enclosing
function: the orchestrator's dispatch closures execute within the step,
so their syncs/launches belong to the enclosing frame.
"""
from __future__ import annotations

import ast
from dataclasses import dataclass, field
from pathlib import Path
from typing import Dict, Iterable, List, Optional, Set, Tuple

# import roots that are never project code (their attribute calls are
# resolved as external, not by method-name match)
EXTERNAL_ROOTS = {
    "np", "numpy", "jnp", "jax", "lax", "pl", "pltpu", "os", "sys", "re",
    "math", "time", "json", "warnings", "functools", "itertools",
    "dataclasses", "collections", "threading", "atexit", "ast", "typing",
}


def module_name_for(path: Path) -> str:
    """Dotted module name: rooted at the innermost ``src`` dir if there is
    one (src/repro/core/x.py -> repro.core.x), else the file stem — which
    is how fixture files are addressed in tests."""
    parts = path.with_suffix("").parts
    for anchor in ("src",):
        if anchor in parts:
            i = len(parts) - 1 - parts[::-1].index(anchor)
            return ".".join(parts[i + 1:])
    return parts[-1]


@dataclass
class SourceFile:
    path: Path
    module: str
    text: str
    tree: ast.Module
    lines: List[str]


@dataclass
class FunctionInfo:
    module: str
    qualname: str          # module.Class.name or module.name
    name: str
    cls: Optional[str]
    node: ast.AST          # FunctionDef / AsyncFunctionDef
    file: SourceFile
    device_return: bool = False
    jitted: bool = False
    calls: List[Tuple[str, ast.Call]] = field(default_factory=list)


def _ann_mentions_device(node: Optional[ast.AST]) -> bool:
    if node is None:
        return False
    src = ast.dump(node)
    return ("jnp" in src and "ndarray" in src) or "Array" in src


def attr_chain(node: ast.AST) -> Optional[List[str]]:
    """``a.b.c`` -> ["a","b","c"]; subscripts are looked through
    (``a[i].b`` -> ["a","b"]); anything else -> None."""
    parts: List[str] = []
    while True:
        if isinstance(node, ast.Attribute):
            parts.append(node.attr)
            node = node.value
        elif isinstance(node, ast.Subscript):
            node = node.value
        elif isinstance(node, ast.Call):
            node = node.func
        elif isinstance(node, ast.Name):
            parts.append(node.id)
            return parts[::-1]
        else:
            return None


def root_name(node: ast.AST) -> Optional[str]:
    chain = attr_chain(node)
    return chain[0] if chain else None


def _is_jit_decorator(dec: ast.AST, jax_aliases: Set[str]) -> bool:
    """@jax.jit / @functools.partial(jax.jit, ...) / @jit (from jax)."""
    if isinstance(dec, ast.Call):
        # functools.partial(jax.jit, ...) or jax.jit(...)-style factory
        chain = attr_chain(dec.func)
        if chain and chain[-1] == "partial" and dec.args:
            return _is_jit_decorator(dec.args[0], jax_aliases)
        dec = dec.func
    chain = attr_chain(dec)
    if not chain:
        return False
    if chain[-1] != "jit":
        return False
    return len(chain) == 1 or chain[0] in jax_aliases or chain[0] == "jax"


class Module:
    """One parsed file plus its import environment."""

    def __init__(self, sf: SourceFile):
        self.sf = sf
        self.alias_to_module: Dict[str, str] = {}   # np -> numpy
        self.from_imports: Dict[str, str] = {}      # route -> repro.models.moe.route
        self.jax_aliases: Set[str] = {"jax"}
        self.np_aliases: Set[str] = set()
        self.jnp_aliases: Set[str] = set()
        for node in ast.walk(sf.tree):
            if isinstance(node, ast.Import):
                for a in node.names:
                    alias = a.asname or a.name.split(".")[0]
                    self.alias_to_module[alias] = a.name
                    if a.name == "numpy":
                        self.np_aliases.add(alias)
                    if a.name == "jax":
                        self.jax_aliases.add(alias)
                    if a.name == "jax.numpy":
                        self.jnp_aliases.add(alias)
            elif isinstance(node, ast.ImportFrom) and node.module:
                for a in node.names:
                    alias = a.asname or a.name
                    self.from_imports[alias] = f"{node.module}.{a.name}"
                    if node.module == "jax" and a.name == "numpy":
                        self.jnp_aliases.add(alias)


class Project:
    def __init__(self, paths: Iterable[str]):
        self.files: List[SourceFile] = []
        self.modules: Dict[str, Module] = {}
        self.functions: Dict[str, FunctionInfo] = {}
        self.by_name: Dict[str, List[FunctionInfo]] = {}
        self.classes: Dict[str, List[str]] = {}  # class name -> method qualnames
        for p in sorted(self._expand(paths)):
            self._load(p)
        for fn in self.functions.values():
            self._index_calls(fn)

    @staticmethod
    def _expand(paths: Iterable[str]) -> Set[Path]:
        out: Set[Path] = set()
        for p in paths:
            pp = Path(p)
            if pp.is_dir():
                out.update(pp.rglob("*.py"))
            elif pp.suffix == ".py":
                out.add(pp)
        return out

    def _load(self, path: Path) -> None:
        text = path.read_text()
        try:
            tree = ast.parse(text, filename=str(path))
        except SyntaxError:
            return
        sf = SourceFile(path=path, module=module_name_for(path), text=text,
                        tree=tree, lines=text.splitlines())
        self.files.append(sf)
        mod = Module(sf)
        self.modules[sf.module] = mod
        for node in tree.body:
            self._collect_defs(sf, mod, node, cls=None)

    def _collect_defs(self, sf: SourceFile, mod: Module, node: ast.AST,
                      cls: Optional[str]) -> None:
        if isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef)):
            qual = (f"{sf.module}.{cls}.{node.name}" if cls
                    else f"{sf.module}.{node.name}")
            info = FunctionInfo(
                module=sf.module, qualname=qual, name=node.name, cls=cls,
                node=node, file=sf,
                device_return=_ann_mentions_device(node.returns),
                jitted=any(_is_jit_decorator(d, mod.jax_aliases)
                           for d in node.decorator_list))
            self.functions[qual] = info
            self.by_name.setdefault(node.name, []).append(info)
            if cls:
                self.classes.setdefault(cls, []).append(qual)
        elif isinstance(node, ast.ClassDef):
            for child in node.body:
                self._collect_defs(sf, mod, child, cls=node.name)

    # -- call-graph construction -------------------------------------------
    def _index_calls(self, fn: FunctionInfo) -> None:
        mod = self.modules[fn.module]
        for node in ast.walk(fn.node):
            if not isinstance(node, ast.Call):
                continue
            for target in self.resolve_call(mod, node):
                fn.calls.append((target, node))

    def resolve_call(self, mod: Module, call: ast.Call) -> List[str]:
        """Qualnames of project functions this call may reach."""
        func = call.func
        if isinstance(func, ast.Name):
            name = func.id
            full = mod.from_imports.get(name)
            if full and full in self.functions:
                return [full]
            local = f"{mod.sf.module}.{name}"
            if local in self.functions:
                return [local]
            # from-import of a project name whose module isn't loaded
            # under the same dotted path (fixtures): fall back to any
            # unique project function of that name
            cands = self.by_name.get(name, [])
            if len({c.qualname for c in cands}) == 1:
                return [cands[0].qualname]
            return []
        if isinstance(func, ast.Attribute):
            chain = attr_chain(func)
            if not chain:
                return []
            root, meth = chain[0], chain[-1]
            if root in mod.alias_to_module:
                target_mod = mod.alias_to_module[root]
                qual = ".".join([target_mod, *chain[1:]])
                if qual in self.functions:
                    return [qual]
                if root in EXTERNAL_ROOTS or target_mod in EXTERNAL_ROOTS:
                    return []
            if root in EXTERNAL_ROOTS:
                return []
            # method-name over-approximation: any project method
            return [c.qualname for c in self.by_name.get(meth, [])
                    if c.cls is not None]
        return []

    # -- reachability -------------------------------------------------------
    def resolve_roots(self, specs: Iterable[str]) -> List[FunctionInfo]:
        out: List[FunctionInfo] = []
        for spec in specs:
            for qual, fn in self.functions.items():
                if qual == spec or qual.endswith("." + spec):
                    out.append(fn)
        return out

    def reachable_from(self, roots: Iterable[FunctionInfo]
                       ) -> Dict[str, str]:
        """BFS over the call graph; returns {qualname: root qualname} for
        every reachable function (first root to reach it wins)."""
        seen: Dict[str, str] = {}
        frontier = [(fn.qualname, fn.qualname) for fn in roots]
        while frontier:
            qual, root = frontier.pop()
            if qual in seen:
                continue
            seen[qual] = root
            fn = self.functions.get(qual)
            if fn is None:
                continue
            for target, _ in fn.calls:
                if target not in seen:
                    frontier.append((target, root))
        return seen
