"""Minimal stand-in for the ``hypothesis`` API used by this test suite.

The real package is declared in ``pyproject.toml`` (test extras) and wins
whenever it is importable; this fallback keeps the property tests runnable
in hermetic environments where it is not.  It implements only what the
suite uses — ``given``/``settings``/``assume`` and the ``integers``,
``floats``, ``booleans``, ``text``, ``lists``, ``tuples``, ``builds`` and
``data`` strategies — with deterministic seeded random draws plus explicit
all-minimum / all-maximum boundary examples in place of hypothesis's
shrinking search.

Registered from ``conftest.py`` via ``sys.modules`` so plain
``from hypothesis import given, strategies as st`` keeps working.
"""
from __future__ import annotations

import functools
import string
import sys
import types
import zlib
from typing import Any, Callable, List, Optional

import numpy as np

__all__ = ["given", "settings", "assume", "strategies", "HealthCheck"]


class UnsatisfiedAssumption(Exception):
    """Raised by ``assume(False)`` — the example is silently discarded."""


def assume(condition: Any) -> bool:
    if not condition:
        raise UnsatisfiedAssumption()
    return True


# ---------------------------------------------------------------------------
# Strategies: objects with draw(rng, mode) for mode in {"min", "max", "rand"}
# ---------------------------------------------------------------------------


class Strategy:
    def draw(self, rng: np.random.Generator, mode: str = "rand") -> Any:
        raise NotImplementedError

    def map(self, fn: Callable[[Any], Any]) -> "Strategy":
        return _Mapped(self, fn)


class _Mapped(Strategy):
    def __init__(self, base: Strategy, fn: Callable[[Any], Any]):
        self.base, self.fn = base, fn

    def draw(self, rng, mode="rand"):
        return self.fn(self.base.draw(rng, mode))


class _Integers(Strategy):
    def __init__(self, min_value: int = 0, max_value: int = 1 << 16):
        self.lo, self.hi = int(min_value), int(max_value)

    def draw(self, rng, mode="rand"):
        if mode == "min":
            return self.lo
        if mode == "max":
            return self.hi
        return int(rng.integers(self.lo, self.hi + 1))


class _Floats(Strategy):
    def __init__(self, min_value: float = 0.0, max_value: float = 1.0):
        self.lo, self.hi = float(min_value), float(max_value)

    def draw(self, rng, mode="rand"):
        if mode == "min":
            return self.lo
        if mode == "max":
            return self.hi
        # mix uniform and log-uniform draws so tiny lower bounds (1e-6
        # latency constants) are actually exercised, as hypothesis would
        if self.lo > 0 and self.hi / max(self.lo, 1e-300) > 1e3 and rng.random() < 0.5:
            return float(np.exp(rng.uniform(np.log(self.lo), np.log(self.hi))))
        return float(rng.uniform(self.lo, self.hi))


class _Booleans(Strategy):
    def draw(self, rng, mode="rand"):
        if mode == "min":
            return False
        if mode == "max":
            return True
        return bool(rng.integers(0, 2))


_TEXT_ALPHABET = string.ascii_letters + string.digits + string.punctuation \
    + " \t\n" + "αβγδé漢字🙂"


class _Text(Strategy):
    def __init__(self, alphabet: Optional[str] = None, min_size: int = 0,
                 max_size: int = 64):
        self.alphabet = alphabet or _TEXT_ALPHABET
        self.min_size, self.max_size = min_size, max_size

    def draw(self, rng, mode="rand"):
        if mode == "min":
            n = self.min_size
        elif mode == "max":
            n = self.max_size
        else:
            n = int(rng.integers(self.min_size, self.max_size + 1))
        chars = [self.alphabet[int(i)]
                 for i in rng.integers(0, len(self.alphabet), size=n)]
        return "".join(chars)


class _Lists(Strategy):
    def __init__(self, elements: Strategy, min_size: int = 0,
                 max_size: int = 16, unique: bool = False):
        self.elements = elements
        self.min_size, self.max_size = min_size, max_size
        self.unique = unique

    def draw(self, rng, mode="rand"):
        if mode == "min":
            n = self.min_size
        elif mode == "max":
            n = self.max_size
        else:
            n = int(rng.integers(self.min_size, self.max_size + 1))
        out: List[Any] = []
        tries = 0
        while len(out) < n and tries < 100 * max(n, 1):
            v = self.elements.draw(rng, mode)
            tries += 1
            if self.unique and v in out:
                continue
            out.append(v)
        return out


class _Tuples(Strategy):
    def __init__(self, *parts: Strategy):
        self.parts = parts

    def draw(self, rng, mode="rand"):
        return tuple(p.draw(rng, mode) for p in self.parts)


class _Builds(Strategy):
    def __init__(self, target: Callable, *args: Strategy, **kwargs: Strategy):
        self.target, self.args, self.kwargs = target, args, kwargs

    def draw(self, rng, mode="rand"):
        a = [s.draw(rng, mode) for s in self.args]
        kw = {k: s.draw(rng, mode) for k, s in self.kwargs.items()}
        return self.target(*a, **kw)


class _SampledFrom(Strategy):
    def __init__(self, elements):
        self.elements = list(elements)

    def draw(self, rng, mode="rand"):
        if mode == "min":
            return self.elements[0]
        if mode == "max":
            return self.elements[-1]
        return self.elements[int(rng.integers(0, len(self.elements)))]


class _JustStrategy(Strategy):
    def __init__(self, value):
        self.value = value

    def draw(self, rng, mode="rand"):
        return self.value


class DataObject:
    """Interactive drawing (``data.draw(strategy)``) inside a test body."""

    def __init__(self, rng: np.random.Generator, mode: str):
        self._rng, self._mode = rng, mode

    def draw(self, strategy: Strategy, label: Optional[str] = None) -> Any:
        return strategy.draw(self._rng, self._mode)


class _Data(Strategy):
    def draw(self, rng, mode="rand"):
        return DataObject(rng, mode)


# public strategies namespace (mirrors ``hypothesis.strategies``)
strategies = types.ModuleType("hypothesis.strategies")
strategies.integers = _Integers
strategies.floats = _Floats
strategies.booleans = _Booleans
strategies.text = _Text
strategies.lists = _Lists
strategies.tuples = _Tuples
strategies.builds = _Builds
strategies.sampled_from = _SampledFrom
strategies.just = _JustStrategy
strategies.data = _Data
strategies.SearchStrategy = Strategy


# ---------------------------------------------------------------------------
# settings / given
# ---------------------------------------------------------------------------


class HealthCheck:
    """Accepted and ignored (API compatibility)."""
    all = classmethod(lambda cls: [])
    too_slow = data_too_large = filter_too_much = None


def settings(max_examples: int = 50, deadline: Any = None, **_ignored):
    def apply(fn):
        fn._fallback_settings = {"max_examples": max_examples}
        return fn
    return apply


DEFAULT_MAX_EXAMPLES = 50
# examples 0/1 are the all-minimum / all-maximum boundary draws
_BOUNDARY_MODES = ("min", "max")


def given(*arg_strategies: Strategy, **kw_strategies: Strategy):
    def decorate(fn):
        @functools.wraps(fn)
        def wrapper(*fixture_args, **fixture_kwargs):
            conf = (getattr(wrapper, "_fallback_settings", None)
                    or getattr(fn, "_fallback_settings", None)
                    or {"max_examples": DEFAULT_MAX_EXAMPLES})
            n = conf["max_examples"]
            rng = np.random.default_rng(
                zlib.crc32(fn.__qualname__.encode()))
            ran = 0
            for i in range(max(4 * n, n + 8)):
                if ran >= n:
                    break
                mode = _BOUNDARY_MODES[i] if i < len(_BOUNDARY_MODES) else "rand"
                try:
                    args = [s.draw(rng, mode) for s in arg_strategies]
                    kwargs = {k: s.draw(rng, mode)
                              for k, s in kw_strategies.items()}
                except UnsatisfiedAssumption:
                    continue
                try:
                    fn(*fixture_args, *args, **fixture_kwargs, **kwargs)
                except UnsatisfiedAssumption:
                    continue
                except Exception as e:
                    raise AssertionError(
                        f"falsifying example (#{ran}, mode={mode}): "
                        f"args={args!r} kwargs={kwargs!r}") from e
                ran += 1
            if ran == 0:
                raise AssertionError(
                    f"{fn.__qualname__}: unable to satisfy assume() on any "
                    f"generated example — property was never checked")
        # pytest must not see the strategy parameters as fixtures
        if hasattr(wrapper, "__wrapped__"):
            del wrapper.__wrapped__
        wrapper.hypothesis = types.SimpleNamespace(inner_test=fn)
        return wrapper
    return decorate


def install() -> None:
    """Register this module as ``hypothesis`` (+``.strategies``) in
    ``sys.modules`` — called from conftest only when the real package is
    missing."""
    mod = types.ModuleType("hypothesis")
    mod.given = given
    mod.settings = settings
    mod.assume = assume
    mod.HealthCheck = HealthCheck
    mod.strategies = strategies
    mod.__is_fallback__ = True
    sys.modules["hypothesis"] = mod
    sys.modules["hypothesis.strategies"] = strategies
