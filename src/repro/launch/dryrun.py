import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"
# ^ MUST be the first two lines — before any jax import — so the host
# platform exposes 512 placeholder devices for the production meshes.
# (Set here ONLY: smoke tests and benches must see 1 device.)

"""Multi-pod dry-run: lower + compile every (architecture × input shape)
for the production meshes and record memory/cost/roofline terms.

For each combination this lowers the REAL step function —

  train_4k    → train_step   (fwd + bwd + AdamW update)
  prefill_32k → prefill_step (prompt → logits + KV cache)
  decode_*    → serve_step   (ONE token against a seq_len KV cache)

— with ShapeDtypeStruct inputs (no allocation), compiles it, and prints
``compiled.memory_analysis()`` / ``cost_analysis()``.  A sharding mismatch,
compile-time OOM or unsupported collective here is a bug in the system.

Usage:
  python -m repro.launch.dryrun --arch qwen3-0.6b --shape train_4k
  python -m repro.launch.dryrun --all [--multi-pod] [--out out.json]
"""
import argparse
import json
import sys
import time
import traceback
from typing import Any, Dict

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.configs import INPUT_SHAPES, applicable_shapes, get_config
from repro.distributed.sharding import (
    batch_pspecs,
    cache_pspecs,
    param_pspecs,
    set_axis_sizes,
    )
from repro.launch import analysis
from repro.launch.mesh import make_production_mesh, mesh_axes
from repro.launch.specs import prefill_specs, train_batch_specs
from repro.models.model import Model, ParallelContext
from repro.training.optimizer import init_opt_state
from repro.training.train_loop import make_train_step

SDS = jax.ShapeDtypeStruct


def _named(mesh, spec_tree):
    return jax.tree.map(
        lambda s: NamedSharding(mesh, s), spec_tree,
        is_leaf=lambda s: isinstance(s, P))


def build_lowered(arch: str, shape_name: str, mesh, *, verbose: bool = True,
                  unroll: bool = False):
    """Lower the step function for one (arch, shape, mesh)."""
    cfg = get_config(arch)
    shape = INPUT_SHAPES[shape_name]
    data_axes, model_axis = mesh_axes(mesh)
    model_size = mesh.shape[model_axis]
    pctx = ParallelContext(mesh=mesh, data_axes=data_axes,
                           model_axis=model_axis)
    model = Model(cfg, pctx, unroll_scan=unroll)

    set_axis_sizes(dict(mesh.shape))
    params_shape = jax.eval_shape(model.init, jax.random.PRNGKey(0))
    p_specs = param_pspecs(cfg, params_shape, model_axis, model_size,
                           data_axes)
    p_shardings = _named(mesh, p_specs)
    mesh_shape = dict(mesh.shape)

    if shape.kind == "train":
        batch_sds = train_batch_specs(cfg, shape)
        b_specs = batch_pspecs(cfg, batch_sds, data_axes, dict(mesh.shape))
        opt_shape = jax.eval_shape(init_opt_state, params_shape)
        o_specs = param_pspecs(cfg, opt_shape["m"], model_axis, model_size,
                               data_axes)
        opt_specs = {"m": o_specs, "v": o_specs, "step": P()}
        step = make_train_step(model)
        fn = jax.jit(
            step,
            in_shardings=(p_shardings, _named(mesh, opt_specs),
                          _named(mesh, b_specs)),
            out_shardings=(p_shardings, _named(mesh, opt_specs), None),
            donate_argnums=(0, 1),
        )
        with mesh:
            lowered = fn.lower(params_shape, opt_shape, batch_sds)

    elif shape.kind == "prefill":
        batch_sds = prefill_specs(cfg, shape)
        b_specs = batch_pspecs(cfg, batch_sds, data_axes, dict(mesh.shape))
        max_seq = shape.seq_len

        def prefill_step(params, batch):
            extra = {k: v for k, v in batch.items() if k != "tokens"}
            return model.prefill(params, batch["tokens"], max_seq,
                                 extra or None)

        cache_shape = jax.eval_shape(
            lambda p, b: prefill_step(p, b)[1], params_shape, batch_sds)
        c_specs = cache_pspecs(cfg, cache_shape, shape.global_batch,
                               data_axes, model_axis, mesh_shape)
        logits_spec = P(None, None)
        fn = jax.jit(
            prefill_step,
            in_shardings=(p_shardings, _named(mesh, b_specs)),
            out_shardings=(NamedSharding(mesh, logits_spec),
                           _named(mesh, c_specs)),
        )
        with mesh:
            lowered = fn.lower(params_shape, batch_sds)

    else:  # decode
        max_seq = shape.seq_len
        B = shape.global_batch

        def serve_step(params, cache, tokens, pos):
            return model.decode_step(params, cache, tokens, pos, max_seq)

        cache_shape = jax.eval_shape(
            lambda: model.make_cache(
                B, max_seq,
                enc_frames=(cfg.encdec.n_audio_frames
                            if cfg.arch_type == "audio" else None)))
        c_specs = cache_pspecs(cfg, cache_shape, B, data_axes, model_axis,
                               mesh_shape)
        c_shardings = _named(mesh, c_specs)
        fn = jax.jit(
            serve_step,
            in_shardings=(p_shardings, c_shardings,
                          NamedSharding(mesh, P(None, None)),
                          NamedSharding(mesh, P())),
            out_shardings=(NamedSharding(mesh, P(None, None)), c_shardings),
            donate_argnums=(1,),
        )
        with mesh:
            lowered = fn.lower(params_shape, cache_shape,
                               SDS((B, 1), jnp.int32), SDS((), jnp.int32))
    return lowered, cfg, shape


def dryrun_one(arch: str, shape_name: str, *, multi_pod: bool = False,
               mesh=None, verbose: bool = True,
               unroll: bool = False) -> Dict[str, Any]:
    t0 = time.time()
    if mesh is None:
        mesh = make_production_mesh(multi_pod=multi_pod)
    mesh_name = "x".join(str(mesh.shape[a]) for a in mesh.axis_names)
    try:
        lowered, cfg, shape = build_lowered(arch, shape_name, mesh,
                                            verbose=verbose, unroll=unroll)
        compiled = lowered.compile()
    except Exception as e:  # a failure here is a bug in the system
        if verbose:
            traceback.print_exc()
        return {"arch": arch, "shape": shape_name, "mesh": mesh_name,
                "ok": False, "error": f"{type(e).__name__}: {e}"}

    n_dev = mesh.size
    n_tokens = (shape.global_batch * shape.seq_len
                if shape.kind != "decode" else shape.global_batch)
    mf = analysis.model_flops(cfg, shape.kind, n_tokens)
    roof = analysis.analyze_compiled(compiled, arch, shape_name, mesh_name,
                                     n_dev, mf)
    row = roof.row()
    row.update({"ok": True, "compile_s": time.time() - t0,
                "coll_breakdown": roof.coll_breakdown})
    if verbose:
        ma = None
        try:
            ma = compiled.memory_analysis()
        except (NotImplementedError, RuntimeError, AttributeError) as e:
            # mirrors launch/analysis.analyze_compiled: absent on some
            # backends/jax versions — report, don't swallow
            print(f"  memory_analysis unavailable: "
                  f"{type(e).__name__}: {e}", file=sys.stderr)
        print(f"[{arch} × {shape_name} × {mesh_name}] ok "
              f"({row['compile_s']:.1f}s compile)")
        if ma is not None:
            print(f"  memory_analysis: {ma}")
        print(f"  flops/dev={roof.flops:.3e}  hbm/dev={roof.hbm_bytes:.3e}  "
              f"coll/dev={roof.coll_bytes:.3e}")
        print(f"  roofline: compute={roof.t_compute*1e3:.2f}ms "
              f"memory={roof.t_memory*1e3:.2f}ms "
              f"collective={roof.t_collective*1e3:.2f}ms "
              f"→ {roof.bottleneck}-bound; useful={roof.useful_flops_ratio:.2f}")
    return row


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None)
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--both-meshes", action="store_true")
    ap.add_argument("--out", default=None)
    args = ap.parse_args(argv)

    from repro.configs import ASSIGNED_ARCHS

    combos = []
    archs = ASSIGNED_ARCHS if (args.all or args.arch is None) else [args.arch]
    for a in archs:
        cfg = get_config(a)
        shapes = applicable_shapes(cfg) if args.shape is None else [args.shape]
        for s in shapes:
            combos.append((a, s))

    meshes = [False, True] if args.both_meshes else [args.multi_pod]
    rows = []
    for mp in meshes:
        mesh = make_production_mesh(multi_pod=mp)
        for a, s in combos:
            rows.append(dryrun_one(a, s, mesh=mesh))
    if args.out:
        with open(args.out, "w") as f:
            json.dump(rows, f, indent=1, default=str)
    n_fail = sum(1 for r in rows if not r.get("ok"))
    print(f"\n{len(rows) - n_fail}/{len(rows)} combinations lowered+compiled")
    return 1 if n_fail else 0


if __name__ == "__main__":
    sys.exit(main())
