"""Common serving-backend protocol.

``ServingEngine`` (static grouped batches) and ``ContinuousEngine``
(slot-based continuous batching) used to be hard-wired to the monolithic
jitted ``Model`` and to ``FiddlerEngine`` respectively.  This module
extracts the surface both schedulers need —

* a **clock source** (wall time for real execution, the orchestrator's
  simulated-seconds ledger for the fast/slow-tier regime),
* **prefill-into-slot** (whole-prompt or chunked, producing a batch-1
  cache that joins the multi-slot cache via ``write_slot``),
* a **multi-slot decode step** (every slot at its own position, with an
  active mask so idle slots are padding, not load),
* **grouped prefill/decode** (the static-batch path),

— so either scheduler runs over either execution engine.  TTFT/ITL
recorded against ``clock()`` are therefore wall-clock for the ``Model``
backend and simulated seconds for the ``FiddlerEngine`` backend (the
paper's setting: the modelled hardware, not this container's CPU).
"""
from __future__ import annotations

import time
from typing import Any, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np


class ServingBackend:
    """Interface both serving schedulers target.  ``max_seq`` is fixed at
    construction (it is baked into jitted signatures and cache shapes)."""

    max_seq: int

    # -- clock --------------------------------------------------------------
    def clock(self) -> float:
        raise NotImplementedError

    def wait_until(self, t: float) -> None:
        """Advance the clock to ``t`` (idle gap between arrivals):
        simulated clocks fast-forward, wall clocks sleep.  Implementations
        must actually reach ``t`` — the continuous scheduler relies on it
        to admit future-arrival requests instead of busy-spinning."""
        raise NotImplementedError

    # -- slot API (continuous batching) -------------------------------------
    def make_cache(self, n_slots: int) -> Any:
        raise NotImplementedError

    def prefill(self, prompt: Sequence[int]) -> Tuple[np.ndarray, Any]:
        """Whole-prompt prefill → ((V,) last-token logits, batch-1 cache)."""
        raise NotImplementedError

    def prefill_chunk(self, slot_cache: Optional[Any],
                      chunk: Sequence[int], pos_offset: int
                      ) -> Tuple[np.ndarray, Any]:
        """Process one prompt chunk at ``pos_offset``; ``slot_cache`` is
        None on the first chunk.  Returns ((V,) logits of the chunk's last
        position, updated batch-1 cache)."""
        raise NotImplementedError

    def write_slot(self, cache: Any, slot_cache: Any, slot: int) -> Any:
        raise NotImplementedError

    def decode_slots(self, cache: Any, tokens: np.ndarray, pos: np.ndarray,
                     active: np.ndarray) -> Tuple[np.ndarray, Any]:
        """One decode step over all slots.  tokens/pos/active: (n_slots,).
        Returns ((n_slots, V) logits, updated cache)."""
        raise NotImplementedError

    # -- group API (static batching) ----------------------------------------
    def prefill_group(self, prompts: np.ndarray
                      ) -> Tuple[jnp.ndarray, Any]:
        """Padded (B, S) prompt batch → ((B, V) logits, cache)."""
        raise NotImplementedError

    def decode_group(self, cache: Any, tokens: np.ndarray, pos: int
                     ) -> Tuple[jnp.ndarray, Any]:
        """One decode step at shared scalar position ``pos``."""
        raise NotImplementedError


# ---------------------------------------------------------------------------
# Monolithic jitted Model backend (capacity-sufficient regime)
# ---------------------------------------------------------------------------


class ModelBackend(ServingBackend):
    """Jitted ``repro.models.Model`` execution; wall-clock timing."""

    def __init__(self, model, params, *, max_seq: int = 256):
        self.model = model
        self.params = params
        self.max_seq = max_seq
        self._prefill1 = jax.jit(
            lambda p, t: model.prefill(p, t, max_seq,
                                       cache_dtype=jnp.float32))
        # group path keeps the model's default (bf16) cache — only the
        # slot path needs fp32 to splice into make_cache(dtype=float32)
        self._prefill_grp = jax.jit(
            lambda p, t: model.prefill(p, t, max_seq))
        self._prefill_chunk = jax.jit(
            lambda p, c, t, off: model.prefill_chunk(p, c, t, off, max_seq))
        self._decode_multi = jax.jit(
            lambda p, c, t, pos: model.decode_step_multi(p, c, t, pos,
                                                         max_seq))
        self._decode1 = jax.jit(
            lambda p, c, t, pos: model.decode_step(p, c, t, pos, max_seq))

    def clock(self) -> float:
        return time.perf_counter()

    def wait_until(self, t: float) -> None:
        dt = t - self.clock()
        if dt > 0:
            time.sleep(dt)

    # slot API
    def make_cache(self, n_slots: int) -> Any:
        return self.model.make_cache(n_slots, self.max_seq,
                                     dtype=jnp.float32)

    def prefill(self, prompt):
        logits, cache = self._prefill1(
            self.params, jnp.asarray([list(prompt)], jnp.int32))
        return np.asarray(logits[0]), cache

    def prefill_chunk(self, slot_cache, chunk, pos_offset):
        if slot_cache is None:
            slot_cache = self.model.make_cache(1, self.max_seq,
                                               dtype=jnp.float32)
        logits, slot_cache = self._prefill_chunk(
            self.params, slot_cache, jnp.asarray([list(chunk)], jnp.int32),
            jnp.int32(pos_offset))
        return np.asarray(logits[0]), slot_cache

    def write_slot(self, cache, slot_cache, slot):
        return self.model.write_slot(cache, slot_cache, slot)

    def decode_slots(self, cache, tokens, pos, active):
        logits, cache = self._decode_multi(
            self.params, cache, jnp.asarray(tokens, jnp.int32)[:, None],
            jnp.asarray(pos, jnp.int32))
        return np.asarray(logits), cache

    # group API
    def prefill_group(self, prompts):
        return self._prefill_grp(self.params, jnp.asarray(prompts, jnp.int32))

    def decode_group(self, cache, tokens, pos):
        return self._decode1(self.params, cache,
                             jnp.asarray(tokens, jnp.int32)[:, None],
                             jnp.int32(pos))


# ---------------------------------------------------------------------------
# Fiddler orchestrator backend (fast/slow-tier regime — the paper's setting)
# ---------------------------------------------------------------------------


class FiddlerBackend(ServingBackend):
    """Orchestrated execution over a ``FiddlerEngine``; the clock is the
    engine ledger's simulated seconds, so per-request TTFT/ITL reflect the
    modelled hardware and the planner's fast/stream/slow decisions."""

    def __init__(self, engine, *, max_seq: int = 256):
        assert engine.model is not None, (
            "FiddlerBackend needs a FiddlerEngine built with params "
            "(real-numerics mode)")
        self.engine = engine
        self.max_seq = max_seq

    @property
    def ledger(self):
        return self.engine.ledger

    def clock(self) -> float:
        return self.engine.ledger.sim_time

    def wait_until(self, t: float) -> None:
        led = self.engine.ledger
        led.sim_time = max(led.sim_time, t)

    # slot API
    def make_cache(self, n_slots: int) -> Any:
        return self.engine.make_decode_caches(n_slots, self.max_seq)

    def prefill(self, prompt):
        logits, caches = self.engine.prefill(
            jnp.asarray([list(prompt)], jnp.int32), self.max_seq)
        return np.asarray(logits[0]), caches

    def prefill_chunk(self, slot_cache, chunk, pos_offset):
        logits, slot_cache = self.engine.prefill_chunk(
            jnp.asarray([list(chunk)], jnp.int32), slot_cache, pos_offset,
            self.max_seq)
        return np.asarray(logits[0]), slot_cache

    def write_slot(self, cache, slot_cache, slot):
        return self.engine.write_slot(cache, slot_cache, slot)

    def decode_slots(self, cache, tokens, pos, active):
        logits, cache = self.engine.decode_step_multi(
            cache, jnp.asarray(tokens, jnp.int32)[:, None], pos,
            self.max_seq, active=active)
        return np.asarray(logits), cache

    # group API
    def prefill_group(self, prompts):
        return self.engine.prefill(jnp.asarray(prompts, jnp.int32),
                                   self.max_seq)

    def decode_group(self, cache, tokens, pos):
        return self.engine.decode_step(cache,
                                       jnp.asarray(tokens, jnp.int32)[:, None],
                                       pos, self.max_seq)


def as_backend(obj, *, params=None, mode: Optional[str] = None,
               max_seq: int = 256) -> ServingBackend:
    """Coerce (Model, params) / FiddlerEngine / ready backend → backend."""
    if isinstance(obj, ServingBackend):
        return obj
    if mode == "fiddler" or (mode is None and hasattr(obj, "ledger")):
        return FiddlerBackend(obj, max_seq=max_seq)
    return ModelBackend(obj, params, max_seq=max_seq)
