"""Expert-popularity profiling (paper §3.4, Appendix C).

Fiddler profiles expert routing frequencies offline on calibration data and
places the most popular experts on the fast tier.  The profile is a
(n_layers, n_experts) count matrix; Appendix C normalises by the most
popular expert and reports hit rates for best/worst/random placements.

:class:`OnlineProfile` is the live counterpart: an EWMA of the routing
distribution actually observed during serving, fed per MoE layer from the
orchestrator's real (or simulated) routing decisions.  It is what the
dynamic rebalancer (core/rebalance.py) re-places against when the live
workload drifts away from the offline calibration set (paper App. D's
distribution-shift failure mode).
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Iterable, Optional

import numpy as np


@dataclass
class ExpertProfile:
    counts: np.ndarray  # (n_layers, n_experts) float64

    @property
    def n_layers(self) -> int:
        return self.counts.shape[0]

    @property
    def n_experts(self) -> int:
        return self.counts.shape[1]

    # -- construction ---------------------------------------------------------
    @staticmethod
    def empty(n_layers: int, n_experts: int) -> "ExpertProfile":
        return ExpertProfile(np.zeros((n_layers, n_experts), np.float64))

    def update(self, layer: int, expert_idx: np.ndarray) -> None:
        """Accumulate a routing trace: expert_idx is any int array of the
        expert assignments observed at `layer` (tokens × top_k flattened)."""
        np.add.at(self.counts[layer], np.asarray(expert_idx).reshape(-1), 1.0)

    def merge(self, other: "ExpertProfile") -> "ExpertProfile":
        return ExpertProfile(self.counts + other.counts)

    # -- paper App. C statistics ----------------------------------------------
    def normalized(self) -> np.ndarray:
        """Popularity normalised so the most popular expert is 1.0."""
        m = self.counts.max()
        return self.counts / max(m, 1.0)

    def probabilities(self) -> np.ndarray:
        """Per-layer routing probabilities (rows sum to 1)."""
        tot = self.counts.sum(axis=1, keepdims=True)
        return self.counts / np.maximum(tot, 1.0)

    # -- persistence ------------------------------------------------------------
    def save(self, path: str) -> None:
        np.savez(path, counts=self.counts)

    @staticmethod
    def load(path: str) -> "ExpertProfile":
        with np.load(path) as z:
            return ExpertProfile(z["counts"].astype(np.float64))


class OnlineProfile:
    """EWMA of the live per-layer routing distribution.

    Each :meth:`observe` call folds one layer's per-expert token counts
    into that layer's running distribution estimate:

        ema[l] = decay * ema[l] + (1 - decay) * counts / counts.sum()

    Observations are normalised to a probability row first, so the
    estimate is invariant to batch size (a 1-token decode step and a
    64-token prefill chunk carry equal weight per observation).  The
    update is O(n_experts) — cheap enough to run on every layer of every
    serving step.

    ``prior`` warm-starts the estimate from an offline calibration
    profile (paper §3.4) so early rebalance decisions are anchored until
    live evidence accumulates; ``decay`` sets the adaptation horizon
    (effective window ≈ 1/(1-decay) observations per layer).
    """

    def __init__(self, n_layers: int, n_experts: int, *,
                 decay: float = 0.95,
                 prior: Optional[ExpertProfile] = None):
        assert 0.0 < decay < 1.0, decay
        self.decay = decay
        self.updates = 0
        if prior is not None:
            assert prior.counts.shape == (n_layers, n_experts), (
                prior.counts.shape, (n_layers, n_experts))
            self._ema = prior.probabilities().astype(np.float64)
        else:
            # uninformative prior: uniform routing
            self._ema = np.full((n_layers, n_experts), 1.0 / n_experts)

    @property
    def n_layers(self) -> int:
        return self._ema.shape[0]

    @property
    def n_experts(self) -> int:
        return self._ema.shape[1]

    def observe(self, layer: int, counts: np.ndarray) -> None:
        """Fold one layer's observed per-expert token counts in."""
        counts = np.asarray(counts, np.float64)
        tot = counts.sum()
        if tot <= 0:
            return
        self._ema[layer] = (self.decay * self._ema[layer]
                            + (1.0 - self.decay) * counts / tot)
        self.updates += 1

    def snapshot(self) -> ExpertProfile:
        """The live estimate as an :class:`ExpertProfile` (rows are kept
        proportional to routing probabilities, which is all the placement
        and hit-rate machinery consumes)."""
        return ExpertProfile(self._ema.copy())

    def probabilities(self) -> np.ndarray:
        tot = self._ema.sum(axis=1, keepdims=True)
        return self._ema / np.maximum(tot, 1e-12)


def profile_from_traces(n_layers: int, n_experts: int,
                        traces: Iterable) -> ExpertProfile:
    """traces yields (layer, expert_idx array)."""
    prof = ExpertProfile.empty(n_layers, n_experts)
    for layer, idx in traces:
        prof.update(layer, idx)
    return prof


def synthetic_profile(n_layers: int, n_experts: int, seed: int = 0,
                      concentration: float = 12.0) -> ExpertProfile:
    """ShareGPT-like popularity: near-uniform with mild skew.  Paper App. C
    reports mean 0.71, std 0.08 relative popularity for Mixtral-8x7B —
    a Dirichlet with high concentration reproduces that regime."""
    rng = np.random.default_rng(seed)
    probs = rng.dirichlet(np.full(n_experts, concentration), size=n_layers)
    counts = probs * 1e6
    return ExpertProfile(counts)
