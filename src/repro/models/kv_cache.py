"""KV / recurrent-state cache structures — the **dense** layout.

Caches are plain pytrees (dict of arrays) so they flow through jit/pjit and
can be sharded with NamedSharding.  Attention layers use a (possibly
windowed) ring buffer; SSM/RG-LRU layers carry recurrent state.

This dense per-slot layout is what the jitted monolithic ``Model`` traces
(and what ``FiddlerEngine(kv_layout="dense")`` keeps for bit-identity
equivalence tests).  The orchestrated serving path defaults to the
**paged** layout in :mod:`repro.models.paged_kv` — a per-layer block pool
with refcounted copy-on-write block tables, so beam-group slot forks and
reshuffles move no KV data and beams share their prompt-prefix blocks.
The two layouts are bit-identical on fp32: the paged gather view
reproduces these ring buffers exactly.

Layout (attention): per layer
    k: (B, W, n_kv, head_dim)
    v: (B, W, n_kv, head_dim)
    pos: (B, W) int32 — absolute position stored in each slot, -1 = empty
where ``W = min(max_seq, window)`` for sliding-window layers.

The ring-buffer write index is ``step % W``; masking is done against the
``pos`` array so full and windowed caches share one code path.
"""
from __future__ import annotations

from typing import Any, Dict

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig

Cache = Dict[str, Any]

# sliding-window fallback for dense archs activates only beyond this
# context length (i.e. for the long_500k shape, not decode_32k)
LONG_CONTEXT_THRESHOLD = 131072


def layer_window(cfg: ModelConfig, layer_idx: int, max_seq: int) -> int:
    """Effective KV window for a given layer (ring-buffer length)."""
    if cfg.attn_pattern == "sliding" and cfg.window:
        return min(cfg.window, max_seq)
    if cfg.attn_pattern == "alternating" and cfg.window:
        # even layers local (windowed), odd layers global (gemma2 style)
        return min(cfg.window, max_seq) if layer_idx % 2 == 0 else max_seq
    if cfg.long_context_window is not None and max_seq > LONG_CONTEXT_THRESHOLD:
        # beyond-paper sliding-window variant for dense archs at 500k
        # (decode_32k still exercises the full cache — the variant only
        # kicks in for the long_500k regime)
        return min(cfg.long_context_window, max_seq)
    return max_seq


def init_attn_cache(cfg: ModelConfig, layer_idx: int, batch: int, max_seq: int,
                    dtype=jnp.bfloat16) -> Cache:
    w = layer_window(cfg, layer_idx, max_seq)
    return {
        "k": jnp.zeros((batch, w, cfg.n_kv_heads, cfg.head_dim), dtype),
        "v": jnp.zeros((batch, w, cfg.n_kv_heads, cfg.head_dim), dtype),
        "pos": jnp.full((batch, w), -1, jnp.int32),
    }


def init_ssm_cache(cfg: ModelConfig, batch: int, dtype=jnp.float32) -> Cache:
    assert cfg.ssm is not None
    inner = cfg.ssm.expand * cfg.d_model
    n_heads = inner // cfg.ssm.head_dim
    conv_dim = inner + 2 * cfg.ssm.n_groups * cfg.ssm.state_dim
    return {
        "ssm_state": jnp.zeros((batch, n_heads, cfg.ssm.head_dim, cfg.ssm.state_dim), dtype),
        "conv_state": jnp.zeros((batch, cfg.ssm.conv_width - 1, conv_dim), dtype),
    }


def init_lru_cache(cfg: ModelConfig, batch: int, dtype=jnp.float32) -> Cache:
    assert cfg.hybrid is not None
    return {
        "h": jnp.zeros((batch, cfg.hybrid.lru_width), dtype),
        "conv_state": jnp.zeros((batch, 3, cfg.hybrid.lru_width), dtype),
    }


def write_prefill(cache: Cache, k_new: jnp.ndarray, v_new: jnp.ndarray) -> Cache:
    """Write a fresh prompt (positions 0..S-1) into the ring buffer.

    Uses only slicing/roll (no scatter) so XLA SPMD partitions the sharded
    window axis without gathers.  k_new/v_new: (B, S, n_kv, hd).
    """
    B, S = k_new.shape[0], k_new.shape[1]
    w = cache["k"].shape[1]
    if S <= w:
        k = jax.lax.dynamic_update_slice_in_dim(
            cache["k"], k_new.astype(cache["k"].dtype), 0, axis=1)
        v = jax.lax.dynamic_update_slice_in_dim(
            cache["v"], v_new.astype(cache["v"].dtype), 0, axis=1)
        pos_new = jnp.broadcast_to(jnp.arange(S, dtype=jnp.int32)[None], (B, S))
        pos = jax.lax.dynamic_update_slice_in_dim(cache["pos"], pos_new, 0, axis=1)
    else:
        # keep only the last w positions; slot of position p is p % w
        shift = (S - w) % w
        k = jnp.roll(k_new[:, S - w:], shift, axis=1).astype(cache["k"].dtype)
        v = jnp.roll(v_new[:, S - w:], shift, axis=1).astype(cache["v"].dtype)
        pos_tail = jnp.arange(S - w, S, dtype=jnp.int32)
        pos = jnp.broadcast_to(jnp.roll(pos_tail, shift)[None], (B, w))
    return {"k": k, "v": v, "pos": pos}


def write_prefill_chunk(cache: Cache, k_new: jnp.ndarray, v_new: jnp.ndarray,
                        positions: jnp.ndarray) -> Cache:
    """Append one prompt chunk at arbitrary ``positions`` (B, S) int32 —
    chunked prefill (a long admission is split so in-flight decodes are
    not stalled behind one monolithic prefill).  Scatter-based like
    ``write_decode_multi``; serving path only, not the dry-run lowering."""
    w = cache["k"].shape[1]
    slots = positions % w                       # (B, S)
    b_idx = jnp.arange(k_new.shape[0])[:, None]
    k = cache["k"].at[b_idx, slots].set(k_new.astype(cache["k"].dtype))
    v = cache["v"].at[b_idx, slots].set(v_new.astype(cache["v"].dtype))
    pos_arr = cache["pos"].at[b_idx, slots].set(positions.astype(jnp.int32))
    return {"k": k, "v": v, "pos": pos_arr}


def write_decode_multi(cache: Cache, k_new: jnp.ndarray, v_new: jnp.ndarray,
                       pos: jnp.ndarray) -> Cache:
    """Per-row decode write: ``pos`` is (B,) int32 (continuous batching —
    every slot is at its own position).  Scatter-based; used by the
    single-host serving engine, NOT by the dry-run decode path (which
    keeps the partition-friendly scalar-position write below)."""
    w = cache["k"].shape[1]
    slots = pos % w  # (B,)
    b_idx = jnp.arange(k_new.shape[0])
    k = cache["k"].at[b_idx, slots].set(
        k_new[:, 0].astype(cache["k"].dtype))
    v = cache["v"].at[b_idx, slots].set(
        v_new[:, 0].astype(cache["v"].dtype))
    pos_arr = cache["pos"].at[b_idx, slots].set(pos.astype(jnp.int32))
    return {"k": k, "v": v, "pos": pos_arr}


def write_decode(cache: Cache, k_new: jnp.ndarray, v_new: jnp.ndarray,
                 pos: jnp.ndarray) -> Cache:
    """Write one token at scalar position ``pos`` (same for all batch rows).

    k_new/v_new: (B, 1, n_kv, hd); pos: () int32.  dynamic-update-slice keeps
    the sharded window axis partition-friendly (no scatter).
    """
    w = cache["k"].shape[1]
    slot = pos % w
    B = k_new.shape[0]
    k = jax.lax.dynamic_update_slice_in_dim(
        cache["k"], k_new.astype(cache["k"].dtype), slot, axis=1)
    v = jax.lax.dynamic_update_slice_in_dim(
        cache["v"], v_new.astype(cache["v"].dtype), slot, axis=1)
    pos_upd = jnp.full((B, 1), pos, jnp.int32)
    pos_arr = jax.lax.dynamic_update_slice_in_dim(cache["pos"], pos_upd, slot, axis=1)
    return {"k": k, "v": v, "pos": pos_arr}
