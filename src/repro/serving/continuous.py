"""Continuous batching: a fixed pool of decode slots, each at its own
position; requests join as slots free up and leave on EOS/max-tokens —
no head-of-line blocking like the static grouped engine.

Runs over any ``ServingBackend``:

* ``ModelBackend``     — jitted monolithic ``Model`` (scatter cache writes,
  see kv_cache.write_decode_multi); wall-clock metrics.
* ``FiddlerBackend``   — the paper's CPU-GPU orchestrator: the planner sees
  the mixed in-flight batch's expert counts each step and the ledger
  advances in simulated seconds, which is also the clock that TTFT/ITL
  are recorded from.
* ``SimulatedBackend`` — no weights: routing sampled from the popularity
  profile, only the ledger advances (paper-scale load sweeps).

Admission can be **chunked** (``prefill_chunk=N``): a long prompt is
prefilled N tokens per engine step into a batch-1 staging cache while the
in-flight slots keep decoding, then joins the multi-slot cache — so one
long admission never stalls the whole pool.  Requests may carry an
``arrival`` time (load generators set it in backend-clock units); the
engine admits a request only once the clock has reached it.

Scheduling decisions — admission order, preemption victims, and the live
slot-pool size — are delegated to a pluggable ``SchedulerPolicy`` (see
serving/policy.py).  The default ``FIFOPolicy`` reproduces the engine's
pre-policy behavior exactly.  Preempted requests return to the queue
carrying their generated tokens and are re-admitted through the (chunked)
prefill path: the prompt plus all-but-the-last emitted token is
re-prefilled, then decoding resumes from the last token — so greedy
outputs are preemption-invariant and in-flight decodes never stall.

**Beam groups** (``Request(beam_width=W)``) are gang-scheduled: the
group claims W slots atomically (or waits), the prompt is prefilled once
into the lead slot and the other beams are ``fork_slot`` aliases — under
the paged KV layout the beams *share* their prompt-prefix blocks — and
each decode step ends with a beam reshuffle via ``reorder_slots`` (a
block-table permutation: zero KV data movement).  Preemption is atomic
too: evicting any member returns the whole group (with its per-beam
tokens and scores) to the queue; re-admission re-prefills every beam and
resumes the search exactly where it stopped.  Beam groups interleave
freely with ordinary requests in the same decode batch.
"""
from __future__ import annotations

import warnings
from dataclasses import dataclass, field
from typing import Any, Dict, List, Optional

import jax.numpy as jnp
import numpy as np

from repro.data.tokenizer import EOS_ID, PAD_ID
from repro.serving.backend import ServingBackend, as_backend
from repro.serving.beam_search import _top_w
from repro.serving.engine import Request
from repro.serving.policy import (
    QueueView,
    SchedulerView,
    SlotView,
    get_policy,
)
from repro.serving.sampler import greedy, log_softmax

# EWMA weight for the inter-arrival-gap estimate feeding
# SchedulerView.arrival_rate (AutoscalePolicy's input).
RATE_EWMA_ALPHA = 0.3


@dataclass
class _BeamGroup:
    """Gang state of one in-flight beam group: W slots decoding in
    lockstep, reshuffled together each step."""
    req: Request
    slots: List[int]                      # member slot indices (lead first)
    scores: Optional[np.ndarray] = None   # (W,) cumulative log-probs
    tokens: List[List[int]] = field(default_factory=list)  # per-beam emitted

    def ready(self, slots: List["_Slot"]) -> bool:
        """All members prefilled and decoding — the gang barrier."""
        return all(slots[i].phase == "decode" for i in self.slots)


@dataclass
class _Slot:
    req: Optional[Request] = None
    phase: str = "idle"        # idle | prefill | reserved | decode
    pos: int = 0               # next decode position
    last_token: int = 0
    steps_left: int = 0
    staging: Any = None        # batch-1 cache being chunk-prefilled
    prefilled: int = 0         # prompt tokens already processed
    started: Optional[float] = None  # backend-clock admission time
    group: Optional[_BeamGroup] = None  # beam-gang membership
    resume_seq: Optional[List[int]] = None  # per-beam re-prefill sequence


class ContinuousEngine:
    def __init__(self, backend, params=None, *, n_slots: int = 4,
                 max_seq: int = 256, prefill_chunk: Optional[int] = None,
                 policy=None):
        """``backend``: a ``ServingBackend``, or a ``Model`` together with
        ``params`` (coerced to a ``ModelBackend`` for back-compat).
        ``prefill_chunk=None`` admits whole prompts in one step (exactly
        the monolithic prefill numerics); an integer enables chunked
        admission.  ``policy``: a ``SchedulerPolicy`` instance/name
        (default ``FIFOPolicy`` — exact pre-policy behavior)."""
        if prefill_chunk is not None and prefill_chunk < 1:
            raise ValueError(
                f"prefill_chunk must be >= 1 (or None for whole-prompt "
                f"admission), got {prefill_chunk}")
        if not isinstance(backend, ServingBackend):
            backend = as_backend(backend, params=params, max_seq=max_seq)
        assert backend.max_seq == max_seq, (backend.max_seq, max_seq)
        self.backend = backend
        self.n_slots = n_slots          # hard cap on the pool
        self.max_seq = max_seq
        self.prefill_chunk = prefill_chunk
        self.policy = get_policy(policy)
        self.queue: List[Request] = []
        self.slots = [_Slot() for _ in range(n_slots)]
        self.steps = 0
        self.finished: List[Request] = []
        # arrival-rate EWMA state (engine-owned so policies stay pure)
        self._rate = 0.0
        self._gap_ewma: Optional[float] = None
        self._last_arrival: Optional[float] = None
        self._rate_counted: set = set()
        # live pool: the policy sizes it; cache rows are allocated lazily
        # (grown via backend.resize_cache) so autoscaling starts small
        boot = self._view(slot_limit=1)
        self.slot_limit = max(1, min(n_slots,
                                     int(self.policy.target_slots(boot))))
        self._alloc = self.slot_limit   # cache rows currently allocated
        self.cache = backend.make_cache(self._alloc)

    # ------------------------------------------------------------------
    def clock(self) -> float:
        return self.backend.clock()

    def submit(self, req: Request) -> None:
        if len(req.prompt) >= self.max_seq:
            raise ValueError(
                f"request {req.rid}: prompt length {len(req.prompt)} >= "
                f"max_seq {self.max_seq} leaves no decode budget")
        if req.beam_width > self.n_slots:
            raise ValueError(
                f"request {req.rid}: beam_width {req.beam_width} exceeds "
                f"the slot pool ({self.n_slots}) — the gang can never be "
                f"admitted")
        if req.arrival is None:
            req.arrival = self.clock()
        self.queue.append(req)

    @property
    def active(self) -> int:
        return sum(1 for s in self.slots if s.req is not None)

    # -- scheduler view -------------------------------------------------
    def _view(self, slot_limit: Optional[int] = None) -> SchedulerView:
        now = self.clock()
        q = tuple(QueueView.from_request(i, r)
                  for i, r in enumerate(self.queue))
        def _phase(sl: _Slot) -> str:
            # a gang member that finished its re-prefill while siblings
            # are still resuming is NOT decoding yet (the gang barrier
            # holds it out of the batch) — and it is not evictable either
            # (_evict refuses non-ready gangs), so don't advertise it to
            # policies as a preemption candidate
            if (sl.group is not None and sl.phase == "decode"
                    and not sl.group.ready(self.slots)):
                return "resume"
            return sl.phase

        s = tuple(
            SlotView(index=i, rid=sl.req.rid if sl.req else None,
                     phase=_phase(sl),
                     priority=sl.req.effective_priority if sl.req else 0,
                     slo_class=sl.req.slo_class if sl.req else "standard",
                     deadline=sl.req.deadline if sl.req else None,
                     pos=sl.pos,
                     prompt_len=len(sl.req.prompt) if sl.req else 0,
                     emitted=len(sl.req.output) if sl.req else 0,
                     steps_left=sl.steps_left, started=sl.started,
                     arrival=sl.req.arrival if sl.req else None,
                     gang=sl.group.req.rid if sl.group else None,
                     gang_size=len(sl.group.slots) if sl.group else 1)
            for i, sl in enumerate(self.slots))
        return SchedulerView(
            clock=now, queue=q, slots=s,
            slot_limit=self.slot_limit if slot_limit is None else slot_limit,
            max_slots=self.n_slots, arrival_rate=self._rate)

    def _update_rate(self, now: float) -> None:
        """EWMA the inter-arrival gap over requests whose arrival the
        clock has reached (each counted once, preemptions excluded)."""
        fresh = [r for r in self.queue
                 if r.rid not in self._rate_counted
                 and (r.arrival is None or r.arrival <= now)]
        for r in sorted(fresh, key=lambda r: (r.arrival is not None,
                                              r.arrival or 0.0)):
            self._rate_counted.add(r.rid)
            t = r.arrival if r.arrival is not None else now
            if self._last_arrival is not None:
                gap = max(t - self._last_arrival, 1e-9)
                self._gap_ewma = (gap if self._gap_ewma is None else
                                  RATE_EWMA_ALPHA * gap
                                  + (1 - RATE_EWMA_ALPHA) * self._gap_ewma)
                self._rate = 1.0 / self._gap_ewma
            self._last_arrival = t

    # -- policy mechanisms ----------------------------------------------
    def _autoscale(self) -> None:
        target = int(self.policy.target_slots(self._view()))
        target = max(1, min(self.n_slots, target))
        # gang-admission floor: a beam group can never fit in fewer live
        # slots than its width, so an arrived gang raises the pool to its
        # width (bounded by n_slots) — otherwise a conservative policy
        # target would deadlock it in the queue
        now = self.clock()
        gangs = [r.beam_width for r in self.queue
                 if r.beam_width > 1
                 and (r.arrival is None or r.arrival <= now)]
        if gangs:
            target = max(target, min(max(gangs), self.n_slots))
        if target > self._alloc:
            self.cache = self.backend.resize_cache(self.cache, target)
            self._alloc = target
        self.slot_limit = target

    def _evict(self, i: int) -> None:
        """Return slot ``i``'s request to the queue carrying its emitted
        tokens; re-admission resumes it via the (chunked) prefill path.
        A beam-gang member evicts the *whole group* atomically: the
        per-beam tokens and scores are stashed on the request and every
        member slot is released."""
        slot = self.slots[i]
        if slot.req is None:
            return
        if slot.group is not None:
            grp = slot.group
            if not grp.ready(self.slots):
                return  # gangs are only preemptable once fully decoding
            req = grp.req
            req.preemptions += 1
            req.beam_resume = {"tokens": [list(t) for t in grp.tokens],
                               "scores": np.asarray(grp.scores).copy()}
            for si in grp.slots:
                self.cache = self.backend.release_slot(self.cache, si)
                self.slots[si] = _Slot()
            self.queue.append(req)
            return
        if slot.phase != "decode":
            return  # policies may only preempt decoding slots
        req = slot.req
        req.preemptions += 1
        self.queue.append(req)
        self.cache = self.backend.release_slot(self.cache, i)
        self.slots[i] = _Slot()

    def _preempt(self) -> None:
        for i in self.policy.preempt(self._view()):
            if 0 <= int(i) < len(self.slots):
                self._evict(int(i))

    # ------------------------------------------------------------------
    def _admit_gang(self, req: Request, slots: List[int],
                    now: float) -> None:
        """Claim ``slots`` for a beam group atomically.  Fresh groups put
        the lead slot into prefill (one shared prompt prefill; members
        are forked from it on completion); resumed groups re-prefill
        every beam's own sequence, then the gang barrier releases them
        into lockstep decode together."""
        grp = _BeamGroup(req=req, slots=list(slots))
        resume = req.beam_resume
        for j, i in enumerate(slots):
            slot = self.slots[i]
            slot.req = req
            slot.group = grp
            slot.staging = None
            slot.prefilled = 0
            slot.started = now
            if resume is None:
                slot.phase = "prefill" if j == 0 else "reserved"
            else:
                beam = resume["tokens"][j]
                slot.phase = "prefill"
                slot.resume_seq = list(req.prompt) + list(beam[:-1])
        if resume is not None:
            grp.tokens = [list(t) for t in resume["tokens"]]
            grp.scores = np.asarray(resume["scores"]).copy()
            req.beam_resume = None

    def _admit(self) -> None:
        now = self.clock()
        free = [i for i in range(self.slot_limit)
                if self.slots[i].req is None]
        if not free:
            return
        order = self.policy.admission_order(self._view())
        chosen: set = set()  # id()s — Request is an unhashable dataclass
        for qi in order:
            if not free:
                break
            if not (0 <= int(qi) < len(self.queue)):
                continue
            req = self.queue[int(qi)]
            if id(req) in chosen or (req.arrival is not None
                                     and req.arrival > now):
                continue  # not arrived (or duplicate index): skip
            if req.beam_width > 1:
                if len(free) < req.beam_width:
                    continue  # gang admission: all W slots or none
                chosen.add(id(req))
                self._admit_gang(req, free[: req.beam_width], now)
                free = free[req.beam_width:]
                continue
            chosen.add(id(req))
            i = free.pop(0)
            slot = self.slots[i]
            slot.req = req
            slot.phase = "prefill"
            slot.staging = None
            slot.prefilled = 0
            slot.started = now
        if chosen:
            self.queue = [r for r in self.queue if id(r) not in chosen]

    def _resume_tokens(self, req: Request) -> List[int]:
        """The token sequence a preempted request must re-prefill: its
        prompt plus all emitted tokens except the last (whose KV is
        produced by the next decode step)."""
        return list(req.prompt) + list(req.output[:-1])

    def _activate_group(self, lead: int, logits: np.ndarray) -> None:
        """The lead slot's shared prompt prefill finished: pick the top-W
        distinct continuations of beam 0, fork the lead slot's KV into
        every member (block-table aliases under the paged layout — the
        beams share the prompt prefix) and release the gang into decode."""
        slot = self.slots[lead]
        grp, req = slot.group, slot.req
        W = len(grp.slots)
        logp = np.asarray(log_softmax(jnp.asarray(logits)[None]))[0]
        first = np.argsort(-logp)[:W]
        grp.scores = logp[first]
        grp.tokens = [[int(t)] for t in first]
        now = self.clock()
        req.ttft = now - req.arrival
        req.token_times.append(now)
        S = len(req.prompt)
        for j, si in enumerate(grp.slots):
            if si != lead:
                self.cache = self.backend.fork_slot(self.cache, lead, si)
            s = self.slots[si]
            s.phase = "decode"
            s.pos = S
            s.last_token = grp.tokens[j][0]
            s.steps_left = req.max_new_tokens - 1
        if req.max_new_tokens <= 1:
            self._retire_group(grp)

    def _resume_group_slot(self, i: int) -> None:
        """One beam's re-prefill finished (gang re-admission): restore
        its decode state; the gang barrier (``_BeamGroup.ready``) holds
        the group out of the decode batch until every beam is back."""
        slot = self.slots[i]
        grp = slot.group
        j = grp.slots.index(i)
        beam = grp.tokens[j]
        slot.resume_seq = None
        slot.phase = "decode"
        slot.pos = len(grp.req.prompt) + len(beam) - 1
        slot.last_token = beam[-1]
        slot.steps_left = grp.req.max_new_tokens - len(beam)

    def _prefill_step(self) -> None:
        """Advance every prefilling slot by one chunk (or the whole prompt
        when chunking is off)."""
        for i, slot in enumerate(self.slots):
            if slot.phase != "prefill":
                continue
            req = slot.req
            if slot.group is not None:
                group_resume = slot.resume_seq is not None
                seq = slot.resume_seq if group_resume else req.prompt
                resume = False
            else:
                group_resume = False
                resume = len(req.output) > 0  # preempted: re-prefill KV
                seq = self._resume_tokens(req) if resume else req.prompt
            if self.prefill_chunk is None:
                logits, slot.staging = self.backend.prefill(seq)
                slot.prefilled = len(seq)
            else:
                chunk = seq[slot.prefilled:
                            slot.prefilled + self.prefill_chunk]
                logits, slot.staging = self.backend.prefill_chunk(
                    slot.staging, chunk, slot.prefilled)
                slot.prefilled += len(chunk)
                if slot.prefilled < len(seq):
                    continue  # more chunks; in-flight decodes run meanwhile
            # prefill complete: join the multi-slot batch
            self.cache = self.backend.write_slot(self.cache, slot.staging, i)
            slot.staging = None
            if group_resume:
                self._resume_group_slot(i)
                continue
            if slot.group is not None:
                self._activate_group(i, logits)
                continue
            slot.phase = "decode"
            if resume:
                # decoding continues from the last emitted token; the
                # re-prefill logits (which re-predict it) are discarded
                slot.pos = len(seq)
                slot.last_token = req.output[-1]
                slot.steps_left = req.max_new_tokens - len(req.output)
                if (slot.last_token == EOS_ID or slot.steps_left <= 0
                        or slot.pos >= self.max_seq - 1):
                    self._retire(i)
                continue
            # fresh admission: the prompt's first generated token
            tok = int(np.argmax(logits))
            now = self.clock()
            req.output.append(tok)
            req.token_times.append(now)
            req.ttft = now - req.arrival
            slot.pos = len(req.prompt)
            slot.last_token = tok
            slot.steps_left = req.max_new_tokens - 1
            if tok == EOS_ID or slot.steps_left <= 0:
                self._retire(i)

    def _retire(self, i: int) -> None:
        slot = self.slots[i]
        if slot.req is not None:
            slot.req.latency = self.clock() - slot.req.arrival
            self.finished.append(slot.req)
        self.cache = self.backend.release_slot(self.cache, i)
        self.slots[i] = _Slot()

    def _retire_group(self, grp: _BeamGroup) -> None:
        """The group's step budget is exhausted: report the best beam as
        ``output`` (all beams in ``beam_tokens``/``beam_scores``) and
        free every member slot."""
        req = grp.req
        req.output = list(grp.tokens[0])   # scores are kept descending
        req.beam_tokens = np.asarray([list(t) for t in grp.tokens],
                                     np.int32)
        req.beam_scores = np.asarray(grp.scores)
        req.latency = self.clock() - req.arrival
        self.finished.append(req)
        for si in grp.slots:
            self.cache = self.backend.release_slot(self.cache, si)
            self.slots[si] = _Slot()

    def _beam_step(self, grp: _BeamGroup, logits: np.ndarray,
                   now: float) -> None:
        """One lockstep extension of a live beam group: top-W over the
        group's candidates, then the reshuffle — ``reorder_slots`` is a
        block-table permutation under the paged layout, so no KV moves."""
        rows = grp.slots
        lp = np.asarray(log_softmax(jnp.asarray(logits[rows])))
        beam_idx, tok_idx, grp.scores = _top_w(grp.scores, lp, len(rows))
        grp.tokens = [grp.tokens[int(b)] + [int(t)]
                      for b, t in zip(beam_idx, tok_idx)]
        src = [rows[int(b)] for b in beam_idx]
        if src != rows:
            self.cache = self.backend.reorder_slots(self.cache, rows, src)
        done = False
        for j, si in enumerate(rows):
            s = self.slots[si]
            s.pos += 1
            s.last_token = int(tok_idx[j])
            s.steps_left -= 1
            done = done or s.steps_left <= 0 or s.pos >= self.max_seq - 1
        grp.req.token_times.append(now)
        if done:
            self._retire_group(grp)

    def _decode_step(self) -> None:
        def live(i: int) -> bool:
            s = self.slots[i]
            if s.phase != "decode":
                return False
            # gang barrier: a beam group only decodes once every member
            # is back in the batch (relevant mid-resume)
            return s.group is None or s.group.ready(self.slots)

        decoding = [live(i) for i in range(self._alloc)]
        if not any(decoding):
            return
        tokens = np.full((self._alloc,), PAD_ID, np.int32)
        pos = np.zeros((self._alloc,), np.int32)
        for i in range(self._alloc):
            if decoding[i]:
                tokens[i] = self.slots[i].last_token
                pos[i] = self.slots[i].pos
        logits, self.cache = self.backend.decode_slots(
            self.cache, tokens, pos, np.asarray(decoding))
        next_tok = greedy(logits)
        now = self.clock()
        self.steps += 1
        groups: Dict[int, _BeamGroup] = {}
        for i in range(self._alloc):
            if not decoding[i]:
                continue
            s = self.slots[i]
            if s.group is not None:
                groups.setdefault(id(s.group), s.group)
                continue
            tok = int(next_tok[i])
            s.req.output.append(tok)
            s.req.token_times.append(now)
            s.pos += 1
            s.last_token = tok
            s.steps_left -= 1
            if tok == EOS_ID or s.steps_left <= 0 or s.pos >= self.max_seq - 1:
                self._retire(i)
        for grp in groups.values():
            self._beam_step(grp, logits, now)

    def step(self) -> None:
        """One scheduler tick: observe arrivals → resize the live pool →
        preempt → admit → advance prefills one chunk → one decode step
        for every decoding slot → one placement-rebalance tick (dynamic
        backends may migrate experts between tiers here, charging the
        transfer to their clock — see core/rebalance.py)."""
        self._update_rate(self.clock())
        self._autoscale()
        self._preempt()
        self._admit()
        self._prefill_step()
        self._decode_step()
        self.backend.maybe_rebalance()

    def _admissible(self) -> bool:
        now = self.clock()
        for qi in self.policy.admission_order(self._view()):
            if 0 <= int(qi) < len(self.queue):
                r = self.queue[int(qi)]
                if r.arrival is None or r.arrival <= now:
                    return True
        return False

    def run(self, max_steps: int = 10_000,
            on_exhausted: str = "warn") -> List[Request]:
        """Drive the scheduler until every request finishes or
        ``max_steps`` ticks elapse.  An exhausted step budget with work
        still queued/in flight warns (``on_exhausted="warn"``, default)
        or raises (``"raise"``) instead of silently dropping requests."""
        assert on_exhausted in ("warn", "raise", "ignore"), on_exhausted
        steps = 0
        while (self.queue or self.active) and steps < max_steps:
            if self.active == 0 and self.queue and not self._admissible():
                # pool idle, nothing admittable yet: fast-forward to the
                # next arrival instead of busy-spinning
                now = self.clock()
                future = [r.arrival for r in self.queue
                          if r.arrival is not None and r.arrival > now]
                if future:
                    self.backend.wait_until(min(future))
            self.step()
            steps += 1
        if self.queue or self.active:
            msg = (f"ContinuousEngine.run: step budget max_steps="
                   f"{max_steps} exhausted with {len(self.queue)} queued "
                   f"and {self.active} in-flight requests unfinished")
            if on_exhausted == "raise":
                raise RuntimeError(msg)
            if on_exhausted == "warn":
                warnings.warn(msg, RuntimeWarning, stacklevel=2)
        # settle in-flight migration prefetches so ledger accounting of
        # this run is complete (core/rebalance.py PrefetchQueue)
        self.backend.finalize()
        return self.finished
