"""FID005 fixture: host-pool thread-safety.

Worker entry point for this module: ``Worker.__call__``.
"""
import threading

_POOL = None
_SAFE = None
_LOCK = threading.Lock()


def make_pool():
    return object()


def get_pool_racy():
    global _POOL
    if _POOL is None:  # EXPECT: FID005
        _POOL = make_pool()
    return _POOL


def get_pool_safe():
    # false-positive candidate: double-checked locking — the assignment
    # happens under the lock
    global _SAFE
    if _SAFE is None:
        with _LOCK:
            if _SAFE is None:
                _SAFE = make_pool()
    return _SAFE


class Worker:
    def __init__(self):
        self._lock = threading.Lock()
        self.unsafe_count = 0
        self.safe_count = 0

    def __call__(self, x):
        self.unsafe_count = self.unsafe_count + 1  # EXPECT: FID005
        with self._lock:
            self.safe_count = self.safe_count + 1  # ok: guarded write
        local = x * 2  # ok: local state only
        return local
