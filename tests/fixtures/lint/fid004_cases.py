"""FID004 fixture: ledger charge conventions.

Charge call sites must name ``n_tokens=`` and ``kv_len=``; every
``*_time`` field on the Ledger (other than the exempt clock) needs its
``*_overlapped`` / ``*_exposed`` split, and no orphan split field may
exist without its ``*_time`` base.
"""
from dataclasses import dataclass


@dataclass
class Ledger:
    sim_time: float = 0.0  # ok: exempt aggregate clock
    migration_time: float = 0.0  # ok: split declared below
    migration_overlapped: float = 0.0
    migration_exposed: float = 0.0
    spill_time: float = 0.0  # EXPECT: FID004
    flops: float = 0.0
    decode_stream_time: float = 0.0  # ok: full triple
    decode_stream_overlapped: float = 0.0
    decode_stream_exposed: float = 0.0
    phantom_overlapped: float = 0.0  # EXPECT: FID004
    phantom_exposed: float = 0.0  # EXPECT: FID004


class Engine:
    def _charge(self, li, plan, n_tokens, kv_len):
        return li, plan, n_tokens, kv_len

    def good_site(self, li, plan):
        self._charge(li, plan, n_tokens=4, kv_len=128)  # ok: named kwargs

    def bad_positional(self, li, plan):
        self._charge(li, plan, 4, 128)  # EXPECT: FID004

    def bad_partial(self, li, plan):
        self._charge(li, plan, n_tokens=4)  # EXPECT: FID004
