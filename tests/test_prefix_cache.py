"""Cross-request prefix cache on the paged KV pool: index
register/match/map roundtrips, cached-block retention + LRU reclaim,
poisoned-hash fallback, refcount-leak property tests, fp32 bit-identity
of matched admissions, the zero-copy chunked join, gang re-admission
block sharing, and EOS handling inside beam groups."""
import numpy as np
from hypothesis import given, settings, strategies as st

from conftest import reduced_model
from repro.configs import get_config
from repro.core import FiddlerEngine
from repro.data.tokenizer import EOS_ID, PAD_ID
from repro.models.paged_kv import (
    BlockMeta,
    PagedSlotStage,
    _chain_hashes,
)
from repro.serving.backend import FiddlerBackend, SimulatedBackend
from repro.serving.continuous import ContinuousEngine
from repro.serving.engine import Request


def _engine(**kw):
    cfg, model, params = reduced_model("mixtral-8x7b")
    kw.setdefault("expert_budget", 30)
    kw.setdefault("kv_block_size", 8)
    return FiddlerEngine(cfg, params, policy="fiddler",
                         host_precision="fp32", **kw)


def _sim_backend(max_seq=128):
    cfg = get_config("mixtral-8x7b")
    fe = FiddlerEngine(cfg, policy="fiddler", seed=0)
    return SimulatedBackend(fe, max_seq=max_seq)


# ---------------------------------------------------------------------------
# PrefixIndex / BlockMeta unit behavior
# ---------------------------------------------------------------------------


def test_prefix_register_match_map_roundtrip():
    m = BlockMeta(2, 64, 16)
    idx = m.enable_prefix_cache()
    toks = list(range(3, 43))            # 40 tokens: 2 full blocks + tail
    m.write_span(0, 0, 40)
    m.register_prefix(0, toks)
    assert len(idx) == 2                 # only full blocks are published
    blocks = m.match_prefix(toks)
    assert blocks == list(m.table[0][:2])
    m.release_slot(0)
    m.check()
    assert m.n_cached == 2               # registered blocks survive ref==0
    # splice the resident prefix into a fresh slot and extend it
    m.map_prefix(1, m.match_prefix(toks))
    m.check()
    assert m.n_cached == 0 and m.blocks_in_use([1]) == 2
    assert m.unique_tokens([1]) == 32
    m.write_span(1, 32, 40)
    m.release_slot(1)
    m.check()
    assert m.blocks_in_use() == 0 and m.n_cached == 2


def test_divergent_tokens_match_only_the_common_prefix():
    m = BlockMeta(2, 64, 16)
    m.enable_prefix_cache()
    toks = [7] * 32 + [9] * 16           # 3 full blocks
    m.write_span(0, 0, 48)
    m.register_prefix(0, toks)
    # same first 2 blocks, divergent third: chain match stops at 2
    assert len(m.match_prefix([7] * 32 + [8] * 16)) == 2
    assert len(m.match_prefix([6] * 48)) == 0


def test_cached_blocks_reclaimed_lru_under_pressure():
    m = BlockMeta(2, 32, 16)             # 4 usable blocks
    m.enable_prefix_cache()
    m.write_span(0, 0, 32)
    m.register_prefix(0, [7] * 32)
    m.release_slot(0)
    assert m.n_cached == 2 and m.n_free == 2
    # demand beyond the free list reclaims cached blocks instead of
    # raising pool exhaustion
    m.write_span(0, 0, 32)
    m.write_span(1, 0, 32)
    m.check()
    assert m.n_cached == 0 and m.blocks_in_use() == 4
    assert len(m.match_prefix([7] * 32)) == 0  # evicted → deregistered


def test_poisoned_hash_entry_is_rejected():
    m = BlockMeta(2, 64, 16)
    idx = m.enable_prefix_cache()
    toks = list(range(3, 35))
    m.write_span(0, 0, 32)
    m.register_prefix(0, toks)
    assert len(m.match_prefix(toks)) == 2
    # collision model: the hash now maps to different stored tokens —
    # verification against the stored tuple must reject the whole chain
    h0, _ = _chain_hashes(toks, 16)[0]
    b0, stored = idx.entries[h0]
    idx.entries[h0] = (b0, tuple(x + 1 for x in stored))
    assert m.match_prefix(toks) == []


@given(st.lists(st.integers(0, 2**16 - 1), min_size=1, max_size=200))
@settings(max_examples=60, deadline=None)
def test_random_interleavings_never_leak(ops):
    """Random admit/write/fork/register/match/release interleavings keep
    every BlockMeta invariant (``check()``), and releasing everything at
    the end returns the pool to empty — no refcount leaks."""
    W, BS, S = 64, 16, 4
    m = BlockMeta(S, W, BS)
    m.enable_prefix_cache()
    fill = [0] * S
    toks = [[] for _ in range(S)]
    for op in ops:
        s = op % S
        kind = (op >> 2) % 5
        if kind == 0:                    # append a span
            n = (op >> 5) % BS + 1
            end = min(fill[s] + n, W)
            if end > fill[s]:
                m.write_span(s, fill[s], end)
                toks[s] += [(op >> 3) % 251 + 3] * (end - fill[s])
                fill[s] = end
        elif kind == 1:                  # release
            m.release_slot(s)
            fill[s], toks[s] = 0, []
        elif kind == 2:                  # fork onto the next slot
            d = (s + 1) % S
            m.release_slot(d)
            m.fork_slot(s, d)
            fill[d], toks[d] = fill[s], list(toks[s])
        elif kind == 3:                  # publish the row
            if fill[s]:
                m.register_prefix(s, toks[s])
        else:                            # match + map into a fresh slot
            d = (s + 1) % S
            m.release_slot(d)
            fill[d], toks[d] = 0, []
            q = toks[s] or [3, 4, 5]
            blocks = m.match_prefix(q)
            n = min(len(blocks), max(0, (len(q) - 1) // BS))
            if n:
                m.map_prefix(d, blocks[:n])
                fill[d], toks[d] = n * BS, q[: n * BS]
        m.check()
    for s in range(S):
        m.release_slot(s)
    m.check()
    assert m.blocks_in_use() == 0


# ---------------------------------------------------------------------------
# real numerics: matched admissions are bit-identical, joins move no bytes
# ---------------------------------------------------------------------------


def test_matched_prefix_prefill_bit_identical_fp32():
    """Sequential requests sharing a 16-token preamble: the second run
    decodes from spliced cached blocks, and its greedy output is
    bit-identical to the same workload with the prefix cache off."""
    pre = list(range(3, 19))
    tails = ([40 + i for i in range(8)], [60 + i for i in range(8)])
    outs = {}
    for pc in (True, False):
        fe = _engine(prefix_cache=pc)
        eng = ContinuousEngine(FiddlerBackend(fe, max_seq=48), n_slots=1,
                               max_seq=48, prefill_chunk=8)
        done = []
        for i, tail in enumerate(tails):
            eng.submit(Request(rid=f"r{i}", prompt=pre + list(tail),
                               max_new_tokens=4))
            done = eng.run(max_steps=500)
        outs[pc] = [r.output for r in sorted(done, key=lambda r: r.rid)]
        if pc:
            assert fe.ledger.prefix_hits >= 1
        else:
            assert fe.ledger.prefix_lookups == 0
    assert outs[True] == outs[False]


def test_chunked_admission_joins_without_device_copies():
    """Chunked admission stages straight into the target pool row: the
    join (write_slot) is a pure table splice — the per-layer pool arrays
    keep their identity, no block is copied."""
    fe = _engine()
    b = FiddlerBackend(fe, max_seq=48)
    cache = b.make_cache(2)
    prompt = list(range(3, 23))          # 20 tokens, 3 chunks of 8
    stage = None
    for off in range(0, len(prompt), 8):
        _, stage = b.prefill_chunk(stage, prompt[off: off + 8], off,
                                   cache=cache, slot=1)
    assert all(isinstance(s, PagedSlotStage) for s in stage)
    ids = [(id(c.k), id(c.v)) for c in cache]
    cache = b.write_slot(cache, stage, 1)
    assert [(id(c.k), id(c.v)) for c in cache] == ids
    m = cache[0].meta
    m.check()
    assert m.blocks_in_use([1]) == 3     # ceil(20/8)


# ---------------------------------------------------------------------------
# gang re-admission: shared prompt re-prefilled once, block sharing kept
# ---------------------------------------------------------------------------


def test_gang_resume_shares_prompt_blocks():
    backend = _sim_backend(max_seq=128)
    eng = ContinuousEngine(backend, n_slots=2, max_seq=128,
                           prefill_chunk=16)
    prompt = [1] * 48
    eng.submit(Request(rid="beam", prompt=prompt, beam_width=2,
                       max_new_tokens=12))
    m = eng.cache["meta"]
    grp = None
    for _ in range(200):
        eng.step()
        grp = eng.slots[0].group
        if (grp is not None and grp.tokens
                and all(eng.slots[i].phase == "decode" for i in grp.slots)
                and len(grp.tokens[0]) >= 4):
            break
    assert grp is not None and len(grp.tokens[0]) >= 4
    u_before = m.blocks_in_use()
    assert u_before < m.dense_blocks()   # beams share the prompt blocks
    tok_before = [list(t) for t in grp.tokens]

    chunks = {"tokens": 0}
    orig = backend.prefill_chunk

    def counting(slot_cache, chunk, pos_offset, **kw):
        chunks["tokens"] += len(list(chunk))
        return orig(slot_cache, chunk, pos_offset, **kw)

    backend.prefill_chunk = counting
    eng._evict(grp.slots[0])
    assert m.blocks_in_use() == 0        # eviction released the gang
    for _ in range(500):
        eng.step()
        g2 = next((eng.slots[i].group for i in range(2)
                   if eng.slots[i].group is not None), None)
        if (g2 is not None and g2.tokens
                and all(eng.slots[i].phase == "decode" for i in g2.slots)
                and all(eng.slots[i].replay is None for i in g2.slots)
                and [list(t) for t in g2.tokens] == tok_before):
            break
    else:  # pragma: no cover
        raise AssertionError("gang never finished resuming")
    # the shared prompt was re-prefilled once, not per beam — and the
    # prefix cache (the prompt registered at the first join) covered its
    # first 2 blocks, so only the 16-token tail actually prefilled
    assert chunks["tokens"] == len(prompt) - 32
    # unique-block residency matches the pre-eviction state: the 3
    # prompt blocks are shared once across the gang, not re-prefilled
    # per beam (the beams' *current* partial block may differ by one —
    # lockstep reorders transiently re-collapse it, replay rebuilds it
    # per beam)
    m.check()
    assert m.blocks_in_use() <= u_before + 1
    assert m.dense_blocks() - m.blocks_in_use() >= 3
    assert m.blocks_in_use() < m.dense_blocks()
    backend.prefill_chunk = orig
    done = eng.run(max_steps=2000)
    assert done[0].beam_tokens.shape == (2, 12)
    assert m.blocks_in_use() == 0


# ---------------------------------------------------------------------------
# EOS inside beam groups
# ---------------------------------------------------------------------------


class _EOSBackend(SimulatedBackend):
    """Simulated backend whose decode logits put EOS on top for chosen
    physical rows from the Nth decode call onward."""

    def __init__(self, engine, *, eos_call, rows=None, **kw):
        super().__init__(engine, **kw)
        self.eos_call = eos_call
        self.rows = rows
        self.calls = 0

    def decode_slots(self, cache, tokens, pos, active):
        logits, cache = super().decode_slots(cache, tokens, pos, active)
        self.calls += 1
        if self.calls >= self.eos_call:
            rows = range(len(logits)) if self.rows is None else self.rows
            for r in rows:
                logits[r, EOS_ID] = 2.0
        return logits, cache


def test_gang_retires_early_when_all_beams_hit_eos():
    cfg = get_config("mixtral-8x7b")
    fe = FiddlerEngine(cfg, policy="fiddler", seed=0)
    backend = _EOSBackend(fe, eos_call=3, max_seq=128)
    eng = ContinuousEngine(backend, n_slots=2, max_seq=128)
    eng.submit(Request(rid="beam", prompt=[1] * 16, beam_width=2,
                       max_new_tokens=12))
    done = eng.run(max_steps=2000)
    assert len(done) == 1
    req = done[0]
    W, width = req.beam_tokens.shape
    assert W == 2 and width < 12         # retired well before the budget
    assert all(req.beam_tokens[j, -1] == EOS_ID for j in range(W))
    assert req.output[-1] == EOS_ID
    m = eng.cache["meta"]
    m.check()
    assert m.blocks_in_use() == 0        # early retire released the gang


def test_single_finished_beam_freezes_and_pads_ragged_retire():
    cfg = get_config("mixtral-8x7b")
    fe = FiddlerEngine(cfg, policy="fiddler", seed=0)
    # EOS lands only on physical row 0 (gang slot 0) — exactly one beam
    # finishes early, the rest run out their budget
    backend = _EOSBackend(fe, eos_call=2, rows=[0], max_seq=128)
    eng = ContinuousEngine(backend, n_slots=2, max_seq=128)
    eng.submit(Request(rid="beam", prompt=[1] * 16, beam_width=2,
                       max_new_tokens=6))
    done = eng.run(max_steps=2000)
    req = done[0]
    toks = req.beam_tokens
    assert toks.shape == (2, 6)          # padded to the longest beam
    lens = [len(t) - np.sum(np.asarray(t) == PAD_ID) for t in toks]
    has_eos = [EOS_ID in list(t) for t in toks]
    assert any(has_eos) and not all(has_eos)
    short = int(np.argmin(lens))
    assert toks[short, lens[short] - 1] == EOS_ID   # finished beam: EOS
    assert np.all(toks[short, lens[short]:] == PAD_ID)
    # ranking is by length-normalised score: best-first still holds
    norm = [req.beam_scores[j] / lens[j] for j in range(2)]
    assert norm[0] >= norm[1] - 1e-9
    assert list(req.output) == [int(t) for t in toks[0][: lens[0]]]
    m = eng.cache["meta"]
    m.check()
    assert m.blocks_in_use() == 0


# ---------------------------------------------------------------------------
# engine-level fallback + end-to-end sim invariants
# ---------------------------------------------------------------------------


def test_poisoned_entry_falls_back_to_full_prefill():
    backend = _sim_backend(max_seq=128)
    eng = ContinuousEngine(backend, n_slots=1, max_seq=128,
                           prefill_chunk=16)
    pre = [7] * 32
    eng.submit(Request(rid="r0", prompt=pre + [11] * 16, max_new_tokens=4))
    eng.run(max_steps=500)
    m = eng.cache["meta"]
    assert len(m.index) > 0
    for h, (b, stored) in list(m.index.entries.items()):
        m.index.entries[h] = (b, tuple(x + 1 for x in stored))
    eng.submit(Request(rid="r1", prompt=pre + [13] * 16, max_new_tokens=4))
    done = eng.run(max_steps=500)
    led = backend.engine.ledger
    assert led.prefix_lookups == 2 and led.prefix_hits == 0
    r1 = next(r for r in done if r.rid == "r1")
    assert len(r1.output) == 4           # full prefill, correct completion
    m.check()
    assert m.blocks_in_use() == 0


def test_queued_same_prefix_stream_hits_and_never_leaks():
    """End-to-end simulated serving: queued same-preamble requests hit
    the cache (registered at the first join), share resident blocks
    while concurrent, and drain with zero leaked blocks."""
    backend = _sim_backend(max_seq=256)
    eng = ContinuousEngine(backend, n_slots=4, max_seq=256,
                           prefill_chunk=16)
    pre = [7] * 64
    for i in range(12):
        eng.submit(Request(rid=f"r{i}", prompt=pre + [100 + i] * 16,
                           max_new_tokens=8, arrival=0.1 * i))
    done = eng.run(max_steps=20_000, on_exhausted="raise")
    assert len(done) == 12
    led = backend.engine.ledger
    assert led.prefix_hits > 0
    assert led.prefix_tokens == led.prefix_hits * 64
    m = eng.cache["meta"]
    m.check()
    assert m.blocks_in_use() == 0
