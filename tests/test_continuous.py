"""Continuous batching: per-slot positions must produce exactly the same
greedy continuations as isolated single-request decoding, with slot
reuse and mid-flight joins."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from conftest import reduced_model
from repro.serving.continuous import ContinuousEngine
from repro.serving.engine import Request


def _reference_generation(model, params, prompt, n_new, max_seq=64):
    logits, cache = model.prefill(params, jnp.asarray([prompt], jnp.int32),
                                  max_seq=max_seq, cache_dtype=jnp.float32)
    out = [int(jnp.argmax(logits[0]))]
    for t in range(n_new - 1):
        logits, cache = model.decode_step(
            params, cache, jnp.asarray([[out[-1]]], jnp.int32),
            jnp.int32(len(prompt) + t), max_seq=max_seq)
        out.append(int(jnp.argmax(logits[0])))
    return out


@pytest.mark.parametrize("arch", ["qwen3-0.6b", "mixtral-8x7b"])
def test_continuous_matches_isolated(arch):
    cfg, model, params = reduced_model(arch)
    prompts = [[1, 17, 23, 9], [1, 40, 11], [1, 7, 7, 7, 2, 30],
               [1, 300, 5], [1, 12, 90, 44, 3]]
    n_new = 5
    # more requests than slots → forces slot reuse + mid-flight joins
    eng = ContinuousEngine(model, params, n_slots=2, max_seq=64)
    for i, p in enumerate(prompts):
        eng.submit(Request(rid=f"r{i}", prompt=p, max_new_tokens=n_new))
    done = {r.rid: r for r in eng.run()}
    assert len(done) == len(prompts)
    for i, p in enumerate(prompts):
        want = _reference_generation(model, params, p, n_new)
        got = done[f"r{i}"].output
        # EOS may truncate both identically; compare common prefix length
        assert got == want[: len(got)], (i, got, want)
        assert len(got) >= 1


def test_slots_do_not_leak_between_requests():
    """A request joining a reused slot must not see the previous
    occupant's KV entries."""
    cfg, model, params = reduced_model("qwen3-0.6b")
    p1, p2 = [1, 5, 9, 13, 2], [1, 30, 31]
    eng = ContinuousEngine(model, params, n_slots=1, max_seq=64)
    eng.submit(Request(rid="a", prompt=p1, max_new_tokens=4))
    eng.submit(Request(rid="b", prompt=p2, max_new_tokens=4))
    done = {r.rid: r for r in eng.run()}
    want_b = _reference_generation(model, params, p2, 4)
    assert done["b"].output == want_b[: len(done['b'].output)]


def test_throughput_accounting():
    cfg, model, params = reduced_model("qwen3-0.6b")
    eng = ContinuousEngine(model, params, n_slots=3, max_seq=64)
    for i in range(4):
        eng.submit(Request(rid=f"r{i}", prompt=[1, 2 + i], max_new_tokens=3))
    done = eng.run()
    assert len(done) == 4
    assert all(r.ttft is not None and r.latency is not None for r in done)
