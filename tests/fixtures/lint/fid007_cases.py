"""FID007 fixture: mesh-dispatch hygiene.

Migration root for this module: ``Engine.apply_migrations``.
"""
import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental.shard_map import shard_map


def top_level_body(xs, ws):
    probe = np.asarray(xs)  # EXPECT: FID007
    return jnp.einsum("td,df->tf", xs + probe.shape[0], ws)


def run_moe(mesh, x, w, idx):
    def body(xs, ws):
        hot = float(xs.sum())  # EXPECT: FID007
        xs.block_until_ready()  # EXPECT: FID007
        return jnp.einsum("td,df->tf", xs * hot, ws)

    fn = shard_map(body, mesh=mesh, in_specs=None, out_specs=None)
    fn2 = shard_map(top_level_body, mesh=mesh, in_specs=None, out_specs=None)
    return fn(x, w) + fn2(x, w)


def run_moe_clean(mesh, x, w):
    # false-positive candidate: a fully traced body stays silent, and
    # host-side numpy prep OUTSIDE the body is FID001's concern, not ours
    cap = int(np.asarray(x).shape[0])

    def body(xs, ws):
        a = jnp.einsum("td,df->tf", xs, ws)
        return jax.nn.silu(a[:cap])

    return shard_map(body, mesh=mesh, in_specs=None, out_specs=None)(x, w)


class Engine:
    def __init__(self, devices):
        self.devices = devices

    def weights_of(self, e):
        return np.zeros((4, 4)), np.zeros((4, 4))

    def apply_migrations(self, plan):
        for e, dev in plan:
            moved = jax.device_put(self.weights_of(e), dev)  # EXPECT: FID007
            self.devices[dev] = moved

    def apply_migrations_batched(self, plan):
        # false-positive candidates: one put per device, payload built as
        # a list (literal or a name bound to a comprehension)
        by_dev = {}
        for e, dev in plan:
            by_dev.setdefault(dev, []).append(e)
        for dev, group in by_dev.items():
            batch = [self.weights_of(e) for e in group]
            self.devices[dev] = jax.device_put(batch, dev)  # ok: batched
            self.devices[dev] += jax.device_put([1, 2], dev)  # ok: literal

    def unrelated_loop_put(self, items, dev):
        # not reachable from a migration root: out of FID007 (b)'s scope
        for it in items:
            jax.device_put(it, dev)
