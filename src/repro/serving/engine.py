"""Batched serving engine: request queue → grouped prefill + decode.

Requests are grouped into static batches (padded prompts), prefilled once,
then decoded until EOS/max-tokens.  Execution goes through the common
``ServingBackend`` protocol (see serving/backend.py): the monolithic
jitted ``Model`` (capacity-sufficient regime) or the ``FiddlerEngine``
orchestrator (fast/slow-tier regime — the paper's setting).  Per-request
TTFT/ITL are recorded from the backend's clock — the engine's simulated
seconds when orchestrated, wall-clock otherwise.

Group formation is delegated to a pluggable ``SchedulerPolicy`` (see
serving/policy.py): the policy orders the queue — FIFO by default, or
SLO-class/deadline-aware with ``PriorityPolicy`` so interactive requests
batch ahead of bulk work.  Preemption and slot autoscaling are
continuous-batching mechanisms; the static engine consumes only the
admission order.

The cross-request prefix cache (serving/continuous.py) is likewise a
continuous-batching mechanism: static groups build ephemeral per-batch
caches that die with the group, so there are no resident blocks to
match against — the prefix hooks are clean no-ops here by design.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import List, Optional

import jax
import numpy as np

from repro.data.tokenizer import EOS_ID, PAD_ID
from repro.serving.backend import ServingBackend, as_backend
from repro.serving.policy import QueueView, SchedulerView, get_policy, slo_priority
from repro.serving.sampler import greedy, sample


@dataclass
class Request:
    rid: str
    prompt: List[int]
    max_new_tokens: int = 32
    temperature: float = 0.0
    arrival: Optional[float] = None     # backend-clock submit/arrival time
    # scheduling (SchedulerPolicy inputs)
    priority: Optional[int] = None      # None → derived from slo_class
    slo_class: str = "standard"         # batch | standard | interactive
    deadline: Optional[float] = None    # absolute backend-clock deadline
    # beam search: width > 1 makes this a gang-scheduled beam group — it
    # occupies ``beam_width`` decode slots at once (admitted, preempted
    # and re-admitted atomically), the beams share the prompt prefill
    # (one prefill + slot forks) and ``output`` becomes the best beam
    # (all beams land in ``beam_tokens``/``beam_scores``).  Beam search
    # is a log-prob argmax search: ``temperature`` is ignored.
    beam_width: int = 1
    # outputs
    output: List[int] = field(default_factory=list)
    token_times: List[float] = field(default_factory=list)
    ttft: Optional[float] = None
    latency: Optional[float] = None
    preemptions: int = 0                # times evicted mid-decode
    beam_tokens: Optional[np.ndarray] = None   # (width, n_new) all beams
    beam_scores: Optional[np.ndarray] = None   # (width,) length-norm-free
    # gang-eviction stash: per-beam tokens + scores for atomic re-admission
    beam_resume: Optional[dict] = None

    @property
    def effective_priority(self) -> int:
        """Explicit ``priority`` if set, else the SLO class default."""
        return self.priority if self.priority is not None \
            else slo_priority(self.slo_class)

    @property
    def itl(self) -> Optional[float]:
        """Mean inter-token latency (backend-clock seconds/token)."""
        if len(self.token_times) < 2:
            return None
        return float(self.token_times[-1] - self.token_times[0]) \
            / (len(self.token_times) - 1)


class ServingEngine:
    def __init__(self, backend, *, mode: Optional[str] = None, params=None,
                 max_batch: int = 8, max_seq: int = 512, seed: int = 0,
                 policy=None):
        """``backend``: a ``ServingBackend``, a ``Model`` (with ``params``;
        mode="model") or a ``FiddlerEngine`` (mode="fiddler").
        ``policy``: a ``SchedulerPolicy`` instance/name ordering group
        formation (default FIFO — exact pre-policy behavior)."""
        assert mode in (None, "model", "fiddler")
        self.raw_backend = backend
        self._backend: ServingBackend = as_backend(
            backend, params=params, mode=mode, max_seq=max_seq)
        from repro.serving.backend import FiddlerBackend

        self.mode = ("fiddler" if isinstance(self._backend, FiddlerBackend)
                     else "model")
        self.max_batch = max_batch
        self.max_seq = max_seq
        self.queue: List[Request] = []
        self.key = jax.random.PRNGKey(seed)
        self.policy = get_policy(policy)
        self._fault_steps = 0   # scheduler ticks — the fault injector's clock

    @property
    def backend(self):
        """The execution engine as passed in (back-compat: launchers read
        ``engine.backend.ledger`` for the orchestrated path)."""
        return self.raw_backend

    def submit(self, req: Request) -> None:
        if len(req.prompt) >= self.max_seq:
            raise ValueError(
                f"request {req.rid}: prompt length {len(req.prompt)} >= "
                f"max_seq {self.max_seq} leaves no decode budget")
        if req.arrival is None:
            req.arrival = self._backend.clock()
        self.queue.append(req)

    # ------------------------------------------------------------------
    def _clock(self) -> float:
        return self._backend.clock()

    def _tick_faults(self) -> None:
        """Advance the backend's fault-injection clock one tick (no-op
        without an attached injector — see core/faults.py)."""
        self._backend.begin_step(self._fault_steps)
        self._fault_steps += 1

    def _sample_step(self, group: List[Request], logits) -> np.ndarray:
        """Next token per row, honoring each request's own temperature
        (mixed-temperature batches: greedy rows stay bit-exact while
        sampled rows draw with their individual settings)."""
        tok = greedy(logits)
        if not any(r.temperature > 0 for r in group):
            return tok
        tok = tok.copy()  # greedy() may return a read-only device view
        self.key, sub = jax.random.split(self.key)
        keys = jax.random.split(sub, len(group))
        for i, r in enumerate(group):
            if r.temperature > 0:
                tok[i] = int(sample(logits[i:i + 1], keys[i],
                                    r.temperature)[0])
        return tok

    def _run_group(self, group: List[Request]) -> None:
        B = len(group)
        S = max(len(r.prompt) for r in group)
        n_steps = min(max(r.max_new_tokens for r in group),
                      self.max_seq - S)
        if n_steps <= 0:
            longest = max(group, key=lambda r: len(r.prompt))
            raise ValueError(
                f"group has no decode budget: prompt length "
                f"{len(longest.prompt)} (rid={longest.rid}) >= max_seq "
                f"{self.max_seq}")
        prompts = np.full((B, S), PAD_ID, np.int32)
        for i, r in enumerate(group):
            prompts[i, S - len(r.prompt):] = r.prompt  # left-pad
        self._tick_faults()
        logits, cache = self._backend.prefill_group(prompts)
        t_first = self._clock()
        for r in group:
            r.ttft = t_first - r.arrival

        done = np.zeros(B, bool)
        for step in range(n_steps):
            tok = self._sample_step(group, logits)
            now = self._clock()
            for i, r in enumerate(group):
                if not done[i]:
                    r.output.append(int(tok[i]))
                    r.token_times.append(now)
                    if tok[i] == EOS_ID or len(r.output) >= r.max_new_tokens:
                        done[i] = True
            if done.all():
                break
            pos = S + step
            self._tick_faults()
            logits, cache = self._backend.decode_group(cache, tok, pos)
            # placement-rebalance tick between decode steps (no-op for
            # static backends — see core/rebalance.py)
            self._backend.maybe_rebalance()
        t_end = self._clock()
        for r in group:
            r.latency = t_end - r.arrival

    def _next_group(self) -> List[Request]:
        """Form the next batch: the policy orders the queue (everything is
        treated as arrived — static batches wait for stragglers below).
        A beam request (``beam_width > 1``) always forms a group of its
        own: its gang of beams *is* the batch.

        Deadline-aware split: a batch only starts once its *last* member
        arrives, so a not-yet-arrived straggler would stall every
        already-arrived higher-priority member batched with it.  Such a
        straggler is deferred to a later group whenever a more urgent,
        earlier-arriving member is already in the forming batch — an
        interactive request landing mid-group splits the batch instead
        of waiting out the stragglers.  Pure-FIFO traffic (equal
        priorities) never splits, preserving the legacy grouping."""
        horizon = max([self._clock()]
                      + [r.arrival for r in self.queue
                         if r.arrival is not None])
        view = SchedulerView(
            clock=horizon,
            queue=tuple(QueueView.from_request(i, r)
                        for i, r in enumerate(self.queue)),
            slots=(), slot_limit=0, max_slots=0, arrival_rate=0.0)
        order = [i for i in self.policy.plan(view).admit
                 if 0 <= int(i) < len(self.queue)]
        if not order:                      # inert policy: fall back to FIFO
            order = list(range(len(self.queue)))
        ordered = list(dict.fromkeys(int(i) for i in order))
        picked: List[int] = []
        for i in ordered:
            if self.queue[i].beam_width > 1:
                if not picked:
                    picked = [i]           # singleton gang group
                break                      # gang boundary: close the batch
            picked.append(i)
            if len(picked) >= self.max_batch:
                break
        if len(picked) > 1:
            now = self._clock()

            def _arr(j: int) -> float:
                a = self.queue[j].arrival
                return now if a is None else a

            kept: List[int] = []
            for i in picked:
                if _arr(i) > now and any(
                        self.queue[h].effective_priority
                        > self.queue[i].effective_priority
                        and _arr(h) < _arr(i)
                        for h in kept):
                    continue  # straggler behind an urgent member: defer
                kept.append(i)
            picked = kept
        group = [self.queue[i] for i in picked]
        taken = set(picked)
        self.queue = [r for i, r in enumerate(self.queue) if i not in taken]
        return group

    def _run_beam(self, req: Request) -> None:
        """One gang-scheduled beam group through the slot API (shared
        prompt prefill + slot forks + table-only reshuffles — see
        serving/beam_search.beam_search_slots)."""
        from repro.serving.beam_search import beam_search_slots

        n_steps = min(req.max_new_tokens, self.max_seq - len(req.prompt))
        if n_steps <= 0:
            raise ValueError(
                f"beam group {req.rid} has no decode budget: prompt length "
                f"{len(req.prompt)} >= max_seq {self.max_seq}")
        res = beam_search_slots(self._backend, req.prompt, req.beam_width,
                                n_steps)
        req.output = [int(t) for t in res.tokens[0]]
        req.beam_tokens = res.tokens
        req.beam_scores = res.scores
        req.token_times = list(res.times or [])
        if req.token_times:
            req.ttft = req.token_times[0] - req.arrival
        req.latency = self._clock() - req.arrival

    def run(self) -> List[Request]:
        """Drain the queue in static batches of ≤ max_batch (a beam
        request runs as its own gang batch)."""
        finished: List[Request] = []
        while self.queue:
            group = self._next_group()
            # a batch can only start once its last member has arrived
            latest = max(r.arrival for r in group if r.arrival is not None)
            if latest > self._backend.clock():
                self._backend.wait_until(latest)
            if len(group) == 1 and group[0].beam_width > 1:
                self._run_beam(group[0])
            else:
                self._run_group(group)
            finished.extend(group)
        # settle in-flight migration prefetches (async rebalancing)
        self._backend.finalize()
        return finished
