"""Example: select any assigned architecture with --arch and either run a
reduced smoke step on CPU or lower the full config for the production mesh.

    PYTHONPATH=src python examples/multi_arch_dryrun.py --arch gemma2-9b
    PYTHONPATH=src python examples/multi_arch_dryrun.py --arch kimi-k2-1t-a32b \
        --dryrun --shape decode_32k
"""
import argparse
import subprocess
import sys


def smoke(arch: str):
    import jax
    import jax.numpy as jnp

    from repro.configs import applicable_shapes, get_config
    from repro.models import Model

    cfg = get_config(arch)
    print(f"{arch}: {cfg.arch_type} {cfg.n_layers}L d={cfg.d_model} "
          f"{cfg.param_count()/1e9:.2f}B params "
          f"({cfg.active_param_count()/1e9:.2f}B active)")
    print(f"applicable shapes: {applicable_shapes(cfg)}")
    r = cfg.reduced()
    model = Model(r, param_dtype=jnp.float32)
    params = model.init(jax.random.PRNGKey(0))
    tokens = jax.random.randint(jax.random.PRNGKey(1), (2, 16), 3,
                                r.vocab_size)
    extra = None
    if r.arch_type == "vlm":
        extra = {"image_embeds": 0.02 * jax.random.normal(
            jax.random.PRNGKey(2), (2, 4, r.d_model))}
    if r.arch_type == "audio":
        extra = {"frames": 0.02 * jax.random.normal(
            jax.random.PRNGKey(2), (2, r.encdec.n_audio_frames, r.d_model))}
    hidden, _ = model.forward_train(params, tokens, extra, remat=False)
    print(f"reduced smoke forward: hidden={hidden.shape} "
          f"finite={bool(jnp.all(jnp.isfinite(hidden)))}")


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen3-0.6b")
    ap.add_argument("--dryrun", action="store_true",
                    help="lower the FULL config on the 256-chip mesh "
                         "(subprocess with 512 host devices)")
    ap.add_argument("--shape", default="decode_32k")
    ap.add_argument("--multi-pod", action="store_true")
    args = ap.parse_args()

    smoke(args.arch)
    if args.dryrun:
        cmd = [sys.executable, "-m", "repro.launch.dryrun",
               "--arch", args.arch, "--shape", args.shape]
        if args.multi_pod:
            cmd.append("--multi-pod")
        print(f"\nlowering full config: {' '.join(cmd)}")
        sys.exit(subprocess.run(cmd).returncode)


if __name__ == "__main__":
    main()
