"""Paper Figure 7 (Appendix A): microbenchmarks of
  W copy  — transferring one expert's weights slow→fast,
  A copy  — transferring one activation fast→slow,
  GPU N   — one expert on the fast tier, input size N,
  CPU N   — one expert on the slow tier, input size N.

Two flavours: REAL wall-clock of this container's kernels (reduced expert
size; fast tier = jitted JAX, slow tier = numpy HostExpert, transfer =
actual jax.device_put of host arrays), and the MODELLED latencies at paper
scale from the cost model — the numbers the planner actually uses.
The paper's two qualitative observations are asserted on both: fast-tier
latency ~constant in N, slow-tier ~linear; W copy ≫ A copy.
"""
import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import ENVS, emit, timeit
from repro.configs import get_config
from repro.core.cost_model import LatencyModel
from repro.kernels.host_expert import HostExpert
from repro.kernels.ops import expert_mlp_op

SIZES = [1, 2, 4, 8, 16, 32, 64]


def run(fast: bool = False):
    sizes = SIZES[:4] if fast else SIZES
    # --- real kernels (reduced expert: d=512, f=1024) ----------------------
    d, f = 512, 1024
    rng = np.random.default_rng(0)
    wg, wu = [rng.standard_normal((d, f)).astype(np.float32) * 0.05
              for _ in range(2)]
    wd = rng.standard_normal((f, d)).astype(np.float32) * 0.05
    host = HostExpert(wg, wu, wd)
    wg_j, wu_j, wd_j = map(jnp.asarray, (wg, wu, wd))

    t_wcopy = timeit(lambda: jax.device_put((host.w_gate, host.w_up,
                                             host.w_down))[0].block_until_ready())
    emit("micro/real/W_copy", t_wcopy * 1e6, f"d={d},f={f}")
    act = rng.standard_normal((1, d)).astype(np.float32)
    t_acopy = timeit(lambda: np.asarray(jax.device_put(act)))
    emit("micro/real/A_copy", t_acopy * 1e6, "")

    fast_t, slow_t = [], []
    for s in sizes:
        x = rng.standard_normal((s, d)).astype(np.float32) * 0.1
        xj = jnp.asarray(x)
        tf = timeit(lambda: expert_mlp_op(xj, wg_j, wu_j, wd_j)
                    .block_until_ready())
        ts = timeit(lambda: host(x))
        fast_t.append(tf)
        slow_t.append(ts)
        emit(f"micro/real/fast_N{s}", tf * 1e6, "")
        emit(f"micro/real/slow_N{s}", ts * 1e6, "")
    # paper App. A shape checks (soft, real CPU timings are noisy)
    emit("micro/real/slow_linear_ratio", 0.0,
         f"slow(N{sizes[-1]})/slow(N1)={slow_t[-1] / slow_t[0]:.1f}")

    # --- modelled at paper scale -------------------------------------------
    cfg = get_config("mixtral-8x7b")
    for env, hw in ENVS.items():
        lat = LatencyModel.derive(cfg, hw)
        emit(f"micro/model/{env}/W_copy", lat.transfer_lat() * 1e6,
             "2-5x gpu exec (paper)")
        emit(f"micro/model/{env}/A_copy", lat.act_per_token * 1e6,
             "<1% of cpu N1 (paper)")
        for s in sizes:
            emit(f"micro/model/{env}/gpu_N{s}", float(lat.gpu_lat(s)) * 1e6, "")
            emit(f"micro/model/{env}/cpu_N{s}", float(lat.cpu_lat(s)) * 1e6, "")
        # the paper's observations hold by construction — assert anyway:
        # W copy dominates one fast-tier exec; the batching effect is
        # strongly asymmetric (slow-tier marginal cost ≫ fast tier's)
        assert lat.transfer_lat() > float(lat.gpu_lat(1))
        cpu_slope = float(lat.cpu_lat(64) - lat.cpu_lat(1))
        gpu_slope = float(lat.gpu_lat(64) - lat.gpu_lat(1))
        assert cpu_slope > 10 * gpu_slope
        emit(f"micro/model/{env}/crossover_tokens", 0.0,
             f"N*={lat.crossover()}")
    return {"fast": fast_t, "slow": slow_t}


if __name__ == "__main__":
    run()
