"""RecurrentGemma / Griffin recurrent block (RG-LRU + conv), pure JAX.

The temporal mixer is: x-branch (linear → causal conv(4) → RG-LRU) gated by
a GeLU branch, then an output projection.  Train/prefill evaluate the linear
recurrence h_t = a_t ⊙ h_{t-1} + b_t with an associative scan; decode is the
single-step update.  Reference: arXiv:2402.19427 (Griffin / RecurrentGemma).
"""
from __future__ import annotations

from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models.layers import Params, dense_init

_C_RGLRU = 8.0  # fixed scalar from the paper


def init_rglru_block(key, cfg: ModelConfig, dtype=jnp.float32) -> Params:
    h = cfg.hybrid
    d, w = cfg.d_model, h.lru_width
    k1, k2, k3, k4, k5, k6 = jax.random.split(key, 6)
    return {
        "w_x": dense_init(k1, (d, w), 0, dtype),
        "w_gate": dense_init(k2, (d, w), 0, dtype),
        "conv_w": dense_init(k3, (4, w), 0, dtype),
        "conv_b": jnp.zeros((w,), dtype),
        "w_a": dense_init(k4, (w, w), 0, dtype),     # recurrence gate
        "b_a": jnp.zeros((w,), jnp.float32),
        "w_i": dense_init(k5, (w, w), 0, dtype),     # input gate
        "b_i": jnp.zeros((w,), jnp.float32),
        # Λ init so that a^c is in (0.9, 0.999) at r=1 — paper's init range
        "lam": jnp.log(jnp.expm1(-jnp.log(
            jnp.linspace(0.9, 0.999, w).astype(jnp.float32)) / _C_RGLRU)),
        "w_out": dense_init(k6, (w, d), 0, dtype),
    }


def _causal_conv4(x: jnp.ndarray, w: jnp.ndarray, b: jnp.ndarray,
                  state: Optional[jnp.ndarray]) -> jnp.ndarray:
    W = w.shape[0]
    if state is None:
        pad = jnp.zeros((x.shape[0], W - 1, x.shape[2]), x.dtype)
    else:
        pad = state.astype(x.dtype)
    xp = jnp.concatenate([pad, x], axis=1)
    return sum(xp[:, i: i + x.shape[1]] * w[i] for i in range(W)) + b


def _rglru_scan(x: jnp.ndarray, a_gate: jnp.ndarray, i_gate: jnp.ndarray,
                lam: jnp.ndarray, h0: Optional[jnp.ndarray]
                ) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """x, a_gate, i_gate: (B, S, W) fp32. Returns (h_seq, h_last)."""
    log_a = -_C_RGLRU * jax.nn.softplus(lam) * a_gate       # (B,S,W) ≤ 0
    a = jnp.exp(log_a)
    # sqrt(1 - a^2) in a numerically-stable form
    mult = jnp.sqrt(jnp.maximum(1.0 - jnp.exp(2.0 * log_a), 1e-12))
    b = mult * (i_gate * x)

    def combine(c1, c2):
        a1, b1 = c1
        a2, b2 = c2
        return a1 * a2, a2 * b1 + b2

    a_s, b_s = jax.lax.associative_scan(combine, (a, b), axis=1)
    if h0 is not None:
        h = a_s * h0[:, None, :] + b_s
    else:
        h = b_s
    return h, h[:, -1]


def rglru_block(params: Params, u: jnp.ndarray, cfg: ModelConfig,
                cache: Optional[Dict[str, jnp.ndarray]] = None
                ) -> Tuple[jnp.ndarray, Optional[Dict[str, jnp.ndarray]]]:
    """u: (B, S, d) → (B, S, d). cache = {"h", "conv_state"} for decode."""
    B, S, _ = u.shape
    x = u @ params["w_x"]
    gate = jax.nn.gelu(u @ params["w_gate"], approximate=True)

    if cache is not None and S == 1:
        conv_in = jnp.concatenate(
            [cache["conv_state"].astype(x.dtype), x], axis=1)  # (B, 4, W)
        w = params["conv_w"]
        xc = sum(conv_in[:, i: i + 1] * w[i] for i in range(w.shape[0])) + params["conv_b"]
        new_conv = conv_in[:, 1:]
        xf = xc.astype(jnp.float32)[:, 0]
        a_gate = jax.nn.sigmoid(xf @ params["w_a"].astype(jnp.float32) + params["b_a"])
        i_gate = jax.nn.sigmoid(xf @ params["w_i"].astype(jnp.float32) + params["b_i"])
        log_a = -_C_RGLRU * jax.nn.softplus(params["lam"]) * a_gate
        a = jnp.exp(log_a)
        mult = jnp.sqrt(jnp.maximum(1.0 - jnp.exp(2.0 * log_a), 1e-12))
        h = a * cache["h"] + mult * (i_gate * xf)
        y = h[:, None, :].astype(u.dtype)
        new_cache = {"h": h, "conv_state": new_conv}
    else:
        x_raw = x
        xc = _causal_conv4(x, params["conv_w"], params["conv_b"],
                           None if cache is None else cache["conv_state"])
        xf = xc.astype(jnp.float32)
        a_gate = jax.nn.sigmoid(xf @ params["w_a"].astype(jnp.float32) + params["b_a"])
        i_gate = jax.nn.sigmoid(xf @ params["w_i"].astype(jnp.float32) + params["b_i"])
        h0 = None if cache is None else cache["h"]
        h_seq, h_last = _rglru_scan(xf, a_gate, i_gate, params["lam"], h0)
        y = h_seq.astype(u.dtype)
        if cache is None:
            new_cache = None
        else:
            hist = jnp.concatenate(
                [cache["conv_state"].astype(x_raw.dtype), x_raw], axis=1)
            new_cache = {"h": h_last, "conv_state": hist[:, -3:].astype(jnp.float32)}

    out = (y * gate) @ params["w_out"]
    return out, new_cache
