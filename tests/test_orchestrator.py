"""Fiddler orchestrator: numerics must be identical to the monolithic jit
model under every policy/placement (the planner may never change results),
and the simulated ledger must reproduce the paper's qualitative claims."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from conftest import reduced_model
from repro.configs import get_config
from repro.core import FiddlerEngine, HardwareSpec
from repro.core.planner import Decision


@pytest.fixture(scope="module")
def mixtral():
    return reduced_model("mixtral-8x7b")


@pytest.mark.parametrize("policy", ["fiddler", "offload", "static_split"])
@pytest.mark.parametrize("budget_frac", [0.0, 0.4, 1.0])
def test_orchestrated_equals_monolithic(mixtral, policy, budget_frac):
    cfg, model, params = mixtral
    tokens = jax.random.randint(jax.random.PRNGKey(1), (2, 12), 3,
                                cfg.vocab_size)
    ref_logits, ref_cache = model.prefill(params, tokens, max_seq=32,
                                          cache_dtype=jnp.float32)
    ref_dec, _ = model.decode_step(params, ref_cache, tokens[:, :1],
                                   jnp.int32(12), max_seq=32)

    budget = int(budget_frac * cfg.n_layers * cfg.moe.n_experts)
    eng = FiddlerEngine(cfg, params, policy=policy, expert_budget=budget,
                        host_precision="fp32")
    logits, caches = eng.prefill(tokens, max_seq=32)
    np.testing.assert_allclose(np.asarray(logits), np.asarray(ref_logits),
                               rtol=3e-4, atol=3e-4)
    dec, _ = eng.decode_step(caches, tokens[:, :1], pos=12, max_seq=32)
    np.testing.assert_allclose(np.asarray(dec), np.asarray(ref_dec),
                               rtol=3e-4, atol=3e-4)


def test_policies_differ_only_in_ledger(mixtral):
    """Same numerics, different decisions/clock across policies."""
    cfg, model, params = mixtral
    tokens = jax.random.randint(jax.random.PRNGKey(2), (1, 8), 3,
                                cfg.vocab_size)
    budget = cfg.n_layers * cfg.moe.n_experts // 3
    ledgers = {}
    outs = {}
    for policy in ("fiddler", "offload", "static_split"):
        eng = FiddlerEngine(cfg, params, policy=policy, expert_budget=budget,
                            host_precision="fp32")
        logits, _ = eng.prefill(tokens, max_seq=16)
        ledgers[policy] = eng.ledger
        outs[policy] = np.asarray(logits)
    np.testing.assert_allclose(outs["fiddler"], outs["offload"], rtol=1e-3, atol=1e-5)
    np.testing.assert_allclose(outs["fiddler"], outs["static_split"], rtol=1e-3, atol=1e-5)
    assert ledgers["offload"].streams > 0
    assert ledgers["offload"].slow_runs == 0
    assert ledgers["static_split"].streams == 0


def test_decision_shift_with_batch_size():
    """Paper §3.2: small per-expert inputs → slow tier; large → stream.
    The planner must flip as the (simulated) batch grows."""
    cfg = get_config("mixtral-8x7b")
    eng = FiddlerEngine(cfg, policy="fiddler", expert_budget=0,
                        hw=HardwareSpec.paper_env1())
    small = eng._decide(0, np.array([1] * 8))
    assert (small.decisions == int(Decision.SLOW)).sum() == 8
    big = eng._decide(0, np.array([4096] * 8))
    assert (big.decisions == int(Decision.FAST_STREAM)).sum() == 8


def test_paper_claims_simulation():
    """The paper's headline: Fiddler ≥ best baseline in ALL three
    scenarios; offload wins long prefill among baselines; static_split
    wins single-batch decode among baselines."""
    cfg = get_config("mixtral-8x7b")
    results = {}
    for policy in ("fiddler", "offload", "static_split"):
        eng = FiddlerEngine(cfg, policy=policy,
                            hw=HardwareSpec.paper_env1(), seed=0)
        results[policy] = eng.simulate_generate(prompt_len=128, gen_len=128)

    # scenario (a): single-batch end-to-end tokens/s
    assert results["fiddler"]["tokens_per_s"] >= results["static_split"]["tokens_per_s"]
    assert results["fiddler"]["tokens_per_s"] >= results["offload"]["tokens_per_s"]
    # baselines trade off exactly as the paper observes
    assert results["static_split"]["tokens_per_s"] > results["offload"]["tokens_per_s"]

    # scenario (b): long prefill TTFT — offload beats static_split
    ttft = {}
    for policy in ("fiddler", "offload", "static_split"):
        eng = FiddlerEngine(cfg, policy=policy,
                            hw=HardwareSpec.paper_env1(), seed=0)
        ttft[policy] = eng.simulate_prefill(4096)
    assert ttft["offload"] < ttft["static_split"]
    assert ttft["fiddler"] <= ttft["offload"] * 1.05

    # scenario (c): beam search — fiddler ≫ static_split (llama.cpp)
    beam = {}
    for policy in ("fiddler", "static_split"):
        eng = FiddlerEngine(cfg, policy=policy,
                            hw=HardwareSpec.paper_env1(), seed=0)
        beam[policy] = eng.simulate_generate(prompt_len=32, gen_len=64,
                                             batch=16)["tokens_per_s"]
    assert beam["fiddler"] > 2.0 * beam["static_split"]


def test_hit_rate_improves_with_budget():
    cfg = get_config("mixtral-8x7b")
    rates = []
    for budget in (0, 56, 125, 256):
        eng = FiddlerEngine(cfg, policy="fiddler", expert_budget=budget)
        eng.simulate_decode(32, batch=1)
        led = eng.ledger
        total = led.fast_hits + led.streams + led.slow_runs
        rates.append(led.fast_hits / max(total, 1))
    assert rates == sorted(rates)
    assert rates[0] == 0.0 and rates[-1] == 1.0


def test_ledger_stream_accounting():
    cfg = get_config("mixtral-8x7b")
    eng = FiddlerEngine(cfg, policy="offload", expert_budget=0)
    eng.simulate_decode(4, batch=1)
    from repro.core.cost_model import expert_weight_bytes
    assert eng.ledger.streams > 0
    assert eng.ledger.stream_bytes == eng.ledger.streams * expert_weight_bytes(cfg)
