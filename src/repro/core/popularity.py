"""Expert-popularity profiling (paper §3.4, Appendix C).

Fiddler profiles expert routing frequencies offline on calibration data and
places the most popular experts on the fast tier.  The profile is a
(n_layers, n_experts) count matrix; Appendix C normalises by the most
popular expert and reports hit rates for best/worst/random placements.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Iterable

import numpy as np


@dataclass
class ExpertProfile:
    counts: np.ndarray  # (n_layers, n_experts) float64

    @property
    def n_layers(self) -> int:
        return self.counts.shape[0]

    @property
    def n_experts(self) -> int:
        return self.counts.shape[1]

    # -- construction ---------------------------------------------------------
    @staticmethod
    def empty(n_layers: int, n_experts: int) -> "ExpertProfile":
        return ExpertProfile(np.zeros((n_layers, n_experts), np.float64))

    def update(self, layer: int, expert_idx: np.ndarray) -> None:
        """Accumulate a routing trace: expert_idx is any int array of the
        expert assignments observed at `layer` (tokens × top_k flattened)."""
        np.add.at(self.counts[layer], np.asarray(expert_idx).reshape(-1), 1.0)

    def merge(self, other: "ExpertProfile") -> "ExpertProfile":
        return ExpertProfile(self.counts + other.counts)

    # -- paper App. C statistics ----------------------------------------------
    def normalized(self) -> np.ndarray:
        """Popularity normalised so the most popular expert is 1.0."""
        m = self.counts.max()
        return self.counts / max(m, 1.0)

    def probabilities(self) -> np.ndarray:
        """Per-layer routing probabilities (rows sum to 1)."""
        tot = self.counts.sum(axis=1, keepdims=True)
        return self.counts / np.maximum(tot, 1.0)

    # -- persistence ------------------------------------------------------------
    def save(self, path: str) -> None:
        np.savez(path, counts=self.counts)

    @staticmethod
    def load(path: str) -> "ExpertProfile":
        with np.load(path) as z:
            return ExpertProfile(z["counts"].astype(np.float64))


def profile_from_traces(n_layers: int, n_experts: int,
                        traces: Iterable) -> ExpertProfile:
    """traces yields (layer, expert_idx array)."""
    prof = ExpertProfile.empty(n_layers, n_experts)
    for layer, idx in traces:
        prof.update(layer, idx)
    return prof


def synthetic_profile(n_layers: int, n_experts: int, seed: int = 0,
                      concentration: float = 12.0) -> ExpertProfile:
    """ShareGPT-like popularity: near-uniform with mild skew.  Paper App. C
    reports mean 0.71, std 0.08 relative popularity for Mixtral-8x7B —
    a Dirichlet with high concentration reproduces that regime."""
    rng = np.random.default_rng(seed)
    probs = rng.dirichlet(np.full(n_experts, concentration), size=n_layers)
    counts = probs * 1e6
    return ExpertProfile(counts)
