"""Pallas TPU kernel: grouped (per-expert) matmul over capacity buckets.

Computes ``out[e] = xs[e] @ ws[e]`` for capacity-bucketed MoE dispatch
buffers, with a per-expert valid-row count so that experts with few routed
tokens skip whole MXU tiles (ragged-friendly — the hot case in Fiddler's
decode regime where most experts see 0–2 tokens).

Grid: (E, C / block_c, f / block_f, d / block_k); the k axis accumulates
into a VMEM fp32 scratch.  The per-expert counts ride in scalar-prefetch
SMEM so the `pl.when` row guard is known before the block loads issue.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

try:  # pragma: no cover
    from jax.experimental.pallas import tpu as pltpu
    _HAS_PLTPU = True
except ImportError:  # pragma: no cover
    import warnings

    _HAS_PLTPU = False
    warnings.warn(
        "jax.experimental.pallas.tpu unavailable; grouped-GEMM kernels "
        "fall back to interpret-safe scratch allocation",
        RuntimeWarning, stacklevel=2)


def _scratch(shape):
    if _HAS_PLTPU:
        return pltpu.VMEM(shape, jnp.float32)
    raise RuntimeError("pallas TPU backend unavailable")


@functools.partial(jax.jit,
                   static_argnames=("block_c", "block_f", "block_k", "interpret"))
def moe_gmm(xs: jnp.ndarray, ws: jnp.ndarray, counts: jnp.ndarray, *,
            block_c: int = 128, block_f: int = 256, block_k: int = 256,
            interpret: bool = True) -> jnp.ndarray:
    """xs: (E, C, d); ws: (E, d, f); counts: (E,) int32 → (E, C, f)."""
    E, C, d = xs.shape
    f = ws.shape[2]
    block_c = min(block_c, C)
    block_f = min(block_f, f)
    block_k = min(block_k, d)
    pc, pf, pk = (-C) % block_c, (-f) % block_f, (-d) % block_k
    if pc or pk:
        xs = jnp.pad(xs, ((0, 0), (0, pc), (0, pk)))
    if pf or pk:
        ws = jnp.pad(ws, ((0, 0), (0, pk), (0, pf)))
    Cp, fp, dp = C + pc, f + pf, d + pk
    grid = (E, Cp // block_c, fp // block_f, dp // block_k)

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, block_c, block_k),
                         lambda e, ic, jf, kk, *_: (e, ic, kk)),
            pl.BlockSpec((1, block_k, block_f),
                         lambda e, ic, jf, kk, *_: (e, kk, jf)),
        ],
        out_specs=pl.BlockSpec((1, block_c, block_f),
                               lambda e, ic, jf, kk, *_: (e, ic, jf)),
        scratch_shapes=[_scratch((1, block_c, block_f))],
    ) if _HAS_PLTPU else None

    if grid_spec is not None:
        out = pl.pallas_call(
            _gmm_kernel_3d,
            grid_spec=grid_spec,
            out_shape=jax.ShapeDtypeStruct((E, Cp, fp), xs.dtype),
            interpret=interpret,
        )(counts.astype(jnp.int32), xs, ws)
    else:  # pragma: no cover
        raise RuntimeError("pallas TPU grid spec unavailable")
    return out[:, :C, :f]


def moe_gmm_mlp(xs: jnp.ndarray, w_gate: jnp.ndarray, w_up: jnp.ndarray,
                w_down: jnp.ndarray, counts: jnp.ndarray, *,
                interpret: bool = True, **block_kw) -> jnp.ndarray:
    """Grouped gated SiLU MLP as three grouped matmuls on the MXU:
    ``silu(gmm(xs, w_gate)) * gmm(xs, w_up)`` then ``gmm(·, w_down)`` —
    the Pallas path of ``ops.grouped_gated_mlp_op``, sharing
    ``ref.grouped_gated_mlp_ref``'s oracle semantics (rows ≥ counts[e]
    are zeroed by every gmm, and silu(0)·0 = 0 keeps them zero between
    stages).  xs: (E, C, d) → (E, C, d)."""
    h = jax.nn.silu(moe_gmm(xs, w_gate, counts, interpret=interpret,
                            **block_kw))
    h = h * moe_gmm(xs, w_up, counts, interpret=interpret, **block_kw)
    return moe_gmm(h, w_down, counts, interpret=interpret, **block_kw)


def _gmm_kernel_3d(counts_ref, x_ref, w_ref, o_ref, acc_ref):
    e = pl.program_id(0)
    ic = pl.program_id(1)
    kk = pl.program_id(3)
    block_c = x_ref.shape[1]

    @pl.when(kk == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    @pl.when(ic * block_c < counts_ref[e])
    def _work():
        acc_ref[...] += jax.lax.dot_general(
            x_ref[...].astype(jnp.float32), w_ref[...].astype(jnp.float32),
            dimension_numbers=(((2,), (1,)), ((0,), (0,))),
            preferred_element_type=jnp.float32)

    @pl.when(kk == pl.num_programs(3) - 1)
    def _done():
        rows = jax.lax.broadcasted_iota(jnp.int32, acc_ref.shape, 1)
        valid = (ic * block_c + rows) < counts_ref[e]
        o_ref[...] = jnp.where(valid, acc_ref[...], 0.0).astype(o_ref.dtype)
