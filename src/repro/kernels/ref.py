"""Pure-jnp oracles for every kernel in this package.

These are the single source of truth for kernel semantics; Pallas kernels
(interpret=True on CPU) and the host (numpy) kernel are asserted allclose
against these in tests/test_kernels.py.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


def expert_mlp_ref(x: jnp.ndarray, w_gate: jnp.ndarray, w_up: jnp.ndarray,
                   w_down: jnp.ndarray) -> jnp.ndarray:
    """Gated SiLU MLP of one expert: (silu(xWg) ⊙ xWu) Wd.

    x: (s, d); w_gate/w_up: (d, f); w_down: (f, d).  fp32 accumulation.
    """
    xf = x.astype(jnp.float32)
    h = jax.nn.silu(xf @ w_gate.astype(jnp.float32))
    h = h * (xf @ w_up.astype(jnp.float32))
    return (h @ w_down.astype(jnp.float32)).astype(x.dtype)


def moe_gmm_ref(xs: jnp.ndarray, ws: jnp.ndarray,
                counts: jnp.ndarray) -> jnp.ndarray:
    """Grouped matmul: out[e] = xs[e] @ ws[e], rows ≥ counts[e] zeroed.

    xs: (E, C, d); ws: (E, d, f); counts: (E,) int32 → (E, C, f).
    """
    out = jnp.einsum("ecd,edf->ecf", xs.astype(jnp.float32),
                     ws.astype(jnp.float32))
    mask = jnp.arange(xs.shape[1])[None, :, None] < counts[:, None, None]
    return jnp.where(mask, out, 0.0).astype(xs.dtype)


def flash_attention_ref(q: jnp.ndarray, k: jnp.ndarray, v: jnp.ndarray,
                        *, causal: bool = True,
                        window: int | None = None,
                        attn_softcap: float | None = None) -> jnp.ndarray:
    """Reference multi-head attention. q/k/v: (B, S, H, hd) (same H)."""
    B, S, H, hd = q.shape
    scale = 1.0 / jnp.sqrt(jnp.float32(hd))
    s = jnp.einsum("bqhd,bkhd->bhqk", q.astype(jnp.float32),
                   k.astype(jnp.float32)) * scale
    if attn_softcap is not None:
        s = attn_softcap * jnp.tanh(s / attn_softcap)
    iq = jnp.arange(S)[:, None]
    ik = jnp.arange(S)[None, :]
    mask = jnp.ones((S, S), bool)
    if causal:
        mask &= ik <= iq
    if window is not None:
        mask &= ik > iq - window
    s = jnp.where(mask[None, None], s, -1e30)
    p = jax.nn.softmax(s, axis=-1)
    out = jnp.einsum("bhqk,bkhd->bqhd", p, v.astype(jnp.float32))
    return out.astype(q.dtype)
