"""Qwen3-4B [hf:Qwen/Qwen3-8B family] — dense, qk_norm, GQA.

36L d_model=2560 32H (GQA kv=8) d_ff=9728 vocab=151936.
"""
from repro.configs.base import ModelConfig, register


@register("qwen3-4b")
def qwen3_4b() -> ModelConfig:
    return ModelConfig(
        name="qwen3-4b",
        arch_type="dense",
        n_layers=36,
        d_model=2560,
        n_heads=32,
        n_kv_heads=8,
        head_dim=128,
        d_ff=9728,
        vocab_size=151936,
        qk_norm=True,
        rope_theta=1000000.0,
        long_context_window=8192,
        citation="[hf:Qwen/Qwen3-8B] Qwen3",
    )
