"""Paper Appendix E: applicability beyond Mixtral-8x7B — Phi-3.5-MoE
(vs the offloading baseline, the only one that supports it in the paper)."""
from benchmarks.common import emit, engine_for


def run(env: str = "env1", fast: bool = False):
    results = {}
    for policy in ("fiddler", "offload"):
        eng = engine_for("phi-3.5-moe", policy, env)
        r = eng.simulate_generate(prompt_len=64, gen_len=32 if fast else 128)
        results[policy] = r["tokens_per_s"]
        emit(f"phi35/{env}/{policy}", r["itl"] * 1e6,
             f"tok_per_s={r['tokens_per_s']:.2f}")
    ratio = results["fiddler"] / results["offload"]
    emit(f"phi35/{env}/speedup_vs_offload", 0.0,
         f"{ratio:.2f}x (paper: 6.5x vs DeepSpeed-MII)")
    assert ratio > 1.0
    return ratio


if __name__ == "__main__":
    run()
