"""Quickstart: build a reduced MoE model, train briefly, then serve it
through the Fiddler orchestrator and compare policies.

    PYTHONPATH=src python examples/quickstart.py
"""
import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config
from repro.core import FiddlerEngine, HardwareSpec
from repro.data.pipeline import make_batch_iter
from repro.data.tokenizer import ByteTokenizer
from repro.models import Model
from repro.training.optimizer import AdamWConfig
from repro.training.train_loop import train


def main():
    # 1. model: a reduced Mixtral-8x7B (the paper's evaluation model)
    cfg = get_config("mixtral-8x7b").reduced()
    print(f"model: {cfg.name}  layers={cfg.n_layers} d={cfg.d_model} "
          f"experts={cfg.moe.n_experts} top-{cfg.moe.top_k}")
    model = Model(cfg, param_dtype=jnp.float32)
    params = model.init(jax.random.PRNGKey(0))

    # 2. short training run on the synthetic ShareGPT-like pipeline
    data = make_batch_iter(cfg, seq_len=64, batch=4)
    params, _, hist = train(model, params, iter(data), n_steps=20,
                            opt_cfg=AdamWConfig(lr=1e-3, warmup_steps=5),
                            log_every=5,
                            callback=lambda s, m: print(
                                f"  step {s:3d} loss={m['loss']:.3f}"))

    # 3. serve through Fiddler: experts split between fast/slow tier
    tok = ByteTokenizer(cfg.vocab_size)
    prompt = jnp.asarray([tok.encode("USER: what is a mixture of experts?")])
    for policy in ("fiddler", "offload", "static_split"):
        eng = FiddlerEngine(cfg, params, policy=policy,
                            expert_budget=cfg.n_layers * cfg.moe.n_experts // 4,
                            timing_cfg=get_config("mixtral-8x7b"),
                            hw=HardwareSpec.paper_env1())
        logits, caches = eng.prefill(prompt, max_seq=128)
        toks = []
        t = int(np.argmax(np.asarray(logits)[0]))
        for step in range(16):
            toks.append(t)
            logits, caches = eng.decode_step(
                caches, jnp.asarray([[t]]), prompt.shape[1] + step, 128)
            t = int(np.argmax(np.asarray(logits)[0]))
        led = eng.ledger
        print(f"{policy:14s} 16 tokens; simulated {led.sim_time*1e3:7.1f}ms "
              f"(hits={led.fast_hits} streams={led.streams} "
              f"slow={led.slow_runs})  text={tok.decode(toks)!r}")


if __name__ == "__main__":
    main()
