"""Paper Figure 6: beam-search inference tokens/s vs llama.cpp-style
static split, widths 4–16, input 32 / output 64."""
from benchmarks.common import emit, engine_for

WIDTHS = [4, 8, 12, 16]


def run(model: str = "mixtral-8x7b", envs=("env1", "env2"),
        fast: bool = False):
    widths = WIDTHS[:2] if fast else WIDTHS
    summary = {}
    for env in envs:
        ratios = []
        for w in widths:
            res = {}
            for policy in ("fiddler", "static_split"):
                eng = engine_for(model, policy, env)
                r = eng.simulate_generate(prompt_len=32, gen_len=64, batch=w)
                res[policy] = r["tokens_per_s"]
                emit(f"beam/{env}/{policy}/w{w}", r["itl"] * 1e6,
                     f"tok_per_s={r['tokens_per_s']:.2f}")
            ratios.append(res["fiddler"] / res["static_split"])
        avg = sum(ratios) / len(ratios)
        emit(f"beam/{env}/avg_speedup", 0.0,
             f"{avg:.2f}x (paper: 11.57x avg vs llama.cpp)")
        summary[env] = avg
    return summary


if __name__ == "__main__":
    run()
