"""Per-architecture smoke tests (deliverable f).

Every assigned architecture is instantiated as a REDUCED variant of the
same family (≤2 layers — one full period for the hybrid —, d_model ≤ 512,
≤4 experts) and runs a real forward + train step + prefill/decode on CPU,
asserting output shapes and the absence of NaNs.  Full-size configs are
exercised only via the dry-run (ShapeDtypeStructs, no allocation).
"""
import jax
import jax.numpy as jnp
import pytest

from conftest import reduced_model
from repro.configs import ASSIGNED_ARCHS, applicable_shapes, get_config
from repro.models import Model, lm_loss
from repro.training.optimizer import AdamWConfig, init_opt_state
from repro.training.train_loop import make_train_step

B, S = 2, 24


def _batch(cfg, key):
    tokens = jax.random.randint(key, (B, S), 3, cfg.vocab_size)
    batch = {"tokens": tokens, "labels": tokens}
    if cfg.arch_type == "vlm":
        batch["image_embeds"] = 0.02 * jax.random.normal(
            key, (B, 4, cfg.d_model))
        batch["labels"] = jnp.pad(tokens, ((0, 0), (4, 0)),
                                  constant_values=-100)
    if cfg.arch_type == "audio":
        batch["frames"] = 0.02 * jax.random.normal(
            key, (B, cfg.encdec.n_audio_frames, cfg.d_model))
    return batch


@pytest.mark.parametrize("arch", ASSIGNED_ARCHS)
def test_reduced_constraints(arch):
    cfg = get_config(arch).reduced()
    assert cfg.n_layers <= 3
    assert cfg.d_model <= 512
    if cfg.moe:
        assert cfg.moe.n_experts <= 4


@pytest.mark.parametrize("arch", ASSIGNED_ARCHS)
def test_forward_shapes_and_finite(arch):
    cfg, model, params = reduced_model(arch)
    batch = _batch(cfg, jax.random.PRNGKey(1))
    extra = {k: v for k, v in batch.items() if k in ("image_embeds", "frames")}
    hidden, aux = model.forward_train(params, batch["tokens"], extra or None,
                                      remat=False)
    S_total = batch["labels"].shape[1]
    assert hidden.shape == (B, S_total, cfg.d_model)
    assert bool(jnp.all(jnp.isfinite(hidden)))
    loss = lm_loss(model, params, hidden, batch["labels"])
    assert bool(jnp.isfinite(loss))
    logits = model.logits(params, hidden[:, -1:])
    assert logits.shape == (B, 1, cfg.vocab_size)


@pytest.mark.parametrize("arch", ASSIGNED_ARCHS)
def test_one_train_step(arch):
    cfg, model, params = reduced_model(arch)
    batch = _batch(cfg, jax.random.PRNGKey(2))
    step = jax.jit(make_train_step(model, AdamWConfig(lr=1e-3, warmup_steps=1)))
    opt = init_opt_state(params)
    new_params, new_opt, metrics = step(params, opt, batch)
    assert bool(jnp.isfinite(metrics["loss"]))
    assert bool(jnp.isfinite(metrics["grad_norm"]))
    # parameters actually moved
    moved = jax.tree.reduce(
        lambda a, b: a or b,
        jax.tree.map(lambda a, b: bool(jnp.any(a != b)), params, new_params))
    assert moved
    assert int(new_opt["step"]) == 1


@pytest.mark.parametrize("arch", ASSIGNED_ARCHS)
def test_prefill_decode_finite(arch):
    cfg, model, params = reduced_model(arch)
    batch = _batch(cfg, jax.random.PRNGKey(3))
    extra = {k: v for k, v in batch.items() if k in ("image_embeds", "frames")}
    logits, cache = model.prefill(params, batch["tokens"], max_seq=64,
                                  extra=extra or None,
                                  cache_dtype=jnp.float32)
    assert logits.shape == (B, cfg.vocab_size)
    assert bool(jnp.all(jnp.isfinite(logits)))
    pos = batch["labels"].shape[1]
    logits2, cache = model.decode_step(params, cache, batch["tokens"][:, :1],
                                       jnp.int32(pos), max_seq=64)
    assert logits2.shape == (B, cfg.vocab_size)
    assert bool(jnp.all(jnp.isfinite(logits2)))


def test_phi35_moe_portability_config():
    """Paper App. E model (not in the assigned pool) also runs."""
    cfg = get_config("phi-3.5-moe").reduced()
    model = Model(cfg, param_dtype=jnp.float32)
    params = model.init(jax.random.PRNGKey(0))
    tokens = jax.random.randint(jax.random.PRNGKey(1), (2, 16), 3,
                                cfg.vocab_size)
    hidden, _ = model.forward_train(params, tokens, remat=False)
    assert bool(jnp.all(jnp.isfinite(hidden)))


def test_paper_model_configs_match_cards():
    """Exact spec fields from the assignment table."""
    c = get_config("kimi-k2-1t-a32b")
    assert (c.n_layers, c.d_model, c.n_heads, c.n_kv_heads, c.d_ff,
            c.vocab_size) == (61, 7168, 64, 8, 2048, 163840)
    assert c.moe.n_experts == 384 and c.moe.top_k == 8
    c = get_config("mixtral-8x22b")
    assert (c.n_layers, c.d_model, c.n_heads, c.n_kv_heads, c.d_ff,
            c.vocab_size) == (56, 6144, 48, 8, 16384, 32768)
    assert c.moe.n_experts == 8 and c.moe.top_k == 2
    c = get_config("mamba2-2.7b")
    assert (c.n_layers, c.d_model, c.vocab_size) == (64, 2560, 50280)
    assert c.ssm.state_dim == 128
    c = get_config("whisper-large-v3")
    assert (c.n_layers, c.d_model, c.n_heads, c.d_ff, c.vocab_size) == \
        (32, 1280, 20, 5120, 51866)
    c = get_config("internvl2-76b")
    assert (c.n_layers, c.d_model, c.n_heads, c.n_kv_heads, c.d_ff,
            c.vocab_size) == (80, 8192, 64, 8, 28672, 128256)
    c = get_config("stablelm-3b")
    assert (c.n_layers, c.d_model, c.n_heads, c.d_ff, c.vocab_size) == \
        (32, 2560, 32, 6912, 50304)
    c = get_config("qwen3-4b")
    assert (c.n_layers, c.d_model, c.n_heads, c.n_kv_heads, c.d_ff,
            c.vocab_size) == (36, 2560, 32, 8, 9728, 151936)
    assert c.qk_norm
    c = get_config("recurrentgemma-2b")
    assert (c.n_layers, c.d_model, c.n_heads, c.n_kv_heads, c.d_ff,
            c.vocab_size) == (26, 2560, 10, 1, 7680, 256000)
    c = get_config("gemma2-9b")
    assert (c.n_layers, c.d_model, c.n_heads, c.n_kv_heads, c.d_ff,
            c.vocab_size) == (42, 3584, 16, 8, 14336, 256000)
    assert c.logit_softcap == 30.0 and c.attn_softcap == 50.0
    c = get_config("qwen3-0.6b")
    assert (c.n_layers, c.d_model, c.n_heads, c.n_kv_heads, c.d_ff,
            c.vocab_size) == (28, 1024, 16, 8, 3072, 151936)


@pytest.mark.parametrize("arch", ASSIGNED_ARCHS)
def test_applicable_shapes_documented(arch):
    cfg = get_config(arch)
    shapes = applicable_shapes(cfg)
    assert "train_4k" in shapes and "decode_32k" in shapes
    if arch in ("whisper-large-v3", "internvl2-76b", "kimi-k2-1t-a32b"):
        assert "long_500k" not in shapes  # DESIGN.md §5 skips
    else:
        assert "long_500k" in shapes
