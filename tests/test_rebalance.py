"""Dynamic rebalancing (core/rebalance.py): the online profile must track
the live routing distribution, the Rebalancer must emit bounded,
positive-gain migration plans (and stay quiet when placement already
matches the workload), migrations must be charged to the ledger, and —
the hard invariant — placement changes must never change numerics.
"""
import dataclasses

import jax
import numpy as np
import pytest

from conftest import reduced_model
from repro.configs import get_config
from repro.core import (
    FiddlerEngine,
    HardwareSpec,
    MigrationPlan,
    OnlineProfile,
    Rebalancer,
)
from repro.core.cost_model import expert_weight_bytes
from repro.core.placement import Placement, hit_rate, place_by_popularity
from repro.core.popularity import ExpertProfile, synthetic_profile
from repro.core.rebalance import apply_plan
from repro.serving.backend import SimulatedBackend
from repro.serving.continuous import ContinuousEngine
from repro.serving.engine import Request


# ---------------------------------------------------------------------------
# OnlineProfile
# ---------------------------------------------------------------------------


def test_online_profile_converges_to_observed_distribution():
    prof = OnlineProfile(2, 4, decay=0.8)
    target = np.array([0.5, 0.3, 0.2, 0.0])
    for _ in range(100):
        prof.observe(0, target * 60)      # layer 0 sees `target`
        prof.observe(1, np.array([0, 0, 0, 9]))
    np.testing.assert_allclose(prof.probabilities()[0], target, atol=1e-6)
    np.testing.assert_allclose(prof.probabilities()[1], [0, 0, 0, 1],
                               atol=1e-6)
    assert prof.updates == 200


def test_online_profile_batch_size_invariant():
    """A 1-token step and a 64-token chunk with the same routing mix must
    move the estimate identically (observations are normalised)."""
    a = OnlineProfile(1, 4, decay=0.9)
    b = OnlineProfile(1, 4, decay=0.9)
    a.observe(0, np.array([1, 1, 0, 0]))
    b.observe(0, np.array([32, 32, 0, 0]))
    np.testing.assert_array_equal(a.probabilities(), b.probabilities())


def test_online_profile_prior_warm_start():
    calib = synthetic_profile(3, 8, seed=0)
    prof = OnlineProfile(3, 8, prior=calib)
    np.testing.assert_allclose(prof.probabilities(),
                               calib.probabilities(), atol=1e-12)
    prof.observe(0, np.ones(8))   # empty counts are ignored
    prof.observe(1, np.zeros(8))
    assert prof.updates == 1


# ---------------------------------------------------------------------------
# Rebalancer planning
# ---------------------------------------------------------------------------


def _skewed(L=4, E=8, seed=0):
    return synthetic_profile(L, E, seed=seed, concentration=0.5)


def test_rebalancer_quiet_when_placement_matches_live():
    calib = _skewed()
    budget = 8
    placement = place_by_popularity(calib, budget)
    reb = Rebalancer(profile=OnlineProfile(4, 8, prior=calib), budget=budget,
                     expert_bytes=1000, transfer_lat=1e-3, interval=1, k=4)
    assert reb.plan(placement) is None  # live == calibration: no churn
    assert reb.tick(placement) is None


def test_rebalancer_plan_bounded_and_positive_gain():
    calib, live = _skewed(seed=0), _skewed(seed=7)
    budget = 8
    placement = place_by_popularity(calib, budget)
    for k in (1, 2, 4):
        reb = Rebalancer(profile=OnlineProfile(4, 8, prior=live),
                         budget=budget, expert_bytes=1000, transfer_lat=1e-3,
                         interval=1, k=k)
        plan = reb.plan(placement)
        assert plan is not None
        assert 1 <= plan.n_swaps <= k
        assert len(plan.promotes) == len(plan.demotes)
        assert plan.est_gain > 0 and plan.gain_per_byte > 0
        assert plan.transfer_bytes == plan.n_swaps * 1000
        assert plan.est_transfer_s == pytest.approx(plan.n_swaps * 1e-3)
        # the swap must improve the expected hit rate under the live mix
        after = apply_plan(placement, plan)
        assert hit_rate(live, after) > hit_rate(live, placement)
        assert after.n_resident == placement.n_resident  # budget respected
        assert hit_rate(live, after) - hit_rate(live, placement) == \
            pytest.approx(plan.est_gain, rel=1e-9)


def test_rebalancer_interval_gating():
    calib, live = _skewed(seed=0), _skewed(seed=7)
    placement = place_by_popularity(calib, 8)
    reb = Rebalancer(profile=OnlineProfile(4, 8, prior=live), budget=8,
                     expert_bytes=1, transfer_lat=0.0, interval=5, k=1)
    fired = [i for i in range(1, 21) if reb.tick(placement) is not None]
    assert fired == [5, 10, 15, 20]  # placement unchanged → fires each time


def test_apply_plan_validates_swaps():
    placement = Placement(np.array([[True, False]]))
    with pytest.raises(AssertionError):
        apply_plan(placement, MigrationPlan(
            promotes=((0, 0),), demotes=((0, 1),),
            est_gain=0.0, transfer_bytes=0, est_transfer_s=0.0))


# ---------------------------------------------------------------------------
# Ledger charging (no free migrations)
# ---------------------------------------------------------------------------


def test_migrations_charge_simulated_clock():
    """Synchronous mode (``async_prefetch=False``): every promotion
    charges ``transfer_lat()`` serially into sim_time at apply time.
    (The async default defers the charge to idle link windows — covered
    by tests/test_dispatch.py.)"""
    cfg = get_config("mixtral-8x7b")
    L, E = cfg.n_layers, cfg.moe.n_experts
    calib = synthetic_profile(L, E, seed=0, concentration=0.5)
    eng = FiddlerEngine(cfg, policy="fiddler", hw=HardwareSpec.paper_env1(),
                        profile=calib, expert_budget=L * E // 4,
                        rebalance_interval=1, rebalance_k=4,
                        async_prefetch=False)
    # drift the live profile hard: routing now prefers the *least*
    # calibrated-popular experts
    eng.profile = ExpertProfile(1.0 / np.maximum(calib.counts, 1.0))
    for _ in range(100):  # let the EWMA forget the calibration prior
        for li in range(L):
            eng.rebalancer.profile.observe(li, eng.profile.counts[li])
    t0 = eng.ledger.sim_time
    plan = eng.maybe_rebalance()
    assert plan is not None and plan.n_swaps >= 1
    led = eng.ledger
    assert led.migrations == plan.n_swaps
    assert led.sim_time - t0 == pytest.approx(
        plan.n_swaps * eng.lat.transfer_lat())
    assert led.migration_time == pytest.approx(led.sim_time - t0)
    assert led.migration_exposed == pytest.approx(led.migration_time)
    assert led.migration_overlapped == 0.0
    assert led.migration_bytes == plan.n_swaps * expert_weight_bytes(cfg)


def test_rebalancer_rejects_static_split():
    cfg = get_config("mixtral-8x7b")
    with pytest.raises(AssertionError):
        FiddlerEngine(cfg, policy="static_split", rebalance_interval=4)


# ---------------------------------------------------------------------------
# Migration correctness: placement changes never change numerics
# ---------------------------------------------------------------------------


@pytest.fixture(scope="module")
def mixtral():
    return reduced_model("mixtral-8x7b")


def _forward(eng, tokens, n_decode=2, max_seq=32):
    """Deterministic prefill + a few decode steps → stacked logits."""
    outs = []
    logits, caches = eng.prefill(tokens, max_seq=max_seq)
    outs.append(np.asarray(logits))
    for step in range(n_decode):
        logits, caches = eng.decode_step(caches, tokens[:, :1],
                                         pos=tokens.shape[1] + step,
                                         max_seq=max_seq)
        outs.append(np.asarray(logits))
    return np.stack(outs)


def _swap_plan(placement):
    """One promote + one demote in the first layer that allows both."""
    for li in range(placement.on_fast.shape[0]):
        row = placement.on_fast[li]
        if row.any() and (~row).any():
            promote = (li, int(np.nonzero(~row)[0][0]))
            demote = (li, int(np.nonzero(row)[0][0]))
            return MigrationPlan(promotes=(promote,), demotes=(demote,),
                                 est_gain=0.0, transfer_bytes=0,
                                 est_transfer_s=0.0)
    raise AssertionError("no layer with a mixed placement")


@pytest.mark.parametrize("host_precision", ["fp32", "bf16"])
def test_promote_demote_cycle_bit_identical(mixtral, host_precision):
    """A promote/demote cycle returns to the original placement and must
    reproduce the original orchestrated outputs bit for bit — in the
    default bf16 slow tier too: each tier's representation is rebuilt
    from the original fp32 params, so cycles never compound rounding."""
    cfg, model, params = mixtral
    tokens = jax.random.randint(jax.random.PRNGKey(3), (1, 8), 3,
                                cfg.vocab_size)
    eng = FiddlerEngine(cfg, params, policy="fiddler",
                        expert_budget=cfg.n_layers * cfg.moe.n_experts // 2,
                        host_precision=host_precision)
    before = _forward(eng, tokens)
    plan = _swap_plan(eng.placement)
    eng.apply_migrations(plan)
    inverse = dataclasses.replace(plan, promotes=plan.demotes,
                                  demotes=plan.promotes)
    eng.apply_migrations(inverse)
    after = _forward(eng, tokens)
    np.testing.assert_array_equal(before, after)
    assert eng.ledger.migrations == 2  # both directions charged


def test_migrated_engine_matches_fresh_engine_with_same_placement(mixtral):
    """Applying a migration plan must be indistinguishable from having
    constructed the engine with the target placement: bit-identical
    logits (the planner may place experts anywhere; results never move)."""
    cfg, model, params = mixtral
    tokens = jax.random.randint(jax.random.PRNGKey(4), (2, 6), 3,
                                cfg.vocab_size)
    budget = cfg.n_layers * cfg.moe.n_experts // 2
    eng = FiddlerEngine(cfg, params, policy="fiddler", expert_budget=budget,
                        host_precision="fp32")
    plan = _swap_plan(eng.placement)
    eng.apply_migrations(plan)
    fresh = FiddlerEngine(cfg, params, policy="fiddler",
                          expert_budget=budget, host_precision="fp32",
                          placement=eng.placement)
    np.testing.assert_array_equal(_forward(eng, tokens),
                                  _forward(fresh, tokens))


# ---------------------------------------------------------------------------
# End to end: dynamic rebalancing recovers from a routing shift (sim)
# ---------------------------------------------------------------------------


def test_dynamic_rebalancing_beats_static_after_shift():
    """Small-scale version of benchmarks/workload_shift.py: after a
    mid-trace routing shift the rebalanced placement must have a strictly
    higher expected hit rate under the live distribution than the frozen
    one, with every migration charged."""
    cfg = get_config("mixtral-8x7b")
    L, E = cfg.n_layers, cfg.moe.n_experts
    calib = synthetic_profile(L, E, seed=0, concentration=0.5)
    rng = np.random.default_rng(1)
    shifted = ExpertProfile(np.stack(
        [calib.counts[l][rng.permutation(E)] for l in range(L)]))

    def serve(dynamic):
        eng = FiddlerEngine(cfg, policy="fiddler",
                            hw=HardwareSpec.paper_env1(), profile=calib,
                            expert_budget=L * E // 4, seed=0,
                            rebalance_interval=2 if dynamic else None,
                            rebalance_k=8)
        serving = ContinuousEngine(SimulatedBackend(eng, max_seq=64),
                                   n_slots=2, max_seq=64, prefill_chunk=8)
        eng.profile = shifted   # the shift: routing no longer matches calib
        t = 0.0
        for i in range(8):
            t += 0.05
            serving.submit(Request(rid=f"r{i}", prompt=[1] * 8,
                                   max_new_tokens=12, arrival=t))
        serving.run(max_steps=50_000, on_exhausted="raise")
        return eng

    static = serve(False)
    dynamic = serve(True)
    assert static.ledger.migrations == 0
    assert dynamic.ledger.migrations > 0
    assert dynamic.ledger.migration_time > 0
    assert hit_rate(shifted, dynamic.placement) > \
        hit_rate(shifted, static.placement)


# ---------------------------------------------------------------------------
# Prefetch ordering (PR 4 follow-on): hottest promotion lands first
# ---------------------------------------------------------------------------


def test_prefetch_queue_orders_by_popularity():
    """The link is serial but the transmission *order* is ours: a pushed
    transfer with higher popularity weight is drained (lands) before an
    earlier, colder one; equal weights keep FIFO."""
    from repro.core.rebalance import PrefetchQueue

    q = PrefetchQueue()
    q.push(0, 11, 1.0, weight=0.1)   # cold, pushed first
    q.push(0, 22, 1.0, weight=0.9)   # hot, pushed second
    q.push(0, 33, 1.0, weight=0.9)   # equally hot: FIFO after 22
    assert q.drain(1.0) == 1.0       # exactly one transfer's worth
    # the hot expert 22 landed: forcing it now exposes nothing, while the
    # cold 11 is still queued (behind 33)
    assert q.force(0, {22}) == 0.0
    assert q.backlog == 2.0
    assert q.force(0, {33}) == 1.0   # 33 next (FIFO among equal weights)
    assert q.force(0, {11}) == 1.0
    assert len(q) == 0


def test_prefetch_queue_default_weight_keeps_fifo():
    from repro.core.rebalance import PrefetchQueue

    q = PrefetchQueue()
    for e in (1, 2, 3):
        q.push(0, e, 1.0)
    q.drain(1.0)
    assert q.force(0, {1}) == 0.0    # first pushed landed first
    assert q.force(0, {2}) == 1.0


def test_engine_prefetch_ranked_by_live_popularity():
    """apply_migrations pushes promotions weighted by the OnlineProfile:
    the queue holds them hottest-first regardless of plan order."""
    cfg = get_config("mixtral-8x7b")
    L, E = cfg.n_layers, cfg.moe.n_experts
    eng = FiddlerEngine(cfg, policy="fiddler",
                        hw=HardwareSpec.paper_env1(), seed=0,
                        rebalance_interval=4, rebalance_k=8,
                        async_prefetch=True)
    # make the live profile heavily skewed, with a *different* skew per
    # layer, so the plan promotes experts of clearly distinct popularity
    rng = np.random.default_rng(3)
    for li in range(L):
        counts = np.ones(E)
        counts[rng.permutation(E)[0]] = 20 + 40 * li  # p_top varies by layer
        for _ in range(50):
            eng.rebalancer.observe(li, counts)
    plan = eng.rebalancer.plan(eng.placement)
    assert plan is not None and plan.n_swaps >= 2
    eng.apply_migrations(plan)
    weights = [p.weight for p in eng._prefetch._q]
    assert len(weights) == plan.n_swaps
    assert weights == sorted(weights, reverse=True)
    assert weights[0] > weights[-1], "needs distinct popularity to rank"
    eng.flush_prefetch()
