"""Model factory: config → init / forward / prefill / decode.

Layers are grouped into *periods* (the repeating layer pattern — e.g. gemma2
alternates local/global attention with period 2, RecurrentGemma repeats
(recurrent, recurrent, local-attn) with period 3) and parameters for each
position-in-period are stacked over periods so the whole stack lowers as a
single ``lax.scan``.  This keeps HLO size (and dry-run compile time) flat in
depth — essential for the 61–80 layer assigned architectures.  Layers that
don't fit a whole period form an explicitly-unrolled ``tail``.

All functions are pure; sharding is injected through a ``ParallelContext``
(``with_sharding_constraint`` + shard_map for MoE) so the same code runs on
one CPU device (smoke tests, Fiddler serving) and on the 512-chip mesh
(dry-run).
"""
from __future__ import annotations

import math
from dataclasses import dataclass
from typing import Any, Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.configs.base import ModelConfig
from repro.models import kv_cache as kvc
from repro.models.attention import (
    attention_block,
    cross_attention_block,
    encode_cross_kv,
    init_attention,
    init_cross_attention,
)
from repro.models.layers import (
    Params,
    dense_init,
    embed_init,
    gated_mlp,
    init_gated_mlp,
    init_layernorm,
    init_rmsnorm,
    layernorm,
    rmsnorm,
    softcap,
)
from repro.models.moe import init_moe, moe_block_ref, moe_block_sharded
from repro.models.rglru import init_rglru_block, rglru_block
from repro.models.ssm import init_ssm_block, ssm_block


# ---------------------------------------------------------------------------
# Parallel context
# ---------------------------------------------------------------------------


@dataclass(frozen=True)
class ParallelContext:
    mesh: Any = None
    data_axes: Tuple[str, ...] = ("data",)
    model_axis: str = "model"

    @property
    def active(self) -> bool:
        return self.mesh is not None

    @property
    def data_size(self) -> int:
        if not self.active:
            return 1
        n = 1
        for ax in self.data_axes:
            n *= self.mesh.shape[ax]
        return n

    def shard(self, x: jnp.ndarray, spec: P) -> jnp.ndarray:
        if not self.active:
            return x
        return jax.lax.with_sharding_constraint(
            x, jax.sharding.NamedSharding(self.mesh, spec))

    def batch_axes(self, batch: int):
        """data axes if the batch is shardable over them, else None."""
        return self.data_axes if (self.data_size > 1
                                  and batch % self.data_size == 0) else None

    def shard_act(self, x: jnp.ndarray) -> jnp.ndarray:
        """Activations: batch over data axes, features replicated.

        With opts.SEQ_SHARD_ACTS (§Perf), the residual stream between
        blocks is additionally sharded over ``model`` on the sequence
        axis (Megatron-style sequence parallelism): the scan's layer-input
        remat carries shrink by the model-axis size, and SPMD inserts the
        gather/reduce-scatter pairs around attention/MLP."""
        if not self.active:
            return x
        from repro.distributed import opts

        seq = None
        if (opts.SEQ_SHARD_ACTS and x.ndim == 3 and x.shape[1] > 1
                and x.shape[1] % self.mesh.shape[self.model_axis] == 0):
            seq = self.model_axis
        spec = P(self.batch_axes(x.shape[0]), seq,
                 *((None,) * (x.ndim - 2)))
        return self.shard(x, spec)

    def shard_logits(self, x: jnp.ndarray) -> jnp.ndarray:
        """Logits: batch over data, vocab over model (when divisible)."""
        if not self.active:
            return x
        vocab = x.shape[-1]
        m = self.model_axis if vocab % self.mesh.shape[self.model_axis] == 0 else None
        spec = P(self.batch_axes(x.shape[0]),
                 *((None,) * (x.ndim - 2)), m)
        return self.shard(x, spec)


NO_PARALLEL = ParallelContext()


# ---------------------------------------------------------------------------
# Period structure
# ---------------------------------------------------------------------------


def period_of(cfg: ModelConfig) -> int:
    if cfg.arch_type == "hybrid":
        return cfg.hybrid.attn_period
    if cfg.attn_pattern == "alternating":
        return 2
    return 1


def sublayer_kind(cfg: ModelConfig, j: int) -> str:
    """Kind of the j-th sub-layer within a period."""
    if cfg.arch_type == "ssm":
        return "ssm"
    if cfg.arch_type == "hybrid":
        return "recurrent" if j < cfg.hybrid.attn_period - 1 else "attention"
    return "attention"


def layer_plan(cfg: ModelConfig) -> Tuple[int, int, List[int]]:
    """Returns (period, n_periods, tail_positions)."""
    p = period_of(cfg)
    n_periods = cfg.n_layers // p
    tail = list(range(cfg.n_layers - n_periods * p))
    return p, n_periods, tail


# ---------------------------------------------------------------------------
# Sub-layer init / apply
# ---------------------------------------------------------------------------


def _norm_init(cfg: ModelConfig, d: int, dtype):
    return init_layernorm(d, dtype) if cfg.arch_type == "audio" else init_rmsnorm(d, dtype)


def _norm(cfg: ModelConfig, p: Params, x: jnp.ndarray) -> jnp.ndarray:
    if cfg.arch_type == "audio":
        return layernorm(p, x, 1e-5)
    return rmsnorm(p, x, cfg.norm_eps)


def init_plain_mlp(key, d: int, f: int, dtype) -> Params:
    k1, k2 = jax.random.split(key)
    return {"w1": dense_init(k1, (d, f), 0, dtype),
            "w2": dense_init(k2, (f, d), 0, dtype)}


def plain_mlp(p: Params, x: jnp.ndarray) -> jnp.ndarray:
    return jax.nn.gelu(x @ p["w1"], approximate=True) @ p["w2"]


def init_sublayer(key, cfg: ModelConfig, j: int, dtype) -> Params:
    kind = sublayer_kind(cfg, j)
    keys = jax.random.split(key, 6)
    d = cfg.d_model
    if kind == "ssm":
        return {"norm1": _norm_init(cfg, d, dtype),
                "mixer": init_ssm_block(keys[0], cfg, dtype)}
    if kind == "recurrent":
        return {"norm1": _norm_init(cfg, d, dtype),
                "temporal": init_rglru_block(keys[0], cfg, dtype),
                "norm2": _norm_init(cfg, d, dtype),
                "mlp": init_gated_mlp(keys[1], d, cfg.d_ff, dtype)}
    # attention-based
    p: Params = {"norm1": _norm_init(cfg, d, dtype),
                 "attn": init_attention(keys[0], cfg, dtype),
                 "norm2": _norm_init(cfg, d, dtype)}
    if cfg.arch_type == "audio":
        p["cross"] = init_cross_attention(keys[1], cfg, dtype)
        p["norm3"] = _norm_init(cfg, d, dtype)
        p["mlp"] = init_plain_mlp(keys[2], d, cfg.d_ff, dtype)
    elif cfg.moe is not None:
        p["moe"] = init_moe(keys[1], cfg, dtype)
    else:
        p["mlp"] = init_gated_mlp(keys[1], d, cfg.d_ff, dtype)
    return p


def apply_sublayer(
    p: Params,
    x: jnp.ndarray,
    positions: jnp.ndarray,
    cfg: ModelConfig,
    j: int,
    layer_idx_for_window: int,
    pctx: ParallelContext,
    *,
    mode: str,
    cache: Optional[Params],
    max_seq: Optional[int],
    cross_kv: Optional[Tuple[jnp.ndarray, jnp.ndarray]] = None,
    rope: bool = True,
    causal: bool = True,
) -> Tuple[jnp.ndarray, Optional[Params], jnp.ndarray]:
    """One (norm → mixer → residual [→ norm → ffn → residual]) sub-layer.

    Returns (x, new_cache, aux_loss).
    """
    kind = sublayer_kind(cfg, j)
    aux = jnp.float32(0.0)
    if kind == "ssm":
        h, new_cache = ssm_block(p["mixer"], _norm(cfg, p["norm1"], x), cfg,
                                 cache=cache)
        x = x + h
        return pctx.shard_act(x), new_cache, aux

    if kind == "recurrent":
        h, new_cache = rglru_block(p["temporal"], _norm(cfg, p["norm1"], x),
                                   cfg, cache=cache)
        x = x + h
        x = x + gated_mlp(p["mlp"], _norm(cfg, p["norm2"], x), cfg.act)
        return pctx.shard_act(x), new_cache, aux

    # ---- attention sub-layer ---------------------------------------------
    if cfg.arch_type == "audio":
        rope = False  # whisper: absolute positions added at the embedding
    h, new_cache = attention_block(
        p["attn"], _norm(cfg, p["norm1"], x), positions, cfg,
        layer_idx_for_window, mode=mode, cache=cache, max_seq=max_seq,
        rope=rope, causal=causal)
    x = x + h
    x = pctx.shard_act(x)

    if cfg.arch_type == "audio" and cross_kv is not None:
        x = x + cross_attention_block(p["cross"], _norm(cfg, p["norm3"], x),
                                      cross_kv, cfg)

    if "moe" in p:
        kind_str = {"train": "train", "prefill": "prefill",
                    "prefill_chunk": "prefill", "decode": "decode",
                    "decode_multi": "decode"}[mode]
        if pctx.active:
            h, stats = moe_block_sharded(
                p["moe"], _norm(cfg, p["norm2"], x), cfg, pctx.mesh,
                pctx.data_axes, pctx.model_axis, kind=kind_str)
        else:
            h, stats = moe_block_ref(p["moe"], _norm(cfg, p["norm2"], x), cfg,
                                     kind=kind_str)
        aux = aux + stats["aux_loss"]
        x = x + h
    elif cfg.arch_type == "audio":
        x = x + plain_mlp(p["mlp"], _norm(cfg, p["norm2"], x))
    else:
        x = x + gated_mlp(p["mlp"], _norm(cfg, p["norm2"], x), cfg.act)
    return pctx.shard_act(x), new_cache, aux


# ---------------------------------------------------------------------------
# Sub-layer cache init
# ---------------------------------------------------------------------------


def init_sublayer_cache(cfg: ModelConfig, j: int, layer_idx: int, batch: int,
                        max_seq: int, dtype=jnp.bfloat16) -> Optional[Params]:
    kind = sublayer_kind(cfg, j)
    if kind == "ssm":
        return kvc.init_ssm_cache(cfg, batch)
    if kind == "recurrent":
        return kvc.init_lru_cache(cfg, batch)
    return kvc.init_attn_cache(cfg, layer_idx, batch, max_seq, dtype)


# ---------------------------------------------------------------------------
# Whisper encoder
# ---------------------------------------------------------------------------


def init_encoder(key, cfg: ModelConfig, dtype) -> Params:
    n = cfg.encdec.n_encoder_layers
    keys = jax.random.split(key, n + 1)
    blocks = [
        {"norm1": _norm_init(cfg, cfg.d_model, dtype),
         "attn": init_attention(keys[i], cfg, dtype),
         "norm2": _norm_init(cfg, cfg.d_model, dtype),
         "mlp": init_plain_mlp(jax.random.fold_in(keys[i], 7), cfg.d_model,
                               cfg.d_ff, dtype)}
        for i in range(n)
    ]
    stacked = jax.tree.map(lambda *a: jnp.stack(a), *blocks)
    return {"blocks": stacked, "final_norm": _norm_init(cfg, cfg.d_model, dtype)}


def sinusoid_pos(n: int, d: int) -> jnp.ndarray:
    pos = jnp.arange(n, dtype=jnp.float32)[:, None]
    dim = jnp.arange(d // 2, dtype=jnp.float32)[None, :]
    inv = jnp.exp(-math.log(10000.0) * dim / max(d // 2 - 1, 1))
    ang = pos * inv
    return jnp.concatenate([jnp.sin(ang), jnp.cos(ang)], axis=-1)


def sinusoid_pos_at(pos: jnp.ndarray, d: int) -> jnp.ndarray:
    """Sinusoidal embedding for a single traced scalar position."""
    dim = jnp.arange(d // 2, dtype=jnp.float32)
    inv = jnp.exp(-math.log(10000.0) * dim / max(d // 2 - 1, 1))
    ang = pos.astype(jnp.float32) * inv
    return jnp.concatenate([jnp.sin(ang), jnp.cos(ang)], axis=-1)


def run_encoder(params: Params, frames: jnp.ndarray, cfg: ModelConfig,
                pctx: ParallelContext) -> jnp.ndarray:
    """frames: (B, F, d) stubbed conv-frontend output → encoder states."""
    B, F, d = frames.shape
    x = frames + sinusoid_pos(F, d)[None].astype(frames.dtype)
    positions = jnp.broadcast_to(jnp.arange(F)[None], (B, F))

    def body(carry, p):
        x = carry
        h, _ = attention_block(p["attn"], _norm(cfg, p["norm1"], x), positions,
                               cfg, 1, mode="train", rope=False, causal=False)
        x = x + h
        x = x + plain_mlp(p["mlp"], _norm(cfg, p["norm2"], x))
        return pctx.shard_act(x), None

    x, _ = jax.lax.scan(body, x, params["blocks"])
    return _norm(cfg, params["final_norm"], x)


# ---------------------------------------------------------------------------
# Model
# ---------------------------------------------------------------------------


class Model:
    """Bound (config, parallel-context) model functions."""

    def __init__(self, cfg: ModelConfig, pctx: ParallelContext = NO_PARALLEL,
                 param_dtype=None, unroll_scan: bool = False):
        self.cfg = cfg
        self.pctx = pctx
        self.param_dtype = param_dtype or jnp.dtype(cfg.param_dtype)
        self.period, self.n_periods, self.tail = layer_plan(cfg)
        # unroll the layer scan into a python loop — used by the roofline
        # analysis (XLA cost_analysis counts a while body once, so scanned
        # stacks under-report FLOPs/bytes; unrolled small-depth variants
        # give exact per-layer costs for extrapolation)
        self.unroll_scan = unroll_scan

    # ---- init -------------------------------------------------------------
    def init(self, key) -> Params:
        cfg, dtype = self.cfg, self.param_dtype
        k_embed, k_blocks, k_tail, k_head, k_enc = jax.random.split(key, 5)
        params: Params = {
            "embed": embed_init(k_embed, (cfg.vocab_size, cfg.d_model), dtype),
            "final_norm": _norm_init(cfg, cfg.d_model, dtype),
        }
        if not cfg.tie_embeddings:
            params["lm_head"] = dense_init(k_head, (cfg.d_model, cfg.vocab_size),
                                           0, dtype)
        blocks = []
        if self.n_periods:
            for j in range(self.period):
                per = [init_sublayer(
                    jax.random.fold_in(k_blocks, i * self.period + j), cfg, j,
                    dtype) for i in range(self.n_periods)]
                blocks.append(jax.tree.map(lambda *a: jnp.stack(a), *per))
        params["blocks"] = blocks
        params["tail"] = [init_sublayer(jax.random.fold_in(k_tail, j), cfg, j, dtype)
                          for j in self.tail]
        if cfg.arch_type == "audio":
            params["encoder"] = init_encoder(k_enc, cfg, dtype)
        return params

    # ---- embedding / head --------------------------------------------------
    def embed(self, params: Params, tokens: jnp.ndarray,
              pos_offset: Optional[jnp.ndarray] = None) -> jnp.ndarray:
        x = params["embed"][tokens]
        if self.cfg.scale_embeddings:
            x = x * jnp.asarray(math.sqrt(self.cfg.d_model), x.dtype)
        if self.cfg.arch_type == "audio":
            # whisper decoder: absolute (sinusoidal stand-in) positions
            S = tokens.shape[1]
            table = sinusoid_pos(S if pos_offset is None else 1, self.cfg.d_model)
            if pos_offset is not None:
                angle = sinusoid_pos_at(pos_offset, self.cfg.d_model)
                x = x + angle[None, None, :].astype(x.dtype)
            else:
                x = x + table[None].astype(x.dtype)
        return x

    def logits(self, params: Params, hidden: jnp.ndarray) -> jnp.ndarray:
        h = _norm(self.cfg, params["final_norm"], hidden)
        w = params["embed"].T if self.cfg.tie_embeddings else params["lm_head"]
        out = h @ w
        out = softcap(out.astype(jnp.float32), self.cfg.logit_softcap)
        return self.pctx.shard_logits(out)

    # ---- caches -------------------------------------------------------------
    def make_cache(self, batch: int, max_seq: int,
                   enc_frames: Optional[int] = None,
                   dtype=jnp.bfloat16) -> Params:
        cfg = self.cfg
        cache: Params = {"blocks": [], "tail": []}
        if self.n_periods:
            for j in range(self.period):
                per = [init_sublayer_cache(cfg, j, i * self.period + j, batch,
                                           max_seq, dtype)
                       for i in range(self.n_periods)]
                cache["blocks"].append(jax.tree.map(lambda *a: jnp.stack(a), *per))
        for j in self.tail:
            cache["tail"].append(
                init_sublayer_cache(cfg, j, self.n_periods * self.period + j,
                                    batch, max_seq, dtype))
        if cfg.arch_type == "audio":
            f = enc_frames if enc_frames is not None else cfg.encdec.n_audio_frames
            cache["cross_kv"] = (
                jnp.zeros((self.n_periods, batch, f, cfg.n_kv_heads,
                           cfg.head_dim), dtype),
                jnp.zeros((self.n_periods, batch, f, cfg.n_kv_heads,
                           cfg.head_dim), dtype),
            )
        return cache

    def reorder_cache(self, cache: Params, idx) -> Params:
        """Reorder the batch dimension of a cache (beam-search reshuffle).
        Block caches are scan-stacked (n_periods, B, …) → batch is axis 1;
        tail caches are per-layer (B, …) → axis 0; cross_kv is stacked.

        This is the dense layout's reshuffle — a full KV row gather.  The
        orchestrated serving path's paged layout (models/paged_kv.py via
        ``FiddlerEngine.reorder_cache``) does the same reshuffle as a
        block-table permutation with zero KV data movement."""
        idx = jnp.asarray(idx)
        out = dict(cache)
        out["blocks"] = jax.tree.map(lambda a: jnp.take(a, idx, axis=1),
                                     cache["blocks"])
        out["tail"] = jax.tree.map(lambda a: jnp.take(a, idx, axis=0),
                                   cache["tail"])
        if "cross_kv" in cache:
            out["cross_kv"] = jax.tree.map(
                lambda a: jnp.take(a, idx, axis=1), cache["cross_kv"])
        return out

    def fork_slot(self, cache: Params, src: int, dst: int) -> Params:
        """Slot ``dst`` becomes a KV copy of ``src`` (beam-group member
        creation on the dense layout — same axis contract as
        ``write_slot``)."""
        out = dict(cache)
        out["blocks"] = jax.tree.map(lambda a: a.at[:, dst].set(a[:, src]),
                                     cache["blocks"])
        out["tail"] = jax.tree.map(lambda a: a.at[dst].set(a[src]),
                                   cache["tail"])
        if "cross_kv" in cache:
            out["cross_kv"] = jax.tree.map(
                lambda a: a.at[:, dst].set(a[:, src]), cache["cross_kv"])
        return out

    def reorder_slots(self, cache: Params, slots, src_of) -> Params:
        """Beam reshuffle over a slot subset: ``slots[i]`` continues the
        sequence held by ``src_of[i]`` (sources may repeat; the gather of
        the source rows happens before any scatter, so aliasing is
        safe)."""
        di = jnp.asarray(list(slots))
        si = jnp.asarray(list(src_of))
        out = dict(cache)
        out["blocks"] = jax.tree.map(
            lambda a: a.at[:, di].set(jnp.take(a, si, axis=1)),
            cache["blocks"])
        out["tail"] = jax.tree.map(
            lambda a: a.at[di].set(jnp.take(a, si, axis=0)),
            cache["tail"])
        if "cross_kv" in cache:
            out["cross_kv"] = jax.tree.map(
                lambda a: a.at[:, di].set(jnp.take(a, si, axis=1)),
                cache["cross_kv"])
        return out

    # ---- backbone -----------------------------------------------------------
    def _backbone(self, params: Params, x: jnp.ndarray, positions: jnp.ndarray,
                  *, mode: str, cache: Optional[Params], max_seq: Optional[int],
                  cross_kv_stacked=None, remat: bool = False
                  ) -> Tuple[jnp.ndarray, Optional[Params], jnp.ndarray]:
        cfg, pctx = self.cfg, self.pctx
        period = self.period

        def period_body(carry, xs):
            x, aux = carry
            block_params, block_cache, cross_kv = xs
            new_caches = []
            for j in range(period):
                c_j = None if block_cache is None else block_cache[j]
                x, nc, a = apply_sublayer(
                    block_params[j], x, positions, cfg, j, j, pctx,
                    mode=mode, cache=c_j, max_seq=max_seq, cross_kv=cross_kv)
                new_caches.append(nc)
                aux = aux + a
            ys = tuple(new_caches) if block_cache is not None else None
            return (x, aux), ys

        body = period_body
        if remat:
            body = jax.checkpoint(period_body, prevent_cse=False)

        if self.n_periods:
            blocks_xs = tuple(params["blocks"])
            cache_xs = tuple(cache["blocks"]) if cache is not None else None
            cross_xs = cache.get("cross_kv") if (cache is not None and
                                                 cfg.arch_type == "audio") else None
            xs = (blocks_xs, cache_xs, cross_xs)
            if self.unroll_scan:
                carry = (x, jnp.float32(0.0))
                ys = []
                for i in range(self.n_periods):
                    xs_i = jax.tree.map(lambda a: a[i], xs)
                    carry, y = body(carry, xs_i)
                    ys.append(y)
                (x, aux) = carry
                if ys and ys[0] is not None:
                    new_block_caches = jax.tree.map(
                        lambda *a: jnp.stack(a), *ys)
                else:
                    new_block_caches = ()
            else:
                (x, aux), new_block_caches = jax.lax.scan(
                    body, (x, jnp.float32(0.0)), xs, length=self.n_periods)
        else:
            aux = jnp.float32(0.0)
            new_block_caches = ()

        new_cache = None
        if cache is not None:
            new_cache = dict(cache)
            new_cache["blocks"] = list(new_block_caches)
            new_tail = []
        for t, j in enumerate(self.tail):
            c_t = cache["tail"][t] if cache is not None else None
            layer_idx = self.n_periods * period + j
            x, nc, a = apply_sublayer(
                params["tail"][t], x, positions, cfg, j, layer_idx, pctx,
                mode=mode, cache=c_t, max_seq=max_seq)
            aux = aux + a
            if cache is not None:
                new_tail.append(nc)
        if cache is not None:
            new_cache["tail"] = new_tail
        return x, new_cache, aux

    # ---- public entry points -------------------------------------------------
    def forward_train(self, params: Params, tokens: jnp.ndarray,
                      extra: Optional[Dict[str, jnp.ndarray]] = None,
                      remat: bool = True) -> Tuple[jnp.ndarray, jnp.ndarray]:
        """Training forward. tokens: (B, S_text). Returns (hidden, aux_loss).

        VLM: extra["image_embeds"] (B, n_img, d) is prepended.
        Audio: extra["frames"] (B, F, d) runs the encoder; decoder
        cross-attends (computed per layer from encoder states).
        """
        cfg, pctx = self.cfg, self.pctx
        x = self.embed(params, tokens)
        if cfg.arch_type == "vlm" and extra is not None:
            img = extra["image_embeds"].astype(x.dtype)
            x = jnp.concatenate([img, x], axis=1)
        x = pctx.shard_act(x)
        B, S, _ = x.shape
        positions = jnp.broadcast_to(jnp.arange(S)[None], (B, S))

        cross = None
        if cfg.arch_type == "audio":
            enc_out = run_encoder(params["encoder"], extra["frames"], cfg, pctx)
            # training path: build a pseudo-cache holding stacked cross K/V
            cross = self._stack_cross_kv(params, enc_out)

        if cross is not None:
            x, _, aux = self._backbone_train_with_cross(
                params, x, positions, cross, remat=remat)
        else:
            x, _, aux = self._backbone(params, x, positions, mode="train",
                                       cache=None, max_seq=S, remat=remat)
        return x, aux

    def _stack_cross_kv(self, params: Params, enc_out: jnp.ndarray):
        cfg = self.cfg

        def per_block(p):
            return encode_cross_kv(p["cross"], enc_out, cfg)

        ks, vs = jax.vmap(per_block, in_axes=(0,))(params["blocks"][0])
        return (ks, vs)

    def _backbone_train_with_cross(self, params, x, positions, cross,
                                   remat: bool):
        cfg, pctx = self.cfg, self.pctx

        def body(carry, xs):
            x, aux = carry
            bp, ckv = xs
            x, _, a = apply_sublayer(bp, x, positions, cfg, 0, 0, pctx,
                                     mode="train", cache=None, max_seq=None,
                                     cross_kv=ckv)
            return (x, aux + a), None

        if remat:
            body = jax.checkpoint(body, prevent_cse=False)
        (x, aux), _ = jax.lax.scan(body, (x, jnp.float32(0.0)),
                                   (params["blocks"][0], cross))
        return x, None, aux

    def prefill(self, params: Params, tokens: jnp.ndarray, max_seq: int,
                extra: Optional[Dict[str, jnp.ndarray]] = None,
                cache_dtype=jnp.bfloat16) -> Tuple[jnp.ndarray, Params]:
        """Process a fresh prompt; returns (last-position logits, cache)."""
        cfg, pctx = self.cfg, self.pctx
        x = self.embed(params, tokens)
        if cfg.arch_type == "vlm" and extra is not None:
            x = jnp.concatenate([extra["image_embeds"].astype(x.dtype), x], axis=1)
        x = pctx.shard_act(x)
        B, S, _ = x.shape
        positions = jnp.broadcast_to(jnp.arange(S)[None], (B, S))
        cache = self.make_cache(
            B, max_seq,
            enc_frames=(extra["frames"].shape[1] if cfg.arch_type == "audio"
                        and extra is not None else None),
            dtype=cache_dtype)
        if cfg.arch_type == "audio":
            enc_out = run_encoder(params["encoder"], extra["frames"], cfg, pctx)
            cache["cross_kv"] = jax.tree.map(
                lambda a: a.astype(cache_dtype), self._stack_cross_kv(params, enc_out))
        x, cache, _ = self._backbone(params, x, positions, mode="prefill",
                                     cache=cache, max_seq=max_seq)
        logits = self.logits(params, x[:, -1:])
        return logits[:, 0], cache

    def prefill_chunk(self, params: Params, cache: Params,
                      tokens: jnp.ndarray, pos_offset: jnp.ndarray,
                      max_seq: int) -> Tuple[jnp.ndarray, Params]:
        """Process one prompt chunk at positions ``pos_offset .. +S-1``
        against an existing cache (chunked prefill — long admissions are
        split across serving steps so in-flight decodes aren't stalled).
        Returns (last-position logits, cache); attention-backbone archs
        only (the recurrent/SSM state path has no chunk-append write)."""
        cfg, pctx = self.cfg, self.pctx
        x = self.embed(params, tokens)
        x = pctx.shard_act(x)
        B, S, _ = x.shape
        positions = (jnp.asarray(pos_offset, jnp.int32)
                     + jnp.arange(S, dtype=jnp.int32))[None, :]
        positions = jnp.broadcast_to(positions, (B, S))
        x, cache, _ = self._backbone(params, x, positions,
                                     mode="prefill_chunk", cache=cache,
                                     max_seq=max_seq)
        logits = self.logits(params, x[:, -1:])
        return logits[:, 0], cache

    def decode_step(self, params: Params, cache: Params, tokens: jnp.ndarray,
                    pos: jnp.ndarray, max_seq: int
                    ) -> Tuple[jnp.ndarray, Params]:
        """One decode step. tokens: (B, 1); pos: () scalar int32 (shared
        across the static batch). Returns (logits (B, V), new cache)."""
        cfg, pctx = self.cfg, self.pctx
        x = self.embed(params, tokens,
                       pos_offset=pos if cfg.arch_type == "audio" else None)
        x = pctx.shard_act(x)
        B = x.shape[0]
        positions = jnp.broadcast_to(pos[None, None], (B, 1)).astype(jnp.int32)
        x, cache, _ = self._backbone(params, x, positions, mode="decode",
                                     cache=cache, max_seq=max_seq)
        logits = self.logits(params, x)
        return logits[:, 0], cache

    def decode_step_multi(self, params: Params, cache: Params,
                          tokens: jnp.ndarray, pos: jnp.ndarray,
                          max_seq: int) -> Tuple[jnp.ndarray, Params]:
        """Continuous-batching decode: ``pos`` is (B,) int32 — every slot
        decodes at its own position (single-host serving path)."""
        cfg, pctx = self.cfg, self.pctx
        x = self.embed(params, tokens)
        positions = pos[:, None].astype(jnp.int32)
        x, cache, _ = self._backbone(params, x, positions,
                                     mode="decode_multi", cache=cache,
                                     max_seq=max_seq)
        logits = self.logits(params, x)
        return logits[:, 0], cache

    def write_slot(self, cache: Params, slot_cache: Params,
                   slot: int) -> Params:
        """Copy a freshly-prefilled single-request cache (batch 1) into
        slot ``slot`` of a multi-slot cache (continuous batching join).
        Structure-aware: blocks are scan-stacked (batch axis 1), tail
        caches are per-layer (batch axis 0)."""
        out = dict(cache)
        out["blocks"] = jax.tree.map(
            lambda b, s: b.at[:, slot].set(s[:, 0].astype(b.dtype)),
            cache["blocks"], slot_cache["blocks"])
        out["tail"] = jax.tree.map(
            lambda b, s: b.at[slot].set(s[0].astype(b.dtype)),
            cache["tail"], slot_cache["tail"])
        if "cross_kv" in cache:
            out["cross_kv"] = jax.tree.map(
                lambda b, s: b.at[:, slot].set(s[:, 0].astype(b.dtype)),
                cache["cross_kv"], slot_cache["cross_kv"])
        return out


# ---------------------------------------------------------------------------
# Loss (chunked cross-entropy — never materialises (B, S, V) in fp32)
# ---------------------------------------------------------------------------


LOSS_CHUNK_DEFAULT = 512


def lm_loss(model: Model, params: Params, hidden: jnp.ndarray,
            labels: jnp.ndarray, chunk: Optional[int] = None) -> jnp.ndarray:
    """hidden: (B, S, d); labels: (B, S) int32, -100 = ignore."""
    if chunk is None:
        chunk = LOSS_CHUNK_DEFAULT
    cfg, pctx = model.cfg, model.pctx
    B, S, d = hidden.shape
    w = params["embed"].T if cfg.tie_embeddings else params["lm_head"]
    chunk = min(chunk, S)
    pad = (-S) % chunk
    if pad:
        hidden = jnp.pad(hidden, ((0, 0), (0, pad), (0, 0)))
        labels = jnp.pad(labels, ((0, 0), (0, pad)), constant_values=-100)
    n_chunks = (S + pad) // chunk
    hc = hidden.reshape(B, n_chunks, chunk, d).swapaxes(0, 1)
    lc = labels.reshape(B, n_chunks, chunk).swapaxes(0, 1)
    final_norm = params["final_norm"]

    def body(carry, inp):
        tot, cnt = carry
        h, lab = inp
        h = _norm(cfg, final_norm, h)
        logits = h @ w
        logits = softcap(logits.astype(jnp.float32), cfg.logit_softcap)
        logits = pctx.shard_logits(logits)
        lse = jax.nn.logsumexp(logits, axis=-1)
        mask = lab >= 0
        safe = jnp.where(mask, lab, 0)
        tgt = jnp.take_along_axis(logits, safe[..., None], axis=-1)[..., 0]
        nll = jnp.where(mask, lse - tgt, 0.0)
        return (tot + jnp.sum(nll), cnt + jnp.sum(mask)), None

    # remat per chunk: (B, chunk, V) logits are recomputed in the backward
    # instead of being saved for every chunk.
    (tot, cnt), _ = jax.lax.scan(jax.checkpoint(body, prevent_cse=False),
                                 (jnp.float32(0.0), jnp.float32(0.0)),
                                 (hc, lc))
    return tot / jnp.maximum(cnt, 1.0)
