"""Expert placement (paper §3.1, §3.4): choose which experts live on the
fast tier, greedily by popularity, subject to the fast-tier memory budget.

Paper App. C: on Mixtral-8x7B, popularity-greedy placement beats random by
~3–5pp hit rate (25.2% vs 21.9% for 56/256 experts in Env-1; 53.0% vs 48.8%
for 125/256 in Env-2).  ``hit_rate`` reproduces those numbers from any
profile.
"""
from __future__ import annotations

from dataclasses import dataclass

import numpy as np

from repro.configs.base import ModelConfig
from repro.core.cost_model import HardwareSpec, expert_weight_bytes
from repro.core.popularity import ExpertProfile


@dataclass(frozen=True)
class Placement:
    """on_fast[l, e] — expert e of layer l resident on the fast tier."""

    on_fast: np.ndarray  # (n_layers, n_experts) bool

    @property
    def n_resident(self) -> int:
        return int(self.on_fast.sum())


@dataclass(frozen=True)
class DevicePlacement(Placement):
    """Placement generalised from two tiers to *devices × tiers*:
    ``device[l, e]`` names the fast-tier device (0..D-1) holding a
    resident expert, -1 for slow-tier experts.  A plain :class:`Placement`
    is the D=1 special case (every resident expert on device 0)."""

    device: np.ndarray  # (n_layers, n_experts) int16, -1 = slow tier

    def __post_init__(self):
        assert self.device.shape == self.on_fast.shape, (
            self.device.shape, self.on_fast.shape)
        assert bool(np.all((self.device >= 0) == self.on_fast)), \
            "device must be >= 0 exactly on resident experts"

    @property
    def n_devices(self) -> int:
        return int(self.device.max()) + 1 if self.on_fast.any() else 1

    def device_counts(self, n_devices: int | None = None) -> np.ndarray:
        """Resident experts per device (the per-device budget check)."""
        D = n_devices if n_devices is not None else self.n_devices
        return np.bincount(self.device[self.device >= 0].ravel(),
                           minlength=D)


def to_device_placement(p: Placement, n_devices: int = 1,
                        profile: ExpertProfile | None = None
                        ) -> DevicePlacement:
    """Assign a two-tier placement's resident experts to fast devices,
    round-robin in descending popularity order (uniform order without a
    profile) — the most popular experts spread across devices, so the
    expert-parallel all-to-all load stays balanced."""
    if isinstance(p, DevicePlacement):
        return p
    L, E = p.on_fast.shape
    flat_on = p.on_fast.reshape(-1)
    if profile is not None:
        order = np.argsort(-profile.probabilities().reshape(-1),
                           kind="stable")
    else:
        order = np.arange(L * E)
    device = np.full(L * E, -1, np.int16)
    k = 0
    for idx in order:
        if flat_on[idx]:
            device[idx] = k % n_devices
            k += 1
    return DevicePlacement(p.on_fast, device.reshape(L, E))


def non_expert_bytes(cfg: ModelConfig, bytes_per_param: int = 2) -> int:
    """Attention + norms + embeddings — always fast-tier (paper §3.1)."""
    moe = cfg.moe
    total = cfg.param_count()
    experts = (cfg.n_layers * (moe.n_experts + moe.n_shared_experts)
               * 3 * cfg.d_model * cfg.d_ff) if moe else 0
    return (total - experts) * bytes_per_param


def fast_tier_expert_budget(cfg: ModelConfig, hw: HardwareSpec,
                            bytes_per_param: int = 2,
                            reserve_frac: float = 0.1) -> int:
    """Max number of experts that fit on the fast tier after the non-expert
    weights and a KV/activation reserve (paper Table 1's
    'Number of Experts on GPU' row)."""
    usable = hw.fast_capacity * (1.0 - reserve_frac) - non_expert_bytes(
        cfg, bytes_per_param)
    if cfg.moe and cfg.moe.n_shared_experts:
        usable -= (cfg.n_layers * cfg.moe.n_shared_experts
                   * 3 * cfg.d_model * cfg.d_ff * bytes_per_param)
    eb = expert_weight_bytes(cfg, bytes_per_param)
    return max(0, int(usable // eb))


# ---------------------------------------------------------------------------
# Strategies
# ---------------------------------------------------------------------------


def place_by_popularity(profile: ExpertProfile, budget: int) -> Placement:
    """Greedy: the `budget` most popular (layer, expert) pairs, ranked by
    per-layer routing probability.  Every token visits every layer, so this
    maximises the expected hit rate (and coincides with the paper's raw
    count ranking when per-layer totals are uniform, which they are for
    real routing traces)."""
    L, E = profile.counts.shape
    flat = profile.probabilities().reshape(-1)
    order = np.argsort(-flat, kind="stable")
    on = np.zeros(L * E, bool)
    on[order[: min(budget, L * E)]] = True
    return Placement(on.reshape(L, E))


def place_by_popularity_devices(profile: ExpertProfile,
                                budget_per_device: int,
                                n_devices: int) -> DevicePlacement:
    """Devices × tiers greedy placement: the ``budget_per_device × D``
    most popular (layer, expert) pairs go fast-tier, assigned to devices
    round-robin in popularity order — each device ends up with exactly
    its budget (±1) and a balanced share of the hot experts."""
    base = place_by_popularity(profile, budget_per_device * n_devices)
    return to_device_placement(base, n_devices, profile=profile)


def place_random(n_layers: int, n_experts: int, budget: int,
                 seed: int = 0) -> Placement:
    rng = np.random.default_rng(seed)
    on = np.zeros(n_layers * n_experts, bool)
    idx = rng.choice(n_layers * n_experts,
                     size=min(budget, n_layers * n_experts), replace=False)
    on[idx] = True
    return Placement(on.reshape(n_layers, n_experts))


def place_worst(profile: ExpertProfile, budget: int) -> Placement:
    """Least-popular placement — the paper's lower bound in App. C."""
    L, E = profile.counts.shape
    flat = profile.probabilities().reshape(-1)
    order = np.argsort(flat, kind="stable")
    on = np.zeros(L * E, bool)
    on[order[: min(budget, L * E)]] = True
    return Placement(on.reshape(L, E))


def place_static_split(n_layers: int, n_experts: int,
                       n_fast_layers: int) -> Placement:
    """llama.cpp-style `ngl`: the first k layers fully resident, the rest
    fully on the slow tier (used by the static_split baseline)."""
    on = np.zeros((n_layers, n_experts), bool)
    on[:n_fast_layers] = True
    return Placement(on)


# ---------------------------------------------------------------------------
# Metrics
# ---------------------------------------------------------------------------


def hit_rate(profile: ExpertProfile, placement: Placement) -> float:
    """Expected probability that a routed expert is fast-tier resident."""
    p = profile.probabilities()  # (L, E)
    per_layer = (p * placement.on_fast).sum(axis=1)
    return float(per_layer.mean())


@dataclass
class PlacementReport:
    budget: int
    best: float
    worst: float
    random: float

    @staticmethod
    def build(profile: ExpertProfile, budget: int,
              seed: int = 0) -> "PlacementReport":
        return PlacementReport(
            budget=budget,
            best=hit_rate(profile, place_by_popularity(profile, budget)),
            worst=hit_rate(profile, place_worst(profile, budget)),
            random=float(budget) / profile.counts.size,
        )
