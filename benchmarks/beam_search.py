"""Paper Figure 6: beam-search inference tokens/s vs llama.cpp-style
static split, widths 4–16, input 32 / output 64.

Fiddler's side is now simulated the way the serving stack actually runs
beams (paged KV, models/paged_kv.py): ONE shared prompt prefill, the
other beams forked as block-table aliases, and every decode step charged
by **unique** block entries (``simulate_decode_multi(kv_unique=...)``) —
the shared prefix streams from memory once — while the per-beam
reshuffle churn is replayed against a real refcounted
:class:`BlockMeta` (copy-on-write included).  The llama.cpp-style
``static_split`` baseline keeps its unbatched per-beam passes with dense
per-beam KV (``simulate_generate(batch=w)``) — the paper's §2.2
"fail to account for batching effects" model.

Also runs a reduced real-numerics beam group through the actual serving
stack (``ContinuousEngine`` + ``FiddlerBackend``, gang-scheduled) and
records its ledger plus unique-vs-dense block counts.

Writes ``BENCH_beam_search.json``:
  results["sim/<env>/w<W>"]  — per-width tokens/s, speedup, block counts
  results["real/..."]        — serving-stack run (reduced numerics)
  summary[env]               — avg/min speedup (the Fig. 6 headline)

CLI: ``--smoke`` (tiny sizes, CI), ``--fast`` (fewer widths).
"""
import argparse
import json

import numpy as np

from benchmarks.common import emit, engine_for
from repro.models.paged_kv import PAGE_SIZE, BlockMeta

WIDTHS = [4, 8, 12, 16]
OUT_PATH = "BENCH_beam_search.json"

# beam reshuffles concentrate on the strongest parents: rank-r beam is
# chosen as a parent with probability ∝ PARENT_DECAY**r
PARENT_DECAY = 0.6


def _sim_beam_paged(engine, prompt_len: int, gen_len: int, width: int,
                    seed: int = 0) -> dict:
    """Simulate one gang-scheduled beam generation with paged-KV
    accounting: shared prompt prefill + forks, per-step reshuffle against
    a real refcounted block table, unique-block KV charging."""
    meta = BlockMeta(width, prompt_len + gen_len, PAGE_SIZE)
    rng = np.random.default_rng(seed)
    parent_p = PARENT_DECAY ** np.arange(width)
    parent_p /= parent_p.sum()

    t0 = engine.ledger.sim_time
    engine.simulate_prefill(prompt_len)          # ONE shared prefill
    meta.write_span(0, 0, prompt_len)
    for j in range(1, width):
        meta.fork_slot(0, j)                     # zero-copy beam creation
    ttft = engine.ledger.sim_time - t0

    max_unique = max_dense = 0
    for step in range(gen_len):
        if step > 0:
            # reshuffle: each slot continues a (popularity-weighted)
            # surviving parent — a table permutation + refcount bumps
            parents = np.sort(rng.choice(width, size=width, p=parent_p))
            meta.reorder_slots(list(range(width)),
                               [int(p) for p in parents])
        pos = prompt_len + step
        for s in range(width):                   # divergent writes → COW
            meta.write_span(s, pos, pos + 1)
        kv_lens = np.full(width, pos + 1, np.int64)
        engine.simulate_decode_multi(kv_lens,
                                     kv_unique=meta.unique_tokens())
        max_unique = max(max_unique, meta.blocks_in_use())
        max_dense = max(max_dense, meta.dense_blocks())
    total = engine.ledger.sim_time - t0
    meta.check()
    return {
        "ttft": ttft,
        "total": total,
        "tokens_per_s": gen_len / total if total else 0.0,
        "itl": (total - ttft) / max(gen_len, 1),
        "unique_blocks": max_unique,
        "dense_blocks": max_dense,
    }


def _real_serving_beam(width: int, n_new: int, smoke: bool) -> dict:
    """A reduced real-numerics beam group through the gang-scheduled
    serving stack (ContinuousEngine over FiddlerBackend, paged KV),
    with a plain request sharing the decode batch."""
    import jax
    import jax.numpy as jnp

    from repro.configs import get_config
    from repro.core import FiddlerEngine, HardwareSpec
    from repro.models import Model
    from repro.serving.backend import FiddlerBackend
    from repro.serving.beam_search import beam_search_slots
    from repro.serving.continuous import ContinuousEngine
    from repro.serving.engine import Request

    full = get_config("mixtral-8x7b")
    cfg = full.reduced()
    model = Model(cfg, param_dtype=jnp.float32)
    params = model.init(jax.random.PRNGKey(0))
    max_seq = 48 if smoke else 64
    fe = FiddlerEngine(cfg, params, policy="fiddler", timing_cfg=full,
                       hw=HardwareSpec.paper_env1(),
                       expert_budget=cfg.n_layers * cfg.moe.n_experts // 4)
    backend = FiddlerBackend(fe, max_seq=max_seq)
    eng = ContinuousEngine(backend, n_slots=width + 1, max_seq=max_seq,
                           prefill_chunk=8)
    # prompt longer than one 16-token page: the full prompt block stays
    # shared whatever the beams do (decode writes never touch it), so
    # unique < dense blocks is structural, not a search-path coincidence
    prompt = [1, 5, 2, 8, 13, 7, 3, 9, 4, 11, 6, 2, 8, 5, 10, 7, 12, 9]
    beam = Request(rid="beam", prompt=prompt, beam_width=width,
                   max_new_tokens=n_new)
    eng.submit(beam)
    eng.submit(Request(rid="plain", prompt=[1, 9, 4], max_new_tokens=n_new))
    eng.run(max_steps=500)
    leaked = sum(int(c.meta.blocks_in_use()) for c in eng.cache)

    # standalone gang kernel on the same engine for the block accounting
    # (the engine releases blocks at retirement, so sample mid-flight here)
    res = beam_search_slots(backend, prompt, width, n_new)
    st = res.block_stats
    return {
        "width": width,
        "n_new": n_new,
        "beam_ttft": beam.ttft,
        "beam_latency": beam.latency,
        "beam_best_score": float(beam.beam_scores[0]),
        "plain_tokens": n_new,
        "sim_time": fe.ledger.sim_time,
        "blocks_leaked_after_run": leaked,
        "unique_blocks": st["unique_blocks"],
        "dense_blocks": st["dense_blocks"],
        "unique_tokens": st["unique_tokens"],
        "dense_tokens": st["dense_tokens"],
    }


def run(model: str = "mixtral-8x7b", envs=("env1", "env2"),
        fast: bool = False, smoke: bool = False, out_path: str = OUT_PATH):
    if smoke:
        widths, prompt_len, gen_len = [2, 4], 16, 12
    elif fast:
        widths, prompt_len, gen_len = WIDTHS[:2], 32, 64
    else:
        widths, prompt_len, gen_len = WIDTHS, 32, 64
    results, summary = {}, {}
    for env in envs:
        ratios = []
        for w in widths:
            fid = engine_for(model, "fiddler", env)
            r_f = _sim_beam_paged(fid, prompt_len, gen_len, w)
            base = engine_for(model, "static_split", env)
            r_s = base.simulate_generate(prompt_len=prompt_len,
                                         gen_len=gen_len, batch=w)
            speedup = r_f["tokens_per_s"] / r_s["tokens_per_s"]
            ratios.append(speedup)
            results[f"sim/{env}/w{w}"] = {
                "fiddler_tok_per_s": r_f["tokens_per_s"],
                "static_tok_per_s": r_s["tokens_per_s"],
                "speedup": speedup,
                "fiddler_itl": r_f["itl"],
                "static_itl": r_s["itl"],
                "unique_blocks": r_f["unique_blocks"],
                "dense_blocks": r_f["dense_blocks"],
            }
            emit(f"beam/{env}/fiddler/w{w}", r_f["itl"] * 1e6,
                 f"tok_per_s={r_f['tokens_per_s']:.2f} "
                 f"unique_blocks={r_f['unique_blocks']} "
                 f"dense_blocks={r_f['dense_blocks']}")
            emit(f"beam/{env}/static_split/w{w}", r_s["itl"] * 1e6,
                 f"tok_per_s={r_s['tokens_per_s']:.2f}")
        avg = sum(ratios) / len(ratios)
        emit(f"beam/{env}/avg_speedup", avg,
             f"{avg:.2f}x mean over widths {widths} "
             f"(paper: 11.57x avg vs llama.cpp)")
        summary[env] = {"avg_speedup": avg, "min_speedup": min(ratios),
                        "widths": widths}
    real = _real_serving_beam(width=2 if smoke else 4,
                              n_new=4 if smoke else 12, smoke=smoke)
    results["real/serving_beam_group"] = real
    emit("beam/real/unique_vs_dense_blocks", real["unique_blocks"],
         f"dense={real['dense_blocks']} (reduced numerics, paged KV)")
    payload = {
        "_meta": {
            "mode": "smoke" if smoke else ("fast" if fast else "full"),
            "model": model,
            "prompt_len": prompt_len,
            "gen_len": gen_len,
            "block_size": PAGE_SIZE,
            "kv_charging": "unique-block (paged); baseline dense per-beam",
        },
        "results": results,
        "summary": summary,
    }
    with open(out_path, "w") as f:
        json.dump(payload, f, indent=1, sort_keys=True)
        f.write("\n")
    return {env: s["avg_speedup"] for env, s in summary.items()}


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="tiny sizes for CI (seconds + one reduced "
                         "real-numerics serving run)")
    ap.add_argument("--fast", action="store_true")
    ap.add_argument("--out", default=OUT_PATH)
    a = ap.parse_args()
    print(json.dumps(run(fast=a.fast, smoke=a.smoke, out_path=a.out),
                     indent=1))
