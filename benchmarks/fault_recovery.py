"""Chaos benchmark: serving under injected faults (docs/resilience.md).

Paper-scale Mixtral-8x7B pure simulation (the ``SimulatedBackend``
ledger path — no weights, real scheduler/planner) under a seeded
:class:`~repro.core.faults.FaultInjector` arming *every* fault kind —
host worker stalls/crashes, link stalls, lost/corrupt prefetch
transfers, latency spikes, and KV block-pool pressure spikes — at a
swept per-tick rate, against the fault-free control.

Standing gates (asserted by the CI ``chaos-smoke`` lane on the summary
block this file writes):

* **completion** — every request finishes under every swept fault rate;
  recovery (watchdog retry, degraded SLOW→stream routing, KV-pressure
  evict→requeue) must never drop work.
* **zero leaks** — the paged-KV pool ends every run with zero blocks in
  use and zero still-reserved by the injector (``BlockMeta.check`` also
  runs, so refcount conservation is verified, not just the totals).
* **bounded degradation** — faulty throughput at the ≥5% rate stays
  within ``DEGRADE_FACTOR``× of fault-free (the defenses degrade
  gracefully instead of collapsing).

Results land in ``BENCH_fault_recovery.json``; rows are also emitted in
the ``name,us_per_call,derived`` CSV format.
"""
from __future__ import annotations

import json
from pathlib import Path
from typing import Dict, List

import numpy as np

from benchmarks.common import ENVS, emit
from benchmarks.serve_load import poisson_requests
from repro.configs import get_config
from repro.core import FiddlerEngine
from repro.core.faults import FAULT_KINDS, FaultInjector
from repro.serving.backend import SimulatedBackend
from repro.serving.continuous import ContinuousEngine

MAX_SEQ = 256
PREFILL_CHUNK = 16
N_SLOTS = 4
REBALANCE_INTERVAL = 32
DEGRADE_FACTOR = 2.0       # max fault-free/faulty throughput ratio (gate)
GATE_RATE = 0.05           # the acceptance-criterion fault rate
RESULTS_JSON = Path(__file__).resolve().parents[1] / \
    "BENCH_fault_recovery.json"


def chaos_once(model: str, env: str, *, fault_rate: float, seed: int,
               rate_hz: float, n_requests: int, prompt_len: int = 64,
               max_new: int = 24) -> Dict[str, float]:
    """One seeded serving run at ``fault_rate`` per tick per fault kind
    (0.0 = the fault-free control, injector detached)."""
    cfg = get_config(model)
    faults = (FaultInjector(seed=seed,
                            rates={k: fault_rate for k in FAULT_KINDS})
              if fault_rate > 0 else None)
    eng = FiddlerEngine(cfg, policy="fiddler", hw=ENVS[env], seed=seed,
                        faults=faults,
                        rebalance_interval=REBALANCE_INTERVAL)
    serving = ContinuousEngine(SimulatedBackend(eng, max_seq=MAX_SEQ),
                               n_slots=N_SLOTS, max_seq=MAX_SEQ,
                               prefill_chunk=PREFILL_CHUNK)
    for r in poisson_requests(rate_hz, n_requests, prompt_len=prompt_len,
                              max_new=max_new, seed=seed):
        serving.submit(r)
    done = serving.run(max_steps=200_000, on_exhausted="raise")
    led = eng.ledger
    meta = serving.cache["meta"]
    meta.check()   # refcount conservation, not just the totals below
    n_tokens = sum(len(r.output) for r in done)
    ttfts = [r.ttft for r in done]
    out = {
        "fault_rate": fault_rate,
        "completed": float(len(done)),
        "submitted": float(n_requests),
        "completion_frac": len(done) / n_requests,
        "throughput_tok_per_s": (n_tokens / led.sim_time
                                 if led.sim_time else 0.0),
        "mean_ttft": float(np.mean(ttfts)),
        "p95_ttft": float(np.percentile(ttfts, 95)),
        "leaked_blocks": float(meta.blocks_in_use()),
        "reserved_blocks": float(meta.n_reserved),
        "preemptions": float(sum(r.preemptions for r in done)),
        "degraded_steps": float(led.degraded_steps),
        "retries": float(led.retries),
        "fault_time_s": led.fault_time,
        "fault_exposed_s": led.fault_exposed,
        "breaker_trips": float(eng.link_breaker.trips),
        "health_trips": float(eng.host_health.trips),
    }
    if faults is not None:
        for kind, n in faults.stats()["injected"].items():
            out[f"injected_{kind}"] = float(n)
        out["injected_total"] = float(
            sum(faults.stats()["injected"].values()))
    return out


def run(fast: bool = True, smoke: bool = False) -> Dict[str, Dict]:
    model, env = "mixtral-8x7b", "env1"
    if smoke:
        fault_rates = [0.0, GATE_RATE]
        seeds = [0]
        n_requests, rate_hz = 8, 16.0
    elif fast:
        fault_rates = [0.0, GATE_RATE, 0.15]
        seeds = [0, 1]
        n_requests, rate_hz = 16, 16.0
    else:
        fault_rates = [0.0, GATE_RATE, 0.15]
        seeds = [0, 1, 2]
        n_requests, rate_hz = 32, 16.0

    results: Dict[str, Dict] = {}
    by_rate: Dict[float, List[Dict]] = {}
    for rate in fault_rates:
        for seed in seeds:
            r = chaos_once(model, env, fault_rate=rate, seed=seed,
                           rate_hz=rate_hz, n_requests=n_requests)
            key = f"fault_recovery/{env}/fiddler/rate{rate:g}_seed{seed}"
            emit(key, r["mean_ttft"] * 1e6,
                 f"tok_per_s={r['throughput_tok_per_s']:.2f} "
                 f"done={r['completed']:.0f}/{r['submitted']:.0f} "
                 f"leaked={r['leaked_blocks']:.0f} "
                 f"retries={r['retries']:.0f} "
                 f"degraded={r['degraded_steps']:.0f} "
                 f"injected={r.get('injected_total', 0.0):.0f}")
            results[key] = r
            by_rate.setdefault(rate, []).append(r)

    # -- standing gates ------------------------------------------------------
    baseline = float(np.mean([r["throughput_tok_per_s"]
                              for r in by_rate[0.0]]))
    gate_tput = min(r["throughput_tok_per_s"] for r in by_rate[GATE_RATE])
    degrade = baseline / gate_tput if gate_tput else float("inf")
    summary = {
        "all_complete": all(r["completion_frac"] == 1.0
                            for rs in by_rate.values() for r in rs),
        "zero_leaks": all(r["leaked_blocks"] == 0.0
                          and r["reserved_blocks"] == 0.0
                          for rs in by_rate.values() for r in rs),
        "faults_injected": all(r.get("injected_total", 0.0) > 0
                               for rate, rs in by_rate.items()
                               if rate > 0 for r in rs),
        "baseline_tok_per_s": baseline,
        "gate_rate": GATE_RATE,
        "gate_tok_per_s": gate_tput,
        "degrade_factor": degrade,
        "degrade_factor_limit": DEGRADE_FACTOR,
        "degraded_within_limit": degrade <= DEGRADE_FACTOR,
    }
    record = {
        "_meta": {
            "mode": "smoke" if smoke else ("fast" if fast else "full"),
            "model": model, "env": env,
            "fault_rates": fault_rates, "seeds": seeds,
            "n_requests": n_requests, "rate_hz": rate_hz,
            "fault_kinds": list(FAULT_KINDS),
        },
        "summary": summary,
        "results": results,
    }
    RESULTS_JSON.write_text(json.dumps(record, indent=2, sort_keys=True))
    print(f"summary: all_complete={summary['all_complete']} "
          f"zero_leaks={summary['zero_leaks']} "
          f"degrade_factor={degrade:.3f} "
          f"(limit {DEGRADE_FACTOR})")
    assert summary["all_complete"], "requests dropped under faults"
    assert summary["zero_leaks"], "paged-KV blocks leaked"
    assert summary["degraded_within_limit"], (
        f"degraded throughput {gate_tput:.2f} tok/s is more than "
        f"{DEGRADE_FACTOR}x below fault-free {baseline:.2f} tok/s")
    return results


if __name__ == "__main__":
    import argparse

    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--full", action="store_true",
                    help="full sweep (default is the fast dev subset)")
    ap.add_argument("--smoke", action="store_true",
                    help="CI chaos-smoke lane: minimal sweep")
    a = ap.parse_args()
    run(fast=not a.full, smoke=a.smoke)
