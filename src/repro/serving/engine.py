"""Batched serving engine: request queue → grouped prefill + decode.

Requests are grouped into static batches (padded prompts), prefilled once,
then decoded until EOS/max-tokens.  Works over the monolithic jitted
``Model`` (capacity-sufficient regime) or over the ``FiddlerEngine``
orchestrator (fast/slow-tier regime — the paper's setting).  Per-request
TTFT/ITL are recorded from the engine's simulated clock when orchestrated,
or wall-clock otherwise.
"""
from __future__ import annotations

import time
from dataclasses import dataclass, field
from typing import Any, Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.data.tokenizer import EOS_ID, PAD_ID
from repro.serving.sampler import greedy, sample


@dataclass
class Request:
    rid: str
    prompt: List[int]
    max_new_tokens: int = 32
    temperature: float = 0.0
    # outputs
    output: List[int] = field(default_factory=list)
    ttft: Optional[float] = None
    latency: Optional[float] = None


class ServingEngine:
    def __init__(self, backend, *, mode: str = "model", params=None,
                 max_batch: int = 8, max_seq: int = 512, seed: int = 0):
        """backend: a ``Model`` (mode="model") or ``FiddlerEngine``
        (mode="fiddler")."""
        assert mode in ("model", "fiddler")
        self.mode = mode
        self.backend = backend
        self.params = params
        self.max_batch = max_batch
        self.max_seq = max_seq
        self.queue: List[Request] = []
        self.key = jax.random.PRNGKey(seed)
        if mode == "model":
            self._prefill = jax.jit(
                lambda p, t: backend.prefill(p, t, max_seq))
            self._decode = jax.jit(
                lambda p, c, t, pos: backend.decode_step(p, c, t, pos, max_seq))

    def submit(self, req: Request) -> None:
        self.queue.append(req)

    # ------------------------------------------------------------------
    def _clock(self) -> float:
        if self.mode == "fiddler":
            return self.backend.ledger.sim_time
        return time.perf_counter()

    def _run_group(self, group: List[Request]) -> None:
        B = len(group)
        S = max(len(r.prompt) for r in group)
        prompts = np.full((B, S), PAD_ID, np.int32)
        for i, r in enumerate(group):
            prompts[i, S - len(r.prompt):] = r.prompt  # left-pad
        t0 = self._clock()
        if self.mode == "model":
            logits, cache = self._prefill(self.params, jnp.asarray(prompts))
        else:
            logits, cache = self.backend.prefill(jnp.asarray(prompts),
                                                 self.max_seq)
        t_first = self._clock()
        for r in group:
            r.ttft = t_first - t0

        done = np.zeros(B, bool)
        n_steps = min(max(r.max_new_tokens for r in group),
                      self.max_seq - S)
        for step in range(n_steps):
            if group[0].temperature > 0:
                self.key, sub = jax.random.split(self.key)
                tok = sample(logits, sub, group[0].temperature)
            else:
                tok = greedy(logits)
            for i, r in enumerate(group):
                if not done[i]:
                    r.output.append(int(tok[i]))
                    if tok[i] == EOS_ID or len(r.output) >= r.max_new_tokens:
                        done[i] = True
            if done.all():
                break
            pos = S + step
            if self.mode == "model":
                logits, cache = self._decode(self.params, cache,
                                             jnp.asarray(tok[:, None]),
                                             jnp.int32(pos))
            else:
                logits, cache = self.backend.decode_step(
                    cache, jnp.asarray(tok[:, None]), pos, self.max_seq)
        t_end = self._clock()
        for r in group:
            r.latency = t_end - t0

    def run(self) -> List[Request]:
        """Drain the queue in static batches of ≤ max_batch."""
        finished: List[Request] = []
        while self.queue:
            group = self.queue[: self.max_batch]
            self.queue = self.queue[self.max_batch:]
            self._run_group(group)
            finished.extend(group)
        return finished
