"""MoE layer: router conservation, capacity dispatch vs a naive loop
oracle, ref-vs-sharded equivalence on a trivial mesh."""
import jax
import jax.numpy as jnp
import numpy as np
from hypothesis import given, settings, strategies as st

from repro.configs import get_config
from repro.models.moe import (
    capacity_for,
    dispatch_compute_combine,
    expert_ranks,
    init_moe,
    moe_block_ref,
    moe_block_sharded,
    route,
)


def _cfg():
    return get_config("mixtral-8x7b").reduced()


def test_router_conservation():
    cfg = _cfg()
    params = init_moe(jax.random.PRNGKey(0), cfg)
    x = jax.random.normal(jax.random.PRNGKey(1), (64, cfg.d_model))
    gates, idx, stats = route(params["router"], x, cfg.moe)
    assert gates.shape == (64, cfg.moe.top_k)
    assert idx.shape == (64, cfg.moe.top_k)
    # every token routed to exactly top_k distinct experts
    for row in np.asarray(idx):
        assert len(set(row.tolist())) == cfg.moe.top_k
    np.testing.assert_allclose(np.asarray(gates).sum(-1), 1.0, rtol=1e-5)
    counts = np.asarray(stats["expert_counts"])
    assert counts.sum() == 64 * cfg.moe.top_k


@given(st.lists(st.integers(0, 7), min_size=1, max_size=200))
@settings(max_examples=100, deadline=None)
def test_expert_ranks_property(ids):
    e = jnp.asarray(ids, jnp.int32)
    ranks = np.asarray(expert_ranks(e))
    seen = {}
    for i, ei in enumerate(ids):
        assert ranks[i] == seen.get(ei, 0)
        seen[ei] = seen.get(ei, 0) + 1


def test_dispatch_matches_naive_loop():
    E, d, f, k = 4, 32, 64, 2
    key = jax.random.PRNGKey(5)
    ks = jax.random.split(key, 5)
    T = 40
    x = jax.random.normal(ks[0], (T, d)) * 0.2
    wg = jax.random.normal(ks[1], (E, d, f)) * 0.1
    wu = jax.random.normal(ks[2], (E, d, f)) * 0.1
    wd = jax.random.normal(ks[3], (E, f, d)) * 0.1
    idx = jax.random.randint(ks[4], (T, k), 0, E)
    gates = jnp.full((T, k), 0.5)
    out = dispatch_compute_combine(x, gates, idx, wg, wu, wd,
                                   capacity=T * k, e_offset=jnp.int32(0))
    # naive per-token oracle
    want = np.zeros((T, d), np.float32)
    xn = np.asarray(x)
    for t in range(T):
        for j in range(k):
            e = int(idx[t, j])
            h = (xn[t] @ np.asarray(wg[e]))
            h = h / (1 + np.exp(-h)) * (xn[t] @ np.asarray(wu[e]))
            want[t] += 0.5 * (h @ np.asarray(wd[e]))
    np.testing.assert_allclose(np.asarray(out), want, rtol=2e-4, atol=2e-4)


def test_capacity_drop_behaviour():
    """Tokens over capacity are dropped (contribute zero), never mis-routed."""
    E, d, f = 2, 8, 16
    ks = jax.random.split(jax.random.PRNGKey(0), 4)
    T = 16
    x = jax.random.normal(ks[0], (T, d))
    wg = jax.random.normal(ks[1], (E, d, f)) * 0.1
    wu = jax.random.normal(ks[2], (E, d, f)) * 0.1
    wd = jax.random.normal(ks[3], (E, f, d)) * 0.1
    idx = jnp.zeros((T, 1), jnp.int32)  # everyone → expert 0
    gates = jnp.ones((T, 1))
    out = dispatch_compute_combine(x, gates, idx, wg, wu, wd,
                                   capacity=4, e_offset=jnp.int32(0))
    out = np.asarray(out)
    assert np.abs(out[:4]).sum() > 0
    np.testing.assert_array_equal(out[4:], 0.0)


def test_ref_vs_sharded_trivial_mesh():
    """moe_block_sharded on a 1×1 mesh ≡ moe_block_ref."""
    cfg = _cfg()
    params = init_moe(jax.random.PRNGKey(0), cfg)
    x = jax.random.normal(jax.random.PRNGKey(1), (2, 8, cfg.d_model)) * 0.3
    ref_out, ref_stats = moe_block_ref(params, x, cfg, kind="decode")
    from repro.launch.mesh import make_debug_mesh

    mesh = make_debug_mesh()
    sh_out, sh_stats = moe_block_sharded(params, x, cfg, mesh, ("data",),
                                         "model", kind="decode")
    np.testing.assert_allclose(np.asarray(ref_out), np.asarray(sh_out),
                               rtol=1e-5, atol=1e-5)
    np.testing.assert_allclose(float(ref_stats["aux_loss"]),
                               float(sh_stats["aux_loss"]), rtol=1e-5)


def test_capacity_for_rules():
    cfg = get_config("mixtral-8x7b")
    m = cfg.moe
    # decode: drop-free
    assert capacity_for(8, m, "decode", m.n_experts) == 16
    # train: capacity-factor based, multiple of 8
    c = capacity_for(65536, m, "train", m.n_experts)
    assert c % 8 == 0
    assert c >= m.capacity_factor * 65536 * m.top_k / m.n_experts


def test_shared_expert_applied():
    cfg = get_config("kimi-k2-1t-a32b").reduced()
    assert cfg.moe.n_shared_experts == 1
    params = init_moe(jax.random.PRNGKey(0), cfg)
    x = jax.random.normal(jax.random.PRNGKey(1), (1, 4, cfg.d_model)) * 0.3
    out, _ = moe_block_ref(params, x, cfg, kind="decode")
    # zero out shared expert → output must change
    p2 = dict(params)
    p2["shared"] = jax.tree.map(jnp.zeros_like, params["shared"])
    out2, _ = moe_block_ref(p2, x, cfg, kind="decode")
    assert float(jnp.abs(out - out2).max()) > 1e-6
