"""Continuous batching: a fixed pool of decode slots, each at its own
position; requests join as slots free up (prefill into the slot) and
leave on EOS/max-tokens — no head-of-line blocking like the static
grouped engine.

Single-host serving path (jitted Model; per-slot cache writes are
scatter-based, see kv_cache.write_decode_multi).
"""
from __future__ import annotations

import time
from dataclasses import dataclass, field
from typing import Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.data.tokenizer import EOS_ID, PAD_ID
from repro.serving.engine import Request
from repro.serving.sampler import greedy


@dataclass
class _Slot:
    req: Optional[Request] = None
    pos: int = 0          # next decode position
    last_token: int = 0
    steps_left: int = 0


class ContinuousEngine:
    def __init__(self, model, params, *, n_slots: int = 4,
                 max_seq: int = 256):
        self.model = model
        self.params = params
        self.n_slots = n_slots
        self.max_seq = max_seq
        self.queue: List[Request] = []
        self.slots = [_Slot() for _ in range(n_slots)]
        self.cache = model.make_cache(n_slots, max_seq, dtype=jnp.float32)
        self._decode = jax.jit(
            lambda p, c, t, pos: model.decode_step_multi(p, c, t, pos,
                                                         max_seq))
        self._prefill1 = jax.jit(
            lambda p, t: model.prefill(p, t, max_seq,
                                       cache_dtype=jnp.float32))
        self.steps = 0
        self.finished: List[Request] = []

    # ------------------------------------------------------------------
    def submit(self, req: Request) -> None:
        self.queue.append(req)

    def _admit(self) -> None:
        for i, slot in enumerate(self.slots):
            if slot.req is not None or not self.queue:
                continue
            req = self.queue.pop(0)
            prompt = jnp.asarray([req.prompt], jnp.int32)
            logits, slot_cache = self._prefill1(self.params, prompt)
            self.cache = self.model.write_slot(self.cache, slot_cache, i)
            tok = int(jnp.argmax(logits[0]))
            req.output.append(tok)
            req.ttft = float(self.steps)  # in engine steps
            slot.req = req
            slot.pos = len(req.prompt)
            slot.last_token = tok
            slot.steps_left = req.max_new_tokens - 1
            if tok == EOS_ID or slot.steps_left <= 0:
                self._retire(i)

    def _retire(self, i: int) -> None:
        slot = self.slots[i]
        if slot.req is not None:
            slot.req.latency = float(self.steps)
            self.finished.append(slot.req)
        self.slots[i] = _Slot()

    @property
    def active(self) -> int:
        return sum(1 for s in self.slots if s.req is not None)

    def step(self) -> None:
        """One decode step for every active slot (idle slots decode a pad
        token at position 0 and are masked out)."""
        self._admit()
        if self.active == 0:
            return
        tokens = np.full((self.n_slots, 1), PAD_ID, np.int32)
        pos = np.zeros((self.n_slots,), np.int32)
        for i, s in enumerate(self.slots):
            if s.req is not None:
                tokens[i, 0] = s.last_token
                pos[i] = s.pos
        logits, self.cache = self._decode(self.params, self.cache,
                                          jnp.asarray(tokens),
                                          jnp.asarray(pos))
        next_tok = greedy(logits)
        self.steps += 1
        for i, s in enumerate(self.slots):
            if s.req is None:
                continue
            tok = int(next_tok[i])
            s.req.output.append(tok)
            s.pos += 1
            s.last_token = tok
            s.steps_left -= 1
            if tok == EOS_ID or s.steps_left <= 0 or s.pos >= self.max_seq - 1:
                self._retire(i)

    def run(self, max_steps: int = 10_000) -> List[Request]:
        while (self.queue or self.active) and self.steps < max_steps:
            self.step()
        return self.finished
