"""Pure-jnp oracles for every kernel in this package.

These are the single source of truth for kernel semantics; Pallas kernels
(interpret=True on CPU) and the host (numpy) kernel are asserted allclose
against these in tests/test_kernels.py.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


def expert_mlp_ref(x: jnp.ndarray, w_gate: jnp.ndarray, w_up: jnp.ndarray,
                   w_down: jnp.ndarray) -> jnp.ndarray:
    """Gated SiLU MLP of one expert: (silu(xWg) ⊙ xWu) Wd.

    x: (s, d); w_gate/w_up: (d, f); w_down: (f, d).  fp32 accumulation.
    """
    xf = x.astype(jnp.float32)
    h = jax.nn.silu(xf @ w_gate.astype(jnp.float32))
    h = h * (xf @ w_up.astype(jnp.float32))
    return (h @ w_down.astype(jnp.float32)).astype(x.dtype)


def moe_gmm_ref(xs: jnp.ndarray, ws: jnp.ndarray,
                counts: jnp.ndarray) -> jnp.ndarray:
    """Grouped matmul: out[e] = xs[e] @ ws[e], rows ≥ counts[e] zeroed.

    xs: (E, C, d); ws: (E, d, f); counts: (E,) int32 → (E, C, f).
    """
    out = jnp.einsum("ecd,edf->ecf", xs.astype(jnp.float32),
                     ws.astype(jnp.float32))
    mask = jnp.arange(xs.shape[1])[None, :, None] < counts[:, None, None]
    return jnp.where(mask, out, 0.0).astype(xs.dtype)


def grouped_gated_mlp_ref(xs: jnp.ndarray, w_gate: jnp.ndarray,
                          w_up: jnp.ndarray, w_down: jnp.ndarray,
                          counts: jnp.ndarray | None = None) -> jnp.ndarray:
    """Grouped gated SiLU MLP: out[e] = expert_mlp_ref(xs[e], ...) with
    rows ≥ counts[e] zeroed — one fused call for a whole capacity-bucketed
    MoE dispatch buffer (the orchestrator's fast-tier hot path).

    xs: (E, C, d); w_gate/w_up: (E, d, f); w_down: (E, f, d);
    counts: (E,) int32 → (E, C, d); ``counts=None`` means every expert
    uses all C rows (the orchestrator's uniform count-class launches).
    fp32 accumulation.

    Deliberately ``lax.map`` over experts with a ``lax.switch`` over the
    C+1 possible row counts, so each expert's GEMMs run at **exactly its
    true row count** — not the padded capacity.  This is what makes every
    per-expert slice *bit-identical* to :func:`expert_mlp_ref`, the
    equivalence the orchestrator's grouped dispatch is tested against:
    XLA's CPU GEMM picks kernels (and reduction orders) that depend on
    the row dimension M, so both a batched (E, C, ·) dot_general and a
    padded-to-C 2D GEMM would perturb results at the ~1e-7 level.  Still
    one kernel launch from the host's perspective; the switch costs C+1
    compiled branches per (E, C) signature — callers keep C small (the
    orchestrator buckets decode-sized capacities and dispatches large
    uniform row counts through the ``counts=None`` form, which compiles
    a single branch).
    """
    if counts is None:
        return jax.lax.map(lambda a: expert_mlp_ref(*a),
                           (xs, w_gate, w_up, w_down))

    C = xs.shape[1]

    def one(args):
        x, wg, wu, wd, n = args

        def branch(m):
            def f(_):
                if m == 0:
                    return jnp.zeros_like(x)
                y = expert_mlp_ref(x[:m], wg, wu, wd)
                return jnp.zeros_like(x).at[:m].set(y)
            return f

        return jax.lax.switch(jnp.clip(n, 0, C),
                              [branch(m) for m in range(C + 1)], None)

    return jax.lax.map(one, (xs, w_gate, w_up, w_down, counts))


def flash_attention_ref(q: jnp.ndarray, k: jnp.ndarray, v: jnp.ndarray,
                        *, causal: bool = True,
                        window: int | None = None,
                        attn_softcap: float | None = None) -> jnp.ndarray:
    """Reference multi-head attention. q/k/v: (B, S, H, hd) (same H)."""
    B, S, H, hd = q.shape
    scale = 1.0 / jnp.sqrt(jnp.float32(hd))
    s = jnp.einsum("bqhd,bkhd->bhqk", q.astype(jnp.float32),
                   k.astype(jnp.float32)) * scale
    if attn_softcap is not None:
        s = attn_softcap * jnp.tanh(s / attn_softcap)
    iq = jnp.arange(S)[:, None]
    ik = jnp.arange(S)[None, :]
    mask = jnp.ones((S, S), bool)
    if causal:
        mask &= ik <= iq
    if window is not None:
        mask &= ik > iq - window
    s = jnp.where(mask[None, None], s, -1e30)
    p = jax.nn.softmax(s, axis=-1)
    out = jnp.einsum("bhqk,bkhd->bqhd", p, v.astype(jnp.float32))
    return out.astype(q.dtype)
