"""Pluggable scheduling policies for the serving engines.

Fiddler's orchestrator wins by handling *every* serving scenario with one
execution engine; this module gives the scheduling layer the same shape.
Admission order, preemption victims, and the live decode-slot count are
decided by a ``SchedulerPolicy`` instead of hard-coded FIFO loops inside
``ContinuousEngine``/``ServingEngine``.

The contract
------------
Each engine step the engine builds a read-only :class:`SchedulerView` —
the queue (with per-request ``priority``/``slo_class``/``deadline``), the
slot states, the backend clock, and an EWMA arrival-rate estimate — and
asks the policy for a :class:`StepPlan` via :meth:`SchedulerPolicy.plan`:
admission order, preemption victims, the live-pool target, which slots
prefill / decode this tick, per-slot prefill chunk sizes, and whether the
two phases run as overlapping streams.  The default ``plan`` is built
from the three legacy hooks, so a policy written against the old
protocol schedules identically:

* :meth:`SchedulerPolicy.admission_order` — which queued requests may be
  admitted this step, in order.  Returning an index whose request has not
  arrived yet is ignored by the engine; *omitting* arrived indices is how
  a policy expresses head-of-line blocking (see :class:`FIFOPolicy`).
* :meth:`SchedulerPolicy.preempt` — decode-slot indices to evict.  The
  engine returns each victim to the queue carrying its generated tokens;
  re-admission re-prefills prompt + emitted tokens through the (chunked)
  prefill path, so under greedy decoding a preempted request's final
  output is identical to its unpreempted output.
* :meth:`SchedulerPolicy.target_slots` — desired live-pool size.  The
  engine clamps to ``[1, max_slots]``, grows the backend cache via
  ``ServingBackend.resize_cache`` when needed, and only ever *admits*
  into slots below the limit (shrinking drains, it never kills work).

Policies must be pure functions of the view (the engine may call them
more than once per step); state that must persist across steps — e.g.
the arrival-rate EWMA — lives in the engine and is surfaced through the
view.

Beam groups are *gangs*: ``QueueView.width`` is the number of slots a
queued request needs at once, and ``SlotView.gang``/``gang_size`` mark
slots that belong to one group.  The engine enforces gang mechanics —
all-or-nothing admission, atomic whole-group eviction when any member is
named a victim — so policies only need widths for capacity arithmetic
(see :meth:`PriorityPolicy.preempt`).

Shipped policies
----------------
* :class:`FIFOPolicy` — exact pre-redesign behavior (the default).
* :class:`PriorityPolicy` — deadline/SLO classes ahead of FIFO,
  preempting the longest-running lower-priority decode when a
  higher-priority arrival is waiting without a free slot; optional
  starvation aging (``aging_time``) promotes long-waiting batch work
  into the interactive tier so no request waits unboundedly.
* :class:`AutoscalePolicy` — sizes the live slot pool against the
  arrival-rate EWMA (Little's law with a configurable service-time
  estimate).
* :class:`RooflinePolicy` — prefill/decode disaggregation: prefill
  chunks sized from the backend's :class:`CostView` to saturate the
  compute roofline (prefill is compute-bound), the decode gang batched
  as the memory-bound stream, and the two run as overlapping streams
  (``StepPlan.overlap``) with the ledger splitting overlapped vs
  exposed time per stream.
"""
from __future__ import annotations

import math
from dataclasses import dataclass, field
from typing import Any, Dict, Mapping, Optional, Sequence, Tuple

# SLO class → default priority when a request does not set one explicitly.
# Higher is more urgent.  Unknown classes fall back to "standard".
SLO_CLASSES = {
    "batch": 0,
    "standard": 1,
    "interactive": 2,
}


def slo_priority(slo_class: str) -> int:
    return SLO_CLASSES.get(slo_class, SLO_CLASSES["standard"])


@dataclass(frozen=True)
class CostView:
    """Per-phase roofline constants a backend exposes to policies
    (``ServingBackend.cost_view``): enough to place prefill and decode on
    the measured compute/bandwidth roofline without the policy knowing
    model internals.  ``None`` from a backend means "no cost model" (the
    wall-clock ``ModelBackend``) — policies must degrade gracefully."""
    gpu_const: float          # one expert's HBM weight-read floor (s)
    gpu_per_token: float      # compute seconds per expert input token
    n_experts: int
    top_k: int
    fast_flops: float
    fast_mem_bw: float

    def saturation_tokens(self) -> float:
        """Per-expert input size where compute time reaches the
        weight-read floor — the compute/bandwidth roofline knee."""
        return self.gpu_const / max(self.gpu_per_token, 1e-30)

    def prefill_chunk_tokens(self) -> int:
        """Prefill chunk that saturates the compute roofline: a chunk of
        ``n`` tokens puts ~``n * top_k / n_experts`` tokens on each
        active expert, so the knee is reached at
        ``saturation_tokens * n_experts / top_k``.  Below this, prefill
        is paying decode's memory-bound weight-read price."""
        return max(1, math.ceil(self.saturation_tokens()
                                * self.n_experts / max(self.top_k, 1)))


@dataclass(frozen=True)
class StepPlan:
    """One scheduler tick's decisions, returned by
    :meth:`SchedulerPolicy.plan`.

    ``admit``/``preempt``/``target_slots`` carry the legacy three-hook
    semantics.  ``prefill``/``decode`` name the slot indices that run
    each phase this tick (``None`` = every eligible slot — the legacy
    interleaved behavior).  ``chunk_sizes`` overrides the engine's
    prefill chunk per slot.  ``overlap=True`` runs decode as the
    foreground stream and hides prefill charges under it (backends with
    a simulated clock charge the two streams separately — see
    ``Ledger.prefill_stream_time``/``decode_stream_time``)."""
    admit: Tuple[int, ...] = ()
    preempt: Tuple[int, ...] = ()
    target_slots: Optional[int] = None   # None = keep the current pool
    prefill: Optional[Tuple[int, ...]] = None
    decode: Optional[Tuple[int, ...]] = None
    chunk_sizes: Mapping[int, int] = field(default_factory=dict)
    overlap: bool = False


@dataclass(frozen=True)
class PolicySpec:
    """Structured policy spec for :func:`get_policy`: a registry name
    plus constructor options — what launchers/benchmarks build
    programmatically instead of ad-hoc strings."""
    name: str
    options: Mapping[str, Any] = field(default_factory=dict)


@dataclass(frozen=True)
class QueueView:
    """Read-only snapshot of one queued request."""
    index: int                   # position in the engine queue
    rid: str
    arrival: Optional[float]     # backend-clock arrival (None = already due)
    priority: int                # resolved priority (explicit or SLO class)
    slo_class: str
    deadline: Optional[float]    # absolute backend-clock deadline
    prompt_len: int
    max_new_tokens: int
    emitted: int                 # >0 means a preempted request awaiting resume
    width: int = 1               # decode slots the request needs at once
    #                              (beam groups: gang admission — all
    #                              ``width`` slots or none)
    phase: str = "prefill"       # prefill | resume (preempted, re-prefilling
    #                              prompt + emitted on re-admission)
    remaining_prefill: Optional[int] = None  # tokens still to prefill once
    #                              admitted (prompt + emitted); None = unknown

    def arrived(self, clock: float) -> bool:
        return self.arrival is None or self.arrival <= clock

    @classmethod
    def from_request(cls, index: int, req) -> "QueueView":
        """Snapshot a ``serving.engine.Request`` at queue position
        ``index`` (single point where Request fields map to the view)."""
        emitted = len(req.output)
        return cls(index=index, rid=req.rid, arrival=req.arrival,
                   priority=req.effective_priority, slo_class=req.slo_class,
                   deadline=req.deadline, prompt_len=len(req.prompt),
                   max_new_tokens=req.max_new_tokens,
                   emitted=emitted,
                   width=getattr(req, "beam_width", 1),
                   phase="resume" if emitted else "prefill",
                   remaining_prefill=len(req.prompt) + emitted)


@dataclass(frozen=True)
class SlotView:
    """Read-only snapshot of one decode slot."""
    index: int
    rid: Optional[str]           # None = free slot
    phase: str                   # idle | prefill | decode
    priority: int
    slo_class: str
    deadline: Optional[float]
    pos: int
    prompt_len: int
    emitted: int
    steps_left: int
    started: Optional[float]     # backend-clock time of admission
    arrival: Optional[float] = None  # request's original arrival (aging)
    remaining_prefill: int = 0   # prompt tokens not yet prefilled (0 once
    #                              the slot reaches the decode phase)
    gang: Optional[str] = None   # beam-group id (rid) this slot belongs to
    gang_size: int = 1           # slots the gang occupies (evicting any
    #                              member frees all of them — the engine
    #                              evicts gangs atomically)

    @property
    def free(self) -> bool:
        return self.rid is None


@dataclass(frozen=True)
class SchedulerView:
    """Everything a policy may look at: queue, slots, clock, load."""
    clock: float
    queue: Tuple[QueueView, ...]
    slots: Tuple[SlotView, ...]  # all allocated slots (live + draining)
    slot_limit: int              # current live-pool size (admittable slots)
    max_slots: int               # hard cap on the pool
    arrival_rate: float          # EWMA req/s of the backend clock (0 = unknown)
    cost: Optional[CostView] = None  # backend roofline constants (None =
    #                              wall-clock backend without a cost model)
    default_chunk: Optional[int] = None  # engine prefill chunk (None =
    #                              whole remaining prompt per tick)

    def arrived_queue(self) -> Tuple[QueueView, ...]:
        return tuple(q for q in self.queue if q.arrived(self.clock))

    def free_live_slots(self) -> int:
        return sum(1 for s in self.slots[: self.slot_limit] if s.free)


class SchedulerPolicy:
    """Base policy: subclasses override :meth:`plan`, or any of the three
    legacy decisions the default ``plan`` is assembled from.

    The legacy defaults are inert — no admissions, no preemption, keep
    the pool at its maximum — so concrete policies state exactly what
    they change.  A policy that only implements the three old hooks
    schedules bit-identically to the pre-``plan`` protocol: the default
    ``plan`` leaves ``prefill``/``decode`` as ``None`` (every eligible
    slot runs both phases interleaved) and ``overlap`` off.
    """

    name = "base"

    def plan(self, view: SchedulerView) -> StepPlan:
        """One tick's full decision set.  The default delegates to the
        legacy three hooks; phase-aware policies override this to name
        separate prefill/decode batches, per-slot chunk sizes, and
        stream overlap."""
        return StepPlan(admit=tuple(self.admission_order(view)),
                        preempt=tuple(self.preempt(view)),
                        target_slots=self.target_slots(view))

    def admission_order(self, view: SchedulerView) -> Sequence[int]:
        """Queue indices to admit, in order.  Non-arrived indices are
        skipped by the engine; arrived-but-omitted indices wait."""
        raise NotImplementedError

    def preempt(self, view: SchedulerView) -> Sequence[int]:
        """Slot indices to evict back to the queue (decode phase only)."""
        return ()

    def target_slots(self, view: SchedulerView) -> int:
        """Desired live-pool size; clamped to [1, max_slots] by the engine."""
        return view.max_slots


class FIFOPolicy(SchedulerPolicy):
    """Exact pre-redesign behavior: admit in queue order, and if the queue
    head has not arrived yet nothing behind it is admitted either
    (head-of-line blocking).  Never preempts, never resizes the pool."""

    name = "fifo"

    def admission_order(self, view: SchedulerView) -> Sequence[int]:
        order = []
        for q in view.queue:
            if not q.arrived(view.clock):
                break  # FIFO: head hasn't arrived yet
            order.append(q.index)
        return order


class PriorityPolicy(SchedulerPolicy):
    """SLO/deadline-aware admission with optional preemption and
    starvation aging.

    Arrived requests are ordered by (priority desc, deadline asc, arrival
    asc) so a higher class never waits behind a lower one.  When a
    higher-priority arrival is waiting and no live slot is free, the
    longest-running strictly-lower-priority decode is evicted; the engine
    re-admits it later via chunked prefill of its prompt + emitted
    tokens, so no token is lost and in-flight decodes never stall behind
    the re-prefill.

    ``aging_time`` bounds starvation: a request that has waited longer
    than it (backend-clock seconds since arrival) is treated as
    ``interactive``-tier for every decision.  An aged batch request then
    sorts ahead of *later-arrived* interactive work (equal priority,
    earlier arrival) so it takes the next free slot, and — because aging
    also applies to the slot side of the preemption test — its decode
    cannot be stolen by fresh interactive arrivals (preemption needs
    *strictly* lower victim priority).  Aging also caps the request's
    effective *deadline* at its aging expiry (``arrival + aging_time``,
    which is already in the past), so deadline-bearing interactive
    traffic cannot sort ahead of it forever either.  Under sustained
    interactive overload every batch request's wait is therefore bounded
    by ``aging_time`` plus one generation length, instead of unbounded
    (the ROADMAP's starvation open item) — assuming sane deadlines
    (``deadline >= arrival``; a request whose deadline predates an aged
    request's expiry is even more overdue and legitimately precedes
    it)."""

    name = "priority"

    def __init__(self, preemption: bool = True,
                 aging_time: Optional[float] = None):
        assert aging_time is None or aging_time > 0, aging_time
        self.preemption = preemption
        self.aging_time = aging_time

    def _aged(self, arrival: Optional[float], clock: float) -> bool:
        return (self.aging_time is not None and arrival is not None
                and clock - arrival >= self.aging_time)

    def _aged_priority(self, priority: int, arrival: Optional[float],
                       clock: float) -> int:
        if self._aged(arrival, clock):
            return max(priority, SLO_CLASSES["interactive"])
        return priority

    def _key(self, q: QueueView, clock: float):
        # an aged request is overdue: its effective deadline is its aging
        # expiry (<= clock, so it precedes any still-future deadline —
        # without this, a stream of deadline-bearing interactive requests
        # would sort ahead of an aged batch request forever)
        deadline = q.deadline if q.deadline is not None else math.inf
        if self._aged(q.arrival, clock):
            deadline = min(deadline, q.arrival + self.aging_time)
        return (-self._aged_priority(q.priority, q.arrival, clock),
                deadline,
                q.arrival if q.arrival is not None else -math.inf,
                q.index)

    def admission_order(self, view: SchedulerView) -> Sequence[int]:
        arrived = sorted(view.arrived_queue(),
                         key=lambda q: self._key(q, view.clock))
        return [q.index for q in arrived]

    def preempt(self, view: SchedulerView) -> Sequence[int]:
        if not self.preemption:
            return ()
        waiters = sorted(view.arrived_queue(),
                         key=lambda q: self._key(q, view.clock))
        if not waiters:
            return ()
        free = view.free_live_slots()

        def slot_prio(s: SlotView) -> int:
            return self._aged_priority(s.priority, s.arrival, view.clock)

        # longest-running first among the lowest (aged) priorities
        candidates = sorted(
            (s for s in view.slots[: view.slot_limit]
             if s.phase == "decode"),
            key=lambda s: (slot_prio(s),
                           s.started if s.started is not None else math.inf))
        victims = []
        taken = set()
        for w in waiters:
            # gang-aware accounting: a beam group needs ``width`` slots
            # at once, and evicting any member of a victim gang frees the
            # whole gang (the engine evicts gangs atomically).  Victims
            # for one waiter are collected tentatively and committed only
            # if the waiter can actually be served — otherwise a wide
            # gang would evict lower-priority work every tick without
            # ever being admitted (preempt/re-admit livelock).
            need = max(1, w.width) - min(free, max(1, w.width))
            wp = self._aged_priority(w.priority, w.arrival, view.clock)
            local: list = []
            local_taken: set = set()
            while need > 0:
                victim = next(
                    (s for s in candidates
                     if s.index not in taken
                     and s.index not in local_taken
                     and slot_prio(s) < wp), None)
                if victim is None:
                    break
                if victim.gang is not None:
                    local_taken.update(s.index for s in view.slots
                                       if s.gang == victim.gang)
                else:
                    local_taken.add(victim.index)
                local.append(victim.index)
                need -= max(1, victim.gang_size)
            if need > 0:
                continue  # unservable waiter: evict nobody on its behalf
            free -= min(free, max(1, w.width))
            free -= need  # need < 0: an oversized victim gang freed
            #               surplus slots — credit them to later waiters
            taken |= local_taken
            victims.extend(local)
        return victims


class AutoscalePolicy(FIFOPolicy):
    """FIFO admission plus slot-pool autoscaling against the engine's
    arrival-rate EWMA: ``target = ceil(rate * service_time * headroom)``
    (Little's law), clamped to ``[min_slots, max_slots]``.  Before the
    estimate warms up (rate == 0) the pool keeps its current size, so a
    cold engine starts at ``min_slots`` and grows with load — exercising
    ``ServingBackend.resize_cache`` — and shrinks back when load drops
    (draining, never killing, occupied slots)."""

    name = "autoscale"

    def __init__(self, min_slots: int = 1, service_time: float = 0.25,
                 headroom: float = 1.5):
        assert min_slots >= 1 and service_time > 0 and headroom > 0
        self.min_slots = min_slots
        self.service_time = service_time
        self.headroom = headroom

    def target_slots(self, view: SchedulerView) -> int:
        if view.arrival_rate <= 0.0:
            return max(self.min_slots, view.slot_limit)
        need = math.ceil(view.arrival_rate * self.service_time
                         * self.headroom)
        return max(self.min_slots, min(view.max_slots, need))


class RooflinePolicy(SchedulerPolicy):
    """Disaggregated prefill/decode scheduling against the backend's
    roofline (:class:`CostView`).

    Prefill is compute-bound: a chunk smaller than the roofline knee
    makes the GPU pay the per-expert weight-read floor (``gpu_const``)
    without amortizing it over enough tokens, so each tick ONE
    prefilling slot advances by ``CostView.prefill_chunk_tokens()``
    (priority-desc, oldest-first among equals) instead of every slot
    advancing by a tiny interleaved chunk.  Decode is memory-bound: all
    decode slots run together as one gang (batching decodes is nearly
    free — the weight read dominates), and ``StepPlan.overlap`` runs the
    prefill chunk concurrently with the decode gang, the ledger charging
    each stream's overlapped vs exposed share.

    Admission is priority-ordered (ties FIFO) so interactive arrivals
    reach a slot — and therefore the front of the prefill stream —
    ahead of queued batch work, protecting their TTFT.  Without a
    backend cost model (``view.cost is None``) the chunk falls back to
    the engine default and only the phase split/overlap remain."""

    name = "roofline"

    def __init__(self, max_chunk: int = 512):
        assert max_chunk >= 1, max_chunk
        self.max_chunk = max_chunk

    def admission_order(self, view: SchedulerView) -> Sequence[int]:
        arrived = sorted(
            view.arrived_queue(),
            key=lambda q: (-q.priority,
                           q.arrival if q.arrival is not None else -math.inf,
                           q.index))
        return [q.index for q in arrived]

    def _chunk(self, view: SchedulerView) -> Optional[int]:
        if view.cost is None:
            return view.default_chunk
        return min(self.max_chunk, view.cost.prefill_chunk_tokens())

    def plan(self, view: SchedulerView) -> StepPlan:
        prefilling = [s for s in view.slots if s.phase == "prefill"]
        prefilling.sort(key=lambda s: (
            -s.priority,
            s.started if s.started is not None else math.inf,
            s.index))
        chunk = self._chunk(view)
        # one saturating prefill chunk per tick; everyone else decodes
        chosen = tuple(s.index for s in prefilling[:1])
        sizes: Dict[int, int] = (
            {i: chunk for i in chosen} if chunk is not None else {})
        return StepPlan(admit=tuple(self.admission_order(view)),
                        preempt=(),
                        target_slots=view.max_slots,
                        prefill=chosen,
                        decode=None,
                        chunk_sizes=sizes,
                        overlap=True)


POLICIES = {
    "fifo": FIFOPolicy,
    "priority": PriorityPolicy,
    "autoscale": AutoscalePolicy,
    "roofline": RooflinePolicy,
}


def get_policy(spec=None) -> SchedulerPolicy:
    """Coerce None / name / class / instance / :class:`PolicySpec` /
    ``{"name": ..., **options}`` dict → a policy instance."""
    if spec is None:
        return FIFOPolicy()
    if isinstance(spec, SchedulerPolicy):
        return spec
    if isinstance(spec, type) and issubclass(spec, SchedulerPolicy):
        return spec()
    if isinstance(spec, dict):
        opts = dict(spec)
        try:
            name = opts.pop("name")
        except KeyError:
            raise ValueError(
                f"policy dict needs a 'name' key: {spec!r}") from None
        spec = PolicySpec(name=name, options=opts)
    if isinstance(spec, PolicySpec):
        try:
            cls = POLICIES[spec.name]
        except KeyError:
            raise ValueError(
                f"unknown scheduler policy {spec.name!r}; "
                f"choose from {sorted(POLICIES)}") from None
        return cls(**dict(spec.options))
    if isinstance(spec, str):
        try:
            return POLICIES[spec]()
        except KeyError:
            raise ValueError(
                f"unknown scheduler policy {spec!r}; "
                f"choose from {sorted(POLICIES)}") from None
    raise TypeError(f"cannot build a SchedulerPolicy from {spec!r}")
