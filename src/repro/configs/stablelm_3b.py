"""StableLM-3B [hf:stabilityai/stablelm-2-1_6b family] — dense.

32L d_model=2560 32H (GQA kv=32, i.e. MHA) d_ff=6912 vocab=50304.
long_500k runs only via the sliding-window variant (beyond-paper opt-in).
"""
from repro.configs.base import ModelConfig, register


@register("stablelm-3b")
def stablelm_3b() -> ModelConfig:
    return ModelConfig(
        name="stablelm-3b",
        arch_type="dense",
        n_layers=32,
        d_model=2560,
        n_heads=32,
        n_kv_heads=32,
        head_dim=80,
        d_ff=6912,
        vocab_size=50304,
        long_context_window=8192,
        citation="[hf:stabilityai/stablelm-2-1_6b] StableLM",
    )
