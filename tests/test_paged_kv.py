"""Paged KV layout: block-table/refcount invariants, paged-vs-dense
fp32 bit-identity across every serving path, zero-copy beam reshuffles,
and unique-block cost charging."""
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from conftest import reduced_model
from repro.core import FiddlerEngine
from repro.core.cost_model import kv_read_entries
from repro.core.orchestrator import nonexpert_layer_time
from repro.models.paged_kv import BlockMeta, PagedLayerCache
from repro.serving.backend import FiddlerBackend
from repro.serving.beam_search import beam_search_slots


def _engine(layout, **kw):
    cfg, model, params = reduced_model("mixtral-8x7b")
    kw.setdefault("expert_budget", 30)
    return FiddlerEngine(cfg, params, policy="fiddler",
                         host_precision="fp32", kv_layout=layout, **kw)


# ---------------------------------------------------------------------------
# BlockMeta unit behavior
# ---------------------------------------------------------------------------


def test_fork_shares_and_cow_diverges():
    m = BlockMeta(3, 48, 16)
    m.write_span(0, 0, 20)              # prompt: 2 blocks (16 + 4)
    for j in (1, 2):
        m.fork_slot(0, j)
    m.check()
    assert m.blocks_in_use() == 2       # fully shared
    assert m.unique_tokens() == 20
    assert m.dense_tokens() == 60       # per-beam accounting triples it
    # divergent writes at pos 20: the shared partial block COWs per beam
    # (the last referrer keeps the original)
    for s in range(3):
        m.write_span(s, 20, 21)
    m.check()
    assert m.blocks_in_use() == 4       # 1 shared full + 3 private
    assert m.unique_tokens() == 16 + 3 * 5


def test_reorder_is_refcount_only_and_recollapses():
    m = BlockMeta(4, 64, 16)
    m.write_span(0, 0, 30)
    for j in range(1, 4):
        m.fork_slot(0, j)
    for s in range(4):
        m.write_span(s, 30, 31)         # diverge
    used = m.blocks_in_use()
    free = m.n_free
    # all beams continue beam 0 → re-collapse onto one lineage
    m.reorder_slots([0, 1, 2, 3], [0, 0, 0, 0])
    m.check()
    assert m.blocks_in_use() < used
    assert m.n_free > free              # COW copies returned to the pool
    assert m.unique_tokens() == 31      # one surviving lineage


def test_release_returns_pool_to_initial():
    m = BlockMeta(4, 48, 16)
    init = m.n_free
    m.write_span(0, 0, 40)
    for j in range(1, 4):
        m.fork_slot(0, j)
    m.write_span(2, 40, 41)
    m.reorder_slots([0, 1], [2, 3])
    for s in range(4):
        m.release_slot(s)
    m.check()
    assert m.n_free == init
    assert (m.table == 0).all()


def test_ring_wrap_keeps_last_window():
    m = BlockMeta(1, 32, 16)
    m.write_span(0, 0, 32)
    m.check()
    assert m.unique_tokens() == 32
    m.write_span(0, 32, 33)             # wraps: overwrites offset 0
    m.check()
    assert m.unique_tokens() == 32      # fill saturated at the window


@settings(max_examples=60, deadline=None)
@given(st.lists(st.tuples(st.integers(0, 5), st.integers(0, 3),
                          st.integers(0, 3), st.integers(1, 8)),
                min_size=1, max_size=40))
def test_refcounts_never_leak_property(ops):
    """Random fork/write/release/reorder/resize sequences: refcounts
    always equal table occurrences, and releasing every slot returns the
    free count to its (possibly resized) pool size."""
    m = BlockMeta(4, 64, 16)
    lengths = [0, 0, 0, 0]
    for op, a, b, n in ops:
        a %= m.n_slots
        b %= m.n_slots
        if op == 0:
            start = lengths[a]
            m.write_span(a, start, start + n)
            lengths[a] = start + n
        elif op == 1:
            m.fork_slot(a, b)
            lengths[b] = lengths[a]
        elif op == 2:
            m.release_slot(a)
            lengths[a] = 0
        elif op == 3:
            src = [(a + i) % m.n_slots for i in range(m.n_slots)]
            m.reorder_slots(list(range(m.n_slots)), src)
            lengths = [lengths[s] for s in src]
        elif op == 4:
            m.resize(m.n_slots + (n % 3))
            lengths += [0] * (m.n_slots - len(lengths))
        else:
            keep = max(1, m.n_slots - 1)
            m.resize(keep)
            lengths = lengths[:keep]
        m.check()
    for s in range(m.n_slots):
        m.release_slot(s)
    m.check()
    assert m.blocks_in_use() == 0
    assert m.n_free == m.n_blocks - 1


# ---------------------------------------------------------------------------
# Paged vs dense: fp32 bit-identity through the orchestrator
# ---------------------------------------------------------------------------


def test_prefill_decode_bit_identical():
    outs = {}
    for layout in ("dense", "paged"):
        e = _engine(layout)
        logits, caches = e.prefill(
            jnp.asarray([[1, 5, 2, 8], [1, 3, 3, 3]], jnp.int32), 32)
        seq = [np.asarray(logits)]
        toks = jnp.argmax(logits, -1)[:, None]
        for t in range(3):
            logits, caches = e.decode_step(caches, toks, 4 + t, 32)
            seq.append(np.asarray(logits))
            toks = jnp.argmax(logits, -1)[:, None]
        outs[layout] = (seq, e.ledger.sim_time)
    for a, b in zip(outs["dense"][0], outs["paged"][0]):
        np.testing.assert_array_equal(a, b)
    # unforked slots: unique-block charging equals dense charging exactly
    assert outs["dense"][1] == outs["paged"][1]


def test_chunked_prefill_decode_multi_bit_identical():
    outs = {}
    for layout in ("dense", "paged"):
        e = _engine(layout)
        caches = e.make_decode_caches(3, 32)
        sc = None
        for off in (0, 2):
            lg, sc = e.prefill_chunk(
                jnp.asarray([[7 + off, 9 + off]], jnp.int32), sc, off, 32)
        caches = e.write_slot(caches, sc, 1)
        toks = np.zeros((3, 1), np.int32)
        toks[1] = int(np.argmax(lg[0]))
        pos = np.array([0, 4, 0])
        act = np.array([False, True, False])
        seq = []
        for t in range(3):
            lg2, caches = e.decode_step_multi(caches, jnp.asarray(toks),
                                              pos, 32, active=act)
            seq.append(np.asarray(lg2)[act])
            toks[1] = int(np.argmax(lg2[1]))
            pos = pos + act
        outs[layout] = seq
    for a, b in zip(outs["dense"], outs["paged"]):
        np.testing.assert_array_equal(a, b)


def test_beam_reshuffle_bit_identical_across_layouts():
    res = {}
    for layout in ("dense", "paged"):
        be = FiddlerBackend(_engine(layout), max_seq=32)
        res[layout] = beam_search_slots(be, [1, 5, 2, 8], width=3, n_new=4)
    np.testing.assert_array_equal(res["dense"].tokens, res["paged"].tokens)
    np.testing.assert_array_equal(res["dense"].scores, res["paged"].scores)
    st_ = res["paged"].block_stats
    assert st_ is not None
    assert st_["unique_blocks"] < st_["dense_blocks"]
    assert res["dense"].block_stats is None


def test_whole_batch_reorder_cache_bit_identical():
    """``FiddlerEngine.reorder_cache`` — the whole-batch reshuffle
    counterpart of ``Model.reorder_cache`` — permutes every slot's
    lineage identically under both layouts (table-only when paged)."""
    idx = [2, 0, 0]
    outs = {}
    for layout in ("dense", "paged"):
        e = _engine(layout)
        logits, caches = e.prefill(
            jnp.asarray([[1, 5, 2], [1, 9, 4], [1, 7, 7]], jnp.int32), 32)
        toks = jnp.argmax(logits, -1)[:, None]
        _, caches = e.decode_step(caches, toks, 3, 32)
        caches = e.reorder_cache(caches, idx)
        lg, _ = e.decode_step(caches, toks[np.asarray(idx)], 4, 32)
        outs[layout] = np.asarray(lg)
    np.testing.assert_array_equal(outs["dense"], outs["paged"])


def test_beam_reshuffle_zero_kv_copies():
    """The acceptance criterion: a paged reshuffle is a block-table
    permutation + refcount bump — the device pool arrays are the *same
    objects* before and after (jnp arrays are immutable, so any data
    movement would have produced new arrays), and no blocks are
    allocated."""
    e = _engine("paged")
    be = FiddlerBackend(e, max_seq=32)
    cache = be.make_cache(3)
    _, sc = e.prefill_chunk(jnp.asarray([[1, 5, 2, 8]], jnp.int32),
                            None, 0, 32)
    cache = be.write_slot(cache, sc, 0)
    for j in (1, 2):
        cache = be.fork_slot(cache, src=0, dst=j)
    ids = [(id(c.k), id(c.v), id(c.pos)) for c in cache]
    free = [c.meta.n_free for c in cache]
    tables = [c.meta.table.copy() for c in cache]
    cache = be.reorder_slots(cache, slots=[0, 1, 2], src_of=[2, 0, 0])
    for c, i3, f, t in zip(cache, ids, free, tables):
        assert (id(c.k), id(c.v), id(c.pos)) == i3, "reorder moved KV data"
        assert c.meta.n_free == f, "reorder allocated/freed blocks"
        np.testing.assert_array_equal(c.meta.table, t[[2, 0, 0]])
        c.meta.check()
    # fork is zero-copy too
    ids = [(id(c.k), id(c.v), id(c.pos)) for c in cache]
    cache = be.fork_slot(cache, src=0, dst=1)
    assert [(id(c.k), id(c.v), id(c.pos)) for c in cache] == ids


def test_refcounts_drain_through_continuous_engine_with_preemption():
    """Mid-group preemption through the real serving stack: a decoding
    beam gang is evicted by an interactive arrival, re-admitted, and
    finishes — afterwards every layer's block pool is back to its initial
    free count (no refcount leaks anywhere in admit/fork/reshuffle/evict/
    resume/retire)."""
    from repro.configs import get_config
    from repro.serving.continuous import ContinuousEngine
    from repro.serving.engine import Request
    from repro.serving.policy import PriorityPolicy

    # full-size timing constants: sim seconds are paper-scale, so the
    # interactive arrival lands mid-gang instead of after the whole run
    e = _engine("paged", timing_cfg=get_config("mixtral-8x7b"))
    be = FiddlerBackend(e, max_seq=48)
    eng = ContinuousEngine(be, n_slots=2, max_seq=48, prefill_chunk=4,
                           policy=PriorityPolicy(preemption=True))
    initial = None
    eng.submit(Request(rid="beam", prompt=[1, 5, 2], beam_width=2,
                       max_new_tokens=8, slo_class="batch", arrival=0.0))
    initial = [c.meta.n_blocks - 1 for c in eng.cache]
    # lands mid-decode of the gang and steals its slots (gang eviction)
    eng.submit(Request(rid="hot", prompt=[1, 9], max_new_tokens=2,
                       slo_class="interactive", arrival=1e-4))
    done = {r.rid: r for r in eng.run(max_steps=300)}
    assert done["beam"].preemptions >= 1, "gang was never preempted"
    assert done["beam"].beam_tokens.shape == (2, 8)
    assert len(done["hot"].output) >= 1
    for c, n in zip(eng.cache, initial):
        c.meta.check()
        assert c.meta.blocks_in_use() == 0
        assert c.meta.n_free == n, "leaked blocks after drain"


# ---------------------------------------------------------------------------
# Unique-block cost charging
# ---------------------------------------------------------------------------


def test_kv_read_entries_dedups_bytes_only():
    kv_lens = np.full(8, 1000, np.int64)
    assert kv_read_entries(kv_lens) == 8000.0
    assert kv_read_entries(kv_lens, kv_unique=1700) == 1700.0
    assert kv_read_entries(500) == 500.0


def test_unique_charging_reduces_beam_layer_time():
    """At paper scale a wide beam group's KV reads are the dominant
    memory term; charging unique blocks (shared prefix once) must be
    strictly cheaper than dense per-beam reads — and never more."""
    from repro.configs import get_config
    from repro.core import HardwareSpec

    cfg = get_config("mixtral-8x7b")
    hw = HardwareSpec.paper_env1()
    W, kv = 16, 4096
    dense_lens = np.full(W, kv, np.int64)
    t_dense = nonexpert_layer_time(cfg, hw, W, dense_lens)
    shared = kv + W * 64  # prompt shared, 64 divergent tokens per beam
    t_paged = nonexpert_layer_time(cfg, hw, W, dense_lens, kv_unique=shared)
    assert t_paged < t_dense
    # kv_unique == sum(kv_len) must be *exactly* the dense charge
    t_same = nonexpert_layer_time(cfg, hw, W, dense_lens,
                                  kv_unique=int(dense_lens.sum()))
    assert t_same == t_dense


def test_paged_cache_view_matches_dense_arrays():
    """The gather view reproduces the dense ring buffer bit-for-bit —
    including cleared never-written lanes."""
    from repro.models import kv_cache as kvc

    cfg, model, params = reduced_model("mixtral-8x7b")
    rng = np.random.default_rng(0)
    B, S, max_seq = 2, 5, 32
    k = jnp.asarray(rng.normal(size=(B, S, cfg.n_kv_heads, cfg.head_dim)),
                    jnp.float32)
    v = jnp.asarray(rng.normal(size=(B, S, cfg.n_kv_heads, cfg.head_dim)),
                    jnp.float32)
    dense = kvc.init_attn_cache(cfg, 0, B, max_seq, jnp.float32)
    dense = kvc.write_prefill(dense, k, v)
    paged = PagedLayerCache(cfg, 0, B, max_seq, jnp.float32)
    paged.write_prefill(k, v)
    view = paged.view()
    for key in ("k", "v", "pos"):
        np.testing.assert_array_equal(np.asarray(dense[key]),
                                      np.asarray(view[key]))


@pytest.mark.parametrize("bad", ["blocked", "row"])
def test_kv_layout_validated(bad):
    cfg, model, params = reduced_model("mixtral-8x7b")
    with pytest.raises(AssertionError):
        FiddlerEngine(cfg, params, policy="fiddler", expert_budget=30,
                      kv_layout=bad)
