"""Mixture-of-Experts layer: router, capacity dispatch, expert execution.

Two equivalent execution paths (tested against each other):

* ``moe_block_ref``     — pure jnp, single device.  The oracle, and the path
  the Fiddler orchestrator decomposes at serving time.
* ``moe_block_sharded`` — shard_map over the mesh.  Tokens are sharded over
  the (pod, data) axes, experts over the model axis:
    - ``ep`` mode (n_experts % model_size == 0): each model shard owns
      E/model experts; every shard routes its (model-replicated) local
      tokens, keeps only assignments that hit its own experts, computes,
      and the per-token outputs are combined with a psum over ``model``.
      No all-to-all is needed because activations are model-replicated in
      the surrounding tensor-parallel layout.
    - ``tp`` mode (otherwise, e.g. Mixtral's 8 experts on a 16-way axis):
      every shard holds all experts but only d_ff/model of each; partial
      down-projections are psum-combined.

Dispatch is capacity-bucketed: tokens are ranked within their expert via an
argsort (O(Tk log Tk), jit-friendly) and scattered into an (E, C, d) buffer,
so compiled FLOPs stay proportional to the real expert compute (no dense
(T, E, C) one-hot einsums).
"""
from __future__ import annotations

from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.configs.base import ModelConfig, MoEConfig
from repro.models.layers import Params, activation, dense_init

try:  # newer jax exports it at top level
    from jax import shard_map as _shard_map
except ImportError:  # pragma: no cover
    from jax.experimental.shard_map import shard_map as _shard_map

import inspect as _inspect

# the no-check kwarg was renamed check_rep → check_vma; pick by signature
# (the top-level export appeared before the rename, so never assume)
_SHARD_MAP_NOCHECK = (
    {"check_vma": False}
    if "check_vma" in _inspect.signature(_shard_map).parameters
    else {"check_rep": False})


def shard_map(*args, **kwargs):
    """shard_map with the replication/VMA check disabled, across the jax
    versions that renamed the kwarg (check_rep → check_vma)."""
    kwargs.update(_SHARD_MAP_NOCHECK)
    return _shard_map(*args, **kwargs)


# ---------------------------------------------------------------------------
# Params
# ---------------------------------------------------------------------------


def init_moe(key, cfg: ModelConfig, dtype=jnp.float32) -> Params:
    assert cfg.moe is not None
    m = cfg.moe
    k_r, k_g, k_u, k_d, k_s = jax.random.split(key, 5)
    E, d, f = m.n_experts, cfg.d_model, cfg.d_ff
    p = {
        "router": dense_init(k_r, (d, E), 0, jnp.float32),  # router in fp32
        "w_gate": dense_init(k_g, (E, d, f), 1, dtype),
        "w_up": dense_init(k_u, (E, d, f), 1, dtype),
        "w_down": dense_init(k_d, (E, f, d), 1, dtype),
    }
    if m.n_shared_experts:
        fs = f * m.n_shared_experts
        ks1, ks2, ks3 = jax.random.split(k_s, 3)
        p["shared"] = {
            "w_gate": dense_init(ks1, (d, fs), 0, dtype),
            "w_up": dense_init(ks2, (d, fs), 0, dtype),
            "w_down": dense_init(ks3, (fs, d), 0, dtype),
        }
    return p


# ---------------------------------------------------------------------------
# Router
# ---------------------------------------------------------------------------


def route(router_w: jnp.ndarray, x_flat: jnp.ndarray, m: MoEConfig
          ) -> Tuple[jnp.ndarray, jnp.ndarray, Dict[str, jnp.ndarray]]:
    """Returns (gates (T,k), expert_idx (T,k), stats)."""
    logits = x_flat.astype(jnp.float32) @ router_w  # (T, E)
    if m.router_type == "sigmoid":
        scores = jax.nn.sigmoid(logits)
    else:
        scores = jax.nn.softmax(logits, axis=-1)
    gates, idx = jax.lax.top_k(scores, m.top_k)
    gates = gates / jnp.maximum(jnp.sum(gates, axis=-1, keepdims=True), 1e-9)
    # Switch-style load-balance auxiliary loss.
    probs = jax.nn.softmax(logits, axis=-1)
    E = router_w.shape[1]
    me = jnp.mean(probs, axis=0)  # (E,)
    onehot = jax.nn.one_hot(idx, E, dtype=jnp.float32)  # (T, k, E)
    ce = jnp.mean(jnp.sum(onehot, axis=1), axis=0)  # fraction routed per expert
    aux = E * jnp.sum(me * ce) * m.aux_loss_coef
    stats = {"aux_loss": aux, "expert_counts": jnp.sum(onehot, axis=(0, 1))}
    return gates, idx, stats


# ---------------------------------------------------------------------------
# Capacity dispatch
# ---------------------------------------------------------------------------


def expert_ranks(expert_idx_flat: jnp.ndarray) -> jnp.ndarray:
    """rank[i] = number of earlier assignments with the same expert id.

    argsort-based: O(n log n), no (T, E) one-hot materialisation.
    """
    n = expert_idx_flat.shape[0]
    order = jnp.argsort(expert_idx_flat, stable=True)
    sorted_e = expert_idx_flat[order]
    iota = jnp.arange(n, dtype=jnp.int32)
    is_start = jnp.concatenate(
        [jnp.ones((1,), bool), sorted_e[1:] != sorted_e[:-1]])
    seg_start = jax.lax.cummax(jnp.where(is_start, iota, 0))
    ranks_sorted = iota - seg_start
    ranks = jnp.zeros((n,), jnp.int32).at[order].set(ranks_sorted)
    return ranks


def capacity_for(n_tokens: int, m: MoEConfig, kind: str, n_experts: int) -> int:
    """Static per-expert capacity.

    * tiny decode batches (≤256 assignments): C = T·k — strictly drop-free;
    * larger decode batches: 8× the expected per-expert load (Poisson tail
      P(load > 8·mean) ≈ 0 at these sizes) — §Perf P1 iter. 4: sizing C to
      min(T·k, 4096) made the dispatch buffers dominate decode HBM traffic;
    * train/prefill: the capacity factor.
    """
    tk = n_tokens * m.top_k
    if kind == "decode" or tk <= 4096:
        if tk <= 256:
            return max(1, tk)
        c = min(tk, max(16, 8 * (-(-tk // n_experts))))
        return -(-c // 8) * 8
    c = int(m.capacity_factor * tk / n_experts) + 1
    return max(8, -(-c // 8) * 8)  # round up to 8


def dispatch_compute_combine(
    x_flat: jnp.ndarray,        # (T, d)
    gates: jnp.ndarray,         # (T, k)
    idx: jnp.ndarray,           # (T, k)
    w_gate: jnp.ndarray,        # (E_loc, d, f_loc)
    w_up: jnp.ndarray,
    w_down: jnp.ndarray,        # (E_loc, f_loc, d)
    *,
    capacity: int,
    e_offset: jnp.ndarray,      # scalar int: first expert id owned locally
    act: str = "silu",
) -> jnp.ndarray:
    """Scatter→grouped-matmul→gather for the locally-owned expert slice.

    Returns the partial output (T, d): tokens whose experts live elsewhere
    contribute zero (combined by the caller's psum in sharded mode).
    """
    T, d = x_flat.shape
    E_loc = w_gate.shape[0]
    k = idx.shape[1]
    a = activation(act)

    e_flat = idx.reshape(-1)                       # (T·k,) global ids
    ranks = expert_ranks(e_flat)                   # (T·k,)
    local_e = e_flat - e_offset
    in_range = (local_e >= 0) & (local_e < E_loc)
    keep = in_range & (ranks < capacity)
    # clamp dropped/remote assignments into a scratch row
    slot_e = jnp.where(keep, local_e, E_loc)       # scratch expert row
    slot_c = jnp.where(keep, ranks, 0)

    tok_ids = jnp.repeat(jnp.arange(T, dtype=jnp.int32), k)
    buf = jnp.zeros((E_loc + 1, capacity, d), x_flat.dtype)
    buf = buf.at[slot_e, slot_c].set(x_flat[tok_ids], mode="drop")
    xb = buf[:E_loc]                               # (E_loc, C, d)

    h = a(jnp.einsum("ecd,edf->ecf", xb, w_gate))
    h = h * jnp.einsum("ecd,edf->ecf", xb, w_up)
    y = jnp.einsum("ecf,efd->ecd", h, w_down)      # (E_loc, C, d)

    y = jnp.concatenate([y, jnp.zeros((1, capacity, d), y.dtype)], axis=0)
    gathered = y[slot_e, slot_c]                   # (T·k, d)
    gathered = jnp.where(keep[:, None], gathered, 0.0)
    weighted = gathered * gates.reshape(-1)[:, None].astype(gathered.dtype)
    out = jnp.zeros((T, d), x_flat.dtype).at[tok_ids].add(weighted)
    return out


def _shared_expert(p: Params, x: jnp.ndarray, act: str) -> jnp.ndarray:
    a = activation(act)
    h = a(x @ p["w_gate"]) * (x @ p["w_up"])
    return h @ p["w_down"]


# ---------------------------------------------------------------------------
# Reference (single-device) block
# ---------------------------------------------------------------------------


def moe_block_ref(params: Params, x: jnp.ndarray, cfg: ModelConfig,
                  kind: str = "train") -> Tuple[jnp.ndarray, Dict[str, Any]]:
    """x: (B, S, d) → (B, S, d), stats. Pure jnp, all experts local."""
    m = cfg.moe
    B, S, d = x.shape
    x_flat = x.reshape(-1, d)
    gates, idx, stats = route(params["router"], x_flat, m)
    C = capacity_for(x_flat.shape[0], m, kind, m.n_experts)
    out = dispatch_compute_combine(
        x_flat, gates, idx, params["w_gate"], params["w_up"],
        params["w_down"], capacity=C, e_offset=jnp.int32(0), act=cfg.act)
    if m.n_shared_experts:
        out = out + _shared_expert(params["shared"], x_flat, cfg.act)
    return out.reshape(B, S, d), stats


# ---------------------------------------------------------------------------
# Sharded block (shard_map over the production mesh)
# ---------------------------------------------------------------------------


def moe_mode(cfg: ModelConfig, model_size: int) -> str:
    assert cfg.moe is not None
    return "ep" if cfg.moe.n_experts % model_size == 0 else "tp"


def fsdp_applicable(cfg: ModelConfig, mode: str, fsdp_size: int) -> bool:
    """FSDP shards d_ff (ep) / d_model (tp) over the data axes — only when
    divisible.  Used by both the spec builder and the shard_map body so
    storage layout and gather logic never diverge."""
    if fsdp_size <= 1:
        return False
    if mode == "ep":
        return cfg.d_ff % fsdp_size == 0
    return cfg.d_model % fsdp_size == 0


def moe_param_specs(cfg: ModelConfig, model_axis: str, model_size: int,
                    fsdp_axes: Optional[Tuple[str, ...]] = None,
                    fsdp_size: int = 0) -> Dict[str, Any]:
    """Expert-weight PartitionSpecs.  With ``fsdp_axes`` (§Perf
    FSDP_EXPERTS), a second dimension of every expert matrix is sharded
    over the data axes and all-gathered per layer inside the body."""
    mode = moe_mode(cfg, model_size)
    fa = fsdp_axes if fsdp_axes else None
    if fa is not None and fsdp_size and not fsdp_applicable(cfg, mode, fsdp_size):
        fa = None
    if fa is not None and len(fa) == 1:
        fa = fa[0]  # newer jax normalises 1-tuples inside P; do it for all
    if mode == "ep":
        specs = {
            "router": P(None, None),
            "w_gate": P(model_axis, None, fa),
            "w_up": P(model_axis, None, fa),
            "w_down": P(model_axis, fa, None),
        }
    else:
        specs = {
            "router": P(None, None),
            "w_gate": P(None, fa, model_axis),
            "w_up": P(None, fa, model_axis),
            "w_down": P(None, model_axis, fa),
        }
    if cfg.moe.n_shared_experts:
        specs["shared"] = {
            "w_gate": P(None, model_axis),
            "w_up": P(None, model_axis),
            "w_down": P(model_axis, None),
        }
    return specs


def _fsdp_gather_axes(mode: str) -> Dict[str, int]:
    """Which dim of each expert matrix the FSDP all-gather restores."""
    if mode == "ep":
        return {"w_gate": 2, "w_up": 2, "w_down": 1}
    return {"w_gate": 1, "w_up": 1, "w_down": 2}


def moe_block_sharded(params: Params, x: jnp.ndarray, cfg: ModelConfig,
                      mesh, data_axes: Tuple[str, ...], model_axis: str,
                      kind: str = "train",
                      fsdp: Optional[bool] = None
                      ) -> Tuple[jnp.ndarray, Dict[str, Any]]:
    """shard_map MoE. x: (B, S, d) with B sharded over data_axes and the
    feature/model axis replicated (tensor-parallel activation layout)."""
    from repro.distributed import opts

    if fsdp is None:
        fsdp = opts.FSDP_EXPERTS
    m = cfg.moe
    model_size = mesh.shape[model_axis]
    mode = moe_mode(cfg, model_size)
    E = m.n_experts
    E_loc = E // model_size if mode == "ep" else E

    B, S, d = x.shape
    store_axes = data_axes  # weight-storage axes (FSDP), batch-independent
    data_size = 1
    for ax in data_axes:
        data_size *= mesh.shape[ax]
    store_size = data_size
    if B % data_size != 0:
        # batch not shardable (e.g. long_500k B=1): replicate tokens over
        # the data axes; the model axis still splits experts/d_ff.
        data_axes = ()
        data_size = 1
    T_loc = (B // data_size) * S
    C = capacity_for(T_loc, m, kind, E)

    # FSDP expert storage only when the second dim divides the data axes
    fsdp = fsdp and fsdp_applicable(cfg, mode, store_size)
    p_specs = moe_param_specs(cfg, model_axis, model_size,
                              fsdp_axes=store_axes if fsdp else None)
    x_spec = P(data_axes if data_axes else None, None, None)
    gather_dims = _fsdp_gather_axes(mode)

    token_gather = fsdp and mode == "ep"
    C_body = (capacity_for(T_loc * store_size, m, kind, E)
              if (token_gather and data_axes) else C)

    def body(p, xb):
        if fsdp and not token_gather:
            # tp mode: restore full expert matrices for this layer
            # (ZeRO-3 style); backward of all_gather = reduce-scatter.
            p = dict(p)
            for k, ax in gather_dims.items():
                p[k] = jax.lax.all_gather(p[k], store_axes, axis=ax,
                                          tiled=True)
        Bl, Sl, dl = xb.shape
        x_flat = xb.reshape(-1, dl)
        x_own = x_flat
        if token_gather and data_axes:
            # ep+FSDP (§Perf P1 iteration 2): weights stay put (f sharded
            # over data); gather the TOKENS over data instead (KBs, not
            # GBs), compute the local (expert, d_ff)-slice for all tokens,
            # and let the final psum over (model, data) both sum the
            # partial d_ff products and combine expert ownership.
            T_own = x_flat.shape[0]
            x_flat = jax.lax.all_gather(x_flat, data_axes, axis=0,
                                        tiled=True)
        gates, idx, stats = route(p["router"], x_flat, m)
        if mode == "ep":
            e_off = jax.lax.axis_index(model_axis) * E_loc
        else:
            e_off = jnp.int32(0)
        out = dispatch_compute_combine(
            x_flat, gates, idx, p["w_gate"], p["w_up"], p["w_down"],
            capacity=C_body, e_offset=e_off, act=cfg.act)
        if token_gather and data_axes:
            # routed outputs: sum partial-d_ff products over data AND
            # expert ownership over model, then take back our token block
            out = jax.lax.psum(out, (model_axis,) + tuple(data_axes))
            didx = jax.lax.axis_index(data_axes[0]) if len(data_axes) == 1 \
                else (jax.lax.axis_index(data_axes[0]) * mesh.shape[data_axes[1]]
                      + jax.lax.axis_index(data_axes[1]))
            out = jax.lax.dynamic_slice_in_dim(out, didx * T_own, T_own,
                                               axis=0)
            if m.n_shared_experts:
                # shared expert is data-replicated: own tokens, model psum
                out = out + jax.lax.psum(
                    _shared_expert(p["shared"], x_own, cfg.act), model_axis)
        else:
            if m.n_shared_experts:
                out = out + _shared_expert(p["shared"], x_flat, cfg.act)
            out = jax.lax.psum(out, model_axis)
        stats = {
            # identical on every model shard; averaged over token shards
            "aux_loss": (jax.lax.pmean(stats["aux_loss"], data_axes)
                         if data_axes else stats["aux_loss"]),
            "expert_counts": (jax.lax.psum(stats["expert_counts"], data_axes)
                              if data_axes and not token_gather
                              else stats["expert_counts"]),
        }
        return out.reshape(Bl, Sl, dl), stats

    out, stats = shard_map(
        body, mesh=mesh,
        in_specs=(p_specs, x_spec),
        out_specs=(x_spec, {"aux_loss": P(), "expert_counts": P()}),
    )(params, x)
    return out, stats
