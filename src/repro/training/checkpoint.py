"""Checkpointing: sharded npz save/restore of param/opt pytrees.

Each leaf is stored under its pytree path; large leaves are split into
row-chunks so a single npz entry stays below ``max_chunk_bytes`` (mirrors
per-host sharded checkpointing on a real cluster — each chunk is what one
host would own).
"""
from __future__ import annotations

import json
import os
import re
from typing import Any, Dict, Tuple

import jax
import numpy as np


def _flatten(tree) -> Dict[str, Any]:
    flat = {}
    for path, leaf in jax.tree_util.tree_flatten_with_path(tree)[0]:
        key = "/".join(
            str(p.key) if isinstance(p, jax.tree_util.DictKey)
            else str(p.idx) if isinstance(p, jax.tree_util.SequenceKey)
            else str(p) for p in path)
        flat[key] = leaf
    return flat


def save_checkpoint(path: str, params, opt_state=None, step: int = 0,
                    max_chunk_bytes: int = 1 << 28) -> None:
    os.makedirs(path, exist_ok=True)
    payload = {"params": params}
    if opt_state is not None:
        payload["opt"] = opt_state
    flat = _flatten(payload)
    manifest = {"step": step, "leaves": {}}
    arrays: Dict[str, np.ndarray] = {}
    for key, leaf in flat.items():
        arr = np.asarray(leaf)
        n_chunks = max(1, -(-arr.nbytes // max_chunk_bytes))
        n_chunks = min(n_chunks, max(1, arr.shape[0])) if arr.ndim else 1
        manifest["leaves"][key] = {
            "shape": list(arr.shape), "dtype": str(arr.dtype),
            "chunks": n_chunks}
        if n_chunks == 1:
            arrays[_safe(key) + "__0"] = arr
        else:
            for ci, piece in enumerate(np.array_split(arr, n_chunks, axis=0)):
                arrays[_safe(key) + f"__{ci}"] = piece
    np.savez(os.path.join(path, "weights.npz"), **arrays)
    with open(os.path.join(path, "manifest.json"), "w") as f:
        json.dump(manifest, f, indent=1)


def _safe(key: str) -> str:
    return re.sub(r"[^\w.]", "_", key)


def load_checkpoint(path: str, like=None) -> Tuple[Dict[str, Any], int]:
    """Returns (payload pytree, step).  If ``like`` is given, the flat dict
    is re-assembled into its structure (and dtypes cast to match)."""
    with open(os.path.join(path, "manifest.json")) as f:
        manifest = json.load(f)
    z = np.load(os.path.join(path, "weights.npz"))
    flat: Dict[str, np.ndarray] = {}
    for key, meta in manifest["leaves"].items():
        parts = [z[_safe(key) + f"__{ci}"] for ci in range(meta["chunks"])]
        arr = parts[0] if len(parts) == 1 else np.concatenate(parts, axis=0)
        flat[key] = arr.reshape(meta["shape"]).astype(meta["dtype"])
    if like is None:
        return flat, manifest["step"]
    ref_flat = _flatten(like)
    assert set(ref_flat) == set(flat), (
        sorted(set(ref_flat) ^ set(flat))[:5])
    leaves_ref, treedef = jax.tree_util.tree_flatten(like)
    keys_in_order = list(_flatten(like).keys())
    rebuilt = treedef.unflatten(
        [flat[k].astype(np.asarray(r).dtype)
         for k, r in zip(keys_in_order, leaves_ref)])
    return rebuilt, manifest["step"]
