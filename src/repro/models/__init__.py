from repro.models.model import NO_PARALLEL, Model, ParallelContext, lm_loss  # noqa: F401
