"""Property tests for Algorithm 1 (the paper's planner) with hypothesis."""
import numpy as np
from hypothesis import given, settings, strategies as st

from repro.core.cost_model import HardwareSpec, LatencyModel
from repro.core.planner import (
    Decision,
    brute_force_plan,
    plan_layer,
    plan_layer_jnp,
)

lat_strategy = st.builds(
    LatencyModel,
    gpu_const=st.floats(1e-6, 1e-2),
    gpu_per_token=st.floats(0.0, 1e-5),
    cpu_base=st.floats(0.0, 1e-3),
    cpu_per_token=st.floats(1e-7, 1e-2),
    weight_transfer=st.floats(1e-6, 1e-1),
    act_per_token=st.floats(0.0, 1e-6),
)

sizes_strategy = st.lists(st.integers(0, 5000), min_size=1, max_size=64)


@given(lat=lat_strategy, sizes=sizes_strategy, data=st.data())
@settings(max_examples=200, deadline=None)
def test_planner_matches_bruteforce(lat, sizes, data):
    s = np.asarray(sizes)
    on_fast = np.asarray(
        data.draw(st.lists(st.booleans(), min_size=len(sizes),
                           max_size=len(sizes))))
    plan = plan_layer(s, on_fast, lat)
    oracle = brute_force_plan(s, on_fast, lat)
    np.testing.assert_array_equal(plan.decisions, oracle)


@given(lat=lat_strategy, sizes=sizes_strategy, data=st.data())
@settings(max_examples=100, deadline=None)
def test_planner_jnp_matches_numpy(lat, sizes, data):
    import jax.numpy as jnp

    s = np.asarray(sizes)
    on_fast = np.asarray(
        data.draw(st.lists(st.booleans(), min_size=len(sizes),
                           max_size=len(sizes))))
    plan = plan_layer(s, on_fast, lat)
    dec_j = np.asarray(plan_layer_jnp(jnp.asarray(s), jnp.asarray(on_fast), lat))
    np.testing.assert_array_equal(plan.decisions, dec_j)


@given(lat=lat_strategy, sizes=sizes_strategy)
@settings(max_examples=100, deadline=None)
def test_planner_invariants(lat, sizes):
    s = np.asarray(sizes)
    on_fast = np.zeros(len(sizes), bool)
    plan = plan_layer(s, on_fast, lat)
    # zero-input experts are skipped; active experts always get a decision
    assert (plan.decisions[s == 0] == int(Decision.SKIP)).all()
    assert (plan.decisions[s > 0] != int(Decision.SKIP)).all()
    # resident experts never stream or go slow
    on_fast2 = np.ones(len(sizes), bool)
    plan2 = plan_layer(s, on_fast2, lat)
    assert (plan2.decisions[s > 0] == int(Decision.FAST_RESIDENT)).all()
    # estimates are non-negative
    assert plan.est_fast_time >= 0 and plan.est_slow_time >= 0
    assert plan.est_overlapped <= plan.est_total + 1e-12


@given(lat=lat_strategy)
@settings(max_examples=100, deadline=None)
def test_decision_monotone_in_input_size(lat):
    """Paper §3.2: CPU is preferred below a crossover input size and the
    stream path above it — the decision is monotone in s.  (Holds under
    the paper's premise that the slow tier's marginal per-token cost
    exceeds the fast tier's.)"""
    from hypothesis import assume

    assume(lat.cpu_per_token + lat.act_per_token > lat.gpu_per_token)
    sizes = np.arange(1, 4097)
    on_fast = np.zeros_like(sizes, dtype=bool)
    plan = plan_layer(sizes, on_fast, lat)
    slow = plan.decisions == int(Decision.SLOW)
    # once streaming wins at size s, it wins for all larger s
    if slow.any() and (~slow).any():
        last_slow = np.nonzero(slow)[0].max()
        first_stream = np.nonzero(~slow)[0].min()
        assert first_stream > last_slow

    cross = lat.crossover(4096)
    if cross < 4096:
        assert not lat.prefer_cpu(cross)
        assert lat.prefer_cpu(max(cross - 1, 1)) or cross == 1


def test_paper_rule_verbatim():
    """cpu_lat(s) > gpu_lat(s) + transfer_lat() ⟺ stream (Alg. 1 line 12)."""
    lat = LatencyModel(gpu_const=1e-3, gpu_per_token=0.0, cpu_base=0.0,
                       cpu_per_token=1e-4, weight_transfer=9e-3,
                       act_per_token=0.0)
    # crossover at s = (1e-3 + 9e-3) / 1e-4 = 100
    plan = plan_layer(np.array([99, 100, 101, 150]),
                      np.zeros(4, bool), lat)
    assert plan.decisions[0] == int(Decision.SLOW)
    assert plan.decisions[3] == int(Decision.FAST_STREAM)


def test_derived_model_shape():
    """Sanity of the napkin-math model: fast tier ~constant, slow ~linear
    (paper App. A observation)."""
    from repro.configs import get_config

    lat = LatencyModel.derive(get_config("mixtral-8x7b"), HardwareSpec())
    g1, g64 = lat.gpu_lat(1), lat.gpu_lat(64)
    c1, c64 = lat.cpu_lat(1), lat.cpu_lat(64)
    assert g64 / g1 < 2.0                       # near-constant fast tier
    assert (c64 - c1) > 10 * (g64 - g1)         # slow-tier slope dominates
    assert lat.transfer_lat() > lat.gpu_lat(1)  # PCIe ≫ HBM read (2–5×, App. A)
