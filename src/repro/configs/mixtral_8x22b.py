"""Mixtral-8x22B [arXiv:2401.04088] — 8 experts top-2, sliding-window attn.

56L d_model=6144 48H (GQA kv=8) d_ff=16384 vocab=32768, MoE 8e top-2.
"""
from repro.configs.base import ModelConfig, MoEConfig, register


@register("mixtral-8x22b")
def mixtral_8x22b() -> ModelConfig:
    return ModelConfig(
        name="mixtral-8x22b",
        arch_type="moe",
        n_layers=56,
        d_model=6144,
        n_heads=48,
        n_kv_heads=8,
        head_dim=128,
        d_ff=16384,
        vocab_size=32768,
        window=4096,
        attn_pattern="sliding",
        moe=MoEConfig(n_experts=8, top_k=2, router_type="softmax"),
        rope_theta=1000000.0,
        citation="[arXiv:2401.04088] Mixtral of Experts",
    )
