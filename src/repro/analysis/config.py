"""fiddlint configuration: defaults + the ``[tool.fiddlint]`` pyproject
table.

Python 3.10 has no ``tomllib``, so a minimal TOML-subset reader backs the
import: only the flat key kinds ``[tool.fiddlint]`` actually uses
(strings, booleans, and one-line string arrays).  Everything the rules
treat as repo convention — hot-path roots, the bucket helper's name, the
BlockMeta acquire/release API — is a config knob so the fixture tests
can retarget the rules at synthetic modules.
"""
from __future__ import annotations

import re
from dataclasses import dataclass, field, replace
from pathlib import Path
from typing import Dict, List, Optional

RULE_IDS = ("FID001", "FID002", "FID003", "FID004", "FID005", "FID006",
            "FID007")


@dataclass(frozen=True)
class FiddlintConfig:
    # what to scan; relative paths resolve against the config file's dir
    paths: List[str] = field(default_factory=lambda: ["src/repro"])
    # committed grandfather file (None/"" disables baseline matching)
    baseline: Optional[str] = "fiddlint-baseline.json"
    # rules to run (subset of RULE_IDS)
    select: List[str] = field(default_factory=lambda: list(RULE_IDS))

    # FID001/FID002 — call-graph roots of the serving hot path.  Matched
    # against fully qualified names, exact or as a ".suffix".
    hot_roots: List[str] = field(default_factory=lambda: [
        "repro.serving.continuous.ContinuousEngine.step",
        "repro.core.orchestrator.FiddlerEngine.decode_step_multi",
        "repro.core.orchestrator.FiddlerEngine._run_moe_layer",
    ])

    # FID002 — helpers that make a data-dependent dimension jit-safe
    bucket_functions: List[str] = field(
        default_factory=lambda: ["_bucket", "bucket", "next_power_of_two"])

    # FID003 — the BlockMeta ownership API
    acquire_methods: List[str] = field(
        default_factory=lambda: ["alloc", "_alloc", "fork_slot", "map_prefix"])
    release_methods: List[str] = field(
        default_factory=lambda: ["release_slot", "free", "_unref",
                                 "_evict_cached", "deregister"])

    # FID004 — ledger conventions
    charge_function: str = "_charge"
    charge_required_kwargs: List[str] = field(
        default_factory=lambda: ["n_tokens", "kv_len"])
    ledger_class: str = "Ledger"
    # *_time fields that are clocks/aggregates, not individual overlap
    # sources needing the overlapped/exposed split
    time_split_exempt: List[str] = field(
        default_factory=lambda: ["sim_time"])

    # FID005 — callables executed on the slow-tier host pool (suffix
    # match on qualified names), beyond statically resolvable .submit()
    worker_entry_points: List[str] = field(default_factory=lambda: [
        "HostExpert.__call__",
        "QuantizedHostExpert.__call__",
    ])

    # FID006 — future-awaiting method names that need a watchdog timeout
    future_await_methods: List[str] = field(
        default_factory=lambda: ["result"])

    # FID007 — call-graph roots of the expert-migration path (per-device
    # device_put batching is checked on everything reachable from these)
    migration_roots: List[str] = field(default_factory=lambda: [
        "repro.core.orchestrator.FiddlerEngine.apply_migrations",
    ])

    def with_overrides(self, **kw) -> "FiddlintConfig":
        return replace(self, **{k: v for k, v in kw.items() if v is not None})


_KEY_RE = re.compile(r"^\s*([A-Za-z_][A-Za-z0-9_-]*)\s*=\s*(.+?)\s*$")


def _parse_value(raw: str):
    raw = raw.strip()
    if raw.startswith("["):
        return re.findall(r'"((?:[^"\\]|\\.)*)"', raw)
    if raw.startswith('"'):
        m = re.match(r'"((?:[^"\\]|\\.)*)"', raw)
        return m.group(1) if m else raw
    if raw in ("true", "false"):
        return raw == "true"
    return raw


def _read_tool_table(pyproject: Path) -> Dict[str, object]:
    """The ``[tool.fiddlint]`` table as a dict (TOML subset: one-line
    values only, which is all this config uses)."""
    try:
        import tomllib  # Python >= 3.11
        with open(pyproject, "rb") as f:
            data = tomllib.load(f)
        return data.get("tool", {}).get("fiddlint", {})
    except ImportError:
        pass
    table: Dict[str, object] = {}
    in_table = False
    pending_key: Optional[str] = None
    pending_val = ""
    for line in pyproject.read_text().splitlines():
        stripped = line.strip()
        if pending_key is not None:
            # continuation of a multi-line array value
            pending_val += " " + stripped
            if pending_val.count("]") >= pending_val.count("["):
                table[pending_key] = _parse_value(pending_val)
                pending_key = None
            continue
        if stripped.startswith("[") and stripped.endswith("]") and "=" not in stripped:
            in_table = stripped == "[tool.fiddlint]"
            continue
        if not in_table or not stripped or stripped.startswith("#"):
            continue
        m = _KEY_RE.match(line)
        if not m:
            continue
        key, raw = m.group(1).replace("-", "_"), m.group(2)
        if raw.startswith("[") and raw.count("]") < raw.count("["):
            pending_key, pending_val = key, raw
        else:
            table[key] = _parse_value(raw)
    return table


def load_config(start: Optional[Path] = None) -> FiddlintConfig:
    """Locate pyproject.toml at/above ``start`` (default cwd) and overlay
    its ``[tool.fiddlint]`` table on the defaults."""
    here = (start or Path.cwd()).resolve()
    for d in [here, *here.parents]:
        pp = d / "pyproject.toml"
        if pp.is_file():
            table = _read_tool_table(pp)
            cfg = FiddlintConfig()
            known = {f for f in cfg.__dataclass_fields__}
            overrides = {k: v for k, v in table.items() if k in known}
            cfg = cfg.with_overrides(**overrides)
            # resolve paths/baseline relative to the pyproject dir
            paths = [str((d / p)) if not Path(p).is_absolute() else p
                     for p in cfg.paths]
            baseline = cfg.baseline
            if baseline and not Path(baseline).is_absolute():
                baseline = str(d / baseline)
            return replace(cfg, paths=paths, baseline=baseline)
    return FiddlintConfig()
