"""Mamba2-2.7B [arXiv:2405.21060] — SSD (state-space duality), attn-free.

64L d_model=2560 (attention-free) d_ff=0 vocab=50280, ssm_state=128.
"""
from repro.configs.base import ModelConfig, SSMConfig, register


@register("mamba2-2.7b")
def mamba2_2p7b() -> ModelConfig:
    return ModelConfig(
        name="mamba2-2.7b",
        arch_type="ssm",
        n_layers=64,
        d_model=2560,
        n_heads=0,
        n_kv_heads=0,
        head_dim=64,
        d_ff=0,
        vocab_size=50280,
        ssm=SSMConfig(state_dim=128, head_dim=64, n_groups=1, conv_width=4,
                      chunk_size=256, expand=2),
        citation="[arXiv:2405.21060] Transformers are SSMs (Mamba-2 / SSD)",
    )
