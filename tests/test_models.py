"""End-to-end model consistency: decode path ≡ training forward at the
same positions, for one representative arch per family."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from conftest import reduced_model

FAMILY_REPS = ["qwen3-0.6b", "gemma2-9b", "mixtral-8x22b", "mamba2-2.7b",
               "recurrentgemma-2b", "whisper-large-v3", "internvl2-76b"]


@pytest.mark.parametrize("arch", FAMILY_REPS)
def test_decode_consistent_with_forward(arch):
    cfg, model, params = reduced_model(arch)
    B, S = 2, 10
    key = jax.random.PRNGKey(7)
    tokens = jax.random.randint(key, (B, S + 1), 3, cfg.vocab_size)
    extra = {}
    if cfg.arch_type == "vlm":
        extra["image_embeds"] = 0.02 * jax.random.normal(key, (B, 4, cfg.d_model))
    if cfg.arch_type == "audio":
        extra["frames"] = 0.02 * jax.random.normal(
            key, (B, cfg.encdec.n_audio_frames, cfg.d_model))

    # full forward over all S+1 tokens; last position predicts token S+1
    hidden, _ = model.forward_train(params, tokens, extra or None, remat=False)
    off = hidden.shape[1] - (S + 1)  # modality prefix length (vlm)
    full_logits = model.logits(params, hidden[:, -1:])[:, 0]

    # prefill tokens 0..S-1 (its logits predict token S) …
    logits_pre, cache = model.prefill(params, tokens[:, :S], max_seq=32,
                                      extra=extra or None,
                                      cache_dtype=jnp.float32)
    want_pre = model.logits(params, hidden[:, off + S - 1: off + S])[:, 0]
    np.testing.assert_allclose(np.asarray(logits_pre), np.asarray(want_pre),
                               rtol=5e-3, atol=5e-3)

    # … then decode token S at position off+S → predicts token S+1
    logits_dec, _ = model.decode_step(params, cache, tokens[:, S:S + 1],
                                      jnp.int32(off + S), max_seq=32)
    np.testing.assert_allclose(np.asarray(logits_dec), np.asarray(full_logits),
                               rtol=5e-3, atol=5e-3)


@pytest.mark.parametrize("arch", ["qwen3-0.6b", "mamba2-2.7b"])
def test_greedy_continuation_deterministic(arch):
    cfg, model, params = reduced_model(arch)
    B, S = 1, 8
    tokens = jax.random.randint(jax.random.PRNGKey(0), (B, S), 3,
                                cfg.vocab_size)
    outs = []
    for _ in range(2):
        logits, cache = model.prefill(params, tokens, max_seq=32,
                                      cache_dtype=jnp.float32)
        seq = []
        tok = jnp.argmax(logits, -1)[:, None]
        for t in range(5):
            seq.append(int(tok[0, 0]))
            logits, cache = model.decode_step(params, cache, tok,
                                              jnp.int32(S + t), max_seq=32)
            tok = jnp.argmax(logits, -1)[:, None]
        outs.append(seq)
    assert outs[0] == outs[1]
