"""ShapeDtypeStruct stand-ins for every model input (no allocation).

``input_specs(cfg, shape_name)`` returns the exact pytree the corresponding
step function is lowered with.  Modality frontends are stubbed per the
assignment: audio provides (B, n_frames, d) frame embeddings, VLM provides
(B, n_image_tokens, d) projected patch embeddings; text token counts are
reduced so the TOTAL sequence length matches the assigned shape.
"""
from __future__ import annotations

from typing import Any, Dict

import jax
import jax.numpy as jnp

from repro.configs.base import INPUT_SHAPES, InputShape, ModelConfig

SDS = jax.ShapeDtypeStruct


def text_len(cfg: ModelConfig, seq_len: int) -> int:
    """Text-token count so that text + modality tokens == seq_len."""
    if cfg.arch_type == "vlm":
        return seq_len - cfg.vlm.n_image_tokens
    return seq_len


def modality_extras(cfg: ModelConfig, batch: int, dtype=jnp.bfloat16
                    ) -> Dict[str, Any]:
    if cfg.arch_type == "vlm":
        return {"image_embeds": SDS((batch, cfg.vlm.n_image_tokens,
                                     cfg.d_model), dtype)}
    if cfg.arch_type == "audio":
        return {"frames": SDS((batch, cfg.encdec.n_audio_frames,
                               cfg.d_model), dtype)}
    return {}


def train_batch_specs(cfg: ModelConfig, shape: InputShape,
                      dtype=jnp.bfloat16) -> Dict[str, Any]:
    B, S = shape.global_batch, shape.seq_len
    st = text_len(cfg, S)
    # labels cover the FULL decoder stream (image-prefix positions are
    # -100-masked by the data pipeline), tokens only the text part.
    label_len = S if cfg.arch_type == "vlm" else st
    batch = {"tokens": SDS((B, st), jnp.int32),
             "labels": SDS((B, label_len), jnp.int32)}
    batch.update(modality_extras(cfg, B, dtype))
    return batch


def prefill_specs(cfg: ModelConfig, shape: InputShape,
                  dtype=jnp.bfloat16) -> Dict[str, Any]:
    B, S = shape.global_batch, shape.seq_len
    specs: Dict[str, Any] = {"tokens": SDS((B, text_len(cfg, S)), jnp.int32)}
    specs.update(modality_extras(cfg, B, dtype))
    return specs


def decode_token_specs(cfg: ModelConfig, shape: InputShape) -> Dict[str, Any]:
    B = shape.global_batch
    return {"tokens": SDS((B, 1), jnp.int32),
            "pos": SDS((), jnp.int32)}


def input_specs(cfg: ModelConfig, shape_name: str) -> Dict[str, Any]:
    shape = INPUT_SHAPES[shape_name]
    if shape.kind == "train":
        return train_batch_specs(cfg, shape)
    if shape.kind == "prefill":
        return prefill_specs(cfg, shape)
    return decode_token_specs(cfg, shape)
