"""Beam groups as a first-class gang-scheduled serving workload."""
import jax.numpy as jnp
import numpy as np

from conftest import reduced_model
from repro.core import FiddlerEngine
from repro.serving.backend import (
    FiddlerBackend,
    ModelBackend,
    SimulatedBackend,
)
from repro.serving.beam_search import beam_search_slots
from repro.serving.continuous import ContinuousEngine
from repro.serving.engine import Request, ServingEngine
from repro.serving.policy import PriorityPolicy


def _fiddler_backend(max_seq=48, **kw):
    cfg, model, params = reduced_model("mixtral-8x7b")
    fe = FiddlerEngine(cfg, params, policy="fiddler", expert_budget=30,
                       host_precision="fp32", **kw)
    return FiddlerBackend(fe, max_seq=max_seq)


def _sim_backend(max_seq=64):
    from repro.configs import get_config

    cfg = get_config("mixtral-8x7b")
    fe = FiddlerEngine(cfg, policy="fiddler", seed=0)
    return SimulatedBackend(fe, max_seq=max_seq)


def test_continuous_beam_group_matches_standalone_gang():
    """A beam request through ContinuousEngine (gang admission, shared
    prefill + forks, lockstep reshuffles) produces bit-identical beams to
    the standalone slot-API gang kernel on an identical engine."""
    W, n_new, prompt = 3, 5, [1, 5, 2, 8]
    ref = beam_search_slots(_fiddler_backend(), prompt, W, n_new)

    eng = ContinuousEngine(_fiddler_backend(), n_slots=W, max_seq=48)
    eng.submit(Request(rid="b", prompt=prompt, beam_width=W,
                       max_new_tokens=n_new))
    done = eng.run(max_steps=100)
    assert len(done) == 1
    req = done[0]
    np.testing.assert_array_equal(req.beam_tokens, ref.tokens)
    np.testing.assert_array_equal(req.beam_scores, ref.scores)
    assert req.output == [int(t) for t in ref.tokens[0]]
    assert req.ttft is not None and req.latency >= req.ttft


def test_beam_width1_equals_greedy_request():
    """A width-1 beam group is greedy decoding: same tokens as a plain
    request on an identical engine."""
    prompt, n_new = [1, 7, 3], 5
    eng = ContinuousEngine(_fiddler_backend(), n_slots=1, max_seq=48)
    eng.submit(Request(rid="g", prompt=prompt, max_new_tokens=n_new))
    greedy_out = eng.run(max_steps=100)[0].output

    eng2 = ContinuousEngine(_fiddler_backend(), n_slots=1, max_seq=48)
    eng2.submit(Request(rid="b", prompt=prompt, beam_width=1,
                        max_new_tokens=n_new))
    beam_out = eng2.run(max_steps=100)[0].output
    # beam groups stop at EOS like plain requests, so outputs are equal
    assert beam_out == greedy_out


def test_static_engine_runs_beam_as_gang_batch():
    """ServingEngine: a beam request forms its own gang batch between
    ordinary grouped batches, on both Model and Fiddler backends."""
    cfg, model, params = reduced_model("qwen3-0.6b")
    for backend in (ModelBackend(model, params, max_seq=48),
                    _fiddler_backend()):
        eng = ServingEngine(backend, max_batch=2, max_seq=48)
        eng.submit(Request(rid="r0", prompt=[1, 4, 9], max_new_tokens=3))
        eng.submit(Request(rid="beam", prompt=[1, 5, 2], beam_width=3,
                           max_new_tokens=4))
        eng.submit(Request(rid="r1", prompt=[1, 6], max_new_tokens=3))
        done = {r.rid: r for r in eng.run()}
        assert len(done) == 3
        b = done["beam"]
        assert b.beam_tokens.shape == (3, 4)
        assert (np.diff(b.beam_scores) <= 1e-6).all()  # sorted desc
        assert b.output == [int(t) for t in b.beam_tokens[0]]
        assert all(len(done[r].output) >= 1 for r in ("r0", "r1"))


def test_gang_preemption_is_atomic():
    """PriorityPolicy evicts a decoding beam gang for an interactive
    arrival: ALL member slots free at once (the interactive request runs
    while the gang is queued), then the gang re-admits atomically and
    finishes with the full beam set."""
    backend = _sim_backend()
    eng = ContinuousEngine(backend, n_slots=2, max_seq=64,
                           policy=PriorityPolicy(preemption=True))
    eng.submit(Request(rid="beam", prompt=[1] * 4, beam_width=2,
                       max_new_tokens=16, slo_class="batch", arrival=0.0))
    eng.submit(Request(rid="hot", prompt=[1] * 4, max_new_tokens=4,
                       slo_class="interactive", arrival=0.05))
    done = {r.rid: r for r in eng.run(max_steps=2000)}
    assert done["beam"].preemptions >= 1
    assert done["beam"].beam_tokens.shape == (2, 16)
    assert len(done["hot"].output) == 4
    # the interactive request was never starved behind the width-2 gang:
    # it got a slot the moment the gang was evicted
    assert done["hot"].ttft < done["beam"].latency
    m = eng.cache["meta"]
    m.check()
    assert m.blocks_in_use() == 0  # gang + single fully released


def test_gang_waits_for_width_slots():
    """Gang admission is all-or-nothing: with one slot busy, a width-2
    gang waits instead of starting half a group."""
    backend = _sim_backend()
    eng = ContinuousEngine(backend, n_slots=2, max_seq=64)
    eng.submit(Request(rid="long", prompt=[1] * 4, max_new_tokens=12,
                       arrival=0.0))
    eng.submit(Request(rid="beam", prompt=[1] * 4, beam_width=2,
                       max_new_tokens=4, arrival=0.0))
    eng.step()  # admits "long" only — one free slot < width 2
    assert eng.active == 1
    assert any(r.rid == "beam" for r in eng.queue)
    done = {r.rid: r for r in eng.run(max_steps=2000)}
    assert done["beam"].beam_tokens.shape == (2, 4)
    # the gang started only after the single finished every token
    assert done["beam"].ttft >= done["long"].latency - 1e-9


def test_simulated_beam_group_charges_unique_blocks():
    """Paper-scale simulated gang: beams share the prompt prefix, so a
    beam step is charged fewer KV bytes than W independent decodes — and
    the block stats show real sharing."""
    backend = _sim_backend(max_seq=128)
    W, n_new = 4, 8
    res = beam_search_slots(backend, [1] * 64, W, n_new)
    st = res.block_stats
    assert st["unique_blocks"] < st["dense_blocks"]
    assert st["unique_tokens"] < st["dense_tokens"]
    assert res.tokens.shape == (W, n_new)

    # an identical engine running W *independent* requests of the same
    # shape must accumulate strictly more simulated seconds (no sharing)
    b2 = _sim_backend(max_seq=128)
    cache = b2.make_cache(W)
    for s in range(W):
        _, stg = b2.prefill_chunk(None, [1] * 64, 0)
        cache = b2.write_slot(cache, stg, s)
    for t in range(n_new - 1):
        pos = np.full(W, 64 + t)
        b2.decode_slots(cache, np.zeros(W, np.int32), pos,
                        np.ones(W, bool))
    shared_t = backend.engine.ledger.sim_time
    dense_t = b2.engine.ledger.sim_time
    assert shared_t < dense_t


def test_submit_rejects_oversized_gang():
    backend = _sim_backend()
    eng = ContinuousEngine(backend, n_slots=2, max_seq=64)
    try:
        eng.submit(Request(rid="x", prompt=[1, 2], beam_width=3))
    except ValueError as err:
        assert "beam_width" in str(err)
    else:  # pragma: no cover
        raise AssertionError("oversized gang accepted")


def test_gang_floor_raises_conservative_slot_target():
    """An arrived gang wider than the policy's live-pool target raises
    the limit to its width instead of deadlocking in the queue."""
    from repro.serving.policy import AutoscalePolicy

    backend = _sim_backend()
    eng = ContinuousEngine(backend, n_slots=4, max_seq=64,
                           policy=AutoscalePolicy(min_slots=1))
    assert eng.slot_limit == 1  # cold autoscaler starts small
    eng.submit(Request(rid="beam", prompt=[1] * 4, beam_width=3,
                       max_new_tokens=3))
    done = eng.run(max_steps=500)
    assert done[0].beam_tokens.shape == (3, 3)


def test_half_resumed_gang_not_advertised_as_preemptible():
    """A gang member that finished re-prefilling while its siblings are
    still resuming sits behind the gang barrier: the scheduler view must
    not show it as 'decode' (policies would count it as an evictable
    victim, but _evict refuses non-ready gangs — phantom slots that
    never free)."""
    from repro.serving.continuous import _BeamGroup

    backend = _sim_backend()
    eng = ContinuousEngine(backend, n_slots=3, max_seq=64)
    req = Request(rid="beam", prompt=[1] * 4, beam_width=2,
                  max_new_tokens=8, arrival=0.0)
    grp = _BeamGroup(req=req, slots=[0, 1],
                     tokens=[[3, 4], [3, 5]])
    grp.scores = np.array([-1.0, -2.0])
    for i, phase in ((0, "decode"), (1, "prefill")):  # mid-resume
        eng.slots[i].req = req
        eng.slots[i].group = grp
        eng.slots[i].phase = phase
    view = eng._view()
    assert view.slots[0].phase == "resume"   # barrier, not decodable
    assert view.slots[1].phase == "prefill"
    assert not view.slots[0].free
    # once every member is decoding, the gang is a normal victim again
    eng.slots[1].phase = "decode"
    assert eng._view().slots[0].phase == "decode"
