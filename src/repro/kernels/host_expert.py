"""Slow-tier (host CPU) expert kernel — the TPU-deployment analogue of the
paper's AVX512_BF16 kernel (§3.4).

On a TPU VM the slow tier is the host CPU; the paper's insight — stock
framework CPU paths lack a good bf16 GEMM, so hand-tile one — maps to a
numpy kernel that (a) emulates bf16 storage (weights/activations are rounded
to bf16 before the fp32-accumulating GEMM, matching AVX512_BF16's
dot-product semantics) and (b) blocks over d_ff so the working set stays in
LLC.  numpy dispatches to the platform BLAS, which is exactly the "use the
CPU's wide-vector GEMM" role the AVX512 kernel plays in the paper.
"""
from __future__ import annotations

import numpy as np


def to_bf16(a: np.ndarray) -> np.ndarray:
    """Round-to-nearest-even fp32 → bf16, kept in a fp32 container."""
    u = a.astype(np.float32).view(np.uint32)
    rounded = ((u + 0x7FFF + ((u >> 16) & 1)) & 0xFFFF0000).astype(np.uint32)
    return rounded.view(np.float32)


def _silu(x: np.ndarray) -> np.ndarray:
    return x / (1.0 + np.exp(-x))


class HostExpert:
    """One expert's weights pinned in host memory, bf16-emulated by default
    (``precision="fp32"`` disables the rounding — used by the equivalence
    tests to compare bit-for-bit against the monolithic jit path)."""

    __slots__ = ("w_gate", "w_up", "w_down", "block_f", "precision")

    def __init__(self, w_gate: np.ndarray, w_up: np.ndarray,
                 w_down: np.ndarray, block_f: int = 1024,
                 precision: str = "bf16"):
        self.precision = precision
        rnd = to_bf16 if precision == "bf16" else (lambda a: a)
        self.w_gate = rnd(np.ascontiguousarray(w_gate, np.float32))
        self.w_up = rnd(np.ascontiguousarray(w_up, np.float32))
        self.w_down = rnd(np.ascontiguousarray(w_down, np.float32))
        self.block_f = block_f

    def nbytes(self) -> int:
        # logical bf16 storage
        return (self.w_gate.size + self.w_up.size + self.w_down.size) * 2

    def __call__(self, x: np.ndarray) -> np.ndarray:
        """x: (s, d) → (s, d).  Blocked over d_ff; fp32 accumulation."""
        rnd = to_bf16 if self.precision == "bf16" else (lambda a: a)
        x = rnd(np.asarray(x, np.float32))
        s, d = x.shape
        f = self.w_gate.shape[1]
        out = np.zeros((s, d), np.float32)
        for j0 in range(0, f, self.block_f):
            j1 = min(j0 + self.block_f, f)
            g = x @ self.w_gate[:, j0:j1]
            u = x @ self.w_up[:, j0:j1]
            h = rnd(_silu(g) * u)
            out += h @ self.w_down[j0:j1]
        return out


def host_expert_mlp(x: np.ndarray, w_gate: np.ndarray, w_up: np.ndarray,
                    w_down: np.ndarray, block_f: int = 1024) -> np.ndarray:
    """Functional form of :class:`HostExpert` (used by kernel tests)."""
    return HostExpert(w_gate, w_up, w_down, block_f)(x)
