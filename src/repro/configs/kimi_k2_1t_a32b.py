"""Kimi K2 — trillion-parameter MoE (paper-table) [arXiv:2501.kimi2].

61L d_model=7168 64H (GQA kv=8) d_ff=2048 (per-expert) vocab=163840,
MoE 384 experts top-8 (+1 shared expert, DeepSeek-V3 lineage),
sigmoid router.
"""
from repro.configs.base import ModelConfig, MoEConfig, register


@register("kimi-k2-1t-a32b")
def kimi_k2_1t_a32b() -> ModelConfig:
    return ModelConfig(
        name="kimi-k2-1t-a32b",
        arch_type="moe",
        n_layers=61,
        d_model=7168,
        n_heads=64,
        n_kv_heads=8,
        head_dim=128,
        d_ff=2048,
        vocab_size=163840,
        moe=MoEConfig(
            n_experts=384,
            top_k=8,
            n_shared_experts=1,
            router_type="sigmoid",
            capacity_factor=1.25,
        ),
        rope_theta=50000.0,
        citation="[arXiv:2501.kimi2] Kimi K2 — trillion-param MoE",
    )
