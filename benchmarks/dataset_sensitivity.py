"""Paper Figure 9 (Appendix D): sensitivity to the input dataset —
ShareGPT-like vs LMSYS-like routing distributions.  The placement is
profiled on ShareGPT; serving runs on both datasets (a distribution shift
for the popularity-based placement)."""
from benchmarks.common import ENVS, POLICIES, emit
from repro.configs import get_config
from repro.core import FiddlerEngine
from repro.core.popularity import synthetic_profile


def run(env: str = "env1", fast: bool = False):
    cfg = get_config("mixtral-8x7b")
    share = synthetic_profile(cfg.n_layers, cfg.moe.n_experts, seed=0,
                              concentration=12.0)
    lmsys = synthetic_profile(cfg.n_layers, cfg.moe.n_experts, seed=99,
                              concentration=6.0)  # more skewed
    results = {}
    for ds_name, serve_prof in (("sharegpt", share), ("lmsys", lmsys)):
        per = {}
        for policy in POLICIES:
            # placement profiled on ShareGPT; traffic follows the dataset
            eng = FiddlerEngine(cfg, policy=policy, hw=ENVS[env],
                                profile=share, seed=1)
            eng.profile = serve_prof  # runtime routing distribution
            r = eng.simulate_generate(prompt_len=64,
                                      gen_len=32 if fast else 128)
            per[policy] = r["tokens_per_s"]
            emit(f"dataset/{ds_name}/{policy}", r["itl"] * 1e6,
                 f"tok_per_s={r['tokens_per_s']:.2f}")
        ratio = per["fiddler"] / max(per["offload"], per["static_split"])
        emit(f"dataset/{ds_name}/fiddler_speedup", 0.0,
             f"{ratio:.2f}x (paper: 1.81x sharegpt / 1.56x lmsys)")
        results[ds_name] = ratio
    # robustness claim: Fiddler still wins on the shifted dataset
    assert results["lmsys"] > 1.0
    return results


if __name__ == "__main__":
    run()
