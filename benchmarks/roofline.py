import os
if "--subprocess" in __import__("sys").argv or os.environ.get("REPRO_ROOFLINE_SUB"):
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Roofline analysis (deliverable g).

Two modes:

* ``report`` (default, used by ``benchmarks.run``): read the dry-run sweep
  results (experiments/dryrun_results.json) and print the per-(arch×shape×
  mesh) roofline table — compute/memory/collective terms, dominant
  bottleneck, MODEL_FLOPS ratio.

* ``extrapolate`` (subprocess with 512 host devices): XLA's
  ``cost_analysis`` counts a ``while``-loop body ONCE, so the scanned layer
  stack is under-counted by ~n_periods.  We lower the SAME (shape, mesh)
  with 1-period and 2-period variants of the model; the difference of the
  two isolates the per-period cost, and

      total(term) = fixed + body · n_periods  (+ tail ≈ body·|tail|/period)

  reconstructs the full-depth roofline exactly for loop-linear terms.
  Results land in experiments/roofline_extrapolated.json.
"""
import argparse
import dataclasses
import json
import sys
from typing import Dict, List, Optional

HW = {"peak_flops": 197e12, "hbm_bw": 819e9, "ici_bw": 50e9}


def _terms(row: Dict) -> Dict[str, float]:
    return {"flops": float(row["flops"]),
            "hbm_bytes": float(row["hbm_bytes"]),
            "coll_bytes": float(row["coll_bytes"])}


def extrapolate_one(arch: str, shape_name: str, multi_pod: bool = False
                    ) -> Dict:
    """Runs inside the 512-device process."""
    from repro.configs import INPUT_SHAPES, get_config
    from repro.configs.base import _REGISTRY
    from repro.launch.dryrun import dryrun_one
    from repro.launch.mesh import make_production_mesh
    from repro.models import attention as attention_mod
    from repro.models import model as model_mod
    from repro.models.model import layer_plan, period_of

    cfg = get_config(arch)
    period = period_of(cfg)
    _, n_periods, tail = layer_plan(cfg)
    mesh = make_production_mesh(multi_pod=multi_pod)

    # ANALYSIS MODE: single-trip inner scans so cost_analysis (which counts
    # a while body once) sees exact FLOPs/bytes.  Compile-only — the huge
    # logical score temporaries are never allocated.  Production memory
    # numbers come from the normal dry-run sweep, not from here.
    sh = INPUT_SHAPES[shape_name]
    attention_mod.KV_CHUNK_DEFAULT = max(sh.seq_len, 1024)
    model_mod.LOSS_CHUNK_DEFAULT = max(sh.seq_len, 512)
    if cfg.ssm is not None:
        # NOTE: raising the SSD chunk to one trip makes loop counting
        # exact but inflates the (B, L, L, nh) decay-matrix traffic, which
        # scales ∝ chunk (production uses 256).  Deltas between runs with
        # identical REPRO_SSM_ANALYSIS_CHUNK remain valid; the P3
        # chunk-size iteration sweeps this knob explicitly.
        chunk = int(os.environ.get("REPRO_SSM_ANALYSIS_CHUNK",
                                   min(sh.seq_len, 8192)))
        cfg = dataclasses.replace(
            cfg, ssm=dataclasses.replace(cfg.ssm, chunk_size=chunk))

    rows = {}
    try:
        for mult in (1, 2):
            small = dataclasses.replace(cfg, n_layers=period * mult)
            name = f"__roofline_{arch}_{mult}"
            _REGISTRY[name] = lambda c=small: c
            rows[mult] = dryrun_one(name, shape_name, mesh=mesh,
                                    verbose=False, unroll=True)
            if not rows[mult].get("ok"):
                return {"arch": arch, "shape": shape_name, "ok": False,
                        "error": rows[mult].get("error")}
    finally:
        attention_mod.KV_CHUNK_DEFAULT = 1024
        model_mod.LOSS_CHUNK_DEFAULT = 512

    t1, t2 = _terms(rows[1]), _terms(rows[2])
    out = {"arch": arch, "shape": shape_name,
           "mesh": rows[1]["mesh"], "ok": True,
           "n_periods": n_periods, "tail": len(tail)}
    eff_periods = n_periods + len(tail) / period
    for k in t1:
        body = max(t2[k] - t1[k], 0.0)
        fixed = max(t1[k] - body, 0.0)
        out[k] = fixed + body * eff_periods
    out["t_compute_s"] = out["flops"] / HW["peak_flops"]
    out["t_memory_s"] = out["hbm_bytes"] / HW["hbm_bw"]
    out["t_collective_s"] = out["coll_bytes"] / HW["ici_bw"]
    terms = {"compute": out["t_compute_s"], "memory": out["t_memory_s"],
             "collective": out["t_collective_s"]}
    out["bottleneck"] = max(terms, key=terms.get)

    # analytic model flops (per device)
    from repro.configs import INPUT_SHAPES
    from repro.launch import analysis
    sh = INPUT_SHAPES[shape_name]
    n_tokens = (sh.global_batch * sh.seq_len if sh.kind != "decode"
                else sh.global_batch)
    out["model_flops_per_dev"] = analysis.model_flops(
        cfg, sh.kind, n_tokens) / mesh.size
    out["useful_ratio"] = (out["model_flops_per_dev"] / out["flops"]
                           if out["flops"] else 0.0)
    return out


def run_extrapolation(pairs: Optional[List] = None, multi_pod: bool = False,
                      out_path: str = "experiments/roofline_extrapolated.json"):
    from repro.configs import ASSIGNED_ARCHS, applicable_shapes, get_config

    if pairs is None:
        pairs = [(a, s) for a in ASSIGNED_ARCHS
                 for s in applicable_shapes(get_config(a))]
    rows = []
    for a, s in pairs:
        r = extrapolate_one(a, s, multi_pod)
        rows.append(r)
        if r.get("ok"):
            print(f"{a},{s},{r['bottleneck']},"
                  f"compute={r['t_compute_s']*1e3:.2f}ms,"
                  f"memory={r['t_memory_s']*1e3:.2f}ms,"
                  f"collective={r['t_collective_s']*1e3:.2f}ms,"
                  f"useful={r['useful_ratio']:.2f}", flush=True)
        else:
            print(f"{a},{s},FAILED,{r.get('error','')[:120]}", flush=True)
    with open(out_path, "w") as f:
        json.dump(rows, f, indent=1, default=str)
    return rows


def report(results_path: str = "experiments/dryrun_results.json",
           extrap_path: str = "experiments/roofline_extrapolated.json"):
    """Print the roofline table from saved sweeps (no compilation)."""
    from benchmarks.common import emit

    try:
        rows = json.load(open(extrap_path))
        src = "extrapolated"
    except FileNotFoundError:
        rows = json.load(open(results_path))
        src = "raw"
    for r in rows:
        if not r.get("ok"):
            continue
        name = f"roofline/{r['arch']}/{r['shape']}/{r.get('mesh', '16x16')}"
        tc = float(r["t_compute_s"]) * 1e6
        tm = float(r["t_memory_s"]) * 1e6
        tl = float(r["t_collective_s"]) * 1e6
        dom = max(tc, tm, tl)
        emit(name, dom,
             f"{src};bottleneck={r['bottleneck']};compute_us={tc:.1f};"
             f"memory_us={tm:.1f};collective_us={tl:.1f};"
             f"useful={float(r.get('useful_ratio', 0)):.2f}")
    return rows


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("mode", nargs="?", default="report",
                    choices=["report", "extrapolate"])
    ap.add_argument("--subprocess", action="store_true")
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None)
    args = ap.parse_args()
    if args.mode == "report":
        report()
    else:
        pairs = ([(args.arch, args.shape)]
                 if args.arch and args.shape else None)
        run_extrapolation(pairs, args.multi_pod)


if __name__ == "__main__":
    main()
