"""FID001 host-sync-in-hot-path.

The Fiddler overlap argument only holds if the decode step never blocks
on the device mid-layer: one stray ``.item()`` serialises the grouped
GEMM launch against the host experts and the "free" CPU work stops being
free.  This rule walks the call graph from the configured hot roots
(``ContinuousEngine.step``, ``decode_step_multi``, ``_run_moe_layer``)
and flags, inside any reachable function:

* ``.item()``, ``.tolist()``, ``.block_until_ready()`` — always a sync
  (these are array-API methods; nothing else in this repo defines them);
* ``jax.device_get(...)`` / ``np.asarray(x)`` / ``np.array(x)`` where
  ``x`` flows from a device value;
* ``float(x)`` / ``int(x)`` / ``bool(x)`` on a device value.

For the np/float/int forms a local device-ness dataflow (annotations +
jnp-rooted expressions) gates the report, so host-side numpy math in the
slow tier does not flood the rule.
"""
from __future__ import annotations

import ast
from typing import List

from repro.analysis.config import FiddlintConfig
from repro.analysis.core import Finding, relpath
from repro.analysis.dataflow import DeviceFlow
from repro.analysis.project import FunctionInfo, Project, attr_chain

ALWAYS_SYNC_METHODS = {"item", "tolist", "block_until_ready"}
SYNC_CASTS = {"float", "int", "bool"}
NP_SYNC_FUNCS = {"asarray", "array"}


def _check_function(project: Project, config: FiddlintConfig,
                    fn: FunctionInfo, root: str,
                    out: List[Finding]) -> None:
    mod = project.modules[fn.module]
    flow = DeviceFlow(project, fn)
    path = relpath(fn.file.path)
    via = "" if fn.qualname == root else f" (reachable from {root})"

    for node in ast.walk(fn.node):
        if not isinstance(node, ast.Call):
            continue
        func = node.func
        # x.item() / x.tolist() / x.block_until_ready()
        if isinstance(func, ast.Attribute) and func.attr in ALWAYS_SYNC_METHODS:
            out.append(Finding(
                "FID001", path, node.lineno, node.col_offset,
                f"`.{func.attr}()` forces a host sync in the hot "
                f"path{via}; keep the value on device or move the read "
                f"out of the step loop", fn.qualname))
            continue
        chain = attr_chain(func)
        # jax.device_get(x)
        if chain and chain[-1] == "device_get" and chain[0] in mod.jax_aliases:
            out.append(Finding(
                "FID001", path, node.lineno, node.col_offset,
                f"`jax.device_get` blocks on the device in the hot "
                f"path{via}", fn.qualname))
            continue
        # np.asarray(x) / np.array(x) on a device value
        if (chain and len(chain) == 2 and chain[0] in mod.np_aliases
                and chain[1] in NP_SYNC_FUNCS and node.args
                and flow.is_device(node.args[0])):
            out.append(Finding(
                "FID001", path, node.lineno, node.col_offset,
                f"`{chain[0]}.{chain[1]}` on a device array synchronizes "
                f"in the hot path{via}", fn.qualname))
            continue
        # float(x) / int(x) / bool(x) on a device value
        if (isinstance(func, ast.Name) and func.id in SYNC_CASTS
                and node.args and flow.is_device(node.args[0])):
            out.append(Finding(
                "FID001", path, node.lineno, node.col_offset,
                f"`{func.id}()` on a device array synchronizes in the hot "
                f"path{via}", fn.qualname))


def check_host_sync(project: Project,
                    config: FiddlintConfig) -> List[Finding]:
    roots = project.resolve_roots(config.hot_roots)
    reach = project.reachable_from(roots)
    out: List[Finding] = []
    for qual, root in reach.items():
        fn = project.functions.get(qual)
        if fn is not None:
            _check_function(project, config, fn, root, out)
    return out
