"""Paged (block) KV-cache layout with refcounted copy-on-write sharing.

The dense layout in :mod:`repro.models.kv_cache` gives every decode slot a
private ``(W, n_kv, head_dim)`` ring buffer, so a beam reshuffle must
*copy* whole cache rows and beams of one group hold W duplicates of their
shared prompt prefix.  This module splits each layer's KV into fixed-size
**blocks** drawn from a per-layer pool:

* ``k``/``v``/``pos`` pools of shape ``(n_blocks, block_size, ...)``;
* a host-side :class:`BlockMeta` — per-slot **block table** mapping the
  slot's logical window offsets to pool blocks, plus per-block
  **refcounts** and a free list;
* **copy-on-write**: a write into a block with refcount > 1 first moves
  the writer onto a private copy, so sharing is transparent to numerics;
* **fork** (``fork_slot``) and **reshuffle** (``reorder_slots``) are
  table permutations + refcount bumps — zero KV data movement, which is
  what makes beam search a first-class serving workload instead of a
  cache-copy storm (paper Fig. 6 regime).

Block 0 is a reserved *null* block: never allocated, always empty
(``pos == -1`` everywhere), the target of every unmapped table entry —
so gathering a table row always yields a well-formed dense view.

Bit-identity contract: :meth:`PagedLayerCache.view` reproduces the dense
ring buffer exactly — logical offset ``p % window`` lives at block
``off // block_size``, lane ``off % block_size``, freshly mapped blocks
are cleared to the dense init state (zeros / ``pos == -1``) — so
attention over the gathered view is bit-identical on fp32 to the dense
layout (tested in tests/test_paged_kv.py).

:class:`BlockMeta` is deliberately standalone (no device arrays): the
pure-simulation serving backend and the beam-search benchmark use it to
account **unique** blocks — shared prefix bytes are charged once, which
is what makes paper-scale simulated beam numbers honest (see
``core/cost_model.nonexpert_layer_time(kv_unique=...)``).
"""
from __future__ import annotations

from typing import List, Optional, Sequence, Tuple, Union

import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig
from repro.models.kv_cache import layer_window

# Tokens per KV block.  16 keeps the per-slot table small while a beam
# group's shared prompt still spans many whole (shareable) blocks.
PAGE_SIZE = 16

# src tag for a freshly-mapped block (caller must clear it to the dense
# init state); an int src means copy-on-write from that block.
FRESH = "fresh"

WritePlan = Tuple[int, int, int, int, int, Union[None, str, int]]


class BlockMeta:
    """Host-side block table + refcounts for one layer('s ring window).

    All bookkeeping is numpy/python — no device data — so the same class
    backs the real paged cache (:class:`PagedLayerCache`) and the
    pure-simulation unique-block accounting.
    """

    def __init__(self, n_slots: int, window: int, block_size: int = PAGE_SIZE):
        assert n_slots >= 1 and window >= 1, (n_slots, window)
        bs = max(1, min(int(block_size), int(window)))
        self.block_size = bs
        self.window = int(window)
        self.blocks_per_slot = -(-self.window // bs)
        # worst case every slot owns a private copy of each of its blocks,
        # so ``n_slots * blocks_per_slot`` (+ the null block) always
        # suffices — COW never needs more than one owner per table entry.
        self.n_blocks = 1 + n_slots * self.blocks_per_slot
        self.table = np.zeros((n_slots, self.blocks_per_slot), np.int32)
        self.ref = np.zeros(self.n_blocks, np.int32)
        self.fill = np.zeros(self.n_blocks, np.int32)  # written lanes per block
        self._free: List[int] = list(range(self.n_blocks - 1, 0, -1))

    # -- introspection ------------------------------------------------------
    @property
    def n_slots(self) -> int:
        return int(self.table.shape[0])

    @property
    def n_free(self) -> int:
        return len(self._free)

    def mapped_blocks(self, slots: Optional[Sequence[int]] = None) -> np.ndarray:
        t = self.table if slots is None else self.table[np.asarray(slots, int)]
        u = np.unique(t)
        return u[u > 0]

    def blocks_in_use(self, slots: Optional[Sequence[int]] = None) -> int:
        """Distinct mapped blocks — what the pool actually holds."""
        return int(self.mapped_blocks(slots).size)

    def dense_blocks(self, slots: Optional[Sequence[int]] = None) -> int:
        """Block count a dense per-slot layout would hold (table entries
        counted *with* multiplicity — shared blocks once per referent)."""
        t = self.table if slots is None else self.table[np.asarray(slots, int)]
        return int((t > 0).sum())

    def unique_tokens(self, slots: Optional[Sequence[int]] = None) -> int:
        """Written KV entries over distinct blocks: the number of K/V rows
        one attention step actually has to read from memory — shared
        prefix entries count once (the honest beam charging)."""
        return int(self.fill[self.mapped_blocks(slots)].sum())

    def dense_tokens(self, slots: Optional[Sequence[int]] = None) -> int:
        """Written KV entries counted per slot (dense accounting)."""
        t = self.table if slots is None else self.table[np.asarray(slots, int)]
        return int(self.fill[t].sum())  # fill[0] == 0: null entries add 0

    # -- allocation ---------------------------------------------------------
    def _alloc(self) -> int:
        if not self._free:
            raise RuntimeError("KV block pool exhausted")
        b = self._free.pop()
        self.ref[b] = 1
        self.fill[b] = 0
        return b

    def _unref(self, b: int) -> None:
        if b <= 0:
            return
        self.ref[b] -= 1
        assert self.ref[b] >= 0, b
        if self.ref[b] == 0:
            self.fill[b] = 0
            self._free.append(b)

    def _writable(self, slot: int, j: int) -> Tuple[int, Union[None, str, int]]:
        """Make table entry ``(slot, j)`` exclusively owned; returns
        ``(block, src)`` with src None (already exclusive), FRESH (newly
        mapped — clear before writing) or the old block id (copy-on-write
        — copy its data before writing)."""
        b = int(self.table[slot, j])
        if b == 0:
            nb = self._alloc()
            self.table[slot, j] = nb
            return nb, FRESH
        if self.ref[b] == 1:
            return b, None
        nb = self._alloc()
        self.fill[nb] = self.fill[b]
        self.ref[b] -= 1  # still >= 1: another slot keeps the original
        self.table[slot, j] = nb
        return nb, b

    # -- slot lifecycle (the zero-copy operations) --------------------------
    def release_slot(self, slot: int) -> None:
        for b in self.table[slot]:
            self._unref(int(b))
        self.table[slot] = 0

    def fork_slot(self, src: int, dst: int) -> None:
        """dst becomes a copy-on-write alias of src: table row copy +
        refcount bumps, zero data movement."""
        if src == dst:
            return
        row = self.table[src].copy()
        for b in row:
            if b > 0:
                self.ref[b] += 1
        self.release_slot(dst)
        self.table[dst] = row

    def reorder_slots(self, slots: Sequence[int], src_of: Sequence[int]) -> None:
        """Beam reshuffle: slot ``slots[i]`` continues the sequence held
        by ``src_of[i]`` — a pure table permutation with refcount bumps
        (sources may repeat or alias destinations)."""
        slots = np.asarray(slots, int)
        rows = self.table[np.asarray(src_of, int)].copy()
        for b in rows.ravel():
            if b > 0:
                self.ref[b] += 1
        for s in slots:
            self.release_slot(int(s))
        self.table[slots] = rows

    def resize(self, n_slots: int) -> int:
        """Grow/shrink the table to ``n_slots`` rows; returns how many
        *new* pool blocks the owner must append to its device arrays."""
        old = self.n_slots
        if n_slots <= old:
            for s in range(n_slots, old):
                self.release_slot(s)
            self.table = self.table[:n_slots].copy()
            return 0
        self.table = np.concatenate(
            [self.table,
             np.zeros((n_slots - old, self.blocks_per_slot), np.int32)])
        need = n_slots * self.blocks_per_slot + 1 - self.n_blocks
        if need <= 0:
            return 0
        start = self.n_blocks
        self.n_blocks += need
        self.ref = np.concatenate([self.ref, np.zeros(need, np.int32)])
        self.fill = np.concatenate([self.fill, np.zeros(need, np.int32)])
        self._free.extend(range(start, self.n_blocks))
        return need

    # -- writes -------------------------------------------------------------
    def write_span(self, slot: int, start: int, end: int) -> List[WritePlan]:
        """Plan the physical writes of logical positions ``[start, end)``
        of ``slot`` (ring offsets ``p % window``; spans longer than the
        window keep only the last ``window`` positions, like the dense
        ring buffer).  Ensures every touched block is exclusively owned.
        Returns ``(block, o0, o1, t0, t1, src)`` tuples: clipped-span
        tokens ``[t0, t1)`` land in lanes ``[o0, o1)`` of ``block``; the
        caller performs the FRESH clear / COW copy that ``src`` demands.
        Pure-simulation users call this for the refcount/fill bookkeeping
        and discard the plan."""
        start = max(int(start), int(end) - self.window)
        plans: List[WritePlan] = []
        p, t = start, 0
        while p < end:
            off = p % self.window
            j, o0 = divmod(off, self.block_size)
            cap = min(self.block_size, self.window - j * self.block_size)
            n = min(end - p, cap - o0)
            b, src = self._writable(slot, j)
            self.fill[b] = max(int(self.fill[b]), o0 + n)
            plans.append((b, o0, o0 + n, t, t + n, src))
            p += n
            t += n
        return plans

    # -- invariants (property tests) ----------------------------------------
    def check(self) -> None:
        """Refcount/free-list consistency: every block's refcount equals
        its table occurrences, freed blocks are exactly the unmapped
        ones, and nothing leaks."""
        occ = np.bincount(self.table.ravel(), minlength=self.n_blocks)
        assert (self.ref[1:] == occ[1:]).all(), "refcount != table occurrences"
        free = set(self._free)
        assert len(free) == len(self._free), "free-list duplicates"
        for b in range(1, self.n_blocks):
            assert (self.ref[b] == 0) == (b in free), b
        assert self.blocks_in_use() + self.n_free == self.n_blocks - 1


class PagedLayerCache:
    """One layer's paged KV: device block pools + a :class:`BlockMeta`.

    Pool arrays are functionally updated jnp arrays; the table/refcounts
    are host state, so this object lives in the orchestrator's python
    serving loop (never inside jit) — the jitted monolithic ``Model``
    keeps the dense layout."""

    layout = "paged"

    def __init__(self, cfg: ModelConfig, layer_idx: int, n_slots: int,
                 max_seq: int, dtype=jnp.float32,
                 block_size: int = PAGE_SIZE):
        w = layer_window(cfg, layer_idx, max_seq)
        self.meta = BlockMeta(n_slots, w, block_size)
        bs = self.meta.block_size
        nb = self.meta.n_blocks
        self.k = jnp.zeros((nb, bs, cfg.n_kv_heads, cfg.head_dim), dtype)
        self.v = jnp.zeros((nb, bs, cfg.n_kv_heads, cfg.head_dim), dtype)
        self.pos = jnp.full((nb, bs), -1, jnp.int32)

    @property
    def window(self) -> int:
        return self.meta.window

    @property
    def n_slots(self) -> int:
        return self.meta.n_slots

    # -- physical write helpers ---------------------------------------------
    def _prepare(self, b: int, src) -> None:
        """FRESH → clear to the dense init state (a recycled block holds
        stale bytes); int → copy-on-write the source block's data."""
        if src is None:
            return
        if src == FRESH:
            self.k = self.k.at[b].set(0.0)
            self.v = self.v.at[b].set(0.0)
            self.pos = self.pos.at[b].set(-1)
        else:
            self.k = self.k.at[b].set(self.k[src])
            self.v = self.v.at[b].set(self.v[src])
            self.pos = self.pos.at[b].set(self.pos[src])

    def write_decode(self, k_new: jnp.ndarray, v_new: jnp.ndarray,
                     pos: np.ndarray,
                     active: Optional[np.ndarray] = None) -> None:
        """One token per slot: k_new/v_new (B, 1, n_kv, hd), pos (B,).
        Rows outside ``active`` are padding — skipped entirely, so idle
        serving slots never allocate or COW blocks."""
        pos = np.asarray(pos, np.int64)
        rows = (range(pos.shape[0]) if active is None
                else np.nonzero(np.asarray(active, bool))[0])
        bids, lanes, ridx = [], [], []
        for i in rows:
            p = int(pos[i])
            for b, o0, _o1, _t0, _t1, src in self.meta.write_span(i, p, p + 1):
                self._prepare(b, src)
                bids.append(b)
                lanes.append(o0)
                ridx.append(int(i))
        if not bids:
            return
        bi, oi, ri = (np.asarray(bids), np.asarray(lanes), np.asarray(ridx))
        self.k = self.k.at[bi, oi].set(k_new[ri, 0].astype(self.k.dtype))
        self.v = self.v.at[bi, oi].set(v_new[ri, 0].astype(self.v.dtype))
        self.pos = self.pos.at[bi, oi].set(
            jnp.asarray(pos[ri], jnp.int32))

    def write_prefill_chunk(self, k_new: jnp.ndarray, v_new: jnp.ndarray,
                            positions: np.ndarray,
                            active: Optional[np.ndarray] = None) -> None:
        """Append one contiguous chunk per slot: k_new/v_new (B, S, ...),
        positions (B, S) int (each row contiguous ascending)."""
        positions = np.asarray(positions, np.int64)
        B, S = positions.shape
        rows = (range(B) if active is None
                else np.nonzero(np.asarray(active, bool))[0])
        for i in rows:
            p0, p1 = int(positions[i, 0]), int(positions[i, -1]) + 1
            assert p1 - p0 == S, "chunk positions must be contiguous"
            skip = max(p0, p1 - self.window) - p0  # ring: last window wins
            for b, o0, o1, t0, t1, src in self.meta.write_span(i, p0, p1):
                self._prepare(b, src)
                self.k = self.k.at[b, o0:o1].set(
                    k_new[i, skip + t0: skip + t1].astype(self.k.dtype))
                self.v = self.v.at[b, o0:o1].set(
                    v_new[i, skip + t0: skip + t1].astype(self.v.dtype))
                self.pos = self.pos.at[b, o0:o1].set(
                    jnp.arange(p0 + skip + t0, p0 + skip + t1, dtype=jnp.int32))

    def write_prefill(self, k_new: jnp.ndarray, v_new: jnp.ndarray) -> None:
        """Fresh prompt at positions 0..S-1 for every slot."""
        B, S = k_new.shape[0], k_new.shape[1]
        positions = np.broadcast_to(np.arange(S, dtype=np.int64)[None], (B, S))
        self.write_prefill_chunk(k_new, v_new, positions)

    # -- reads ---------------------------------------------------------------
    def view(self) -> dict:
        """The dense ``{"k", "v", "pos"}`` view the attention kernels
        consume, gathered through the block table — bit-identical to the
        dense ring buffer's arrays."""
        tbl = jnp.asarray(self.meta.table)          # (B, blocks_per_slot)
        B = tbl.shape[0]
        w = self.window
        k = self.k[tbl].reshape(B, -1, *self.k.shape[2:])[:, :w]
        v = self.v[tbl].reshape(B, -1, *self.v.shape[2:])[:, :w]
        pos = self.pos[tbl].reshape(B, -1)[:, :w]
        return {"k": k, "v": v, "pos": pos}

    # -- slot lifecycle -------------------------------------------------------
    def fork_slot(self, src: int, dst: int) -> None:
        self.meta.fork_slot(src, dst)           # zero KV data movement

    def reorder_slots(self, slots, src_of) -> None:
        self.meta.reorder_slots(slots, src_of)  # zero KV data movement

    def release_slot(self, slot: int) -> None:
        self.meta.release_slot(slot)

    def copy_in(self, slot: int, src: "PagedLayerCache",
                src_slot: int = 0) -> None:
        """Splice a freshly-prefilled staging cache's slot into ``slot``
        (continuous-batching join) — block-granular data copy, the paged
        counterpart of the dense row copy in ``write_slot``."""
        assert src.meta.block_size == self.meta.block_size, "page mismatch"
        self.meta.release_slot(slot)
        for j, sb in enumerate(src.meta.table[src_slot]):
            sb = int(sb)
            if sb == 0:
                continue
            b, how = self.meta._writable(slot, j)
            assert how == FRESH, how  # the row was just released
            self.k = self.k.at[b].set(src.k[sb].astype(self.k.dtype))
            self.v = self.v.at[b].set(src.v[sb].astype(self.v.dtype))
            self.pos = self.pos.at[b].set(src.pos[sb])
            self.meta.fill[b] = src.meta.fill[sb]

    def resize(self, n_slots: int) -> None:
        need = self.meta.resize(n_slots)
        if need:
            self.k = jnp.concatenate(
                [self.k, jnp.zeros((need,) + self.k.shape[1:], self.k.dtype)])
            self.v = jnp.concatenate(
                [self.v, jnp.zeros((need,) + self.v.shape[1:], self.v.dtype)])
            self.pos = jnp.concatenate(
                [self.pos, jnp.full((need,) + self.pos.shape[1:], -1,
                                    self.pos.dtype)])
