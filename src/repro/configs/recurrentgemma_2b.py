"""RecurrentGemma-2B [arXiv:2402.19427] — RG-LRU + local attention, 1:2.

26L d_model=2560 10H (GQA kv=1, MQA) d_ff=7680 vocab=256000.
Layer pattern repeats (recurrent, recurrent, local-attention).
"""
from repro.configs.base import HybridConfig, ModelConfig, register


@register("recurrentgemma-2b")
def recurrentgemma_2b() -> ModelConfig:
    return ModelConfig(
        name="recurrentgemma-2b",
        arch_type="hybrid",
        n_layers=26,
        d_model=2560,
        n_heads=10,
        n_kv_heads=1,
        head_dim=256,
        d_ff=7680,
        vocab_size=256000,
        act="gelu",
        hybrid=HybridConfig(lru_width=2560, attn_period=3, window=2048),
        tie_embeddings=True,
        scale_embeddings=True,
        citation="[arXiv:2402.19427] Griffin / RecurrentGemma (RG-LRU)",
    )
