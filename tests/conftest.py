import os
import sys

# Keep the default device count at 1 for smoke tests/benches (the dry-run
# sets its own XLA_FLAGS in a subprocess).
sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

# Property tests use hypothesis when installed; otherwise fall back to the
# minimal shim so the suite still collects and runs hermetically.
try:
    import hypothesis  # noqa: F401
except ImportError:
    import _hypothesis_fallback

    _hypothesis_fallback.install()

import jax
import jax.numpy as jnp
import pytest

from repro.configs import get_config
from repro.models import Model


@pytest.fixture(scope="session")
def rng_key():
    return jax.random.PRNGKey(0)


_MODEL_CACHE = {}


def reduced_model(arch: str):
    """Cached (cfg, model, params) for a reduced architecture."""
    if arch not in _MODEL_CACHE:
        cfg = get_config(arch).reduced()
        model = Model(cfg, param_dtype=jnp.float32)
        params = model.init(jax.random.PRNGKey(42))
        _MODEL_CACHE[arch] = (cfg, model, params)
    return _MODEL_CACHE[arch]
