"""Mamba2 SSD + RG-LRU: chunked-scan vs step-by-step recurrence, chunk-size
invariance, cache continuation."""
import dataclasses

import jax
import numpy as np

from repro.configs import get_config
from repro.models import kv_cache as kvc
from repro.models.rglru import init_rglru_block, rglru_block
from repro.models.ssm import init_ssm_block, ssm_block


def _ssm_cfg(chunk=8):
    cfg = get_config("mamba2-2.7b").reduced()
    return dataclasses.replace(cfg, ssm=dataclasses.replace(cfg.ssm,
                                                            chunk_size=chunk))


def test_chunk_size_invariance():
    """SSD output must not depend on the chunk size."""
    key = jax.random.PRNGKey(0)
    u = jax.random.normal(jax.random.PRNGKey(1), (2, 24, 256)) * 0.3
    outs = []
    for chunk in (4, 8, 24):
        cfg = _ssm_cfg(chunk)
        params = init_ssm_block(key, cfg)
        out, _ = ssm_block(params, u, cfg)
        outs.append(np.asarray(out))
    np.testing.assert_allclose(outs[0], outs[1], rtol=2e-4, atol=2e-4)
    np.testing.assert_allclose(outs[0], outs[2], rtol=2e-4, atol=2e-4)


def test_ssm_prefill_then_decode_matches_full():
    """prefill(x[:S]) + decode(x[S]) ≡ full forward over S+1 tokens."""
    cfg = _ssm_cfg(4)
    params = init_ssm_block(jax.random.PRNGKey(0), cfg)
    B, S = 2, 11
    u = jax.random.normal(jax.random.PRNGKey(2), (B, S + 1, cfg.d_model)) * 0.3
    want, _ = ssm_block(params, u, cfg)

    cache = kvc.init_ssm_cache(cfg, B)
    _, cache = ssm_block(params, u[:, :S], cfg, cache=cache)
    got, _ = ssm_block(params, u[:, S:], cfg, cache=cache)
    np.testing.assert_allclose(np.asarray(got[:, 0]), np.asarray(want[:, S]),
                               rtol=2e-3, atol=2e-3)


def test_ssm_decode_chain_matches_scan():
    """Running decode step-by-step over a sequence equals the chunked scan."""
    cfg = _ssm_cfg(4)
    params = init_ssm_block(jax.random.PRNGKey(0), cfg)
    B, S = 1, 9
    u = jax.random.normal(jax.random.PRNGKey(3), (B, S, cfg.d_model)) * 0.3
    want, _ = ssm_block(params, u, cfg)
    cache = kvc.init_ssm_cache(cfg, B)
    got = []
    for t in range(S):
        y, cache = ssm_block(params, u[:, t:t + 1], cfg, cache=cache)
        got.append(np.asarray(y[:, 0]))
    got = np.stack(got, axis=1)
    np.testing.assert_allclose(got, np.asarray(want), rtol=2e-3, atol=2e-3)


def test_rglru_prefill_then_decode_matches_full():
    cfg = get_config("recurrentgemma-2b").reduced()
    params = init_rglru_block(jax.random.PRNGKey(0), cfg)
    B, S = 2, 10
    u = jax.random.normal(jax.random.PRNGKey(4), (B, S + 1, cfg.d_model)) * 0.3
    want, _ = rglru_block(params, u, cfg)
    cache = kvc.init_lru_cache(cfg, B)
    _, cache = rglru_block(params, u[:, :S], cfg, cache=cache)
    got, _ = rglru_block(params, u[:, S:], cfg, cache=cache)
    np.testing.assert_allclose(np.asarray(got[:, 0]), np.asarray(want[:, S]),
                               rtol=2e-3, atol=2e-3)


def test_rglru_decode_chain():
    cfg = get_config("recurrentgemma-2b").reduced()
    params = init_rglru_block(jax.random.PRNGKey(0), cfg)
    B, S = 1, 7
    u = jax.random.normal(jax.random.PRNGKey(5), (B, S, cfg.d_model)) * 0.3
    want, _ = rglru_block(params, u, cfg)
    cache = kvc.init_lru_cache(cfg, B)
    got = []
    for t in range(S):
        y, cache = rglru_block(params, u[:, t:t + 1], cfg, cache=cache)
        got.append(np.asarray(y[:, 0]))
    np.testing.assert_allclose(np.stack(got, 1), np.asarray(want),
                               rtol=2e-3, atol=2e-3)


def test_rglru_decay_bounded():
    """RG-LRU gate: 0 < a < 1 always (stability)."""
    cfg = get_config("recurrentgemma-2b").reduced()
    params = init_rglru_block(jax.random.PRNGKey(0), cfg)
    lam = np.asarray(params["lam"])
    a_at_r1 = np.exp(-8.0 * np.log1p(np.exp(lam)))
    assert (a_at_r1 > 0).all() and (a_at_r1 < 1).all()
