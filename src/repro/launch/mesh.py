"""Production mesh construction.

``make_production_mesh`` is a FUNCTION (not a module constant) so importing
this module never touches jax device state; the dry-run sets
``XLA_FLAGS=--xla_force_host_platform_device_count=512`` before any jax
import to get placeholder devices.
"""
from __future__ import annotations

from typing import Tuple

import jax


def _make_mesh(shape, axes):
    """jax.make_mesh across jax versions: ``axis_types``/``AxisType``
    only exist in newer releases — explicit Auto axes there, default
    behaviour (equivalent) on older ones."""
    axis_type = getattr(jax.sharding, "AxisType", None)
    if axis_type is None:
        return jax.make_mesh(shape, axes)
    return jax.make_mesh(shape, axes,
                         axis_types=(axis_type.Auto,) * len(axes))


def make_production_mesh(*, multi_pod: bool = False):
    """Single pod: (data=16, model=16) = 256 chips (TPU v5e-256).
    Multi-pod: (pod=2, data=16, model=16) = 512 chips."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return _make_mesh(shape, axes)


def make_debug_mesh(model: int = 1, data: int = 1):
    """Tiny mesh over however many local devices exist (tests)."""
    return _make_mesh((data, model), ("data", "model"))


def mesh_axes(mesh) -> Tuple[Tuple[str, ...], str]:
    """(data_axes, model_axis) for a production or debug mesh."""
    names = mesh.axis_names
    model_axis = "model"
    data_axes = tuple(n for n in names if n != model_axis)
    return data_axes, model_axis


def parse_mesh_spec(spec: str) -> Tuple[int, int]:
    """Parse a serving ``--mesh`` string into ``(data, model)`` sizes.

    Accepted forms: ``"data=2,model=4"`` (any order), ``"2x4"`` /
    ``"2,4"`` (positional data,model), or a bare int (``"4"`` = model
    size, data=1).  ``model`` is the expert-parallel fast-device count;
    ``data`` replicates serving over independent data-parallel replicas.
    """
    s = spec.strip().lower()
    if not s:
        return 1, 1
    sizes = {"data": 1, "model": 1}
    if "=" in s:
        for part in s.replace("x", ",").split(","):
            name, _, val = part.partition("=")
            name = name.strip()
            assert name in sizes, f"unknown mesh axis {name!r} in {spec!r}"
            sizes[name] = int(val)
        return sizes["data"], sizes["model"]
    nums = [int(p) for p in s.replace("x", ",").split(",") if p.strip()]
    if len(nums) == 1:
        return 1, nums[0]
    assert len(nums) == 2, f"mesh spec {spec!r} needs 1 or 2 sizes"
    return nums[0], nums[1]


def make_serving_mesh(spec: str = "1,1"):
    """(data, model) serving mesh from a ``--mesh`` spec string, over the
    process's local devices.  Returns None for the 1×1 spec — the
    single-device engine needs no mesh object and must stay byte-for-byte
    the historical path (the bit-identity twin).  When the process has
    fewer devices than the spec asks for (the common simulation case),
    no mesh is built either: the engine's ``n_fast_devices`` ledger
    models the extra devices instead."""
    data, model = parse_mesh_spec(spec)
    assert data >= 1 and model >= 1, (data, model)
    if data * model == 1:
        return None
    if len(jax.devices()) < data * model:
        return None
    return _make_mesh((data, model), ("data", "model"))
