"""Beam-search decoding (paper scenario ⓒ, the 11.57× Fig. 6 result).

The beams form a decode batch of width W; per MoE layer the router sees
W tokens, so per-expert input sizes grow with the width — exactly the
regime where Fiddler's planner beats llama.cpp-style static splits.

Beam search is now a first-class *serving* workload riding the common
``ServingBackend`` slot API instead of a standalone cache-copying loop:

* the prompt is prefilled **once** and the other beams are created by
  ``fork_slot`` — under the paged KV layout (models/paged_kv.py) a fork
  is a block-table alias, so all beams *share* the prompt-prefix blocks;
* every reshuffle is ``reorder_slots`` — a block-table permutation plus
  refcount bumps, **zero KV data movement** (copy-on-write only when a
  beam's next token diverges into a shared block);
* the serving engines schedule a beam group as a gang: admitted,
  preempted and re-admitted atomically (``Request(beam_width=W)`` through
  ``ServingEngine``/``ContinuousEngine``).

:func:`beam_search_slots` is the gang kernel both engines use;
:func:`beam_search_fiddler` wraps it over a ``FiddlerBackend`` (kept for
the examples/back-compat); :func:`beam_search_model` is the monolithic
jitted reference (capacity-sufficient regime, dense cache reshuffles).
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import List, Optional, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from repro.serving.sampler import log_softmax


@dataclass
class BeamResult:
    tokens: np.ndarray      # (width, n_new), scores-descending
    scores: np.ndarray      # (width,)
    times: Optional[List[float]] = field(default=None)  # backend clock/token
    block_stats: Optional[dict] = None  # unique-vs-dense KV blocks (paged)


def _top_w(scores: np.ndarray, logp: np.ndarray, width: int):
    """Standard beam extension: (W,) scores + (W, V) log-probs → the top
    ``width`` (parent, token, score) triples, score-descending."""
    cand = scores[:, None] + logp
    flat = cand.reshape(-1)
    top = np.argsort(-flat)[:width]
    beam_idx, tok_idx = np.divmod(top, logp.shape[-1])
    return beam_idx, tok_idx.astype(np.int32), flat[top]


def beam_search_slots(backend, prompt: Sequence[int], width: int,
                      n_new: int, *,
                      prefill_chunk: Optional[int] = None) -> BeamResult:
    """Gang-scheduled beam search over any ``ServingBackend``.

    One shared prompt prefill, ``width - 1`` slot forks, then batched
    decode with table-only reshuffles.  Slots are released at the end, so
    the backend's block pool returns to its pre-call state."""
    prompt = [int(t) for t in np.asarray(prompt).reshape(-1)]
    S = len(prompt)
    cache = backend.make_cache(width)
    staging, done = None, 0
    size = prefill_chunk or S  # one chunk = whole prompt when chunking off
    while done < S:
        chunk = prompt[done: done + size]
        logits, staging = backend.prefill_chunk(staging, chunk, done)
        done += len(chunk)
    cache = backend.write_slot(cache, staging, 0)
    for j in range(1, width):
        cache = backend.fork_slot(cache, src=0, dst=j)  # shared-prefix alias

    logp = np.asarray(log_softmax(jnp.asarray(logits)[None]))[0]  # (V,)
    first = np.argsort(-logp)[:width]
    scores = logp[first]
    tokens = first[:, None].astype(np.int32)    # (W, 1)
    times = [backend.clock()]

    for step in range(1, n_new):
        pos = np.full(width, S + step - 1, np.int32)
        logits, cache = backend.decode_slots(
            cache, tokens[:, -1].astype(np.int32), pos,
            np.ones(width, bool))
        lp = np.asarray(log_softmax(jnp.asarray(logits)))
        beam_idx, tok_idx, scores = _top_w(scores, lp, width)
        tokens = np.concatenate([tokens[beam_idx], tok_idx[:, None]], axis=1)
        # the reshuffle: slot i continues beam beam_idx[i] — table-only
        # (zero KV copies) on paged backends
        cache = backend.reorder_slots(cache, slots=list(range(width)),
                                      src_of=[int(b) for b in beam_idx])
        times.append(backend.clock())

    stats = backend.block_stats(cache, list(range(width)))
    for j in range(width):
        cache = backend.release_slot(cache, slot=j)
    return BeamResult(tokens=tokens, scores=scores, times=times,
                      block_stats=stats)


def beam_search_fiddler(engine, prompt: np.ndarray, width: int, n_new: int,
                        max_seq: int) -> BeamResult:
    """Beam search through the Fiddler orchestrator (real numerics +
    simulated-latency ledger), on the gang-scheduled slot path."""
    from repro.serving.backend import FiddlerBackend

    backend = FiddlerBackend(engine, max_seq=max_seq)
    return beam_search_slots(backend, np.asarray(prompt).reshape(-1),
                             width, n_new)


def beam_search_model(model, params, prompt: np.ndarray, width: int,
                      n_new: int, max_seq: int) -> BeamResult:
    """prompt: (1, S) int32.  Monolithic jitted reference: beams are a
    static batch, reshuffles gather whole cache rows
    (``Model.reorder_cache`` — the dense layout's copying reshuffle)."""
    S = prompt.shape[1]
    prompts = np.repeat(prompt, width, axis=0)  # (W, S)
    prefill = jax.jit(lambda p, t: model.prefill(p, t, max_seq))
    decode = jax.jit(lambda p, c, t, pos: model.decode_step(p, c, t, pos, max_seq))

    logits, cache = prefill(params, jnp.asarray(prompts))
    logp = np.asarray(log_softmax(logits))  # (W, V)
    # first step: distinct top-W continuations of beam 0
    first = np.argsort(-logp[0])[:width]
    scores = logp[0, first]
    tokens = first[:, None].astype(np.int32)  # (W, 1)

    for step in range(1, n_new):
        pos = S + step - 1
        logits, cache = decode(params, cache,
                               jnp.asarray(tokens[:, -1:]), jnp.int32(pos))
        lp = np.asarray(log_softmax(logits))  # (W, V)
        beam_idx, tok_idx, scores = _top_w(scores, lp, width)
        tokens = np.concatenate([tokens[beam_idx], tok_idx[:, None]], axis=1)
        cache = model.reorder_cache(cache, beam_idx)
    return BeamResult(tokens=tokens, scores=scores)
