"""Training step + loop.

``make_train_step(model)`` returns the pure function that the launcher
pjit-compiles for the production mesh (and the multi-pod dry-run lowers for
every architecture × train shape).
"""
from __future__ import annotations

import time
from typing import Callable, Dict, Optional

import jax

from repro.models.model import Model, lm_loss
from repro.training.optimizer import AdamWConfig, adamw_update, init_opt_state


def make_loss_fn(model: Model) -> Callable:
    def loss_fn(params, batch):
        extra = {k: v for k, v in batch.items()
                 if k in ("image_embeds", "frames")}
        hidden, aux = model.forward_train(params, batch["tokens"],
                                          extra or None, remat=True)
        loss = lm_loss(model, params, hidden, batch["labels"])
        return loss + aux, {"lm_loss": loss, "aux_loss": aux}

    return loss_fn


def make_train_step(model: Model, opt_cfg: AdamWConfig = AdamWConfig()
                    ) -> Callable:
    loss_fn = make_loss_fn(model)

    def train_step(params, opt_state, batch):
        (loss, parts), grads = jax.value_and_grad(loss_fn, has_aux=True)(
            params, batch)
        params, opt_state, opt_stats = adamw_update(params, grads, opt_state,
                                                    opt_cfg)
        metrics = {"loss": loss, **parts, **opt_stats}
        return params, opt_state, metrics

    return train_step


def train(model: Model, params, data_iter, n_steps: int,
          opt_cfg: AdamWConfig = AdamWConfig(),
          log_every: int = 10,
          callback: Optional[Callable[[int, Dict[str, float]], None]] = None):
    """Single-host eager training loop (examples + integration tests)."""
    opt_state = init_opt_state(params)
    step_fn = jax.jit(make_train_step(model, opt_cfg))
    history = []
    t0 = time.perf_counter()
    for step in range(n_steps):
        batch = next(data_iter)
        params, opt_state, metrics = step_fn(params, opt_state, batch)
        if step % log_every == 0 or step == n_steps - 1:
            m = {k: float(v) for k, v in metrics.items()}
            m["step"] = step
            m["wall"] = time.perf_counter() - t0
            history.append(m)
            if callback:
                callback(step, m)
    return params, opt_state, history
