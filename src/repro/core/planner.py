"""Fiddler's execution planner — Algorithm 1 of the paper.

Given the router's per-expert input sizes for one MoE layer, decide for each
activated expert whether to execute it

* ``FAST_RESIDENT`` — weights already on the fast tier → execute there;
* ``FAST_STREAM``   — stream weights slow→fast, execute on the fast tier
  (what offloading systems always do);
* ``SLOW``          — ship activations to the slow tier and execute there
  (what llama.cpp effectively does for host layers).

The rule (paper Alg. 1 line 12): stream iff
``cpu_lat(s) > gpu_lat(s) + transfer_lat()``.

Both a numpy planner (used by the serving orchestrator, where decisions are
data-dependent python control flow — the paper's system is eager too) and a
jnp planner (for property tests / potential on-device planning) are
provided, plus a brute-force optimal baseline used by the hypothesis tests.
"""
from __future__ import annotations

from dataclasses import dataclass
from enum import IntEnum

import numpy as np

from repro.core.cost_model import LatencyModel


class Decision(IntEnum):
    SKIP = -1           # expert received no tokens
    FAST_RESIDENT = 0   # paper Fig. 3 (a)
    FAST_STREAM = 1     # paper Fig. 3 (b)
    SLOW = 2            # paper Fig. 3 (c)


@dataclass(frozen=True)
class LayerPlan:
    decisions: np.ndarray        # (E,) Decision values
    est_fast_time: float         # est. serial time of fast-tier work (s)
    est_slow_time: float         # est. serial time of slow-tier work (s)
    est_stream_time: float       # est. weight-streaming time (s)

    @property
    def est_total(self) -> float:
        """Non-overlapped estimate (paper's conservative model)."""
        return self.est_fast_time + self.est_slow_time + self.est_stream_time

    @property
    def est_overlapped(self) -> float:
        """Fast tier and slow tier run concurrently (beyond-paper overlap
        model; streaming serialises with fast-tier compute)."""
        return max(self.est_fast_time + self.est_stream_time,
                   self.est_slow_time)


def plan_layer(input_sizes: np.ndarray, on_fast: np.ndarray,
               lat: LatencyModel) -> LayerPlan:
    """Algorithm 1, vectorised over the experts of one layer.

    input_sizes: (E,) tokens routed to each expert (s in the paper).
    on_fast:     (E,) bool — is_at_gpu(i, j).
    """
    s = np.asarray(input_sizes, np.int64)
    on_fast = np.asarray(on_fast, bool)
    E = s.shape[0]
    dec = np.full(E, int(Decision.SKIP), np.int64)

    active = s > 0
    # line 10: resident experts always execute on the fast tier
    dec[active & on_fast] = int(Decision.FAST_RESIDENT)
    # line 12: cpu_lat(s) > gpu_lat(s) + transfer_lat() → stream to fast
    missing = active & ~on_fast
    stream_better = lat.cpu_lat(s) > (lat.gpu_lat(s) + lat.transfer_lat())
    dec[missing & stream_better] = int(Decision.FAST_STREAM)
    dec[missing & ~stream_better] = int(Decision.SLOW)

    fast_mask = dec == int(Decision.FAST_RESIDENT)
    stream_mask = dec == int(Decision.FAST_STREAM)
    slow_mask = dec == int(Decision.SLOW)
    est_fast = float(lat.gpu_lat(s)[fast_mask | stream_mask].sum())
    est_stream = float(stream_mask.sum()) * lat.transfer_lat()
    est_slow = float(lat.cpu_lat(s)[slow_mask].sum())
    return LayerPlan(dec, est_fast, est_slow, est_stream)


def plan_layer_jnp(input_sizes, on_fast, lat: LatencyModel):
    """jit-friendly version of Algorithm 1 (same semantics)."""
    import jax.numpy as jnp

    s = input_sizes.astype(jnp.float32)
    cpu = jnp.where(s > 0, lat.cpu_base + (lat.cpu_per_token + lat.act_per_token) * s, 0.0)
    gpu = jnp.where(s > 0, lat.gpu_const + lat.gpu_per_token * s, 0.0)
    stream_better = cpu > gpu + lat.weight_transfer
    dec = jnp.where(
        s <= 0, int(Decision.SKIP),
        jnp.where(on_fast, int(Decision.FAST_RESIDENT),
                  jnp.where(stream_better, int(Decision.FAST_STREAM),
                            int(Decision.SLOW))))
    return dec


def brute_force_plan(input_sizes: np.ndarray, on_fast: np.ndarray,
                     lat: LatencyModel) -> np.ndarray:
    """Per-expert exhaustive minimisation of the paper's cost model —
    the oracle the hypothesis tests compare Algorithm 1 against."""
    s = np.asarray(input_sizes, np.int64)
    E = s.shape[0]
    out = np.full(E, int(Decision.SKIP), np.int64)
    for j in range(E):
        if s[j] == 0:
            continue
        if on_fast[j]:
            out[j] = int(Decision.FAST_RESIDENT)
            continue
        cost_stream = float(lat.gpu_lat(s[j])) + lat.transfer_lat()
        cost_slow = float(lat.cpu_lat(s[j]))
        out[j] = int(Decision.FAST_STREAM) if cost_slow > cost_stream else int(Decision.SLOW)
    return out
