"""Perf-knob (distributed/opts.py) correctness: every optimization must be
numerics-preserving (or bf16-level for the bf16 knob)."""

import jax
import jax.numpy as jnp
import pytest

from repro.configs import get_config
from repro.distributed import opts
from repro.kernels.ref import flash_attention_ref
from repro.models.attention import chunked_attention
from repro.models.ssm import init_ssm_block, ssm_block


@pytest.fixture(autouse=True)
def _reset_opts():
    yield
    opts.FSDP_EXPERTS = False
    opts.SEQ_SHARD_ACTS = False
    opts.SPLIT_SSM_PROJ = False
    opts.BF16_ATTN_SCORES = False


def test_bf16_attn_scores_close_to_f32():
    ks = jax.random.split(jax.random.PRNGKey(0), 3)
    B, S, H, hd = 2, 48, 4, 32
    q, k, v = [jax.random.normal(kk, (B, S, H, hd)) * 0.3 for kk in ks]
    pos = jnp.broadcast_to(jnp.arange(S)[None], (B, S))
    ref = flash_attention_ref(q, k, v)
    opts.BF16_ATTN_SCORES = True
    got = chunked_attention(q, k, v, pos, pos, kv_chunk=16)
    err = float(jnp.abs(got - ref).max())
    assert err < 0.02, err


def test_split_ssm_proj_same_distribution():
    """Split projection is a different parameterisation (different init
    keys) — verify forward works and params are properly partitioned."""
    cfg = get_config("mamba2-2.7b").reduced()
    opts.SPLIT_SSM_PROJ = True
    params = init_ssm_block(jax.random.PRNGKey(0), cfg)
    assert "w_z" in params and "in_proj" not in params
    u = jax.random.normal(jax.random.PRNGKey(1), (2, 16, cfg.d_model)) * 0.3
    out, _ = ssm_block(params, u, cfg)
    assert bool(jnp.all(jnp.isfinite(out)))
    # dims line up with the fused variant
    from repro.models.ssm import ssm_dims
    dims = ssm_dims(cfg)
    assert params["w_z"].shape == (cfg.d_model, dims["inner"])
    assert params["w_xbc"].shape == (cfg.d_model, dims["conv_dim"])
    assert params["w_dt"].shape == (cfg.d_model, dims["n_heads"])


def test_fsdp_specs_divisibility_guard():
    from repro.models.moe import fsdp_applicable, moe_param_specs

    cfg = get_config("kimi-k2-1t-a32b")
    assert fsdp_applicable(cfg, "ep", 16)         # d_ff 2048 % 16
    assert not fsdp_applicable(cfg, "ep", 3000)
    specs = moe_param_specs(cfg, "model", 16, fsdp_axes=("data",),
                            fsdp_size=16)
    assert specs["w_gate"][2] == "data"  # P normalises 1-tuples
    specs_nd = moe_param_specs(cfg, "model", 16, fsdp_axes=("data",),
                               fsdp_size=3000)
    assert specs_nd["w_gate"][2] is None
