"""Beyond-paper orchestrator extensions.

1. :class:`LRUExpertCache` — Mixtral-Offloading (Eliseev & Mazur 2023)
   keeps an LRU cache of recently-streamed experts in spare fast-tier
   memory.  Fiddler's placement is static; adding the cache on top of
   Algorithm 1 is strictly complementary: a FAST_STREAM decision inserts
   the expert, future hits skip both the transfer and the slow path.

2. :class:`AdaptivePlacement` — the paper profiles popularity offline and
   fixes the placement (§3.4, "popularity is almost universal across
   domains").  For workloads where that fails (App. D's distribution
   shift), we maintain an EMA of observed routing and periodically
   re-place; the swap cost is charged to the simulated clock.

3. int8 expert storage (``quantize=True`` on HostExpert streams /
   :func:`quantize_expert`) — the paper calls compression orthogonal
   (§2.2); per-channel symmetric int8 halves stream bytes and doubles
   the fast-tier expert budget at ~1e-2 relative error.
"""
from __future__ import annotations

from collections import OrderedDict
from dataclasses import dataclass
from typing import Optional, Tuple

import numpy as np

from repro.core.placement import Placement
from repro.core.popularity import ExpertProfile


# ---------------------------------------------------------------------------
# LRU cache of streamed experts
# ---------------------------------------------------------------------------


class LRUExpertCache:
    """Tracks which streamed experts currently sit in spare fast memory.

    Keys are (layer, expert).  Capacity is in experts (the orchestrator
    converts spare bytes / expert bytes)."""

    def __init__(self, capacity: int):
        self.capacity = max(0, int(capacity))
        self._slots: "OrderedDict[Tuple[int, int], bool]" = OrderedDict()
        self.hits = 0
        self.misses = 0

    def __contains__(self, key: Tuple[int, int]) -> bool:
        return key in self._slots

    def lookup(self, layer: int, expert: int) -> bool:
        key = (layer, expert)
        if self.capacity and key in self._slots:
            self._slots.move_to_end(key)
            self.hits += 1
            return True
        self.misses += 1
        return False

    def insert(self, layer: int, expert: int) -> Optional[Tuple[int, int]]:
        """Insert after a stream; returns the evicted key (if any)."""
        if not self.capacity:
            return None
        key = (layer, expert)
        self._slots[key] = True
        self._slots.move_to_end(key)
        if len(self._slots) > self.capacity:
            return self._slots.popitem(last=False)[0]
        return None

    @property
    def occupancy(self) -> int:
        return len(self._slots)


# ---------------------------------------------------------------------------
# Adaptive placement
# ---------------------------------------------------------------------------


@dataclass
class AdaptivePlacement:
    """EMA popularity tracker + periodic greedy re-placement."""

    budget: int
    decay: float = 0.98
    refresh_every: int = 256  # layer-steps between re-placements

    def __post_init__(self):
        self._ema: Optional[np.ndarray] = None
        self._steps = 0
        self.replacements = 0
        self.swapped_experts = 0

    def observe(self, layer: int, counts: np.ndarray, n_layers: int) -> None:
        if self._ema is None:
            self._ema = np.zeros((n_layers, counts.shape[0]))
        self._ema[layer] = self.decay * self._ema[layer] + \
            (1 - self.decay) * counts
        self._steps += 1

    def maybe_replace(self, current: Placement) -> Tuple[Placement, int]:
        """Returns (placement, n_swapped).  n_swapped experts must be
        streamed in (cost charged by the caller)."""
        if self._ema is None or self._steps % self.refresh_every != 0:
            return current, 0
        from repro.core.placement import place_by_popularity

        prof = ExpertProfile(self._ema + 1e-9)
        new = place_by_popularity(prof, self.budget)
        swapped = int((new.on_fast & ~current.on_fast).sum())
        if swapped == 0:
            return current, 0
        self.replacements += 1
        self.swapped_experts += swapped
        return new, swapped


# ---------------------------------------------------------------------------
# int8 quantization (per-output-channel symmetric)
# ---------------------------------------------------------------------------


def quantize_expert(w: np.ndarray) -> Tuple[np.ndarray, np.ndarray]:
    """w: (in, out) fp32 → (int8 (in, out), scale (out,))."""
    scale = np.abs(w).max(axis=0) / 127.0
    scale = np.maximum(scale, 1e-12)
    q = np.clip(np.round(w / scale), -127, 127).astype(np.int8)
    return q, scale.astype(np.float32)


def dequantize_expert(q: np.ndarray, scale: np.ndarray) -> np.ndarray:
    return q.astype(np.float32) * scale


class QuantizedHostExpert:
    """int8 slow-tier expert: half the stream bytes, half the DRAM reads."""

    __slots__ = ("q_gate", "s_gate", "q_up", "s_up", "q_down", "s_down",
                 "block_f")

    def __init__(self, w_gate, w_up, w_down, block_f: int = 1024):
        self.q_gate, self.s_gate = quantize_expert(np.asarray(w_gate, np.float32))
        self.q_up, self.s_up = quantize_expert(np.asarray(w_up, np.float32))
        self.q_down, self.s_down = quantize_expert(np.asarray(w_down, np.float32))
        self.block_f = block_f

    def nbytes(self) -> int:
        return (self.q_gate.size + self.q_up.size + self.q_down.size
                + 4 * (self.s_gate.size + self.s_up.size + self.s_down.size))

    def weights(self) -> Tuple[np.ndarray, np.ndarray, np.ndarray]:
        return (dequantize_expert(self.q_gate, self.s_gate),
                dequantize_expert(self.q_up, self.s_up),
                dequantize_expert(self.q_down, self.s_down))

    def __call__(self, x: np.ndarray) -> np.ndarray:
        x = np.asarray(x, np.float32)
        f = self.q_gate.shape[1]
        out = np.zeros((x.shape[0], self.q_down.shape[1]), np.float32)
        for j0 in range(0, f, self.block_f):
            j1 = min(j0 + self.block_f, f)
            g = (x @ self.q_gate[:, j0:j1].astype(np.float32)) * self.s_gate[j0:j1]
            u = (x @ self.q_up[:, j0:j1].astype(np.float32)) * self.s_up[j0:j1]
            h = g / (1.0 + np.exp(-g)) * u
            out += (h @ self.q_down[j0:j1].astype(np.float32))
        return out * self.s_down
