"""Phi-3.5-MoE [arXiv:2404.14219] — paper Appendix E portability model.

32L d_model=4096 32H (GQA kv=8) d_ff=6400, MoE 16 experts top-2,
vocab 32064.  (Not part of the assigned pool — used by the App. E
benchmark to show model-agnosticism, like the paper does.)
"""
from repro.configs.base import ModelConfig, MoEConfig, register


@register("phi-3.5-moe")
def phi35_moe() -> ModelConfig:
    return ModelConfig(
        name="phi-3.5-moe",
        arch_type="moe",
        n_layers=32,
        d_model=4096,
        n_heads=32,
        n_kv_heads=8,
        head_dim=128,
        d_ff=6400,
        vocab_size=32064,
        moe=MoEConfig(n_experts=16, top_k=2, router_type="softmax"),
        rope_theta=10000.0,
        citation="[arXiv:2404.14219] Phi-3.5-MoE (paper App. E)",
    )
