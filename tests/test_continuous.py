"""Continuous batching: per-slot positions must produce exactly the same
greedy continuations as isolated single-request decoding, with slot
reuse and mid-flight joins — through the monolithic jitted Model and
through the Fiddler orchestrator backend (whose ledger advances in
simulated seconds and feeds per-request TTFT/ITL)."""
import jax.numpy as jnp
import numpy as np
import pytest

from conftest import reduced_model
from repro.core import FiddlerEngine
from repro.serving.backend import FiddlerBackend, ModelBackend
from repro.serving.continuous import ContinuousEngine
from repro.serving.engine import Request


def _reference_generation(model, params, prompt, n_new, max_seq=64):
    logits, cache = model.prefill(params, jnp.asarray([prompt], jnp.int32),
                                  max_seq=max_seq, cache_dtype=jnp.float32)
    out = [int(jnp.argmax(logits[0]))]
    for t in range(n_new - 1):
        logits, cache = model.decode_step(
            params, cache, jnp.asarray([[out[-1]]], jnp.int32),
            jnp.int32(len(prompt) + t), max_seq=max_seq)
        out.append(int(jnp.argmax(logits[0])))
    return out


@pytest.mark.parametrize("arch", ["qwen3-0.6b", "mixtral-8x7b"])
def test_continuous_matches_isolated(arch):
    cfg, model, params = reduced_model(arch)
    prompts = [[1, 17, 23, 9], [1, 40, 11], [1, 7, 7, 7, 2, 30],
               [1, 300, 5], [1, 12, 90, 44, 3]]
    n_new = 5
    # more requests than slots → forces slot reuse + mid-flight joins
    eng = ContinuousEngine(model, params, n_slots=2, max_seq=64)
    for i, p in enumerate(prompts):
        eng.submit(Request(rid=f"r{i}", prompt=p, max_new_tokens=n_new))
    done = {r.rid: r for r in eng.run()}
    assert len(done) == len(prompts)
    for i, p in enumerate(prompts):
        want = _reference_generation(model, params, p, n_new)
        got = done[f"r{i}"].output
        # EOS may truncate both identically; compare common prefix length
        assert got == want[: len(got)], (i, got, want)
        assert len(got) >= 1


def test_slots_do_not_leak_between_requests():
    """A request joining a reused slot must not see the previous
    occupant's KV entries."""
    cfg, model, params = reduced_model("qwen3-0.6b")
    p1, p2 = [1, 5, 9, 13, 2], [1, 30, 31]
    eng = ContinuousEngine(model, params, n_slots=1, max_seq=64)
    eng.submit(Request(rid="a", prompt=p1, max_new_tokens=4))
    eng.submit(Request(rid="b", prompt=p2, max_new_tokens=4))
    done = {r.rid: r for r in eng.run()}
    want_b = _reference_generation(model, params, p2, 4)
    assert done["b"].output == want_b[: len(done['b'].output)]


PROMPTS = [[1, 17, 23, 9], [1, 40, 11], [1, 7, 7, 7, 2, 30], [1, 300, 5]]


def _fiddler_backend(policy="fiddler", max_seq=64):
    cfg, model, params = reduced_model("mixtral-8x7b")
    fe = FiddlerEngine(cfg, params, policy=policy, expert_budget=30,
                       host_precision="fp32")
    return fe, FiddlerBackend(fe, max_seq=max_seq)


def test_continuous_fiddler_matches_model():
    """Orchestrated continuous batching ≡ monolithic Model path
    token-for-token, while the ledger advances in simulated seconds."""
    cfg, model, params = reduced_model("mixtral-8x7b")
    fe, backend = _fiddler_backend()
    eng = ContinuousEngine(backend, n_slots=2, max_seq=64)
    n_new = 5
    for i, p in enumerate(PROMPTS):
        eng.submit(Request(rid=f"r{i}", prompt=p, max_new_tokens=n_new))
    done = {r.rid: r for r in eng.run()}
    assert len(done) == len(PROMPTS)
    for i, p in enumerate(PROMPTS):
        want = _reference_generation(model, params, p, n_new)
        got = done[f"r{i}"].output
        assert got == want[: len(got)], (i, got, want)
        assert len(got) >= 1
    # the clock is the orchestrator's simulated-seconds ledger
    assert fe.ledger.sim_time > 0
    assert fe.ledger.tokens_out >= len(PROMPTS)


def test_continuous_fiddler_chunked_prefill_matches_model():
    """Chunked admission (2 tokens/step, interleaved with in-flight
    decodes) must not change any request's tokens."""
    cfg, model, params = reduced_model("mixtral-8x7b")
    fe, backend = _fiddler_backend()
    eng = ContinuousEngine(backend, n_slots=2, max_seq=64, prefill_chunk=2)
    for i, p in enumerate(PROMPTS):
        eng.submit(Request(rid=f"r{i}", prompt=p, max_new_tokens=4))
    done = {r.rid: r for r in eng.run()}
    for i, p in enumerate(PROMPTS):
        want = _reference_generation(model, params, p, 4)
        got = done[f"r{i}"].output
        assert got == want[: len(got)], (i, got, want)


def test_continuous_model_chunked_prefill_matches_isolated():
    cfg, model, params = reduced_model("qwen3-0.6b")
    eng = ContinuousEngine(ModelBackend(model, params, max_seq=64),
                           n_slots=2, max_seq=64, prefill_chunk=3)
    for i, p in enumerate(PROMPTS):
        eng.submit(Request(rid=f"r{i}", prompt=p, max_new_tokens=4))
    done = {r.rid: r for r in eng.run()}
    for i, p in enumerate(PROMPTS):
        want = _reference_generation(model, params, p, 4)
        got = done[f"r{i}"].output
        assert got == want[: len(got)], (i, got, want)


def test_ttft_itl_from_simulated_clock():
    """Per-request TTFT/ITL must be measured on the simulated clock:
    positive, and every request's token timestamps strictly increasing
    and bounded by the final ledger time."""
    fe, backend = _fiddler_backend()
    eng = ContinuousEngine(backend, n_slots=2, max_seq=64)
    for i, p in enumerate(PROMPTS):
        eng.submit(Request(rid=f"r{i}", prompt=p, max_new_tokens=6))
    done = eng.run()
    assert len(done) == len(PROMPTS)
    for r in done:
        assert r.ttft is not None and r.ttft > 0
        assert r.latency is not None and r.latency >= r.ttft
        assert len(r.token_times) == len(r.output)
        diffs = np.diff(r.token_times)
        assert (diffs > 0).all(), r.token_times  # decode charges per step
        assert r.itl is not None and r.itl > 0
        assert r.token_times[-1] <= fe.ledger.sim_time + 1e-12


def test_arrival_gated_admission():
    """Requests with future arrival times are admitted only once the
    simulated clock reaches them (idle pools fast-forward)."""
    fe, backend = _fiddler_backend()
    eng = ContinuousEngine(backend, n_slots=2, max_seq=64)
    t_gap = 0.5  # far beyond the sim time of a few decode steps
    eng.submit(Request(rid="now", prompt=[1, 4, 2], max_new_tokens=3,
                       arrival=0.0))
    eng.submit(Request(rid="later", prompt=[1, 9, 5], max_new_tokens=3,
                       arrival=t_gap))
    done = {r.rid: r for r in eng.run()}
    assert len(done) == 2
    first_tok_later = done["later"].token_times[0]
    assert first_tok_later >= t_gap
    # TTFT is measured from arrival, not from engine start
    assert done["later"].ttft < t_gap / 2


def test_throughput_accounting():
    cfg, model, params = reduced_model("qwen3-0.6b")
    eng = ContinuousEngine(model, params, n_slots=3, max_seq=64)
    for i in range(4):
        eng.submit(Request(rid=f"r{i}", prompt=[1, 2 + i], max_new_tokens=3))
    done = eng.run()
    assert len(done) == 4
    assert all(r.ttft is not None and r.latency is not None for r in done)
