"""Paper Figure 4 (+ Figures 11/12): end-to-end single-request tokens/s for
15 input/output-length configurations, Fiddler vs baselines, both paper
environments.  ``--breakdown`` adds the TTFT/ITL split (Fig. 11/12)."""
import itertools

from benchmarks.common import POLICIES, emit, engine_for

IN_LENS = [32, 64, 128, 256]
OUT_LENS = [64, 128, 256, 512]
# the paper uses 15 of the 16 combinations (drops 256/512)
CONFIGS = [c for c in itertools.product(IN_LENS, OUT_LENS)
           if c != (256, 512)]


def run(model: str = "mixtral-8x7b", envs=("env1", "env2"),
        breakdown: bool = False, fast: bool = False):
    configs = CONFIGS[:4] if fast else CONFIGS
    summary = {}
    for env in envs:
        per_policy = {p: [] for p in POLICIES}
        for (n_in, n_out) in configs:
            for policy in POLICIES:
                eng = engine_for(model, policy, env)
                r = eng.simulate_generate(prompt_len=n_in, gen_len=n_out)
                per_policy[policy].append(r)
                emit(f"e2e/{env}/{policy}/in{n_in}_out{n_out}",
                     r["itl"] * 1e6, f"tok_per_s={r['tokens_per_s']:.2f}")
                if breakdown:
                    emit(f"ttft/{env}/{policy}/in{n_in}_out{n_out}",
                         r["ttft"] * 1e6, "")
                    emit(f"itl/{env}/{policy}/in{n_in}_out{n_out}",
                         r["itl"] * 1e6, "")
        means = {p: sum(x["tokens_per_s"] for x in rs) / len(rs)
                 for p, rs in per_policy.items()}
        best_baseline = max(means["offload"], means["static_split"])
        speedup = means["fiddler"] / best_baseline
        emit(f"e2e/{env}/avg_speedup_vs_best_baseline", 0.0,
             f"{speedup:.2f}x (paper: 1.26x avg)")
        summary[env] = (means, speedup)
    return summary


if __name__ == "__main__":
    import sys
    run(breakdown="--breakdown" in sys.argv)
