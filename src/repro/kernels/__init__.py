"""Pallas TPU kernels for the paper's compute hot-spots + the host
(slow-tier) kernel.  See DESIGN.md §7 for the GPU→TPU rethinking.

  expert_mlp       fused gated-SiLU expert MLP (VMEM-tiled) — the TPU
                   analogue of the paper's AVX512_BF16 CPU kernel
  moe_gmm          grouped per-expert matmul with count-guarded tiles
                   (+ moe_gmm_mlp: three of them fused into a gated MLP)
  flash_attention  causal/windowed flash attention (VMEM-resident scores)
  host_expert      the slow-tier bf16 kernel (numpy; paper Fig. 3c path)
  ops              jit'd wrappers;  ref — pure-jnp oracles
"""
from repro.kernels.host_expert import HostExpert, host_expert_mlp  # noqa: F401
from repro.kernels.ops import (  # noqa: F401
    expert_mlp_op,
    grouped_gated_mlp_op,
    grouped_gather_mlp_op,
    moe_gmm_op,
)
