"""Continuous-batching load benchmark: Poisson arrivals through the
orchestrated serving scheduler.

Two modes share one sweep harness:

* **reduced real numerics** — a Poisson load generator (arrivals in
  *simulated* seconds on the paper-env hardware specs) drives
  ``ContinuousEngine`` over a ``FiddlerBackend``: real reduced-Mixtral
  numerics, full-size-config latency constants (``timing_cfg``), chunked
  admission.
* **pure simulation at paper scale** — the same scheduler over a
  ``SimulatedBackend`` wrapping a *param-less* ``FiddlerEngine`` on the
  full Mixtral-8x7B config: routing sampled from the popularity profile,
  only the ledger advances.  This is where heavy-traffic (tens of req/s)
  sweeps get paper-scale numbers on a bare CPU container.

Both sweep arrival rate × slot count across the orchestrator policies
*and* the scheduler policies (``fifo`` / ``priority`` / ``autoscale`` —
see serving/policy.py), reporting throughput (tokens / simulated second),
mean/p95 TTFT overall and per SLO class, mean ITL, and preemption counts.

A shared-prefix axis (``serve_load_prefix/...`` keys, all modes
including ``--smoke``) runs the cross-request prefix cache against a
no-cache control on a same-preamble workload (``--prefix-pool N
--prefix-len L``): a warm phase primes the index, then a high-rate
flood measures p95 TTFT, matched tokens, peak unique/dense KV residency
(sampled every scheduler tick) and leaked blocks — prefix hits must cut
both TTFT and peak unique KV bytes.

A disaggregation axis (``serve_load_disagg/...`` keys, all modes
including ``--smoke``) sweeps the roofline prefill/decode-disaggregated
scheduler (``RooflinePolicy``: saturating prefill chunks overlapped
under the decode stream) against interleaved FIFO at paper-scale
Mixtral-8x7B simulation with long prompts — CI gates on roofline
beating FIFO throughput at the high-rate point without regressing
interactive p95 TTFT.  Results are dumped to ``BENCH_serve_load.json``
at the repo root.
"""
from __future__ import annotations

import json
from pathlib import Path
from typing import Dict, List

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import ENVS, POLICIES, emit
from repro.configs import get_config
from repro.core import FiddlerEngine
from repro.serving.backend import FiddlerBackend, SimulatedBackend
from repro.serving.continuous import ContinuousEngine
from repro.serving.engine import Request

MAX_SEQ = 48
PREFILL_CHUNK = 8
SIM_MAX_SEQ = 256
SIM_PREFILL_CHUNK = 16
SCHED_POLICIES = ("fifo", "priority", "autoscale")
RESULTS_JSON = Path(__file__).resolve().parents[1] / "BENCH_serve_load.json"

_model_cache = {}


def _reduced(model_name: str):
    if model_name not in _model_cache:
        from repro.models import Model

        full = get_config(model_name)
        cfg = full.reduced()
        model = Model(cfg, param_dtype=jnp.float32)
        params = model.init(jax.random.PRNGKey(0))
        _model_cache[model_name] = (full, cfg, model, params)
    return _model_cache[model_name]


def _prefix_pools(prefix_pool: int, prefix_len: int,
                  seed: int) -> List[List[int]]:
    """Deterministic shared preambles (system prompts) for the
    shared-prefix workload axis — the warm phase and the load generator
    both derive the same pool from the seed."""
    rng = np.random.default_rng(seed + 7919)
    return [[1] + rng.integers(3, 250, size=prefix_len - 1).tolist()
            for _ in range(prefix_pool)]


def poisson_requests(rate_hz: float, n: int, *, prompt_len: int = 12,
                     max_new: int = 8, seed: int = 0,
                     interactive_frac: float = 0.0, prefix_pool: int = 0,
                     prefix_len: int = 0, t0: float = 0.0) -> List[Request]:
    """n requests with exponential inter-arrival gaps at ``rate_hz``
    (simulated seconds, starting at ``t0``) and random prompts; a
    ``interactive_frac`` fraction is tagged with the high-priority
    ``interactive`` SLO class (the rest are ``batch``).  With
    ``prefix_pool > 0`` every prompt is one of ``prefix_pool`` shared
    ``prefix_len``-token preambles (round-robin) followed by a unique
    ``prompt_len``-token tail — the cross-request prefix-cache workload."""
    rng = np.random.default_rng(seed)
    pools = _prefix_pools(prefix_pool, prefix_len, seed) if prefix_pool else []
    t = t0
    reqs = []
    for i in range(n):
        t += rng.exponential(1.0 / rate_hz)
        if pools:
            prompt = list(pools[i % len(pools)])
            prompt += rng.integers(3, 250, size=prompt_len).tolist()
        else:
            plen = int(rng.integers(prompt_len // 2, prompt_len + 1))
            prompt = [1] + rng.integers(3, 250, size=plen - 1).tolist()
        slo = ("interactive" if rng.random() < interactive_frac else "batch")
        reqs.append(Request(rid=f"r{i}", prompt=prompt,
                            max_new_tokens=max_new, arrival=t,
                            slo_class=slo))
    return reqs


def _metrics(done: List[Request], led) -> Dict[str, float]:
    n_tokens = sum(len(r.output) for r in done)
    ttfts = [r.ttft for r in done]
    itls = [r.itl for r in done if r.itl is not None]
    out = {
        "throughput_tok_per_s": n_tokens / led.sim_time if led.sim_time else 0.0,
        "mean_ttft": float(np.mean(ttfts)),
        "p95_ttft": float(np.percentile(ttfts, 95)),
        "mean_itl": float(np.mean(itls)) if itls else 0.0,
        "hit_rate": led.fast_hits / max(led.fast_hits + led.streams
                                        + led.slow_runs, 1),
        "preemptions": float(sum(r.preemptions for r in done)),
    }
    by_class: Dict[str, List[float]] = {}
    for r in done:
        by_class.setdefault(r.slo_class, []).append(r.ttft)
    for c, vals in sorted(by_class.items()):
        out[f"mean_ttft_{c}"] = float(np.mean(vals))
        out[f"p95_ttft_{c}"] = float(np.percentile(vals, 95))
    return out


def serve_once(model_name: str, policy: str, env: str, *, rate_hz: float,
               n_slots: int, n_requests: int, seed: int = 0,
               sched: str = "fifo",
               interactive_frac: float = 0.0) -> Dict[str, float]:
    """Reduced real-numerics run: orchestrated execution, real weights."""
    full, cfg, model, params = _reduced(model_name)
    eng = FiddlerEngine(cfg, params, policy=policy, hw=ENVS[env],
                        timing_cfg=full, host_precision="fp32",
                        expert_budget=cfg.n_layers * cfg.moe.n_experts // 4,
                        seed=seed)
    serving = ContinuousEngine(FiddlerBackend(eng, max_seq=MAX_SEQ),
                               n_slots=n_slots, max_seq=MAX_SEQ,
                               prefill_chunk=PREFILL_CHUNK, policy=sched)
    for r in poisson_requests(rate_hz, n_requests, seed=seed,
                              interactive_frac=interactive_frac):
        serving.submit(r)
    done = serving.run()
    assert len(done) == n_requests, (len(done), n_requests)
    return _metrics(done, eng.ledger)


def simulate_once(model_name: str, policy: str, env: str, *, rate_hz: float,
                  n_slots: int, n_requests: int, seed: int = 0,
                  sched: str = "fifo", interactive_frac: float = 0.25,
                  prompt_len: int = 64, max_new: int = 24,
                  prefix_pool: int = 0, prefix_len: int = 0,
                  prefix_cache: bool = True) -> Dict[str, float]:
    """Paper-scale pure simulation: full-size config, no params — the
    ``simulate_*`` ledger path under the real scheduler.

    With ``prefix_pool > 0`` the workload is the shared-prefix axis:
    ``prompt_len`` becomes the unique tail length behind a shared
    ``prefix_len``-token preamble, a warm phase primes the prefix index
    (one request per preamble, excluded from metrics — it runs in the
    ``prefix_cache=False`` control too so both sides pay identical
    warm-up work), and peak unique/dense KV residency is sampled every
    scheduler tick."""
    cfg = get_config(model_name)
    eng = FiddlerEngine(cfg, policy=policy, hw=ENVS[env], seed=seed,
                        prefix_cache=prefix_cache)
    serving = ContinuousEngine(SimulatedBackend(eng, max_seq=SIM_MAX_SEQ),
                               n_slots=n_slots, max_seq=SIM_MAX_SEQ,
                               prefill_chunk=SIM_PREFILL_CHUNK, policy=sched)
    if prefix_pool:
        for p, pre in enumerate(_prefix_pools(prefix_pool, prefix_len, seed)):
            serving.submit(Request(rid=f"warm{p}", prompt=list(pre) + [3],
                                   max_new_tokens=1))
        serving.run(max_steps=100_000, on_exhausted="raise")
    led = eng.ledger
    l0 = (led.prefix_lookups, led.prefix_hits, led.prefix_tokens)
    peak = {"unique": 0, "dense": 0}

    def _sample(s: ContinuousEngine) -> None:
        st = s.backend.block_stats(s.cache)
        peak["unique"] = max(peak["unique"], st["unique_tokens"])
        peak["dense"] = max(peak["dense"], st["dense_tokens"])

    for r in poisson_requests(rate_hz, n_requests, prompt_len=prompt_len,
                              max_new=max_new, seed=seed,
                              interactive_frac=interactive_frac,
                              prefix_pool=prefix_pool, prefix_len=prefix_len,
                              t0=serving.clock()):
        serving.submit(r)
    done = [r for r in serving.run(max_steps=100_000, on_exhausted="raise",
                                   on_step=_sample)
            if not r.rid.startswith("warm")]
    assert len(done) == n_requests, (len(done), n_requests)
    out = _metrics(done, led)
    meta = serving.cache["meta"]
    meta.check()
    # K + V, bf16, every layer — bytes one KV-cache token entry occupies
    kv_entry_bytes = 2 * cfg.kv_dim * 2 * cfg.n_layers
    out.update({
        "prefix_lookups": float(led.prefix_lookups - l0[0]),
        "prefix_hits": float(led.prefix_hits - l0[1]),
        "prefix_matched_tokens": float(led.prefix_tokens - l0[2]),
        "peak_unique_kv_tokens": float(peak["unique"]),
        "peak_dense_kv_tokens": float(peak["dense"]),
        "peak_unique_kv_bytes": float(peak["unique"] * kv_entry_bytes),
        "peak_dense_kv_bytes": float(peak["dense"] * kv_entry_bytes),
        "leaked_blocks": float(meta.blocks_in_use()),
    })
    return out


def run(model: str = "mixtral-8x7b", env: str = "env1",
        fast: bool = False, smoke: bool = False,
        prefix_pool: int = 1, prefix_len: int = 96
        ) -> Dict[str, Dict[str, float]]:
    """``smoke=True`` is CI's bench-smoke lane: pure simulation only (no
    jitted reduced-numerics runs), a handful of requests — seconds, not
    minutes — while still writing the full self-describing JSON record."""
    results: Dict[str, Dict[str, float]] = {}

    # -- reduced real numerics: orchestrator-policy axis (sched=fifo) --------
    rates = [2.0, 16.0] if fast else [2.0, 8.0, 32.0]
    slot_counts = [2] if fast else [2, 4]
    n_requests = 6 if fast else 16
    if not smoke:
        for policy in POLICIES:
            for rate in rates:
                for n_slots in slot_counts:
                    r = serve_once(model, policy, env, rate_hz=rate,
                                   n_slots=n_slots, n_requests=n_requests)
                    key = (f"serve_load/{env}/{policy}/"
                           f"rate{rate:g}_slots{n_slots}")
                    emit(key, r["mean_itl"] * 1e6,
                         f"tok_per_s={r['throughput_tok_per_s']:.2f} "
                         f"ttft={r['mean_ttft']:.4f}s "
                         f"hit_rate={r['hit_rate']:.2f}")
                    results[key] = r

        # -- scheduler-policy axis, reduced real numerics --------------------
        sched_rate = 16.0 if fast else 32.0
        for sched in (("fifo", "priority") if fast else SCHED_POLICIES):
            r = serve_once(model, "fiddler", env, rate_hz=sched_rate,
                           n_slots=2, n_requests=n_requests, sched=sched,
                           interactive_frac=0.25)
            key = f"serve_load/{env}/fiddler/sched_{sched}_rate{sched_rate:g}"
            emit(key, r["mean_itl"] * 1e6,
                 f"tok_per_s={r['throughput_tok_per_s']:.2f} "
                 f"p95_ttft={r['p95_ttft']:.4f}s "
                 f"preempt={r['preemptions']:.0f}")
            results[key] = r

    # -- paper-scale pure simulation: full-size Mixtral, heavy traffic -------
    sim_rates = [16.0] if smoke else ([8.0, 32.0] if fast
                                      else [8.0, 32.0, 64.0])
    sim_requests = 4 if smoke else (16 if fast else 48)
    sim_slots = 4
    sim_scheds = ("fifo",) if smoke else SCHED_POLICIES
    for sched in sim_scheds:
        for rate in sim_rates:
            r = simulate_once(model, "fiddler", env, rate_hz=rate,
                              n_slots=sim_slots, n_requests=sim_requests,
                              sched=sched)
            key = (f"serve_load_sim/{env}/fiddler/"
                   f"sched_{sched}_rate{rate:g}_slots{sim_slots}")
            emit(key, r["mean_itl"] * 1e6,
                 f"tok_per_s={r['throughput_tok_per_s']:.2f} "
                 f"p95_ttft={r['p95_ttft']:.4f}s "
                 f"p95_ttft_int={r.get('p95_ttft_interactive', 0.0):.4f}s "
                 f"preempt={r['preemptions']:.0f}")
            results[key] = r

    # -- shared-prefix axis: cross-request prefix cache on vs off ------------
    # Warm index, then a high-rate flood of same-preamble prompts: the
    # cached run's TTFT and peak unique KV residency must both drop.
    pre_rates = [32.0] if smoke else [8.0, 32.0]
    pre_requests = 8 if smoke else 24
    for rate in pre_rates:
        for cache_on in (True, False):
            r = simulate_once(model, "fiddler", env, rate_hz=rate,
                              n_slots=sim_slots, n_requests=pre_requests,
                              prompt_len=32, max_new=16,
                              interactive_frac=0.0,
                              prefix_pool=prefix_pool, prefix_len=prefix_len,
                              prefix_cache=cache_on)
            key = (f"serve_load_prefix/{env}/fiddler/"
                   f"rate{rate:g}_{'cache' if cache_on else 'nocache'}")
            emit(key, r["p95_ttft"] * 1e6,
                 f"p95_ttft={r['p95_ttft']:.4f}s "
                 f"matched_tok={r['prefix_matched_tokens']:.0f} "
                 f"peak_unique_kv={r['peak_unique_kv_bytes'] / 2**20:.1f}MiB "
                 f"leaked={r['leaked_blocks']:.0f}")
            results[key] = r

    # -- disaggregation axis: roofline prefill/decode split vs interleaved ---
    # Long prompts at paper scale: saturating prefill chunks + overlap
    # under the decode stream must beat interleaved FIFO's throughput at
    # the high rate without hurting interactive p95 TTFT (CI gate).
    dis_rates = [32.0] if smoke else ([16.0, 32.0] if fast
                                      else [16.0, 32.0, 64.0])
    dis_requests = 8 if smoke else 32
    for rate in dis_rates:
        for sched in ("fifo", "roofline"):
            r = simulate_once(model, "fiddler", env, rate_hz=rate,
                              n_slots=sim_slots, n_requests=dis_requests,
                              sched=sched, interactive_frac=0.25,
                              prompt_len=96, max_new=24)
            key = f"serve_load_disagg/{env}/fiddler/rate{rate:g}_{sched}"
            emit(key, r["mean_itl"] * 1e6,
                 f"tok_per_s={r['throughput_tok_per_s']:.2f} "
                 f"p95_ttft_int={r.get('p95_ttft_interactive', 0.0):.4f}s "
                 f"p95_ttft={r['p95_ttft']:.4f}s")
            results[key] = r

    # self-describing record: a fast/dev/smoke run must not masquerade as
    # the full sweep when it overwrites the file
    record = {
        "_meta": {
            "mode": "smoke" if smoke else ("fast" if fast else "full"),
            "model": model, "env": env,
            # null in smoke mode: the reduced-numerics sweeps did not run
            "reduced_rates": None if smoke else rates,
            "reduced_slots": None if smoke else slot_counts,
            "reduced_requests": None if smoke else n_requests,
            "sim_rates": sim_rates, "sim_requests": sim_requests,
            "sim_slots": sim_slots,
            "prefix_rates": pre_rates, "prefix_requests": pre_requests,
            "prefix_pool": prefix_pool, "prefix_len": prefix_len,
            "disagg_rates": dis_rates, "disagg_requests": dis_requests,
        },
        "results": results,
    }
    RESULTS_JSON.write_text(json.dumps(record, indent=2, sort_keys=True))
    return results


if __name__ == "__main__":
    import argparse

    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--full", action="store_true",
                    help="full sweep (default is the fast dev subset)")
    ap.add_argument("--smoke", action="store_true",
                    help="CI bench-smoke lane: pure simulation only")
    ap.add_argument("--prefix-pool", type=int, default=1, metavar="N",
                    help="shared preambles in the prefix-cache axis")
    ap.add_argument("--prefix-len", type=int, default=96, metavar="L",
                    help="shared preamble length (tokens)")
    a = ap.parse_args()
    run(fast=not a.full, smoke=a.smoke,
        prefix_pool=a.prefix_pool, prefix_len=a.prefix_len)
