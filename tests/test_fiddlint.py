"""fiddlint: fixture suites per rule, suppression/baseline semantics,
and the repo-wide zero-actionable gate.

Each fixture under tests/fixtures/lint seeds true positives (marked
``# EXPECT: FID00N`` on the exact line the rule must report) next to
false-positive candidates that must stay clean; the tests assert the
*complete* finding set — rule ids and line numbers — so a rule that
over- or under-fires fails loudly.
"""
import json
import re
import subprocess
import sys
from pathlib import Path

from repro.analysis.config import FiddlintConfig, load_config
from repro.analysis.core import (
    Baseline,
    Finding,
    run_lint,
    scan_suppressions,
)

REPO = Path(__file__).resolve().parents[1]
FIXTURES = REPO / "tests" / "fixtures" / "lint"


def expected_findings(path: Path):
    out = []
    for i, line in enumerate(path.read_text().splitlines(), start=1):
        m = re.search(r"#\s*EXPECT:\s*(FID\d+)", line)
        if m:
            out.append((m.group(1), i))
    return sorted(out)


def run_rule(rule_id: str, fixture: Path, **overrides):
    cfg = FiddlintConfig(
        paths=[str(fixture)], baseline=None, select=[rule_id],
    ).with_overrides(**overrides)
    result = run_lint(cfg, use_baseline=False)
    return sorted({(f.rule, f.line) for f in result.findings})


# ---------------------------------------------------------------------------
# per-rule fixture suites
# ---------------------------------------------------------------------------


def test_fid001_fixture():
    fx = FIXTURES / "fid001_cases.py"
    got = run_rule("FID001", fx, hot_roots=["Engine.step"])
    assert got == expected_findings(fx)


def test_fid002_fixture():
    fx = FIXTURES / "fid002_cases.py"
    got = run_rule("FID002", fx, hot_roots=["Engine.run"])
    assert got == expected_findings(fx)


def test_fid003_fixture():
    fx = FIXTURES / "fid003_cases.py"
    got = run_rule("FID003", fx)
    assert got == expected_findings(fx)


def test_fid004_fixture():
    fx = FIXTURES / "fid004_cases.py"
    got = run_rule("FID004", fx)
    assert got == expected_findings(fx)


def test_fid005_fixture():
    fx = FIXTURES / "fid005_cases.py"
    got = run_rule("FID005", fx, worker_entry_points=["Worker.__call__"])
    assert got == expected_findings(fx)


def test_fid006_fixture():
    fx = FIXTURES / "fid006_cases.py"
    got = run_rule("FID006", fx, hot_roots=["Engine.step"])
    assert got == expected_findings(fx)


def test_fid007_fixture():
    fx = FIXTURES / "fid007_cases.py"
    # both migration entry points are roots so the batched variant's
    # exemptions (list literal / comprehension-bound name) are exercised,
    # while unrelated_loop_put stays outside the rule's scope
    got = run_rule("FID007", fx,
                   migration_roots=["Engine.apply_migrations",
                                    "Engine.apply_migrations_batched"])
    assert got == expected_findings(fx)


# ---------------------------------------------------------------------------
# suppression semantics
# ---------------------------------------------------------------------------


def test_suppression_requires_reason():
    lines = [
        "x = a.item()  # fiddlint: ignore[FID001]",
        "y = b.item()  # fiddlint: ignore[FID001] sampling boundary",
    ]
    supp = scan_suppressions(lines)
    assert 1 not in supp  # no reason -> not a suppression
    assert supp[2] == {"FID001"}


def test_suppression_block_covers_first_code_line():
    lines = [
        "# fiddlint: ignore[FID001] the routing sync is the design:",
        "# expert ids must land on host for the planner",
        "idx_np = np.asarray(idx)",
        "other = 1",
    ]
    supp = scan_suppressions(lines)
    assert "FID001" in supp[3]
    assert 4 not in supp


def test_suppression_multiple_rules():
    supp = scan_suppressions(
        ["z = f()  # fiddlint: ignore[FID001, FID002] both intentional"])
    assert supp[1] == {"FID001", "FID002"}


def test_suppressed_finding_not_actionable(tmp_path):
    mod = tmp_path / "hot.py"
    mod.write_text(
        "import jax.numpy as jnp\n"
        "class Engine:\n"
        "    def step(self, x: jnp.ndarray):\n"
        "        # fiddlint: ignore[FID001] test suppression\n"
        "        return x.item()\n")
    cfg = FiddlintConfig(paths=[str(mod)], baseline=None,
                         select=["FID001"], hot_roots=["Engine.step"])
    result = run_lint(cfg, use_baseline=False)
    assert not result.findings
    assert len(result.suppressed) == 1


# ---------------------------------------------------------------------------
# baseline semantics
# ---------------------------------------------------------------------------


def test_baseline_roundtrip(tmp_path):
    f = Finding("FID001", "src/x.py", 12, 0, "msg", "mod.Cls.fn")
    bpath = tmp_path / "baseline.json"
    Baseline.write(bpath, [f], reason="known eager path")
    b = Baseline(bpath)
    assert b.covers(f)
    # line drift must not break the match (keyed on rule/path/symbol)
    assert b.covers(Finding("FID001", "src/x.py", 99, 4, "msg", "mod.Cls.fn"))
    assert not b.covers(Finding("FID002", "src/x.py", 12, 0, "msg",
                                "mod.Cls.fn"))
    data = json.loads(bpath.read_text())
    assert data["findings"][0]["reason"] == "known eager path"


def test_committed_baseline_entries_have_reasons():
    data = json.loads((REPO / "fiddlint-baseline.json").read_text())
    for entry in data["findings"]:
        assert entry["reason"].strip(), entry
        assert entry["rule"] in {"FID001", "FID002", "FID003", "FID004",
                                 "FID005", "FID006", "FID007"}


# ---------------------------------------------------------------------------
# repo-wide gate + CLI
# ---------------------------------------------------------------------------


def test_repo_is_fiddlint_clean(monkeypatch):
    """Tier-1 gate: src/repro must carry zero non-baseline violations."""
    monkeypatch.chdir(REPO)
    cfg = load_config(REPO)
    result = run_lint(cfg)
    assert not result.findings, "\n".join(f.render() for f in result.findings)
    # the invariants are live: the intentional syncs are documented via
    # suppressions/baseline, not invisible to the rules
    assert result.suppressed or result.baselined


def test_repo_config_loads_hot_roots():
    cfg = load_config(REPO)
    assert any(r.endswith("ContinuousEngine.step") for r in cfg.hot_roots)
    assert cfg.select == ["FID001", "FID002", "FID003", "FID004", "FID005",
                          "FID006", "FID007"]


def test_cli_smoke():
    proc = subprocess.run(
        [sys.executable, "-m", "repro.analysis.lint", "--stats"],
        cwd=REPO, capture_output=True, text=True,
        env={"PYTHONPATH": str(REPO / "src"), "PATH": "/usr/bin:/bin"},
    )
    assert proc.returncode == 0, proc.stdout + proc.stderr
    assert "fiddlint:" in proc.stdout


def test_cli_reports_seeded_violation(tmp_path):
    mod = tmp_path / "leaky.py"
    mod.write_text(
        "def leak(pool, n):\n"
        "    b = pool.alloc(n)\n"
        "    return n\n")
    proc = subprocess.run(
        [sys.executable, "-m", "repro.analysis.lint", str(mod),
         "--no-baseline", "--select", "FID003"],
        cwd=REPO, capture_output=True, text=True,
        env={"PYTHONPATH": str(REPO / "src"), "PATH": "/usr/bin:/bin"},
    )
    assert proc.returncode == 1
    assert "FID003" in proc.stdout
    assert "leaky.py:3:" in proc.stdout  # reported at the leaking return
