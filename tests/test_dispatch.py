"""Grouped-GEMM dispatch + real overlap + async migration prefetch.

The grouped execution engine (``dispatch_mode="grouped"``, the default)
must be *bit-identical* on fp32 to the paper-style per-expert eager loop
it replaced — including under continuous-batching row masks, mid-sequence
migrations, threaded slow-tier overlap, and the LRU/stream paths — while
issuing far fewer fast-tier kernel dispatches.  Async rebalancer
prefetches must never charge more exposed time than the old serial
migration model, with bytes unchanged.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from conftest import reduced_model
from repro.configs import get_config
from repro.core import FiddlerEngine, HardwareSpec
from repro.core.cost_model import expert_weight_bytes, link_idle_time
from repro.core.orchestrator import _FastStack, _bucket
from repro.core.popularity import ExpertProfile, synthetic_profile
from repro.core.rebalance import MigrationPlan, PrefetchQueue


@pytest.fixture(scope="module")
def mixtral():
    return reduced_model("mixtral-8x7b")


def _engine(mixtral, mode, **kw):
    cfg, model, params = mixtral
    kw.setdefault("expert_budget", cfg.n_layers * cfg.moe.n_experts // 2)
    kw.setdefault("host_precision", "fp32")
    return FiddlerEngine(cfg, params, dispatch_mode=mode, **kw)


def _forward(eng, tokens, n_decode=2, max_seq=32):
    outs = []
    logits, caches = eng.prefill(tokens, max_seq=max_seq)
    outs.append(np.asarray(logits))
    for step in range(n_decode):
        logits, caches = eng.decode_step(caches, tokens[:, :1],
                                         pos=tokens.shape[1] + step,
                                         max_seq=max_seq)
        outs.append(np.asarray(logits))
    return np.stack(outs)


# ---------------------------------------------------------------------------
# Equivalence: grouped dispatch vs the per-expert eager loop
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("policy", ["fiddler", "offload"])
def test_grouped_bit_identical_to_eager_fp32(mixtral, policy):
    """All three decision paths (resident group / streamed group / slow
    host pool) must reproduce the eager loop bit for bit on fp32."""
    cfg, _, _ = mixtral
    tokens = jax.random.randint(jax.random.PRNGKey(1), (2, 12), 3,
                                cfg.vocab_size)
    got = {m: _forward(_engine(mixtral, m, policy=policy), tokens)
           for m in ("grouped", "eager")}
    np.testing.assert_array_equal(got["grouped"], got["eager"])


def test_grouped_matches_eager_bf16_slow_tier(mixtral):
    """With the lossy bf16 slow tier both modes run the identical
    HostExpert kernels on the identical rows — agreement within bf16
    tolerance (empirically bit-identical; tolerance guards against BLAS
    thread-count variation in the overlapped path)."""
    cfg, _, _ = mixtral
    tokens = jax.random.randint(jax.random.PRNGKey(2), (1, 9), 3,
                                cfg.vocab_size)
    got = {m: _forward(_engine(mixtral, m, host_precision="bf16"), tokens)
           for m in ("grouped", "eager")}
    np.testing.assert_allclose(got["grouped"], got["eager"],
                               rtol=1e-6, atol=1e-6)


def test_grouped_overlap_off_bit_identical(mixtral):
    """Serial mode (overlap=False) — slow experts inline instead of on
    the host worker pool — must not change a single bit."""
    cfg, _, _ = mixtral
    tokens = jax.random.randint(jax.random.PRNGKey(3), (1, 8), 3,
                                cfg.vocab_size)
    a = _forward(_engine(mixtral, "grouped", overlap=True), tokens)
    b = _forward(_engine(mixtral, "grouped", overlap=False), tokens)
    np.testing.assert_array_equal(a, b)


def test_grouped_masked_rows_continuous(mixtral):
    """The continuous-batching case: idle slots are padding.  Grouped
    dispatch must exclude masked rows from the buffers exactly like the
    eager loop excludes them from execution — bit-identical logits and
    identical ledger decision counts."""
    cfg, _, _ = mixtral
    outs, ledgers = {}, {}
    for m in ("grouped", "eager"):
        eng = _engine(mixtral, m)
        caches = eng.make_decode_caches(2, 32)
        _, sc = eng.prefill_chunk(jnp.asarray([[1, 5, 9]], jnp.int32),
                                  None, 0, 32)
        caches = eng.write_slot(caches, sc, 0)
        logits, _ = eng.decode_step_multi(
            caches, jnp.asarray([[7], [0]], jnp.int32), np.array([3, 0]),
            32, active=np.array([True, False]))
        outs[m] = np.asarray(logits)
        led = eng.ledger
        ledgers[m] = (led.fast_hits, led.streams, led.slow_runs,
                      led.tokens_out)
    np.testing.assert_array_equal(outs["grouped"], outs["eager"])
    assert ledgers["grouped"] == ledgers["eager"]


def test_grouped_large_counts_prefill_equivalence(mixtral):
    """Row counts above SWITCH_CAP dispatch through the uniform
    exact-count launches (single compiled branch, no switch) — a
    prefill-sized workload must stay bit-identical to eager."""
    from repro.core.orchestrator import SWITCH_CAP

    cfg, _, _ = mixtral
    tokens = jax.random.randint(jax.random.PRNGKey(9), (2, 24), 3,
                                cfg.vocab_size)
    # 48 tokens × top_k over 4 experts → per-expert counts ≫ SWITCH_CAP
    assert 2 * 24 * cfg.moe.top_k / cfg.moe.n_experts > SWITCH_CAP
    a = _forward(_engine(mixtral, "grouped"), tokens, n_decode=1,
                 max_seq=64)
    b = _forward(_engine(mixtral, "eager"), tokens, n_decode=1, max_seq=64)
    np.testing.assert_array_equal(a, b)


@pytest.mark.parametrize("mode", ["grouped", "eager"])
def test_lru_evict_while_plan_still_needs_it(mixtral, mode):
    """A stream burst can evict an LRU-cached expert that the *same*
    layer plan marked FAST_RESIDENT: the eviction's device-weight free
    must be deferred past execution (regression: KeyError)."""
    cfg, _, _ = mixtral
    eng = _engine(mixtral, mode, policy="offload", expert_budget=0,
                  lru_cache_experts=1)
    rng = np.random.default_rng(0)
    x = rng.standard_normal((4, cfg.d_model)).astype(np.float32) * 0.1
    gates = np.full((4, cfg.moe.top_k), 1.0 / cfg.moe.top_k, np.float32)
    execute = (eng._execute_grouped if mode == "grouped"
               else eng._execute_eager)

    def run(idx):
        idx = np.asarray(idx, np.int64)
        counts = np.bincount(idx.reshape(-1), minlength=cfg.moe.n_experts)
        plan = eng._decide(0, counts)
        return execute(0, plan, counts, x, idx, gates, None)

    run(np.tile([0, 1], (4, 1)))          # streams e0, e1; cap-1 keeps e1
    assert set(eng._lru_pool) == {(0, 1)}
    # e1 is FAST_RESIDENT via the cache in this plan, while e2 and e3
    # stream — their inserts evict e1 mid-plan
    run(np.array([[1, 2], [1, 2], [1, 3], [1, 3]]))
    assert set(eng._lru_pool) == {(0, 3)}  # deferred free happened
    assert not eng._lru_evict_deferred


def test_migration_mid_sequence_equivalence(mixtral):
    """A migration applied between prefill and decode: both dispatch
    modes must agree bit for bit afterwards, and the incrementally
    maintained stacked pool must match a fresh engine built with the
    migrated placement."""
    cfg, _, params = mixtral
    tokens = jax.random.randint(jax.random.PRNGKey(4), (1, 8), 3,
                                cfg.vocab_size)

    def swap_plan(placement):
        for li in range(placement.on_fast.shape[0]):
            row = placement.on_fast[li]
            if row.any() and (~row).any():
                return MigrationPlan(
                    promotes=((li, int(np.nonzero(~row)[0][0])),),
                    demotes=((li, int(np.nonzero(row)[0][0])),),
                    est_gain=0.0, transfer_bytes=0, est_transfer_s=0.0)
        raise AssertionError("no mixed layer")

    outs = {}
    for m in ("grouped", "eager"):
        eng = _engine(mixtral, m)
        logits, caches = eng.prefill(tokens, max_seq=32)
        eng.apply_migrations(swap_plan(eng.placement))
        dec = []
        for step in range(3):
            logits, caches = eng.decode_step(caches, tokens[:, :1],
                                             pos=8 + step, max_seq=32)
            dec.append(np.asarray(logits))
        outs[m] = np.stack(dec)
        if m == "grouped":
            fresh = FiddlerEngine(cfg, params, dispatch_mode="grouped",
                                  host_precision="fp32",
                                  expert_budget=eng.expert_budget,
                                  placement=eng.placement)
            np.testing.assert_array_equal(_forward(eng, tokens),
                                          _forward(fresh, tokens))
    np.testing.assert_array_equal(outs["grouped"], outs["eager"])


def test_fast_stack_promote_demote_and_overflow(mixtral):
    """The stacked pool's incremental maintenance: promote fills padded
    slots in place, overflow forces a rebuild with doubled capacity,
    demote swap-removes — and row contents always match the original
    fp32 expert weights."""
    cfg, _, _ = mixtral
    eng = _engine(mixtral, "grouped")
    li = 0
    st = eng.fast_stack[li]
    assert st.cap == _bucket(max(len(st), 1))

    def check(stack):
        for e in stack.ids:
            for got, want in zip(stack.weights(e), eng._expert_weights(li, e)):
                np.testing.assert_array_equal(np.asarray(got),
                                              np.asarray(want))

    check(st)
    # promote every remaining expert: exercises in-place writes and the
    # overflow rebuild (cap is a power of two ≥ current size)
    missing = [e for e in range(cfg.moe.n_experts)
               if e not in eng.fast_stack[li].slot]
    for e in missing:
        eng.apply_migrations(MigrationPlan(
            promotes=((li, e),), demotes=(), est_gain=0.0,
            transfer_bytes=0, est_transfer_s=0.0))
        check(eng.fast_stack[li])
    st = eng.fast_stack[li]
    assert len(st) == cfg.moe.n_experts
    # demote from the middle: swap-remove must keep every survivor intact
    victim = st.ids[0]
    eng.apply_migrations(MigrationPlan(
        promotes=(), demotes=((li, victim),), est_gain=0.0,
        transfer_bytes=0, est_transfer_s=0.0))
    st = eng.fast_stack[li]
    assert victim not in st.slot and len(st) == cfg.moe.n_experts - 1
    check(st)


def test_bucket_padding():
    assert [_bucket(n) for n in (1, 2, 3, 4, 5, 8, 9)] == \
        [1, 2, 4, 4, 8, 8, 16]


def test_fast_stack_unit():
    d, f = 4, 8
    rng = np.random.default_rng(0)
    mats = {e: (rng.standard_normal((d, f)).astype(np.float32),
                rng.standard_normal((d, f)).astype(np.float32),
                rng.standard_normal((f, d)).astype(np.float32))
            for e in range(3)}
    st = _FastStack([0], jnp.asarray(mats[0][0][None]),
                    jnp.asarray(mats[0][1][None]),
                    jnp.asarray(mats[0][2][None]))
    assert not st.promote(1, tuple(map(jnp.asarray, mats[1])))  # cap=1: full
    st = _FastStack([0, 1], *[
        jnp.stack([jnp.asarray(mats[0][i]), jnp.asarray(mats[1][i])])
        for i in range(3)])
    st.demote(0)  # swap-remove: expert 1 moves into slot 0
    assert st.ids == [1] and st.slot == {1: 0}
    for got, want in zip(st.weights(1), mats[1]):
        np.testing.assert_array_equal(np.asarray(got), want)


# ---------------------------------------------------------------------------
# Dispatch-count reduction
# ---------------------------------------------------------------------------


def _decode_workload(eng, n_steps=4, n_slots=4, max_seq=32):
    """Multi-slot decode — the paper's hot regime (tiny per-expert row
    counts).  Returns fast dispatches issued during the decode steps."""
    cfg = eng.cfg
    caches = eng.make_decode_caches(n_slots, max_seq)
    for slot in range(n_slots):
        _, sc = eng.prefill_chunk(
            jnp.asarray([[1 + slot, 5, 9]], jnp.int32), None, 0, max_seq)
        caches = eng.write_slot(caches, sc, slot)
    before = eng.ledger.fast_dispatches
    tokens = jnp.asarray(np.arange(3, 3 + n_slots)[:, None], jnp.int32)
    pos = np.full(n_slots, 3)
    for step in range(n_steps):
        logits, caches = eng.decode_step_multi(caches, tokens, pos + step,
                                               max_seq)
    return eng.ledger.fast_dispatches - before


def test_grouped_issues_fewer_dispatches(mixtral):
    """Grouped dispatch: the whole resident tier is ONE launch per layer
    per step (the per-expert loop pays one per activated expert), and
    streamed experts bucket into at most one extra launch."""
    cfg, _, _ = mixtral
    E, L = cfg.moe.n_experts, cfg.n_layers
    n = {}
    for m in ("grouped", "eager"):
        eng = _engine(mixtral, m, expert_budget=L * E)  # all resident
        n[m] = _decode_workload(eng)
    assert n["grouped"] == 4 * L        # one launch per layer-step
    assert n["eager"] > n["grouped"]    # one per activated expert
    # offload (nothing resident): everything streams → still ≤ one
    # stacked launch per layer-step
    eng = _engine(mixtral, "grouped", policy="offload", expert_budget=0)
    assert _decode_workload(eng) <= 4 * L


# ---------------------------------------------------------------------------
# Satellite regressions: LRU device-pool leak, layer_log growth
# ---------------------------------------------------------------------------


def test_lru_pool_bounded_by_capacity(mixtral):
    """Eviction must drop the evicted expert's device weights: before the
    fix ``_lru_pool`` retained every expert ever streamed."""
    cfg, _, _ = mixtral
    cap = 2
    eng = _engine(mixtral, "grouped", policy="offload", expert_budget=0,
                  lru_cache_experts=cap)
    tokens = jax.random.randint(jax.random.PRNGKey(6), (2, 10), 3,
                                cfg.vocab_size)
    logits, caches = eng.prefill(tokens, max_seq=32)
    for step in range(2):
        logits, caches = eng.decode_step(caches, tokens[:, :1],
                                         pos=10 + step, max_seq=32)
    assert eng.ledger.streams > cap  # enough traffic to evict
    assert len(eng._lru_pool) <= cap
    assert eng.lru.occupancy <= cap
    assert set(eng._lru_pool) <= set(eng.lru._slots)


def test_layer_log_ring_buffer():
    cfg = get_config("mixtral-8x7b")
    eng = FiddlerEngine(cfg, policy="fiddler", seed=0)
    eng.ledger.layer_log_limit = 64
    eng.simulate_decode(8, batch=1)   # 8 steps × 32 layers = 256 charges
    assert len(eng.ledger.layer_log) == 64
    assert eng.ledger.layer_log[-1]["layer"] == cfg.n_layers - 1  # newest
    eng.ledger.layer_log_limit = 0    # opt out entirely
    eng.ledger.layer_log.clear()
    eng.simulate_decode(2, batch=1)
    assert eng.ledger.layer_log == []


# ---------------------------------------------------------------------------
# Async migration prefetch: ledger invariants
# ---------------------------------------------------------------------------


def test_link_idle_time():
    assert link_idle_time(2.0, 3.0, 1.0) == 4.0
    assert link_idle_time(1.0, 0.5, 9.0) == 0.0  # link saturated: no idle


def test_prefetch_queue_fifo_semantics():
    q = PrefetchQueue()
    q.push(0, 3, 1.0)
    q.push(5, 1, 2.0)
    assert q.backlog == pytest.approx(3.0)
    assert q.drain(0.5) == pytest.approx(0.5)       # partial head drain
    # forcing a later transfer serialises everything queued ahead (FIFO)
    assert q.force(5, {1}) == pytest.approx(2.5)
    assert len(q) == 0 and q.backlog == 0.0
    q.push(1, 2, 4.0)
    assert q.force(1, {7}) == 0.0                   # different expert: no-op
    assert q.flush() == pytest.approx(4.0)


def _shifted(calib, E, L, seed=1):
    rng = np.random.default_rng(seed)
    return ExpertProfile(np.stack(
        [calib.counts[li][rng.permutation(E)] for li in range(L)]))


def _drive(async_on, n_steps=48):
    cfg = get_config("mixtral-8x7b")
    L, E = cfg.n_layers, cfg.moe.n_experts
    calib = synthetic_profile(L, E, seed=0, concentration=0.5)
    eng = FiddlerEngine(cfg, policy="fiddler", hw=HardwareSpec.paper_env1(),
                        profile=calib, expert_budget=L * E // 4, seed=0,
                        rebalance_interval=4, rebalance_k=8,
                        async_prefetch=async_on)
    eng.profile = _shifted(calib, E, L)  # drift → migrations fire
    for _ in range(n_steps):
        eng.simulate_decode(1, batch=4)
        eng.maybe_rebalance()
    eng.flush_prefetch()
    return eng


def test_async_prefetch_ledger_invariants():
    """The acceptance invariant: with async prefetch, exposed
    (sim_time-charged) migration time ≤ the serial
    ``n_swaps * transfer_lat()`` charge, migration_bytes unchanged, and
    the overlapped + exposed split accounts for every committed
    link-second."""
    a = _drive(async_on=True)
    s = _drive(async_on=False)
    led = a.ledger
    assert led.migrations > 0
    serial_charge = led.migrations * a.lat.transfer_lat()
    assert led.migration_exposed <= serial_charge + 1e-12
    assert led.migration_overlapped > 0.0   # some transfer actually hid
    assert led.migration_overlapped + led.migration_exposed == \
        pytest.approx(led.migration_time)
    assert led.migration_time == pytest.approx(serial_charge)
    assert led.migration_bytes == led.migrations * \
        expert_weight_bytes(a.cfg)
    # identical routing/decisions → identical migrations; hiding
    # transfers can only make the clock faster, never slower
    assert s.ledger.migrations == led.migrations
    assert s.ledger.migration_exposed == pytest.approx(
        s.ledger.migration_time)
    assert led.sim_time <= s.ledger.sim_time + 1e-12
    assert led.sim_time < s.ledger.sim_time  # and strictly faster here


def test_sync_vs_async_identical_numerics(mixtral):
    """async_prefetch only moves *when* transfer time is charged — the
    real-numerics outputs and the migration set must be identical."""
    cfg, _, _ = mixtral
    tokens = jax.random.randint(jax.random.PRNGKey(7), (1, 8), 3,
                                cfg.vocab_size)
    outs = {}
    for async_on in (True, False):
        eng = _engine(mixtral, "grouped", profile=synthetic_profile(
            cfg.n_layers, cfg.moe.n_experts, seed=0, concentration=0.5),
            rebalance_interval=2, rebalance_k=4, async_prefetch=async_on)
        logits, caches = eng.prefill(tokens, max_seq=32)
        dec = []
        for step in range(4):
            logits, caches = eng.decode_step(caches, tokens[:, :1],
                                             pos=8 + step, max_seq=32)
            eng.maybe_rebalance()
            dec.append(np.asarray(logits))
        eng.flush_prefetch()
        outs[async_on] = (np.stack(dec), eng.ledger.migrations,
                          eng.ledger.migration_bytes)
    np.testing.assert_array_equal(outs[True][0], outs[False][0])
    assert outs[True][1:] == outs[False][1:]
