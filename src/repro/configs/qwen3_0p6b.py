"""Qwen3-0.6B [hf:Qwen/Qwen3-8B family] — dense, qk_norm, GQA.

28L d_model=1024 16H (GQA kv=8) d_ff=3072 vocab=151936.
"""
from repro.configs.base import ModelConfig, register


@register("qwen3-0.6b")
def qwen3_0p6b() -> ModelConfig:
    return ModelConfig(
        name="qwen3-0.6b",
        arch_type="dense",
        n_layers=28,
        d_model=1024,
        n_heads=16,
        n_kv_heads=8,
        head_dim=128,
        d_ff=3072,
        vocab_size=151936,
        qk_norm=True,
        rope_theta=1000000.0,
        long_context_window=8192,
        citation="[hf:Qwen/Qwen3-8B] Qwen3",
    )
