from repro.models.model import Model, NO_PARALLEL, ParallelContext, lm_loss  # noqa: F401
