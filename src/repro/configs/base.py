"""Config system for the repro framework.

Every architecture is described by a :class:`ModelConfig` dataclass.  Configs
are registered in a global registry keyed by their public ``--arch`` id, and
each registered config cites its source (paper / model card).

Input shapes (the four assigned workload shapes) are described by
:class:`InputShape` and registered in ``INPUT_SHAPES``.
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass
from typing import Callable, Dict, List, Optional

# ---------------------------------------------------------------------------
# Model configuration
# ---------------------------------------------------------------------------


@dataclass(frozen=True)
class MoEConfig:
    """Mixture-of-experts settings for routed FFN layers."""

    n_experts: int
    top_k: int
    # capacity factor used by the capacity-bucketed dispatch.
    capacity_factor: float = 1.25
    # number of shared (always-on) experts, DeepSeek/Kimi style.
    n_shared_experts: int = 0
    # router type: "softmax" (Mixtral) or "sigmoid" (Kimi/DeepSeek-V3 style)
    router_type: str = "softmax"
    # router logits jitter/aux-loss coefficient for training.
    aux_loss_coef: float = 0.01


@dataclass(frozen=True)
class SSMConfig:
    """Mamba2 (SSD) settings."""

    state_dim: int = 128
    head_dim: int = 64
    n_groups: int = 1
    conv_width: int = 4
    chunk_size: int = 256
    expand: int = 2


@dataclass(frozen=True)
class HybridConfig:
    """RecurrentGemma-style hybrid (RG-LRU + local attention) settings."""

    lru_width: int = 2560
    # pattern period: 1 attention layer per `period` layers (1:2 → period 3
    # in the paper is 2 recurrent + 1 local-attn; RG uses (R,R,A) repeating)
    attn_period: int = 3
    window: int = 2048


@dataclass(frozen=True)
class EncDecConfig:
    """Encoder-decoder (Whisper) settings. Frontend is stubbed: the encoder
    consumes precomputed frame embeddings of shape (n_frames, d_model)."""

    n_encoder_layers: int = 32
    n_audio_frames: int = 1500  # 30s of audio after conv frontend (stubbed)


@dataclass(frozen=True)
class VLMConfig:
    """VLM (InternVL2) settings. Vision tower is stubbed: ``input_specs``
    provides projected patch embeddings interleaved with text tokens."""

    n_image_tokens: int = 256  # tokens per image tile after pixel-shuffle


@dataclass(frozen=True)
class ModelConfig:
    name: str
    arch_type: str  # dense | moe | ssm | hybrid | audio | vlm
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab_size: int
    head_dim: Optional[int] = None  # default d_model // n_heads
    # --- attention variants -------------------------------------------------
    qk_norm: bool = False
    # sliding window size; None → full attention. "alternating" archs set
    # window and attn_pattern.
    window: Optional[int] = None
    # attention pattern: "full" | "sliding" | "alternating" (local/global,
    # gemma2) — alternating means even layers local (window), odd global.
    attn_pattern: str = "full"
    logit_softcap: Optional[float] = None  # gemma2 final-logit softcap
    attn_softcap: Optional[float] = None  # gemma2 attention softcap
    rope_theta: float = 10000.0
    # --- sub-configs ---------------------------------------------------------
    moe: Optional[MoEConfig] = None
    ssm: Optional[SSMConfig] = None
    hybrid: Optional[HybridConfig] = None
    encdec: Optional[EncDecConfig] = None
    vlm: Optional[VLMConfig] = None
    # --- misc ----------------------------------------------------------------
    tie_embeddings: bool = False
    scale_embeddings: bool = False  # gemma-family sqrt(d) embedding scale
    norm_eps: float = 1e-6
    act: str = "silu"  # silu | gelu
    citation: str = ""
    # dtype for parameters in dry-run / deployment
    param_dtype: str = "bfloat16"
    # sliding-window variant opt-in for long-context decode on dense archs
    # (beyond-paper option; see DESIGN.md §5). None → arch default.
    long_context_window: Optional[int] = None

    # ---------------------------------------------------------------------
    def __post_init__(self):
        if self.head_dim is None:
            object.__setattr__(self, "head_dim", self.d_model // max(self.n_heads, 1))

    # ---- derived quantities ----------------------------------------------
    @property
    def is_attention_free(self) -> bool:
        return self.arch_type == "ssm"

    @property
    def q_dim(self) -> int:
        return self.n_heads * self.head_dim

    @property
    def kv_dim(self) -> int:
        return self.n_kv_heads * self.head_dim

    def param_count(self) -> int:
        """Analytic total parameter count (embedding + blocks + head)."""
        d, f, v = self.d_model, self.d_ff, self.vocab_size
        emb = v * d
        head = 0 if self.tie_embeddings else v * d
        if self.arch_type == "ssm":
            assert self.ssm is not None
            inner = self.ssm.expand * d
            n_heads = inner // self.ssm.head_dim
            # in/out projections + conv + SSM params (A, D, dt) + norm
            per_layer = (
                d * (2 * inner + 2 * self.ssm.n_groups * self.ssm.state_dim + n_heads)
                + inner * d
                + self.ssm.conv_width * (inner + 2 * self.ssm.n_groups * self.ssm.state_dim)
                + 3 * n_heads
                + 2 * d
            )
            return emb + head + self.n_layers * per_layer + d

        attn = d * self.q_dim + 2 * d * self.kv_dim + self.q_dim * d
        if self.moe is not None:
            dense_ff = 3 * d * f * (self.moe.n_experts + self.moe.n_shared_experts)
            router = d * self.moe.n_experts
            ffn = dense_ff + router
        else:
            ffn = 3 * d * f
        per_layer = attn + ffn + 2 * d  # two RMSNorms
        total = emb + head + self.n_layers * per_layer + d  # final norm
        if self.arch_type == "audio" and self.encdec is not None:
            # encoder blocks (dense, self-attn only) + cross-attn in decoder
            enc_per_layer = attn + 3 * d * f + 2 * d
            total += self.encdec.n_encoder_layers * enc_per_layer
            total += self.n_layers * (attn + d)  # cross-attention + norm
        return total

    def active_param_count(self) -> int:
        """Parameters touched per token (MoE: only top-k experts)."""
        if self.moe is None:
            return self.param_count()
        d, f = self.d_model, self.d_ff
        full = self.param_count()
        all_experts = 3 * d * f * self.moe.n_experts * self.n_layers
        active = 3 * d * f * (self.moe.top_k + self.moe.n_shared_experts) * self.n_layers
        return full - all_experts + active

    def supports_long_context(self) -> bool:
        """True if the arch can decode at 500k+ context sub-quadratically
        (SSM / hybrid / sliding-window, or dense w/ the window variant)."""
        if self.arch_type == "ssm" or self.arch_type == "hybrid":
            return True
        if self.window is not None or self.attn_pattern in ("sliding", "alternating"):
            return True
        return self.long_context_window is not None

    def supports_decode(self) -> bool:
        return True  # all assigned archs have a decoder stream

    def reduced(self, n_layers: int = 2, d_model: int = 256, max_experts: int = 4) -> "ModelConfig":
        """A smoke-test variant of the same family: ≤2 layers, d_model≤512,
        ≤4 experts, tiny vocab — runs a real fwd/train step on CPU."""
        d = min(d_model, 512)
        n_heads = max(2, min(self.n_heads, 4))
        head_dim = max(32, d // n_heads)
        n_kv = max(1, min(self.n_kv_heads, n_heads))
        kwargs = dict(
            name=self.name + "-smoke",
            n_layers=min(n_layers, self.n_layers),
            d_model=d,
            n_heads=n_heads,
            n_kv_heads=n_kv,
            head_dim=head_dim,
            d_ff=max(64, d * 2) if self.d_ff else 0,
            vocab_size=512,
        )
        if self.moe is not None:
            kwargs["moe"] = dataclasses.replace(
                self.moe,
                n_experts=min(self.moe.n_experts, max_experts),
                top_k=min(self.moe.top_k, 2),
                n_shared_experts=min(self.moe.n_shared_experts, 1),
            )
        if self.ssm is not None:
            kwargs["ssm"] = dataclasses.replace(
                self.ssm, state_dim=32, head_dim=32, chunk_size=32
            )
        if self.hybrid is not None:
            kwargs["hybrid"] = dataclasses.replace(
                self.hybrid, lru_width=d, window=64
            )
            # one full (R, R, A) period so the smoke test covers both kinds
            kwargs["n_layers"] = min(self.hybrid.attn_period, self.n_layers)
        if self.encdec is not None:
            kwargs["encdec"] = dataclasses.replace(
                self.encdec, n_encoder_layers=2, n_audio_frames=16
            )
        if self.window is not None:
            kwargs["window"] = min(self.window, 64)
        return dataclasses.replace(self, **kwargs)


# ---------------------------------------------------------------------------
# Input shapes
# ---------------------------------------------------------------------------


@dataclass(frozen=True)
class InputShape:
    name: str
    seq_len: int
    global_batch: int
    kind: str  # "train" | "prefill" | "decode"


INPUT_SHAPES: Dict[str, InputShape] = {
    "train_4k": InputShape("train_4k", 4096, 256, "train"),
    "prefill_32k": InputShape("prefill_32k", 32768, 32, "prefill"),
    "decode_32k": InputShape("decode_32k", 32768, 128, "decode"),
    "long_500k": InputShape("long_500k", 524288, 1, "decode"),
}


# ---------------------------------------------------------------------------
# Registry
# ---------------------------------------------------------------------------

_REGISTRY: Dict[str, Callable[[], ModelConfig]] = {}


def register(arch_id: str):
    def deco(fn: Callable[[], ModelConfig]):
        _REGISTRY[arch_id] = fn
        return fn

    return deco


def get_config(arch_id: str) -> ModelConfig:
    if arch_id not in _REGISTRY:
        # import side effects: all config modules register on import
        from repro import configs as _c  # noqa: F401

    if arch_id not in _REGISTRY:
        raise KeyError(
            f"unknown arch {arch_id!r}; available: {sorted(_REGISTRY)}"
        )
    return _REGISTRY[arch_id]()


def list_archs() -> List[str]:
    from repro import configs as _c  # noqa: F401

    return sorted(_REGISTRY)


def applicable_shapes(cfg: ModelConfig) -> List[str]:
    """Which of the four assigned input shapes apply to this arch
    (DESIGN.md §5 skip rules)."""
    shapes = ["train_4k", "prefill_32k", "decode_32k"]
    if cfg.supports_long_context():
        shapes.append("long_500k")
    return shapes
