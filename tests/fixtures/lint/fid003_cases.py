"""FID003 fixture: block-refcount escapes over the acquire/release API.

The rule reports at the *leaking exit*: the swallowing handler, the
``raise``, the ``return``, or (for a fall-off-the-end leak) the acquire
itself.
"""


def leaks_on_swallowed_error(pool, weights, n):
    blocks = pool.alloc(n)
    try:
        x = weights[n]
        pool.free(blocks)
    except KeyError:  # EXPECT: FID003
        x = None
    return x


def leaks_on_raise(pool, n, limit):
    blocks = pool.alloc(n)
    if n > limit:
        raise ValueError(n)  # EXPECT: FID003
    pool.free(blocks)
    return n


def leaks_on_return(pool, n):
    blocks = pool.alloc(n)
    count = len(blocks)
    return count  # EXPECT: FID003


def safe_finally(pool, weights, n):
    # false-positive candidate: the canonical try/finally release covers
    # the exception edge
    blocks = pool.alloc(n)
    try:
        x = weights[n]
    finally:
        pool.free(blocks)
    return x


def safe_handoff(pool, n):
    # false-positive candidate: ownership transfers to the caller
    blocks = pool.alloc(n)
    return blocks


def safe_store(pool, table, n):
    # false-positive candidate: ownership transfers into a container
    blocks = pool.alloc(n)
    table[n] = blocks
    return n


def safe_statement_form(cache, slot, chain):
    # false-positive candidate: map_prefix records ownership inside the
    # receiver; a normal exit afterwards is the intended protocol
    cache.map_prefix(slot, chain)
    return slot


class Cache:
    def grow(self, n):
        # false-positive candidate: self-rooted acquire — the object owns
        # the reference and its release paths
        blocks = self.meta.alloc(n)
        self.table.append(blocks)
