"""Cost model: calibration against the REAL kernels (the paper's
initialization-phase measurement), hardware derivation, budget math."""
import numpy as np

from repro.configs import get_config
from repro.core.cost_model import (
    HardwareSpec,
    LatencyModel,
    expert_flops_per_token,
    expert_weight_bytes,
    measure,
)
from repro.core.placement import fast_tier_expert_budget, non_expert_bytes


def test_calibrate_from_real_kernels():
    """LatencyModel.calibrate fits the measured fast/slow kernels and the
    planner built on it behaves like the paper's: CPU preferred at small
    N when transfers are expensive."""
    import jax.numpy as jnp

    from repro.kernels.host_expert import HostExpert
    from repro.kernels.ops import expert_mlp_op

    d, f = 256, 512
    rng = np.random.default_rng(0)
    wg, wu = [rng.standard_normal((d, f)).astype(np.float32) * 0.05
              for _ in range(2)]
    wd = rng.standard_normal((f, d)).astype(np.float32) * 0.05
    host = HostExpert(wg, wu, wd)
    wg_j, wu_j, wd_j = map(jnp.asarray, (wg, wu, wd))

    def fast_fn(s):
        x = jnp.asarray(rng.standard_normal((s, d)).astype(np.float32))
        return measure(lambda: expert_mlp_op(x, wg_j, wu_j, wd_j)
                       .block_until_ready(), iters=3)

    def slow_fn(s):
        x = rng.standard_normal((s, d)).astype(np.float32)
        return measure(lambda: host(x), iters=3)

    def transfer_fn():
        import jax as _j
        return measure(lambda: _j.device_put(host.w_gate).block_until_ready(),
                       iters=3)

    lat = LatencyModel.calibrate(fast_fn, slow_fn, transfer_fn,
                                 sizes=(1, 4, 16))
    # sane, positive, and usable by the planner
    assert lat.gpu_const > 0 and lat.cpu_per_token > 0
    assert lat.transfer_lat() > 0
    assert np.isfinite(lat.crossover())


def test_derive_scales_with_model_size():
    small = LatencyModel.derive(get_config("qwen3-0.6b"))  # dense: no experts
    big = LatencyModel.derive(get_config("mixtral-8x22b"))
    assert big.weight_transfer > small.weight_transfer
    assert expert_weight_bytes(get_config("mixtral-8x22b")) > \
        expert_weight_bytes(get_config("mixtral-8x7b"))


def test_paper_env_budgets():
    """Paper Table 1: Env-1 fits 56/256 experts, Env-2 fits 125/256
    (Mixtral-8x7B bf16).  Our capacity math reproduces the same order."""
    cfg = get_config("mixtral-8x7b")
    b1 = fast_tier_expert_budget(cfg, HardwareSpec.paper_env1())
    b2 = fast_tier_expert_budget(cfg, HardwareSpec.paper_env2())
    assert 40 <= b1 <= 70, b1
    assert 100 <= b2 <= 145, b2
    assert non_expert_bytes(cfg) < 5e9  # "< 2B params" (paper §3.1)


def test_expert_flops_formula():
    cfg = get_config("mixtral-8x7b")
    assert expert_flops_per_token(cfg) == 2 * 3 * 4096 * 14336
