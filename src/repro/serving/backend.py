"""Common serving-backend protocol.

``ServingEngine`` (static grouped batches) and ``ContinuousEngine``
(slot-based continuous batching) used to be hard-wired to the monolithic
jitted ``Model`` and to ``FiddlerEngine`` respectively.  This module
extracts the surface both schedulers need —

* a **clock source** (wall time for real execution, the orchestrator's
  simulated-seconds ledger for the fast/slow-tier regime),
* **prefill-into-slot** (whole-prompt or chunked, producing a batch-1
  cache that joins the multi-slot cache via ``write_slot``),
* a **multi-slot decode step** (every slot at its own position, with an
  active mask so idle slots are padding, not load),
* **slot lineage** for gang-scheduled beam groups — ``fork_slot`` /
  ``reorder_slots`` / ``release_slot`` (+ ``block_stats``): block-table
  aliases and permutations under the paged KV layout (zero data
  movement), row copies under dense layouts,
* **grouped prefill/decode** (the static-batch path),

— so either scheduler runs over either execution engine.  TTFT/ITL
recorded against ``clock()`` are therefore wall-clock for the ``Model``
backend and simulated seconds for the ``FiddlerEngine`` backend (the
paper's setting: the modelled hardware, not this container's CPU).
"""
from __future__ import annotations

import time
import warnings
from typing import Any, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.serving.policy import CostView


class ServingBackend:
    """Interface both serving schedulers target.  ``max_seq`` is fixed at
    construction (it is baked into jitted signatures and cache shapes).

    Slot-mask contract: ``decode_slots``'s ``active`` mask is what governs
    accounting — rows outside the mask are padding, never load, so a
    scheduler that shrinks its live pool (see ``SchedulerPolicy
    .target_slots``) is charged only for the slots it actually runs.
    ``resize_cache`` grows (or shrinks) the allocated pool itself."""

    max_seq: int

    # -- clock --------------------------------------------------------------
    def clock(self) -> float:
        raise NotImplementedError

    def wait_until(self, t: float) -> None:
        """Advance the clock to ``t`` (idle gap between arrivals):
        simulated clocks fast-forward, wall clocks sleep.  Implementations
        must actually reach ``t`` — the continuous scheduler relies on it
        to admit future-arrival requests instead of busy-spinning."""
        raise NotImplementedError

    # -- placement maintenance ----------------------------------------------
    def maybe_rebalance(self) -> Any:
        """One dynamic-rebalancing tick (core/rebalance.py).  The serving
        engines call this between decode steps; backends whose execution
        engine tracks live expert popularity migrate experts between
        tiers here (charging transfer time to their clock).  Default:
        placement is static — a no-op."""
        return None

    def finalize(self) -> None:
        """End-of-run settlement.  The serving engines call this when a
        run drains; backends with asynchronous migration prefetches
        (core/rebalance.py ``PrefetchQueue``) force the in-flight
        transfers to completion here so ledger accounting adds up
        (overlapped + exposed == migration_time).  Default: no-op."""
        return None

    # -- fault injection (core/faults.py) ------------------------------------
    @property
    def faults(self):
        """The backend's :class:`FaultInjector`, if any (``None`` =
        fault-free — the default)."""
        return None

    def begin_step(self, step: int) -> None:
        """Per-scheduler-tick fault bookkeeping hook: backends with an
        injector arm this tick's scripted/seeded faults, release expired
        KV-pressure holds, and settle the previous tick's degraded flag
        here.  The serving engines call it once at the top of every
        tick.  Default: no-op."""
        return None

    def record_fault_recovery(self) -> None:
        """The scheduler recovered a slot from a mid-step failure
        (evict→requeue→re-prefill) — backends with a ledger charge their
        retry counters here.  Default: no-op."""
        return None

    # -- cost model (roofline scheduling) ------------------------------------
    def cost_view(self) -> Optional[CostView]:
        """Per-phase roofline constants for phase-aware policies
        (``RooflinePolicy``).  Default: ``None`` — wall-clock backends
        have no cost model and policies must degrade gracefully."""
        return None

    # -- stream overlap (disaggregated prefill/decode) -----------------------
    def open_overlap_window(self, seconds: float) -> None:
        """Declare that the next prefill charges may hide under a decode
        stream that just ran for ``seconds`` of backend clock.  Backends
        with a simulated clock split subsequent prefill time into
        overlapped (absorbed into the window) vs exposed; the default —
        real wall clocks, where time is not ours to rewrite — is a
        no-op."""
        return None

    def close_overlap_window(self) -> None:
        """End the overlap window: any unused decode budget lapses."""
        return None

    # -- slot API (continuous batching) -------------------------------------
    def make_cache(self, n_slots: int) -> Any:
        raise NotImplementedError

    def prefill(self, prompt: Sequence[int]) -> Tuple[np.ndarray, Any]:
        """Deprecated whole-prompt prefill → ((V,) last-token logits,
        batch-1 cache).  There is one prefill surface now —
        ``prefill_chunk`` — and this wrapper simply runs the whole prompt
        as a single chunk."""
        warnings.warn(
            "ServingBackend.prefill is deprecated; use "
            "prefill_chunk(None, prompt, 0) (one chunk = whole prompt)",
            DeprecationWarning, stacklevel=2)
        return self.prefill_chunk(None, list(prompt), 0)

    def prefill_chunk(self, slot_cache: Optional[Any],
                      chunk: Sequence[int], pos_offset: int,
                      cache: Any = None, slot: Optional[int] = None
                      ) -> Tuple[np.ndarray, Any]:
        """Process one prompt chunk at ``pos_offset``; ``slot_cache`` is
        None on the first chunk.  Returns ((V,) logits of the chunk's last
        position, updated batch-1 cache).  ``cache``/``slot`` (optional)
        name the multi-slot row this prefill will join: paged backends
        then stage the chunks directly into that row's pool blocks, so
        ``write_slot`` is a zero-copy table splice and prefix-matched
        blocks already in the row are attended to."""
        raise NotImplementedError

    def write_slot(self, cache: Any, slot_cache: Any, slot: int) -> Any:
        raise NotImplementedError

    # -- cross-request prefix cache ------------------------------------------
    def match_prefix(self, cache: Any, slot: int,
                     tokens: Sequence[int]) -> int:
        """Admission probe: splice the longest resident verified prefix of
        ``tokens`` into row ``slot`` (refcount bumps, COW on divergence)
        and return how many prompt tokens it covers — the scheduler then
        prefills only the tail.  Default: no prefix cache (dense/Model
        backends) — always 0, the clean no-op."""
        return 0

    def register_prefix(self, cache: Any, slot: int,
                        tokens: Sequence[int]) -> None:
        """Publish row ``slot``'s fully-written prompt blocks for reuse by
        later admissions (post-join).  Default: no-op."""
        return None

    def resize_cache(self, cache: Any, *, n_slots: int) -> Any:
        """Re-allocate the multi-slot cache with ``n_slots`` rows,
        preserving rows ``0..min(old, new)-1`` (slot autoscaling).  The
        default allocates fresh via ``make_cache`` and copies leaf axis 0;
        backends whose leaves are not slot-major must override."""
        fresh = self.make_cache(n_slots)
        return jax.tree.map(_copy_rows(0), fresh, cache)

    def decode_slots(self, cache: Any, tokens: np.ndarray, pos: np.ndarray,
                     active: np.ndarray) -> Tuple[np.ndarray, Any]:
        """One decode step over all slots.  tokens/pos/active: (n_slots,).
        Returns ((n_slots, V) logits, updated cache)."""
        raise NotImplementedError

    # -- slot lineage (beam groups) ------------------------------------------
    def fork_slot(self, cache: Any, *, src: int, dst: int) -> Any:
        """Slot ``dst`` becomes a copy of ``src`` — beam-group member
        creation after the shared prompt prefill.  Paged-KV backends
        implement this as a block-table alias (copy-on-write, zero KV
        data movement); dense backends copy the row."""
        raise NotImplementedError

    def reorder_slots(self, cache: Any, *, slots: Sequence[int],
                      src_of: Sequence[int]) -> Any:
        """Beam reshuffle: ``slots[i]`` continues the sequence held by
        ``src_of[i]`` (sources may repeat).  Paged: table permutation +
        refcount bumps only."""
        raise NotImplementedError

    def release_slot(self, cache: Any, *, slot: int) -> Any:
        """A retired/evicted request leaves ``slot``: paged backends
        return its KV blocks to the pool (refcount decrements).  Default:
        no-op — dense rows are just overwritten by the next occupant."""
        return cache

    def block_stats(self, cache: Any,
                    slots: Optional[Sequence[int]] = None
                    ) -> Optional[dict]:
        """Unique-vs-dense KV block accounting for ``slots`` (paged
        backends; None otherwise) — what the beam benchmark reports."""
        return None

    # -- group API (static batching) ----------------------------------------
    def prefill_group(self, prompts: np.ndarray
                      ) -> Tuple[jnp.ndarray, Any]:
        """Padded (B, S) prompt batch → ((B, V) logits, cache)."""
        raise NotImplementedError

    def decode_group(self, cache: Any, tokens: np.ndarray, pos: int
                     ) -> Tuple[jnp.ndarray, Any]:
        """One decode step at shared scalar position ``pos``."""
        raise NotImplementedError


def _copy_rows(axis: int):
    """Tree-map helper: copy the leading ``min(new, old)`` entries of
    ``axis`` from the old cache leaf into the freshly-allocated one."""
    def _copy(f, o):
        n = min(f.shape[axis], o.shape[axis])
        idx = (slice(None),) * axis + (slice(0, n),)
        return f.at[idx].set(o[idx].astype(f.dtype))
    return _copy


# ---------------------------------------------------------------------------
# Monolithic jitted Model backend (capacity-sufficient regime)
# ---------------------------------------------------------------------------


class ModelBackend(ServingBackend):
    """Jitted ``repro.models.Model`` execution; wall-clock timing."""

    def __init__(self, model, params, *, max_seq: int = 256, faults=None):
        self.model = model
        self.params = params
        self.max_seq = max_seq
        self._faults = faults
        # group path keeps the model's default (bf16) cache — only the
        # slot path needs fp32 to splice into make_cache(dtype=float32)
        self._prefill_grp = jax.jit(
            lambda p, t: model.prefill(p, t, max_seq))
        self._prefill_chunk = jax.jit(
            lambda p, c, t, off: model.prefill_chunk(p, c, t, off, max_seq))
        self._decode_multi = jax.jit(
            lambda p, c, t, pos: model.decode_step_multi(p, c, t, pos,
                                                         max_seq))
        self._decode1 = jax.jit(
            lambda p, c, t, pos: model.decode_step(p, c, t, pos, max_seq))

    def clock(self) -> float:
        return time.perf_counter()

    def wait_until(self, t: float) -> None:
        dt = t - self.clock()
        if dt > 0:
            time.sleep(dt)

    @property
    def faults(self):
        return self._faults

    def begin_step(self, step: int) -> None:
        if self._faults is None:
            return
        self._faults.begin_step(step)
        # wall-clock backend: the only meaningful injection is a real
        # per-step latency spike (capped — this is a smoke-scale knob)
        ev = self._faults.fires("latency_spike")
        if ev is not None:
            time.sleep(min(ev.magnitude * self._faults.latency_spike_s,
                           0.05))

    # slot API
    def make_cache(self, n_slots: int) -> Any:
        return self.model.make_cache(n_slots, self.max_seq,
                                     dtype=jnp.float32)

    def prefill_chunk(self, slot_cache, chunk, pos_offset,
                      cache=None, slot=None):
        # dense layout: staging stays a private batch-1 cache (cache/slot
        # hints are paged-only)
        if slot_cache is None:
            slot_cache = self.model.make_cache(1, self.max_seq,
                                               dtype=jnp.float32)
        logits, slot_cache = self._prefill_chunk(
            self.params, slot_cache, jnp.asarray([list(chunk)], jnp.int32),
            jnp.int32(pos_offset))
        return np.asarray(logits[0]), slot_cache

    def write_slot(self, cache, slot_cache, slot):
        return self.model.write_slot(cache, slot_cache, slot)

    def resize_cache(self, cache, *, n_slots):
        """``Model.make_cache`` leaves are not slot-major: block caches
        are scan-stacked (n_periods, B, ...) — batch axis 1 — while tail
        and per-layer caches keep batch on axis 0 (same layout contract
        as ``Model.write_slot``/``reorder_cache``)."""
        fresh = self.make_cache(n_slots)
        out = dict(fresh)
        out["blocks"] = jax.tree.map(_copy_rows(1), fresh["blocks"],
                                     cache["blocks"])
        out["tail"] = jax.tree.map(_copy_rows(0), fresh["tail"],
                                   cache["tail"])
        if "cross_kv" in fresh:
            out["cross_kv"] = jax.tree.map(_copy_rows(1), fresh["cross_kv"],
                                           cache["cross_kv"])
        return out

    def decode_slots(self, cache, tokens, pos, active):
        logits, cache = self._decode_multi(
            self.params, cache, jnp.asarray(tokens, jnp.int32)[:, None],
            jnp.asarray(pos, jnp.int32))
        return np.asarray(logits), cache

    def fork_slot(self, cache, *, src, dst):
        return self.model.fork_slot(cache, src, dst)

    def reorder_slots(self, cache, *, slots, src_of):
        return self.model.reorder_slots(cache, slots, src_of)

    # group API
    def prefill_group(self, prompts):
        return self._prefill_grp(self.params, jnp.asarray(prompts, jnp.int32))

    def decode_group(self, cache, tokens, pos):
        return self._decode1(self.params, cache,
                             jnp.asarray(tokens, jnp.int32)[:, None],
                             jnp.int32(pos))


# ---------------------------------------------------------------------------
# Fiddler orchestrator backend (fast/slow-tier regime — the paper's setting)
# ---------------------------------------------------------------------------


class FiddlerBackend(ServingBackend):
    """Orchestrated execution over a ``FiddlerEngine``; the clock is the
    engine ledger's simulated seconds, so per-request TTFT/ITL reflect the
    modelled hardware and the planner's fast/stream/slow decisions."""

    def __init__(self, engine, *, max_seq: int = 256):
        assert engine.model is not None, (
            "FiddlerBackend needs a FiddlerEngine built with params "
            "(real-numerics mode)")
        self.engine = engine
        self.max_seq = max_seq

    @property
    def ledger(self):
        return self.engine.ledger

    def clock(self) -> float:
        return self.engine.ledger.sim_time

    def wait_until(self, t: float) -> None:
        led = self.engine.ledger
        led.sim_time = max(led.sim_time, t)

    def maybe_rebalance(self):
        return self.engine.maybe_rebalance()

    def finalize(self) -> None:
        self.engine.flush_prefetch()
        self.engine.release_fault_holds()

    @property
    def faults(self):
        return self.engine.faults

    def begin_step(self, step: int) -> None:
        self.engine.begin_fault_step(step)

    def record_fault_recovery(self) -> None:
        self.engine.note_recovery()

    def cost_view(self):
        return _engine_cost_view(self.engine)

    def open_overlap_window(self, seconds: float) -> None:
        self.engine.open_overlap_window(seconds)

    def close_overlap_window(self) -> None:
        self.engine.close_overlap_window()

    # slot API
    def make_cache(self, n_slots: int) -> Any:
        return self.engine.make_decode_caches(n_slots, self.max_seq)

    def prefill_chunk(self, slot_cache, chunk, pos_offset,
                      cache=None, slot=None):
        if (slot_cache is None and cache is not None and slot is not None
                and self.engine.kv_layout == "paged"):
            # stage the chunks straight into the target pool row: the
            # join is then a pure table splice (write_slot no-op) and any
            # prefix-matched blocks already in the row are attended to
            slot_cache = self.engine.make_slot_stage(cache, slot)
        logits, slot_cache = self.engine.prefill_chunk(
            jnp.asarray([list(chunk)], jnp.int32), slot_cache, pos_offset,
            self.max_seq)
        return np.asarray(logits[0]), slot_cache

    def write_slot(self, cache, slot_cache, slot):
        return self.engine.write_slot(cache, slot_cache, slot)

    def match_prefix(self, cache, slot, tokens):
        return self.engine.kv_match_prefix(cache, slot, list(tokens))

    def register_prefix(self, cache, slot, tokens):
        self.engine.kv_register_prefix(cache, slot, list(tokens))

    def resize_cache(self, cache, *, n_slots):
        if self.engine.kv_layout == "paged":
            # block tables grow/shrink in place; the pool only ever grows
            return self.engine.resize_decode_caches(cache, n_slots)
        return super().resize_cache(cache, n_slots=n_slots)

    def decode_slots(self, cache, tokens, pos, active):
        f = self.engine.faults
        if f is not None and self.engine.kv_layout == "paged":
            f.kv_pressure_tick([c.meta for c in cache])
        logits, cache = self.engine.decode_step_multi(
            cache, jnp.asarray(tokens, jnp.int32)[:, None], pos,
            self.max_seq, active=active)
        return np.asarray(logits), cache

    def fork_slot(self, cache, *, src, dst):
        return self.engine.fork_slot(cache, src, dst)

    def reorder_slots(self, cache, *, slots, src_of):
        return self.engine.reorder_slots(cache, list(slots), list(src_of))

    def release_slot(self, cache, *, slot):
        return self.engine.release_slot(cache, slot)

    def block_stats(self, cache, slots=None):
        return self.engine.kv_block_stats(
            cache, None if slots is None else list(slots))

    # group API
    def prefill_group(self, prompts):
        return self.engine.prefill(jnp.asarray(prompts, jnp.int32),
                                   self.max_seq)

    def decode_group(self, cache, tokens, pos):
        return self.engine.decode_step(cache,
                                       jnp.asarray(tokens, jnp.int32)[:, None],
                                       pos, self.max_seq)


# ---------------------------------------------------------------------------
# Pure-simulation backend (full-size configs, no weights)
# ---------------------------------------------------------------------------


class SimulatedBackend(ServingBackend):
    """Slot API over a *param-less* ``FiddlerEngine``: routing is sampled
    from the popularity profile (the engine's ``simulate_*`` path) and
    only the simulated-seconds ledger advances — no weights, no numerics.
    This is what lets ``ContinuousEngine`` + ``SchedulerPolicy`` sweeps
    run at paper scale (full Mixtral-8x7B configs, heavy traffic) on a
    bare CPU container.

    Logits are a fixed one-hot on a non-EOS token, so greedy decoding
    always runs each request to its ``max_new_tokens`` — the load pattern,
    not the text, is what the simulation measures.

    KV accounting mirrors the paged layout: the cache carries a
    :class:`BlockMeta` (models/paged_kv.py) — block table, refcounts,
    copy-on-write — with no device data, so slot forks/reshuffles are
    table-only and every decode step is charged by *unique* block entries
    (``simulate_decode_multi(kv_unique=...)``).  Unforked workloads have
    ``unique == sum(kv_len)`` exactly, so non-beam sweeps
    (BENCH_serve_load.json) are unchanged; beam groups charge their
    shared prompt prefix once — the honest paper-scale beam story.

    **N-device ledger** (``FiddlerEngine(n_fast_devices=D)``, D > 1):
    each fast device owns its *own* block pool — the cache carries D
    :class:`BlockMeta` shards and slots map to devices in contiguous
    stripes of ``chunk`` slots (``device = (slot // chunk) % D``, stable
    under ``resize_cache`` growth), so gangs/slots schedule against
    per-device capacity and the leak audit is per device.  KV never
    aliases across pools: a cross-device ``fork_slot`` (gang spilled over
    a device boundary — the scheduler's device-aligned admission makes
    this the rare fallback) rebuilds a dense private copy instead of a
    COW table alias.  D == 1 keeps the single-meta cache byte-for-byte —
    the bit-identity twin."""

    FAKE_TOKEN = 5  # != EOS_ID(2), != PAD_ID(0)
    # minimum contiguous slots per device stripe: covers typical beam
    # widths so gangs admit device-local even when the pool boots small
    KV_STRIPE = 4

    def __init__(self, engine, *, max_seq: int = 256):
        self.engine = engine
        self.max_seq = max_seq
        self._vocab = engine.cfg.vocab_size
        self.n_kv_devices = max(1, int(getattr(engine, "n_fast_devices", 1)))

    @property
    def ledger(self):
        return self.engine.ledger

    def clock(self) -> float:
        return self.engine.ledger.sim_time

    def wait_until(self, t: float) -> None:
        led = self.engine.ledger
        led.sim_time = max(led.sim_time, t)

    def maybe_rebalance(self):
        return self.engine.maybe_rebalance()

    def finalize(self) -> None:
        self.engine.flush_prefetch()
        self.engine.release_fault_holds()

    @property
    def faults(self):
        return self.engine.faults

    def begin_step(self, step: int) -> None:
        self.engine.begin_fault_step(step)

    def record_fault_recovery(self) -> None:
        self.engine.note_recovery()

    def cost_view(self):
        return _engine_cost_view(self.engine)

    def open_overlap_window(self, seconds: float) -> None:
        self.engine.open_overlap_window(seconds)

    def close_overlap_window(self) -> None:
        self.engine.close_overlap_window()

    def _logits(self, n: Optional[int] = None) -> np.ndarray:
        row = np.zeros((self._vocab,), np.float32)
        row[self.FAKE_TOKEN] = 1.0
        return row if n is None else np.tile(row, (n, 1))

    # slot API — caches carry slot count + block-table metadata; only the
    # ledger (and the table bookkeeping that feeds its KV charging) matters
    @staticmethod
    def _dev_slots(n_slots: int, chunk: int, D: int, d: int) -> int:
        """How many of ``n_slots`` striped global slots land on device
        ``d`` (device = (slot // chunk) % D)."""
        cycles, rem = divmod(n_slots, chunk * D)
        return cycles * chunk + min(max(rem - d * chunk, 0), chunk)

    def make_cache(self, n_slots: int) -> Any:
        from repro.models.paged_kv import BlockMeta
        D = self.n_kv_devices
        prefix = getattr(self.engine, "prefix_cache", False)
        # ``matched``: per-slot prompt tokens spliced from the prefix
        # index at admission (write_slot then skips re-writing them)
        if D == 1:
            meta = BlockMeta(n_slots, self.max_seq)
            if prefix:
                meta.enable_prefix_cache()
            return {"n_slots": n_slots, "meta": meta, "matched": {}}
        chunk = max(self.KV_STRIPE, -(-n_slots // D))
        metas = [BlockMeta(max(self._dev_slots(n_slots, chunk, D, d), 1),
                           self.max_seq) for d in range(D)]
        if prefix:
            for m in metas:
                m.enable_prefix_cache()
        return {"n_slots": n_slots, "chunk": chunk, "metas": metas,
                "matched": {}}

    def _metas(self, cache: Any) -> list:
        return cache["metas"] if "metas" in cache else [cache["meta"]]

    def _locate(self, cache: Any, slot: int) -> Tuple[Any, int]:
        """(owning device pool, device-local slot) of a global slot."""
        if "metas" not in cache:
            return cache["meta"], int(slot)
        D, chunk = len(cache["metas"]), cache["chunk"]
        slot = int(slot)
        d = (slot // chunk) % D
        local = (slot // (chunk * D)) * chunk + slot % chunk
        return cache["metas"][d], local

    def device_of_slot(self, cache: Any, slot: int) -> int:
        """Which fast device's pool holds ``slot``'s KV (the scheduler's
        gang-colocation hint)."""
        if "metas" not in cache:
            return 0
        return (int(slot) // cache["chunk"]) % len(cache["metas"])

    def _locals_by_device(self, cache: Any,
                          slots: Optional[Sequence[int]]) -> dict:
        """device → local slot list for ``slots`` (None = every slot)."""
        if slots is None:
            slots = range(cache["n_slots"])
        by_dev: dict = {}
        for s in slots:
            d = self.device_of_slot(cache, int(s))
            _, local = self._locate(cache, int(s))
            by_dev.setdefault(d, []).append(local)
        return by_dev

    def _unique_tokens(self, cache: Any,
                       slots: Optional[Sequence[int]]) -> int:
        """Unique written KV entries over ``slots``: shards can never
        alias across device pools, so the total is the per-pool sum."""
        if "metas" not in cache:
            return cache["meta"].unique_tokens(slots)
        return sum(cache["metas"][d].unique_tokens(loc)
                   for d, loc in self._locals_by_device(cache, slots).items())

    def resize_cache(self, cache: Any, *, n_slots: int) -> Any:
        if "metas" not in cache:
            cache["meta"].resize(n_slots)
            return {"n_slots": n_slots, "meta": cache["meta"],
                    "matched": cache.get("matched", {})}
        chunk, metas = cache["chunk"], cache["metas"]
        for d, m in enumerate(metas):
            m.resize(max(self._dev_slots(n_slots, chunk, len(metas), d), 1))
        return {"n_slots": n_slots, "chunk": chunk, "metas": metas,
                "matched": cache.get("matched", {})}

    def prefill_chunk(self, slot_cache, chunk, pos_offset,
                      cache=None, slot=None):
        n = len(list(chunk))
        self.engine.simulate_prefill_chunk(n, kv_len=pos_offset + n)
        return self._logits(), {"staged": pos_offset + n}

    def write_slot(self, cache, slot_cache, slot):
        meta, local = self._locate(cache, slot)
        start = int(cache.get("matched", {}).pop(slot, 0))
        if start == 0:
            meta.release_slot(local)
        # a prefix-matched slot keeps its spliced head blocks and only
        # appends the freshly-prefilled tail
        meta.write_span(local, start, int(slot_cache["staged"]))
        return cache

    def match_prefix(self, cache, slot, tokens):
        meta, local = self._locate(cache, slot)
        if meta.index is None:
            return 0
        led = self.engine.ledger
        led.prefix_lookups += 1
        tokens = [int(t) for t in tokens]
        blocks = meta.match_prefix(tokens)
        bs = meta.block_size
        n = min(len(blocks), (len(tokens) - 1) // bs)
        if n <= 0:
            return 0
        meta.map_prefix(local, blocks[:n])
        cache.setdefault("matched", {})[slot] = n * bs
        led.prefix_hits += 1
        led.prefix_tokens += n * bs
        return n * bs

    def register_prefix(self, cache, slot, tokens):
        meta, local = self._locate(cache, slot)
        if meta.index is not None:
            meta.register_prefix(local, [int(t) for t in tokens])

    def decode_slots(self, cache, tokens, pos, active):
        active = np.asarray(active, bool)
        live = np.nonzero(active)[0]
        f = self.engine.faults
        if f is not None:
            f.kv_pressure_tick(self._metas(cache))
        for i in live:
            meta, local = self._locate(cache, int(i))
            p = int(pos[i])
            meta.write_span(local, p, p + 1)
        kv_lens = np.asarray(pos)[active].astype(np.int64) + 1
        self.engine.simulate_decode_multi(
            kv_lens, kv_unique=self._unique_tokens(cache, live))
        return self._logits(len(active)), cache

    def fork_slot(self, cache, *, src, dst):
        ms, ls = self._locate(cache, src)
        md, ld = self._locate(cache, dst)
        if ms is md:
            ms.fork_slot(ls, ld)
        else:
            # gang spilled across a device boundary: pools cannot share
            # blocks, so the sibling rebuilds a dense private copy of the
            # lead's written entries instead of a COW alias
            md.release_slot(ld)
            md.write_span(ld, 0, ms.dense_tokens([ls]))
        return cache

    def reorder_slots(self, cache, *, slots, src_of):
        if "metas" not in cache:
            cache["meta"].reorder_slots(list(slots), list(src_of))
            return cache
        per: dict = {}
        for s, r in zip(slots, src_of):
            ms, ls = self._locate(cache, s)
            mr, lr = self._locate(cache, r)
            assert ms is mr, (
                f"beam reshuffle crosses device pools (slot {s} ← {r}); "
                "gangs must stay device-local")
            _, dst, src = per.setdefault(id(ms), (ms, [], []))
            dst.append(ls)
            src.append(lr)
        for m, dst, src in per.values():
            m.reorder_slots(dst, src)
        return cache

    def release_slot(self, cache, *, slot):
        meta, local = self._locate(cache, slot)
        meta.release_slot(local)
        cache.get("matched", {}).pop(slot, None)
        return cache

    def kv_check(self, cache) -> list:
        """Per-device leak audit: refcount/free-list consistency on every
        pool plus each pool's still-referenced block count — all zeros
        after a clean drain.  What the mesh-scaling gate asserts."""
        out = []
        for m in self._metas(cache):
            m.check()
            out.append(int(m.blocks_in_use()))
        return out

    def block_stats(self, cache, slots=None):
        def _one(m, sl):
            return {"unique_blocks": m.blocks_in_use(sl),
                    "dense_blocks": m.dense_blocks(sl),
                    "unique_tokens": m.unique_tokens(sl),
                    "dense_tokens": m.dense_tokens(sl),
                    "cached_blocks": m.n_cached}
        if "metas" not in cache:
            return _one(cache["meta"], slots)
        by_dev = self._locals_by_device(cache, slots)
        per = [_one(m, by_dev.get(d, []))
               for d, m in enumerate(cache["metas"])]
        agg = {k: sum(p[k] for p in per) for k in per[0]}
        agg["n_devices"] = len(per)
        agg["per_device"] = per
        return agg

    # group API (static scheduler over the simulation)
    def prefill_group(self, prompts):
        B, S = np.asarray(prompts).shape
        self.engine.simulate_prefill_chunk(B * S, kv_len=S)
        return jnp.asarray(self._logits(B)), {"pos": S, "batch": B}

    def decode_group(self, cache, tokens, pos):
        B = cache["batch"]
        self.engine.simulate_decode_multi(np.full(B, pos + 1, np.int64))
        return jnp.asarray(self._logits(B)), cache


def _engine_cost_view(engine) -> Optional[CostView]:
    """Roofline constants from a ``FiddlerEngine``'s latency model —
    the same per-phase flops/bytes its simulated ledger charges with."""
    cfg, lat, hw = engine.cfg, engine.lat, engine.hw
    if cfg.moe is None:
        return None
    return CostView(gpu_const=lat.gpu_const,
                    gpu_per_token=lat.gpu_per_token,
                    n_experts=cfg.moe.n_experts,
                    top_k=cfg.moe.top_k,
                    fast_flops=hw.fast_flops,
                    fast_mem_bw=hw.fast_mem_bw)


def as_backend(obj, *, params=None, mode: Optional[str] = None,
               max_seq: int = 256) -> ServingBackend:
    """Coerce (Model, params) / FiddlerEngine / ready backend → backend."""
    if isinstance(obj, ServingBackend):
        return obj
    if mode == "fiddler" or (mode is None and hasattr(obj, "ledger")):
        if getattr(obj, "model", None) is None:
            return SimulatedBackend(obj, max_seq=max_seq)
        return FiddlerBackend(obj, max_seq=max_seq)
    return ModelBackend(obj, params, max_seq=max_seq)
