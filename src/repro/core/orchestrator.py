"""Fiddler's two-tier execution engine (paper §3.1–§3.3, Figure 2/3).

The engine serves a MoE model whose experts are split between a fast tier
(TPU HBM / the paper's GPU) and a slow tier (host DRAM / the paper's CPU
memory).  Non-expert layers always live on the fast tier.  Per MoE layer it
runs the gate, observes per-expert input sizes, and plans each expert's
execution (core/planner.py, Algorithm 1):

* FAST_RESIDENT — fast-tier kernel over the layer's *stacked* resident
  pool (one ``(E_fast, d, f)`` array per weight matrix);
* FAST_STREAM   — weights move slow→fast (a real ``jax.device_put`` of the
  host numpy weights) and then the fast kernel runs — paper Fig. 3(b);
* SLOW          — activations move to the host and the numpy
  ``HostExpert`` kernel runs — paper Fig. 3(c).

Only the *planning* is data-dependent python control flow; execution is
**batched grouped dispatch** (``dispatch_mode="grouped"``, the default):
a layer's fast-tier rows are gathered into a capacity-bucketed dispatch
buffer (group size and capacity padded to powers of two so the jit
cache holds a handful of shapes) and executed by ONE grouped gated-MLP
launch over the resident stack (kernels/ops.py
``grouped_gather_mlp_op``; streamed/LRU weights get one more stacked
launch) instead of one jit dispatch plus a host round-trip per expert.
The grouped kernel evaluates every expert at its exact routed row count
(a ``lax.switch`` over count branches — see kernels/ref.py), so grouped
execution is bit-identical on fp32 to ``dispatch_mode="eager"``, the
one-kernel-per-expert loop (the paper's PyTorch-style implementation)
kept for equivalence tests and old-vs-new benchmarks.  SLOW experts run
on a shared host worker pool *concurrently* with the fast-tier calls
when ``overlap=True`` — the paper's CPU/GPU overlap, for real, not just
in the ledger's estimate.

Numerics are real — tests assert the orchestrated output matches the
monolithic jit MoE — and the wall-clock ledger is kept in *simulated
seconds* from the calibrated latency model, so benchmark numbers reflect
the modelled hardware (TPU-v5e host or the paper's GPU environments)
rather than this container's CPU.  Dynamic-rebalancing promotions
(core/rebalance.py) are asynchronous prefetches by default: their
transfer time rides idle link windows between FAST_STREAM transfers and
only the exposed remainder is charged to ``sim_time`` (see
``Ledger.migration_overlapped`` / ``migration_exposed``).

``policy`` selects the paper's system or a baseline:
  fiddler      — Algorithm 1 (this paper);
  offload      — always stream missing experts (DeepSpeed-MII /
                 Mixtral-Offloading-style);
  static_split — llama.cpp-style: first k layers fully fast-tier, the rest
                 executed wholly on the host (including attention).
"""
from __future__ import annotations

import atexit
import dataclasses
import os
import threading
import time
from concurrent.futures import ThreadPoolExecutor
from concurrent.futures import TimeoutError as FuturesTimeout
from dataclasses import dataclass, field
from typing import Any, Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig
from repro.core.cost_model import (
    HardwareSpec,
    LatencyModel,
    alltoall_time,
    expert_weight_bytes,
    kv_read_entries,
    link_idle_time,
)
from repro.core.faults import (
    CircuitBreaker,
    FaultInjector,
    HostHealth,
    HostWorkerFault,
)
from repro.core.placement import (
    DevicePlacement,
    Placement,
    fast_tier_expert_budget,
    place_by_popularity,
    place_static_split,
    to_device_placement,
)
from repro.core.planner import Decision, LayerPlan, plan_layer
from repro.core.popularity import ExpertProfile, OnlineProfile, synthetic_profile
from repro.core.rebalance import (
    MigrationPlan,
    PrefetchQueue,
    Rebalancer,
    apply_plan,
)
from repro.kernels.host_expert import HostExpert
from repro.kernels.ops import (
    expert_mlp_op,
    grouped_gated_mlp_op,
    grouped_gather_mlp_op,
)
from repro.models.model import Model
from repro.models.moe import route
from repro.models.paged_kv import (
    PAGE_SIZE,
    GlobalPagedPool,
    PagedLayerCache,
    PagedSlotStage,
)

POLICIES = ("fiddler", "offload", "static_split")
DISPATCH_MODES = ("grouped", "eager")
KV_LAYOUTS = ("paged", "dense")

# Default cap on Ledger.layer_log: a ring buffer of the most recent
# per-layer charges — long serving sweeps used to grow it one dict per
# layer per step, unbounded.
LAYER_LOG_LIMIT = 512

# Row counts up to this share one capacity-bucketed launch whose kernel
# switches on the exact count (≤ SWITCH_CAP+1 compiled branches — the
# decode regime).  Larger counts (prefill-sized) dispatch as uniform
# exact-count launches instead, so the switch never traces hundreds of
# GEMM branches.
SWITCH_CAP = 16

# Shared host worker pool for slow-tier experts: one per process (engines
# come and go — tests build hundreds — so pooling threads per engine
# would leak).  Slow experts are pure numpy; jax stays on the caller's
# thread.  Init is double-checked under a lock: _host_pool() is called
# from overlap futures as well as the main thread, and a check-then-set
# on the bare global can construct two executors and strand one.
_HOST_POOL: Optional[ThreadPoolExecutor] = None
_HOST_POOL_LOCK = threading.Lock()
# Static default worker count; the calibration probe (core/host_calibration)
# replaces it with the measured scaling knee via set_host_pool_workers.
_HOST_POOL_WORKERS = max(2, min(8, (os.cpu_count() or 2) - 1))


def _shutdown_host_pool() -> None:
    global _HOST_POOL
    with _HOST_POOL_LOCK:
        if _HOST_POOL is not None:
            _HOST_POOL.shutdown(wait=False, cancel_futures=True)
            _HOST_POOL = None


atexit.register(_shutdown_host_pool)


def _host_pool() -> ThreadPoolExecutor:
    global _HOST_POOL
    pool = _HOST_POOL  # racy fast-path read is fine: set-once under lock
    if pool is None:
        with _HOST_POOL_LOCK:
            pool = _HOST_POOL
            if pool is None:
                pool = _HOST_POOL = ThreadPoolExecutor(
                    max_workers=_HOST_POOL_WORKERS,
                    thread_name_prefix="fiddler-slow")
    return pool


def set_host_pool_workers(n: int) -> None:
    """Resize the shared slow-tier worker pool (one-shot calibration —
    core/host_calibration.py — calls this with the measured scaling knee).
    An existing pool is torn down so the next submit rebuilds it at the
    new width; in-flight work is never cancelled mid-layer because the
    engine only calibrates at init, before submitting."""
    global _HOST_POOL, _HOST_POOL_WORKERS
    n = max(1, int(n))
    with _HOST_POOL_LOCK:
        if n == _HOST_POOL_WORKERS:
            return
        _HOST_POOL_WORKERS = n
        if _HOST_POOL is not None:
            _HOST_POOL.shutdown(wait=True)
            _HOST_POOL = None


def _faulty_worker(fn, ev, real_stall_s: float):
    """Wrap one submitted slow-tier kernel with an injected fault (see
    core/faults.py): a crash raises :class:`HostWorkerFault` through the
    future (the watchdog's retry path resubmits the clean kernel); a
    stall sleeps long enough *wall-clock* that the watchdog timeout
    expires first, then computes the true result."""
    def run(x):
        if ev.kind == "host_crash":
            raise HostWorkerFault(
                f"injected host worker crash (step {ev.step})")
        time.sleep(real_stall_s * ev.magnitude)
        return fn(x)
    return run


def _bucket(n: int) -> int:
    """Pad a dispatch dimension (group size / capacity) to the next power
    of two, so each layer geometry compiles at most log2(max) distinct
    grouped-kernel shapes — the jit cache stays bounded under arbitrary
    routing."""
    return 1 << max(0, int(n) - 1).bit_length() if n > 1 else 1


# ---------------------------------------------------------------------------
# Simulated clock / ledger
# ---------------------------------------------------------------------------


@dataclass
class Ledger:
    sim_time: float = 0.0
    fast_hits: int = 0
    streams: int = 0
    slow_runs: int = 0
    stream_bytes: float = 0.0
    tokens_out: int = 0
    ttft: Optional[float] = None
    # real-execution fast-tier kernel launches (grouped dispatch issues
    # one per expert *group*; the eager loop one per expert)
    fast_dispatches: int = 0
    # dynamic rebalancing (core/rebalance.py): promotions stream over the
    # host link — these fields break the overhead out so benchmarks can
    # report it honestly.  ``migration_time`` is the total link-seconds
    # committed; with async prefetch it splits into ``migration_overlapped``
    # (hidden under idle link windows — costs no sim_time) and
    # ``migration_exposed`` (serialised into sim_time); any difference is
    # still in flight.  Sync mode exposes everything.
    migrations: int = 0             # experts promoted slow → fast
    migration_bytes: float = 0.0
    migration_time: float = 0.0
    migration_overlapped: float = 0.0
    migration_exposed: float = 0.0
    # disaggregated prefill/decode serving (serving/policy.RooflinePolicy):
    # per-stream time under the same overlapped/exposed convention.  The
    # decode gang is the foreground stream — its time is always exposed —
    # and each tick's prefill chunk may hide under the decode window just
    # run (``open_overlap_window``): ``prefill_stream_overlapped`` costs
    # no sim_time, ``prefill_stream_exposed`` is serialised into it.
    # Interleaved (non-overlap) policies leave all six fields at zero.
    prefill_stream_time: float = 0.0
    prefill_stream_overlapped: float = 0.0
    prefill_stream_exposed: float = 0.0
    decode_stream_time: float = 0.0
    decode_stream_overlapped: float = 0.0
    decode_stream_exposed: float = 0.0
    # cross-request prefix cache (models/paged_kv.PrefixIndex): admission
    # lookups, hits, and prompt tokens whose KV was reused from resident
    # blocks instead of being re-prefilled
    prefix_lookups: int = 0
    prefix_hits: int = 0
    prefix_tokens: int = 0
    # fault injection / graceful degradation (core/faults.py): time the
    # clock spent on fault handling — watchdog backoff on host-expert
    # futures, injected link/latency stalls — under the same
    # overlapped/exposed convention.  Fault time never hides under
    # planned overlap (a stall IS the critical path), so the overlapped
    # share stays 0 and fault_time == fault_exposed by construction.
    fault_time: float = 0.0
    fault_overlapped: float = 0.0
    fault_exposed: float = 0.0
    # scheduler ticks that ran in a degraded mode (any fault observed,
    # recovery taken, or SLOW routing re-routed while the host tier was
    # unhealthy), and total retry actions (watchdog re-awaits/resubmits,
    # requeued prefetch transfers, slot-level recoveries)
    degraded_steps: int = 0
    retries: int = 0
    # expert-parallel serving (n_fast_devices > 1): seconds MoE layers
    # spent exchanging dispatch/combine activations between fast devices,
    # under the usual overlapped/exposed convention — the overlapped share
    # hid under concurrent slow-tier work, the exposed share serialised
    # into sim_time.  Single-device engines leave all three at zero.
    alltoall_time: float = 0.0
    alltoall_overlapped: float = 0.0
    alltoall_exposed: float = 0.0
    # per-fast-device busy seconds (compute + stream transfers charged to
    # that device) — the utilization/balance view of an expert-parallel
    # run.  Empty for single-device engines.
    device_busy: List[float] = field(default_factory=list)
    # ring buffer of the most recent per-layer charges (0 disables, None
    # keeps everything — old unbounded behavior)
    layer_log_limit: Optional[int] = LAYER_LOG_LIMIT
    layer_log: List[Dict[str, float]] = field(default_factory=list)

    def tokens_per_second(self) -> float:
        return self.tokens_out / self.sim_time if self.sim_time > 0 else 0.0

    def log_layer(self, entry: Dict[str, float]) -> None:
        lim = self.layer_log_limit
        if lim == 0:
            return
        self.layer_log.append(entry)
        if lim is not None and len(self.layer_log) > lim:
            del self.layer_log[: len(self.layer_log) - lim]


# ---------------------------------------------------------------------------
# Non-expert layer timing (fast tier unless static_split pushes it slow)
# ---------------------------------------------------------------------------


def nonexpert_layer_bytes(cfg: ModelConfig, bytes_per_param: int = 2) -> int:
    d, q, kv = cfg.d_model, cfg.q_dim, cfg.kv_dim
    attn = d * q + 2 * d * kv + q * d
    shared = 0
    if cfg.moe and cfg.moe.n_shared_experts:
        shared = 3 * d * cfg.d_ff * cfg.moe.n_shared_experts
    router = cfg.moe.n_experts * d if cfg.moe else 0
    return (attn + shared + router + 2 * d) * bytes_per_param


def nonexpert_layer_time(cfg: ModelConfig, hw: HardwareSpec, n_tokens: int,
                         kv_len, tier: str = "fast",
                         kv_unique: Optional[float] = None) -> float:
    """``kv_len`` is either a scalar — one sequence's KV read once
    (prefill: queries stream against the same cache) — or an array of
    per-token KV lengths (decode: every row reads its own cache; the
    continuous path has mixed per-slot positions, the static path equal
    ones).  ``kv_unique`` (paged layout) dedups the KV *bytes* read to
    the distinct block entries — a beam group's shared prompt streams
    from memory once — while the attention flop term stays per-token
    (see cost_model.kv_read_entries)."""
    d, q, kv = cfg.d_model, cfg.q_dim, cfg.kv_dim
    wbytes = nonexpert_layer_bytes(cfg)
    if np.ndim(kv_len):
        kv_read = kv_read_entries(kv_len, kv_unique)
        attn_kv = float(np.sum(kv_len))   # per-beam score/value flops
    else:
        kv_read = kv_read_entries(kv_len, kv_unique)
        attn_kv = float(n_tokens) * float(kv_len)
    kv_bytes = 2 * kv_read * kv * 2  # K+V read, bf16
    flops = 2 * n_tokens * (d * q + 2 * d * kv + q * d)
    flops += 4 * attn_kv * q  # attention score+value flops
    if cfg.moe and cfg.moe.n_shared_experts:
        flops += 2 * n_tokens * 3 * d * cfg.d_ff * cfg.moe.n_shared_experts
    if tier == "fast":
        return max((wbytes + kv_bytes) / hw.fast_mem_bw, flops / hw.fast_flops)
    return max((wbytes + kv_bytes) / hw.slow_mem_bw, flops / hw.slow_flops)


# ---------------------------------------------------------------------------
# Stacked fast-tier expert pool (grouped dispatch reads these)
# ---------------------------------------------------------------------------


class _FastStack:
    """One MoE layer's device-resident experts as *stacked* weight arrays
    ``wg/wu`` (cap, d, f) and ``wd`` (cap, f, d): grouped dispatch gathers
    active experts by row index and runs one kernel over the whole group
    instead of one launch per expert.  ``slot[e]`` maps expert id → row;
    ``cap`` is padded to a power of two so promotions rarely reallocate.
    Maintained incrementally as migrations change residency (promote =
    write one row, demote = swap-remove) — rows are always written from
    the engine's original fp32 params, so a migrated expert is
    bit-identical to one stacked at init."""

    __slots__ = ("ids", "slot", "wg", "wu", "wd")

    def __init__(self, ids: List[int], wg: jnp.ndarray, wu: jnp.ndarray,
                 wd: jnp.ndarray):
        self.ids = list(ids)
        self.slot = {e: s for s, e in enumerate(self.ids)}
        self.wg, self.wu, self.wd = wg, wu, wd

    def __len__(self) -> int:
        return len(self.ids)

    @property
    def cap(self) -> int:
        return int(self.wg.shape[0])

    def weights(self, e: int) -> Tuple[jnp.ndarray, ...]:
        s = self.slot[e]
        return self.wg[s], self.wu[s], self.wd[s]

    def promote(self, e: int, w: Tuple[jnp.ndarray, ...]) -> bool:
        """Append expert ``e`` (weights already device-ready).  Returns
        False when the stack is full and must be rebuilt with more
        capacity."""
        assert e not in self.slot, e
        s = len(self.ids)
        if s >= self.cap:
            return False
        wg, wu, wd = w
        self.wg = self.wg.at[s].set(wg)
        self.wu = self.wu.at[s].set(wu)
        self.wd = self.wd.at[s].set(wd)
        self.ids.append(e)
        self.slot[e] = s
        return True

    def grown(self, cap: int) -> "_FastStack":
        """This stack with capacity ``cap``: existing rows are copied on
        device (no host→device re-upload — growing must not cost link
        transfers the ledger doesn't charge)."""
        assert cap > self.cap, (cap, self.cap)

        def pad(a):
            return jnp.concatenate(
                [a, jnp.zeros((cap - a.shape[0],) + a.shape[1:], a.dtype)])

        return _FastStack(self.ids, pad(self.wg), pad(self.wu),
                          pad(self.wd))

    def demote(self, e: int) -> None:
        """Swap-remove expert ``e`` (the last slot's expert moves into the
        hole; the freed row keeps stale bytes but is unreachable)."""
        s = self.slot.pop(e)
        last = len(self.ids) - 1
        if s != last:
            moved = self.ids[last]
            self.wg = self.wg.at[s].set(self.wg[last])
            self.wu = self.wu.at[s].set(self.wu[last])
            self.wd = self.wd.at[s].set(self.wd[last])
            self.ids[s] = moved
            self.slot[moved] = s
        self.ids.pop()


# ---------------------------------------------------------------------------
# Engine
# ---------------------------------------------------------------------------


class FiddlerEngine:
    def __init__(
        self,
        cfg: ModelConfig,
        params: Optional[Dict[str, Any]] = None,
        *,
        policy: str = "fiddler",
        hw: HardwareSpec = HardwareSpec(),
        profile: Optional[ExpertProfile] = None,
        lat: Optional[LatencyModel] = None,
        expert_budget: Optional[int] = None,
        placement: Optional["Placement"] = None,
        timing_cfg: Optional[ModelConfig] = None,
        seed: int = 0,
        overlap: bool = True,
        host_precision: str = "bf16",
        batched_beams: Optional[bool] = None,
        lru_cache_experts: int = 0,
        adaptive: bool = False,
        quantize_slow: bool = False,
        rebalance_interval: Optional[int] = None,
        rebalance_k: int = 4,
        rebalancer: Optional["Rebalancer"] = None,
        dispatch_mode: str = "grouped",
        async_prefetch: Optional[bool] = None,
        kv_layout: str = "paged",
        kv_block_size: int = PAGE_SIZE,
        kv_global_pool: bool = False,
        prefix_cache: bool = True,
        faults: Optional[FaultInjector] = None,
        watchdog_s: float = 60.0,
        host_retries: int = 3,
        mesh: Optional[Any] = None,
        n_fast_devices: int = 1,
        calibrate_host: bool = False,
    ):
        """``params=None`` → pure-simulation mode (routing drawn from the
        profile; only the ledger advances).  ``timing_cfg`` lets the real
        numerics run a reduced config while latency constants are derived
        from the full-size config (benchmarks do this).

        ``rebalance_interval`` enables dynamic placement rebalancing
        (core/rebalance.py): an ``OnlineProfile`` tracks live routing and
        every ``interval`` serving ticks at most ``rebalance_k`` experts
        are swapped between tiers (the serving layer drives the ticks via
        :meth:`maybe_rebalance`).  A prebuilt ``rebalancer`` overrides
        both knobs.

        ``dispatch_mode``: "grouped" (default) batches each layer's
        fast-tier experts into one capacity-bucketed grouped-GEMM launch
        per tier group (bit-identical on fp32 to "eager", the per-expert
        loop kept for equivalence tests/benchmarks) and overlaps slow
        experts on a host worker pool.  ``async_prefetch`` (default:
        follows ``overlap``) makes rebalancer promotions ride idle link
        time instead of charging ``transfer_lat()`` serially — see
        :class:`PrefetchQueue`.

        ``kv_layout``: "paged" (default) stores serving KV in per-layer
        block pools with refcounted copy-on-write block tables
        (models/paged_kv.py) — slot forks and beam reshuffles are table
        permutations with zero KV data movement, beams share their
        prompt-prefix blocks, and decode KV bytes are charged by
        *unique* blocks.  "dense" keeps the per-slot ring buffers
        (models/kv_cache.py), bit-identical on fp32 and kept for
        equivalence tests — the kv-layout analogue of
        ``dispatch_mode="eager"``.

        ``prefix_cache`` (default on; paged layout only) indexes fully
        written prompt blocks by content hash so later admissions splice
        the longest shared prefix into their block table (refcount bump +
        COW) and prefill only the unmatched tail; retired requests'
        blocks stay resident for reuse and are reclaimed LRU under pool
        pressure.  ``prefix_cache=False`` restores the exact pre-cache
        admission numerics/accounting.

        ``faults`` attaches a :class:`FaultInjector` (docs/resilience.md):
        scripted/seeded host-worker stalls and crashes, link stalls,
        lost/corrupt prefetch transfers, latency spikes and KV-pressure
        spikes, exercised against the engine's defenses — host-future
        watchdogs with bounded retry (``host_retries``) and inline
        fallback, degraded SLOW→stream routing while the host tier is
        unhealthy, prefetch verification behind a link circuit breaker.
        ``watchdog_s`` bounds every host-future await in *wall-clock*
        seconds even with no injector attached (tightened to the
        injector's ``watchdog_s`` when one is); with ``faults=None`` no
        fault ever fires and all numerics/accounting are unchanged.

        ``mesh`` / ``n_fast_devices`` make the fast tier expert-parallel
        over D devices (docs/distributed_serving.md): the per-device
        expert budget multiplies out to D× total residency, placement
        generalises to devices × tiers (:class:`DevicePlacement`),
        migrations target a named device over its own link
        (``PrefetchQueue(n_links=D)``), and the ledger charges the
        dispatch/combine all-to-all.  A ``jax.Mesh`` supplies D from its
        ``model`` axis (and real-mode stacks pin to its devices);
        ``n_fast_devices`` alone drives the pure-simulation path.  D=1 is
        the bit-identity twin: every code path and charge is exactly
        today's single-device engine.

        ``calibrate_host=True`` runs the one-shot CPU-throughput probe
        (core/host_calibration.py) at init: the measured GEMM rate
        replaces the cost model's derived ``cpu_per_token`` and the host
        worker pool is resized to the measured scaling knee."""
        assert policy in POLICIES, policy
        assert dispatch_mode in DISPATCH_MODES, dispatch_mode
        assert kv_layout in KV_LAYOUTS, kv_layout
        assert cfg.moe is not None, "Fiddler orchestrates MoE models"
        self.cfg = cfg
        self.policy = policy
        self.hw = hw
        tcfg = timing_cfg or cfg
        self.tcfg = tcfg
        self.lat = lat or LatencyModel.derive(tcfg, hw)
        self.rng = np.random.default_rng(seed)
        self.overlap = overlap
        self.dispatch_mode = dispatch_mode
        self.kv_layout = kv_layout
        self.kv_block_size = kv_block_size
        # one global block pool with per-layer tables (models/paged_kv
        # GlobalPagedPool) instead of worst-case-sized per-layer pools;
        # requires uniform block geometry across layers
        self.kv_global_pool = bool(kv_global_pool) and kv_layout == "paged"
        self.prefix_cache = bool(prefix_cache) and kv_layout == "paged"
        self.async_prefetch = (overlap if async_prefetch is None
                               else async_prefetch)

        # --- expert-parallel device mesh (distributed/, launch/mesh.py) ------
        self.mesh = mesh
        D = max(1, int(n_fast_devices))
        if mesh is not None and n_fast_devices == 1:
            D = int(dict(zip(mesh.axis_names, mesh.devices.shape))
                    .get("model", 1))
        self.n_fast_devices = D
        self._fast_devices: Optional[List[Any]] = None
        if D > 1:
            devs = (list(mesh.devices.reshape(-1)) if mesh is not None
                    else list(jax.devices()))
            if len(devs) >= D:
                self._fast_devices = devs[:D]
        self._prefetch = PrefetchQueue(n_links=D)

        # --- one-shot host calibration (core/host_calibration.py) ------------
        self.host_calibration = None
        if calibrate_host:
            from repro.core.host_calibration import calibrate_host_pool
            cal = calibrate_host_pool(tcfg)
            self.host_calibration = cal
            self.lat = cal.apply(self.lat, tcfg)
            set_host_pool_workers(cal.workers)

        # --- fault injection + defenses (core/faults.py) ---------------------
        self.faults = faults
        self.host_retries = int(host_retries)
        self.watchdog_s = float(watchdog_s)
        if faults is not None:
            self.watchdog_s = min(self.watchdog_s, faults.watchdog_s)
        self.host_health = HostHealth()
        # cooldown sized in link terms: a few would-be transfers long
        self.link_breaker = CircuitBreaker(
            cooldown_s=8 * self.lat.transfer_lat())
        # set whenever a tick observed a fault / ran degraded; folded
        # into ledger.degraded_steps at the next begin_fault_step
        self._fault_step_dirty = False
        E, L = cfg.moe.n_experts, cfg.n_layers
        self.profile = profile or synthetic_profile(L, E, seed=seed)

        # ``expert_budget`` is per fast device (the HBM of one chip); the
        # engine's total residency is budget × D
        per_device = (expert_budget if expert_budget is not None
                      else fast_tier_expert_budget(tcfg, hw))
        budget = min(per_device * D, L * E)
        self.expert_budget = budget
        self.expert_budget_per_device = per_device
        if D > 1:
            assert policy != "static_split", (
                "static_split is the single-device llama.cpp baseline")
        if placement is not None:
            # explicit placement (tests / replaying a rebalanced state);
            # budget still bounds later rebalancing, so the placement must
            # fit it — Rebalancer plans swap (never shed) residents
            assert placement.on_fast.shape == (L, E), placement.on_fast.shape
            assert placement.n_resident <= budget, (
                f"explicit placement holds {placement.n_resident} experts "
                f"but the fast-tier budget is {budget}")
            assert policy != "static_split", (
                "static_split derives its placement from the budget")
            self.placement = (to_device_placement(placement, D,
                                                  profile=self.profile)
                              if D > 1 else placement)
            self.n_fast_layers = L
        elif policy == "static_split":
            n_fast_layers = min(L, budget // E)
            self.placement = place_static_split(L, E, n_fast_layers)
            self.n_fast_layers = n_fast_layers
        else:
            self.placement = place_by_popularity(self.profile, budget)
            if D > 1:
                self.placement = to_device_placement(
                    self.placement, D, profile=self.profile)
            self.n_fast_layers = L
        self.ledger = Ledger()
        self.host_precision = host_precision
        # llama.cpp-style systems evaluate beams as separate forwards (the
        # paper's §2.2 'fail to account for batching effects'); Fiddler and
        # offloading systems batch the beams into one step.
        self.batched_beams = (policy != "static_split"
                              if batched_beams is None else batched_beams)

        # --- beyond-paper extensions (core/expert_cache.py) ------------------
        from repro.core.expert_cache import AdaptivePlacement, LRUExpertCache

        self.lru = LRUExpertCache(lru_cache_experts)
        self.quantize_slow = quantize_slow
        if quantize_slow:
            # int8 slow tier: half the stream bytes and DRAM reads
            self.lat = dataclasses.replace(
                self.lat, weight_transfer=self.lat.weight_transfer / 2,
                cpu_base=self.lat.cpu_base / 2)
        self.adaptive = (AdaptivePlacement(budget, refresh_every=16 * L)
                         if adaptive else None)

        # --- dynamic rebalancing (core/rebalance.py) -------------------------
        if rebalancer is None and rebalance_interval is not None:
            rebalancer = Rebalancer(
                profile=OnlineProfile(L, E, prior=self.profile),
                budget=budget,
                expert_bytes=expert_weight_bytes(self.tcfg),
                transfer_lat=self.lat.transfer_lat(),
                interval=rebalance_interval, k=rebalance_k)
        if rebalancer is not None:
            assert policy != "static_split", (
                "dynamic rebalancing swaps individual experts; the "
                "static_split baseline places whole layers")
            assert self.adaptive is None, (
                "rebalancer supersedes the AdaptivePlacement extension — "
                "enable one or the other")
        self.rebalancer = rebalancer

        # --- disaggregated-serving overlap window ---------------------------
        # (serving/backend open_overlap_window → prefill charges absorbed)
        self._overlap_budget = 0.0
        self._overlap_armed = False

        # --- real-execution pools -------------------------------------------
        self._lru_pool: Dict[Any, Any] = {}
        self._lru_evict_deferred: List[Tuple[int, int]] = []
        self.model: Optional[Model] = None
        if params is not None:
            self.model = Model(cfg, param_dtype=jnp.float32)
            assert self.model.period == 1 and not self.model.tail, (
                "orchestrator supports uniform-period MoE stacks")
            self._split_params(params)

    # -- initialization (paper Fig. 2a) ---------------------------------------
    def _expert_weights(self, li: int, e: int) -> Tuple[jnp.ndarray, ...]:
        """Expert ``e`` of layer ``li``'s original fp32 weight triple —
        the single source both tiers' representations are built from (so
        migrating an expert can never compound tier rounding)."""
        moe_p = self.layer_params[li]["moe"]
        return (moe_p["w_gate"][e], moe_p["w_up"][e], moe_p["w_down"][e])

    def _make_slow_expert(self, li: int, e: int):
        """The slow-tier representation of one expert (bf16-emulated /
        int8-quantized / fp32 per engine settings)."""
        w = self._expert_weights(li, e)
        if self.quantize_slow:
            from repro.core.expert_cache import QuantizedHostExpert
            return QuantizedHostExpert(*(np.asarray(m) for m in w))
        return HostExpert(*(np.asarray(m) for m in w),
                          precision=self.host_precision)

    def _device_target(self, device: int):
        """The jax device backing fast-tier device ``device``, when the
        process actually has one per modelled device (a mesh / forced
        host-device tests); otherwise None → the default device carries
        every modelled device's weights (accounting still splits them)."""
        if self._fast_devices is None:
            return None
        return self._fast_devices[device % len(self._fast_devices)]

    def _make_stack(self, li: int, ids: List[int],
                    device: int = 0) -> _FastStack:
        """Build layer ``li``'s stacked device pool for experts ``ids``
        (rows derived from the original fp32 params; slots padded to a
        power of two), pinned to fast device ``device`` when the process
        has one per modelled device."""
        cfg = self.cfg
        d, f = cfg.d_model, cfg.d_ff
        cap = _bucket(max(len(ids), 1))
        wg = np.zeros((cap, d, f), np.float32)
        wu = np.zeros((cap, d, f), np.float32)
        wd = np.zeros((cap, f, d), np.float32)
        for s, e in enumerate(ids):
            g, u, dn = self._expert_weights(li, e)
            wg[s], wu[s], wd[s] = np.asarray(g), np.asarray(u), np.asarray(dn)
        tgt = self._device_target(device)
        put = (jax.device_put if tgt is None
               else (lambda a: jax.device_put(a, tgt)))
        return _FastStack(ids, put(wg), put(wu), put(wd))

    @property
    def fast_stack(self) -> List[_FastStack]:
        """Device-0 view of the per-layer stacks (the whole fast tier for
        single-device engines — kept as the historical attribute name)."""
        return [devs[0] for devs in self.fast_stacks]

    def _resident_stack(self, li: int, e: int) -> Optional[_FastStack]:
        """The per-device stack holding resident expert ``e`` of layer
        ``li`` (None if not resident on any fast device)."""
        for st in self.fast_stacks[li]:
            if e in st.slot:
                return st
        return None

    def _fast_weights(self, li: int, e: int) -> Tuple[jnp.ndarray, ...]:
        """Device weights of a fast-tier-executable expert: a row of the
        resident stack, or the LRU pool of previously-streamed experts."""
        st = self._resident_stack(li, e)
        if st is not None:
            return st.weights(e)
        return self._lru_pool[(li, e)]

    def _device_of_expert(self, li: int, e: int) -> int:
        """Fast device assigned to a resident (layer, expert) by the
        placement; device 0 for plain single-device placements."""
        if isinstance(self.placement, DevicePlacement):
            return max(0, int(self.placement.device[li, e]))
        return 0

    def _split_params(self, params) -> None:
        blocks = params["blocks"][0]
        L = self.cfg.n_layers
        self.layer_params = [
            jax.tree.map(lambda a, i=i: a[i], blocks) for i in range(L)]
        self.top_params = {k: v for k, v in params.items() if k != "blocks"}
        D = self.n_fast_devices
        self.fast_stacks: List[List[_FastStack]] = []
        self.slow_pool: List[Dict[int, HostExpert]] = []
        for li in range(L):
            ids: List[List[int]] = [[] for _ in range(D)]
            slow: Dict[int, HostExpert] = {}
            for e in range(self.cfg.moe.n_experts):
                if self.placement.on_fast[li, e]:
                    ids[self._device_of_expert(li, e)].append(e)
                else:
                    slow[e] = self._make_slow_expert(li, e)
            self.fast_stacks.append(
                [self._make_stack(li, ids[dv], device=dv)
                 for dv in range(D)])
            self.slow_pool.append(slow)

    # -- decision per policy ---------------------------------------------------
    def _effective_on_fast(self, li: int) -> np.ndarray:
        on_fast = self.placement.on_fast[li]
        if self.lru.capacity:
            cached = np.array([(li, e) in self.lru
                               for e in range(on_fast.shape[0])])
            on_fast = on_fast | cached
        return on_fast

    def _post_plan(self, li: int, counts: np.ndarray,
                   plan: LayerPlan) -> None:
        """LRU bookkeeping + adaptive placement observation."""
        if self.lru.capacity:
            for e in np.nonzero(counts)[0]:
                d = Decision(plan.decisions[e])
                if d == Decision.FAST_RESIDENT and not self.placement.on_fast[li, e]:
                    self.lru.lookup(li, int(e))  # cache hit
                elif d == Decision.FAST_STREAM:
                    evicted = self.lru.insert(li, int(e))
                    if evicted is None:
                        continue
                    li_e, e_e = evicted
                    if (self.model is not None and li_e == li
                            and Decision(plan.decisions[e_e])
                            == Decision.FAST_RESIDENT
                            and not self.placement.on_fast[li, e_e]):
                        # this very plan still executes the evicted
                        # expert from the LRU pool — dropping its device
                        # weights now would crash the layer; defer the
                        # free until the layer has run
                        self._lru_evict_deferred.append(evicted)
                    else:
                        # free the evicted expert's device weights —
                        # keeping them would grow _lru_pool without bound
                        self._lru_pool.pop(evicted, None)
        if self.adaptive is not None:
            self.adaptive.observe(li, counts.astype(np.float64),
                                  self.cfg.n_layers)
            new, swapped = self.adaptive.maybe_replace(self.placement)
            if swapped:
                self.placement = new
                # swapped-in experts stream during idle time; charge half
                self.ledger.sim_time += 0.5 * swapped * self.lat.transfer_lat()
                self.ledger.stream_bytes += swapped * expert_weight_bytes(self.tcfg)

    def _decide(self, li: int, counts: np.ndarray) -> LayerPlan:
        if self.rebalancer is not None:
            # every routing decision — real (router output) or simulated
            # (profile draw) — feeds the live popularity estimate
            self.rebalancer.observe(li, counts)
        on_fast = self._effective_on_fast(li)
        if self.policy == "fiddler":
            plan = plan_layer(counts, on_fast, self.lat)
            if self.host_health.unhealthy:
                plan = self._reroute_slow(counts, plan)
            self._post_plan(li, counts, plan)
            return plan
        dec = np.full(counts.shape[0], int(Decision.SKIP), np.int64)
        active = counts > 0
        dec[active & on_fast] = int(Decision.FAST_RESIDENT)
        if self.policy == "offload":
            dec[active & ~on_fast] = int(Decision.FAST_STREAM)
        else:  # static_split: missing experts run on the host
            dec[active & ~on_fast] = int(Decision.SLOW)
        fast = dec == int(Decision.FAST_RESIDENT)
        stream = dec == int(Decision.FAST_STREAM)
        slow = dec == int(Decision.SLOW)
        est_fast = float(self.lat.gpu_lat(counts)[fast | stream].sum())
        est_stream = float(stream.sum()) * self.lat.transfer_lat()
        est_slow = float(self.lat.cpu_lat(counts)[slow].sum())
        plan = LayerPlan(dec, est_fast, est_slow, est_stream)
        self._post_plan(li, counts, plan)
        return plan

    def _reroute_slow(self, counts: np.ndarray, plan: LayerPlan) -> LayerPlan:
        """Degraded routing while the host tier is unhealthy (watchdog
        trips — :class:`HostHealth`): SLOW experts re-route through the
        FAST_STREAM path, the eager offload decision, so no new work is
        handed to the sick tier until the cooldown expires.  Estimates
        are rebuilt the way the offload policy builds them, so the
        ledger charges the streamed execution, not the tier we just
        stopped trusting.  Numerics are unchanged — a streamed expert is
        computed from the same slow-pool weights on the fast tier."""
        dec = plan.decisions
        if not (dec == int(Decision.SLOW)).any():
            return plan
        dec = dec.copy()
        dec[dec == int(Decision.SLOW)] = int(Decision.FAST_STREAM)
        fast = dec == int(Decision.FAST_RESIDENT)
        stream = dec == int(Decision.FAST_STREAM)
        est_fast = float(self.lat.gpu_lat(counts)[fast | stream].sum())
        est_stream = float(stream.sum()) * self.lat.transfer_lat()
        self._fault_step_dirty = True
        return LayerPlan(dec, est_fast, 0.0, est_stream)

    def _device_moe_times(self, li: int, plan: LayerPlan,
                          counts: np.ndarray
                          ) -> Tuple[np.ndarray, np.ndarray, float]:
        """Expert-parallel decomposition of one layer's fast-tier work:
        per-device compute seconds, per-device stream-link seconds, and
        the expected number of expert assignments whose tokens cross the
        fabric.  Tokens are data-parallel over the D fast devices while a
        resident expert lives on exactly one of them, so (D-1)/D of each
        fast assignment's tokens arrive through the all-to-all."""
        D = self.n_fast_devices
        gl = self.lat.gpu_lat(counts)
        fast_t = np.zeros(D)
        stream_t = np.zeros(D)
        remote = 0.0
        dev_row = (np.asarray(self.placement.device[li])
                   if isinstance(self.placement, DevicePlacement) else None)
        tl = self.lat.transfer_lat()
        rr = 0  # round-robin for experts without a placed device
        for e in np.nonzero(counts)[0]:
            dec = Decision(plan.decisions[e])
            if dec == Decision.FAST_RESIDENT:
                if dev_row is not None and dev_row[e] >= 0:
                    dv = int(dev_row[e])
                else:  # LRU-cached streamed expert: no home device
                    dv = rr % D
                    rr += 1
            elif dec == Decision.FAST_STREAM:
                dv = rr % D
                rr += 1
                stream_t[dv] += tl
            else:
                continue
            fast_t[dv] += float(gl[e])
            remote += float(counts[e]) * (D - 1) / D
        return fast_t, stream_t, remote

    def _device_nonexpert_time(self, n_tokens: int, kv_len, tier: str,
                               kv_unique: Optional[float]) -> float:
        """Data-parallel non-expert time: each fast device runs attention
        over its contiguous share of the live slots (the backend maps
        slots to devices block-contiguously), and the layer waits for the
        slowest share."""
        D = self.n_fast_devices
        if np.ndim(kv_len):
            kv = np.asarray(kv_len)
            total = float(kv.sum()) or 1.0
            t = 0.0
            for c in np.array_split(kv, D):
                if c.size == 0:
                    continue
                ku = (kv_unique * float(c.sum()) / total
                      if kv_unique is not None else None)
                t = max(t, nonexpert_layer_time(self.tcfg, self.hw, c.size,
                                                c, tier, kv_unique=ku))
            return t
        nt = -(-int(n_tokens) // D)
        ku = kv_unique / D if kv_unique is not None else None
        return nonexpert_layer_time(self.tcfg, self.hw, nt, kv_len, tier,
                                    kv_unique=ku)

    def _charge(self, li: int, plan: LayerPlan, n_tokens: int,
                kv_len: int, kv_unique: Optional[float] = None,
                counts: Optional[np.ndarray] = None) -> None:
        tier = ("fast" if (self.policy != "static_split"
                           or li < self.n_fast_layers) else "slow")
        D = self.n_fast_devices
        a2a = a2a_exposed = 0.0
        fast_t = stream_t = None
        if D > 1 and counts is not None:
            # expert-parallel layer time: every device runs its own
            # residents concurrently; the all-to-all rides the fast-tier
            # critical path, so only the share that sticks out past the
            # concurrent slow-tier work is exposed
            t_nonexp = self._device_nonexpert_time(n_tokens, kv_len, tier,
                                                   kv_unique)
            fast_t, stream_t, remote = self._device_moe_times(
                li, plan, counts)
            t_fast = float(fast_t.max())
            t_stream = float(stream_t.max())
            a2a = alltoall_time(self.tcfg, remote, self.hw, D)
            if self.overlap:
                base = max(t_fast + t_stream, plan.est_slow_time)
                t_moe = max(t_fast + t_stream + a2a, plan.est_slow_time)
            else:
                base = t_fast + t_stream + plan.est_slow_time
                t_moe = base + a2a
            a2a_exposed = t_moe - base
        else:
            t_nonexp = nonexpert_layer_time(self.tcfg, self.hw, n_tokens,
                                            kv_len, tier,
                                            kv_unique=kv_unique)
            t_moe = plan.est_overlapped if self.overlap else plan.est_total
        if len(self._prefetch):
            # an in-flight promotion whose expert executes at this layer
            # must land first: the remainder of its transfer serialises
            used = set(
                int(e) for e in np.nonzero(
                    plan.decisions == int(Decision.FAST_RESIDENT))[0])
            exposed = self._prefetch.force(li, used)
            if exposed:
                self.ledger.sim_time += exposed
                self.ledger.migration_exposed += exposed
        self.ledger.sim_time += t_nonexp + t_moe
        if D > 1 and fast_t is not None:
            led = self.ledger
            led.alltoall_time += a2a
            led.alltoall_exposed += a2a_exposed
            led.alltoall_overlapped += a2a - a2a_exposed
            if not led.device_busy:
                led.device_busy = [0.0] * D
            for dv in range(D):
                led.device_busy[dv] += (
                    t_nonexp + float(fast_t[dv] + stream_t[dv]))
        if len(self._prefetch):
            # the rest of the backlog rides the link while this layer's
            # compute keeps the clock busy (minus FAST_STREAM link use)
            idle = link_idle_time(t_nonexp, t_moe, plan.est_stream_time)
            self.ledger.migration_overlapped += self._prefetch.drain(idle)
        n_stream = int((plan.decisions == int(Decision.FAST_STREAM)).sum())
        if self.faults is not None and (
                n_stream or self._prefetch.completed or len(self._prefetch)):
            # the link was in use this layer: an injected stall blocks it
            ev = self.faults.fires("link_stall")
            if ev is not None:
                self._charge_fault(ev.magnitude * self.faults.link_stall_s)
        self._verify_transfers()
        self.ledger.fast_hits += int((plan.decisions == int(Decision.FAST_RESIDENT)).sum())
        self.ledger.streams += n_stream
        self.ledger.stream_bytes += n_stream * expert_weight_bytes(self.tcfg)
        self.ledger.slow_runs += int((plan.decisions == int(Decision.SLOW)).sum())
        self.ledger.log_layer(
            {"layer": li, "nonexpert": t_nonexp, "moe": t_moe})

    # -- dynamic rebalancing (core/rebalance.py) --------------------------------
    def maybe_rebalance(self) -> Optional[MigrationPlan]:
        """One rebalancer tick — the serving layer calls this between
        decode steps.  When the interval expires and the live profile has
        drifted, applies the bounded migration plan and returns it."""
        if self.rebalancer is None:
            return None
        if not self.link_breaker.allow(self.ledger.sim_time):
            # circuit open: the link is flaky (failed transfer
            # verifications) — pause new migration plans until the
            # cooldown; in-flight prefetches still drain
            return None
        plan = self.rebalancer.tick(self.placement)
        if plan is not None:
            self.apply_migrations(plan)
        return plan

    def apply_migrations(self, plan: MigrationPlan) -> None:
        """Apply a migration plan incrementally: promotions move expert
        weights slow→fast over a ``device_put`` (the FAST_STREAM link,
        paper Fig. 3b) into the layer's stacked pool; demotions drop
        fast-tier residency (freeing HBM costs nothing).  No free
        migrations: every promotion commits ``transfer_lat()`` of link
        time to the ledger — serially into ``sim_time`` in sync mode, or
        as an asynchronous prefetch (``async_prefetch=True``) that rides
        idle link windows and only charges ``sim_time`` for the exposed
        remainder (see ``Ledger.migration_overlapped``/``_exposed``).
        Each tier's representation is rebuilt from the original fp32
        params, so a migrated expert is indistinguishable from one placed
        on that tier at init — placement changes never change numerics
        (bit-identical with ``host_precision="fp32"``; with lossy
        slow-tier storage the usual per-tier rounding applies, never
        compounded by cycles)."""
        if self.model is not None:
            for li, e in plan.demotes:
                st = self._resident_stack(li, e)
                assert st is not None, (li, e)
                st.demote(e)
                self.slow_pool[li][e] = self._make_slow_expert(li, e)
            # the actual slow→fast transfer, batched per target device:
            # ONE device_put of each device's share of the plan's weight
            # pytree — one link transaction per link in use, never one
            # per expert (fewer transactions is also less fault surface
            # for the link circuit breaker to cover).  Single-device
            # plans keep the historical single batched put.
            by_dev: Dict[int, List[Tuple[int, int]]] = {}
            for i, (li, e) in enumerate(plan.promotes):
                by_dev.setdefault(plan.device_of(i), []).append((li, e))
            for dv in sorted(by_dev):
                group = by_dev[dv]
                batch = [self._expert_weights(li, e) for li, e in group]
                tgt = self._device_target(dv)
                moved = (jax.device_put(batch) if tgt is None
                         else jax.device_put(batch, tgt))
                for (li, e), w in zip(group, moved):
                    self.slow_pool[li].pop(e)
                    # the stack grows in place (one row write), doubling
                    # its device capacity first when the padded slots are
                    # exhausted
                    stacks = self.fast_stacks[li]
                    st = stacks[dv % len(stacks)]
                    if not st.promote(e, w):
                        st = st.grown(_bucket(len(st.ids) + 1))
                        stacks[dv % len(stacks)] = st
                        promoted = st.promote(e, w)
                        assert promoted, (li, e)
        self.placement = apply_plan(self.placement, plan)
        n = plan.n_swaps
        cost = n * self.lat.transfer_lat()
        bytes_moved = n * expert_weight_bytes(self.tcfg)
        self.ledger.migrations += n
        self.ledger.migration_time += cost
        self.ledger.migration_bytes += bytes_moved
        if self.async_prefetch:
            # rank in-flight transfers by live routing popularity: the
            # promotion most likely to be routed next rides the link
            # first (PR 4 follow-on — prefetch *ordering*)
            probs = (self.rebalancer.profile.probabilities()
                     if self.rebalancer is not None else None)
            for i, (li, e) in enumerate(plan.promotes):
                w = float(probs[li, e]) if probs is not None else 0.0
                # each promotion rides the host link of its target device
                self._prefetch.push(li, e, self.lat.transfer_lat(),
                                    weight=w, link=plan.device_of(i))
        else:
            self.ledger.sim_time += cost
            self.ledger.migration_exposed += cost

    def flush_prefetch(self) -> float:
        """Force-complete every in-flight promotion transfer, charging
        the remainder to ``sim_time`` as exposed migration seconds.  The
        serving layer calls this when a run ends so phase accounting adds
        up (overlapped + exposed == migration_time).  Returns the seconds
        charged."""
        if not len(self._prefetch):
            self._prefetch.pop_completed()
            return 0.0
        t = self._prefetch.flush()
        self.ledger.sim_time += t
        self.ledger.migration_exposed += t
        # settlement, not verification: requeueing a failed transfer at
        # shutdown would never converge — flushed transfers are final
        self._prefetch.pop_completed()
        return t

    def _verify_transfers(self) -> None:
        """Post-transfer verification of completed prefetches: a lost or
        corrupt transfer (injected — see core/faults.py) is requeued at
        full length, its link-seconds and bytes recommitted to the
        migration ledger so the overlapped/exposed split still closes,
        and the failure feeds the link circuit breaker.  In real-numerics
        mode the weights already landed (``apply_migrations`` put them),
        so this is a control-plane/accounting defense — numerics stay
        bit-identical."""
        done = self._prefetch.pop_completed()
        if not done:
            return
        now = self.ledger.sim_time
        for p in done:
            ev = None
            if self.faults is not None:
                ev = (self.faults.fires("prefetch_lost")
                      or self.faults.fires("prefetch_corrupt"))
            if ev is None:
                self.link_breaker.record_success()
                continue
            self.ledger.retries += 1
            self._fault_step_dirty = True
            self.link_breaker.record_failure(now)
            # the full transfer goes back on the link
            self.ledger.migration_time += p.total
            self.ledger.migration_bytes += expert_weight_bytes(self.tcfg)
            self._prefetch.push(p.layer, p.expert, p.total, weight=p.weight)

    # -- fault injection + defenses (core/faults.py) ----------------------------
    def begin_fault_step(self, step: Optional[int] = None) -> None:
        """Per-scheduler-tick fault bookkeeping: settle the previous
        tick's degraded flag into ``ledger.degraded_steps``, age the
        host-tier health cooldown, and advance the injector's schedule
        (arming this tick's faults, releasing expired KV-pressure
        holds).  The serving backends call this from ``begin_step``."""
        if self._fault_step_dirty:
            self.ledger.degraded_steps += 1
            self._fault_step_dirty = False
        self.host_health.tick()
        if self.faults is not None:
            self.faults.begin_step(step)

    def release_fault_holds(self) -> None:
        """Finalize hook: return injector-reserved KV blocks and settle
        the last tick's degraded flag — a finished run pins nothing."""
        if self.faults is not None:
            self.faults.release_all()
        if self._fault_step_dirty:
            self.ledger.degraded_steps += 1
            self._fault_step_dirty = False

    def note_recovery(self) -> None:
        """The serving layer recovered a slot from a mid-step failure
        (evict→requeue→re-prefill) — charge the retry ledger."""
        self.ledger.retries += 1
        self._fault_step_dirty = True

    def _charge_fault(self, seconds: float) -> None:
        """Serial fault/recovery penalty: extends ``sim_time`` and is
        always *exposed* — a stall IS the critical path, it never hides
        under planned overlap — and marks the tick degraded."""
        if seconds > 0:
            led = self.ledger
            led.sim_time += seconds
            led.fault_time += seconds
            led.fault_exposed += seconds
        self._fault_step_dirty = True

    def _fault_spike(self) -> None:
        """Consume an armed per-step latency spike (background load,
        SMI, page-fault storm — unattributed wall time)."""
        if self.faults is None:
            return
        ev = self.faults.fires("latency_spike")
        if ev is not None:
            self._charge_fault(ev.magnitude * self.faults.latency_spike_s)

    def _fault_host_sim(self) -> None:
        """Pure-simulation host-tier faults: no real futures exist, so a
        stall/crash charges the watchdog+backoff penalty directly and a
        crash feeds the health tracker — repeated crashes flip the tier
        unhealthy and ``_decide`` re-routes SLOW work through the stream
        path (the same degraded mode the real watchdog triggers)."""
        f = self.faults
        if f is None or self.model is not None:
            return
        ev = f.fires("host_crash") or f.fires("host_stall")
        if ev is None:
            return
        self.ledger.retries += 1
        self._charge_fault(ev.magnitude * f.host_stall_s)
        if ev.kind == "host_crash":
            self.host_health.record_failure()

    # -- simulated routing ------------------------------------------------------
    def _sample_counts(self, li: int, n_tokens: int) -> np.ndarray:
        p = self.profile.probabilities()[li]
        E, k = self.cfg.moe.n_experts, self.cfg.moe.top_k
        # Gumbel top-k per token — without-replacement draws from popularity
        g = self.rng.gumbel(size=(n_tokens, E)) + np.log(np.maximum(p, 1e-12))
        idx = np.argpartition(-g, k - 1, axis=1)[:, :k]
        return np.bincount(idx.reshape(-1), minlength=E).astype(np.int64)

    # -- MoE layer execution (real numerics) -------------------------------------
    def _stream_weights(self, li: int, e: int) -> Tuple[jnp.ndarray, ...]:
        """The actual slow→fast weight transfer of a FAST_STREAM decision
        (paper Fig. 3b), with LRU retention when the cache is enabled."""
        he = self.slow_pool[li][e]
        if hasattr(he, "weights"):  # quantized: dequant on stream
            wg, wu, wd = map(jnp.asarray, he.weights())
        else:
            wg = jnp.asarray(he.w_gate)
            wu = jnp.asarray(he.w_up)
            wd = jnp.asarray(he.w_down)
        # retain on-device only while the LRU still tracks the key: a
        # burst of streams in one layer can insert-and-evict at decide
        # time before execution gets here, and writing unconditionally
        # would regrow the pool past capacity (the old leak)
        if self.lru.capacity and (li, int(e)) in self.lru:
            self._lru_pool[(li, int(e))] = (wg, wu, wd)
        return wg, wu, wd

    def _run_moe_layer(self, li: int, x_flat: jnp.ndarray,
                       row_mask: Optional[np.ndarray] = None
                       ) -> Tuple[jnp.ndarray, np.ndarray, LayerPlan]:
        """Route + execute one MoE layer.  ``row_mask`` (T,) bool marks the
        rows that are real in-flight tokens (continuous batching pads idle
        slots): masked-out rows are excluded from the expert counts the
        planner sees, from execution, and from the ledger."""
        cfg = self.cfg
        m = cfg.moe
        moe_p = self.layer_params[li]["moe"]
        gates, idx, _ = route(moe_p["router"], x_flat, m)
        # fiddlint: ignore[FID001] the routing sync IS the Fiddler design:
        # expert ids must land on host so the planner can split tiers; it
        # is the one sequencing point per layer (paper §3.1)
        idx_np = np.asarray(idx)
        gates_np = np.asarray(gates, np.float32)  # fiddlint: ignore[FID001] same routing sync; gates ride along with idx
        live = None if row_mask is None else np.asarray(row_mask, bool)
        counted = idx_np if live is None else idx_np[live]
        counts = np.bincount(counted.reshape(-1), minlength=m.n_experts)
        plan = self._decide(li, counts)

        # fiddlint: ignore[FID001] slow-tier experts consume host
        # activations by definition (Fig. 3c); the copy is charged to the
        # ledger as activation transfer, not hidden
        x_np = np.asarray(x_flat, np.float32)
        execute = (self._execute_eager if self.dispatch_mode == "eager"
                   else self._execute_grouped)
        out = execute(li, plan, counts, x_np, idx_np, gates_np, live)

        y = jnp.asarray(out, x_flat.dtype)
        if m.n_shared_experts:
            sp = moe_p["shared"]
            from repro.models.moe import _shared_expert
            y = y + _shared_expert(sp, x_flat, cfg.act)
        return y, counts, plan

    def _execute_eager(self, li: int, plan: LayerPlan, counts: np.ndarray,
                       x_np: np.ndarray, idx_np: np.ndarray,
                       gates_np: np.ndarray,
                       live: Optional[np.ndarray]) -> np.ndarray:
        """The paper-style per-expert loop: one fast-tier kernel dispatch
        (and one host↔device round-trip) per activated expert."""
        out = np.zeros_like(x_np)
        for e in np.nonzero(counts)[0]:
            hit = idx_np == e
            if live is not None:
                hit = hit & live[:, None]
            rows, kpos = np.nonzero(hit)
            xe = x_np[rows]
            d = Decision(plan.decisions[e])
            if d == Decision.FAST_RESIDENT:
                wg, wu, wd = self._fast_weights(li, int(e))
                ye = np.asarray(expert_mlp_op(jnp.asarray(xe), wg, wu, wd))
                self.ledger.fast_dispatches += 1
            elif d == Decision.FAST_STREAM:
                wg, wu, wd = self._stream_weights(li, int(e))
                ye = np.asarray(expert_mlp_op(jnp.asarray(xe), wg, wu, wd))
                self.ledger.fast_dispatches += 1
            else:  # SLOW: activations → host, numpy kernel (paper Fig. 3c)
                ye = self.slow_pool[li][e](xe)
            out[rows] += gates_np[rows, kpos, None] * ye
        self._drain_deferred_evictions()
        return out

    def _drain_deferred_evictions(self) -> None:
        """Free device weights of LRU evictions the just-executed plan
        still needed (see ``_post_plan``)."""
        while self._lru_evict_deferred:
            self._lru_pool.pop(self._lru_evict_deferred.pop(), None)

    def _execute_grouped(self, li: int, plan: LayerPlan, counts: np.ndarray,
                         x_np: np.ndarray, idx_np: np.ndarray,
                         gates_np: np.ndarray,
                         live: Optional[np.ndarray]) -> np.ndarray:
        """Batched grouped dispatch: the layer's resident experts' rows
        are gathered into ONE capacity-bucketed dispatch buffer (group
        and capacity padded to powers of two, so the jit cache holds a
        handful of shapes) and executed by a single grouped gated-MLP
        launch over the stacked pool; streamed/LRU-cached weights get one
        more stacked launch.  SLOW experts run on the shared host pool
        concurrently with the fast-tier calls (``overlap=True``) — real
        CPU/GPU overlap, not just the ledger's estimate.  The grouped
        kernel evaluates each expert at its exact routed row count
        (kernels/ref.py) and combining is ordered by expert id, which
        together make every mode/overlap setting bit-identical to the
        eager loop on fp32."""
        T, d = x_np.shape
        k = idx_np.shape[1]
        flat_e = idx_np.reshape(-1)
        if live is None:
            sel = np.arange(flat_e.size)
        else:
            sel = np.nonzero(np.repeat(live, k))[0]
        # assignments grouped by expert, ascending; stable keeps each
        # expert's rows in row-major order — exactly np.nonzero's order
        # in the eager loop, so accumulation order (and bits) match
        order = sel[np.argsort(flat_e[sel], kind="stable")]
        sorted_e = flat_e[order]
        uniq, starts = np.unique(sorted_e, return_index=True)
        bounds = np.append(starts, order.size)
        segs = {}
        for gi, e in enumerate(uniq):
            span = order[bounds[gi]: bounds[gi + 1]]
            segs[int(e)] = (span // k, span % k)

        sts = self.fast_stacks[li]
        resident: List[List[int]] = [[] for _ in sts]
        extra, slow = [], []
        extra_w: Dict[int, Tuple[jnp.ndarray, ...]] = {}
        for e in uniq:
            e = int(e)
            dec = Decision(plan.decisions[e])
            if dec == Decision.FAST_RESIDENT:
                for di, st in enumerate(sts):
                    if e in st.slot:
                        resident[di].append(e)
                        break
                else:  # LRU-cached previously-streamed expert
                    extra.append(e)
                    extra_w[e] = self._lru_pool[(li, e)]
            elif dec == Decision.FAST_STREAM:
                extra.append(e)
                extra_w[e] = self._stream_weights(li, e)
            elif dec == Decision.SLOW:
                slow.append(e)

        ye: Dict[int, np.ndarray] = {}
        # slow tier first: submit to the host pool so the numpy kernels
        # run while the fast-tier grouped calls execute
        futures = []
        if slow and self.overlap:
            pool = _host_pool()
            hostile = (self.faults.fires("host_crash")
                       or self.faults.fires("host_stall")
                       if self.faults is not None else None)
            for e in slow:
                fn = self.slow_pool[li][e]
                xe = x_np[segs[e][0]]
                submitted = fn
                if hostile is not None:
                    # the layer's first slow expert takes the armed fault
                    submitted = _faulty_worker(fn, hostile,
                                               self.faults.real_stall_s)
                    hostile = None
                futures.append((e, pool.submit(submitted, xe), fn, xe))

        def _launch(group, fn, uniform):
            # uniform: every expert in the group has the same row count —
            # C is exact and the kernel compiles a single branch (no
            # switch); otherwise C buckets to a power of two ≤ SWITCH_CAP
            cp = (segs[group[0]][0].size if uniform
                  else _bucket(max(segs[e][0].size for e in group)))
            gp = _bucket(len(group))
            xs = np.zeros((gp, cp, d), np.float32)
            cnt = None if uniform else np.zeros(gp, np.int32)
            for gi, e in enumerate(group):
                rows = segs[e][0]
                xs[gi, : rows.size] = x_np[rows]
                if cnt is not None:
                    cnt[gi] = rows.size
            ys = np.asarray(fn(jnp.asarray(xs),
                               None if cnt is None else jnp.asarray(cnt),
                               group, gp))
            self.ledger.fast_dispatches += 1
            for gi, e in enumerate(group):
                ye[e] = ys[gi, : segs[e][0].size]

        def _dispatch(group, fn):
            small, large = [], {}
            for e in group:
                n = segs[e][0].size
                if n <= SWITCH_CAP:
                    small.append(e)
                else:
                    large.setdefault(n, []).append(e)
            if small:
                _launch(small, fn, uniform=False)
            for n in sorted(large):
                _launch(large[n], fn, uniform=True)

        def _gather_for(st):
            # one grouped launch per device stack: each modelled fast
            # device runs exactly its own resident experts (expert
            # parallelism); D=1 reduces to the historical single launch
            def _gather_fn(xs, cnt, group, gp):
                slots = np.array([st.slot[e] for e in group]
                                 + [0] * (gp - len(group)), np.int32)
                return grouped_gather_mlp_op(xs, jnp.asarray(slots),
                                             st.wg, st.wu, st.wd, cnt)
            return _gather_fn

        def _stacked_fn(xs, cnt, group, gp):
            trips = [extra_w[e] for e in group]
            trips += [trips[-1]] * (gp - len(group))
            return grouped_gated_mlp_op(
                xs, jnp.stack([t[0] for t in trips]),
                jnp.stack([t[1] for t in trips]),
                jnp.stack([t[2] for t in trips]), cnt)

        for st, group in zip(sts, resident):
            _dispatch(group, _gather_for(st))
        _dispatch(extra, _stacked_fn)
        if slow and not self.overlap:
            for e in slow:
                ye[e] = self.slow_pool[li][e](x_np[segs[e][0]])
        for e, fut, fn, xe in futures:
            ye[e] = self._await_host(fut, fn, xe)

        out = np.zeros_like(x_np)
        for e in uniq:  # ascending expert id == the eager loop's order
            e = int(e)
            rows, kpos = segs[e]
            out[rows] += gates_np[rows, kpos, None] * ye[e]
        self._drain_deferred_evictions()
        return out

    def _await_host(self, fut, fn, x: np.ndarray) -> np.ndarray:
        """Watchdog-guarded await of one slow-tier expert future: bounded
        retry with exponential backoff — each watchdog expiry or worker
        crash resubmits the clean kernel with a doubled timeout and
        charges the backoff penalty as exposed fault time — then a final
        inline fallback on the scheduler thread.  Retry and fallback run
        the *same* ``HostExpert`` on the same rows, so recovery never
        changes numerics (fp32 bit-identity holds through any fault)."""
        timeout = self.watchdog_s
        backoff = (self.faults.host_stall_s if self.faults is not None
                   else 0.0)
        for attempt in range(self.host_retries):
            try:
                return fut.result(timeout=timeout)
            except HostWorkerFault:
                self.host_health.record_failure()
            except FuturesTimeout:
                pass
            self.ledger.retries += 1
            self._charge_fault(backoff * (2 ** attempt))
            timeout *= 2
            fut = _host_pool().submit(fn, x)
        try:
            return fut.result(timeout=timeout)
        except (HostWorkerFault, FuturesTimeout):
            # host tier unresponsive after bounded retries: degrade to
            # running the kernel inline on the scheduler thread
            self.host_health.record_failure()
            self.ledger.retries += 1
            self._charge_fault(backoff * (2 ** self.host_retries))
            return fn(x)

    # -- full forward passes (real numerics) -------------------------------------
    def prefill(self, tokens: jnp.ndarray, max_seq: int):
        """Real-numerics prefill through the orchestrator."""
        assert self.model is not None
        model, cfg = self.model, self.cfg
        x = model.embed({"embed": self.top_params["embed"]}, tokens)
        B, S, _ = x.shape
        positions = jnp.broadcast_to(jnp.arange(S)[None], (B, S))
        caches = []
        t_start = self.ledger.sim_time
        for li in range(cfg.n_layers):
            cache = self._init_layer_cache(li, B, max_seq)
            x, cache = self._run_layer(li, x, positions, "prefill", cache,
                                       max_seq, kv_len=S)
            caches.append(cache)
        logits = self._logits(x[:, -1:])
        self.ledger.ttft = self.ledger.sim_time - t_start
        return logits[:, 0], caches

    def decode_step(self, caches, tokens: jnp.ndarray, pos: int, max_seq: int):
        assert self.model is not None
        model, cfg = self.model, self.cfg
        x = model.embed({"embed": self.top_params["embed"]}, tokens)
        B = x.shape[0]
        positions = jnp.full((B, 1), pos, jnp.int32)
        # per-row KV lengths: every batch row reads its own cache (same
        # accounting as the continuous multi-slot path)
        kv_lens = np.full(B, pos + 1, np.int64)
        for li in range(cfg.n_layers):
            x, caches[li] = self._run_layer(li, x, positions, "decode",
                                            caches[li], max_seq,
                                            kv_len=kv_lens)
        logits = self._logits(x)
        self.ledger.tokens_out += 1
        return logits[:, 0], caches

    # -- slot-based serving path (continuous batching) ---------------------------
    def make_decode_caches(self, n_slots: int, max_seq: int) -> List[Any]:
        """Per-layer multi-slot KV caches for continuous batching.  With
        ``kv_global_pool`` (and uniform block geometry) every layer's
        table draws from ONE shared block pool + device store, so KV
        capacity is a fungible model-wide budget instead of worst-case
        per layer."""
        if (self.kv_global_pool
                and GlobalPagedPool.shareable(self.cfg, max_seq,
                                              self.kv_block_size)):
            shared = GlobalPagedPool.for_model(
                self.cfg, n_slots, max_seq, jnp.float32, self.kv_block_size)
            caches: List[Any] = [
                PagedLayerCache(self.cfg, li, n_slots, max_seq, jnp.float32,
                                block_size=self.kv_block_size, shared=shared)
                for li in range(self.cfg.n_layers)]
        else:
            caches = [self._init_layer_cache(li, n_slots, max_seq)
                      for li in range(self.cfg.n_layers)]
        if self.prefix_cache:
            for c in caches:
                c.meta.enable_prefix_cache()
        return caches

    def make_slot_stage(self, caches: List[Any],
                        slot: int) -> List[PagedSlotStage]:
        """Per-layer batch-1 staging views that chunk-prefill straight
        into row ``slot`` of the multi-slot pools: the continuous-batching
        join becomes a pure table splice (``write_slot`` no-op) instead
        of a block-by-block device copy, and a prefix-matched admission's
        tail chunks attend to the shared blocks already in the row."""
        assert all(isinstance(c, PagedLayerCache) for c in caches)
        return [PagedSlotStage(c, slot) for c in caches]

    def write_slot(self, caches: List[Any], slot_caches: List[Any],
                   slot: int) -> List[Any]:
        """Join a freshly-prefilled staging cache into row ``slot`` of the
        multi-slot caches (request joins the in-flight batch).  Stages
        from :meth:`make_slot_stage` already wrote through the target
        pool, so their join moves zero device bytes; private batch-1
        caches (whole-prompt prefill, dense layout) are copied in."""
        for li in range(self.cfg.n_layers):
            sc = slot_caches[li]
            if isinstance(sc, PagedSlotStage):
                assert sc.parent is caches[li] and sc.slot == slot, (
                    "stage does not belong to this cache row")
                continue  # table already spliced in place
            if isinstance(caches[li], PagedLayerCache):
                caches[li].copy_in(slot, sc)
            else:
                caches[li] = jax.tree.map(
                    lambda b, s: b.at[slot].set(s[0].astype(b.dtype)),
                    caches[li], sc)
        return caches

    def kv_match_prefix(self, caches: List[Any], slot: int,
                        tokens: List[int]) -> int:
        """Admission-time prefix-cache probe: the longest verified prefix
        of ``tokens`` resident in *every* layer's index is spliced into
        row ``slot`` (refcount bumps, zero data movement).  Returns the
        number of prompt tokens covered — the caller prefills only the
        tail.  At least one tail token is always left so the join still
        produces first-token logits."""
        if (not self.prefix_cache or not caches
                or not isinstance(caches[0], PagedLayerCache)):
            return 0
        tokens = [int(t) for t in tokens]
        self.ledger.prefix_lookups += 1
        cands = [c.meta.match_prefix(tokens) for c in caches]
        bs = caches[0].meta.block_size
        n = min(min(len(x) for x in cands), (len(tokens) - 1) // bs)
        if n <= 0:
            return 0
        for c, cand in zip(caches, cands):
            c.meta.map_prefix(slot, cand[:n])
        self.ledger.prefix_hits += 1
        self.ledger.prefix_tokens += n * bs
        return n * bs

    def kv_register_prefix(self, caches: List[Any], slot: int,
                           tokens: List[int]) -> None:
        """Publish row ``slot``'s fully-written prompt blocks into every
        layer's prefix index (post-join), making them matchable by later
        admissions."""
        if (not self.prefix_cache or not caches
                or not isinstance(caches[0], PagedLayerCache)):
            return
        tokens = [int(t) for t in tokens]
        for c in caches:
            c.meta.register_prefix(slot, tokens)

    def fork_slot(self, caches: List[Any], src: int, dst: int) -> List[Any]:
        """Slot ``dst`` becomes a fork of ``src`` (beam-group member
        creation).  Paged: a block-table copy with refcount bumps — the
        beams *share* the prompt-prefix blocks until a divergent write
        triggers copy-on-write.  Dense: a full KV row copy."""
        for li in range(self.cfg.n_layers):
            if isinstance(caches[li], PagedLayerCache):
                caches[li].fork_slot(src, dst)
            else:
                caches[li] = jax.tree.map(
                    lambda a: a.at[dst].set(a[src]), caches[li])
        return caches

    def reorder_slots(self, caches: List[Any], slots: List[int],
                      src_of: List[int]) -> List[Any]:
        """Beam reshuffle over a subset of slots: ``slots[i]`` continues
        the sequence currently held by ``src_of[i]``.  Paged: a pure
        block-table permutation + refcount bumps — **zero KV data
        movement** (the pool arrays are untouched).  Dense: a gather/
        scatter row copy."""
        for li in range(self.cfg.n_layers):
            if isinstance(caches[li], PagedLayerCache):
                caches[li].reorder_slots(slots, src_of)
            else:
                di = jnp.asarray(slots)
                si = jnp.asarray(src_of)
                caches[li] = jax.tree.map(
                    lambda a: a.at[di].set(a[si]), caches[li])
        return caches

    def reorder_cache(self, caches: List[Any], idx) -> List[Any]:
        """Whole-batch beam reshuffle (row ``i`` continues ``idx[i]``) —
        table-only under the paged layout."""
        idx = [int(i) for i in np.asarray(idx)]
        return self.reorder_slots(caches, list(range(len(idx))), idx)

    def release_slot(self, caches: List[Any], slot: int) -> List[Any]:
        """Return a retired slot's KV blocks to the pool (paged; dense
        rows are simply overwritten by the next occupant)."""
        for li in range(self.cfg.n_layers):
            if isinstance(caches[li], PagedLayerCache):
                caches[li].release_slot(slot)
        return caches

    def resize_decode_caches(self, caches: List[Any],
                             n_slots: int) -> List[Any]:
        """Grow/shrink the paged slot tables (slot autoscaling); the
        serving layer's dense resize goes through the backend's
        make-and-copy path instead."""
        for li in range(self.cfg.n_layers):
            assert isinstance(caches[li], PagedLayerCache), (
                "resize_decode_caches is the paged path")
            caches[li].resize(n_slots)
        return caches

    def kv_block_stats(self, caches: List[Any],
                       slots: Optional[List[int]] = None
                       ) -> Optional[Dict[str, int]]:
        """Unique-vs-dense block accounting of the first layer's pool
        (all layers share one table structure) — what the beam benchmark
        reports.  None under the dense layout."""
        if not caches or not isinstance(caches[0], PagedLayerCache):
            return None
        m = caches[0].meta
        return {
            "unique_blocks": m.blocks_in_use(slots),
            "dense_blocks": m.dense_blocks(slots),
            "unique_tokens": m.unique_tokens(slots),
            "dense_tokens": m.dense_tokens(slots),
            "cached_blocks": m.n_cached,
        }

    def prefill_chunk(self, tokens: jnp.ndarray, caches: Optional[List[Any]],
                      pos_offset: int, max_seq: int
                      ) -> Tuple[jnp.ndarray, List[Any]]:
        """One chunk of a chunked prefill: tokens (B, C) are processed at
        positions ``pos_offset .. +C-1`` against ``caches`` (``None`` on
        the first chunk).  Splitting a long admission into chunks lets the
        serving loop interleave in-flight decode steps between chunks
        instead of stalling them behind one monolithic prefill."""
        assert self.model is not None
        model, cfg = self.model, self.cfg
        B, C = tokens.shape
        self._fault_spike()  # charged outside the absorbable window
        t0 = self.ledger.sim_time
        if caches is None:
            caches = [self._init_layer_cache(li, B, max_seq)
                      for li in range(cfg.n_layers)]
        x = model.embed({"embed": self.top_params["embed"]}, tokens)
        positions = jnp.broadcast_to(
            (pos_offset + jnp.arange(C, dtype=jnp.int32))[None], (B, C))
        for li in range(cfg.n_layers):
            x, caches[li] = self._run_layer(li, x, positions, "prefill_chunk",
                                            caches[li], max_seq,
                                            kv_len=pos_offset + C)
        logits = self._logits(x[:, -1:])
        self._absorb_prefill(self.ledger.sim_time - t0)
        return logits[:, 0], caches

    def decode_step_multi(self, caches: List[Any], tokens: jnp.ndarray,
                          pos: np.ndarray, max_seq: int,
                          active: Optional[np.ndarray] = None
                          ) -> Tuple[jnp.ndarray, List[Any]]:
        """Continuous-batching decode through the orchestrator: every slot
        decodes at its own position.  tokens (n_slots, 1); pos (n_slots,).
        ``active`` masks live slots — idle rows flow through the numerics
        as padding but are excluded from the expert counts fed to the
        planner, from expert execution, and from the ledger, so the
        simulated clock charges exactly the mixed in-flight batch."""
        assert self.model is not None
        cfg = self.cfg
        pos = np.asarray(pos, np.int32)
        if active is None:
            active = np.ones(pos.shape[0], bool)
        active = np.asarray(active, bool)
        assert active.any(), "decode_step_multi needs at least one live slot"
        self._fault_spike()
        x = self.model.embed({"embed": self.top_params["embed"]}, tokens)
        positions = jnp.asarray(pos)[:, None]
        kv_lens = pos[active].astype(np.int64) + 1
        for li in range(cfg.n_layers):
            x, caches[li] = self._run_layer(li, x, positions, "decode_multi",
                                            caches[li], max_seq,
                                            kv_len=kv_lens, row_mask=active)
        logits = self._logits(x)
        self.ledger.tokens_out += int(active.sum())
        return logits[:, 0], caches

    def _init_layer_cache(self, li, B, max_seq):
        from repro.models import kv_cache as kvc
        if self.kv_layout == "paged":
            return PagedLayerCache(self.cfg, li, B, max_seq, jnp.float32,
                                   block_size=self.kv_block_size)
        return kvc.init_attn_cache(self.cfg, li, B, max_seq, jnp.float32)

    def _run_layer(self, li, x, positions, mode, cache, max_seq, kv_len,
                   row_mask: Optional[np.ndarray] = None):
        from repro.models.attention import attention_block
        from repro.models.layers import rmsnorm
        cfg = self.cfg
        p = self.layer_params[li]
        h, cache = attention_block(
            p["attn"], rmsnorm(p["norm1"], x, cfg.norm_eps), positions, cfg,
            li, mode=mode, cache=cache, max_seq=max_seq, active=row_mask)
        x = x + h
        B, S, d = x.shape
        normed = rmsnorm(p["norm2"], x, cfg.norm_eps).reshape(-1, d)
        moe_out, counts, plan = self._run_moe_layer(li, normed,
                                                    row_mask=row_mask)
        n_real = B * S if row_mask is None else int(np.sum(row_mask))
        kv_unique = None
        if (isinstance(cache, PagedLayerCache)
                and mode in ("decode", "decode_multi")):
            # paged decode reads each distinct block once — a beam
            # group's shared prefix is charged a single memory pass
            live = (None if row_mask is None
                    else np.nonzero(np.asarray(row_mask, bool))[0])
            kv_unique = cache.meta.unique_tokens(live)
        self._charge(li, plan, n_tokens=n_real, kv_len=kv_len,
                     kv_unique=kv_unique, counts=counts)
        x = x + moe_out.reshape(B, S, d)
        return x, cache

    def _logits(self, x):
        from repro.models.layers import rmsnorm, softcap
        p = self.top_params
        h = rmsnorm(p["final_norm"], x, self.cfg.norm_eps)
        w = p["embed"].T if self.cfg.tie_embeddings else p["lm_head"]
        return softcap((h @ w).astype(jnp.float32), self.cfg.logit_softcap)

    # -- pure simulation (full-size configs, no weights) -------------------------
    def simulate_prefill(self, n_tokens: int) -> float:
        t0 = self.ledger.sim_time
        for li in range(self.cfg.n_layers):
            counts = self._sample_counts(li, n_tokens)
            plan = self._decide(li, counts)
            self._charge(li, plan, n_tokens=n_tokens, kv_len=n_tokens,
                         counts=counts)
        self.ledger.ttft = self.ledger.sim_time - t0
        return self.ledger.ttft

    def simulate_decode(self, n_steps: int, batch: int = 1,
                        kv_start: int = 0) -> float:
        t0 = self.ledger.sim_time
        # unbatched-beam systems run `batch` single-token forwards per step
        passes = 1 if self.batched_beams else batch
        per_pass = batch if self.batched_beams else 1
        for step in range(n_steps):
            for _ in range(passes):
                kv_lens = np.full(per_pass, kv_start + step + 1, np.int64)
                for li in range(self.cfg.n_layers):
                    counts = self._sample_counts(li, per_pass)
                    plan = self._decide(li, counts)
                    self._charge(li, plan, n_tokens=per_pass,
                                 kv_len=kv_lens, counts=counts)
            self.ledger.tokens_out += 1
        return self.ledger.sim_time - t0

    # -- disaggregated-serving stream overlap ---------------------------------
    def open_overlap_window(self, seconds: float) -> None:
        """Arm the prefill-under-decode window: the decode gang (the
        foreground stream) just ran for ``seconds`` of sim clock, and the
        next prefill charges may hide under it.  Decode stream time is
        always exposed — it is what the clock advanced by."""
        assert seconds >= 0.0, seconds
        led = self.ledger
        led.decode_stream_time += seconds
        led.decode_stream_exposed += seconds
        self._overlap_budget += seconds
        self._overlap_armed = True

    def close_overlap_window(self) -> None:
        """Unused decode budget lapses (it was idle GPU, not a credit)."""
        self._overlap_budget = 0.0
        self._overlap_armed = False

    def _absorb_prefill(self, dt: float) -> None:
        """Split a prefill charge of ``dt`` sim-seconds into hidden
        (absorbed into the armed decode window — refunded from sim_time)
        vs exposed.  Called inside the prefill-chunk boundary so
        downstream timestamps (token_times, TTFT) stay monotone: the
        refund happens before anyone reads the clock."""
        if not self._overlap_armed:
            return
        led = self.ledger
        hidden = min(self._overlap_budget, dt)
        self._overlap_budget -= hidden
        led.sim_time -= hidden
        led.prefill_stream_time += dt
        led.prefill_stream_overlapped += hidden
        led.prefill_stream_exposed += dt - hidden

    def simulate_prefill_chunk(self, n_tokens: int, kv_len: int) -> float:
        """Charge one prefill chunk (``n_tokens`` tokens attending to
        ``kv_len`` KV entries) without touching ``ledger.ttft`` — the
        serving layer's simulated chunked-admission path."""
        self._fault_spike()  # charged outside the absorbable window
        self._fault_host_sim()
        t0 = self.ledger.sim_time
        for li in range(self.cfg.n_layers):
            counts = self._sample_counts(li, n_tokens)
            plan = self._decide(li, counts)
            self._charge(li, plan, n_tokens=n_tokens, kv_len=kv_len,
                         counts=counts)
        self._absorb_prefill(self.ledger.sim_time - t0)
        return self.ledger.sim_time - t0

    def simulate_decode_multi(self, kv_lens: np.ndarray,
                              kv_unique: Optional[float] = None) -> float:
        """Charge one continuous-batching decode step: one token per live
        slot, each reading its own KV length.  Mirrors
        ``decode_step_multi``'s accounting without weights — the
        ``SimulatedBackend`` serving path.  ``kv_unique`` (paged-layout
        accounting, see cost_model.kv_read_entries) dedups the KV bytes
        read to the distinct block entries — how simulated beam groups
        charge their shared prompt prefix once."""
        kv_lens = np.asarray(kv_lens, np.int64)
        n = int(kv_lens.shape[0])
        assert n >= 1, "simulate_decode_multi needs at least one live slot"
        self._fault_spike()
        self._fault_host_sim()
        t0 = self.ledger.sim_time
        for li in range(self.cfg.n_layers):
            counts = self._sample_counts(li, n)
            plan = self._decide(li, counts)
            self._charge(li, plan, n_tokens=n, kv_len=kv_lens,
                         kv_unique=kv_unique, counts=counts)
        self.ledger.tokens_out += n
        return self.ledger.sim_time - t0

    def simulate_generate(self, prompt_len: int, gen_len: int,
                          batch: int = 1) -> Dict[str, float]:
        """End-to-end scenario (paper's ⓐ/ⓑ/ⓒ): returns latency metrics."""
        self.simulate_prefill(prompt_len * batch if batch > 1 else prompt_len)
        t_dec = self.simulate_decode(gen_len, batch=batch, kv_start=prompt_len)
        led = self.ledger
        return {
            "ttft": led.ttft,
            "decode_time": t_dec,
            "total": led.sim_time,
            "tokens_per_s": gen_len / led.sim_time if led.sim_time else 0.0,
            "itl": t_dec / max(gen_len, 1),
            "hit_rate": led.fast_hits / max(led.fast_hits + led.streams
                                            + led.slow_runs, 1),
        }
