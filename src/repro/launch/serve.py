"""Serving launcher: run the Fiddler engine (or the monolithic model) over
a stream of requests from the synthetic conversation pipeline, with either
the static grouped scheduler or slot-based continuous batching.

Configuration is a :class:`ServeConfig` dataclass — benchmarks and
examples construct it programmatically (``run(ServeConfig(...))``) and
the CLI is just ``ServeConfig.from_args``.

``--policy`` picks the *orchestrator* policy (paper Algorithm 1 vs
baselines); ``--sched-policy`` picks the *scheduler* policy (the
SchedulerPolicy seam: fifo / priority / autoscale / roofline) and
``--slo`` assigns SLO classes to the generated request stream, e.g.
``--slo interactive=1,batch=3`` for a 1:3 class mix.

``--prefix-pool N --prefix-len L`` prepends one of N shared L-token
preambles (system prompts) to every request: with the paged layout and
continuous scheduler, the cross-request prefix cache (on by default,
``--no-prefix-cache`` to disable) splices the resident preamble blocks
into each later admission and only prefills the unique tail — the
ledger reports lookups/hits/matched tokens.

  PYTHONPATH=src python -m repro.launch.serve --arch mixtral-8x7b \
      --policy fiddler --requests 8 --max-new 16 --scheduler continuous \
      --sched-policy priority --slo interactive=1,batch=3 \
      --prefix-pool 1 --prefix-len 32
"""
import argparse
from dataclasses import dataclass, fields
from typing import Any, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config
from repro.core import FiddlerEngine, HardwareSpec
from repro.data.pipeline import synthetic_conversations
from repro.data.tokenizer import ByteTokenizer
from repro.models import Model
from repro.models.kv_cache import layer_window
from repro.serving.backend import FiddlerBackend, ModelBackend
from repro.serving.continuous import ContinuousEngine
from repro.serving.engine import Request, ServingEngine

SCHED_POLICIES = ["fifo", "priority", "autoscale", "roofline"]


@dataclass
class ServeConfig:
    """Everything the serving launcher needs, as data.  ``sched_policy``
    takes anything ``serving.policy.get_policy`` accepts — a registry
    name, a ``PolicySpec``, or a ready ``SchedulerPolicy`` instance —
    so programmatic callers pass structured specs instead of flag
    strings."""
    arch: str = "mixtral-8x7b"
    policy: str = "fiddler"          # orchestrator policy
    requests: int = 8
    max_new: int = 16
    max_batch: int = 4
    hw: str = "env1"
    scheduler: str = "static"        # static | continuous
    slots: int = 4
    prefill_chunk: int = 16
    sched_policy: Any = "fifo"       # SchedulerPolicy spec (see get_policy)
    slo: Optional[str] = None        # "interactive=1,batch=3" class mix
    rebalance_interval: Optional[int] = None
    rebalance_k: int = 4
    kv_layout: str = "paged"
    beam_width: int = 1
    prefix_cache: bool = True
    prefix_pool: int = 0
    prefix_len: int = 96
    max_seq: int = 256
    mesh: str = "1,1"                # "data,model" serving mesh spec
    calibrate_host: bool = False

    def validate(self) -> "ServeConfig":
        if self.beam_width > 1 and self.beam_width > self.slots \
                and self.scheduler == "continuous":
            raise SystemExit(
                f"--beam-width {self.beam_width} needs at least that many "
                f"--slots (got {self.slots})")
        if self.rebalance_interval is not None and self.policy in (
                "model", "static_split"):
            raise SystemExit(
                "--rebalance-interval needs an expert-level orchestrator "
                "policy (fiddler or offload)")
        from repro.launch.mesh import parse_mesh_spec
        if parse_mesh_spec(self.mesh)[1] > 1 and self.policy in (
                "model", "static_split"):
            raise SystemExit(
                "--mesh with model>1 needs an expert-level orchestrator "
                "policy (fiddler or offload)")
        return self

    def slo_mix(self) -> Tuple[List[str], np.ndarray]:
        """The ``--slo`` class mix as (classes, probabilities)."""
        if not self.slo:
            return ["standard"], np.asarray([1.0])
        classes, weights = [], []
        for part in self.slo.split(","):
            name, _, w = part.partition("=")
            classes.append(name.strip())
            weights.append(float(w) if w else 1.0)
        if min(weights) < 0 or sum(weights) <= 0:
            raise SystemExit(
                f"--slo weights must be non-negative with a positive sum, "
                f"got {self.slo!r}")
        return classes, np.asarray(weights) / np.sum(weights)

    @classmethod
    def parser(cls) -> argparse.ArgumentParser:
        ap = argparse.ArgumentParser()
        ap.add_argument("--arch", default=cls.arch)
        ap.add_argument("--policy", default=cls.policy,
                        choices=["fiddler", "offload", "static_split",
                                 "model"])
        ap.add_argument("--requests", type=int, default=cls.requests)
        ap.add_argument("--max-new", type=int, default=cls.max_new)
        ap.add_argument("--max-batch", type=int, default=cls.max_batch)
        ap.add_argument("--hw", default=cls.hw,
                        choices=["env1", "env2", "tpuhost"])
        ap.add_argument("--scheduler", default=cls.scheduler,
                        choices=["static", "continuous"])
        ap.add_argument("--slots", type=int, default=cls.slots,
                        help="decode slots (continuous scheduler)")
        ap.add_argument("--prefill-chunk", type=int,
                        default=cls.prefill_chunk,
                        help="chunked-admission size (continuous scheduler)")
        ap.add_argument("--sched-policy", default=cls.sched_policy,
                        choices=SCHED_POLICIES,
                        help="SchedulerPolicy: admission order, preemption, "
                             "slot autoscaling, or roofline-disaggregated "
                             "prefill/decode streams")
        ap.add_argument("--slo", default=cls.slo,
                        help="SLO class mix for the request stream, e.g. "
                             "'interactive=1,batch=3' (weights); default: "
                             "all standard")
        ap.add_argument("--rebalance-interval", type=int,
                        default=cls.rebalance_interval,
                        help="dynamic placement rebalancing: serving ticks "
                             "between bounded expert-migration plans "
                             "(default: off — static placement)")
        ap.add_argument("--rebalance-k", type=int, default=cls.rebalance_k,
                        help="max expert swaps per rebalance interval")
        ap.add_argument("--kv-layout", default=cls.kv_layout,
                        choices=["paged", "dense"],
                        help="serving KV layout: paged (block pool + "
                             "copy-on-write tables; beam forks/reshuffles "
                             "are zero-copy) or dense ring buffers")
        ap.add_argument("--beam-width", type=int, default=cls.beam_width,
                        help=">1 submits every request as a gang-scheduled "
                             "beam group of this width (continuous "
                             "scheduler runs them alongside ordinary "
                             "traffic)")
        ap.add_argument("--prefix-cache",
                        action=argparse.BooleanOptionalAction,
                        default=cls.prefix_cache,
                        help="cross-request prefix cache over the paged KV "
                             "pool: prompts sharing a preamble reuse its "
                             "resident blocks and only prefill the tail "
                             "(paged layout + continuous scheduler; "
                             "--no-prefix-cache disables)")
        ap.add_argument("--prefix-pool", type=int, default=cls.prefix_pool,
                        metavar="N",
                        help="prepend one of N shared preambles "
                             "(round-robin) to every prompt — a "
                             "system-prompt workload that exercises the "
                             "prefix cache (default: off)")
        ap.add_argument("--prefix-len", type=int, default=cls.prefix_len,
                        metavar="L",
                        help="shared preamble length in tokens "
                             "(with --prefix-pool)")
        ap.add_argument("--mesh", default=cls.mesh, metavar="DATA,MODEL",
                        help="serving mesh: data-parallel replicas × "
                             "expert-parallel fast devices, e.g. '1,4' or "
                             "'data=1,model=4' (launch/mesh.py "
                             "parse_mesh_spec; default 1,1 = the "
                             "single-device engine)")
        ap.add_argument("--calibrate-host",
                        action="store_true", default=cls.calibrate_host,
                        help="one-shot CPU-throughput probe at engine "
                             "init: sizes the host worker pool and the "
                             "cost model's CPU GEMM rate from measurement "
                             "(core/host_calibration.py)")
        return ap

    @classmethod
    def from_args(cls, argv=None) -> "ServeConfig":
        args = cls.parser().parse_args(argv)
        known = {f.name for f in fields(cls)}
        picked = {k: v for k, v in vars(args).items() if k in known}
        return cls(**picked).validate()


def build_engine(cfg: ServeConfig):
    """ServeConfig → a ready serving engine over the requested backend."""
    full = get_config(cfg.arch)
    mcfg = full.reduced()  # real numerics at reduced scale on CPU
    model = Model(mcfg, param_dtype=jnp.float32)
    params = model.init(jax.random.PRNGKey(0))

    hw = {"env1": HardwareSpec.paper_env1(),
          "env2": HardwareSpec.paper_env2(),
          "tpuhost": HardwareSpec()}[cfg.hw]

    from repro.launch.mesh import make_serving_mesh, parse_mesh_spec
    _, n_model = parse_mesh_spec(cfg.mesh)
    mesh = make_serving_mesh(cfg.mesh)

    fe = None
    if cfg.policy != "model":
        fe = FiddlerEngine(
            mcfg, params, policy=cfg.policy, timing_cfg=full, hw=hw,
            expert_budget=mcfg.n_layers * mcfg.moe.n_experts // 4
            if mcfg.moe else 0,
            rebalance_interval=cfg.rebalance_interval,
            rebalance_k=cfg.rebalance_k,
            kv_layout=cfg.kv_layout,
            prefix_cache=cfg.prefix_cache,
            mesh=mesh, n_fast_devices=n_model,
            calibrate_host=cfg.calibrate_host)
    if cfg.scheduler == "continuous":
        backend = (ModelBackend(model, params, max_seq=cfg.max_seq)
                   if fe is None
                   else FiddlerBackend(fe, max_seq=cfg.max_seq))
        eng = ContinuousEngine(backend, n_slots=cfg.slots,
                               max_seq=cfg.max_seq,
                               prefill_chunk=cfg.prefill_chunk,
                               policy=cfg.sched_policy)
    elif fe is None:
        eng = ServingEngine(model, mode="model", params=params,
                            max_batch=cfg.max_batch, max_seq=cfg.max_seq,
                            policy=cfg.sched_policy)
    else:
        eng = ServingEngine(fe, mode="fiddler", max_batch=cfg.max_batch,
                            max_seq=cfg.max_seq, policy=cfg.sched_policy)
    return eng, mcfg


def run(cfg: ServeConfig) -> None:
    eng, mcfg = build_engine(cfg)
    tok = ByteTokenizer(mcfg.vocab_size)
    classes, probs = cfg.slo_mix()
    rng = np.random.default_rng(0)

    # shared system-prompt preambles for the prefix-cache workload: a
    # ring-wrapped row cannot serve as a shared prefix, so keep
    # preamble + tail + decode inside the smallest layer KV window
    # (reduced Mixtral runs 64-token sliding-window rings)
    w_min = min(layer_window(mcfg, li, cfg.max_seq)
                for li in range(mcfg.n_layers))
    pre_len = min(cfg.prefix_len, max(16, w_min - 16 - cfg.max_new))
    tail_cap = max(1, min(48, w_min - pre_len - cfg.max_new))
    if cfg.prefix_pool and pre_len < cfg.prefix_len:
        print(f"note: --prefix-len clipped to {pre_len} (layer KV window "
              f"{w_min} with --max-new {cfg.max_new})")
    pools = [rng.integers(3, min(250, mcfg.vocab_size),
                          size=pre_len).tolist()
             for _ in range(cfg.prefix_pool)]
    for i, conv in enumerate(synthetic_conversations(cfg.requests)):
        slo = classes[int(rng.choice(len(classes), p=probs))]
        prompt = tok.encode(conv["text"])[:48]
        if pools:
            prompt = pools[i % len(pools)] + prompt[:tail_cap]
        eng.submit(Request(rid=f"req{i}", prompt=prompt,
                           max_new_tokens=cfg.max_new, slo_class=slo,
                           beam_width=cfg.beam_width))
    for r in eng.run():
        unit = "s(sim)" if cfg.policy != "model" else "s"
        beam = (f" beams={r.beam_width}" if r.beam_width > 1 else "")
        print(f"{r.rid}[{r.slo_class}]: ttft={r.ttft:.4f}{unit} "
              f"latency={r.latency:.4f}{unit} tokens={len(r.output)} "
              f"preempt={r.preemptions}{beam}")
    if cfg.policy not in ("model",):
        led = eng.backend.ledger
        print(f"ledger: sim_time={led.sim_time:.4f}s hits={led.fast_hits} "
              f"streams={led.streams} slow={led.slow_runs} "
              f"migrations={led.migrations} "
              f"migration_time={led.migration_time:.4f}s")
        if led.prefix_lookups:
            print(f"prefix cache: lookups={led.prefix_lookups} "
                  f"hits={led.prefix_hits} "
                  f"matched_tokens={led.prefix_tokens}")
        if led.prefill_stream_time or led.decode_stream_time:
            print(f"streams: prefill={led.prefill_stream_time:.4f}s "
                  f"(overlapped={led.prefill_stream_overlapped:.4f}s) "
                  f"decode={led.decode_stream_time:.4f}s")


def main(argv=None):
    run(ServeConfig.from_args(argv))


if __name__ == "__main__":
    main()
