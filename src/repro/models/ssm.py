"""Mamba2 (SSD — state-space duality) block, pure JAX.

Train/prefill use the chunked SSD algorithm (lax.scan over chunks carrying
the (B, nh, hd, d_state) inter-chunk state); decode is the O(1) recurrent
update.  Reference: Dao & Gu, "Transformers are SSMs" (arXiv:2405.21060).
"""
from __future__ import annotations

from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models.layers import Params, dense_init, init_rmsnorm, rmsnorm


def ssm_dims(cfg: ModelConfig) -> Dict[str, int]:
    s = cfg.ssm
    inner = s.expand * cfg.d_model
    n_heads = inner // s.head_dim
    conv_dim = inner + 2 * s.n_groups * s.state_dim
    return dict(inner=inner, n_heads=n_heads, conv_dim=conv_dim,
                proj_dim=2 * inner + 2 * s.n_groups * s.state_dim + n_heads)


def _use_split_proj() -> bool:
    from repro.distributed import opts

    return opts.SPLIT_SSM_PROJ


def init_ssm_block(key, cfg: ModelConfig, dtype=jnp.float32) -> Params:
    s = cfg.ssm
    dims = ssm_dims(cfg)
    k1, k2, k3, k4 = jax.random.split(key, 4)
    if _use_split_proj():
        # §Perf SPLIT_SSM_PROJ: three separately-sharded projections
        # instead of one fused matrix whose column split straddles shard
        # boundaries (removing the per-layer resharding collectives).
        ka, kb, kc = jax.random.split(k1, 3)
        proj = {
            "w_z": dense_init(ka, (cfg.d_model, dims["inner"]), 0, dtype),
            "w_xbc": dense_init(kb, (cfg.d_model, dims["conv_dim"]), 0, dtype),
            "w_dt": dense_init(kc, (cfg.d_model, dims["n_heads"]), 0, dtype),
        }
    else:
        proj = {"in_proj": dense_init(k1, (cfg.d_model, dims["proj_dim"]),
                                      0, dtype)}
    return {
        **proj,
        "conv_w": dense_init(k2, (s.conv_width, dims["conv_dim"]), 0, dtype),
        "conv_b": jnp.zeros((dims["conv_dim"],), dtype),
        "A_log": jnp.log(jnp.linspace(1.0, 16.0, dims["n_heads"]).astype(jnp.float32)),
        "D": jnp.ones((dims["n_heads"],), jnp.float32),
        "dt_bias": jnp.zeros((dims["n_heads"],), jnp.float32),
        "norm": init_rmsnorm(dims["inner"], dtype),
        "out_proj": dense_init(k4, (dims["inner"], cfg.d_model), 0, dtype),
    }


def _split_proj(zxbcdt: jnp.ndarray, cfg: ModelConfig):
    s = cfg.ssm
    dims = ssm_dims(cfg)
    inner, g, st, nh = dims["inner"], s.n_groups, s.state_dim, dims["n_heads"]
    z = zxbcdt[..., :inner]
    xBC = zxbcdt[..., inner: inner + dims["conv_dim"]]
    dt = zxbcdt[..., inner + dims["conv_dim"]:]
    return z, xBC, dt


def _project(params: Params, u: jnp.ndarray, cfg: ModelConfig):
    """Input projection → (z, xBC, dt), fused or split per SPLIT_SSM_PROJ."""
    if "in_proj" in params:
        return _split_proj(u @ params["in_proj"], cfg)
    return u @ params["w_z"], u @ params["w_xbc"], u @ params["w_dt"]


def _causal_conv(xBC: jnp.ndarray, w: jnp.ndarray, b: jnp.ndarray,
                 state: Optional[jnp.ndarray] = None) -> jnp.ndarray:
    """Depthwise causal conv1d.  xBC: (B, S, C); w: (W, C)."""
    W = w.shape[0]
    if state is None:
        pad = jnp.zeros((xBC.shape[0], W - 1, xBC.shape[2]), xBC.dtype)
    else:
        pad = state.astype(xBC.dtype)
    xp = jnp.concatenate([pad, xBC], axis=1)  # (B, S+W-1, C)
    out = sum(xp[:, i: i + xBC.shape[1]] * w[i] for i in range(W))
    return jax.nn.silu(out + b)


def _ssd_chunked(x: jnp.ndarray, dt: jnp.ndarray, A: jnp.ndarray,
                 Bm: jnp.ndarray, Cm: jnp.ndarray, chunk: int,
                 state0: Optional[jnp.ndarray] = None
                 ) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Chunked SSD scan.

    x:  (B, S, nh, hd)      dt: (B, S, nh)        A: (nh,) negative
    Bm: (B, S, g, st)       Cm: (B, S, g, st)
    Returns (y: (B, S, nh, hd), final_state: (B, nh, hd, st)).
    """
    Bsz, S, nh, hd = x.shape
    g, st = Bm.shape[2], Bm.shape[3]
    rep = nh // g
    pad = (-S) % chunk
    if pad:
        x = jnp.pad(x, ((0, 0), (0, pad), (0, 0), (0, 0)))
        dt = jnp.pad(dt, ((0, 0), (0, pad), (0, 0)))
        Bm = jnp.pad(Bm, ((0, 0), (0, pad), (0, 0), (0, 0)))
        Cm = jnp.pad(Cm, ((0, 0), (0, pad), (0, 0), (0, 0)))
    n_chunks = (S + pad) // chunk

    def resh(a, feat_shape):
        return a.reshape((Bsz, n_chunks, chunk) + feat_shape).swapaxes(0, 1)

    xc = resh(x, (nh, hd))
    dtc = resh(dt, (nh,))
    Bc = resh(Bm, (g, st))
    Cc = resh(Cm, (g, st))

    def body(state, inp):
        x_i, dt_i, B_i, C_i = inp
        # x_i: (B, L, nh, hd); dt_i: (B, L, nh); B_i/C_i: (B, L, g, st)
        a = dt_i * A  # (B, L, nh) log-decay per step (negative)
        cum = jnp.cumsum(a, axis=1)  # (B, L, nh)
        # intra-chunk: Y[i] += sum_{j<=i} exp(cum[i]-cum[j]) dt[j] (C_i·B_j) x[j]
        Lmat = cum[:, :, None, :] - cum[:, None, :, :]  # (B, L, L, nh)
        iota = jnp.arange(x_i.shape[1])
        causal = iota[:, None] >= iota[None, :]
        # mask BEFORE exp: anti-causal entries are positive and can
        # overflow to inf, which would poison the backward pass through
        # the where (NaN gradients)
        Lmat = jnp.exp(jnp.where(causal[None, :, :, None], Lmat, -1e30))
        Bh = jnp.repeat(B_i, rep, axis=2)  # (B, L, nh, st)
        Ch = jnp.repeat(C_i, rep, axis=2)
        scores = jnp.einsum("blhs,bmhs->blmh", Ch, Bh)  # (B, L, L, nh)
        M = scores * Lmat * dt_i[:, None, :, :]
        y_intra = jnp.einsum("blmh,bmhd->blhd", M, x_i)
        # inter-chunk: contribution of carried state
        y_inter = jnp.einsum("blhs,bhds->blhd", Ch, state) * jnp.exp(cum)[..., None]
        # state update: state' = exp(sum a) * state + sum_j exp(cum[-1]-cum[j]) dt_j B_j ⊗ x_j
        decay_to_end = jnp.exp(cum[:, -1:, :] - cum)  # (B, L, nh)
        w = decay_to_end * dt_i  # (B, L, nh)
        state_new = (jnp.exp(cum[:, -1])[:, :, None, None] * state
                     + jnp.einsum("blh,blhs,blhd->bhds", w, Bh, x_i))
        return state_new, y_intra + y_inter

    if state0 is None:
        state0 = jnp.zeros((Bsz, nh, hd, st), jnp.float32)
    # remat per chunk: the (B, L, L, nh) decay/score blocks are recomputed
    # in the backward pass instead of being saved for all chunks.
    final_state, yc = jax.lax.scan(jax.checkpoint(body, prevent_cse=False),
                                   state0, (xc, dtc, Bc, Cc))
    y = yc.swapaxes(0, 1).reshape(Bsz, S + pad, nh, hd)[:, :S]
    return y, final_state


def ssm_block(params: Params, u: jnp.ndarray, cfg: ModelConfig,
              cache: Optional[Dict[str, jnp.ndarray]] = None
              ) -> Tuple[jnp.ndarray, Optional[Dict[str, jnp.ndarray]]]:
    """Full Mamba2 mixer. u: (B, S, d). With a cache and S == 1 → decode."""
    s = cfg.ssm
    dims = ssm_dims(cfg)
    nh, hd, g, st = dims["n_heads"], s.head_dim, s.n_groups, s.state_dim
    B_, S, _ = u.shape
    z, xBC, dt = _project(params, u, cfg)
    dt = jax.nn.softplus(dt.astype(jnp.float32) + params["dt_bias"])  # (B,S,nh)
    A = -jnp.exp(params["A_log"])  # (nh,) negative

    if cache is not None and S == 1:
        # --- decode: O(1) recurrent update --------------------------------
        conv_in = jnp.concatenate([cache["conv_state"].astype(xBC.dtype), xBC], axis=1)
        w = params["conv_w"]
        conv_out = sum(conv_in[:, i: i + 1] * w[i] for i in range(w.shape[0]))
        xBC_t = jax.nn.silu(conv_out + params["conv_b"])  # (B,1,conv_dim)
        new_conv_state = conv_in[:, 1:]
        x = xBC_t[..., : dims["inner"]].reshape(B_, nh, hd)
        Bm = xBC_t[..., dims["inner"]: dims["inner"] + g * st].reshape(B_, g, st)
        Cm = xBC_t[..., dims["inner"] + g * st:].reshape(B_, g, st)
        Bh = jnp.repeat(Bm, nh // g, axis=1)  # (B, nh, st)
        Ch = jnp.repeat(Cm, nh // g, axis=1)
        dt1 = dt[:, 0]  # (B, nh)
        decay = jnp.exp(dt1 * A)  # (B, nh)
        xf = x.astype(jnp.float32)
        state = (cache["ssm_state"] * decay[..., None, None]
                 + dt1[..., None, None] * jnp.einsum("bhs,bhd->bhds", Bh.astype(jnp.float32), xf))
        y = jnp.einsum("bhs,bhds->bhd", Ch.astype(jnp.float32), state)
        y = y + params["D"][:, None] * xf
        y = y.reshape(B_, 1, dims["inner"]).astype(u.dtype)
        new_cache = {"ssm_state": state, "conv_state": new_conv_state}
    else:
        # --- train / prefill: chunked SSD ---------------------------------
        xBC_raw = xBC
        xBC = _causal_conv(xBC, params["conv_w"], params["conv_b"],
                           None if cache is None else cache["conv_state"])
        x = xBC[..., : dims["inner"]].reshape(B_, S, nh, hd)
        Bm = xBC[..., dims["inner"]: dims["inner"] + g * st].reshape(B_, S, g, st)
        Cm = xBC[..., dims["inner"] + g * st:].reshape(B_, S, g, st)
        state0 = None if cache is None else cache["ssm_state"]
        y, final_state = _ssd_chunked(
            x.astype(jnp.float32), dt, A, Bm.astype(jnp.float32),
            Cm.astype(jnp.float32), s.chunk_size, state0)
        y = y + params["D"][:, None] * x.astype(jnp.float32)
        y = y.reshape(B_, S, dims["inner"]).astype(u.dtype)
        if cache is None:
            new_cache = None
        else:
            W = params["conv_w"].shape[0]
            hist = jnp.concatenate(
                [cache["conv_state"].astype(xBC_raw.dtype), xBC_raw], axis=1)
            new_cache = {
                "ssm_state": final_state,
                "conv_state": hist[:, -(W - 1):].astype(jnp.float32),
            }
    # gated RMSNorm + output projection
    y = rmsnorm(params["norm"], y * jax.nn.silu(z), cfg.norm_eps)
    return y @ params["out_proj"], new_cache
