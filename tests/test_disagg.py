"""Prefill/decode disaggregation (PR 8): roofline chunk math, the
overlapped-stream ledger accounting, the roofline-vs-interleaved win,
the deadline-aware static-batch split, and the redesigned serving API
surface (kw-only slot mutations, structured policy specs, ServeConfig).
"""
import math

import numpy as np
import pytest

from repro.configs import get_config
from repro.core import FiddlerEngine, HardwareSpec
from repro.serving.backend import SimulatedBackend
from repro.serving.continuous import ContinuousEngine
from repro.serving.engine import Request, ServingEngine
from repro.serving.policy import (CostView, PolicySpec, PriorityPolicy,
                                  RooflinePolicy, StepPlan, get_policy)


def _sim_serving(policy, *, n_slots=4, max_seq=256, prefill_chunk=16):
    cfg = get_config("mixtral-8x7b")
    fe = FiddlerEngine(cfg, policy="fiddler",
                       hw=HardwareSpec.paper_env1(), seed=0)
    eng = ContinuousEngine(SimulatedBackend(fe, max_seq=max_seq),
                           n_slots=n_slots, max_seq=max_seq,
                           prefill_chunk=prefill_chunk, policy=policy)
    return fe, eng


def _long_prompt_workload(eng, n=8, prompt_len=96, max_new=24):
    for i in range(n):
        prompt = [1] + [3 + (i * 11 + j * 7) % 200
                        for j in range(prompt_len - 1)]
        slo = "interactive" if i % 4 == 0 else "batch"
        eng.submit(Request(rid=f"r{i}", prompt=prompt,
                           max_new_tokens=max_new, arrival=i * 0.05,
                           slo_class=slo))
    return eng.run(max_steps=200_000, on_exhausted="raise")


# ---------------------------------------------------------------------------
# CostView roofline math
# ---------------------------------------------------------------------------


def test_costview_roofline_knee():
    cv = CostView(gpu_const=2e-3, gpu_per_token=4e-5, n_experts=8, top_k=2,
                  fast_flops=1e12, fast_mem_bw=1e11)
    # knee: compute time catches the weight-read floor at const/per_token
    assert cv.saturation_tokens() == pytest.approx(50.0)
    # a prompt chunk spreads over the experts: knee * n_experts / top_k
    assert cv.prefill_chunk_tokens() == 200
    # never degenerate, whatever the constants
    tiny = CostView(gpu_const=0.0, gpu_per_token=1.0, n_experts=8, top_k=2,
                    fast_flops=1.0, fast_mem_bw=1.0)
    assert tiny.prefill_chunk_tokens() >= 1


def test_simulated_backend_exposes_cost_view():
    _, eng = _sim_serving("fifo")
    cv = eng.backend.cost_view()
    assert cv is not None
    assert cv.gpu_const > 0 and cv.gpu_per_token > 0
    assert cv.n_experts == 8 and cv.top_k == 2
    # the saturating chunk is far above the interleaved default — the
    # whole reason disaggregation pays
    assert cv.prefill_chunk_tokens() > 16


def test_roofline_plan_shape():
    _, eng = _sim_serving("roofline", prefill_chunk=8)
    for i in range(3):
        eng.submit(Request(rid=f"r{i}", prompt=[1] * 32, max_new_tokens=4))
    eng._admit()
    view = eng._view()
    plan = eng.policy.plan(view)
    assert isinstance(plan, StepPlan) and plan.overlap
    # exactly one slot prefills per tick, at the saturating chunk
    assert plan.prefill is not None and len(plan.prefill) == 1
    chunk = plan.chunk_sizes[plan.prefill[0]]
    assert chunk == min(512, view.cost.prefill_chunk_tokens())
    assert plan.decode is None  # every decode-phase slot runs batched


def test_roofline_chunk_falls_back_without_cost_model():
    pol = RooflinePolicy()
    _, eng = _sim_serving(pol, prefill_chunk=8)
    view = eng._view()
    import dataclasses
    blind = dataclasses.replace(view, cost=None, default_chunk=8)
    assert pol._chunk(blind) == 8
    assert pol._chunk(view) == min(512, view.cost.prefill_chunk_tokens())


# ---------------------------------------------------------------------------
# The disaggregation win + per-stream ledger accounting
# ---------------------------------------------------------------------------


def test_roofline_beats_interleaved_fifo_on_long_prompts():
    fe_f, eng_f = _sim_serving("fifo")
    done_f = _long_prompt_workload(eng_f)
    fe_r, eng_r = _sim_serving("roofline")
    done_r = _long_prompt_workload(eng_r)

    def tput(fe, done):
        return sum(len(r.output) for r in done) / fe.ledger.sim_time

    def worst_interactive_ttft(done):
        return max(r.ttft for r in done if r.slo_class == "interactive")

    # saturating prefill chunks + overlap: strictly higher delivered
    # throughput...
    assert tput(fe_r, done_r) > tput(fe_f, done_f)
    # ...and priority admission keeps interactive TTFT no worse than the
    # head-of-line-blocked FIFO baseline
    assert (worst_interactive_ttft(done_r)
            <= worst_interactive_ttft(done_f))
    # same tokens delivered either way (greedy decode, same engine seed)
    assert (sorted((r.rid, len(r.output)) for r in done_r)
            == sorted((r.rid, len(r.output)) for r in done_f))


def test_overlap_stream_ledger_invariants():
    fe, eng = _sim_serving("roofline")
    done = _long_prompt_workload(eng)
    led = fe.ledger
    # both streams ran and split completely: overlapped + exposed == time
    assert led.prefill_stream_time > 0 and led.decode_stream_time > 0
    assert (led.prefill_stream_overlapped + led.prefill_stream_exposed
            == pytest.approx(led.prefill_stream_time))
    assert (led.decode_stream_overlapped + led.decode_stream_exposed
            == pytest.approx(led.decode_stream_time))
    # overlap actually hid prefill under the decode stream
    assert led.prefill_stream_overlapped > 0
    # decode is the foreground stream: never hidden
    assert led.decode_stream_overlapped == 0.0
    assert led.decode_stream_exposed == led.decode_stream_time
    # hiding must not bend the clock: per-request timestamps stay monotone
    for r in done:
        ts = list(r.token_times)
        assert all(a <= b for a, b in zip(ts, ts[1:])), r.rid
        assert ts[-1] <= led.sim_time + 1e-9


def test_interleaved_policies_leave_stream_fields_zero():
    fe, eng = _sim_serving("fifo")
    _long_prompt_workload(eng, n=3)
    led = fe.ledger
    assert led.prefill_stream_time == 0.0
    assert led.prefill_stream_overlapped == 0.0
    assert led.prefill_stream_exposed == 0.0
    assert led.decode_stream_time == 0.0
    assert led.decode_stream_overlapped == 0.0
    assert led.decode_stream_exposed == 0.0


# ---------------------------------------------------------------------------
# Deadline-aware static-batch formation (ServingEngine group split)
# ---------------------------------------------------------------------------


def _static_engine(policy):
    cfg = get_config("mixtral-8x7b")
    fe = FiddlerEngine(cfg, policy="fiddler",
                       hw=HardwareSpec.paper_env1(), seed=0)
    return ServingEngine(SimulatedBackend(fe, max_seq=64),
                         max_batch=4, max_seq=64, policy=policy)


def test_static_group_splits_for_interactive_mid_group():
    """A static batch only starts once its last member arrives, so a
    not-yet-arrived batch straggler grouped with an already-arrived
    interactive request would stall it — the group must split."""
    eng = _static_engine("priority")
    eng.submit(Request(rid="bulk", prompt=[1, 5, 9], max_new_tokens=2,
                       arrival=0.0, slo_class="batch"))
    eng.submit(Request(rid="late-bulk", prompt=[1, 6, 2], max_new_tokens=2,
                       arrival=5.0, slo_class="batch"))
    eng.submit(Request(rid="inter", prompt=[1, 7], max_new_tokens=2,
                       arrival=0.0, slo_class="interactive"))
    first = {r.rid for r in eng._next_group()}
    assert first == {"inter", "bulk"}  # straggler deferred, not waited on
    second = {r.rid for r in eng._next_group()}
    assert second == {"late-bulk"}


def test_static_group_never_splits_pure_fifo():
    """Equal-priority traffic keeps the legacy grouping even with late
    arrivals — the split rule needs a strictly more urgent member."""
    eng = _static_engine("fifo")
    for i, arr in enumerate((0.0, 5.0, 0.0)):
        eng.submit(Request(rid=f"r{i}", prompt=[1, 4 + i], max_new_tokens=2,
                           arrival=arr, slo_class="batch"))
    assert {r.rid for r in eng._next_group()} == {"r0", "r1", "r2"}


def test_static_group_split_end_to_end_ttft():
    """Through a full run: the interactive request's TTFT must not pay
    for a straggler that arrives 5 simulated seconds later."""
    eng = _static_engine("priority")
    eng.submit(Request(rid="bulk", prompt=[1, 5, 9], max_new_tokens=2,
                       arrival=0.0, slo_class="batch"))
    eng.submit(Request(rid="late-bulk", prompt=[1, 6, 2], max_new_tokens=2,
                       arrival=5.0, slo_class="batch"))
    eng.submit(Request(rid="inter", prompt=[1, 7], max_new_tokens=2,
                       arrival=0.0, slo_class="interactive"))
    done = {r.rid: r for r in eng.run()}
    assert done["inter"].ttft < 5.0  # would be >= 5 if batched with the
    #                                  straggler (batch waits for arrival)
    assert len(done["late-bulk"].output) == 2


# ---------------------------------------------------------------------------
# Redesigned API surface
# ---------------------------------------------------------------------------


def test_slot_mutations_are_keyword_only():
    _, eng = _sim_serving("fifo", max_seq=64)
    backend = eng.backend
    cache = backend.make_cache(2)
    with pytest.raises(TypeError):
        backend.resize_cache(cache, 3)
    with pytest.raises(TypeError):
        backend.fork_slot(cache, 0, 1)
    with pytest.raises(TypeError):
        backend.reorder_slots(cache, [0, 1], [1, 0])
    with pytest.raises(TypeError):
        backend.release_slot(cache, 0)


def test_get_policy_structured_specs():
    p = get_policy(PolicySpec("priority", {"aging_time": 4.0}))
    assert isinstance(p, PriorityPolicy) and p.aging_time == 4.0
    p = get_policy({"name": "roofline", "max_chunk": 64})
    assert isinstance(p, RooflinePolicy) and p.max_chunk == 64
    assert isinstance(get_policy("roofline"), RooflinePolicy)
    with pytest.raises(ValueError, match="unknown scheduler policy"):
        get_policy(PolicySpec("nope"))
    with pytest.raises(ValueError, match="needs a 'name'"):
        get_policy({"max_chunk": 64})
    with pytest.raises(TypeError):
        get_policy(3.14)


def test_serve_config_parses_and_validates():
    from repro.launch.serve import ServeConfig

    cfg = ServeConfig.from_args(["--sched-policy", "roofline",
                                 "--requests", "2",
                                 "--slo", "interactive=1,batch=3"])
    assert cfg.sched_policy == "roofline" and cfg.requests == 2
    classes, probs = cfg.slo_mix()
    assert classes == ["interactive", "batch"]
    np.testing.assert_allclose(probs, [0.25, 0.75])
    # programmatic structured spec straight through the same field
    cfg2 = ServeConfig(sched_policy=PolicySpec("priority",
                                               {"aging_time": 2.0}))
    assert isinstance(get_policy(cfg2.sched_policy), PriorityPolicy)
    with pytest.raises(SystemExit):
        ServeConfig(scheduler="continuous", beam_width=8,
                    slots=4).validate()
    with pytest.raises(SystemExit):
        ServeConfig(slo="interactive=-1").slo_mix()
