"""Serving engine + beam search."""
import jax.numpy as jnp
import numpy as np

from conftest import reduced_model
from repro.core import FiddlerEngine
from repro.serving.beam_search import beam_search_fiddler, beam_search_model
from repro.serving.engine import Request, ServingEngine


def test_engine_model_mode_batches():
    cfg, model, params = reduced_model("qwen3-0.6b")
    eng = ServingEngine(model, mode="model", params=params, max_batch=3,
                        max_seq=64)
    for i in range(5):
        eng.submit(Request(rid=f"r{i}", prompt=[1] + [10 + i] * (4 + i),
                           max_new_tokens=6))
    done = eng.run()
    assert len(done) == 5
    for r in done:
        assert 1 <= len(r.output) <= 6
        assert r.ttft is not None and r.latency is not None and r.latency >= r.ttft


def test_engine_fiddler_mode_sim_clock():
    cfg, model, params = reduced_model("mixtral-8x7b")
    fe = FiddlerEngine(cfg, params, policy="fiddler", expert_budget=30,
                       host_precision="fp32")
    eng = ServingEngine(fe, mode="fiddler", max_batch=2, max_seq=48)
    eng.submit(Request(rid="a", prompt=[1, 5, 9, 13], max_new_tokens=4))
    eng.submit(Request(rid="b", prompt=[1, 6, 2], max_new_tokens=4))
    done = eng.run()
    assert all(r.latency > 0 for r in done)  # simulated seconds
    assert fe.ledger.tokens_out >= 3  # first token comes from prefill


def test_beam_search_scores_sorted_and_widths():
    cfg, model, params = reduced_model("qwen3-0.6b")
    prompt = np.array([[1, 7, 11, 3]], np.int32)
    res = beam_search_model(model, params, prompt, width=4, n_new=5,
                            max_seq=32)
    assert res.tokens.shape == (4, 5)
    assert (np.diff(res.scores) <= 1e-6).all()  # sorted desc
    # wider beam can only improve (or match) the best score
    res8 = beam_search_model(model, params, prompt, width=8, n_new=5,
                             max_seq=32)
    assert res8.scores[0] >= res.scores[0] - 1e-5


def test_beam_search_width1_is_greedy():
    cfg, model, params = reduced_model("qwen3-0.6b")
    prompt = np.array([[1, 4, 9]], np.int32)
    res = beam_search_model(model, params, prompt, width=1, n_new=4,
                            max_seq=32)
    logits, cache = model.prefill(params, jnp.asarray(prompt), max_seq=32,
                                  cache_dtype=jnp.float32)
    toks = []
    tok = jnp.argmax(logits, -1)[:, None]
    for t in range(4):
        toks.append(int(tok[0, 0]))
        logits, cache = model.decode_step(params, cache, tok,
                                          jnp.int32(3 + t), max_seq=32)
        tok = jnp.argmax(logits, -1)[:, None]
    assert res.tokens[0].tolist() == toks


def test_beam_search_fiddler_matches_model():
    """Beam search through the orchestrator must pick identical beams."""
    cfg, model, params = reduced_model("mixtral-8x7b")
    prompt = np.array([[1, 5, 2, 8]], np.int32)
    want = beam_search_model(model, params, prompt, width=3, n_new=4,
                             max_seq=32)
    fe = FiddlerEngine(cfg, params, policy="fiddler", expert_budget=40,
                       host_precision="fp32")
    got = beam_search_fiddler(fe, prompt, width=3, n_new=4, max_seq=32)
    # near-tied scores may order differently between the two numeric paths:
    # compare the best beam and the score multiset
    np.testing.assert_array_equal(got.tokens[0], want.tokens[0])
    np.testing.assert_allclose(np.sort(got.scores), np.sort(want.scores),
                               rtol=1e-3, atol=1e-3)
    assert fe.ledger.sim_time > 0
