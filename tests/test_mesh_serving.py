"""Expert-parallel serving over a device mesh (distributed/sharding.py,
launch/mesh.py, distributed/expert_parallel.py, the engine's
``n_fast_devices`` ledger, and the N-device SimulatedBackend KV pools).

Multi-device cases need forced host devices
(``XLA_FLAGS=--xla_force_host_platform_device_count=8`` — the CI
mesh-smoke lane) and skip on the default single-device run; everything
else — spec parsing, placement, per-device accounting, the 1×1-mesh
bit-identity twin — runs everywhere.
"""
import jax
import numpy as np
import pytest

from conftest import reduced_model
from repro.configs import get_config
from repro.core import FiddlerEngine
from repro.core.cost_model import alltoall_time, expert_flops_per_token
from repro.core.host_calibration import HostCalibration, calibrate_host_pool
from repro.core.placement import (
    DevicePlacement,
    place_by_popularity,
    to_device_placement,
)
from repro.core.popularity import synthetic_profile
from repro.core.rebalance import MigrationPlan, PrefetchQueue, apply_plan
from repro.distributed.expert_parallel import (
    check_expert_divisibility,
    dense_reference_moe,
    expert_parallel_moe,
    expert_shard_spec,
    mesh_model_size,
    shard_expert_stack,
)
from repro.distributed.sharding import fast_stack_pspecs, serving_mesh_axes
from repro.launch.mesh import make_serving_mesh, parse_mesh_spec
from repro.serving.backend import FiddlerBackend, SimulatedBackend
from repro.serving.continuous import ContinuousEngine
from repro.serving.engine import Request

multi_device = pytest.mark.skipif(
    jax.device_count() < 2,
    reason="needs XLA_FLAGS=--xla_force_host_platform_device_count=8")


class _FakeMesh:
    """Axis bookkeeping stand-in: divisibility edge cases need mesh
    *shape*, not devices."""

    def __init__(self, **axes):
        self.axis_names = tuple(axes)
        self.devices = np.zeros(tuple(axes.values()))


# ---------------------------------------------------------------------------
# mesh spec parsing / construction
# ---------------------------------------------------------------------------


def test_parse_mesh_spec_forms():
    assert parse_mesh_spec("data=2,model=4") == (2, 4)
    assert parse_mesh_spec("model=4,data=2") == (2, 4)
    assert parse_mesh_spec("2x4") == (2, 4)
    assert parse_mesh_spec("2,4") == (2, 4)
    assert parse_mesh_spec("4") == (1, 4)
    assert parse_mesh_spec("") == (1, 1)
    with pytest.raises(AssertionError):
        parse_mesh_spec("expert=2")


def test_make_serving_mesh_1x1_is_none():
    # the bit-identity twin: no mesh object, the historical engine path
    assert make_serving_mesh("1,1") is None


def test_make_serving_mesh_insufficient_devices_is_none():
    big = 4 * jax.device_count()
    assert make_serving_mesh(f"1,{big}") is None


@multi_device
def test_make_serving_mesh_builds_axes():
    mesh = make_serving_mesh("1,2")
    assert mesh is not None
    assert serving_mesh_axes(mesh) == {"data": 1, "model": 2}


# ---------------------------------------------------------------------------
# param specs / divisibility
# ---------------------------------------------------------------------------


def test_fast_stack_pspecs_shards_when_divisible():
    specs = fast_stack_pspecs(8, model_size=4)
    assert all(s[0] == "model" for s in specs.values())
    for bad in (fast_stack_pspecs(7, model_size=4),     # 7 % 4 != 0
                fast_stack_pspecs(8, model_size=1),     # no model axis
                fast_stack_pspecs(0, model_size=4)):    # empty stack
        assert all(s[0] is None for s in bad.values())
    assert serving_mesh_axes(None) == {"data": 1, "model": 1}


def test_expert_divisibility_edge_cases():
    m2 = _FakeMesh(data=1, model=2)
    assert mesh_model_size(m2) == 2
    assert check_expert_divisibility(8, m2) == 4
    with pytest.raises(AssertionError):
        check_expert_divisibility(7, m2)
    # a mesh without a model axis is a single expert shard
    assert check_expert_divisibility(7, _FakeMesh(data=4)) == 7


@multi_device
def test_fast_stack_pspec_roundtrip():
    """Sharding a stacked expert triple over the model axis and gathering
    it back must be lossless (the param-spec round-trip)."""
    mesh = make_serving_mesh("1,2")
    rng = np.random.default_rng(0)
    wg, wu = rng.standard_normal((2, 4, 8, 16)).astype(np.float32)
    wd = rng.standard_normal((4, 16, 8)).astype(np.float32)
    assert expert_shard_spec() == fast_stack_pspecs(4, model_size=2)["wg"]
    for src, out in zip((wg, wu, wd), shard_expert_stack(mesh, wg, wu, wd)):
        np.testing.assert_array_equal(np.asarray(out), src)


@multi_device
def test_expert_parallel_moe_matches_dense_reference():
    mesh = make_serving_mesh("1,2")
    rng = np.random.default_rng(1)
    T, d, f, E, k = 8, 8, 16, 4, 2
    x = rng.standard_normal((T, d)).astype(np.float32)
    wg = rng.standard_normal((E, d, f)).astype(np.float32) * 0.1
    wu = rng.standard_normal((E, d, f)).astype(np.float32) * 0.1
    wd = rng.standard_normal((E, f, d)).astype(np.float32) * 0.1
    idx = rng.integers(0, E, size=(T, k)).astype(np.int32)
    gates = rng.random((T, k)).astype(np.float32)
    got = expert_parallel_moe(mesh, x, idx, gates, wg, wu, wd)
    want = dense_reference_moe(x, idx, gates, wg, wu, wd)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-5, atol=1e-5)


# ---------------------------------------------------------------------------
# devices × tiers placement
# ---------------------------------------------------------------------------


def test_to_device_placement_balances_round_robin():
    prof = synthetic_profile(4, 8, seed=0)
    p = place_by_popularity(prof, budget=16)
    dp = to_device_placement(p, 4, profile=prof)
    assert isinstance(dp, DevicePlacement) and dp.n_devices == 4
    np.testing.assert_array_equal(dp.on_fast, p.on_fast)
    counts = dp.device_counts()
    assert counts.sum() == 16 and counts.max() - counts.min() <= 1
    # slow experts carry no device
    assert (dp.device[~p.on_fast] == -1).all()


def test_apply_plan_preserves_device_targets():
    prof = synthetic_profile(2, 4, seed=1)
    dp = to_device_placement(place_by_popularity(prof, budget=4), 2,
                             profile=prof)
    fast = [tuple(x) for x in np.argwhere(dp.on_fast)]
    slow = [tuple(x) for x in np.argwhere(~dp.on_fast)]
    plan = MigrationPlan(promotes=(slow[0],), demotes=(fast[0],),
                         est_gain=0.1, transfer_bytes=100,
                         est_transfer_s=0.0, devices=(1,))
    out = apply_plan(dp, plan)
    assert isinstance(out, DevicePlacement)
    assert out.device[slow[0]] == 1 and out.device[fast[0]] == -1


def test_prefetch_queue_multilink_conservation():
    q = PrefetchQueue(n_links=2)
    q.push(0, 1, 0.4, link=0)
    q.push(0, 2, 0.6, link=1)
    hidden = q.drain(0.5)          # each link gets the full idle window
    exposed = q.flush()
    assert hidden == pytest.approx(0.4 + 0.5)   # link0 fully, link1 partly
    assert hidden + exposed == pytest.approx(1.0)


def test_alltoall_time_charges_only_multi_device():
    cfg = get_config("mixtral-8x7b")
    hw = FiddlerEngine(cfg, policy="fiddler").hw
    assert alltoall_time(cfg, 100, hw, 1) == 0.0
    t2, t4 = (alltoall_time(cfg, 100, hw, D) for D in (2, 4))
    assert t2 > 0 and t4 > 0 and t4 < t2   # more links, faster exchange


# ---------------------------------------------------------------------------
# host-pool calibration
# ---------------------------------------------------------------------------


def test_host_calibration_probe_and_apply():
    cfg = get_config("mixtral-8x7b")
    cal = calibrate_host_pool(cfg, max_workers=2, reps=2)
    assert cal.gemm_flops > 0 and cal.pool_flops > 0 and cal.workers >= 2
    lat = FiddlerEngine(cfg, policy="fiddler").lat
    lat2 = HostCalibration(1e9, 2, 2e9).apply(lat, cfg)
    assert lat2.cpu_per_token == pytest.approx(
        expert_flops_per_token(cfg) / 2e9)


def test_engine_calibrate_host_rescales_cpu_term():
    cfg = get_config("mixtral-8x7b")
    base = FiddlerEngine(cfg, policy="fiddler")
    eng = FiddlerEngine(cfg, policy="fiddler", calibrate_host=True)
    assert eng.host_calibration is not None
    assert eng.lat.cpu_per_token != base.lat.cpu_per_token
    assert eng.lat.cpu_per_token == pytest.approx(
        expert_flops_per_token(cfg) / eng.host_calibration.pool_flops)


# ---------------------------------------------------------------------------
# N-device simulation: ledger + per-device KV pools
# ---------------------------------------------------------------------------


def _sim_run(n_devices: int, *, n_requests: int = 8, rate: float = 50.0):
    cfg = get_config("mixtral-8x7b")
    eng = FiddlerEngine(cfg, policy="fiddler", seed=0,
                        n_fast_devices=n_devices, expert_budget=24)
    serving = ContinuousEngine(SimulatedBackend(eng, max_seq=128),
                               n_slots=8, max_seq=128, prefill_chunk=16)
    rng = np.random.default_rng(0)
    t = 0.0
    for i in range(n_requests):
        t += rng.exponential(1.0 / rate)
        prompt = [1] + rng.integers(3, 250, size=31).tolist()
        serving.submit(Request(rid=f"r{i}", prompt=prompt,
                               max_new_tokens=8, arrival=t))
    done = serving.run(max_steps=50_000, on_exhausted="raise")
    assert len(done) == n_requests
    return eng, serving


def test_multi_device_ledger_charges_alltoall():
    eng1, _ = _sim_run(1)
    eng4, s4 = _sim_run(4)
    led1, led4 = eng1.ledger, eng4.ledger
    assert led1.alltoall_time == 0.0 and led1.device_busy == []
    assert led4.alltoall_time > 0.0        # the exchange is never free
    assert led4.alltoall_overlapped + led4.alltoall_exposed == pytest.approx(
        led4.alltoall_time)
    assert len(led4.device_busy) == 4 and all(
        b > 0 for b in led4.device_busy)   # every device did expert work
    # 4× the per-device budget: same tokens, less slow-tier time
    assert led4.sim_time < led1.sim_time


def test_simulated_backend_per_device_pools():
    eng, serving = _sim_run(4)
    be, cache = serving.backend, serving.cache
    assert len(cache["metas"]) == 4
    devs = [be.device_of_slot(cache, s) for s in range(cache["n_slots"])]
    assert set(devs) <= set(range(4))
    # contiguous stripes: a gang window within one stripe is device-local
    chunk = cache["chunk"]
    for s in range(cache["n_slots"] - 1):
        if (s + 1) % chunk:
            assert devs[s] == devs[s + 1]
    # drained run: per-device leak audit all zeros
    assert be.kv_check(cache) == [0, 0, 0, 0]
    st = be.block_stats(cache)
    assert st["n_devices"] == 4 and len(st["per_device"]) == 4
    assert st["unique_blocks"] == sum(
        p["unique_blocks"] for p in st["per_device"])


def test_gang_admission_stays_device_local():
    cfg = get_config("mixtral-8x7b")
    eng = FiddlerEngine(cfg, policy="fiddler", seed=0, n_fast_devices=2,
                        expert_budget=24)
    serving = ContinuousEngine(SimulatedBackend(eng, max_seq=128),
                               n_slots=8, max_seq=128)
    be = serving.backend
    serving.submit(Request(rid="b0", prompt=[1, 5, 9], max_new_tokens=6,
                           beam_width=3))
    done = serving.run(max_steps=5_000, on_exhausted="raise")
    assert len(done) == 1 and done[0].beam_tokens is not None
    assert be.kv_check(serving.cache) == [0, 0]


# ---------------------------------------------------------------------------
# 1×1 mesh == single-device engine, fp32 bit-identity
# ---------------------------------------------------------------------------


@pytest.fixture(scope="module")
def mixtral():
    return reduced_model("mixtral-8x7b")


def _twin_engines(mixtral, **kw):
    cfg, _, params = mixtral
    kw.setdefault("policy", "fiddler")
    kw.setdefault("host_precision", "fp32")
    kw.setdefault("expert_budget", cfg.n_layers * cfg.moe.n_experts // 2)
    plain = FiddlerEngine(cfg, params, **kw)
    # the serve.py --mesh 1,1 path: no mesh object, one fast device, the
    # global paged-KV block pool backing the decode caches
    meshed = FiddlerEngine(cfg, params, mesh=make_serving_mesh("1,1"),
                           n_fast_devices=1, kv_global_pool=True, **kw)
    return cfg, plain, meshed


def test_1x1_mesh_bit_identical_prefill_decode(mixtral):
    cfg, plain, meshed = _twin_engines(mixtral)
    tokens = jax.random.randint(jax.random.PRNGKey(3), (2, 10), 3,
                                cfg.vocab_size)
    outs = {}
    for name, eng in (("plain", plain), ("mesh", meshed)):
        rows = []
        logits, caches = eng.prefill(tokens, max_seq=32)
        rows.append(np.asarray(logits))
        for step in range(2):
            logits, caches = eng.decode_step(
                caches, tokens[:, :1], pos=tokens.shape[1] + step, max_seq=32)
            rows.append(np.asarray(logits))
        outs[name] = np.stack(rows)
    np.testing.assert_array_equal(outs["plain"], outs["mesh"])


def test_1x1_mesh_bit_identical_beam(mixtral):
    cfg, plain, meshed = _twin_engines(mixtral)
    results = {}
    for name, eng in (("plain", plain), ("mesh", meshed)):
        serving = ContinuousEngine(FiddlerBackend(eng, max_seq=32),
                                   n_slots=4, max_seq=32)
        serving.submit(Request(rid="b", prompt=[1, 7, 4, 5],
                               max_new_tokens=5, beam_width=2))
        done = serving.run(max_steps=2_000, on_exhausted="raise")
        assert len(done) == 1
        results[name] = done[0]
    np.testing.assert_array_equal(results["plain"].beam_tokens,
                                  results["mesh"].beam_tokens)
    np.testing.assert_array_equal(results["plain"].beam_scores,
                                  results["mesh"].beam_scores)
