"""Paper Table 2 (Appendix B): distribution of |SiLU(x·W_gate)| activations
across layers — the evidence that Mixtral-style models are NOT
ReLU-sparse, so sparsity-offloading (PowerInfer/LLM-in-a-flash) doesn't
transfer and Fiddler's approach is needed.

We run a reduced Mixtral on synthetic ShareGPT-like data and report the
fraction of post-SiLU values under each threshold, per layer.
"""
import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import emit
from repro.configs import get_config
from repro.data.pipeline import sample_prompts
from repro.models import Model
from repro.models.layers import rmsnorm
from repro.models.moe import route

THRESHOLDS = [1e-3, 1e-2, 1e-1, 1.0]


def run(n_samples: int = 8, fast: bool = False):
    cfg = get_config("mixtral-8x7b").reduced()
    model = Model(cfg, param_dtype=jnp.float32)
    params = model.init(jax.random.PRNGKey(0))
    prompts = sample_prompts(cfg, n=2 if fast else n_samples, min_tokens=64)

    blocks = params["blocks"][0]
    tokens = jnp.asarray(prompts)
    x = model.embed(params, tokens)
    rows = []
    for li in range(cfg.n_layers):
        p = jax.tree.map(lambda a, i=li: a[i], blocks)
        # post-SiLU activations of the routed experts' gate projection
        normed = rmsnorm(p["norm2"], x, cfg.norm_eps).reshape(-1, cfg.d_model)
        gates, idx, _ = route(p["moe"]["router"], normed, cfg.moe)
        acts = []
        for e in range(cfg.moe.n_experts):
            mask = np.asarray((idx == e).any(axis=1))
            if mask.sum() == 0:
                continue
            h = jax.nn.silu(normed[mask] @ p["moe"]["w_gate"][e])
            acts.append(np.abs(np.asarray(h)).reshape(-1))
        a = np.concatenate(acts)
        fr = {t: float((a < t).mean()) for t in THRESHOLDS}
        rows.append(fr)
        emit(f"sparsity/layer{li}", 0.0,
             " ".join(f"<{t:g}:{fr[t]*100:.2f}%" for t in THRESHOLDS))
        # advance x through the layer for the next layer's stats
        from repro.models.model import apply_sublayer, NO_PARALLEL
        positions = jnp.broadcast_to(jnp.arange(x.shape[1])[None],
                                     x.shape[:2])
        x, _, _ = apply_sublayer(p, x, positions, cfg, 0, li, NO_PARALLEL,
                                 mode="train", cache=None, max_seq=None)
    # paper's conclusion: almost no exact zeros, most values not tiny
    mean_under_001 = float(np.mean([r[1e-3] for r in rows]))
    emit("sparsity/mean_under_1e-3", 0.0,
         f"{mean_under_001*100:.2f}% (paper: <2% every layer)")
    assert mean_under_001 < 0.10
    return rows


if __name__ == "__main__":
    run()
