"""Back-compat: the PR-8 ``plan()`` scheduler redesign must not change
a single scheduling decision for policies written against the old
three-hook protocol.

``ThirdPartySJF`` below implements ONLY ``admission_order`` /
``preempt`` / ``target_slots`` — the pre-redesign ``SchedulerPolicy``
surface, exactly as an out-of-tree policy would.  ``_GOLDEN`` is the
trace that policy produced on the PRE-redesign engines (captured before
the ``plan()`` seam landed): per-request outputs, TTFT, latency, every
per-token timestamp, plus the orchestrator ledger.  The test replays
the identical workload through the redesigned engine and requires
**exact float equality** — not tolerance — because the default
``plan()`` is documented to reproduce the legacy interleaved schedule
bit-for-bit.

Also pinned here: the deprecated ``ServingBackend.prefill`` surface
warns but still returns exactly what ``prefill_chunk(None, prompt, 0)``
returns.
"""
import json
import math
import warnings

import numpy as np
import pytest

from repro.configs import get_config
from repro.core import FiddlerEngine, HardwareSpec
from repro.serving.backend import SimulatedBackend
from repro.serving.continuous import ContinuousEngine
from repro.serving.engine import Request
from repro.serving.policy import SchedulerPolicy, StepPlan


class ThirdPartySJF(SchedulerPolicy):
    """Old-protocol-only policy: shortest-job-first admission, preempt
    the longest-running decode when a shorter arrived job waits without
    a free slot, pool pinned at 3 slots.  Deliberately does NOT override
    ``plan`` — the default must assemble it from these three hooks."""

    name = "third-party-sjf"

    def admission_order(self, view):
        arrived = sorted(
            view.arrived_queue(),
            key=lambda q: (q.prompt_len + q.max_new_tokens, q.index))
        return [q.index for q in arrived]

    def preempt(self, view):
        waiters = view.arrived_queue()
        if not waiters or view.free_live_slots() > 0:
            return ()
        shortest = min(q.prompt_len + q.max_new_tokens for q in waiters)
        decoding = [s for s in view.slots[: view.slot_limit]
                    if s.phase == "decode"]
        victims = [s for s in decoding
                   if (s.prompt_len + s.emitted + s.steps_left)
                   > shortest + 8]
        victims.sort(key=lambda s: s.started if s.started is not None
                     else math.inf)
        return [victims[0].index] if victims else ()

    def target_slots(self, view):
        return 3


# captured from the pre-plan() engines; see module docstring
_GOLDEN = json.loads(r'''
{
 "_ledger": {
  "fast_hits": 2186,
  "sim_time": 27.26394488254534,
  "slow_runs": 6823,
  "streams": 0,
  "tokens_out": 95
 },
 "a": {
  "latency": 22.804560304587856,
  "output": [
   5,
   5,
   5,
   5,
   5,
   5,
   5,
   5,
   5,
   5,
   5,
   5,
   5,
   5,
   5,
   5,
   5,
   5,
   5,
   5,
   5,
   5,
   5,
   5
  ],
  "preemptions": 0,
  "token_times": [
   6.41148020461655,
   7.442276296143586,
   8.554124919236333,
   8.95958019161494,
   9.992814335094488,
   11.136929216416688,
   11.546723655187092,
   12.005578821574206,
   12.452235755627251,
   13.470295409497423,
   14.609533918724095,
   15.033429240267829,
   15.464371577674422,
   15.896937015620795,
   16.32381425082079,
   16.743917169009794,
   17.21334568738167,
   17.6480856944287,
   18.087700488809375,
   18.49343470156366,
   19.521537157714885,
   20.55966815328998,
   21.616238417839735,
   22.804560304587856
  ],
  "ttft": 6.41148020461655
 },
 "b": {
  "latency": 8.909580191614939,
  "output": [
   5,
   5,
   5,
   5,
   5,
   5
  ],
  "preemptions": 0,
  "token_times": [
   4.3328232182757365,
   4.628213533176839,
   5.693697355838235,
   7.442276296143586,
   8.554124919236333,
   8.95958019161494
  ],
  "ttft": 4.282823218275737
 },
 "c": {
  "latency": 27.163944882545337,
  "output": [
   5,
   5,
   5,
   5,
   5,
   5,
   5,
   5,
   5,
   5,
   5,
   5,
   5,
   5,
   5,
   5,
   5,
   5,
   5,
   5,
   5,
   5,
   5,
   5,
   5,
   5,
   5,
   5,
   5,
   5,
   5,
   5
  ],
  "preemptions": 0,
  "token_times": [
   14.180221923000133,
   14.609533918724095,
   15.033429240267829,
   15.464371577674422,
   15.896937015620795,
   16.32381425082079,
   16.743917169009794,
   17.21334568738167,
   17.6480856944287,
   18.087700488809375,
   18.49343470156366,
   19.521537157714885,
   20.55966815328998,
   21.616238417839735,
   22.804560304587856,
   23.13710102733776,
   23.45934402266905,
   23.732527265621734,
   24.047773531431524,
   24.3995610627593,
   24.703374955973082,
   25.04350750714672,
   25.35873656420516,
   25.662008570572347,
   26.00024824806862,
   26.177211171446785,
   26.33577304886092,
   26.516531106260476,
   26.707581784849797,
   26.888340232344593,
   27.083186044955305,
   27.26394488254534
  ],
  "ttft": 14.080221923000133
 },
 "d": {
  "latency": 5.343697355838235,
  "output": [
   5,
   5,
   5,
   5
  ],
  "preemptions": 0,
  "token_times": [
   2.179073347929083,
   3.093851417275883,
   4.628213533176839,
   5.693697355838235
  ],
  "ttft": 1.829073347929083
 },
 "e": {
  "latency": 18.093434701563663,
  "output": [
   5,
   5,
   5,
   5,
   5,
   5,
   5,
   5,
   5,
   5,
   5,
   5,
   5,
   5,
   5,
   5
  ],
  "preemptions": 0,
  "token_times": [
   10.706804000327605,
   11.136929216416688,
   11.546723655187092,
   12.005578821574206,
   12.452235755627251,
   13.470295409497423,
   14.609533918724095,
   15.033429240267829,
   15.464371577674422,
   15.896937015620795,
   16.32381425082079,
   16.743917169009794,
   17.21334568738167,
   17.6480856944287,
   18.087700488809375,
   18.49343470156366
  ],
  "ttft": 10.306804000327604
 },
 "f": {
  "latency": 12.002235755627252,
  "output": [
   5,
   5,
   5,
   5,
   5,
   5,
   5,
   5
  ],
  "preemptions": 0,
  "token_times": [
   8.124272597046739,
   8.554124919236333,
   8.95958019161494,
   9.992814335094488,
   11.136929216416688,
   11.546723655187092,
   12.005578821574206,
   12.452235755627251
  ],
  "ttft": 7.6742725970467385
 },
 "g": {
  "latency": 25.50024824806862,
  "output": [
   5,
   5,
   5,
   5,
   5,
   5,
   5,
   5,
   5,
   5,
   5,
   5
  ],
  "preemptions": 0,
  "token_times": [
   22.347588649592403,
   22.804560304587856,
   23.13710102733776,
   23.45934402266905,
   23.732527265621734,
   24.047773531431524,
   24.3995610627593,
   24.703374955973082,
   25.04350750714672,
   25.35873656420516,
   25.662008570572347,
   26.00024824806862
  ],
  "ttft": 21.847588649592403
 }
}
''')


def _run_workload():
    cfg = get_config("mixtral-8x7b")
    eng = FiddlerEngine(cfg, policy="fiddler",
                        hw=HardwareSpec.paper_env1(), seed=0)
    serving = ContinuousEngine(SimulatedBackend(eng, max_seq=256),
                               n_slots=4, max_seq=256, prefill_chunk=8,
                               policy=ThirdPartySJF())
    specs = [
        # (rid, prompt_len, max_new, arrival, slo) — a mix that exercises
        # admission reordering, head-of-line arrivals, preemption and the
        # pinned 3-slot pool inside a 4-slot engine
        ("a", 40, 24, 0.0, "batch"),
        ("b", 12, 6, 0.05, "interactive"),
        ("c", 64, 32, 0.1, "batch"),
        ("d", 8, 4, 0.35, "interactive"),
        ("e", 48, 16, 0.4, "standard"),
        ("f", 16, 8, 0.45, "interactive"),
        ("g", 96, 12, 0.5, "batch"),
    ]
    for rid, plen, mnew, arr, slo in specs:
        prompt = [1] + [3 + (i * 7 + len(rid)) % 200
                        for i in range(plen - 1)]
        serving.submit(Request(rid=rid, prompt=prompt, max_new_tokens=mnew,
                               arrival=arr, slo_class=slo))
    done = serving.run(max_steps=50_000, on_exhausted="raise")
    return eng, done


def test_three_hook_policy_schedules_bit_identically():
    eng, done = _run_workload()
    assert len(done) == len(_GOLDEN) - 1  # minus the _ledger entry
    for r in done:
        g = _GOLDEN[r.rid]
        # exact equality everywhere: same admissions in the same order on
        # the same simulated clock produce the same floats or the seam
        # changed behavior
        assert list(r.output) == g["output"], r.rid
        assert r.ttft == g["ttft"], (r.rid, r.ttft, g["ttft"])
        assert r.latency == g["latency"], r.rid
        assert list(r.token_times) == g["token_times"], r.rid
        assert r.preemptions == g["preemptions"], r.rid
    led = eng.ledger
    g = _GOLDEN["_ledger"]
    assert led.sim_time == g["sim_time"]
    assert led.tokens_out == g["tokens_out"]
    assert led.fast_hits == g["fast_hits"]
    assert led.slow_runs == g["slow_runs"]
    assert led.streams == g["streams"]
    # a legacy policy must leave the per-stream disaggregation fields
    # untouched — they exist only for overlap-planning policies
    assert led.prefill_stream_time == 0.0
    assert led.decode_stream_time == 0.0
    assert led.prefill_stream_overlapped == 0.0
    assert led.decode_stream_exposed == 0.0


def test_default_plan_is_assembled_from_legacy_hooks():
    """The default ``plan()`` forwards the three hooks verbatim and keeps
    the legacy interleaved phase semantics (no phase restriction, no
    per-slot chunks, no overlap)."""
    cfg = get_config("mixtral-8x7b")
    eng = FiddlerEngine(cfg, policy="fiddler",
                        hw=HardwareSpec.paper_env1(), seed=0)
    serving = ContinuousEngine(SimulatedBackend(eng, max_seq=64),
                               n_slots=4, max_seq=64,
                               policy=ThirdPartySJF())
    for i, plen in enumerate((8, 4)):
        serving.submit(Request(rid=f"r{i}", prompt=[1] * plen,
                               max_new_tokens=2))
    view = serving._view()
    plan = serving.policy.plan(view)
    assert isinstance(plan, StepPlan)
    assert list(plan.admit) == list(
        serving.policy.admission_order(view))  # SJF: r1 before r0
    assert plan.admit[0] == 1
    assert plan.preempt == ()
    assert plan.target_slots == 3
    assert plan.prefill is None and plan.decode is None
    assert not plan.chunk_sizes
    assert plan.overlap is False


def test_legacy_prefill_warns_and_matches_prefill_chunk():
    cfg = get_config("mixtral-8x7b")
    eng = FiddlerEngine(cfg, policy="fiddler",
                        hw=HardwareSpec.paper_env1(), seed=0)
    backend = SimulatedBackend(eng, max_seq=64)
    prompt = [1, 7, 19, 4, 2, 11]

    with pytest.warns(DeprecationWarning, match="prefill_chunk"):
        legacy_logits, legacy_staging = backend.prefill(prompt)

    eng2 = FiddlerEngine(cfg, policy="fiddler",
                         hw=HardwareSpec.paper_env1(), seed=0)
    b2 = SimulatedBackend(eng2, max_seq=64)
    new_logits, new_staging = b2.prefill_chunk(None, prompt, 0)

    np.testing.assert_array_equal(np.asarray(legacy_logits),
                                  np.asarray(new_logits))
    # identical ledger charge: the wrapper IS one whole-prompt chunk
    assert eng.ledger.sim_time == eng2.ledger.sim_time
    assert legacy_staging["staged"] == new_staging["staged"]


def test_new_surface_emits_no_deprecation_warning():
    cfg = get_config("mixtral-8x7b")
    eng = FiddlerEngine(cfg, policy="fiddler",
                        hw=HardwareSpec.paper_env1(), seed=0)
    backend = SimulatedBackend(eng, max_seq=64)
    with warnings.catch_warnings():
        warnings.simplefilter("error", DeprecationWarning)
        _, staging = backend.prefill_chunk(None, [1, 5, 9], 0)
        cache = backend.make_cache(2)
        cache = backend.write_slot(cache, staging, 0)
        cache = backend.resize_cache(cache, n_slots=3)
        cache = backend.fork_slot(cache, src=0, dst=1)
        cache = backend.reorder_slots(cache, slots=[0, 1], src_of=[1, 0])
        cache = backend.release_slot(cache, slot=1)
        cache = backend.release_slot(cache, slot=0)
