"""Kernel correctness: Pallas (interpret=True) and host kernels vs the
pure-jnp oracles in ref.py, swept over shapes and dtypes."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import ref
from repro.kernels.expert_mlp import expert_mlp
from repro.kernels.host_expert import HostExpert, host_expert_mlp, to_bf16
from repro.kernels.moe_gmm import moe_gmm
from repro.kernels.ops import expert_mlp_op

SHAPES = [(8, 64, 128), (64, 128, 256), (130, 256, 640), (1, 128, 128),
          (257, 128, 384)]
DTYPES = [jnp.float32, jnp.bfloat16]


def _tol(dtype):
    return dict(rtol=2e-2, atol=2e-2) if dtype == jnp.bfloat16 else \
        dict(rtol=2e-5, atol=2e-5)


@pytest.mark.parametrize("s,d,f", SHAPES)
@pytest.mark.parametrize("dtype", DTYPES)
def test_expert_mlp_pallas_vs_ref(s, d, f, dtype):
    k = jax.random.split(jax.random.PRNGKey(s * 7 + d), 4)
    x = (jax.random.normal(k[0], (s, d)) * 0.1).astype(dtype)
    wg = (jax.random.normal(k[1], (d, f)) * 0.05).astype(dtype)
    wu = (jax.random.normal(k[2], (d, f)) * 0.05).astype(dtype)
    wd = (jax.random.normal(k[3], (f, d)) * 0.05).astype(dtype)
    got = expert_mlp(x, wg, wu, wd, block_s=64, block_f=128, interpret=True)
    want = ref.expert_mlp_ref(x, wg, wu, wd)
    np.testing.assert_allclose(np.asarray(got, np.float32),
                               np.asarray(want, np.float32), **_tol(dtype))


@pytest.mark.parametrize("E,C,d,f", [(4, 64, 128, 256), (3, 130, 96, 200),
                                     (1, 8, 128, 128), (8, 32, 64, 64)])
@pytest.mark.parametrize("dtype", DTYPES)
def test_moe_gmm_pallas_vs_ref(E, C, d, f, dtype):
    k = jax.random.split(jax.random.PRNGKey(E * 31 + C), 2)
    xs = (jax.random.normal(k[0], (E, C, d)) * 0.1).astype(dtype)
    ws = (jax.random.normal(k[1], (E, d, f)) * 0.05).astype(dtype)
    counts = jnp.asarray(
        np.random.default_rng(E).integers(0, C + 1, E), jnp.int32)
    got = moe_gmm(xs, ws, counts, block_c=32, block_f=64, block_k=64,
                  interpret=True)
    want = ref.moe_gmm_ref(xs, ws, counts)
    np.testing.assert_allclose(np.asarray(got, np.float32),
                               np.asarray(want, np.float32), **_tol(dtype))


@pytest.mark.parametrize("E,C,d,f", [(4, 32, 64, 128), (3, 17, 96, 200)])
@pytest.mark.parametrize("dtype", DTYPES)
def test_moe_gmm_mlp_pallas_vs_ref(E, C, d, f, dtype):
    from repro.kernels.moe_gmm import moe_gmm_mlp

    k = jax.random.split(jax.random.PRNGKey(E * 13 + C), 4)
    xs = (jax.random.normal(k[0], (E, C, d)) * 0.1).astype(dtype)
    wg = (jax.random.normal(k[1], (E, d, f)) * 0.05).astype(dtype)
    wu = (jax.random.normal(k[2], (E, d, f)) * 0.05).astype(dtype)
    wd = (jax.random.normal(k[3], (E, f, d)) * 0.05).astype(dtype)
    counts = jnp.asarray(
        np.random.default_rng(E).integers(0, C + 1, E), jnp.int32)
    got = moe_gmm_mlp(xs, wg, wu, wd, counts, block_c=16, block_f=64,
                      block_k=64, interpret=True)
    want = ref.grouped_gated_mlp_ref(xs, wg, wu, wd, counts)
    np.testing.assert_allclose(np.asarray(got, np.float32),
                               np.asarray(want, np.float32), **_tol(dtype))


def test_grouped_gated_mlp_bit_identical_to_per_expert():
    """The grouped fast-tier MLP must reproduce the per-expert op bit for
    bit on fp32 — the orchestrator's grouped dispatch rewrite (and its
    pre/post-change equivalence guarantee) rests on this."""
    from repro.kernels.ops import grouped_gated_mlp_op, grouped_gather_mlp_op

    E, C, d, f = 4, 8, 32, 64
    k = jax.random.split(jax.random.PRNGKey(5), 4)
    wg = jax.random.normal(k[0], (E, d, f)) * 0.05
    wu = jax.random.normal(k[1], (E, d, f)) * 0.05
    wd = jax.random.normal(k[2], (E, f, d)) * 0.05
    counts = np.array([1, 8, 3, 5], np.int32)
    xs = np.zeros((E, C, d), np.float32)
    rng = np.random.default_rng(0)
    for e in range(E):
        xs[e, :counts[e]] = rng.standard_normal((counts[e], d)) * 0.1
    out = np.asarray(grouped_gated_mlp_op(
        jnp.asarray(xs), wg, wu, wd, jnp.asarray(counts), use_pallas=False))
    gathered = np.asarray(grouped_gather_mlp_op(
        jnp.asarray(xs), jnp.arange(E, dtype=jnp.int32), wg, wu, wd,
        jnp.asarray(counts), use_pallas=False))
    np.testing.assert_array_equal(out, gathered)
    for e in range(E):
        want = np.asarray(expert_mlp_op(
            jnp.asarray(xs[e, :counts[e]]), wg[e], wu[e], wd[e],
            use_pallas=False))
        np.testing.assert_array_equal(out[e, :counts[e]], want)
        np.testing.assert_array_equal(out[e, counts[e]:], 0.0)


@pytest.mark.parametrize("s,d,f", SHAPES[:3])
def test_host_expert_vs_ref(s, d, f):
    rng = np.random.default_rng(0)
    x = rng.standard_normal((s, d)).astype(np.float32) * 0.1
    wg = rng.standard_normal((d, f)).astype(np.float32) * 0.05
    wu = rng.standard_normal((d, f)).astype(np.float32) * 0.05
    wd = rng.standard_normal((f, d)).astype(np.float32) * 0.05
    got = host_expert_mlp(x, wg, wu, wd, block_f=96)
    want = np.asarray(ref.expert_mlp_ref(
        jnp.asarray(x), jnp.asarray(wg), jnp.asarray(wu), jnp.asarray(wd)))
    # bf16-emulated weights/activations → bf16-level agreement
    np.testing.assert_allclose(got, want, rtol=5e-2, atol=5e-2)
    # fp32 mode is exact up to blocking order
    exact = HostExpert(wg, wu, wd, block_f=96, precision="fp32")(x)
    np.testing.assert_allclose(exact, want, rtol=2e-5, atol=2e-5)


def test_to_bf16_round_nearest_even():
    vals = np.array([1.0, 1.0 + 2**-9, -3.14159, 65504.0, 1e-8], np.float32)
    got = to_bf16(vals)
    want = np.asarray(jnp.asarray(vals).astype(jnp.bfloat16).astype(jnp.float32))
    np.testing.assert_array_equal(got, want)


def test_ops_fallback_matches_pallas():
    k = jax.random.split(jax.random.PRNGKey(0), 4)
    x = jax.random.normal(k[0], (32, 128)) * 0.1
    wg = jax.random.normal(k[1], (128, 256)) * 0.05
    wu = jax.random.normal(k[2], (128, 256)) * 0.05
    wd = jax.random.normal(k[3], (256, 128)) * 0.05
    a = expert_mlp_op(x, wg, wu, wd, use_pallas=False)
    b = expert_mlp_op(x, wg, wu, wd, use_pallas=True)
    np.testing.assert_allclose(np.asarray(a), np.asarray(b), rtol=2e-5,
                               atol=2e-5)


@pytest.mark.parametrize("B,S,H,hd", [(1, 64, 2, 32), (2, 100, 2, 32),
                                      (1, 33, 1, 64)])
@pytest.mark.parametrize("window,cap", [(None, None), (16, None),
                                        (None, 5.0)])
@pytest.mark.parametrize("dtype", DTYPES)
def test_flash_attention_pallas_vs_ref(B, S, H, hd, window, cap, dtype):
    from repro.kernels.flash_attention import flash_attention

    ks = jax.random.split(jax.random.PRNGKey(S * 3 + H), 3)
    q = (jax.random.normal(ks[0], (B, S, H, hd)) * 0.3).astype(dtype)
    k = (jax.random.normal(ks[1], (B, S, H, hd)) * 0.3).astype(dtype)
    v = (jax.random.normal(ks[2], (B, S, H, hd)) * 0.3).astype(dtype)
    got = flash_attention(q, k, v, causal=True, window=window,
                          attn_softcap=cap, block_q=32, block_k=32,
                          interpret=True)
    want = ref.flash_attention_ref(q, k, v, causal=True, window=window,
                                   attn_softcap=cap)
    np.testing.assert_allclose(np.asarray(got, np.float32),
                               np.asarray(want, np.float32), **_tol(dtype))


def test_flash_attention_matches_model_chunked():
    """The Pallas kernel and the model's chunked_attention agree."""
    from repro.kernels.flash_attention import flash_attention
    from repro.models.attention import chunked_attention

    B, S, H, hd = 2, 48, 2, 32
    ks = jax.random.split(jax.random.PRNGKey(9), 3)
    q = jax.random.normal(ks[0], (B, S, H, hd)) * 0.3
    k = jax.random.normal(ks[1], (B, S, H, hd)) * 0.3
    v = jax.random.normal(ks[2], (B, S, H, hd)) * 0.3
    pos = jnp.broadcast_to(jnp.arange(S)[None], (B, S))
    a = flash_attention(q, k, v, causal=True, window=16, block_q=16,
                        block_k=16, interpret=True)
    b = chunked_attention(q, k, v, pos, pos, causal=True, window=16,
                          kv_chunk=16)
    np.testing.assert_allclose(np.asarray(a), np.asarray(b), rtol=2e-5,
                               atol=2e-5)


def test_flash_attention_ref_matches_naive():
    # ref.py's flash oracle vs an independent dense construction
    k = jax.random.split(jax.random.PRNGKey(1), 3)
    B, S, H, hd = 2, 33, 4, 32
    q = jax.random.normal(k[0], (B, S, H, hd)) * 0.3
    kk = jax.random.normal(k[1], (B, S, H, hd)) * 0.3
    v = jax.random.normal(k[2], (B, S, H, hd)) * 0.3
    out = ref.flash_attention_ref(q, kk, v, causal=True, window=8)
    # naive loop check at a few positions
    for (b, t, h) in [(0, 0, 0), (1, 17, 2), (0, 32, 3)]:
        lo = max(0, t - 8 + 1)
        s = np.asarray(q)[b, t, h] @ np.asarray(kk)[b, lo:t + 1, h].T / np.sqrt(hd)
        p = np.exp(s - s.max())
        p /= p.sum()
        want = p @ np.asarray(v)[b, lo:t + 1, h]
        np.testing.assert_allclose(np.asarray(out)[b, t, h], want,
                                   rtol=2e-5, atol=2e-5)
