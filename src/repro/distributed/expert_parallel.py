"""Expert-parallel fused MoE dispatch under ``shard_map`` (mesh serving).

The serving engine's per-device grouped launches (core/orchestrator.py
``_execute_grouped``) model expert parallelism one device stack at a
time — correct and bit-stable, but each launch is a separate dispatch.
This module is the fused form the mesh runs when every fast device is a
real jax device: stacked expert weights sharded over the ``model`` axis
(``E/D`` experts per device), tokens sharded over the same axis, and one
``shard_map`` body that

1. buckets each local token-assignment into a capacity-``C`` send buffer
   addressed ``(dest device, local expert, slot)``,
2. exchanges buffers with ``lax.all_to_all`` (the dispatch hop),
3. runs ONE grouped gated-MLP einsum over the device's local expert
   shard — zero-padded rows produce exactly-zero outputs, so padding
   never contaminates the combine,
4. reverses the all-to-all (the combine hop) and scatters each
   assignment's output back to its token, scaled by the router gate.

Rows beyond an expert's capacity are dropped (the classic capacity
discipline); callers that need exactness pass ``capacity`` ≥ the true
max bucket size — ``expert_parallel_moe`` defaults to computing that
bound from the concrete assignments.

The cost model charges the two hops via
``core.cost_model.alltoall_time``; this module is the executable
counterpart, validated by tests/test_mesh_serving.py against the dense
reference on forced host devices.
"""
from __future__ import annotations

from typing import Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental.shard_map import shard_map
from jax.sharding import NamedSharding, PartitionSpec as P


def expert_shard_spec(axis: str = "model") -> P:
    """PartitionSpec of a stacked expert weight triple ``(E, d, f)`` /
    ``(E, f, d)``: experts sharded over the mesh's model axis."""
    return P(axis, None, None)


def mesh_model_size(mesh, axis: str = "model") -> int:
    return int(dict(zip(mesh.axis_names, mesh.devices.shape)).get(axis, 1))


def check_expert_divisibility(n_experts: int, mesh, axis: str = "model"
                              ) -> int:
    """Experts per device, asserting the shard is exact — a ragged expert
    shard would silently skew the all-to-all load."""
    D = mesh_model_size(mesh, axis)
    assert n_experts % D == 0, (
        f"{n_experts} experts do not shard evenly over {axis}={D}")
    return n_experts // D


def shard_expert_stack(mesh, wg: jnp.ndarray, wu: jnp.ndarray,
                       wd: jnp.ndarray, axis: str = "model"
                       ) -> Tuple[jnp.ndarray, jnp.ndarray, jnp.ndarray]:
    """Place a stacked expert triple on the mesh, experts sharded over
    ``axis`` (round-trips through ``expert_shard_spec``)."""
    check_expert_divisibility(wg.shape[0], mesh, axis)
    sh = NamedSharding(mesh, expert_shard_spec(axis))
    return (jax.device_put(wg, sh), jax.device_put(wu, sh),
            jax.device_put(wd, sh))


def pad_tokens(x: np.ndarray, idx: np.ndarray, gates: np.ndarray, d: int
               ) -> Tuple[np.ndarray, np.ndarray, np.ndarray, int]:
    """Pad the token dim to a multiple of ``d`` with zero-gated rows
    routed to expert 0 (their outputs are scaled by gate 0, so padding
    never changes the combine).  Returns the padded triple + original T."""
    T = x.shape[0]
    pad = (-T) % d
    if pad == 0:
        return x, idx, gates, T
    x = np.concatenate([x, np.zeros((pad,) + x.shape[1:], x.dtype)])
    idx = np.concatenate([idx, np.zeros((pad,) + idx.shape[1:], idx.dtype)])
    gates = np.concatenate(
        [gates, np.zeros((pad,) + gates.shape[1:], gates.dtype)])
    return x, idx, gates, T


def expert_parallel_moe(mesh, x, idx, gates, wg, wu, wd, *,
                        axis: str = "model",
                        capacity: Optional[int] = None,
                        act=jax.nn.silu) -> jnp.ndarray:
    """Fused expert-parallel MoE layer: ``x`` (T, d) tokens, ``idx`` /
    ``gates`` (T, k) router output, ``wg``/``wu`` (E, d, f) and ``wd``
    (E, f, d) stacked over ALL experts.  Returns (T, d) ==
    ``sum_k gates[t, k] · MLP_{idx[t, k]}(x[t])``.

    T must divide by the mesh's ``axis`` size (see :func:`pad_tokens`);
    experts must too (:func:`check_expert_divisibility`).
    """
    D = mesh_model_size(mesh, axis)
    E = int(wg.shape[0])
    e_loc = check_expert_divisibility(E, mesh, axis)
    T, k = idx.shape
    assert T % D == 0, f"{T} tokens do not shard evenly over {axis}={D}"
    if capacity is None:
        # exact per-(source, expert) worst case from the concrete routing
        counts = np.bincount(np.asarray(idx).reshape(-1), minlength=E)
        capacity = max(int(counts.max()), 1)
    C = int(capacity)
    dmodel = int(x.shape[1])

    def body(xs, idxs, gs, wg_l, wu_l, wd_l):
        tl = xs.shape[0]
        flat_e = idxs.reshape(-1)                       # (tl·k,)
        dest = flat_e // e_loc                          # target device
        loc = flat_e % e_loc                            # local expert there
        # slot within each (dest, loc) bucket: running count via one-hot
        onehot = jax.nn.one_hot(flat_e, E, dtype=jnp.int32)
        slot = (jnp.cumsum(onehot, axis=0) * onehot).sum(axis=1) - 1
        rows = jnp.repeat(jnp.arange(tl), k)
        buf = jnp.zeros((D, e_loc, C, dmodel), xs.dtype)
        # over-capacity writes fall out of bounds and are dropped
        buf = buf.at[dest, loc, slot].set(xs[rows], mode="drop")
        recv = jax.lax.all_to_all(buf, axis, 0, 0, tiled=True)
        hs = recv.transpose(1, 0, 2, 3).reshape(e_loc, D * C, dmodel)
        a = jnp.einsum("ecd,edf->ecf", hs, wg_l)
        u = jnp.einsum("ecd,edf->ecf", hs, wu_l)
        ys = jnp.einsum("ecf,efd->ecd", act(a) * u, wd_l)
        ys = ys.reshape(e_loc, D, C, dmodel).transpose(1, 0, 2, 3)
        back = jax.lax.all_to_all(ys, axis, 0, 0, tiled=True)
        ye = back[dest, loc, jnp.clip(slot, 0, C - 1)]
        keep = (slot < C)[:, None]
        ye = jnp.where(keep, ye, 0.0)
        out = jnp.zeros_like(xs)
        return out.at[rows].add(gs.reshape(-1)[:, None] * ye)

    tok = P(axis, None)
    wspec = expert_shard_spec(axis)
    fn = shard_map(body, mesh=mesh,
                   in_specs=(tok, tok, tok, wspec, wspec, wspec),
                   out_specs=tok, check_rep=False)
    return fn(jnp.asarray(x), jnp.asarray(idx, jnp.int32),
              jnp.asarray(gates), jnp.asarray(wg), jnp.asarray(wu),
              jnp.asarray(wd))


def dense_reference_moe(x, idx, gates, wg, wu, wd, act=jax.nn.silu
                        ) -> jnp.ndarray:
    """Unsharded reference for the fused path (tests): the same combine,
    one expert at a time."""
    x = jnp.asarray(x)
    idx_np = np.asarray(idx)
    gates = jnp.asarray(gates)
    out = jnp.zeros_like(x)
    for e in np.unique(idx_np.reshape(-1)):
        rows, kpos = np.nonzero(idx_np == e)
        xe = x[rows]
        ye = (act(xe @ wg[e]) * (xe @ wu[e])) @ wd[e]
        out = out.at[rows].add(gates[rows, kpos][:, None] * ye)
    return out
