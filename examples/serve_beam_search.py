"""Serving + beam-search demo (the paper's scenario ⓒ, 11.57× result).

Serves batched requests through the ServingEngine, then runs beam search
over the Fiddler orchestrator with increasing widths and shows how the
planner's decisions shift from slow-tier execution to weight streaming as
per-expert input sizes grow (paper §3.2).

    PYTHONPATH=src python examples/serve_beam_search.py
"""
import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config
from repro.core import FiddlerEngine, HardwareSpec
from repro.data.tokenizer import ByteTokenizer
from repro.models import Model
from repro.serving.beam_search import beam_search_fiddler
from repro.serving.engine import Request, ServingEngine


def main():
    cfg = get_config("mixtral-8x7b").reduced()
    full = get_config("mixtral-8x7b")
    model = Model(cfg, param_dtype=jnp.float32)
    params = model.init(jax.random.PRNGKey(0))
    tok = ByteTokenizer(cfg.vocab_size)

    # --- batched serving --------------------------------------------------
    print("== batched serving through the orchestrator ==")
    fe = FiddlerEngine(cfg, params, policy="fiddler", expert_budget=40,
                       timing_cfg=full, hw=HardwareSpec.paper_env1())
    eng = ServingEngine(fe, mode="fiddler", max_batch=4, max_seq=96)
    for i, text in enumerate(["USER: hi", "USER: what is moe?",
                              "USER: explain experts", "USER: fast inference",
                              "USER: how to serve?"]):
        eng.submit(Request(rid=f"r{i}", prompt=tok.encode(text),
                           max_new_tokens=8))
    for r in eng.run():
        print(f"  {r.rid}: ttft={r.ttft*1e3:7.1f}ms "
              f"latency={r.latency*1e3:7.1f}ms (simulated) "
              f"out={tok.decode(r.output)!r}")

    # --- beam search, width sweep ------------------------------------------
    print("== beam search: planner decisions vs width ==")
    prompt = np.asarray([tok.encode("USER: tell me about")], np.int32)
    n_total = cfg.n_layers * cfg.moe.n_experts
    for width in (1, 4, 8, 16):
        # small fast-tier budget (1/4 of experts) so the planner has real
        # choices; latency constants come from the FULL-size model
        fe = FiddlerEngine(cfg, params, policy="fiddler",
                           expert_budget=n_total // 4,
                           timing_cfg=full, hw=HardwareSpec.paper_env1())
        res = beam_search_fiddler(fe, prompt, width=width, n_new=6,
                                  max_seq=96)
        led = fe.ledger
        total = max(led.fast_hits + led.streams + led.slow_runs, 1)
        print(f"  width={width:2d}  best={res.scores[0]:8.3f} "
              f"sim={led.sim_time*1e3:8.1f}ms  "
              f"decisions: resident={led.fast_hits/total:.0%} "
              f"stream={led.streams/total:.0%} slow={led.slow_runs/total:.0%}")


if __name__ == "__main__":
    main()
