"""FID005 unsynchronized-host-pool-state.

The slow tier runs expert FFNs on a ``ThreadPoolExecutor`` while the
main thread keeps scheduling: any state touched by both sides needs a
lock.  Two patterns:

* **check-then-set lazy init of a module global** —
  ``if G is None: G = make()`` under a ``global G`` declaration without
  a surrounding ``with <lock>:``.  Two threads can interleave between
  the check and the set and construct the resource twice (the
  ``_HOST_POOL`` bug).  The double-checked idiom (re-check inside
  ``with lock:``) passes, because the *assignment* sits under the lock.
* **worker-reachable unsynchronized writes** — functions reachable from
  the configured worker entry points (the callables the pool executes)
  that assign to ``self.<attr>`` or to a declared ``global`` outside a
  ``with <lock>:`` block.  Reads are not flagged (GIL-atomic loads of
  a reference are the tolerated idiom here); unprotected read-modify-
  write is where the corruption lives.

A context manager counts as a lock when its expression names something
containing "lock" (``self._lock``, ``_POOL_LOCK``, ``threading.Lock``
instances by convention) — a naming-convention check, stated as such.
"""
from __future__ import annotations

import ast
from typing import List, Optional, Set

from repro.analysis.config import FiddlintConfig
from repro.analysis.core import Finding, relpath
from repro.analysis.project import FunctionInfo, Project, attr_chain


def _is_lockish(expr: ast.AST) -> bool:
    chain = attr_chain(expr)
    if not chain:
        return False
    return any("lock" in part.lower() for part in chain)


def _lock_guarded(node: ast.AST, ancestors) -> bool:
    for anc in ancestors.get(id(node), []):
        if isinstance(anc, (ast.With, ast.AsyncWith)):
            for item in anc.items:
                if _is_lockish(item.context_expr):
                    return True
    return False


def _ancestor_map(root: ast.AST):
    """{id(node): [ancestors innermost-last]} for every node under root."""
    out = {}

    def walk(node, stack):
        out[id(node)] = list(stack)
        stack.append(node)
        for child in ast.iter_child_nodes(node):
            walk(child, stack)
        stack.pop()

    walk(root, [])
    return out


def _global_names(fn_node: ast.AST) -> Set[str]:
    names: Set[str] = set()
    for node in ast.walk(fn_node):
        if isinstance(node, ast.Global):
            names.update(node.names)
    return names


def _check_lazy_init(fn: FunctionInfo, path: str,
                     out: List[Finding]) -> None:
    globals_ = _global_names(fn.node)
    if not globals_:
        return
    anc = _ancestor_map(fn.node)
    for node in ast.walk(fn.node):
        if not isinstance(node, ast.If):
            continue
        checked = _none_checked_name(node.test)
        if checked is None or checked not in globals_:
            continue
        for inner in ast.walk(node):
            if (isinstance(inner, ast.Assign)
                    and any(isinstance(t, ast.Name) and t.id == checked
                            for t in inner.targets)
                    and not _lock_guarded(inner, anc)):
                out.append(Finding(
                    "FID005", path, node.lineno, node.col_offset,
                    f"check-then-set race on module global `{checked}`: "
                    f"two threads can pass the `is None` check before "
                    f"either assigns; use double-checked locking "
                    f"(`with <lock>:` re-check, then assign)",
                    fn.qualname))
                break


def _none_checked_name(test: ast.AST) -> Optional[str]:
    """`X is None` / `not X` / `X is not None` guards on a plain name."""
    if (isinstance(test, ast.Compare) and isinstance(test.left, ast.Name)
            and len(test.ops) == 1
            and isinstance(test.ops[0], (ast.Is, ast.Eq))
            and isinstance(test.comparators[0], ast.Constant)
            and test.comparators[0].value is None):
        return test.left.id
    if (isinstance(test, ast.UnaryOp) and isinstance(test.op, ast.Not)
            and isinstance(test.operand, ast.Name)):
        return test.operand.id
    return None


def _check_worker_writes(fn: FunctionInfo, path: str, root: str,
                         out: List[Finding]) -> None:
    anc = _ancestor_map(fn.node)
    globals_ = _global_names(fn.node)
    via = "" if fn.qualname == root else f" (reachable from {root})"
    for node in ast.walk(fn.node):
        if not isinstance(node, (ast.Assign, ast.AugAssign)):
            continue
        targets = (node.targets if isinstance(node, ast.Assign)
                   else [node.target])
        for t in targets:
            label = None
            if (isinstance(t, ast.Attribute)
                    and isinstance(t.value, ast.Name)
                    and t.value.id == "self"):
                label = f"self.{t.attr}"
            elif isinstance(t, ast.Name) and t.id in globals_:
                label = f"global `{t.id}`"
            if label is None:
                continue
            if _lock_guarded(node, anc):
                continue
            out.append(Finding(
                "FID005", path, node.lineno, node.col_offset,
                f"unsynchronized write to {label} on a host-pool worker "
                f"path{via}: the main thread can observe or race this "
                f"store; guard it with a lock", fn.qualname))
            break


def check_threads(project: Project,
                  config: FiddlintConfig) -> List[Finding]:
    out: List[Finding] = []

    # (a) lazy-init races anywhere in the project
    for fn in project.functions.values():
        _check_lazy_init(fn, relpath(fn.file.path), out)

    # (b) unsynchronized writes on worker-reachable paths
    workers = project.resolve_roots(config.worker_entry_points)
    reach = project.reachable_from(workers)
    for qual, root in reach.items():
        fn = project.functions.get(qual)
        if fn is not None:
            _check_worker_writes(fn, relpath(fn.file.path), root, out)
    return out
