"""SchedulerPolicy API: scheduler invariants, preemption equivalence,
and the policy seam over every backend.

Property tests (hypothesis, with the tests/_hypothesis_fallback shim):

* FIFOPolicy reproduces the pre-redesign admission loop exactly
  (head-of-line blocking on the queue head's arrival);
* priority requests never wait behind a preemptible lower class;
* no token is lost or duplicated across preempt/re-admit;
* ledger expert counts / tokens_out only ever reflect active slots.

Plus concrete equivalence tests: a preempted request's final output
equals its unpreempted output under greedy decoding (whole-prompt and
chunked re-prefill), and FIFOPolicy runs bit-identically to the engine
default.
"""
import warnings

import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from conftest import reduced_model
from repro.configs import get_config
from repro.core import FiddlerEngine, HardwareSpec
from repro.serving.backend import FiddlerBackend, ModelBackend, SimulatedBackend
from repro.serving.continuous import ContinuousEngine
from repro.serving.engine import Request, ServingEngine
from repro.serving.policy import (
    AutoscalePolicy,
    FIFOPolicy,
    PriorityPolicy,
    QueueView,
    SchedulerView,
    SlotView,
    get_policy,
    slo_priority,
)


def _reference_generation(model, params, prompt, n_new, max_seq=64):
    logits, cache = model.prefill(params, jnp.asarray([prompt], jnp.int32),
                                  max_seq=max_seq, cache_dtype=jnp.float32)
    out = [int(jnp.argmax(logits[0]))]
    for t in range(n_new - 1):
        logits, cache = model.decode_step(
            params, cache, jnp.asarray([[out[-1]]], jnp.int32),
            jnp.int32(len(prompt) + t), max_seq=max_seq)
        out.append(int(jnp.argmax(logits[0])))
    return out


def _queue_view(i, arrival, priority=1, deadline=None, emitted=0):
    return QueueView(index=i, rid=f"q{i}", arrival=arrival,
                     priority=priority, slo_class="standard",
                     deadline=deadline, prompt_len=4, max_new_tokens=8,
                     emitted=emitted)


def _slot_view(i, rid="s", phase="decode", priority=1, started=0.0):
    return SlotView(index=i, rid=None if rid is None else f"{rid}{i}",
                    phase=phase if rid is not None else "idle",
                    priority=priority, slo_class="standard", deadline=None,
                    pos=8, prompt_len=4, emitted=4, steps_left=4,
                    started=started)


def _view(clock, queue, slots, slot_limit=None, rate=0.0):
    return SchedulerView(clock=clock, queue=tuple(queue), slots=tuple(slots),
                         slot_limit=len(slots) if slot_limit is None
                         else slot_limit,
                         max_slots=len(slots), arrival_rate=rate)


# ---------------------------------------------------------------------------
# Property: FIFO admission == pre-redesign head-of-line-blocking loop
# ---------------------------------------------------------------------------


@settings(max_examples=60, deadline=None)
@given(st.lists(st.floats(min_value=0.0, max_value=10.0), max_size=8),
       st.floats(min_value=0.0, max_value=10.0))
def test_fifo_admission_is_headblocking_prefix(arrivals, clock):
    queue = [_queue_view(i, a) for i, a in enumerate(arrivals)]
    order = list(FIFOPolicy().admission_order(
        _view(clock, queue, [_slot_view(0, rid=None)])))
    # the old loop admitted queue[0], queue[1], ... and stopped at the
    # first request whose arrival the clock had not reached
    want = []
    for i, a in enumerate(arrivals):
        if a > clock:
            break
        want.append(i)
    assert order == want


# ---------------------------------------------------------------------------
# Property: priority requests never wait behind a preemptible lower class
# ---------------------------------------------------------------------------


@settings(max_examples=60, deadline=None)
@given(st.lists(st.tuples(st.integers(min_value=0, max_value=3),
                          st.floats(min_value=0.0, max_value=5.0)),
                min_size=1, max_size=8))
def test_priority_order_never_behind_lower_class(entries):
    clock = 10.0  # everything has arrived
    queue = [_queue_view(i, a, priority=p)
             for i, (p, a) in enumerate(entries)]
    pol = PriorityPolicy()
    order = list(pol.admission_order(_view(clock, queue,
                                           [_slot_view(0, rid=None)])))
    assert sorted(order) == list(range(len(entries)))
    prios = [entries[i][0] for i in order]
    assert prios == sorted(prios, reverse=True)


@settings(max_examples=60, deadline=None)
@given(st.lists(st.integers(min_value=0, max_value=3), min_size=1,
                max_size=6),
       st.integers(min_value=0, max_value=3))
def test_priority_preempts_iff_strictly_lower_victim(slot_prios, waiter_prio):
    """With a full pool and one arrived waiter, a victim is chosen exactly
    when some decoding slot has strictly lower priority — and it is the
    longest-running such slot at the lowest priority."""
    clock = 1.0
    queue = [_queue_view(0, 0.0, priority=waiter_prio)]
    slots = [_slot_view(i, priority=p, started=float(-i))
             for i, p in enumerate(slot_prios)]
    victims = list(PriorityPolicy().preempt(_view(clock, queue, slots)))
    lower = [i for i, p in enumerate(slot_prios) if p < waiter_prio]
    if not lower:
        assert victims == []
    else:
        assert len(victims) == 1
        v = victims[0]
        assert slot_prios[v] < waiter_prio
        best = min(lower, key=lambda i: (slot_prios[i], slots[i].started))
        assert v == best
    # a free live slot absorbs the waiter instead
    slots_with_free = slots + [_slot_view(len(slots), rid=None)]
    assert list(PriorityPolicy().preempt(
        _view(clock, queue, slots_with_free))) == []


def test_slo_class_priorities():
    assert slo_priority("interactive") > slo_priority("standard") \
        > slo_priority("batch")
    assert Request(rid="r", prompt=[1], slo_class="interactive") \
        .effective_priority == slo_priority("interactive")
    assert Request(rid="r", prompt=[1], slo_class="interactive",
                   priority=0).effective_priority == 0


def test_get_policy_coercions():
    assert isinstance(get_policy(None), FIFOPolicy)
    assert isinstance(get_policy("priority"), PriorityPolicy)
    assert isinstance(get_policy(AutoscalePolicy), AutoscalePolicy)
    pol = PriorityPolicy(preemption=False)
    assert get_policy(pol) is pol
    with pytest.raises(ValueError):
        get_policy("nope")


# ---------------------------------------------------------------------------
# Property: no token lost or duplicated across preempt/re-admit (simulation)
# ---------------------------------------------------------------------------


def _sim_engine(n_slots=2, policy="fifo", max_seq=64, prefill_chunk=4,
                seed=0):
    cfg = reduced_model("mixtral-8x7b")[0]
    fe = FiddlerEngine(cfg, policy="fiddler", seed=seed)  # param-less
    return fe, ContinuousEngine(SimulatedBackend(fe, max_seq=max_seq),
                                n_slots=n_slots, max_seq=max_seq,
                                prefill_chunk=prefill_chunk, policy=policy)


@settings(max_examples=10, deadline=None)
@given(st.lists(st.tuples(st.integers(min_value=0, max_value=2),   # priority
                          st.integers(min_value=1, max_value=10),  # prompt len
                          st.integers(min_value=1, max_value=6)),  # max_new
                min_size=1, max_size=10),
       st.sampled_from(["fifo", "priority", "autoscale"]),
       st.integers(min_value=1, max_value=3))
def test_no_token_lost_or_duplicated(specs, policy, n_slots):
    fe, eng = _sim_engine(n_slots=n_slots, policy=policy)
    t = 0.0
    for i, (prio, plen, max_new) in enumerate(specs):
        t += 0.01 * (i % 3)
        eng.submit(Request(rid=f"r{i}", prompt=[1] * plen,
                           max_new_tokens=max_new, priority=prio,
                           arrival=t))
    done = eng.run(max_steps=50_000, on_exhausted="raise")
    assert sorted(r.rid for r in done) == [f"r{i}" for i in
                                           range(len(specs))]
    for r, (prio, plen, max_new) in zip(sorted(done, key=lambda r: r.rid),
                                        specs):
        # fake logits never emit EOS: exactly max_new tokens, no dup/loss
        assert len(r.output) == max_new, (r.rid, r.output)
        assert len(r.token_times) == len(r.output)
        assert (np.diff(r.token_times) > 0).all()
    # ledger charges exactly the live decodes: every token beyond each
    # request's prefill-produced first token is a decode_step_multi token
    emitted = sum(len(r.output) for r in done)
    assert fe.ledger.tokens_out == emitted - len(done)


def test_ledger_counts_only_active_slots_under_autoscale():
    """Slot-pool growth/shrink must never charge idle rows: tokens_out
    advances by exactly the live decode count even while the pool is
    resized mid-run."""
    fe, eng = _sim_engine(n_slots=6, policy=AutoscalePolicy(
        min_slots=1, service_time=0.05))
    assert eng.slot_limit == 1 and eng._alloc == 1  # cold start: minimum
    rng = np.random.default_rng(0)
    t = 0.0
    for i in range(16):
        t += float(rng.exponential(1 / 20.0))
        eng.submit(Request(rid=f"r{i}", prompt=[1, 2, 3], max_new_tokens=5,
                           arrival=t))
    done = eng.run(max_steps=50_000, on_exhausted="raise")
    assert len(done) == 16
    assert eng._alloc > 1, "autoscale never grew the pool"
    emitted = sum(len(r.output) for r in done)
    assert fe.ledger.tokens_out == emitted - len(done)


# ---------------------------------------------------------------------------
# FIFOPolicy ≡ engine default (bit-identical outputs and timings)
# ---------------------------------------------------------------------------


def test_fifo_policy_identical_to_default():
    cfg, model, params = reduced_model("qwen3-0.6b")
    prompts = [[1, 17, 23, 9], [1, 40, 11], [1, 7, 7, 7, 2, 30], [1, 300, 5]]

    def run_engine(policy):
        eng = ContinuousEngine(ModelBackend(model, params, max_seq=64),
                               n_slots=2, max_seq=64, policy=policy)
        for i, p in enumerate(prompts):
            eng.submit(Request(rid=f"r{i}", prompt=p, max_new_tokens=5,
                               arrival=float(i) * 1e-4))
        return {r.rid: r for r in eng.run()}

    a, b = run_engine(None), run_engine(FIFOPolicy())
    assert set(a) == set(b)
    for rid in a:
        assert a[rid].output == b[rid].output
        # wall clocks differ between runs; the *token sequence* and the
        # reference match is the bitwise contract here
        want = _reference_generation(model, params,
                                     prompts[int(rid[1:])], 5)
        assert a[rid].output == want[: len(a[rid].output)]


def test_fifo_policy_identical_timings_on_sim_clock():
    """On the simulated clock the FIFO policy must reproduce the default
    engine's timings exactly, not just its tokens."""
    def run_engine(policy):
        fe, eng = _sim_engine(n_slots=2, policy=policy, seed=3)
        rng = np.random.default_rng(7)
        t = 0.0
        for i in range(8):
            t += float(rng.exponential(1 / 8.0))
            eng.submit(Request(rid=f"r{i}", prompt=[1] * (3 + i % 4),
                               max_new_tokens=4, arrival=t))
        return {r.rid: r for r in eng.run(on_exhausted="raise")}

    a, b = run_engine(None), run_engine("fifo")
    for rid in a:
        assert a[rid].output == b[rid].output
        assert a[rid].token_times == b[rid].token_times
        assert a[rid].ttft == b[rid].ttft and a[rid].latency == b[rid].latency


# ---------------------------------------------------------------------------
# Preemption equivalence: preempted ≡ unpreempted under greedy decoding
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("prefill_chunk", [None, 2])
def test_preempted_output_matches_unpreempted(prefill_chunk):
    """A low-priority decode evicted for a high-priority arrival and
    re-admitted via (chunked) re-prefill of prompt + emitted tokens must
    produce exactly its unpreempted greedy output."""
    cfg, model, params = reduced_model("mixtral-8x7b")
    fe = FiddlerEngine(cfg, params, policy="fiddler", expert_budget=30,
                       host_precision="fp32")
    eng = ContinuousEngine(FiddlerBackend(fe, max_seq=64), n_slots=1,
                           max_seq=64, prefill_chunk=prefill_chunk,
                           policy=PriorityPolicy())
    low = Request(rid="low", prompt=[1, 17, 23, 9], max_new_tokens=8,
                  slo_class="batch", arrival=0.0)
    # arrives (on the sim clock) mid-decode of `low`, forcing a slot steal
    high = Request(rid="high", prompt=[1, 40, 11], max_new_tokens=4,
                   slo_class="interactive", arrival=1e-9)
    eng.submit(low)
    eng.submit(high)
    done = {r.rid: r for r in eng.run(on_exhausted="raise")}
    assert done["low"].preemptions >= 1, "low was never preempted"
    for rid, req in done.items():
        want = _reference_generation(model, params, req.prompt,
                                     req.max_new_tokens)
        assert req.output == want[: len(req.output)], (rid, req.output, want)
        assert len(req.output) >= 1
    # the interactive request overtook the preempted batch request
    assert done["high"].token_times[-1] <= done["low"].token_times[-1]


def test_priority_improves_high_class_p95_ttft():
    """Acceptance: identical Poisson traces, overloaded pool — the
    priority policy must beat FIFO on interactive-class p95 TTFT."""
    from benchmarks.serve_load import simulate_once

    kw = dict(rate_hz=32.0, n_slots=2, n_requests=24, seed=0,
              interactive_frac=0.25, prompt_len=32, max_new=12)
    fifo = simulate_once("mixtral-8x7b", "fiddler", "env1", sched="fifo",
                         **kw)
    prio = simulate_once("mixtral-8x7b", "fiddler", "env1", sched="priority",
                         **kw)
    assert prio["p95_ttft_interactive"] < fifo["p95_ttft_interactive"]


# ---------------------------------------------------------------------------
# Engine guards (satellites): step budget, prompt length, mixed temperature
# ---------------------------------------------------------------------------


def test_run_budget_exhaustion_warns_and_raises():
    fe, eng = _sim_engine(n_slots=1)
    for i in range(3):
        eng.submit(Request(rid=f"r{i}", prompt=[1, 2], max_new_tokens=6))
    with pytest.warns(RuntimeWarning, match="max_steps"):
        eng.run(max_steps=2)
    fe2, eng2 = _sim_engine(n_slots=1)
    for i in range(3):
        eng2.submit(Request(rid=f"r{i}", prompt=[1, 2], max_new_tokens=6))
    with pytest.raises(RuntimeError, match="max_steps"):
        eng2.run(max_steps=2, on_exhausted="raise")
    # and a sufficient budget completes silently
    with warnings.catch_warnings():
        warnings.simplefilter("error")
        done = eng2.run(on_exhausted="warn")
    assert len(done) == 3


def test_prompt_longer_than_max_seq_rejected():
    fe, eng = _sim_engine(max_seq=16, prefill_chunk=None)
    with pytest.raises(ValueError, match="max_seq"):
        eng.submit(Request(rid="big", prompt=[1] * 16, max_new_tokens=2))
    cfg, model, params = reduced_model("qwen3-0.6b")
    se = ServingEngine(model, mode="model", params=params, max_seq=16)
    with pytest.raises(ValueError, match="max_seq"):
        se.submit(Request(rid="big", prompt=[1] * 20, max_new_tokens=2))
    # the group runner guards too (requests enqueued before a reconfigure)
    se.queue.append(Request(rid="big", prompt=[1] * 16, max_new_tokens=2,
                            arrival=0.0))
    with pytest.raises(ValueError, match="decode budget"):
        se.run()


def test_mixed_temperature_group_samples_per_request():
    """A greedy request's tokens must be unaffected by a batch neighbor's
    temperature (old behavior applied group[0].temperature to everyone, so
    a hot first request made the whole batch stochastic)."""
    cfg, model, params = reduced_model("qwen3-0.6b")

    def run_pair(hot_temp):
        eng = ServingEngine(model, mode="model", params=params, max_batch=2,
                            max_seq=64, seed=0)
        # hot request first: under the old bug its temperature governed
        # the greedy request too
        eng.submit(Request(rid="hot", prompt=[1, 40, 11], max_new_tokens=6,
                           temperature=hot_temp))
        eng.submit(Request(rid="cold", prompt=[1, 17, 23, 9],
                           max_new_tokens=6, temperature=0.0))
        return {r.rid: r for r in eng.run()}

    sampled, all_greedy = run_pair(5.0), run_pair(0.0)
    assert sampled["cold"].output == all_greedy["cold"].output
    assert 1 <= len(sampled["hot"].output) <= 6


def test_static_engine_priority_groups_first():
    """ServingEngine consumes the policy for group formation: interactive
    requests batch ahead of earlier-submitted bulk work."""
    cfg, model, params = reduced_model("qwen3-0.6b")
    eng = ServingEngine(model, mode="model", params=params, max_batch=1,
                        max_seq=64, policy="priority")
    eng.submit(Request(rid="bulk", prompt=[1, 5, 9], max_new_tokens=3,
                       slo_class="batch"))
    eng.submit(Request(rid="int", prompt=[1, 6, 2], max_new_tokens=3,
                       slo_class="interactive"))
    done = eng.run()
    assert [r.rid for r in done] == ["int", "bulk"]
    for r in done:
        want = _reference_generation(model, params, r.prompt, 3)
        assert r.output == want[: len(r.output)]


@pytest.mark.parametrize("backend_kind", ["model", "fiddler"])
def test_resize_cache_preserves_inflight_kv(backend_kind):
    """Growing the slot pool mid-decode must preserve every in-flight
    slot's KV: tokens decoded after the resize equal the unresized
    reference.  Model caches are layer-major (blocks stacked
    (n_periods, B, ...)) — the resize must grow the *batch* axis."""
    if backend_kind == "model":
        cfg, model, params = reduced_model("qwen3-0.6b")
        backend = ModelBackend(model, params, max_seq=64)
    else:
        cfg, model, params = reduced_model("mixtral-8x7b")
        fe = FiddlerEngine(cfg, params, policy="fiddler", expert_budget=30,
                           host_precision="fp32")
        backend = FiddlerBackend(fe, max_seq=64)
    prompts = [[1, 17, 23, 9], [1, 40, 11]]
    refs = [_reference_generation(model, params, p, 5) for p in prompts]

    cache = backend.make_cache(2)
    state = []  # (pos, last_token, output)
    for slot, p in enumerate(prompts):
        logits, staging = backend.prefill_chunk(None, p, 0)
        cache = backend.write_slot(cache, staging, slot)
        tok = int(np.argmax(logits))
        state.append([len(p), tok, [tok]])

    def decode_all(cache, n_slots, steps):
        for _ in range(steps):
            tokens = np.full((n_slots,), 0, np.int32)
            pos = np.zeros((n_slots,), np.int32)
            active = np.zeros((n_slots,), bool)
            for i, (pp, tt, _out) in enumerate(state):
                tokens[i], pos[i], active[i] = tt, pp, True
            logits, cache = backend.decode_slots(cache, tokens, pos, active)
            nxt = np.asarray(np.argmax(logits, -1))
            for i, s in enumerate(state):
                s[0] += 1
                s[1] = int(nxt[i])
                s[2].append(int(nxt[i]))
        return cache

    cache = decode_all(cache, 2, 2)      # two steps at 2 slots
    cache = backend.resize_cache(cache, n_slots=4)   # grow mid-decode
    cache = decode_all(cache, 4, 2)      # two more steps at 4 slots
    for i, ref in enumerate(refs):
        assert state[i][2] == ref, (i, state[i][2], ref)


def test_autoscale_target_respects_bounds():
    pol = AutoscalePolicy(min_slots=2, service_time=0.5, headroom=1.0)
    slots = [_slot_view(i, rid=None) for i in range(8)]
    # unknown rate: hold the current pool (but never below min)
    assert pol.target_slots(_view(0.0, [], slots, slot_limit=1)) == 2
    assert pol.target_slots(_view(0.0, [], slots, slot_limit=5, rate=0.0)) == 5
    # Little's law, clamped to [min, max]
    assert pol.target_slots(_view(0.0, [], slots, rate=0.1)) == 2
    assert pol.target_slots(_view(0.0, [], slots, rate=8.0)) == 4
    assert pol.target_slots(_view(0.0, [], slots, rate=1000.0)) == 8


# ---------------------------------------------------------------------------
# Starvation aging: batch-class requests age into the interactive tier
# ---------------------------------------------------------------------------


@settings(max_examples=60, deadline=None)
@given(st.lists(st.tuples(st.booleans(),                            # batch?
                          st.floats(min_value=0.0, max_value=20.0)),  # arrival
                min_size=2, max_size=8),
       st.floats(min_value=0.5, max_value=5.0),  # aging_time
       st.floats(min_value=0.0, max_value=40.0))  # clock
def test_aged_batch_precedes_later_arrivals(entries, aging_time, clock):
    """Any batch request that has waited >= aging_time must be admitted
    before every request — of any class — that arrived strictly later
    (aged requests join the interactive tier and tie-break by arrival),
    so no request's wait grows without bound."""
    queue = [_queue_view(i, a, priority=slo_priority(
                 "batch" if is_batch else "interactive"))
             for i, (is_batch, a) in enumerate(entries)]
    pol = PriorityPolicy(aging_time=aging_time)
    order = list(pol.admission_order(_view(clock, queue,
                                           [_slot_view(0, rid=None)])))
    rank = {qi: pos for pos, qi in enumerate(order)}
    for i, (is_batch, a) in enumerate(entries):
        if not (is_batch and a <= clock and clock - a >= aging_time):
            continue  # not an aged, arrived batch request
        for j, (_, b) in enumerate(entries):
            if b <= clock and b > a:
                assert rank[i] < rank[j], (i, j, entries, clock)


def test_aging_bounds_batch_wait_under_interactive_overload():
    """Sustained interactive overload on one slot: without aging the
    batch request is served dead last; with aging it overtakes every
    interactive request that arrived after its aging deadline (and its
    decode, once running, is not stolen by fresh interactive arrivals)."""
    AGING = 0.5

    def run(aging_time):
        # full-size sim on paper-env1: service time (≈100ms/step) dwarfs
        # the 10ms arrival gap, so the interactive stream truly overloads
        # the single slot
        cfg = get_config("mixtral-8x7b")
        fe = FiddlerEngine(cfg, policy="fiddler",
                           hw=HardwareSpec.paper_env1(), seed=0)
        eng = ContinuousEngine(SimulatedBackend(fe, max_seq=64), n_slots=1,
                               max_seq=64, prefill_chunk=4,
                               policy=PriorityPolicy(preemption=True,
                                                     aging_time=aging_time))
        # batch request lands just after the interactive stream starts;
        # one interactive arrival every 250 sim-ms with ~1s service each
        # keeps the queue permanently non-empty (sustained overload)
        eng.submit(Request(rid="starved", prompt=[1] * 4, max_new_tokens=4,
                           arrival=0.05, slo_class="batch"))
        for i in range(24):
            eng.submit(Request(rid=f"int{i:02d}", prompt=[1] * 4,
                               max_new_tokens=4, arrival=0.25 * i,
                               slo_class="interactive"))
        done = eng.run(max_steps=50_000, on_exhausted="raise")
        assert len(done) == 25
        return {r.rid: r for r in done}

    aged = run(AGING)
    unaged = run(None)
    # without aging: every interactive request beats the batch one
    assert all(unaged["starved"].ttft > r.ttft
               for rid, r in unaged.items() if rid != "starved")
    # with aging the wait is bounded: strictly earlier first token than
    # the no-aging run, and every interactive request that arrived after
    # the aging deadline is served no earlier than the aged batch request
    assert aged["starved"].ttft < unaged["starved"].ttft
    batch_first = aged["starved"].token_times[0]
    expiry = 0.05 + AGING  # batch arrival + aging_time
    later = [r for rid, r in aged.items()
             if rid != "starved" and r.arrival > expiry]
    assert later, "overload stream ended before the aging deadline"
    assert all(r.token_times[0] >= batch_first for r in later)


@pytest.mark.parametrize("backend_kind", ["model", "fiddler"])
def test_resize_cache_shrink_preserves_leading_slots(backend_kind):
    """The shrink path of ``resize_cache``: dropping trailing rows must
    preserve every surviving slot's KV bit-for-bit — tokens decoded after
    the shrink equal the unresized reference."""
    if backend_kind == "model":
        cfg, model, params = reduced_model("qwen3-0.6b")
        backend = ModelBackend(model, params, max_seq=64)
    else:
        cfg, model, params = reduced_model("mixtral-8x7b")
        fe = FiddlerEngine(cfg, params, policy="fiddler", expert_budget=30,
                           host_precision="fp32")
        backend = FiddlerBackend(fe, max_seq=64)
    prompts = [[1, 17, 23, 9], [1, 40, 11]]
    refs = [_reference_generation(model, params, p, 5) for p in prompts]

    cache = backend.make_cache(4)        # over-allocated pool
    state = []
    for slot, p in enumerate(prompts):
        logits, staging = backend.prefill_chunk(None, p, 0)
        cache = backend.write_slot(cache, staging, slot)
        tok = int(np.argmax(logits))
        state.append([len(p), tok, [tok]])

    def decode_all(cache, n_slots, steps):
        for _ in range(steps):
            tokens = np.full((n_slots,), 0, np.int32)
            pos = np.zeros((n_slots,), np.int32)
            active = np.zeros((n_slots,), bool)
            for i, (pp, tt, _out) in enumerate(state):
                tokens[i], pos[i], active[i] = tt, pp, True
            logits, cache = backend.decode_slots(cache, tokens, pos, active)
            nxt = np.asarray(np.argmax(logits, -1))
            for i, s in enumerate(state):
                s[0] += 1
                s[1] = int(nxt[i])
                s[2].append(int(nxt[i]))
        return cache

    cache = decode_all(cache, 4, 2)          # two steps at 4 slots
    cache = backend.resize_cache(cache, n_slots=2)   # shrink to the live pool
    cache = decode_all(cache, 2, 2)          # two more steps at 2 slots
    for i, ref in enumerate(refs):
        assert state[i][2] == ref, (i, state[i][2], ref)


def test_simulated_backend_resize_cache_roundtrip():
    fe, eng = _sim_engine()
    backend = eng.backend
    cache = backend.make_cache(2)
    grown = backend.resize_cache(cache, n_slots=6)
    assert grown["n_slots"] == 6 and grown["meta"].n_slots == 6
    shrunk = backend.resize_cache(grown, n_slots=1)
    assert shrunk["n_slots"] == 1 and shrunk["meta"].n_slots == 1
    shrunk["meta"].check()


def test_aged_batch_not_starved_by_deadline_traffic():
    """Aging must neutralise the deadline tie-breaker too: an aged batch
    request (deadline None → effective deadline = its aging expiry, in
    the past) precedes deadline-bearing interactive requests that
    arrived after it, instead of losing the (priority, deadline) sort to
    every future deadline forever."""
    clock, aging = 10.0, 1.0
    queue = [_queue_view(0, 0.0, priority=slo_priority("batch"))]
    for i in range(1, 4):  # later interactive arrivals with deadlines
        queue.append(QueueView(
            index=i, rid=f"q{i}", arrival=1.0 + i,
            priority=slo_priority("interactive"), slo_class="interactive",
            deadline=clock + i, prompt_len=4, max_new_tokens=8, emitted=0))
    pol = PriorityPolicy(aging_time=aging)
    order = list(pol.admission_order(_view(clock, queue,
                                           [_slot_view(0, rid=None)])))
    assert order[0] == 0, order
    # a request whose deadline predates the aged expiry is more overdue
    # still, and legitimately goes first
    queue.append(QueueView(
        index=4, rid="q4", arrival=0.5,
        priority=slo_priority("interactive"), slo_class="interactive",
        deadline=0.6, prompt_len=4, max_new_tokens=8, emitted=0))
    order = list(pol.admission_order(_view(clock, queue,
                                           [_slot_view(0, rid=None)])))
    assert order[0] == 4 and order[1] == 0, order


# ---------------------------------------------------------------------------
# Gang-aware preemption: capacity arithmetic
# ---------------------------------------------------------------------------


def _gang_queue_view(i, width, priority=2, arrival=0.0):
    return QueueView(index=i, rid=f"g{i}", arrival=arrival,
                     priority=priority, slo_class="interactive",
                     deadline=None, prompt_len=4, max_new_tokens=8,
                     emitted=0, width=width)


def test_preempt_skips_unservable_gang_waiter():
    """A gang waiter that cannot be fully served — even after evicting
    every lower-priority decode — must evict NOBODY (otherwise the
    evicted requests thrash through re-prefill every tick while the gang
    never admits)."""
    pol = PriorityPolicy(preemption=True)
    # 4 slots: two batch decodes (evictable), two interactive decodes
    # (not evictable by an interactive waiter); width-4 gang queued
    slots = [_slot_view(0, priority=0), _slot_view(1, priority=0),
             _slot_view(2, priority=2), _slot_view(3, priority=2)]
    view = _view(1.0, [_gang_queue_view(0, width=4)], slots)
    assert list(pol.preempt(view)) == []
    # width-2 is servable: exactly the two batch decodes are evicted
    view2 = _view(1.0, [_gang_queue_view(0, width=2)], slots)
    assert sorted(pol.preempt(view2)) == [0, 1]


def test_preempt_credits_surplus_gang_slots():
    """Evicting a width-3 gang for a width-1 waiter frees two surplus
    slots; a second width-1 waiter must ride those instead of costing
    another victim its decode."""
    pol = PriorityPolicy(preemption=True)
    gang = [SlotView(index=i, rid="beam", phase="decode", priority=0,
                     slo_class="batch", deadline=None, pos=8, prompt_len=4,
                     emitted=4, steps_left=4, started=0.0, arrival=0.0,
                     gang="beam", gang_size=3) for i in range(3)]
    single = _slot_view(3, priority=0, started=1.0)
    waiters = [_gang_queue_view(0, width=1), _gang_queue_view(1, width=1)]
    victims = list(pol.preempt(_view(2.0, waiters, gang + [single])))
    # one gang member named (engine evicts the whole gang); the innocent
    # width-1 batch decode in slot 3 is spared
    assert len(victims) == 1 and victims[0] in (0, 1, 2)
