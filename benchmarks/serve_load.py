"""Continuous-batching load benchmark: Poisson arrivals through the
orchestrated serving scheduler.

A Poisson load generator (arrivals in *simulated* seconds on the
paper-env hardware specs) drives ``ContinuousEngine`` over a
``FiddlerBackend``: real reduced-Mixtral numerics, full-size-config
latency constants (``timing_cfg``), chunked admission.  Sweeps arrival
rate × slot count across the three policies and reports per-config
throughput (tokens / simulated second), mean TTFT and mean ITL — the
heavy-traffic scenario axis the monolithic static-batch benchmarks never
exercise.
"""
from __future__ import annotations

from typing import Dict, List

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import ENVS, POLICIES, emit
from repro.configs import get_config
from repro.core import FiddlerEngine
from repro.serving.backend import FiddlerBackend
from repro.serving.continuous import ContinuousEngine
from repro.serving.engine import Request

MAX_SEQ = 48
PREFILL_CHUNK = 8

_model_cache = {}


def _reduced(model_name: str):
    if model_name not in _model_cache:
        from repro.models import Model

        full = get_config(model_name)
        cfg = full.reduced()
        model = Model(cfg, param_dtype=jnp.float32)
        params = model.init(jax.random.PRNGKey(0))
        _model_cache[model_name] = (full, cfg, model, params)
    return _model_cache[model_name]


def poisson_requests(rate_hz: float, n: int, *, prompt_len: int = 12,
                     max_new: int = 8, seed: int = 0) -> List[Request]:
    """n requests with exponential inter-arrival gaps at ``rate_hz``
    (simulated seconds) and random prompts."""
    rng = np.random.default_rng(seed)
    t = 0.0
    reqs = []
    for i in range(n):
        t += rng.exponential(1.0 / rate_hz)
        plen = int(rng.integers(prompt_len // 2, prompt_len + 1))
        prompt = [1] + rng.integers(3, 250, size=plen - 1).tolist()
        reqs.append(Request(rid=f"r{i}", prompt=prompt, max_new_tokens=max_new,
                            arrival=t))
    return reqs


def serve_once(model_name: str, policy: str, env: str, *, rate_hz: float,
               n_slots: int, n_requests: int, seed: int = 0) -> Dict[str, float]:
    full, cfg, model, params = _reduced(model_name)
    eng = FiddlerEngine(cfg, params, policy=policy, hw=ENVS[env],
                        timing_cfg=full, host_precision="fp32",
                        expert_budget=cfg.n_layers * cfg.moe.n_experts // 4,
                        seed=seed)
    serving = ContinuousEngine(FiddlerBackend(eng, max_seq=MAX_SEQ),
                               n_slots=n_slots, max_seq=MAX_SEQ,
                               prefill_chunk=PREFILL_CHUNK)
    for r in poisson_requests(rate_hz, n_requests, seed=seed):
        serving.submit(r)
    done = serving.run()
    assert len(done) == n_requests, (len(done), n_requests)
    led = eng.ledger
    n_tokens = sum(len(r.output) for r in done)
    itls = [r.itl for r in done if r.itl is not None]
    return {
        "throughput_tok_per_s": n_tokens / led.sim_time if led.sim_time else 0.0,
        "mean_ttft": float(np.mean([r.ttft for r in done])),
        "mean_itl": float(np.mean(itls)) if itls else 0.0,
        "hit_rate": led.fast_hits / max(led.fast_hits + led.streams
                                        + led.slow_runs, 1),
    }


def run(model: str = "mixtral-8x7b", env: str = "env1",
        fast: bool = False) -> Dict[str, Dict[str, float]]:
    rates = [2.0, 16.0] if fast else [2.0, 8.0, 32.0]
    slot_counts = [2] if fast else [2, 4]
    n_requests = 6 if fast else 16
    results = {}
    for policy in POLICIES:
        for rate in rates:
            for n_slots in slot_counts:
                r = serve_once(model, policy, env, rate_hz=rate,
                               n_slots=n_slots, n_requests=n_requests)
                key = f"serve_load/{env}/{policy}/rate{rate:g}_slots{n_slots}"
                emit(key, r["mean_itl"] * 1e6,
                     f"tok_per_s={r['throughput_tok_per_s']:.2f} "
                     f"ttft={r['mean_ttft']:.4f}s "
                     f"hit_rate={r['hit_rate']:.2f}")
                results[key] = r
    return results


if __name__ == "__main__":
    import sys

    run(fast="--full" not in sys.argv)
