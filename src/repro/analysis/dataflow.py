"""Lightweight per-function dataflow used by FID001 and FID002.

Two single-function, flow-insensitive-but-iterated analyses:

* **device-ness** — which local names (may) hold jax device arrays.
  Sources: parameters annotated ``jnp.ndarray``/``*Array``, expressions
  rooted at a ``jnp``/``jax`` call, and calls to project functions whose
  return annotation mentions device arrays.  Propagates through
  assignment, tuple unpacking, arithmetic, subscripts, and ternaries.
  Under-approximate by design: an unknown value is assumed host-side, so
  FID001 reports carry high confidence (the rule exists to catch *known*
  sync constructs on *known* device values).

* **dimension provenance** — which local names are data-dependent sizes
  (``len(x)``, ``x.size``, ``.shape`` of a data value) and which have
  been made jit-safe by a bucket helper (``_bucket(n)``; ``min``/``max``
  over a bucketed value stays bucketed).  ``.shape`` of a parameter, of
  a name unpacked from a parameter, or of a ``self`` attribute is
  *stable* geometry (model dims, pool layout) — only shapes of locally
  computed data count as trace-minting.
"""
from __future__ import annotations

import ast
from typing import Optional, Set

from repro.analysis.config import FiddlintConfig
from repro.analysis.project import FunctionInfo, Module, Project, attr_chain


def _target_names(t: ast.AST) -> Set[str]:
    out: Set[str] = set()
    for node in ast.walk(t):
        if isinstance(node, ast.Name):
            out.add(node.id)
    return out


class DeviceFlow:
    """Device-ness of names within one function (nested defs included)."""

    def __init__(self, project: Project, fn: FunctionInfo):
        self.project = project
        self.fn = fn
        self.mod: Module = project.modules[fn.module]
        self.device: Set[str] = set()
        self._seed_params(fn.node)
        for _ in range(3):  # small fixpoint: chains like a = b; c = a[0]
            before = len(self.device)
            for node in ast.walk(fn.node):
                self._visit_assign(node)
            if len(self.device) == before:
                break

    def _seed_params(self, node: ast.AST) -> None:
        for inner in ast.walk(node):
            if not isinstance(inner, (ast.FunctionDef, ast.AsyncFunctionDef)):
                continue
            a = inner.args
            for arg in [*a.posonlyargs, *a.args, *a.kwonlyargs]:
                if arg.annotation is not None:
                    src = ast.dump(arg.annotation)
                    if ("jnp" in src and "ndarray" in src) or "Array" in src:
                        self.device.add(arg.arg)

    def _visit_assign(self, node: ast.AST) -> None:
        if isinstance(node, ast.Assign) and self.is_device(node.value):
            for t in node.targets:
                self.device |= _target_names(t)
        elif isinstance(node, ast.AnnAssign) and node.value is not None:
            if self.is_device(node.value):
                self.device |= _target_names(node.target)
        elif isinstance(node, ast.AugAssign) and self.is_device(node.value):
            self.device |= _target_names(node.target)

    # -- expression classification -----------------------------------------
    def _call_returns_device(self, call: ast.Call) -> bool:
        func = call.func
        chain = attr_chain(func)
        if chain and chain[0] in (self.mod.jnp_aliases | {"jax"}
                                  | self.mod.jax_aliases):
            return True  # jnp.*(...) / jax.*(...) produce device values
        for qual in self.project.resolve_call(self.mod, call):
            info = self.project.functions.get(qual)
            if info is not None and info.device_return:
                return True
        return False

    def is_device(self, node: ast.AST) -> bool:
        if isinstance(node, ast.Name):
            return node.id in self.device
        if isinstance(node, ast.Call):
            return self._call_returns_device(node)
        if isinstance(node, (ast.Subscript, ast.Attribute, ast.Starred)):
            return self.is_device(node.value)
        if isinstance(node, ast.BinOp):
            return self.is_device(node.left) or self.is_device(node.right)
        if isinstance(node, ast.UnaryOp):
            return self.is_device(node.operand)
        if isinstance(node, ast.IfExp):
            return self.is_device(node.body) or self.is_device(node.orelse)
        if isinstance(node, (ast.Tuple, ast.List)):
            return any(self.is_device(e) for e in node.elts)
        return False


class DimFlow:
    """Data-dependent vs bucketed size provenance within one function."""

    def __init__(self, fn: FunctionInfo, config: FiddlintConfig):
        self.config = config
        self.params: Set[str] = set()
        for inner in ast.walk(fn.node):
            if isinstance(inner, (ast.FunctionDef, ast.AsyncFunctionDef)):
                a = inner.args
                self.params |= {arg.arg for arg in
                                [*a.posonlyargs, *a.args, *a.kwonlyargs]}
        self.dynamic: Set[str] = set()
        self.bucketed: Set[str] = set()
        # names unpacked (possibly transitively) from parameters: their
        # .shape is call-stable geometry, same as a parameter's
        self.param_derived: Set[str] = set(self.params)
        for _ in range(3):
            n = (len(self.dynamic), len(self.bucketed),
                 len(self.param_derived))
            for node in ast.walk(fn.node):
                if isinstance(node, ast.Assign):
                    self._flow(node.targets, node.value)
                elif isinstance(node, ast.AnnAssign) and node.value is not None:
                    self._flow([node.target], node.value)
            if (len(self.dynamic), len(self.bucketed),
                    len(self.param_derived)) == n:
                break

    def _flow(self, targets, value) -> None:
        if self.is_bucketed(value):
            for t in targets:
                self.bucketed |= _target_names(t)
        elif self.classify(value) == "dynamic":
            for t in targets:
                self.dynamic |= _target_names(t)
        if self._param_rooted(value):
            for t in targets:
                self.param_derived |= _target_names(t)

    def _param_rooted(self, node: ast.AST) -> bool:
        """Unpacking/indexing of a parameter: ``k, v = enc_kv`` or
        ``x_i, dt_i = inp`` — the pieces carry the parameter's
        call-stable geometry."""
        if isinstance(node, ast.Name):
            return node.id in self.param_derived
        if isinstance(node, (ast.Subscript, ast.Starred)):
            return self._param_rooted(node.value)
        if isinstance(node, (ast.Tuple, ast.List)):
            return all(self._param_rooted(e) for e in node.elts)
        return False

    def is_bucketed(self, node: ast.AST) -> bool:
        if isinstance(node, ast.Call):
            chain = attr_chain(node.func)
            if chain and chain[-1] in self.config.bucket_functions:
                return True
            if chain and chain[-1] in ("min", "max"):
                return any(self.is_bucketed(a) for a in node.args)
        if isinstance(node, ast.Name):
            return node.id in self.bucketed
        return False

    def classify(self, node: ast.AST) -> Optional[str]:
        """"dynamic" for a data-dependent, unbucketed size expression."""
        if self.is_bucketed(node):
            return None
        if isinstance(node, ast.Name):
            return "dynamic" if node.id in self.dynamic else None
        if isinstance(node, ast.Call):
            chain = attr_chain(node.func)
            if (isinstance(node.func, ast.Name) and node.func.id == "len"
                    and node.args):
                return "dynamic"
            if chain and chain[-1] in ("min", "max", "int"):
                if any(self.classify(a) == "dynamic" for a in node.args):
                    return "dynamic"
            return None
        if isinstance(node, ast.Attribute):
            if node.attr == "size":
                return "dynamic"
            return None
        if isinstance(node, ast.Subscript):
            # x.shape[i]: geometry of a parameter (or param-derived, or
            # self-attribute) array is stable across calls; .shape of
            # locally computed data is data-shaped
            v = node.value
            if isinstance(v, ast.Attribute) and v.attr == "shape":
                base = v.value
                if isinstance(base, ast.Name) and base.id in self.param_derived:
                    return None
                if (isinstance(base, ast.Attribute)
                        and isinstance(base.value, ast.Name)
                        and base.value.id == "self"):
                    return None  # pool/model geometry on the object
                return "dynamic"
            return None
        if isinstance(node, ast.BinOp):
            if (self.classify(node.left) == "dynamic"
                    or self.classify(node.right) == "dynamic"):
                return "dynamic"
            return None
        if isinstance(node, ast.IfExp):
            if (self.classify(node.body) == "dynamic"
                    or self.classify(node.orelse) == "dynamic"):
                return "dynamic"
            return None
        if isinstance(node, (ast.Tuple, ast.List)):
            # shape tuples: (n, 4) is dynamic when any element is
            if any(self.classify(e) == "dynamic" for e in node.elts):
                return "dynamic"
            return None
        return None
