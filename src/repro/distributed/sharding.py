"""Sharding rules: parameter / batch / cache pytrees → PartitionSpecs.

Axis layout (DESIGN.md §6):
  * ``model`` — tensor/expert parallel: vocab, attention heads, d_ff,
    experts (when divisible), KV-cache window (sequence-parallel decode).
  * ``data`` (+ ``pod``) — batch parallel; optimizer state is additionally
    ZeRO-shardable over these axes (perf knob).

Rules are name-based over the pytree paths produced by models/model.py.
Stacked layer parameters (leading n_periods axis from the scan) are
detected by rank and get a ``None`` prepended.
"""
from __future__ import annotations

from typing import Any, Dict, Optional, Tuple

import jax
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.configs.base import ModelConfig
from repro.models.moe import moe_mode


# ---------------------------------------------------------------------------
# Parameter rules
# ---------------------------------------------------------------------------

# name → (base_rank, spec_tail) where spec_tail applies to the LAST
# len(spec_tail) dims; leading dims (stacking) are replicated.
def _param_rules(cfg: ModelConfig, model_axis: str, ep: bool) -> Dict[str, Tuple[int, Tuple]]:
    M = model_axis
    rules: Dict[str, Tuple[int, Tuple]] = {
        "embed": (2, (M, None)),
        "lm_head": (2, (None, M)),
        "wq": (2, (None, M)),
        "wk": (2, (None, M)),
        "wv": (2, (None, M)),
        "wo": (2, (M, None)),
        "w1": (2, (None, M)),
        "w2": (2, (M, None)),
        # ssm / rglru
        "in_proj": (2, (None, M)),
        "w_z": (2, (None, M)),
        "w_xbc": (2, (None, M)),
        "w_dt": (2, (None, M)),
        "out_proj": (2, (M, None)),
        "w_x": (2, (None, M)),
        "w_a": (2, (None, M)),
        "w_i": (2, (None, M)),
        "b_a": (1, (M,)),
        "b_i": (1, (M,)),
        "lam": (1, (M,)),
        "w_out": (2, (M, None)),
        "conv_w": (2, (None, M)),
        "conv_b": (1, (M,)),
        "A_log": (1, (None,)),
        "D": (1, (None,)),
        "dt_bias": (1, (None,)),
        "router": (2, (None, None)),
    }
    return rules


def _moe_expert_rules(cfg: ModelConfig, model_axis: str, model_size: int,
                      data_axes: Tuple[str, ...]
                      ) -> Dict[str, Tuple[int, Tuple]]:
    """Single source of truth: repro.models.moe.moe_param_specs (so the
    dry-run in_shardings always match the shard_map in_specs), including
    the FSDP_EXPERTS storage layout."""
    from repro.distributed import opts
    from repro.models.moe import moe_param_specs

    specs = moe_param_specs(
        cfg, model_axis, model_size,
        fsdp_axes=data_axes if opts.FSDP_EXPERTS else None,
        fsdp_size=_axes_size(data_axes))
    return {k: (3, tuple(specs[k])) for k in ("w_gate", "w_up", "w_down")}


_AXIS_SIZES: Dict[str, int] = {"pod": 2, "data": 16, "model": 16}


def set_axis_sizes(mesh_shape: Dict[str, int]) -> None:
    """Record the current mesh axis sizes (used for divisibility checks in
    the name-based rules; defaults match the production mesh)."""
    _AXIS_SIZES.update(mesh_shape)


def _axes_size(axes) -> int:
    n = 1
    for a in (axes if isinstance(axes, (tuple, list)) else [axes]):
        n *= _AXIS_SIZES.get(a, 1)
    return n


def _tail_spec(leaf, base_rank: int, tail: Tuple) -> P:
    lead = leaf.ndim - len(tail)
    assert lead >= 0, (leaf.shape, tail)
    return P(*((None,) * lead + tuple(tail)))


def _path_names(path) -> Tuple[str, ...]:
    names = []
    for p in path:
        if isinstance(p, jax.tree_util.DictKey):
            names.append(str(p.key))
        elif isinstance(p, jax.tree_util.SequenceKey):
            names.append(f"[{p.idx}]")
        else:
            names.append(str(p))
    return tuple(names)


def _validate_spec(spec: P, leaf, axis_sizes: Dict[str, int]) -> P:
    """Drop (replicate) any sharded dim that the axis size doesn't divide."""
    out = []
    for dim, entry in enumerate(spec):
        if entry is None:
            out.append(None)
            continue
        axes = entry if isinstance(entry, tuple) else (entry,)
        size = 1
        for a in axes:
            size *= axis_sizes.get(a, 1)
        out.append(entry if leaf.shape[dim] % size == 0 else None)
    return P(*out)


def param_pspecs(cfg: ModelConfig, params_shape, model_axis: str = "model",
                 model_size: int = 16,
                 data_axes: Tuple[str, ...] = ("data",)) -> Any:
    """PartitionSpec pytree matching ``params_shape`` (from eval_shape)."""
    ep = cfg.moe is not None and moe_mode(cfg, model_size) == "ep"
    rules = _param_rules(cfg, model_axis, ep)
    moe_rules = (_moe_expert_rules(cfg, model_axis, model_size, data_axes)
                 if cfg.moe is not None else {})
    M = model_axis
    vocab_ok = cfg.vocab_size % model_size == 0
    if not vocab_ok:
        # vocab not divisible (mamba2 50280, whisper 51866): shard d_model
        # instead; logits become partial sums that SPMD all-reduces.
        rules["embed"] = (2, (None, M))
        rules["lm_head"] = (2, (M, None))

    def spec_for(path, leaf) -> P:
        names = _path_names(path)
        name = names[-1]
        in_moe = "moe" in names
        in_shared = "shared" in names
        if in_shared:  # shared expert: plain tensor-parallel gated MLP
            tails = {"w_gate": (None, M), "w_up": (None, M), "w_down": (M, None)}
            return _tail_spec(leaf, 2, tails[name])
        if in_moe and name in moe_rules:
            base, tail = moe_rules[name]
            return _tail_spec(leaf, base, tail)
        if not in_moe and name in ("w_gate", "w_up"):
            return _tail_spec(leaf, 2, (None, M))
        if not in_moe and name == "w_down":
            return _tail_spec(leaf, 2, (M, None))
        if name in rules:
            base, tail = rules[name]
            return _tail_spec(leaf, base, tail)
        if name in ("scale", "bias"):  # norms
            return _tail_spec(leaf, 1, (None,))
        # default: replicate
        return P(*((None,) * leaf.ndim))

    axis_sizes = {model_axis: model_size}

    def spec_checked(path, leaf) -> P:
        return _validate_spec(spec_for(path, leaf), leaf, axis_sizes)

    return jax.tree_util.tree_map_with_path(spec_checked, params_shape)


# ---------------------------------------------------------------------------
# Batch / cache rules
# ---------------------------------------------------------------------------


def batch_pspecs(cfg: ModelConfig, batch_shape,
                 data_axes: Tuple[str, ...] = ("data",),
                 mesh_shape: Optional[Dict[str, int]] = None) -> Any:
    def spec_for(path, leaf):
        name = _path_names(path)[-1]
        if name in ("tokens", "labels"):
            spec = P(data_axes, None)
        elif name in ("image_embeds", "frames"):
            spec = P(data_axes, None, None)
        elif leaf.ndim == 0:
            return P()
        else:
            spec = P(data_axes, *((None,) * (leaf.ndim - 1)))
        return _validate_spec(spec, leaf, dict(mesh_shape or {}))

    return jax.tree_util.tree_map_with_path(spec_for, batch_shape)


def cache_pspecs(cfg: ModelConfig, cache_shape, global_batch: int,
                 data_axes: Tuple[str, ...] = ("data",),
                 model_axis: str = "model",
                 mesh_shape: Optional[Dict[str, int]] = None) -> Any:
    """Cache sharding.  The KV window axis is sequence-parallel over
    ``model`` (GQA kv-heads are usually < model axis size, so head-sharding
    can't absorb it; softmax reductions over the sharded axis are handled
    by SPMD).  When the batch doesn't cover the data axes (long_500k B=1),
    the batch axis is left unsharded and the window takes all axes."""
    data_size = 1
    if mesh_shape:
        for ax in data_axes:
            data_size *= mesh_shape[ax]
    batch_ok = data_size > 1 and global_batch % data_size == 0

    b_axes = data_axes if batch_ok else None
    w_axes = model_axis if batch_ok else tuple(data_axes) + (model_axis,)

    def spec_for(path, leaf):
        names = _path_names(path)
        name = names[-1]
        # cross_kv leaves are unnamed tuple members under "cross_kv":
        # (n_periods, B, Se, n_kv, head_dim)
        if "cross_kv" in names:
            full = (None, b_axes, None, None, None)
            return P(*full[5 - leaf.ndim:])
        nd = leaf.ndim
        if name in ("k", "v"):
            base = (b_axes, w_axes, None, None)
        elif name == "pos":
            base = (b_axes, w_axes)
        elif name == "ssm_state":
            base = (b_axes, model_axis, None, None)
        elif name == "conv_state":
            base = (b_axes, None, model_axis)
        elif name == "h":
            base = (b_axes, model_axis)
        else:
            return P(*((None,) * nd))
        lead_n = nd - len(base)
        return P(*((None,) * lead_n + base))

    axis_sizes = dict(mesh_shape or {})

    def spec_checked(path, leaf):
        return _validate_spec(spec_for(path, leaf), leaf, axis_sizes)

    return jax.tree_util.tree_map_with_path(spec_checked, cache_shape)


def to_named(mesh, spec_tree):
    return jax.tree.map(lambda s: NamedSharding(mesh, s), spec_tree,
                        is_leaf=lambda s: isinstance(s, P))


# ---------------------------------------------------------------------------
# Serving fast-tier rules (expert-parallel stacked pools)
# ---------------------------------------------------------------------------


def fast_stack_pspecs(n_resident: int, model_axis: str = "model",
                      model_size: int = 1) -> Dict[str, P]:
    """PartitionSpecs for one layer's stacked fast-tier expert pool
    (core/orchestrator.py ``_FastStack``: ``wg``/``wu`` (cap, d, f) and
    ``wd`` (cap, f, d)): the stacked-expert axis shards over the mesh's
    ``model`` axis — expert parallelism — when the resident count
    divides, replicating otherwise (the same divisibility discipline as
    ``_validate_spec``)."""
    M = model_axis if model_size > 1 and n_resident > 0 \
        and n_resident % model_size == 0 else None
    return {"wg": P(M, None, None), "wu": P(M, None, None),
            "wd": P(M, None, None)}


def serving_mesh_axes(mesh) -> Dict[str, int]:
    """Axis-name → size for a serving mesh (None → the 1×1 default)."""
    if mesh is None:
        return {"data": 1, "model": 1}
    return dict(zip(mesh.axis_names, mesh.devices.shape))
