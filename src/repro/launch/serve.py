"""Serving launcher: run the Fiddler engine (or the monolithic model) over
a stream of requests from the synthetic conversation pipeline, with either
the static grouped scheduler or slot-based continuous batching.

``--policy`` picks the *orchestrator* policy (paper Algorithm 1 vs
baselines); ``--sched-policy`` picks the *scheduler* policy (the
SchedulerPolicy seam: fifo / priority / autoscale) and ``--slo`` assigns
SLO classes to the generated request stream, e.g.
``--slo interactive=1,batch=3`` for a 1:3 class mix.

``--prefix-pool N --prefix-len L`` prepends one of N shared L-token
preambles (system prompts) to every request: with the paged layout and
continuous scheduler, the cross-request prefix cache (on by default,
``--no-prefix-cache`` to disable) splices the resident preamble blocks
into each later admission and only prefills the unique tail — the
ledger reports lookups/hits/matched tokens.

  PYTHONPATH=src python -m repro.launch.serve --arch mixtral-8x7b \
      --policy fiddler --requests 8 --max-new 16 --scheduler continuous \
      --sched-policy priority --slo interactive=1,batch=3 \
      --prefix-pool 1 --prefix-len 32
"""
import argparse

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config
from repro.core import FiddlerEngine, HardwareSpec
from repro.data.pipeline import synthetic_conversations
from repro.data.tokenizer import ByteTokenizer
from repro.models import Model
from repro.models.kv_cache import layer_window
from repro.serving.backend import FiddlerBackend, ModelBackend
from repro.serving.continuous import ContinuousEngine
from repro.serving.engine import Request, ServingEngine


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="mixtral-8x7b")
    ap.add_argument("--policy", default="fiddler",
                    choices=["fiddler", "offload", "static_split", "model"])
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--max-new", type=int, default=16)
    ap.add_argument("--max-batch", type=int, default=4)
    ap.add_argument("--hw", default="env1",
                    choices=["env1", "env2", "tpuhost"])
    ap.add_argument("--scheduler", default="static",
                    choices=["static", "continuous"])
    ap.add_argument("--slots", type=int, default=4,
                    help="decode slots (continuous scheduler)")
    ap.add_argument("--prefill-chunk", type=int, default=16,
                    help="chunked-admission size (continuous scheduler)")
    ap.add_argument("--sched-policy", default="fifo",
                    choices=["fifo", "priority", "autoscale"],
                    help="SchedulerPolicy: admission order, preemption, "
                         "slot autoscaling")
    ap.add_argument("--slo", default=None,
                    help="SLO class mix for the request stream, e.g. "
                         "'interactive=1,batch=3' (weights); default: all "
                         "standard")
    ap.add_argument("--rebalance-interval", type=int, default=None,
                    help="dynamic placement rebalancing: serving ticks "
                         "between bounded expert-migration plans "
                         "(default: off — static placement)")
    ap.add_argument("--rebalance-k", type=int, default=4,
                    help="max expert swaps per rebalance interval")
    ap.add_argument("--kv-layout", default="paged",
                    choices=["paged", "dense"],
                    help="serving KV layout: paged (block pool + "
                         "copy-on-write tables; beam forks/reshuffles are "
                         "zero-copy) or dense ring buffers")
    ap.add_argument("--beam-width", type=int, default=1,
                    help=">1 submits every request as a gang-scheduled "
                         "beam group of this width (continuous scheduler "
                         "runs them alongside ordinary traffic)")
    ap.add_argument("--prefix-cache", action=argparse.BooleanOptionalAction,
                    default=True,
                    help="cross-request prefix cache over the paged KV "
                         "pool: prompts sharing a preamble reuse its "
                         "resident blocks and only prefill the tail "
                         "(paged layout + continuous scheduler; "
                         "--no-prefix-cache disables)")
    ap.add_argument("--prefix-pool", type=int, default=0, metavar="N",
                    help="prepend one of N shared preambles (round-robin) "
                         "to every prompt — a system-prompt workload that "
                         "exercises the prefix cache (default: off)")
    ap.add_argument("--prefix-len", type=int, default=96, metavar="L",
                    help="shared preamble length in tokens "
                         "(with --prefix-pool)")
    args = ap.parse_args(argv)
    if args.beam_width > 1 and args.beam_width > args.slots \
            and args.scheduler == "continuous":
        raise SystemExit(
            f"--beam-width {args.beam_width} needs at least that many "
            f"--slots (got {args.slots})")
    if args.rebalance_interval is not None and args.policy in (
            "model", "static_split"):
        raise SystemExit(
            "--rebalance-interval needs an expert-level orchestrator "
            "policy (fiddler or offload)")

    full = get_config(args.arch)
    cfg = full.reduced()  # real numerics at reduced scale on CPU
    model = Model(cfg, param_dtype=jnp.float32)
    params = model.init(jax.random.PRNGKey(0))
    tok = ByteTokenizer(cfg.vocab_size)

    hw = {"env1": HardwareSpec.paper_env1(),
          "env2": HardwareSpec.paper_env2(),
          "tpuhost": HardwareSpec()}[args.hw]

    fe = None
    if args.policy != "model":
        fe = FiddlerEngine(cfg, params, policy=args.policy, timing_cfg=full,
                           hw=hw,
                           expert_budget=cfg.n_layers * cfg.moe.n_experts // 4
                           if cfg.moe else 0,
                           rebalance_interval=args.rebalance_interval,
                           rebalance_k=args.rebalance_k,
                           kv_layout=args.kv_layout,
                           prefix_cache=args.prefix_cache)
    if args.scheduler == "continuous":
        backend = (ModelBackend(model, params, max_seq=256) if fe is None
                   else FiddlerBackend(fe, max_seq=256))
        eng = ContinuousEngine(backend, n_slots=args.slots, max_seq=256,
                               prefill_chunk=args.prefill_chunk,
                               policy=args.sched_policy)
    elif fe is None:
        eng = ServingEngine(model, mode="model", params=params,
                            max_batch=args.max_batch, max_seq=256,
                            policy=args.sched_policy)
    else:
        eng = ServingEngine(fe, mode="fiddler", max_batch=args.max_batch,
                            max_seq=256, policy=args.sched_policy)

    # SLO class mix: "interactive=1,batch=3" → weighted random assignment
    classes, weights = ["standard"], [1.0]
    if args.slo:
        classes, weights = [], []
        for part in args.slo.split(","):
            name, _, w = part.partition("=")
            classes.append(name.strip())
            weights.append(float(w) if w else 1.0)
        if min(weights) < 0 or sum(weights) <= 0:
            raise SystemExit(
                f"--slo weights must be non-negative with a positive sum, "
                f"got {args.slo!r}")
    probs = np.asarray(weights) / np.sum(weights)
    rng = np.random.default_rng(0)

    # shared system-prompt preambles for the prefix-cache workload: a
    # ring-wrapped row cannot serve as a shared prefix, so keep
    # preamble + tail + decode inside the smallest layer KV window
    # (reduced Mixtral runs 64-token sliding-window rings)
    w_min = min(layer_window(cfg, li, 256) for li in range(cfg.n_layers))
    pre_len = min(args.prefix_len, max(16, w_min - 16 - args.max_new))
    tail_cap = max(1, min(48, w_min - pre_len - args.max_new))
    if args.prefix_pool and pre_len < args.prefix_len:
        print(f"note: --prefix-len clipped to {pre_len} (layer KV window "
              f"{w_min} with --max-new {args.max_new})")
    pools = [rng.integers(3, min(250, cfg.vocab_size),
                          size=pre_len).tolist()
             for _ in range(args.prefix_pool)]
    for i, conv in enumerate(synthetic_conversations(args.requests)):
        slo = classes[int(rng.choice(len(classes), p=probs))]
        prompt = tok.encode(conv["text"])[:48]
        if pools:
            prompt = pools[i % len(pools)] + prompt[:tail_cap]
        eng.submit(Request(rid=f"req{i}", prompt=prompt,
                           max_new_tokens=args.max_new, slo_class=slo,
                           beam_width=args.beam_width))
    for r in eng.run():
        unit = "s(sim)" if args.policy != "model" else "s"
        beam = (f" beams={r.beam_width}" if r.beam_width > 1 else "")
        print(f"{r.rid}[{r.slo_class}]: ttft={r.ttft:.4f}{unit} "
              f"latency={r.latency:.4f}{unit} tokens={len(r.output)} "
              f"preempt={r.preemptions}{beam}")
    if args.policy not in ("model",):
        led = eng.backend.ledger
        print(f"ledger: sim_time={led.sim_time:.4f}s hits={led.fast_hits} "
              f"streams={led.streams} slow={led.slow_runs} "
              f"migrations={led.migrations} "
              f"migration_time={led.migration_time:.4f}s")
        if led.prefix_lookups:
            print(f"prefix cache: lookups={led.prefix_lookups} "
                  f"hits={led.prefix_hits} "
                  f"matched_tokens={led.prefix_tokens}")


if __name__ == "__main__":
    main()
