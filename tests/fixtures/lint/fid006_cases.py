"""FID006 fixture: watchdogged future awaits + blanket handlers.

Hot root for this module: ``Engine.step``.
"""
from concurrent.futures import ThreadPoolExecutor

POOL = ThreadPoolExecutor(2)


def kernel(x):
    return x + 1


def dispatch_unbounded(xs):
    futs = [POOL.submit(kernel, x) for x in xs]
    return [f.result() for f in futs]  # EXPECT: FID006


def dispatch_watchdogged(xs):
    futs = [POOL.submit(kernel, x) for x in xs]
    return [f.result(timeout=1.0) for f in futs]  # ok: bounded await


def dispatch_positional(xs):
    futs = [POOL.submit(kernel, x) for x in xs]
    return [f.result(1.0) for f in futs]  # ok: positional timeout


def offline_result(report):
    # false-positive candidate: submits nothing and is not hot-reachable —
    # ``.result()`` here is some other object's API, not a future await
    return report.result()


class Engine:
    def step(self, xs):
        out = self.guarded(xs)
        out += self.narrated(xs)
        out += self.swallowing(xs)
        out += self.swallowing_bare(xs)
        return out

    def guarded(self, xs):
        try:
            return sum(xs)
        except ValueError:  # ok: specific recoverable type
            return 0

    def narrated(self, xs):
        try:
            return sum(xs)
        except Exception as e:  # ok: re-raises (narrates, doesn't swallow)
            raise RuntimeError("step failed") from e

    def swallowing(self, xs):
        try:
            return sum(xs)
        except Exception:  # EXPECT: FID006
            return 0

    def swallowing_bare(self, xs):
        try:
            return sum(xs)
        except:  # EXPECT: FID006
            return 0
