"""Paper Figure 5: Time-To-First-Token for long-context prefill
(512–4096 input tokens), Fiddler vs baselines."""
from benchmarks.common import POLICIES, emit, engine_for

IN_LENS = [512, 1024, 2048, 4096]


def run(model: str = "mixtral-8x7b", envs=("env1", "env2"),
        fast: bool = False):
    lens = IN_LENS[:2] if fast else IN_LENS
    summary = {}
    for env in envs:
        ttfts = {p: [] for p in POLICIES}
        for n_in in lens:
            for policy in POLICIES:
                eng = engine_for(model, policy, env)
                t = eng.simulate_prefill(n_in)
                ttfts[policy].append(t)
                emit(f"prefill/{env}/{policy}/in{n_in}", t * 1e6,
                     f"ttft_s={t:.3f}")
        mean = {p: sum(v) / len(v) for p, v in ttfts.items()}
        emit(f"prefill/{env}/fiddler_vs_offload", 0.0,
             f"{mean['offload'] / mean['fiddler']:.2f}x (paper: 1.07x vs DS-MII)")
        emit(f"prefill/{env}/fiddler_vs_static", 0.0,
             f"{mean['static_split'] / mean['fiddler']:.2f}x")
        summary[env] = mean
    return summary


if __name__ == "__main__":
    run()
