"""Data pipeline: synthetic ShareGPT-like conversations + batching.

The paper evaluates on ShareGPT (human–chatbot conversations) and
LMSYS-Chat-1M.  Offline, we generate statistically-similar synthetic
corpora: Zipf-distributed "word" tokens composed into turns with
role markers, which (a) exercise the tokenizer/batcher exactly like real
text and (b) give the popularity profiler a realistic skewed token
distribution.  ``dataset="lmsys"`` changes the Zipf exponent/seed —
used by the paper's Appendix D sensitivity study.
"""
from __future__ import annotations

from typing import Dict, Iterator

import numpy as np

from repro.configs.base import ModelConfig
from repro.data.tokenizer import EOS_ID, ByteTokenizer

_WORDS = [
    "the", "of", "and", "to", "in", "model", "expert", "token", "layer",
    "what", "how", "why", "is", "a", "can", "you", "explain", "write",
    "code", "python", "data", "system", "memory", "fast", "slow", "please",
    "gpu", "cpu", "batch", "time", "use", "run", "serve", "infer", "train",
]


def _zipf_text(rng: np.random.Generator, n_words: int, alpha: float) -> str:
    ranks = np.arange(1, len(_WORDS) + 1, dtype=np.float64)
    p = ranks ** (-alpha)
    p /= p.sum()
    return " ".join(rng.choice(_WORDS, size=n_words, p=p))


def synthetic_conversations(n: int, seed: int = 0, dataset: str = "sharegpt"
                            ) -> Iterator[Dict[str, str]]:
    alpha = 1.1 if dataset == "sharegpt" else 1.4
    rng = np.random.default_rng(seed + (0 if dataset == "sharegpt" else 777))
    for i in range(n):
        n_turns = int(rng.integers(1, 4))
        turns = []
        for t in range(n_turns):
            q = _zipf_text(rng, int(rng.integers(8, 64)), alpha)
            a = _zipf_text(rng, int(rng.integers(16, 128)), alpha)
            turns.append(f"USER: {q}\nASSISTANT: {a}\n")
        yield {"id": f"{dataset}-{i}", "text": "".join(turns)}


class TokenStream:
    """Packs tokenized conversations into fixed-length LM training batches
    {tokens, labels} (labels = next token, -100 on padding)."""

    def __init__(self, cfg: ModelConfig, seq_len: int, batch: int,
                 seed: int = 0, dataset: str = "sharegpt"):
        self.tok = ByteTokenizer(cfg.vocab_size)
        self.seq_len = seq_len
        self.batch = batch
        self.cfg = cfg
        self._convs = synthetic_conversations(1 << 30, seed, dataset)
        self._buf: list = []

    def _fill(self, n_tokens: int) -> None:
        while len(self._buf) < n_tokens:
            conv = next(self._convs)
            self._buf.extend(self.tok.encode(conv["text"]) + [EOS_ID])

    def __iter__(self):
        return self

    def __next__(self) -> Dict[str, np.ndarray]:
        need = self.batch * (self.seq_len + 1)
        self._fill(need)
        flat = np.asarray(self._buf[:need], np.int32)
        self._buf = self._buf[need:]
        arr = flat.reshape(self.batch, self.seq_len + 1)
        return {"tokens": arr[:, :-1].copy(), "labels": arr[:, 1:].copy()}


def make_batch_iter(cfg: ModelConfig, seq_len: int, batch: int, seed: int = 0,
                    dataset: str = "sharegpt", extra_dtype=np.float32
                    ) -> Iterator[Dict[str, np.ndarray]]:
    """Training iterator; adds stubbed modality inputs for vlm/audio."""
    stream = TokenStream(cfg, seq_len, batch, seed, dataset)
    rng = np.random.default_rng(seed + 1)
    for b in stream:
        if cfg.arch_type == "vlm":
            b["image_embeds"] = rng.standard_normal(
                (batch, cfg.vlm.n_image_tokens, cfg.d_model)).astype(extra_dtype) * 0.02
            b["labels"] = np.concatenate(
                [np.full((batch, cfg.vlm.n_image_tokens), -100, np.int32),
                 b["labels"]], axis=1)
        if cfg.arch_type == "audio":
            b["frames"] = rng.standard_normal(
                (batch, cfg.encdec.n_audio_frames, cfg.d_model)).astype(extra_dtype) * 0.02
        yield b


def sample_prompts(cfg: ModelConfig, n: int, min_tokens: int, seed: int = 0,
                   dataset: str = "sharegpt") -> np.ndarray:
    """Paper §4.1: random ShareGPT samples with ≥ N prompt tokens; take the
    first N.  Returns (n, min_tokens) int32."""
    tok = ByteTokenizer(cfg.vocab_size)
    out = []
    for conv in synthetic_conversations(1 << 30, seed, dataset):
        ids = tok.encode(conv["text"])
        while len(ids) < min_tokens:
            ids = ids + tok.encode(next(iter(
                synthetic_conversations(1, seed + len(out), dataset)))["text"],
                bos=False)
        out.append(ids[:min_tokens])
        if len(out) == n:
            break
    return np.asarray(out, np.int32)
