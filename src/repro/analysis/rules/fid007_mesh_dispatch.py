"""FID007 per-device-work-in-mesh-dispatch.

Expert-parallel serving multiplies every per-step mistake by the device
count: a host sync inside a ``shard_map`` body runs once *per device per
step* and serialises the all-to-all it was supposed to overlap, and a
migration loop that ``device_put``s one expert at a time turns one link
transaction per device into one per expert.  Two patterns:

* **host sync inside a shard_map dispatch body** — the function object
  passed to ``shard_map(...)`` (positional arg or decorator; nested defs,
  lambdas, and module-level functions all resolve) must stay traced jax
  end to end.  ``.item()`` / ``.tolist()`` / ``.block_until_ready()``,
  ``jax.device_get``, ``np.asarray`` / ``np.array``, and ``float`` /
  ``int`` / ``bool`` on non-literal values are flagged unconditionally:
  inside a shard_map body every value is a traced shard, so there is no
  host-side false-positive population to gate on (unlike FID001's
  dataflow-gated hot-path scan).

* **unbatched per-device ``device_put`` in a migration path** — inside
  functions reachable from the configured ``migration_roots``, a
  ``jax.device_put`` under a ``for`` loop whose payload is a single
  array (not a list/tuple literal, comprehension, or a local name bound
  to one) moves weights one transfer at a time; batch the group into one
  put per target device.
"""
from __future__ import annotations

import ast
from typing import Dict, List, Optional, Set

from repro.analysis.config import FiddlintConfig
from repro.analysis.core import Finding, relpath
from repro.analysis.project import FunctionInfo, Project, attr_chain

SYNC_METHODS = {"item", "tolist", "block_until_ready"}
SYNC_CASTS = {"float", "int", "bool"}
NP_SYNC_FUNCS = {"asarray", "array"}
BATCHED_NODES = (ast.List, ast.Tuple, ast.ListComp, ast.GeneratorExp)


def _is_shard_map_call(node: ast.Call) -> bool:
    chain = attr_chain(node.func)
    return bool(chain) and chain[-1] == "shard_map"


def _named_defs(scope: ast.AST) -> Dict[str, ast.AST]:
    """Every function definition visible under ``scope`` by name
    (innermost wins — matches how a nested ``body`` shadows)."""
    out: Dict[str, ast.AST] = {}
    for n in ast.walk(scope):
        if isinstance(n, (ast.FunctionDef, ast.AsyncFunctionDef)):
            out[n.name] = n
        elif isinstance(n, ast.Assign) and isinstance(n.value, ast.Lambda):
            for t in n.targets:
                if isinstance(t, ast.Name):
                    out[t.id] = n.value
    return out


def _dispatch_bodies(project: Project, fn: FunctionInfo) -> List[ast.AST]:
    """AST nodes of every shard_map body rooted in ``fn``: the first
    positional argument of each ``shard_map(...)`` call (resolved against
    nested defs, then module-level functions), plus ``fn`` itself when a
    decorator wraps it in shard_map."""
    bodies: List[ast.AST] = []
    local = _named_defs(fn.node)
    for node in ast.walk(fn.node):
        if not (isinstance(node, ast.Call) and _is_shard_map_call(node)
                and node.args):
            continue
        target = node.args[0]
        if isinstance(target, ast.Lambda):
            bodies.append(target)
        elif isinstance(target, ast.Name):
            if target.id in local:
                bodies.append(local[target.id])
            else:
                top = project.functions.get(f"{fn.module}.{target.id}")
                if top is not None:
                    bodies.append(top.node)
    decs = getattr(fn.node, "decorator_list", [])
    if any(isinstance(d, ast.Call) and _is_shard_map_call(d) for d in decs):
        bodies.append(fn.node)
    return bodies


def _check_body_syncs(body: ast.AST, fn: FunctionInfo, path: str,
                      np_aliases: Set[str], jax_aliases: Set[str],
                      out: List[Finding]) -> None:
    for node in ast.walk(body):
        if not isinstance(node, ast.Call):
            continue
        func = node.func
        label: Optional[str] = None
        if isinstance(func, ast.Attribute) and func.attr in SYNC_METHODS:
            label = f"`.{func.attr}()`"
        else:
            chain = attr_chain(func)
            if (chain and chain[-1] == "device_get"
                    and chain[0] in jax_aliases):
                label = "`jax.device_get`"
            elif (chain and len(chain) == 2 and chain[0] in np_aliases
                    and chain[1] in NP_SYNC_FUNCS):
                label = f"`{chain[0]}.{chain[1]}`"
            elif (isinstance(func, ast.Name) and func.id in SYNC_CASTS
                    and node.args
                    and not isinstance(node.args[0], ast.Constant)):
                label = f"`{func.id}()`"
        if label is not None:
            out.append(Finding(
                "FID007", path, node.lineno, node.col_offset,
                f"{label} inside a shard_map dispatch body runs a host "
                f"sync once per device per step and serialises the "
                f"collective; keep the body traced jax end to end",
                fn.qualname))


def _batched_names(fn_node: ast.AST) -> Set[str]:
    """Local names bound to list/tuple literals or comprehensions — a
    ``device_put`` of one of these IS the batched idiom."""
    names: Set[str] = set()
    for node in ast.walk(fn_node):
        if isinstance(node, ast.Assign) and isinstance(node.value,
                                                       BATCHED_NODES):
            for t in node.targets:
                if isinstance(t, ast.Name):
                    names.add(t.id)
    return names


def _check_migration_puts(fn: FunctionInfo, path: str, root: str,
                          jax_aliases: Set[str],
                          out: List[Finding]) -> None:
    batched = _batched_names(fn.node)
    via = "" if fn.qualname == root else f" (reachable from {root})"
    for loop in ast.walk(fn.node):
        if not isinstance(loop, (ast.For, ast.AsyncFor)):
            continue
        for node in ast.walk(loop):
            if not isinstance(node, ast.Call):
                continue
            chain = attr_chain(node.func)
            if not (chain and chain[-1] == "device_put"
                    and (len(chain) == 1 or chain[0] in jax_aliases)):
                continue
            if not node.args:
                continue
            payload = node.args[0]
            if isinstance(payload, BATCHED_NODES):
                continue
            if isinstance(payload, ast.Name) and payload.id in batched:
                continue
            out.append(Finding(
                "FID007", path, node.lineno, node.col_offset,
                f"unbatched `device_put` inside a migration loop{via}: "
                f"one link transaction per iteration — group the "
                f"transfers and issue one put per target device",
                fn.qualname))


def check_mesh_dispatch(project: Project,
                        config: FiddlintConfig) -> List[Finding]:
    out: List[Finding] = []

    # (a) host syncs inside shard_map dispatch bodies, project-wide
    for fn in project.functions.values():
        mod = project.modules[fn.module]
        path = relpath(fn.file.path)
        for body in _dispatch_bodies(project, fn):
            _check_body_syncs(body, fn, path, mod.np_aliases,
                              mod.jax_aliases, out)

    # (b) unbatched per-device puts on migration-reachable paths
    roots = project.resolve_roots(config.migration_roots)
    reach = project.reachable_from(roots)
    for qual, root in reach.items():
        fn = project.functions.get(qual)
        if fn is not None:
            mod = project.modules[fn.module]
            _check_migration_puts(fn, relpath(fn.file.path), root,
                                  mod.jax_aliases, out)
    return out
