"""InternVL2-76B [arXiv:2404.16821] — InternViT + InternLM2; ViT STUBBED.

Language backbone: 80L d_model=8192 64H (GQA kv=8) d_ff=28672 vocab=128256.
The vision encoder + MLP projector are stubs — input_specs() provides
projected patch embeddings interleaved with the text stream.
"""
from repro.configs.base import ModelConfig, VLMConfig, register


@register("internvl2-76b")
def internvl2_76b() -> ModelConfig:
    return ModelConfig(
        name="internvl2-76b",
        arch_type="vlm",
        n_layers=80,
        d_model=8192,
        n_heads=64,
        n_kv_heads=8,
        head_dim=128,
        d_ff=28672,
        vocab_size=128256,
        rope_theta=500000.0,
        vlm=VLMConfig(n_image_tokens=256),
        citation="[arXiv:2404.16821] InternVL2 (InternViT + InternLM2)",
    )
