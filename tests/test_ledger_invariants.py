"""Ledger accounting invariants, across all three policies:

* every activated expert gets exactly one decision —
  ``fast_hits + streams + slow_runs`` equals the number of activated
  experts the planner saw;
* ``stream_bytes`` is exactly ``streams * expert_weight_bytes``;
* the simulated clock strictly increases with every charged layer;
* with an active-slot mask, padded slots contribute nothing to expert
  counts or the ledger.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from conftest import reduced_model
from repro.configs import get_config
from repro.core import FiddlerEngine
from repro.core.cost_model import expert_weight_bytes
from repro.core.orchestrator import POLICIES


def _spy_decide(eng):
    """Wrap eng._decide to record (activated experts, sim_time) per call."""
    orig = eng._decide
    seen = []

    def spy(li, counts):
        seen.append({"activated": int((counts > 0).sum()),
                     "total": int(counts.sum()),
                     "sim_time": eng.ledger.sim_time})
        return orig(li, counts)

    eng._decide = spy
    return seen


@pytest.mark.parametrize("policy", POLICIES)
def test_decision_accounting_real_numerics(policy):
    cfg, model, params = reduced_model("mixtral-8x7b")
    eng = FiddlerEngine(cfg, params, policy=policy, expert_budget=30,
                        host_precision="fp32")
    seen = _spy_decide(eng)
    tokens = jax.random.randint(jax.random.PRNGKey(0), (2, 6), 3,
                                cfg.vocab_size)
    _, caches = eng.prefill(tokens, max_seq=32)
    _, caches = eng.decode_step(caches, tokens[:, :1], pos=6, max_seq=32)
    led = eng.ledger
    assert led.fast_hits + led.streams + led.slow_runs == \
        sum(s["activated"] for s in seen)
    assert led.stream_bytes == led.streams * expert_weight_bytes(cfg)
    assert len(seen) == 2 * cfg.n_layers  # prefill + one decode step


@pytest.mark.parametrize("policy", POLICIES)
def test_sim_time_strictly_increasing_per_layer(policy):
    cfg = get_config("mixtral-8x7b")
    eng = FiddlerEngine(cfg, policy=policy, seed=0)
    seen = _spy_decide(eng)
    eng.simulate_prefill(64)
    eng.simulate_decode(4, batch=2)
    times = [s["sim_time"] for s in seen] + [eng.ledger.sim_time]
    diffs = np.diff(times)
    assert (diffs > 0).all(), times
    # per-layer log mirrors the charges: every layer costs real time
    for entry in eng.ledger.layer_log:
        assert entry["nonexpert"] > 0
        assert entry["moe"] >= 0


@pytest.mark.parametrize("policy", POLICIES)
def test_ledger_accounting_simulated(policy):
    cfg = get_config("mixtral-8x7b")
    eng = FiddlerEngine(cfg, policy=policy, seed=1)
    seen = _spy_decide(eng)
    eng.simulate_generate(prompt_len=32, gen_len=8, batch=4)
    led = eng.ledger
    assert led.fast_hits + led.streams + led.slow_runs == \
        sum(s["activated"] for s in seen)
    assert led.stream_bytes == led.streams * expert_weight_bytes(cfg)


def test_multi_slot_mask_excludes_padding():
    """decode_step_multi with one live slot of two: the planner must see
    exactly top_k assignments per layer and tokens_out advances by the
    live count only."""
    cfg, model, params = reduced_model("mixtral-8x7b")
    eng = FiddlerEngine(cfg, params, policy="fiddler", expert_budget=30,
                        host_precision="fp32")
    caches = eng.make_decode_caches(2, 32)
    # give slot 0 some KV history via a chunked prefill joined into slot 0
    logits, slot_cache = eng.prefill_chunk(
        jnp.asarray([[1, 5, 9]], jnp.int32), None, 0, 32)
    caches = eng.write_slot(caches, slot_cache, 0)
    seen = _spy_decide(eng)
    led = eng.ledger
    tokens_before = led.tokens_out
    decisions_before = led.fast_hits + led.streams + led.slow_runs
    tokens = jnp.asarray([[7], [0]], jnp.int32)
    active = np.array([True, False])
    _, caches = eng.decode_step_multi(caches, tokens, np.array([3, 0]),
                                      32, active=active)
    assert led.tokens_out == tokens_before + 1
    for s in seen:
        assert s["total"] == cfg.moe.top_k  # one live token only
    assert led.fast_hits + led.streams + led.slow_runs - decisions_before == \
        sum(s["activated"] for s in seen)


def test_mixed_batch_counts_reach_planner():
    """With two live slots the planner sees 2·top_k assignments — the
    expert counts reflect the mixed in-flight batch, not per-request
    singletons."""
    cfg, model, params = reduced_model("mixtral-8x7b")
    eng = FiddlerEngine(cfg, params, policy="fiddler", expert_budget=30,
                        host_precision="fp32")
    caches = eng.make_decode_caches(2, 32)
    for slot, prompt in enumerate([[1, 5, 9], [1, 8]]):
        _, sc = eng.prefill_chunk(jnp.asarray([prompt], jnp.int32), None, 0,
                                  32)
        caches = eng.write_slot(caches, sc, slot)
    seen = _spy_decide(eng)
    tokens = jnp.asarray([[7], [4]], jnp.int32)
    _, caches = eng.decode_step_multi(caches, tokens, np.array([3, 2]), 32)
    for s in seen:
        assert s["total"] == 2 * cfg.moe.top_k
