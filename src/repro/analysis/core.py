"""fiddlint core: findings, inline suppressions, the baseline file, and
the lint driver.

Suppressions are ruff-style but require a reason::

    x = float(logits[0])  # fiddlint: ignore[FID001] sampling is host-side

A suppression with no reason does not suppress — the point of the rule
set is that every tolerated violation documents *why* it is safe.  The
comment may sit on the flagged line or on the line directly above it.

The baseline file grandfathers findings by (rule, path, symbol) — line
numbers drift too easily to key on.  ``--update-baseline`` rewrites it
from the current findings; each entry carries a reason string.
"""
from __future__ import annotations

import json
import re
from dataclasses import dataclass, field
from pathlib import Path
from typing import Dict, List, Optional, Set, Tuple

from repro.analysis.config import FiddlintConfig
from repro.analysis.project import Project

SUPPRESS_RE = re.compile(
    r"#\s*fiddlint:\s*ignore\[([A-Z0-9,\s]+)\]\s*(\S.*)?$")


@dataclass(frozen=True)
class Finding:
    rule: str
    path: str              # repo-relative (or as-given) posix path
    line: int
    col: int
    message: str
    symbol: str = ""       # enclosing function qualname, for baselining

    def render(self) -> str:
        return f"{self.path}:{self.line}: {self.rule} {self.message}"

    def key(self) -> Tuple[str, str, str]:
        return (self.rule, self.path, self.symbol)


def scan_suppressions(lines: List[str]) -> Dict[int, Set[str]]:
    """{1-based line number: rule ids suppressed there}.  A trailing
    comment covers its own line; a standalone comment covers the first
    code line after its comment block, so a multi-line justification
    reads naturally above the flagged statement."""
    out: Dict[int, Set[str]] = {}
    for i, line in enumerate(lines, start=1):
        m = SUPPRESS_RE.search(line)
        if not m or not (m.group(2) or "").strip():
            continue  # no reason -> not a valid suppression
        rules = {r.strip() for r in m.group(1).split(",") if r.strip()}
        out.setdefault(i, set()).update(rules)
        if line.lstrip().startswith("#"):
            j = i  # 0-based index of the line after this one
            while j < len(lines) and lines[j].lstrip().startswith("#"):
                j += 1
            out.setdefault(j + 1, set()).update(rules)
    return out


class Baseline:
    def __init__(self, path: Optional[Path]):
        self.path = path
        self.entries: List[Dict[str, str]] = []
        if path is not None and path.is_file():
            data = json.loads(path.read_text())
            self.entries = list(data.get("findings", []))
        self._keys = {(e["rule"], e["path"], e.get("symbol", ""))
                      for e in self.entries}

    def covers(self, f: Finding) -> bool:
        return f.key() in self._keys

    @staticmethod
    def write(path: Path, findings: List[Finding],
              reason: str = "grandfathered at baseline creation") -> None:
        seen: Set[Tuple[str, str, str]] = set()
        entries = []
        for f in sorted(findings, key=lambda f: (f.path, f.line)):
            if f.key() in seen:
                continue
            seen.add(f.key())
            entries.append({"rule": f.rule, "path": f.path,
                            "symbol": f.symbol, "message": f.message,
                            "reason": reason})
        path.write_text(json.dumps(
            {"_comment": "fiddlint grandfathered findings; regenerate with "
                         "`python -m repro.analysis.lint --update-baseline`",
             "findings": entries}, indent=2) + "\n")


@dataclass
class LintResult:
    findings: List[Finding] = field(default_factory=list)   # actionable
    suppressed: List[Finding] = field(default_factory=list)
    baselined: List[Finding] = field(default_factory=list)

    @property
    def exit_code(self) -> int:
        return 1 if self.findings else 0


def relpath(p: Path) -> str:
    """Repo-relative posix path when possible — the stable key findings,
    suppressions, and baseline entries are matched on."""
    try:
        return p.resolve().relative_to(Path.cwd().resolve()).as_posix()
    except ValueError:
        return p.as_posix()


def run_lint(config: FiddlintConfig,
             project: Optional[Project] = None,
             use_baseline: bool = True) -> LintResult:
    """Run every selected rule over the configured paths."""
    from repro.analysis.rules import get_rules
    project = project or Project(config.paths)
    raw: List[Finding] = []
    for rule in get_rules(config.select):
        raw.extend(rule(project, config))
    raw.sort(key=lambda f: (f.path, f.line, f.rule))

    baseline = Baseline(Path(config.baseline)
                        if (use_baseline and config.baseline) else None)
    suppress_by_file = {
        relpath(sf.path): scan_suppressions(sf.lines)
        for sf in project.files}

    result = LintResult()
    for f in raw:
        supp = suppress_by_file.get(f.path, {})
        if f.rule in supp.get(f.line, set()):
            result.suppressed.append(f)
        elif baseline.covers(f):
            result.baselined.append(f)
        else:
            result.findings.append(f)
    return result
