"""Chaos layer: fault injection, watchdogs, and graceful degradation.

Covers the primitives (FaultInjector determinism, CircuitBreaker,
HostHealth, BlockMeta reservations), the serving-level defenses
(watchdog retry/fallback on host futures, KV-pressure evict→requeue
recovery, exhaustion drain), and the standing invariants: every request
completes under injected faults, zero paged-KV blocks leak, ledger
charges are complete (``fault_time == fault_overlapped +
fault_exposed``), and greedy outputs are preemption-invariant — faults
change *when* tokens appear, never *which*.
"""
import warnings

import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from conftest import reduced_model
from repro.configs import get_config
from repro.core.faults import (
    FAULT_KINDS,
    CircuitBreaker,
    FaultEvent,
    FaultInjector,
    HostHealth,
)
from repro.core.orchestrator import FiddlerEngine
from repro.models.paged_kv import BlockMeta, KVPoolExhausted
from repro.serving.backend import SimulatedBackend
from repro.serving.continuous import ContinuousEngine
from repro.serving.engine import Request


@pytest.fixture(scope="module")
def mixtral():
    return reduced_model("mixtral-8x7b")


# ---------------------------------------------------------------------------
# FaultInjector primitives
# ---------------------------------------------------------------------------


def _drive(seed, rates, steps=64):
    fi = FaultInjector(seed=seed, rates=rates)
    seq = []
    for s in range(steps):
        fi.begin_step(s)
        seq.append(tuple(k for k in FAULT_KINDS if fi.fires(k)))
    return seq


def test_injector_is_deterministic_in_seed_and_tick():
    rates = {k: 0.3 for k in FAULT_KINDS}
    assert _drive(7, rates) == _drive(7, rates)
    assert _drive(7, rates) != _drive(8, rates)


def test_injector_rng_independent_of_polling():
    """The rng only advances in begin_step: a site that polls twice (or
    never) must not shift later ticks' draws."""
    rates = {"host_stall": 0.5, "latency_spike": 0.5}
    a = FaultInjector(seed=3, rates=rates)
    b = FaultInjector(seed=3, rates=rates)
    got_a, got_b = [], []
    for s in range(40):
        a.begin_step(s)
        got_a.append(a.fires("host_stall") is not None)
        a.fires("host_stall")  # double poll
        a.fires("latency_spike")
        b.begin_step(s)
        got_b.append(b.fires("host_stall") is not None)
        # b never polls latency_spike: the event lapses at the next tick
    assert got_a == got_b


def test_scripted_event_preempts_random_draw():
    ev = FaultEvent("host_crash", step=5, magnitude=3.0)
    fi = FaultInjector(seed=0, rates={"host_crash": 1.0}, schedule=[ev])
    for s in range(6):
        fi.begin_step(s)
        got = fi.fires("host_crash")
        assert got is not None  # rate 1.0 fires every tick
    assert got is ev  # the scripted magnitude won at its tick
    assert fi.fires("host_crash") is None  # consumed


def test_begin_step_is_idempotent_and_monotone():
    fi = FaultInjector(seed=0, schedule=[FaultEvent("link_stall", 2)])
    fi.begin_step(2)
    fi.begin_step(2)   # same tick again: armed event survives
    assert fi.fires("link_stall") is not None
    fi.begin_step(1)   # going backwards is a no-op
    assert fi.step == 2


def test_unknown_rate_kind_rejected():
    with pytest.raises(AssertionError):
        FaultInjector(rates={"gamma_ray": 0.1})
    with pytest.raises(AssertionError):
        FaultEvent("gamma_ray", 0)


def test_circuit_breaker_state_machine():
    br = CircuitBreaker(fail_threshold=2, cooldown_s=1.0)
    assert br.state == "closed" and br.allow(0.0)
    br.record_failure(0.0)
    assert br.state == "closed"  # one failure: below threshold
    br.record_failure(0.0)
    assert br.state == "open" and not br.allow(0.5)
    assert br.allow(1.5)                  # cooldown over → half-open
    assert br.state == "half-open"
    br.record_failure(1.5)                # first failure re-opens
    assert not br.allow(2.0) and br.trips == 2
    assert br.allow(3.0)
    br.record_success()                   # verified success closes fully
    assert br.state == "closed" and br.failures == 0


def test_host_health_window_and_cooldown():
    h = HostHealth(unhealthy_after=2, window_steps=4, cooldown_steps=3)
    h.record_failure()
    for _ in range(4):
        h.tick()       # window passes failure-free: counter resets
    h.record_failure()
    assert not h.unhealthy  # old failure forgotten, this is the first
    h.record_failure()
    assert h.unhealthy and h.trips == 1
    for _ in range(3):
        h.tick()
    assert not h.unhealthy  # cooldown expired


# ---------------------------------------------------------------------------
# BlockMeta reservations (the kv_pressure mechanism)
# ---------------------------------------------------------------------------


def test_reserve_blocks_invisible_to_tables_and_checked():
    meta = BlockMeta(2, 64)
    taken = meta.reserve_blocks(3)
    assert len(taken) == 3 and meta.n_reserved == 3
    meta.check()   # reserved blocks keep the pool identity balanced
    free_before = meta.n_free
    meta.free_reserved(taken)
    assert meta.n_reserved == 0 and meta.n_free == free_before + 3
    meta.check()


def test_reserve_blocks_is_best_effort():
    meta = BlockMeta(1, 16)
    got = meta.reserve_blocks(10_000)   # more than the pool holds
    assert 0 < len(got) < 10_000
    assert meta.n_free == 0
    with pytest.raises(KVPoolExhausted):
        meta.write_span(0, 0, 1)   # pool empty: allocation must fail
    meta.free_reserved(got)
    meta.write_span(0, 0, 1)       # released blocks circulate again
    meta.check()


def test_injector_releases_held_blocks():
    meta = BlockMeta(2, 64)
    fi = FaultInjector(seed=0, schedule=[FaultEvent("kv_pressure", 0)],
                       kv_pressure_blocks=2, kv_pressure_hold=3)
    fi.begin_step(0)
    assert fi.kv_pressure_tick([meta]) == 2
    assert meta.n_reserved == 2
    for s in range(1, 3):
        fi.begin_step(s)
        assert meta.n_reserved == 2   # hold not yet expired
    fi.begin_step(3)
    assert meta.n_reserved == 0       # released on schedule
    # release_all is idempotent settlement
    fi.release_all()
    meta.check()


# ---------------------------------------------------------------------------
# serving-level chaos (simulated backend — paper-scale config, no weights)
# ---------------------------------------------------------------------------


def _chaos_serve(cfg, *, faults, n_requests=10, prompt=36, new=20,
                 max_seq=128, chunk=8, rebalance=16, max_steps=50_000,
                 on_exhausted="raise"):
    eng = FiddlerEngine(cfg, faults=faults, rebalance_interval=rebalance)
    be = SimulatedBackend(eng, max_seq=max_seq)
    ce = ContinuousEngine(be, n_slots=4, max_seq=max_seq,
                          prefill_chunk=chunk)
    rng = np.random.default_rng(0)
    for r in range(n_requests):
        ce.submit(Request(rid=str(r),
                          prompt=list(rng.integers(5, 99, prompt)),
                          max_new_tokens=new, arrival=0.002 * r))
    done = ce.run(max_steps=max_steps, on_exhausted=on_exhausted)
    return ce, eng, done


@pytest.mark.parametrize("seed", [0, 1, 2])
def test_chaos_run_completes_without_leaks(seed):
    fi = FaultInjector(seed=seed, rates={k: 0.1 for k in FAULT_KINDS})
    cfg = get_config("mixtral-8x7b")
    ce, eng, done = _chaos_serve(cfg, faults=fi)
    assert len(done) == 10
    assert all(len(r.output) > 0 for r in done)
    meta = ce.cache["meta"]
    meta.check()
    assert meta.blocks_in_use() == 0, "leaked paged-KV blocks"
    assert meta.n_reserved == 0, "injector left blocks pinned"
    led = eng.ledger
    assert led.fault_time == pytest.approx(
        led.fault_overlapped + led.fault_exposed)
    assert led.fault_time > 0 and led.retries > 0
    assert sum(fi.stats()["injected"].values()) > 0


def test_kv_pressure_forces_recovery_and_outputs_are_invariant():
    """Scripted pool-pressure spikes big enough to exhaust the pool must
    drive the evict→requeue→re-prefill path — and greedy outputs must be
    bit-identical to the fault-free run."""
    cfg = get_config("mixtral-8x7b")
    sched = [FaultEvent("kv_pressure", s, magnitude=12.0)
             for s in (3, 9, 15)]
    fi = FaultInjector(seed=0, schedule=sched, kv_pressure_blocks=16,
                       kv_pressure_hold=3)
    ce, eng, done = _chaos_serve(cfg, faults=fi, n_requests=8, prompt=30,
                                 new=16, max_seq=64)
    assert len(done) == 8
    assert sum(r.preemptions for r in done) > 0, \
        "pressure never exercised the recovery path"
    assert eng.ledger.retries > 0
    meta = ce.cache["meta"]
    meta.check()
    assert meta.blocks_in_use() == 0

    ce2, _, done2 = _chaos_serve(cfg, faults=None, n_requests=8, prompt=30,
                                 new=16, max_seq=64)
    assert ({r.rid: r.output for r in done}
            == {r.rid: r.output for r in done2})


def test_degraded_mode_reroutes_slow_tier():
    """Back-to-back host crashes flip HostHealth unhealthy; the planner
    must stop scheduling SLOW experts while degraded (SLOW→stream
    remap), and the degraded ticks must be charged to the ledger."""
    cfg = get_config("mixtral-8x7b")
    sched = [FaultEvent("host_crash", s) for s in range(2, 12)]
    fi = FaultInjector(seed=0, schedule=sched)
    ce, eng, done = _chaos_serve(cfg, faults=fi)
    assert len(done) == 10
    assert eng.host_health.trips > 0
    assert eng.ledger.degraded_steps > 0


def test_exhaustion_drain_releases_all_blocks():
    """Satellite regression: run() with an exhausted step budget must
    drain in-flight slots — zero leaked blocks, requests requeued with
    their progress intact."""
    cfg = get_config("mixtral-8x7b")
    eng = FiddlerEngine(cfg)
    be = SimulatedBackend(eng, max_seq=64)
    ce = ContinuousEngine(be, n_slots=4, max_seq=64, prefill_chunk=8)
    for r in range(4):
        ce.submit(Request(rid=str(r), prompt=list(range(5, 25)),
                          max_new_tokens=16, arrival=0.0))
    with warnings.catch_warnings(record=True) as w:
        warnings.simplefilter("always")
        out = ce.run(max_steps=5, on_exhausted="warn")
    assert len(out) == 0 and ce.active == 0
    assert len(ce.queue) == 4        # drained back, nothing dropped
    meta = ce.cache["meta"]
    meta.check()
    assert meta.blocks_in_use() == 0, "exhaustion leaked paged-KV blocks"
    assert any("drained" in str(x.message) for x in w)
    # the drained requests keep their emitted tokens for a future resume
    assert any(r.output for r in ce.queue)


def test_exhaustion_drain_on_raise():
    cfg = get_config("mixtral-8x7b")
    eng = FiddlerEngine(cfg)
    be = SimulatedBackend(eng, max_seq=64)
    ce = ContinuousEngine(be, n_slots=2, max_seq=64, prefill_chunk=8)
    ce.submit(Request(rid="r", prompt=list(range(5, 25)),
                      max_new_tokens=16, arrival=0.0))
    with pytest.raises(RuntimeError, match="drained"):
        ce.run(max_steps=2, on_exhausted="raise")
    meta = ce.cache["meta"]
    meta.check()
    assert meta.blocks_in_use() == 0


# ---------------------------------------------------------------------------
# property test: random seeded fault schedules
# ---------------------------------------------------------------------------


@settings(max_examples=12, deadline=None)
@given(seed=st.integers(min_value=0, max_value=2**16),
       rate=st.floats(min_value=0.0, max_value=0.3),
       spike=st.booleans())
def test_random_fault_schedules_conserve_invariants(seed, rate, spike):
    """Any seeded fault schedule: every request completes, block
    refcounts balance (meta.check + zero in use), ledger charges are
    complete, and greedy outputs match the fault-free twin."""
    cfg = get_config("mixtral-8x7b")
    rates = {k: rate for k in FAULT_KINDS}
    sched = ([FaultEvent("kv_pressure", s, magnitude=10.0)
              for s in (4, 11)] if spike else [])
    fi = FaultInjector(seed=seed, rates=rates, schedule=sched,
                       kv_pressure_blocks=12, kv_pressure_hold=2)
    ce, eng, done = _chaos_serve(cfg, faults=fi, n_requests=6, prompt=24,
                                 new=12, max_seq=64)
    assert len(done) == 6
    assert all(len(r.output) > 0 for r in done)
    meta = ce.cache["meta"]
    meta.check()
    assert meta.blocks_in_use() == 0
    assert meta.n_reserved == 0
    led = eng.ledger
    assert led.fault_time == pytest.approx(
        led.fault_overlapped + led.fault_exposed)
    assert led.sim_time > 0

    ce2, _, done2 = _chaos_serve(cfg, faults=None, n_requests=6, prompt=24,
                                 new=12, max_seq=64)
    assert ({r.rid: r.output for r in done}
            == {r.rid: r.output for r in done2})


# ---------------------------------------------------------------------------
# real numerics: watchdog retry/fallback must not perturb fp32 outputs
# ---------------------------------------------------------------------------


def _forward(eng, tokens, n_decode=2):
    outs = []
    logits, caches = eng.prefill(tokens, max_seq=32)
    outs.append(np.asarray(logits))
    for step in range(n_decode):
        logits, caches = eng.decode_step(caches, tokens[:, :1],
                                         pos=tokens.shape[1] + step,
                                         max_seq=32)
        outs.append(np.asarray(logits))
    return outs


def test_host_fault_retry_is_bit_identical(mixtral):
    """Injected worker stalls/crashes exercise watchdog → retry →
    inline fallback; every path re-runs the same fp32 kernel, so logits
    must be bit-identical to the fault-free engine.  Also guards the
    fault-free path: attaching an idle injector changes nothing."""
    cfg, model, params = mixtral
    kw = dict(expert_budget=cfg.n_layers * cfg.moe.n_experts // 2,
              host_precision="fp32")
    tokens = np.arange(1, 9, dtype=np.int32)[None, :]
    base = _forward(FiddlerEngine(cfg, params, **kw), tokens)

    idle = FiddlerEngine(cfg, params, faults=FaultInjector(seed=0), **kw)
    for a, b in zip(base, _forward(idle, tokens)):
        assert np.array_equal(a, b)
    assert idle.ledger.fault_time == 0.0

    sched = [FaultEvent("host_stall", 0), FaultEvent("host_crash", 1)]
    for step0 in (0, 1):
        fi = FaultInjector(seed=0, schedule=sched)
        eng = FiddlerEngine(cfg, params, faults=fi, **kw)
        eng.begin_fault_step(step0)   # arm stall (0) or crash (1)
        got = _forward(eng, tokens)
        for a, b in zip(base, got):
            assert np.array_equal(a, b), "host-fault retry changed logits"
        assert eng.ledger.retries > 0
        assert eng.ledger.fault_time > 0
