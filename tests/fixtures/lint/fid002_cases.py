"""FID002 fixture: jit-cache explosion via unbucketed dims / runtime jit.

Hot root for this module: ``Engine.run``.
"""
import jax
import jax.numpy as jnp


def _bucket(n):
    m = 1
    while m < n:
        m *= 2
    return m


class Engine:
    def run(self, tokens, enc):
        n = len(tokens)
        pad = jnp.zeros((n, 4))  # EXPECT: FID002
        cap = _bucket(len(tokens))
        good = jnp.zeros((cap, 4))  # ok: bucketed capacity
        k, v = enc
        pos = jnp.arange(k.shape[1])  # ok: param-derived geometry
        fresh = jax.jit(lambda t: t + 1)  # EXPECT: FID002
        lim = min(cap, 128)
        also_good = jnp.ones((lim, 2))  # ok: min() over a bucketed value
        return pad, good, pos, fresh, also_good

    def cold(self, tokens):
        # false-positive candidate: same unbucketed pattern, but this
        # method is not reachable from the hot root
        return jnp.zeros((len(tokens), 4))
