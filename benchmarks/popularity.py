"""Paper Figure 8 + Appendix C: expert-popularity heat map statistics and
best/worst/random placement hit rates at the paper's two memory budgets
(56/256 and 125/256 experts).

The profile comes from REAL routing of a reduced Mixtral over synthetic
ShareGPT-like prompts (same pipeline the serving path uses), scaled to the
paper's 32×8 expert grid via the synthetic profile for the budget study.
"""
import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import emit
from repro.configs import get_config
from repro.core.placement import PlacementReport
from repro.core.popularity import ExpertProfile, synthetic_profile
from repro.data.pipeline import sample_prompts
from repro.models import Model
from repro.models.layers import rmsnorm
from repro.models.moe import route


def routed_profile(n_prompts: int = 8, seq: int = 64) -> ExpertProfile:
    """Real routing trace of the reduced Mixtral on the data pipeline."""
    cfg = get_config("mixtral-8x7b").reduced()
    model = Model(cfg, param_dtype=jnp.float32)
    params = model.init(jax.random.PRNGKey(0))
    prompts = jnp.asarray(sample_prompts(cfg, n=n_prompts, min_tokens=seq))
    prof = ExpertProfile.empty(cfg.n_layers, cfg.moe.n_experts)
    x = model.embed(params, prompts)
    blocks = params["blocks"][0]
    from repro.models.model import NO_PARALLEL, apply_sublayer
    for li in range(cfg.n_layers):
        p = jax.tree.map(lambda a, i=li: a[i], blocks)
        normed = rmsnorm(p["norm2"], x, cfg.norm_eps).reshape(-1, cfg.d_model)
        _, idx, _ = route(p["moe"]["router"], normed, cfg.moe)
        prof.update(li, np.asarray(idx))
        positions = jnp.broadcast_to(jnp.arange(x.shape[1])[None], x.shape[:2])
        x, _, _ = apply_sublayer(p, x, positions, cfg, 0, li, NO_PARALLEL,
                                 mode="train", cache=None, max_seq=None)
    return prof


def run(fast: bool = False):
    prof = routed_profile(n_prompts=2 if fast else 8)
    norm = prof.normalized()
    emit("popularity/real/normalized_mean", 0.0,
         f"mean={norm.mean():.2f} std={norm.std():.2f} "
         f"(paper fig8: mean 0.71 std 0.08)")

    # paper App. C budget study on the 32×8 grid
    prof_full = synthetic_profile(32, 8, seed=0, concentration=12.0)
    for budget, env in ((56, "env1"), (125, "env2")):
        rep = PlacementReport.build(prof_full, budget)
        emit(f"popularity/hit_rate/{env}", 0.0,
             f"best={rep.best*100:.1f}% worst={rep.worst*100:.1f}% "
             f"random={rep.random*100:.1f}% "
             f"(paper {'25.2/18.7/21.9' if env == 'env1' else '53.0/44.6/48.8'})")
        assert rep.best > rep.random > rep.worst
    return prof


if __name__ == "__main__":
    run()
