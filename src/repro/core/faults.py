"""Deterministic fault injection + resilience primitives (docs/resilience.md).

The serving hot path straddles two failure-prone domains: a host
``ThreadPoolExecutor`` running CPU expert kernels concurrently with the
fast-tier launches, and an async prefetch queue moving expert weights
over the link mid-decode.  :class:`FaultInjector` is the single seam all
three ``ServingBackend``\\s consult to exercise those failure modes on
purpose — seeded and scripted, so every chaos run is reproducible bit
for bit and a recovery regression is a deterministic test failure, not
a flake.

Fault kinds (``FAULT_KINDS``):

* ``host_stall``      — a slow-tier worker hangs (real path: the
  submitted kernel sleeps past the watchdog; simulation: the stall
  penalty is charged directly).
* ``host_crash``      — a slow-tier worker dies mid-kernel
  (:class:`HostWorkerFault`); the watchdog's retry path resubmits the
  clean kernel.
* ``link_stall``      — the slow↔fast link blocks for a beat while
  transfers are in flight.
* ``prefetch_lost`` / ``prefetch_corrupt`` — a completed async
  promotion transfer fails verification and must be requeued at full
  length (feeds the link :class:`CircuitBreaker`).
* ``latency_spike``   — an unattributed per-step latency spike
  (background load, SMI, page fault storm).
* ``kv_pressure``     — a transient KV block-pool pressure spike:
  blocks are reserved out of the pool for a few ticks
  (``BlockMeta.reserve_blocks``), forcing admission/decode into the
  exhaustion→recovery path.

Faults arm at :meth:`FaultInjector.begin_step` — once per scheduler
tick — from two deterministic sources: an explicit scripted
``schedule`` of :class:`FaultEvent`\\s, and per-kind Bernoulli ``rates``
drawn from a seeded generator in fixed kind order.  Injection sites
then *consume* armed events via :meth:`FaultInjector.fires`; at most
one event per kind arms per tick, and unconsumed events lapse at the
next tick (an armed host fault on a tick that ran no slow experts never
happened).  The rng state only advances inside ``begin_step``, so the
fault sequence depends on the seed and the tick count alone — never on
how many sites polled.

The defenses these faults exercise live in the orchestrator and the
serving engines: watchdog timeouts with bounded retry/backoff on
host-expert futures, prefetch transfer verification with
requeue-on-failure behind the circuit breaker, degraded SLOW→stream
routing while the host tier is unhealthy (:class:`HostHealth`), and
slot-level evict→requeue→re-prefill recovery in
``ContinuousEngine``.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Dict, List, Optional, Sequence, Tuple

import numpy as np

FAULT_KINDS = (
    "host_stall",
    "host_crash",
    "link_stall",
    "prefetch_lost",
    "prefetch_corrupt",
    "latency_spike",
    "kv_pressure",
)


class FaultError(RuntimeError):
    """Base class of injected faults that escape their injection site.

    Recovery code catches *this* (plus ``KVPoolExhausted``) — never bare
    ``Exception`` (fiddlint FID006): an injected fault is recoverable by
    construction, an arbitrary exception is a bug that must surface."""


class HostWorkerFault(FaultError):
    """An injected slow-tier worker crash (raised inside the submitted
    kernel; surfaces through the future on the scheduler thread)."""


@dataclass(frozen=True)
class FaultEvent:
    """One armed fault: ``kind`` at scheduler tick ``step``;
    ``magnitude`` scales the kind's base penalty/size knob."""
    kind: str
    step: int
    magnitude: float = 1.0

    def __post_init__(self):
        assert self.kind in FAULT_KINDS, self.kind


class FaultInjector:
    """Seeded, scripted fault source — see the module docstring.

    ``rates`` maps fault kind → per-tick Bernoulli probability;
    ``schedule`` is an explicit sequence of :class:`FaultEvent`\\s fired
    at exact ticks (both may be used together — a scripted event
    pre-empts that tick's random draw for its kind).  The remaining
    knobs size the injected damage and the matching defense:

    * ``host_stall_s`` / ``latency_spike_s`` / ``link_stall_s`` —
      simulated-seconds penalty per fired fault (scaled by the event's
      ``magnitude``).
    * ``kv_pressure_blocks`` / ``kv_pressure_hold`` — blocks reserved
      out of each consulted pool per ``kv_pressure`` event, and how
      many ticks they stay reserved.
    * ``real_stall_s`` — *wall-clock* sleep an injected stall adds to a
      real host worker (long enough that ``watchdog_s`` — the watchdog
      timeout the orchestrator uses while an injector is attached —
      genuinely expires first).
    """

    def __init__(self, seed: int = 0,
                 rates: Optional[Dict[str, float]] = None,
                 schedule: Sequence[FaultEvent] = (), *,
                 host_stall_s: float = 5e-3,
                 latency_spike_s: float = 5e-3,
                 link_stall_s: float = 5e-3,
                 kv_pressure_blocks: int = 4,
                 kv_pressure_hold: int = 4,
                 real_stall_s: float = 0.05,
                 watchdog_s: float = 0.005):
        self.rates = dict(rates or {})
        unknown = set(self.rates) - set(FAULT_KINDS)
        assert not unknown, f"unknown fault kinds: {sorted(unknown)}"
        self.schedule = sorted(schedule, key=lambda ev: ev.step)
        self.rng = np.random.default_rng(seed)
        self.host_stall_s = float(host_stall_s)
        self.latency_spike_s = float(latency_spike_s)
        self.link_stall_s = float(link_stall_s)
        self.kv_pressure_blocks = int(kv_pressure_blocks)
        self.kv_pressure_hold = int(kv_pressure_hold)
        self.real_stall_s = float(real_stall_s)
        self.watchdog_s = float(watchdog_s)
        self.step = -1
        self._armed: Dict[str, FaultEvent] = {}
        # consumed (actually delivered) events per kind; armed counts
        # every arming including ones that lapsed unconsumed
        self.injected: Dict[str, int] = {k: 0 for k in FAULT_KINDS}
        self.armed_total: Dict[str, int] = {k: 0 for k in FAULT_KINDS}
        # live kv-pressure holds: (pool meta, reserved block ids,
        # release-at step)
        self._held: List[Tuple[object, List[int], int]] = []

    # -- tick protocol -----------------------------------------------------
    def begin_step(self, step: Optional[int] = None) -> None:
        """Advance to scheduler tick ``step`` (monotone; ``None``
        auto-increments), release expired KV-pressure holds, and arm
        this tick's faults.  Unconsumed events from the previous tick
        lapse.  Repeated calls with the same step are idempotent."""
        step = self.step + 1 if step is None else int(step)
        if step <= self.step:
            return
        self.step = step
        self._release_due(step)
        self._armed = {}
        for ev in self.schedule:
            if ev.step == step:
                self._armed[ev.kind] = ev
        for kind in FAULT_KINDS:  # fixed order: rng stream is stable
            rate = self.rates.get(kind, 0.0)
            if rate <= 0.0:
                continue
            hit = self.rng.random() < rate
            if hit and kind not in self._armed:
                self._armed[kind] = FaultEvent(kind, step)
        for kind in self._armed:
            self.armed_total[kind] += 1

    def fires(self, kind: str) -> Optional[FaultEvent]:
        """Consume this tick's armed ``kind`` event, if any.  Each event
        is delivered at most once."""
        ev = self._armed.pop(kind, None)
        if ev is not None:
            self.injected[kind] += 1
        return ev

    # -- kv pressure -------------------------------------------------------
    def kv_pressure_tick(self, metas: Sequence[object]) -> int:
        """Consume an armed ``kv_pressure`` event by reserving blocks
        out of every pool in ``metas`` (``BlockMeta.reserve_blocks`` —
        best-effort, never raises) for ``kv_pressure_hold`` ticks.
        Returns the number of blocks reserved."""
        ev = self.fires("kv_pressure")
        if ev is None:
            return 0
        want = max(1, int(round(ev.magnitude * self.kv_pressure_blocks)))
        taken = 0
        for meta in metas:
            blocks = meta.reserve_blocks(want)
            if blocks:
                self._held.append(
                    (meta, blocks, self.step + self.kv_pressure_hold))
                taken += len(blocks)
        return taken

    def _release_due(self, step: int) -> None:
        keep = []
        for meta, blocks, until in self._held:
            if step >= until:
                meta.free_reserved(blocks)
            else:
                keep.append((meta, blocks, until))
        self._held = keep

    def release_all(self) -> None:
        """Return every still-held reserved block to its pool — the
        finalize/settlement hook, so a run always ends with zero blocks
        pinned by the injector."""
        for meta, blocks, _ in self._held:
            meta.free_reserved(blocks)
        self._held = []

    def stats(self) -> Dict[str, Dict[str, int]]:
        return {"injected": dict(self.injected),
                "armed": dict(self.armed_total)}


@dataclass
class HostHealth:
    """Sliding-window health tracker for the slow tier.

    ``unhealthy_after`` worker failures within ``window_steps``
    scheduler ticks flip the tier unhealthy for ``cooldown_steps``
    ticks; while unhealthy the planner re-routes SLOW experts through
    the FAST_STREAM path (degraded mode — see
    ``FiddlerEngine._reroute_slow``).  ``tick()`` is called once per
    scheduler tick."""
    unhealthy_after: int = 2
    window_steps: int = 16
    cooldown_steps: int = 8
    failures: int = 0
    trips: int = 0
    _since_failure: int = field(default=0, repr=False)
    _unhealthy_left: int = field(default=0, repr=False)

    def record_failure(self) -> None:
        self.failures += 1
        self._since_failure = 0
        if self.failures >= self.unhealthy_after:
            self.trips += 1
            self._unhealthy_left = self.cooldown_steps
            self.failures = 0

    def tick(self) -> None:
        if self._unhealthy_left > 0:
            self._unhealthy_left -= 1
        self._since_failure += 1
        if self._since_failure >= self.window_steps:
            self.failures = 0

    @property
    def unhealthy(self) -> bool:
        return self._unhealthy_left > 0


class CircuitBreaker:
    """Closed → open → half-open breaker over the migration link.

    ``fail_threshold`` consecutive transfer-verification failures open
    the breaker for ``cooldown_s`` simulated seconds — while open,
    ``maybe_rebalance`` plans no new migrations (in-flight prefetches
    still drain).  After the cooldown the breaker is *half-open*: plans
    flow again, but the first failure re-opens it immediately; a
    verified success closes it fully."""

    def __init__(self, fail_threshold: int = 2, cooldown_s: float = 0.05):
        assert fail_threshold >= 1, fail_threshold
        self.fail_threshold = int(fail_threshold)
        self.cooldown_s = float(cooldown_s)
        self.failures = 0          # consecutive verification failures
        self.trips = 0
        self.open_until = float("-inf")
        self._half_open = False

    def allow(self, now: float) -> bool:
        if now < self.open_until:
            return False
        if self.open_until > float("-inf"):
            self._half_open = True
        return True

    def record_failure(self, now: float) -> None:
        self.failures += 1
        threshold = 1 if self._half_open else self.fail_threshold
        if self.failures >= threshold:
            self.trips += 1
            self.failures = 0
            self._half_open = False
            self.open_until = now + self.cooldown_s

    def record_success(self) -> None:
        self.failures = 0
        self._half_open = False
        self.open_until = float("-inf")

    @property
    def state(self) -> str:
        if self._half_open:
            return "half-open"
        return "open" if self.open_until > float("-inf") else "closed"
