"""Fiddler's contribution: cost model, placement, planner, orchestrator."""
from repro.core.cost_model import HardwareSpec, LatencyModel  # noqa: F401
from repro.core.orchestrator import FiddlerEngine, Ledger  # noqa: F401
from repro.core.placement import (  # noqa: F401
    Placement,
    PlacementReport,
    fast_tier_expert_budget,
    hit_rate,
    place_by_popularity,
    place_random,
    place_static_split,
    place_worst,
)
from repro.core.planner import Decision, LayerPlan, plan_layer  # noqa: F401
from repro.core.popularity import (  # noqa: F401
    ExpertProfile,
    OnlineProfile,
    synthetic_profile,
)
from repro.core.rebalance import (  # noqa: F401
    MigrationPlan,
    PrefetchQueue,
    Rebalancer,
)
