"""Continuous batching: a fixed pool of decode slots, each at its own
position; requests join as slots free up and leave on EOS/max-tokens —
no head-of-line blocking like the static grouped engine.

Runs over any ``ServingBackend``:

* ``ModelBackend``   — jitted monolithic ``Model`` (scatter cache writes,
  see kv_cache.write_decode_multi); wall-clock metrics.
* ``FiddlerBackend`` — the paper's CPU-GPU orchestrator: the planner sees
  the mixed in-flight batch's expert counts each step and the ledger
  advances in simulated seconds, which is also the clock that TTFT/ITL
  are recorded from.

Admission can be **chunked** (``prefill_chunk=N``): a long prompt is
prefilled N tokens per engine step into a batch-1 staging cache while the
in-flight slots keep decoding, then joins the multi-slot cache — so one
long admission never stalls the whole pool.  Requests may carry an
``arrival`` time (load generators set it in backend-clock units); the
engine admits a request only once the clock has reached it.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Any, List, Optional

import numpy as np

from repro.data.tokenizer import EOS_ID, PAD_ID
from repro.serving.backend import ServingBackend, as_backend
from repro.serving.engine import Request
from repro.serving.sampler import greedy


@dataclass
class _Slot:
    req: Optional[Request] = None
    phase: str = "idle"        # idle | prefill | decode
    pos: int = 0               # next decode position
    last_token: int = 0
    steps_left: int = 0
    staging: Any = None        # batch-1 cache being chunk-prefilled
    prefilled: int = 0         # prompt tokens already processed


class ContinuousEngine:
    def __init__(self, backend, params=None, *, n_slots: int = 4,
                 max_seq: int = 256, prefill_chunk: Optional[int] = None):
        """``backend``: a ``ServingBackend``, or a ``Model`` together with
        ``params`` (coerced to a ``ModelBackend`` for back-compat).
        ``prefill_chunk=None`` admits whole prompts in one step (exactly
        the monolithic prefill numerics); an integer enables chunked
        admission."""
        if prefill_chunk is not None and prefill_chunk < 1:
            raise ValueError(
                f"prefill_chunk must be >= 1 (or None for whole-prompt "
                f"admission), got {prefill_chunk}")
        if not isinstance(backend, ServingBackend):
            backend = as_backend(backend, params=params, max_seq=max_seq)
        assert backend.max_seq == max_seq, (backend.max_seq, max_seq)
        self.backend = backend
        self.n_slots = n_slots
        self.max_seq = max_seq
        self.prefill_chunk = prefill_chunk
        self.queue: List[Request] = []
        self.slots = [_Slot() for _ in range(n_slots)]
        self.cache = backend.make_cache(n_slots)
        self.steps = 0
        self.finished: List[Request] = []

    # ------------------------------------------------------------------
    def clock(self) -> float:
        return self.backend.clock()

    def submit(self, req: Request) -> None:
        if req.arrival is None:
            req.arrival = self.clock()
        self.queue.append(req)

    @property
    def active(self) -> int:
        return sum(1 for s in self.slots if s.req is not None)

    # ------------------------------------------------------------------
    def _admit(self) -> None:
        now = self.clock()
        for slot in self.slots:
            if slot.req is not None or not self.queue:
                continue
            if self.queue[0].arrival is not None and \
                    self.queue[0].arrival > now:
                break  # FIFO: head hasn't arrived yet
            req = self.queue.pop(0)
            slot.req = req
            slot.phase = "prefill"
            slot.staging = None
            slot.prefilled = 0

    def _prefill_step(self) -> None:
        """Advance every prefilling slot by one chunk (or the whole prompt
        when chunking is off)."""
        for i, slot in enumerate(self.slots):
            if slot.phase != "prefill":
                continue
            req = slot.req
            if self.prefill_chunk is None:
                logits, slot.staging = self.backend.prefill(req.prompt)
                slot.prefilled = len(req.prompt)
            else:
                chunk = req.prompt[slot.prefilled:
                                   slot.prefilled + self.prefill_chunk]
                logits, slot.staging = self.backend.prefill_chunk(
                    slot.staging, chunk, slot.prefilled)
                slot.prefilled += len(chunk)
                if slot.prefilled < len(req.prompt):
                    continue  # more chunks; in-flight decodes run meanwhile
            # prompt complete: first token, join the multi-slot batch
            tok = int(np.argmax(logits))
            now = self.clock()
            req.output.append(tok)
            req.token_times.append(now)
            req.ttft = now - req.arrival
            self.cache = self.backend.write_slot(self.cache, slot.staging, i)
            slot.staging = None
            slot.phase = "decode"
            slot.pos = len(req.prompt)
            slot.last_token = tok
            slot.steps_left = req.max_new_tokens - 1
            if tok == EOS_ID or slot.steps_left <= 0:
                self._retire(i)

    def _retire(self, i: int) -> None:
        slot = self.slots[i]
        if slot.req is not None:
            slot.req.latency = self.clock() - slot.req.arrival
            self.finished.append(slot.req)
        self.slots[i] = _Slot()

    def _decode_step(self) -> None:
        decoding = [s.phase == "decode" for s in self.slots]
        if not any(decoding):
            return
        tokens = np.full((self.n_slots,), PAD_ID, np.int32)
        pos = np.zeros((self.n_slots,), np.int32)
        for i, s in enumerate(self.slots):
            if decoding[i]:
                tokens[i] = s.last_token
                pos[i] = s.pos
        logits, self.cache = self.backend.decode_slots(
            self.cache, tokens, pos, np.asarray(decoding))
        next_tok = greedy(logits)
        now = self.clock()
        self.steps += 1
        for i, s in enumerate(self.slots):
            if not decoding[i]:
                continue
            tok = int(next_tok[i])
            s.req.output.append(tok)
            s.req.token_times.append(now)
            s.pos += 1
            s.last_token = tok
            s.steps_left -= 1
            if tok == EOS_ID or s.steps_left <= 0 or s.pos >= self.max_seq - 1:
                self._retire(i)

    def step(self) -> None:
        """One scheduler tick: admit → advance prefills one chunk → one
        decode step for every decoding slot."""
        self._admit()
        self._prefill_step()
        self._decode_step()

    def run(self, max_steps: int = 10_000) -> List[Request]:
        steps = 0
        while (self.queue or self.active) and steps < max_steps:
            if self.active == 0 and self.queue and \
                    self.queue[0].arrival is not None and \
                    self.queue[0].arrival > self.clock():
                # pool idle, next request hasn't arrived: fast-forward
                self.backend.wait_until(self.queue[0].arrival)
            self.step()
            steps += 1
        return self.finished
