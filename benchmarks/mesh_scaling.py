"""Expert-parallel mesh scaling: throughput vs fast-device count.

Paper-scale pure simulation (full Mixtral-8x7B config, param-less
engine) of the continuous-batching scheduler at a saturating Poisson
rate, swept over ``n_fast_devices`` D ∈ {1, 2, 4} on the paper's env2
hardware spec — every fast device is one RTX 6000 Ada's worth of HBM,
so D=2 nearly doubles expert residency and D=4 makes the whole model
fast-resident.  Each extra fast
device adds one chip's worth of expert residency (``expert_budget`` is
per device), one host↔device DMA link for migration prefetches, and its
own share of the dispatch/combine all-to-all — so throughput must grow
with D, and the ledger must show the fabric was *charged*, not assumed
free: ``alltoall_time > 0`` on every D > 1 point, and dynamic
rebalancing stays on so every planned migration pays link time
(``migration_time > 0`` whenever ``migrations > 0``; a fully resident
D=4 model correctly plans none).  Each device also owns its own
paged-KV pool shard in the ``SimulatedBackend``; the per-device leak
audit must come back all zeros after every run.

A reduced real-numerics twin checks the other half of the contract: an
engine built through the mesh path at 1×1 (``make_serving_mesh("1,1")``
→ no mesh object, one fast device, global paged-KV pool) must produce
fp32 **bit-identical** prefill + decode logits to the historical
single-device engine (``bit_identical_fp32`` in the JSON).

CI gates (.github/workflows/ci.yml mesh-smoke lane, --smoke mode):
throughput monotone in D, zero leaked blocks per device, and
``bit_identical_fp32`` true.  The committed full run additionally shows
>= 1.7x throughput from 1 -> 2 devices and >= 3x from 1 -> 4.
Results land in ``BENCH_mesh_scaling.json``.
"""
from __future__ import annotations

import json
from pathlib import Path
from typing import Dict, List

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import ENVS, emit
from repro.configs import get_config
from repro.core import FiddlerEngine
from repro.launch.mesh import make_serving_mesh
from repro.serving.backend import SimulatedBackend
from repro.serving.continuous import ContinuousEngine
from repro.serving.engine import Request

SIM_MAX_SEQ = 256
SIM_PREFILL_CHUNK = 16
DEVICE_COUNTS = (1, 2, 4)
RESULTS_JSON = Path(__file__).resolve().parents[1] / "BENCH_mesh_scaling.json"


def poisson_requests(rate_hz: float, n: int, *, prompt_len: int = 64,
                     max_new: int = 24, seed: int = 0) -> List[Request]:
    rng = np.random.default_rng(seed)
    t, reqs = 0.0, []
    for i in range(n):
        t += rng.exponential(1.0 / rate_hz)
        plen = int(rng.integers(prompt_len // 2, prompt_len + 1))
        prompt = [1] + rng.integers(3, 250, size=plen - 1).tolist()
        reqs.append(Request(rid=f"r{i}", prompt=prompt,
                            max_new_tokens=max_new, arrival=t))
    return reqs


def simulate_scale(model_name: str, env: str, n_devices: int, *,
                   rate_hz: float, n_slots: int, n_requests: int,
                   seed: int = 0) -> Dict[str, float]:
    """One sweep point: paper-scale simulation with ``n_devices`` fast
    devices, dynamic rebalancing on (so the per-link migration cost is
    exercised), per-device KV pools in the backend."""
    cfg = get_config(model_name)
    eng = FiddlerEngine(cfg, policy="fiddler", hw=ENVS[env], seed=seed,
                        n_fast_devices=n_devices, rebalance_interval=16)
    serving = ContinuousEngine(SimulatedBackend(eng, max_seq=SIM_MAX_SEQ),
                               n_slots=n_slots, max_seq=SIM_MAX_SEQ,
                               prefill_chunk=SIM_PREFILL_CHUNK)
    for r in poisson_requests(rate_hz, n_requests, seed=seed):
        serving.submit(r)
    done = serving.run(max_steps=200_000, on_exhausted="raise")
    assert len(done) == n_requests, (len(done), n_requests)

    led = eng.ledger
    n_tokens = sum(len(r.output) for r in done)
    leaked = serving.backend.kv_check(serving.cache)
    busy = list(led.device_busy) or [0.0]
    return {
        "n_devices": n_devices,
        "throughput_tok_per_s": n_tokens / led.sim_time if led.sim_time
        else 0.0,
        "mean_ttft": float(np.mean([r.ttft for r in done])),
        "hit_rate": led.fast_hits / max(led.fast_hits + led.streams
                                        + led.slow_runs, 1),
        "resident_experts": int(eng.expert_budget),
        "alltoall_time": led.alltoall_time,
        "alltoall_exposed": led.alltoall_exposed,
        "migrations": led.migrations,
        "migration_time": led.migration_time,
        "device_busy": busy,
        "busy_balance": min(busy) / max(busy) if max(busy) else 1.0,
        "leaked_blocks_per_device": leaked,
        "leaked_blocks": int(sum(leaked)),
    }


def bit_identity_1x1(model_name: str, seed: int = 0) -> bool:
    """fp32 prefill + decode logits of the mesh-path 1x1 engine vs the
    historical single-device engine, on reduced real numerics."""
    from repro.models import Model

    full = get_config(model_name)
    cfg = full.reduced()
    model = Model(cfg, param_dtype=jnp.float32)
    params = model.init(jax.random.PRNGKey(seed))
    kw = dict(policy="fiddler", host_precision="fp32",
              expert_budget=cfg.n_layers * cfg.moe.n_experts // 2)
    plain = FiddlerEngine(cfg, params, **kw)
    meshed = FiddlerEngine(cfg, params, mesh=make_serving_mesh("1,1"),
                           n_fast_devices=1, kv_global_pool=True, **kw)
    tokens = jax.random.randint(jax.random.PRNGKey(seed + 1), (2, 10), 3,
                                cfg.vocab_size)
    outs = []
    for eng in (plain, meshed):
        rows = []
        logits, caches = eng.prefill(tokens, max_seq=32)
        rows.append(np.asarray(logits))
        for step in range(2):
            logits, caches = eng.decode_step(
                caches, tokens[:, :1], pos=tokens.shape[1] + step, max_seq=32)
            rows.append(np.asarray(logits))
        outs.append(np.stack(rows))
    return bool(np.array_equal(outs[0], outs[1]))


def run(model: str = "mixtral-8x7b", env: str = "env2",
        smoke: bool = False) -> Dict[str, object]:
    rate = 32.0 if smoke else 64.0          # saturating either way
    n_requests = 6 if smoke else 32
    n_slots = 4

    results: Dict[str, object] = {}
    for D in DEVICE_COUNTS:
        r = simulate_scale(model, env, D, rate_hz=rate, n_slots=n_slots,
                           n_requests=n_requests)
        key = f"mesh_scaling/{env}/fiddler/devices{D}_rate{rate:g}"
        emit(key, r["alltoall_time"] * 1e6,
             f"tok_per_s={r['throughput_tok_per_s']:.2f} "
             f"hit_rate={r['hit_rate']:.3f} "
             f"migr={r['migrations']:.0f} "
             f"balance={r['busy_balance']:.2f} "
             f"leaked={r['leaked_blocks']:.0f}")
        results[key] = r

    xs = {r["n_devices"]: r["throughput_tok_per_s"]
          for r in results.values()}
    bit_ok = bit_identity_1x1(model)
    emit("mesh_scaling/bit_identical_fp32_1x1", 0.0, str(bit_ok))
    emit("mesh_scaling/speedup_1to2", 0.0, f"{xs[2] / xs[1]:.2f}x")
    emit("mesh_scaling/speedup_1to4", 0.0, f"{xs[4] / xs[1]:.2f}x")

    record = {
        "_meta": {
            "mode": "smoke" if smoke else "full",
            "model": model, "env": env, "rate_hz": rate,
            "n_requests": n_requests, "n_slots": n_slots,
            "device_counts": list(DEVICE_COUNTS),
        },
        "bit_identical_fp32": bit_ok,
        "speedup_1to2": xs[2] / xs[1],
        "speedup_1to4": xs[4] / xs[1],
        "results": results,
    }
    RESULTS_JSON.write_text(json.dumps(record, indent=2, sort_keys=True))
    return record


if __name__ == "__main__":
    import argparse

    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--smoke", action="store_true",
                    help="CI mesh-smoke lane: tiny workload, same gates")
    ap.add_argument("--env", default="env2", choices=sorted(ENVS))
    a = ap.parse_args()
    run(env=a.env, smoke=a.smoke)
