"""Benchmark harness entry point — one benchmark per paper table/figure.

Prints ``name,us_per_call,derived`` CSV (plus section headers on stderr).

  Fig. 4 / 11 / 12  e2e_latency        Fig. 5   prefill_ttft
  Fig. 6            beam_search        Fig. 7   microbench
  Table 2           sparsity           Fig. 8 / App. C popularity
  Fig. 9 (App. D)   dataset_sensitivity
  App. E            portability (Phi-3.5-MoE)
  Dry-run roofline  roofline (reads experiments/*.json)

``python -m benchmarks.run [--full]`` — default is the fast subset so the
whole harness completes in minutes on CPU; --full runs every paper
configuration.
"""
import sys
import traceback


def main() -> None:
    fast = "--full" not in sys.argv
    from benchmarks import (
        beam_search,
        dataset_sensitivity,
        dispatch_overlap,
        e2e_latency,
        extensions,
        microbench,
        popularity,
        portability,
        prefill_ttft,
        roofline,
        serve_load,
        sparsity,
        workload_shift,
    )

    print("name,us_per_call,derived")
    sections = [
        ("fig4_e2e_latency", lambda: e2e_latency.run(breakdown=True, fast=fast)),
        ("fig5_prefill_ttft", lambda: prefill_ttft.run(fast=fast)),
        ("fig6_beam_search", lambda: beam_search.run(fast=fast)),
        ("fig7_microbench", lambda: microbench.run(fast=fast)),
        ("table2_sparsity", lambda: sparsity.run(fast=fast)),
        ("fig8_popularity", lambda: popularity.run(fast=fast)),
        ("fig9_dataset_sensitivity", lambda: dataset_sensitivity.run(fast=fast)),
        ("appE_portability", lambda: portability.run(fast=fast)),
        ("serve_load_poisson", lambda: serve_load.run(fast=fast)),
        ("workload_shift", lambda: workload_shift.run(fast=fast)),
        ("dispatch_overlap", lambda: dispatch_overlap.run(fast=fast)),
        ("beyond_paper_extensions", lambda: extensions.run(fast=fast)),
        ("roofline", roofline.report),
    ]
    failures = []
    for name, fn in sections:
        print(f"# === {name} ===", file=sys.stderr)
        try:
            fn()
        except FileNotFoundError as e:
            print(f"# {name}: skipped ({e})", file=sys.stderr)
        except Exception:
            failures.append(name)
            traceback.print_exc()
    if failures:
        print(f"# FAILED sections: {failures}", file=sys.stderr)
        sys.exit(1)


if __name__ == "__main__":
    main()
