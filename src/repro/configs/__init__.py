"""Config registry. Importing this package registers all architectures."""
# Register all architectures (import side effects).
from repro.configs import (  # noqa: F401
    gemma2_9b,
    internvl2_76b,
    kimi_k2_1t_a32b,
    mamba2_2p7b,
    mixtral_8x22b,
    mixtral_8x7b,
    phi35_moe,
    qwen3_0p6b,
    qwen3_4b,
    recurrentgemma_2b,
    stablelm_3b,
    whisper_large_v3,
)
from repro.configs.base import (  # noqa: F401
    INPUT_SHAPES,
    InputShape,
    ModelConfig,
    MoEConfig,
    SSMConfig,
    applicable_shapes,
    get_config,
    list_archs,
)

ASSIGNED_ARCHS = [
    "kimi-k2-1t-a32b",
    "mixtral-8x22b",
    "mamba2-2.7b",
    "whisper-large-v3",
    "internvl2-76b",
    "stablelm-3b",
    "qwen3-4b",
    "recurrentgemma-2b",
    "gemma2-9b",
    "qwen3-0.6b",
]
