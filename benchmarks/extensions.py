"""Beyond-paper extensions benchmark: LRU expert cache
(Mixtral-Offloading-style) on top of each policy, int8 slow tier, and
adaptive placement under the App.-D distribution shift."""
from benchmarks.common import ENVS, emit
from repro.configs import get_config
from repro.core import FiddlerEngine
from repro.core.popularity import synthetic_profile


def run(env: str = "env1", fast: bool = False):
    full = get_config("mixtral-8x7b")
    gen = 48 if fast else 128
    hw = ENVS[env]
    results = {}
    for name, kw in [
        ("fiddler", {}),
        ("fiddler+int8", {"quantize_slow": True}),
        ("fiddler+lru64", {"lru_cache_experts": 64}),
        ("offload", {"policy": "offload"}),
        ("offload+lru64", {"policy": "offload", "lru_cache_experts": 64}),
    ]:
        policy = kw.pop("policy", "fiddler")
        eng = FiddlerEngine(full, policy=policy, hw=hw, seed=0, **kw)
        r = eng.simulate_generate(prompt_len=64, gen_len=gen)
        results[name] = r["tokens_per_s"]
        emit(f"ext/{env}/{name}", r["itl"] * 1e6,
             f"tok_per_s={r['tokens_per_s']:.2f}")
    assert results["fiddler+int8"] > results["fiddler"]
    assert results["offload+lru64"] > results["offload"]

    # adaptive placement under distribution shift (paper App. D regime)
    serve = synthetic_profile(full.n_layers, full.moe.n_experts, seed=123,
                              concentration=3.0)
    prof = synthetic_profile(full.n_layers, full.moe.n_experts, seed=0)
    for name, kw in [("static", {}), ("adaptive", {"adaptive": True})]:
        eng = FiddlerEngine(full, policy="fiddler", hw=hw, seed=0,
                            profile=prof, **kw)
        eng.profile = serve
        r = eng.simulate_generate(prompt_len=64, gen_len=max(gen, 256))
        results[f"shift/{name}"] = r["tokens_per_s"]
        emit(f"ext/{env}/shifted_{name}", r["itl"] * 1e6,
             f"tok_per_s={r['tokens_per_s']:.2f}")
    assert results["shift/adaptive"] > results["shift/static"]
    return results


if __name__ == "__main__":
    run()
