"""Token samplers over final-position logits."""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np


def greedy(logits: jnp.ndarray) -> np.ndarray:
    """logits: (B, V) → (B,) int32."""
    # fiddlint: ignore[FID001] sampling is the per-step sequencing point:
    # the next token must reach the host scheduler to build the next batch
    return np.asarray(jnp.argmax(logits, axis=-1), np.int32)


def sample(logits: jnp.ndarray, key, temperature: float = 1.0,
           top_k: Optional[int] = None) -> np.ndarray:
    if temperature <= 0.0:
        return greedy(logits)
    l = logits / temperature
    if top_k is not None:
        vals, _ = jax.lax.top_k(l, top_k)
        thresh = vals[:, -1:]
        l = jnp.where(l < thresh, -1e30, l)
    return np.asarray(jax.random.categorical(key, l, axis=-1), np.int32)


def log_softmax(logits: jnp.ndarray) -> jnp.ndarray:
    return jax.nn.log_softmax(logits.astype(jnp.float32), axis=-1)
