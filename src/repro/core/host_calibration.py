"""One-shot host-CPU throughput calibration (engine init).

The derived :class:`~repro.core.cost_model.LatencyModel` guesses the slow
tier's GEMM rate from a hardware spec, and the slow-tier worker pool
(core/orchestrator.py ``_host_pool``) guesses its width from
``os.cpu_count()``.  Both guesses are wrong on shared/throttled containers.
``calibrate_host_pool`` replaces them with measurement, mirroring the
paper's initialization-phase microbenchmarks:

* a small numpy GEMM probe measures the *achieved* host flop rate
  (single worker), which rescales the cost model's ``cpu_per_token``;
* the same probe is run at widths 1, 2, 4, ... across a thread pool, and
  the worker count is set to the scaling knee — the last width whose
  marginal speedup still clears ``KNEE_GAIN`` — so the pool never holds
  more threads than the memory bus can feed.

The probe is deliberately tiny (a few ms): it runs once per engine when
``FiddlerEngine(calibrate_host=True)`` and never touches jax.
"""
from __future__ import annotations

import time
from concurrent.futures import ThreadPoolExecutor
from dataclasses import dataclass, replace

import numpy as np

from repro.configs.base import ModelConfig
from repro.core.cost_model import LatencyModel, expert_flops_per_token

# Marginal-speedup floor: doubling the workers must buy at least this
# factor over the previous width to keep growing the pool.
KNEE_GAIN = 1.2

# Probe GEMM geometry: big enough to exercise the BLAS kernel, small
# enough that the whole calibration stays in the low milliseconds.
_PROBE_TOKENS = 32
_PROBE_DIM = 256
_PROBE_FF = 512
_MAX_WORKERS = 8


@dataclass(frozen=True)
class HostCalibration:
    """Measured host-tier constants: the achieved GEMM flop rate of one
    worker (``gemm_flops``), the pool width at the measured scaling knee
    (``workers``), and the aggregate rate at that width
    (``pool_flops``)."""

    gemm_flops: float
    workers: int
    pool_flops: float

    def apply(self, lat: LatencyModel, cfg: ModelConfig) -> LatencyModel:
        """The latency model with its CPU GEMM term re-derived from the
        measured aggregate rate (the slow tier runs experts across the
        whole pool)."""
        per_token = expert_flops_per_token(cfg) / max(self.pool_flops, 1.0)
        return replace(lat, cpu_per_token=per_token)


def _probe_once(x: np.ndarray, w1: np.ndarray, w2: np.ndarray) -> None:
    ((x @ w1) @ w2).sum()


def _time_workers(n_workers: int, reps: int, x, w1, w2) -> float:
    """Seconds per probe GEMM with ``reps`` probes spread over
    ``n_workers`` threads (reps ≥ n_workers, so every thread is busy)."""
    if n_workers == 1:
        t0 = time.perf_counter()
        for _ in range(reps):
            _probe_once(x, w1, w2)
        return (time.perf_counter() - t0) / reps
    with ThreadPoolExecutor(max_workers=n_workers) as pool:
        t0 = time.perf_counter()
        futs = [pool.submit(_probe_once, x, w1, w2) for _ in range(reps)]
        for f in futs:
            # a probe GEMM is low-ms work; a stalled worker must not hang
            # engine init (the FID006 watchdog discipline)
            f.result(timeout=30.0)
        return (time.perf_counter() - t0) / reps


def calibrate_host_pool(cfg: ModelConfig, *, max_workers: int = _MAX_WORKERS,
                        reps: int = 8) -> HostCalibration:
    """Run the probe and return the measured constants.  ``cfg`` only
    feeds the flops-per-token conversion in :meth:`HostCalibration.apply`;
    the probe geometry is fixed so calibration cost is config-independent.
    """
    rng = np.random.default_rng(0)
    x = rng.standard_normal((_PROBE_TOKENS, _PROBE_DIM)).astype(np.float32)
    w1 = rng.standard_normal((_PROBE_DIM, _PROBE_FF)).astype(np.float32)
    w2 = rng.standard_normal((_PROBE_FF, _PROBE_DIM)).astype(np.float32)
    flops = 2.0 * _PROBE_TOKENS * (_PROBE_DIM * _PROBE_FF * 2)

    _time_workers(1, 2, x, w1, w2)  # warm the BLAS threads / caches
    t1 = max(_time_workers(1, reps, x, w1, w2), 1e-9)
    gemm_flops = flops / t1

    workers, best_rate = 1, reps / (t1 * reps)  # probes per second / rep
    prev_rate = 1.0 / t1
    width = 2
    while width <= max_workers:
        t = max(_time_workers(width, max(reps, width * 2), x, w1, w2), 1e-9)
        rate = 1.0 / t
        if rate < prev_rate * KNEE_GAIN:
            break  # marginal speedup collapsed: past the memory-bw knee
        workers, prev_rate, best_rate = width, rate, rate
        width *= 2
    pool_flops = flops * best_rate if workers > 1 else gemm_flops
    return HostCalibration(gemm_flops=gemm_flops, workers=max(2, workers),
                           pool_flops=pool_flops)
