"""Production mesh construction.

``make_production_mesh`` is a FUNCTION (not a module constant) so importing
this module never touches jax device state; the dry-run sets
``XLA_FLAGS=--xla_force_host_platform_device_count=512`` before any jax
import to get placeholder devices.
"""
from __future__ import annotations

from typing import Tuple

import jax


def _make_mesh(shape, axes):
    """jax.make_mesh across jax versions: ``axis_types``/``AxisType``
    only exist in newer releases — explicit Auto axes there, default
    behaviour (equivalent) on older ones."""
    axis_type = getattr(jax.sharding, "AxisType", None)
    if axis_type is None:
        return jax.make_mesh(shape, axes)
    return jax.make_mesh(shape, axes,
                         axis_types=(axis_type.Auto,) * len(axes))


def make_production_mesh(*, multi_pod: bool = False):
    """Single pod: (data=16, model=16) = 256 chips (TPU v5e-256).
    Multi-pod: (pod=2, data=16, model=16) = 512 chips."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return _make_mesh(shape, axes)


def make_debug_mesh(model: int = 1, data: int = 1):
    """Tiny mesh over however many local devices exist (tests)."""
    return _make_mesh((data, model), ("data", "model"))


def mesh_axes(mesh) -> Tuple[Tuple[str, ...], str]:
    """(data_axes, model_axis) for a production or debug mesh."""
    names = mesh.axis_names
    model_axis = "model"
    data_axes = tuple(n for n in names if n != model_axis)
    return data_axes, model_axis
