"""Distributed training launcher.

Builds a mesh over the available devices (data × model), shards parameters
and optimizer state by the production rules, and runs the pjit'd train
step over the synthetic data pipeline.  On a real TPU slice this is the
entry point per host; on this container it runs with a trivial mesh.

  PYTHONPATH=src python -m repro.launch.train --arch qwen3-0.6b \
      --reduced --steps 50 --batch 4 --seq 128
"""
import argparse
import time

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.configs import get_config
from repro.data.pipeline import make_batch_iter
from repro.distributed.sharding import param_pspecs
from repro.launch.mesh import make_debug_mesh, mesh_axes
from repro.models.model import Model, ParallelContext
from repro.training.checkpoint import save_checkpoint
from repro.training.optimizer import AdamWConfig, init_opt_state
from repro.training.train_loop import make_train_step


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen3-0.6b")
    ap.add_argument("--reduced", action="store_true",
                    help="train the smoke-scale variant (CPU-friendly)")
    ap.add_argument("--steps", type=int, default=50)
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--model-parallel", type=int, default=1)
    ap.add_argument("--ckpt", default=None)
    args = ap.parse_args(argv)

    cfg = get_config(args.arch)
    if args.reduced:
        cfg = cfg.reduced()
    n_dev = len(jax.devices())
    mp = min(args.model_parallel, n_dev)
    mesh = make_debug_mesh(model=mp, data=n_dev // mp)
    data_axes, model_axis = mesh_axes(mesh)
    pctx = ParallelContext(mesh=mesh, data_axes=data_axes,
                           model_axis=model_axis)
    model = Model(cfg, pctx, param_dtype=jnp.float32)
    print(f"arch={cfg.name} mesh={dict(mesh.shape)} "
          f"params≈{cfg.param_count()/1e6:.1f}M")

    params = model.init(jax.random.PRNGKey(0))
    p_specs = param_pspecs(cfg, jax.eval_shape(lambda: params),
                           model_axis, mesh.shape[model_axis])
    p_sh = jax.tree.map(lambda s: NamedSharding(mesh, s), p_specs,
                        is_leaf=lambda s: isinstance(s, P))
    params = jax.device_put(params, p_sh)
    opt_state = init_opt_state(params)

    step_fn = jax.jit(make_train_step(model, AdamWConfig(lr=args.lr)))
    data = make_batch_iter(cfg, seq_len=args.seq, batch=args.batch)
    t0 = time.time()
    for step, batch in enumerate(data):
        if step >= args.steps:
            break
        params, opt_state, metrics = step_fn(params, opt_state, batch)
        if step % 10 == 0 or step == args.steps - 1:
            print(f"step {step:4d} loss={float(metrics['loss']):.4f} "
                  f"gnorm={float(metrics['grad_norm']):.2f} "
                  f"({time.time()-t0:.1f}s)")
    if args.ckpt:
        save_checkpoint(args.ckpt, params, opt_state, step=args.steps)
        print(f"checkpoint → {args.ckpt}")


if __name__ == "__main__":
    main()
