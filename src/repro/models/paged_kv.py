"""Paged (block) KV-cache layout with refcounted copy-on-write sharing.

The dense layout in :mod:`repro.models.kv_cache` gives every decode slot a
private ``(W, n_kv, head_dim)`` ring buffer, so a beam reshuffle must
*copy* whole cache rows and beams of one group hold W duplicates of their
shared prompt prefix.  This module splits each layer's KV into fixed-size
**blocks** drawn from a per-layer pool:

* ``k``/``v``/``pos`` pools of shape ``(n_blocks, block_size, ...)``;
* a host-side :class:`BlockMeta` — per-slot **block table** mapping the
  slot's logical window offsets to pool blocks, plus per-block
  **refcounts** and a free list;
* **copy-on-write**: a write into a block with refcount > 1 first moves
  the writer onto a private copy, so sharing is transparent to numerics;
* **fork** (``fork_slot``) and **reshuffle** (``reorder_slots``) are
  table permutations + refcount bumps — zero KV data movement, which is
  what makes beam search a first-class serving workload instead of a
  cache-copy storm (paper Fig. 6 regime).

Block 0 is a reserved *null* block: never allocated, always empty
(``pos == -1`` everywhere), the target of every unmapped table entry —
so gathering a table row always yields a well-formed dense view.

Per-layer pools can be **collapsed into one global pool**: a shared
:class:`BlockPool` (free list / refcounts / fill) plus a
:class:`GlobalPagedPool` device store back every layer's
:class:`BlockMeta` *table*, so KV capacity is one fungible budget
co-optimized across layers (and, on a mesh, sized per device).  A
``BlockMeta`` constructed without an explicit pool keeps its private
worst-case pool — the historical behavior, bit-identical.

Bit-identity contract: :meth:`PagedLayerCache.view` reproduces the dense
ring buffer exactly — logical offset ``p % window`` lives at block
``off // block_size``, lane ``off % block_size``, freshly mapped blocks
are cleared to the dense init state (zeros / ``pos == -1``) — so
attention over the gathered view is bit-identical on fp32 to the dense
layout (tested in tests/test_paged_kv.py).

:class:`BlockMeta` is deliberately standalone (no device arrays): the
pure-simulation serving backend and the beam-search benchmark use it to
account **unique** blocks — shared prefix bytes are charged once, which
is what makes paper-scale simulated beam numbers honest (see
``core/cost_model.nonexpert_layer_time(kv_unique=...)``).
"""
from __future__ import annotations

from typing import Dict, List, Optional, Sequence, Tuple, Union

import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig
from repro.models.kv_cache import layer_window

# Tokens per KV block.  16 keeps the per-slot table small while a beam
# group's shared prompt still spans many whole (shareable) blocks.
PAGE_SIZE = 16

# src tag for a freshly-mapped block (caller must clear it to the dense
# init state); an int src means copy-on-write from that block.
FRESH = "fresh"

WritePlan = Tuple[int, int, int, int, int, Union[None, str, int]]


def _chain_hashes(tokens: Sequence[int],
                  block_size: int) -> List[Tuple[int, Tuple[int, ...]]]:
    """Rolling content hash per *full* block of ``tokens``: entry ``i`` is
    ``(hash((h_{i-1}, block_i_tokens)), block_i_tokens)``.  Chaining makes
    the hash positional — two prompts share entry ``i`` iff they share the
    entire first ``(i+1) * block_size`` tokens."""
    out: List[Tuple[int, Tuple[int, ...]]] = []
    h = 0
    for i in range(len(tokens) // block_size):
        blk = tuple(int(t) for t in tokens[i * block_size:
                                           (i + 1) * block_size])
        h = hash((h, blk))
        out.append((h, blk))
    return out


class PrefixIndex:
    """Cross-request prefix cache over one :class:`BlockMeta`'s pool.

    Maps chain hashes (see :func:`_chain_hashes`) of fully-written prompt
    blocks to resident pool blocks, so a new admission can splice the
    longest shared prefix into its block table (refcount bumps, zero data
    movement) and prefill only the unmatched tail.  Lookups *verify* the
    stored token content — a hash collision (or poisoned entry) breaks
    the walk and the engine falls back to a full prefill rather than ever
    serving wrong KV.  ``last-match`` stamps order eviction (LRU) when
    the pool is under pressure."""

    def __init__(self, block_size: int):
        self.block_size = int(block_size)
        # chain-hash -> (block id, that block's token content)
        self.entries: Dict[int, Tuple[int, Tuple[int, ...]]] = {}
        self.by_block: Dict[int, int] = {}   # block id -> chain-hash
        self._stamp: Dict[int, int] = {}     # block id -> LRU clock
        self._clock = 0

    def __len__(self) -> int:
        return len(self.entries)

    def _touch(self, b: int) -> None:
        self._clock += 1
        self._stamp[b] = self._clock

    def register(self, chain: Sequence[Tuple[int, Tuple[int, ...]]],
                 blocks: Sequence[int]) -> None:
        """Publish ``blocks`` (pool ids, one per chain entry) as the KV of
        the token chain.  Existing entries win — re-registering the same
        chain from another slot just refreshes the LRU stamp."""
        for (h, blk), b in zip(chain, blocks):
            b = int(b)
            cur = self.entries.get(h)
            if cur is not None:
                self._touch(cur[0])
                continue
            if b in self.by_block:
                # block already serves a different chain; registering the
                # rest would leave unreachable entries — stop here
                break
            self.entries[h] = (b, blk)
            self.by_block[b] = h
            self._touch(b)

    def match(self, chain: Sequence[Tuple[int, Tuple[int, ...]]]
              ) -> List[int]:
        """Longest verified prefix walk: pool block ids whose *stored*
        token content equals the request's blocks.  Stops at the first
        miss or content mismatch (collision safety)."""
        out: List[int] = []
        for h, blk in chain:
            e = self.entries.get(h)
            if e is None or e[1] != blk:
                break
            out.append(e[0])
        return out

    def deregister(self, b: int) -> None:
        h = self.by_block.pop(int(b), None)
        if h is not None:
            self.entries.pop(h, None)
        self._stamp.pop(int(b), None)

    def lru_block(self, blocks) -> int:
        """Least-recently-matched block of ``blocks`` (reclaim victim)."""
        return min(blocks, key=lambda b: self._stamp.get(b, 0))


class KVPoolExhausted(RuntimeError):
    """The block pool has no free (or reclaimable cached) block left.

    A ``RuntimeError`` subclass for back-compat with callers matching
    the old bare ``RuntimeError``; the serving layer catches this
    specifically (together with ``FaultError`` — see core/faults.py) to
    route mid-step exhaustion into slot-level evict→requeue recovery
    instead of crashing the run."""


class BlockPool:
    """Shared block bookkeeping: refcounts, fill counts, the free list and
    the cached/reserved sets — everything about blocks that is *not* a
    per-slot table.

    One pool can back many :class:`BlockMeta` tables (the global-pool
    layout: per-layer tables drawing from one free list, so KV capacity
    is co-optimized across layers instead of worst-case-sized per layer).
    A :class:`BlockMeta` constructed without an explicit pool makes a
    private one — byte-for-byte the historical per-layer behavior."""

    def __init__(self, n_blocks: int):
        assert n_blocks >= 1, n_blocks
        self.n_blocks = int(n_blocks)   # includes the reserved null block 0
        self.ref = np.zeros(self.n_blocks, np.int32)
        self.fill = np.zeros(self.n_blocks, np.int32)  # written lanes/block
        self._free: List[int] = list(range(self.n_blocks - 1, 0, -1))
        self._cached: set = set()
        self._reserved: set = set()
        # cached block -> the meta whose PrefixIndex registered it (the
        # eviction path must deregister from the right per-layer index)
        self._owner: Dict[int, "BlockMeta"] = {}
        self.metas: List["BlockMeta"] = []

    def adopt(self, meta: "BlockMeta") -> None:
        self.metas.append(meta)

    @property
    def n_free(self) -> int:
        return len(self._free)

    def grow(self, need: int) -> int:
        """Append ``need`` fresh blocks to the pool (cache resize)."""
        if need <= 0:
            return 0
        start = self.n_blocks
        self.n_blocks += need
        self.ref = np.concatenate([self.ref, np.zeros(need, np.int32)])
        self.fill = np.concatenate([self.fill, np.zeros(need, np.int32)])
        self._free.extend(range(start, self.n_blocks))
        return need

    def _lru_cached_block(self) -> int:
        """Global reclaim victim: the least-recently-matched cached block
        across every adopting meta's prefix index.  (Stamps are per-index
        clocks — comparing them across layers is a heuristic, but any
        cached block is semantically safe to evict.)"""
        def stamp(b: int) -> int:
            owner = self._owner[b]
            return owner.index._stamp.get(b, 0) if owner.index else 0
        return min(self._cached, key=stamp)

    def evict_one_cached(self) -> None:
        b = self._lru_cached_block()
        self._owner[b].index.deregister(b)
        self._owner.pop(b, None)
        self._cached.discard(b)
        self.fill[b] = 0
        self._free.append(b)

    def check(self) -> None:
        """Pool-wide refcount/free-list consistency over every adopting
        table (the :meth:`BlockMeta.check` invariants, aggregated)."""
        occ = np.zeros(self.n_blocks, np.int64)
        in_use = 0
        for m in self.metas:
            occ += np.bincount(m.table.ravel(), minlength=self.n_blocks)
            in_use += m.blocks_in_use()
        assert (self.ref[1:] == occ[1:]).all(), "refcount != table occurrences"
        free = set(self._free)
        assert len(free) == len(self._free), "free-list duplicates"
        assert not (free & self._cached), "cached block on the free list"
        assert not (free & self._reserved), "reserved block on the free list"
        assert not (self._cached & self._reserved), "cached block reserved"
        for b in range(1, self.n_blocks):
            assert (self.ref[b] == 0) == (
                b in free or b in self._cached or b in self._reserved), b
        for b in self._cached:
            owner = self._owner[b]
            assert owner.index is not None and b in owner.index.by_block, b
            assert self.fill[b] == owner.block_size, (b, int(self.fill[b]))
        for m in self.metas:
            if m.index is not None:
                for b, h in m.index.by_block.items():
                    assert m.index.entries.get(h, (None,))[0] == b, (b, h)
        assert (in_use + self.n_free + len(self._cached)
                + len(self._reserved) == self.n_blocks - 1)


class BlockMeta:
    """Host-side block table + refcounts for one layer('s ring window).

    All bookkeeping is numpy/python — no device data — so the same class
    backs the real paged cache (:class:`PagedLayerCache`) and the
    pure-simulation unique-block accounting.

    ``pool`` attaches the table to a shared :class:`BlockPool` (the
    global-pool layout); by default each meta owns a private pool sized
    for its worst case, which is exactly the historical per-layer
    behavior.
    """

    def __init__(self, n_slots: int, window: int, block_size: int = PAGE_SIZE,
                 pool: Optional[BlockPool] = None):
        assert n_slots >= 1 and window >= 1, (n_slots, window)
        bs = max(1, min(int(block_size), int(window)))
        self.block_size = bs
        self.window = int(window)
        self.blocks_per_slot = -(-self.window // bs)
        # worst case every slot owns a private copy of each of its blocks,
        # so ``n_slots * blocks_per_slot`` (+ the null block) always
        # suffices — COW never needs more than one owner per table entry.
        if pool is None:
            pool = BlockPool(1 + n_slots * self.blocks_per_slot)
        self.pool = pool
        pool.adopt(self)
        # slots this meta's private worst-case share of the pool covers
        # (resize grows the pool only beyond this high-water mark)
        self._slots_capacity = n_slots
        self.table = np.zeros((n_slots, self.blocks_per_slot), np.int32)
        # cross-request prefix cache (None = disabled, the default): the
        # index maps content-hash chains to resident blocks; cached blocks
        # (ref==0, retained for reuse) live on the pool
        self.index: Optional[PrefixIndex] = None

    # -- pool delegation ----------------------------------------------------
    @property
    def n_blocks(self) -> int:
        return self.pool.n_blocks

    @property
    def ref(self) -> np.ndarray:
        return self.pool.ref

    @property
    def fill(self) -> np.ndarray:
        return self.pool.fill

    @property
    def _free(self) -> List[int]:
        return self.pool._free

    @property
    def _cached(self) -> set:
        return self.pool._cached

    @property
    def _reserved(self) -> set:
        return self.pool._reserved

    # -- introspection ------------------------------------------------------
    @property
    def n_slots(self) -> int:
        return int(self.table.shape[0])

    @property
    def n_free(self) -> int:
        return self.pool.n_free

    @property
    def n_cached(self) -> int:
        """Unreferenced blocks retained by the prefix cache."""
        return len(self.pool._cached)

    @property
    def n_reserved(self) -> int:
        """Blocks reserved out of the pool (injected KV pressure)."""
        return len(self.pool._reserved)

    def enable_prefix_cache(self) -> PrefixIndex:
        if self.index is None:
            self.index = PrefixIndex(self.block_size)
        return self.index

    def mapped_blocks(self, slots: Optional[Sequence[int]] = None) -> np.ndarray:
        t = self.table if slots is None else self.table[np.asarray(slots, int)]
        u = np.unique(t)
        return u[u > 0]

    def blocks_in_use(self, slots: Optional[Sequence[int]] = None) -> int:
        """Distinct mapped blocks — what the pool actually holds."""
        return int(self.mapped_blocks(slots).size)

    def dense_blocks(self, slots: Optional[Sequence[int]] = None) -> int:
        """Block count a dense per-slot layout would hold (table entries
        counted *with* multiplicity — shared blocks once per referent)."""
        t = self.table if slots is None else self.table[np.asarray(slots, int)]
        return int((t > 0).sum())

    def unique_tokens(self, slots: Optional[Sequence[int]] = None) -> int:
        """Written KV entries over distinct blocks: the number of K/V rows
        one attention step actually has to read from memory — shared
        prefix entries count once (the honest beam charging)."""
        return int(self.fill[self.mapped_blocks(slots)].sum())

    def dense_tokens(self, slots: Optional[Sequence[int]] = None) -> int:
        """Written KV entries counted per slot (dense accounting)."""
        t = self.table if slots is None else self.table[np.asarray(slots, int)]
        return int(self.fill[t].sum())  # fill[0] == 0: null entries add 0

    # -- allocation ---------------------------------------------------------
    def _alloc(self) -> int:
        if not self._free and self._cached:
            # pool pressure: reclaim the least-recently-matched cached
            # prefix block (eviction-aware prefix cache, LRU by last match;
            # under a shared pool the victim may belong to another layer)
            self.pool.evict_one_cached()
        if not self._free:
            raise KVPoolExhausted("KV block pool exhausted")
        b = self._free.pop()
        self.ref[b] = 1
        self.fill[b] = 0
        return b

    def reserve_blocks(self, n: int) -> List[int]:
        """Take up to ``n`` blocks out of circulation (fault injection:
        a transient pool-pressure spike).  Best-effort — reclaims cached
        prefix blocks under pressure like ``_alloc`` but never raises;
        returns the block ids actually reserved (hand them back via
        :meth:`free_reserved`).  Reserved blocks keep ``ref == 0`` and
        are invisible to the table."""
        taken: List[int] = []
        for _ in range(max(0, int(n))):
            if not self._free and self._cached:
                self.pool.evict_one_cached()
            if not self._free:
                break
            b = self._free.pop()
            self._reserved.add(b)
            taken.append(b)
        return taken

    def free_reserved(self, blocks: Sequence[int]) -> None:
        """Return blocks taken by :meth:`reserve_blocks` to the pool."""
        for b in blocks:
            b = int(b)
            assert b in self._reserved, b
            self._reserved.discard(b)
            self.fill[b] = 0
            self._free.append(b)

    def _evict_cached(self, b: int) -> None:
        b = int(b)
        assert b in self._cached and self.ref[b] == 0, b
        self._cached.discard(b)
        owner = self.pool._owner.pop(b, self)
        owner.index.deregister(b)
        self.fill[b] = 0
        self._free.append(b)

    def _unref(self, b: int) -> None:
        if b <= 0:
            return
        self.ref[b] -= 1
        assert self.ref[b] >= 0, b
        if self.ref[b] == 0:
            if self.index is not None and int(b) in self.index.by_block:
                self._cached.add(int(b))  # resident for prefix reuse
                self.pool._owner[int(b)] = self
            else:
                self.fill[b] = 0
                self._free.append(b)

    def _deregister_written(self, b: int) -> None:
        """An in-place write is about to change ``b``'s content: its
        published prefix entry (if any) would go stale — drop it."""
        if self.index is not None and b in self.index.by_block:
            self.index.deregister(b)

    def _writable(self, slot: int, j: int) -> Tuple[int, Union[None, str, int]]:
        """Make table entry ``(slot, j)`` exclusively owned; returns
        ``(block, src)`` with src None (already exclusive), FRESH (newly
        mapped — clear before writing) or the old block id (copy-on-write
        — copy its data before writing)."""
        b = int(self.table[slot, j])
        if b == 0:
            nb = self._alloc()
            self.table[slot, j] = nb
            return nb, FRESH
        if self.ref[b] == 1:
            self._deregister_written(b)
            return b, None
        nb = self._alloc()
        self.fill[nb] = self.fill[b]
        self.ref[b] -= 1  # still >= 1: another slot keeps the original
        self.table[slot, j] = nb
        return nb, b

    # -- slot lifecycle (the zero-copy operations) --------------------------
    def release_slot(self, slot: int) -> None:
        for b in self.table[slot]:
            self._unref(int(b))
        self.table[slot] = 0

    def fork_slot(self, src: int, dst: int) -> None:
        """dst becomes a copy-on-write alias of src: table row copy +
        refcount bumps, zero data movement."""
        if src == dst:
            return
        row = self.table[src].copy()
        for b in row:
            if b > 0:
                self.ref[b] += 1
        self.release_slot(dst)
        self.table[dst] = row

    def reorder_slots(self, slots: Sequence[int], src_of: Sequence[int]) -> None:
        """Beam reshuffle: slot ``slots[i]`` continues the sequence held
        by ``src_of[i]`` — a pure table permutation with refcount bumps
        (sources may repeat or alias destinations)."""
        slots = np.asarray(slots, int)
        rows = self.table[np.asarray(src_of, int)].copy()
        for b in rows.ravel():
            if b > 0:
                self.ref[b] += 1
        for s in slots:
            self.release_slot(int(s))
        self.table[slots] = rows

    # -- cross-request prefix cache -----------------------------------------
    def match_prefix(self, tokens: Sequence[int]) -> List[int]:
        """Verified longest-prefix lookup: resident block ids whose stored
        content equals the head of ``tokens`` (full blocks only).  Pure
        read — :meth:`map_prefix` performs the splice."""
        if self.index is None:
            return []
        chain = _chain_hashes(tokens, self.block_size)
        out: List[int] = []
        for b in self.index.match(chain[: self.blocks_per_slot]):
            if self.fill[b] != self.block_size:
                break  # stale entry (paranoia): never serve partial blocks
            out.append(b)
        return out

    def map_prefix(self, slot: int, blocks: Sequence[int]) -> None:
        """Splice matched prefix blocks into the head of ``slot``'s table
        (admission hit): refcount bumps only, zero data movement — COW
        keeps any later divergent write private."""
        for j, b in enumerate(blocks):
            b = int(b)
            assert self.table[slot, j] == 0, (slot, j)
            assert self.fill[b] == self.block_size, (b, int(self.fill[b]))
            if self.ref[b] == 0:
                self._cached.discard(b)
                self.pool._owner.pop(b, None)
            self.ref[b] += 1
            self.table[slot, j] = b
            self.index._touch(b)

    def register_prefix(self, slot: int, tokens: Sequence[int]) -> None:
        """Publish ``slot``'s fully-written prompt blocks into the prefix
        index so later admissions can reuse them.  Only position-aligned
        blocks are publishable, so ring-wrapped sequences (longer than
        the window) are skipped entirely."""
        if self.index is None or len(tokens) > self.window:
            return
        chain = _chain_hashes(tokens, self.block_size)
        good: List[int] = []
        for j in range(min(len(chain), self.blocks_per_slot)):
            b = int(self.table[slot, j])
            if b <= 0 or self.fill[b] != self.block_size:
                break  # content-incomplete tail: stop at first gap
            good.append(b)
        self.index.register(chain[: len(good)], good)

    def resize(self, n_slots: int) -> int:
        """Grow/shrink the table to ``n_slots`` rows; returns how many
        *new* pool blocks the owner must append to its device arrays."""
        old = self.n_slots
        if n_slots <= old:
            for s in range(n_slots, old):
                self.release_slot(s)
            self.table = self.table[:n_slots].copy()
            return 0
        self.table = np.concatenate(
            [self.table,
             np.zeros((n_slots - old, self.blocks_per_slot), np.int32)])
        # grow the pool only past this meta's worst-case high-water mark
        # (under a shared pool every meta contributes its own share)
        need = (n_slots - self._slots_capacity) * self.blocks_per_slot
        self._slots_capacity = max(self._slots_capacity, n_slots)
        return self.pool.grow(need)

    # -- writes -------------------------------------------------------------
    def write_span(self, slot: int, start: int, end: int) -> List[WritePlan]:
        """Plan the physical writes of logical positions ``[start, end)``
        of ``slot`` (ring offsets ``p % window``; spans longer than the
        window keep only the last ``window`` positions, like the dense
        ring buffer).  Ensures every touched block is exclusively owned.
        Returns ``(block, o0, o1, t0, t1, src)`` tuples: clipped-span
        tokens ``[t0, t1)`` land in lanes ``[o0, o1)`` of ``block``; the
        caller performs the FRESH clear / COW copy that ``src`` demands.
        Pure-simulation users call this for the refcount/fill bookkeeping
        and discard the plan."""
        start = max(int(start), int(end) - self.window)
        plans: List[WritePlan] = []
        p, t = start, 0
        while p < end:
            off = p % self.window
            j, o0 = divmod(off, self.block_size)
            cap = min(self.block_size, self.window - j * self.block_size)
            n = min(end - p, cap - o0)
            b, src = self._writable(slot, j)
            self.fill[b] = max(int(self.fill[b]), o0 + n)
            plans.append((b, o0, o0 + n, t, t + n, src))
            p += n
            t += n
        return plans

    # -- invariants (property tests) ----------------------------------------
    def check(self) -> None:
        """Refcount/free-list consistency: every block's refcount equals
        its table occurrences, unreferenced blocks are exactly the free
        ones plus the retained prefix-cache residents, and nothing leaks.
        Under a shared pool the invariants hold over *all* adopting
        tables together (see :meth:`BlockPool.check`)."""
        self.pool.check()


class _LayerStore:
    """Private device arrays of one :class:`PagedLayerCache` (the
    historical per-layer layout)."""

    def __init__(self, cfg: ModelConfig, n_blocks: int, block_size: int,
                 dtype):
        self.k = jnp.zeros((n_blocks, block_size, cfg.n_kv_heads,
                            cfg.head_dim), dtype)
        self.v = jnp.zeros_like(self.k)
        self.pos = jnp.full((n_blocks, block_size), -1, jnp.int32)

    def grow(self, need: int) -> None:
        self.k = jnp.concatenate(
            [self.k, jnp.zeros((need,) + self.k.shape[1:], self.k.dtype)])
        self.v = jnp.concatenate(
            [self.v, jnp.zeros((need,) + self.v.shape[1:], self.v.dtype)])
        self.pos = jnp.concatenate(
            [self.pos, jnp.full((need,) + self.pos.shape[1:], -1,
                                self.pos.dtype)])


class GlobalPagedPool:
    """One global block store shared by every layer of a model: a single
    :class:`BlockPool` free list plus single k/v/pos device arrays, with
    per-layer :class:`BlockMeta` *tables* drawing from it.

    Collapsing the per-layer pools means KV capacity is one fungible
    budget: a layer holding long prefix-cache chains borrows blocks that
    idle layers are not using, and per-device capacity can be
    co-optimized against the per-device expert budget (the mesh engine
    sizes one pool per fast device).  Requires every layer to share the
    same effective block geometry (``min(block_size, window)`` equal
    across layers) — callers check :meth:`shareable` and fall back to
    private per-layer pools otherwise."""

    def __init__(self, cfg: ModelConfig, n_blocks: int, block_size: int,
                 dtype=jnp.float32):
        self.cfg = cfg
        self.block_size = int(block_size)
        self.pool = BlockPool(n_blocks)
        self.k = jnp.zeros((n_blocks, block_size, cfg.n_kv_heads,
                            cfg.head_dim), dtype)
        self.v = jnp.zeros_like(self.k)
        self.pos = jnp.full((n_blocks, block_size), -1, jnp.int32)

    def grow(self, need: int) -> None:
        self.k = jnp.concatenate(
            [self.k, jnp.zeros((need,) + self.k.shape[1:], self.k.dtype)])
        self.v = jnp.concatenate(
            [self.v, jnp.zeros((need,) + self.v.shape[1:], self.v.dtype)])
        self.pos = jnp.concatenate(
            [self.pos, jnp.full((need,) + self.pos.shape[1:], -1,
                                self.pos.dtype)])

    @staticmethod
    def shareable(cfg: ModelConfig, max_seq: int,
                  block_size: int = PAGE_SIZE) -> bool:
        sizes = {max(1, min(int(block_size),
                            layer_window(cfg, li, max_seq)))
                 for li in range(cfg.n_layers)}
        return len(sizes) == 1

    @staticmethod
    def for_model(cfg: ModelConfig, n_slots: int, max_seq: int,
                  dtype=jnp.float32, block_size: int = PAGE_SIZE
                  ) -> "GlobalPagedPool":
        """A pool sized for the worst case of every layer together (one
        null block total instead of one per layer)."""
        assert GlobalPagedPool.shareable(cfg, max_seq, block_size)
        bs = max(1, min(int(block_size), layer_window(cfg, 0, max_seq)))
        total = 1 + sum(
            n_slots * -(-layer_window(cfg, li, max_seq) // bs)
            for li in range(cfg.n_layers))
        return GlobalPagedPool(cfg, total, bs, dtype)


class PagedLayerCache:
    """One layer's paged KV: device block pools + a :class:`BlockMeta`.

    Pool arrays are functionally updated jnp arrays; the table/refcounts
    are host state, so this object lives in the orchestrator's python
    serving loop (never inside jit) — the jitted monolithic ``Model``
    keeps the dense layout.

    ``shared`` attaches the layer to a :class:`GlobalPagedPool` (one
    free list + one set of device arrays for the whole model); the
    default is a private per-layer store."""

    layout = "paged"

    def __init__(self, cfg: ModelConfig, layer_idx: int, n_slots: int,
                 max_seq: int, dtype=jnp.float32,
                 block_size: int = PAGE_SIZE,
                 shared: Optional[GlobalPagedPool] = None):
        w = layer_window(cfg, layer_idx, max_seq)
        if shared is not None:
            assert shared.block_size == max(1, min(int(block_size), w)), \
                "layer block geometry incompatible with the shared pool"
            self.meta = BlockMeta(n_slots, w, shared.block_size,
                                  pool=shared.pool)
            self._store = shared
        else:
            self.meta = BlockMeta(n_slots, w, block_size)
            self._store = _LayerStore(cfg, self.meta.n_blocks,
                                      self.meta.block_size, dtype)

    @property
    def k(self) -> jnp.ndarray:
        return self._store.k

    @k.setter
    def k(self, val) -> None:
        self._store.k = val

    @property
    def v(self) -> jnp.ndarray:
        return self._store.v

    @v.setter
    def v(self, val) -> None:
        self._store.v = val

    @property
    def pos(self) -> jnp.ndarray:
        return self._store.pos

    @pos.setter
    def pos(self, val) -> None:
        self._store.pos = val

    @property
    def window(self) -> int:
        return self.meta.window

    @property
    def n_slots(self) -> int:
        return self.meta.n_slots

    # -- physical write helpers ---------------------------------------------
    def _prepare(self, b: int, src) -> None:
        """FRESH → clear to the dense init state (a recycled block holds
        stale bytes); int → copy-on-write the source block's data."""
        if src is None:
            return
        if src == FRESH:
            self.k = self.k.at[b].set(0.0)
            self.v = self.v.at[b].set(0.0)
            self.pos = self.pos.at[b].set(-1)
        else:
            self.k = self.k.at[b].set(self.k[src])
            self.v = self.v.at[b].set(self.v[src])
            self.pos = self.pos.at[b].set(self.pos[src])

    def write_decode(self, k_new: jnp.ndarray, v_new: jnp.ndarray,
                     pos: np.ndarray,
                     active: Optional[np.ndarray] = None) -> None:
        """One token per slot: k_new/v_new (B, 1, n_kv, hd), pos (B,).
        Rows outside ``active`` are padding — skipped entirely, so idle
        serving slots never allocate or COW blocks."""
        pos = np.asarray(pos, np.int64)
        rows = (range(pos.shape[0]) if active is None
                else np.nonzero(np.asarray(active, bool))[0])
        bids, lanes, ridx = [], [], []
        for i in rows:
            p = int(pos[i])
            for b, o0, _o1, _t0, _t1, src in self.meta.write_span(i, p, p + 1):
                self._prepare(b, src)
                bids.append(b)
                lanes.append(o0)
                ridx.append(int(i))
        if not bids:
            return
        bi, oi, ri = (np.asarray(bids), np.asarray(lanes), np.asarray(ridx))
        self.k = self.k.at[bi, oi].set(k_new[ri, 0].astype(self.k.dtype))
        self.v = self.v.at[bi, oi].set(v_new[ri, 0].astype(self.v.dtype))
        self.pos = self.pos.at[bi, oi].set(
            jnp.asarray(pos[ri], jnp.int32))

    def _write_chunk_row(self, slot: int, k_row: jnp.ndarray,
                         v_row: jnp.ndarray, p0: int, p1: int) -> None:
        """Write one slot's contiguous chunk ``[p0, p1)`` from ``(S, ...)``
        per-token arrays (shared by the batch writer and
        :class:`PagedSlotStage`)."""
        skip = max(p0, p1 - self.window) - p0  # ring: last window wins
        for b, o0, o1, t0, t1, src in self.meta.write_span(slot, p0, p1):
            self._prepare(b, src)
            self.k = self.k.at[b, o0:o1].set(
                k_row[skip + t0: skip + t1].astype(self.k.dtype))
            self.v = self.v.at[b, o0:o1].set(
                v_row[skip + t0: skip + t1].astype(self.v.dtype))
            self.pos = self.pos.at[b, o0:o1].set(
                jnp.arange(p0 + skip + t0, p0 + skip + t1, dtype=jnp.int32))

    def write_prefill_chunk(self, k_new: jnp.ndarray, v_new: jnp.ndarray,
                            positions: np.ndarray,
                            active: Optional[np.ndarray] = None) -> None:
        """Append one contiguous chunk per slot: k_new/v_new (B, S, ...),
        positions (B, S) int (each row contiguous ascending)."""
        positions = np.asarray(positions, np.int64)
        B, S = positions.shape
        rows = (range(B) if active is None
                else np.nonzero(np.asarray(active, bool))[0])
        for i in rows:
            p0, p1 = int(positions[i, 0]), int(positions[i, -1]) + 1
            assert p1 - p0 == S, "chunk positions must be contiguous"
            self._write_chunk_row(int(i), k_new[i], v_new[i], p0, p1)

    def write_prefill(self, k_new: jnp.ndarray, v_new: jnp.ndarray) -> None:
        """Fresh prompt at positions 0..S-1 for every slot."""
        B, S = k_new.shape[0], k_new.shape[1]
        positions = np.broadcast_to(np.arange(S, dtype=np.int64)[None], (B, S))
        self.write_prefill_chunk(k_new, v_new, positions)

    # -- reads ---------------------------------------------------------------
    def view(self) -> dict:
        """The dense ``{"k", "v", "pos"}`` view the attention kernels
        consume, gathered through the block table — bit-identical to the
        dense ring buffer's arrays."""
        tbl = jnp.asarray(self.meta.table)          # (B, blocks_per_slot)
        B = tbl.shape[0]
        w = self.window
        k = self.k[tbl].reshape(B, -1, *self.k.shape[2:])[:, :w]
        v = self.v[tbl].reshape(B, -1, *self.v.shape[2:])[:, :w]
        pos = self.pos[tbl].reshape(B, -1)[:, :w]
        return {"k": k, "v": v, "pos": pos}

    # -- slot lifecycle -------------------------------------------------------
    def fork_slot(self, src: int, dst: int) -> None:
        self.meta.fork_slot(src, dst)           # zero KV data movement

    def reorder_slots(self, slots, src_of) -> None:
        self.meta.reorder_slots(slots, src_of)  # zero KV data movement

    def release_slot(self, slot: int) -> None:
        self.meta.release_slot(slot)

    def copy_in(self, slot: int, src: "PagedLayerCache",
                src_slot: int = 0) -> None:
        """Splice a freshly-prefilled staging cache's slot into ``slot``
        (continuous-batching join) — block-granular data copy, the paged
        counterpart of the dense row copy in ``write_slot``."""
        assert src.meta.block_size == self.meta.block_size, "page mismatch"
        self.meta.release_slot(slot)
        for j, sb in enumerate(src.meta.table[src_slot]):
            sb = int(sb)
            if sb == 0:
                continue
            b, how = self.meta._writable(slot, j)
            assert how == FRESH, how  # the row was just released
            self.k = self.k.at[b].set(src.k[sb].astype(self.k.dtype))
            self.v = self.v.at[b].set(src.v[sb].astype(self.v.dtype))
            self.pos = self.pos.at[b].set(src.pos[sb])
            self.meta.fill[b] = src.meta.fill[sb]

    def resize(self, n_slots: int) -> None:
        need = self.meta.resize(n_slots)
        if need:
            self._store.grow(need)


class PagedSlotStage:
    """Batch-1 staging *view* over one slot of a parent
    :class:`PagedLayerCache`.

    Chunked admission used to prefill into a private batch-1 pool and
    join the multi-slot cache via a block-by-block device copy
    (:meth:`PagedLayerCache.copy_in`).  A stage instead allocates its
    blocks straight from the target pool, through the parent's
    :class:`BlockMeta` (so refcounts/COW hold): the join becomes a pure
    table splice that moves zero device bytes, and — crucially for the
    prefix cache — the tail chunks of a prefix-matched admission attend
    to the shared blocks already mapped into the slot's table row."""

    layout = "paged"

    def __init__(self, parent: PagedLayerCache, slot: int):
        self.parent = parent
        self.slot = int(slot)

    @property
    def window(self) -> int:
        return self.parent.window

    @property
    def meta(self) -> BlockMeta:
        return self.parent.meta

    def write_prefill_chunk(self, k_new: jnp.ndarray, v_new: jnp.ndarray,
                            positions: np.ndarray,
                            active: Optional[np.ndarray] = None) -> None:
        positions = np.asarray(positions, np.int64)
        assert k_new.shape[0] == 1 and positions.shape[0] == 1, "batch-1 stage"
        p0, p1 = int(positions[0, 0]), int(positions[0, -1]) + 1
        assert p1 - p0 == positions.shape[1], "chunk positions must be contiguous"
        self.parent._write_chunk_row(self.slot, k_new[0], v_new[0], p0, p1)

    def write_prefill(self, k_new: jnp.ndarray, v_new: jnp.ndarray) -> None:
        S = k_new.shape[1]
        positions = np.arange(S, dtype=np.int64)[None]
        self.write_prefill_chunk(k_new, v_new, positions)

    def view(self) -> dict:
        """Dense batch-1 view of just the staged slot's table row —
        bit-identical to what a private staging cache would expose at the
        same logical state."""
        p = self.parent
        tbl = jnp.asarray(p.meta.table[self.slot: self.slot + 1])
        w = p.window
        k = p.k[tbl].reshape(1, -1, *p.k.shape[2:])[:, :w]
        v = p.v[tbl].reshape(1, -1, *p.v.shape[2:])[:, :w]
        pos = p.pos[tbl].reshape(1, -1)[:, :w]
        return {"k": k, "v": v, "pos": pos}
