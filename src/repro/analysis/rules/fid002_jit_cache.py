"""FID002 jit-cache-explosion.

XLA retraces a jitted callable for every new static-argument/shape
combination.  The repo's defence is the pow-2 bucket helper: every
data-dependent dimension that reaches a compiled op must pass through
``_bucket`` first, or routing skew mints a fresh executable per distinct
token count and the cache (and compile time) grows without bound.

Two checks, over functions reachable from the hot roots:

* **runtime jit construction** — any ``jax.jit(...)`` call inside a
  function body (as opposed to module scope / a decorator) builds a new
  cache per call; inside the step loop that is a leak by construction.
* **unbucketed dimension into a compiled sink** — a value tainted as a
  data-dependent size (``len(x)``, ``x.size``, ``.shape[i]`` of a
  non-parameter) reaches a shape-ish argument of a compiled op: a jitted
  project function, a ``*_op`` kernel wrapper, or ``jnp.zeros``-style
  constructors whose first arg is a shape.
"""
from __future__ import annotations

import ast
from typing import List

from repro.analysis.config import FiddlintConfig
from repro.analysis.core import Finding, relpath
from repro.analysis.dataflow import DimFlow
from repro.analysis.project import FunctionInfo, Project, attr_chain

# jnp constructors whose positional args are shapes
SHAPE_CONSTRUCTORS = {"zeros", "ones", "full", "empty", "arange"}


def _is_compiled_sink(project: Project, fn: FunctionInfo,
                      call: ast.Call) -> str:
    """Non-empty description when ``call`` targets compiled code."""
    mod = project.modules[fn.module]
    chain = attr_chain(call.func)
    if chain and chain[0] in mod.jnp_aliases and chain[-1] in SHAPE_CONSTRUCTORS:
        return f"`jnp.{chain[-1]}`"
    if chain and chain[-1].endswith("_op"):
        return f"kernel wrapper `{chain[-1]}`"
    for qual in project.resolve_call(mod, call):
        info = project.functions.get(qual)
        if info is not None and info.jitted:
            return f"jitted `{info.name}`"
    return ""


def _check_function(project: Project, config: FiddlintConfig,
                    fn: FunctionInfo, root: str,
                    out: List[Finding]) -> None:
    mod = project.modules[fn.module]
    path = relpath(fn.file.path)
    via = "" if fn.qualname == root else f" (reachable from {root})"
    flow = DimFlow(fn, config)

    for node in ast.walk(fn.node):
        if not isinstance(node, ast.Call):
            continue
        chain = attr_chain(node.func)
        # jax.jit(...) constructed at call time
        if (chain and chain[-1] == "jit"
                and (len(chain) == 1 or chain[0] in mod.jax_aliases)):
            out.append(Finding(
                "FID002", path, node.lineno, node.col_offset,
                f"`jax.jit` constructed inside a function body{via}: each "
                f"call builds a fresh trace cache; hoist to module scope",
                fn.qualname))
            continue
        sink = _is_compiled_sink(project, fn, node)
        if not sink:
            continue
        for arg in [*node.args, *[kw.value for kw in node.keywords]]:
            if flow.classify(arg) == "dynamic":
                src = ast.unparse(arg) if hasattr(ast, "unparse") else "<dim>"
                out.append(Finding(
                    "FID002", path, node.lineno, node.col_offset,
                    f"data-dependent dimension `{src}` reaches {sink} "
                    f"unbucketed{via}: every distinct value mints a new "
                    f"XLA trace; round with `_bucket(...)` first",
                    fn.qualname))
                break


def check_jit_cache(project: Project,
                    config: FiddlintConfig) -> List[Finding]:
    roots = project.resolve_roots(config.hot_roots)
    reach = project.reachable_from(roots)
    out: List[Finding] = []
    for qual, root in reach.items():
        fn = project.functions.get(qual)
        if fn is not None:
            _check_function(project, config, fn, root, out)
    return out
