"""Workload-shift benchmark: dynamic CPU↔GPU rebalancing vs frozen
offline placement under a mid-trace routing-distribution shift.

The paper profiles expert popularity offline and freezes the placement
(§3.4); App. D measures what a calibration/workload mismatch costs.  This
benchmark replays that failure mode *live*: a Poisson request stream runs
through ``ContinuousEngine`` over a ``SimulatedBackend`` (full-size
configs, paper-env hardware, simulated-seconds ledger), and mid-trace the
routing distribution is switched to a per-layer permutation of the
calibration popularity (the code→chat mismatch regime: same skew, different
experts).  Placement was fit to the calibration profile, so post-shift the
static engine's fast-tier hit rate collapses; with ``--rebalance`` the
``Rebalancer`` (core/rebalance.py) tracks the live EWMA profile and
migrates at most ``k`` experts per interval back toward the optimum —
paying real transfer time into the ledger (no free migrations).

Reported per phase: fast-tier hit rate, simulated per-token latency, and
the migration overhead (count / bytes / seconds).  Results land in
``BENCH_workload_shift.json`` at the repo root.
"""
from __future__ import annotations

import json
from pathlib import Path
from typing import Dict, List

import numpy as np

from benchmarks.common import ENVS, emit
from benchmarks.serve_load import poisson_requests
from repro.configs import get_config
from repro.core import FiddlerEngine
from repro.core.placement import hit_rate
from repro.core.popularity import ExpertProfile, synthetic_profile
from repro.serving.backend import SimulatedBackend
from repro.serving.continuous import ContinuousEngine
from repro.serving.engine import Request

MAX_SEQ = 256
PREFILL_CHUNK = 16
# skewed popularity (low Dirichlet concentration): placement quality
# matters, so a shift has something to break — App. D's regime, not the
# near-uniform ShareGPT one
CONCENTRATION = 0.5
RESULTS_JSON = Path(__file__).resolve().parents[1] / "BENCH_workload_shift.json"


def shifted_profile(calib: ExpertProfile, seed: int = 1) -> ExpertProfile:
    """The post-shift routing distribution: each layer's popularity vector
    permuted — same skew, different popular experts (the worst case for a
    frozen placement at equal entropy)."""
    rng = np.random.default_rng(seed)
    L, E = calib.counts.shape
    return ExpertProfile(np.stack(
        [calib.counts[l][rng.permutation(E)] for l in range(L)]))


def _phase(serving: ContinuousEngine, led, reqs: List[Request],
           max_steps: int) -> Dict[str, float]:
    """Run one traffic phase and report ledger deltas for exactly it."""
    pre = (led.fast_hits, led.streams, led.slow_runs, led.sim_time,
           led.tokens_out, led.migrations, led.migration_time,
           led.migration_bytes)
    for r in reqs:
        serving.submit(r)
    done = serving.run(max_steps=max_steps, on_exhausted="raise")
    assert len(done) >= len(reqs), (len(done), len(reqs))
    d_hits = led.fast_hits - pre[0]
    d_streams = led.streams - pre[1]
    d_slow = led.slow_runs - pre[2]
    d_time = led.sim_time - pre[3]
    d_tokens = led.tokens_out - pre[4]
    return {
        "hit_rate": d_hits / max(d_hits + d_streams + d_slow, 1),
        "latency_per_token": d_time / max(d_tokens, 1),
        "tokens": float(d_tokens),
        "sim_seconds": d_time,
        "migrations": float(led.migrations - pre[5]),
        "migration_time": led.migration_time - pre[6],
        "migration_bytes": led.migration_bytes - pre[7],
    }


def shift_once(model_name: str, env: str, *, dynamic: bool,
               rate_hz: float = 16.0, n_slots: int = 4,
               n_requests: int = 12, shift_requests: int = 24,
               prompt_len: int = 32, max_new: int = 16,
               rebalance_interval: int = 4, rebalance_k: int = 8,
               seed: int = 0, max_steps: int = 100_000) -> Dict[str, Dict]:
    """One trace: calibration-matched traffic, then the routing shift.

    Placement is fit to the calibration profile; phase 2 draws routing
    from the shifted profile.  ``dynamic=True`` attaches a Rebalancer."""
    cfg = get_config(model_name)
    L, E = cfg.n_layers, cfg.moe.n_experts
    calib = synthetic_profile(L, E, seed=seed, concentration=CONCENTRATION)
    shifted = shifted_profile(calib, seed=seed + 1)
    eng = FiddlerEngine(
        cfg, policy="fiddler", hw=ENVS[env], profile=calib,
        expert_budget=L * E // 4, seed=seed,
        rebalance_interval=rebalance_interval if dynamic else None,
        rebalance_k=rebalance_k)
    serving = ContinuousEngine(SimulatedBackend(eng, max_seq=MAX_SEQ),
                               n_slots=n_slots, max_seq=MAX_SEQ,
                               prefill_chunk=PREFILL_CHUNK)
    led = eng.ledger

    def stream(n, phase_seed, t0):
        reqs = poisson_requests(rate_hz, n, prompt_len=prompt_len,
                                max_new=max_new, seed=phase_seed)
        for r in reqs:
            r.arrival += t0
        return reqs

    phase1 = _phase(serving, led, stream(n_requests, seed + 10, 0.0),
                    max_steps)
    # --- the mid-trace routing shift: traffic keeps flowing, the router's
    # distribution is now the permuted one; placement still fits calib ---
    eng.profile = shifted
    phase2 = _phase(serving, led,
                    stream(shift_requests, seed + 11, led.sim_time),
                    max_steps)
    return {
        "phase1": phase1,
        "phase2": phase2,
        "placement_hit_rate_calib": hit_rate(calib, eng.placement),
        "placement_hit_rate_shifted": hit_rate(shifted, eng.placement),
    }


def run(model: str = "mixtral-8x7b", fast: bool = False,
        smoke: bool = False) -> Dict[str, Dict]:
    """Sweep static vs dynamic placement across paper envs.  ``smoke``
    shrinks everything to a few requests (CI's bench-smoke lane)."""
    if smoke:
        envs, sizes = ["env1"], dict(n_requests=3, shift_requests=6,
                                     max_new=8, prompt_len=16)
    elif fast:
        envs, sizes = ["env1"], dict(n_requests=8, shift_requests=16)
    else:
        envs, sizes = ["env1", "env2"], dict(n_requests=12,
                                             shift_requests=32)
    results: Dict[str, Dict] = {}
    for env in envs:
        for mode in ("static", "dynamic"):
            r = shift_once(model, env, dynamic=(mode == "dynamic"), **sizes)
            key = f"workload_shift/{env}/{mode}"
            p2 = r["phase2"]
            emit(key, p2["latency_per_token"] * 1e6,
                 f"post_shift_hit_rate={p2['hit_rate']:.3f} "
                 f"lat_per_tok={p2['latency_per_token'] * 1e3:.2f}ms "
                 f"migrations={p2['migrations']:.0f} "
                 f"mig_time={p2['migration_time'] * 1e3:.1f}ms")
            results[key] = r
    record = {
        "_meta": {
            "mode": "smoke" if smoke else ("fast" if fast else "full"),
            "model": model, "envs": envs, "concentration": CONCENTRATION,
            **sizes,
        },
        "results": results,
        "summary": {
            env: {
                "static_post_shift_hit_rate":
                    results[f"workload_shift/{env}/static"]["phase2"]["hit_rate"],
                "dynamic_post_shift_hit_rate":
                    results[f"workload_shift/{env}/dynamic"]["phase2"]["hit_rate"],
                "static_post_shift_latency_per_token":
                    results[f"workload_shift/{env}/static"]["phase2"]["latency_per_token"],
                "dynamic_post_shift_latency_per_token":
                    results[f"workload_shift/{env}/dynamic"]["phase2"]["latency_per_token"],
                "dynamic_migration_time":
                    results[f"workload_shift/{env}/dynamic"]["phase2"]["migration_time"],
            } for env in envs
        },
    }
    RESULTS_JSON.write_text(json.dumps(record, indent=2, sort_keys=True))
    return results


if __name__ == "__main__":
    import sys

    run(fast="--full" not in sys.argv, smoke="--smoke" in sys.argv)
