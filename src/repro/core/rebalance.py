"""Dynamic CPU↔GPU expert rebalancing against the live routing profile.

Fiddler places experts once, from an offline popularity profile, and
freezes the placement (paper §3.4 / App. C: "popularity is almost
universal across domains").  App. D shows where that assumption breaks —
a routing-distribution shift between the calibration set and the live
workload strands popular experts on the slow tier.  This module makes
placement a living part of the serving loop:

* an :class:`repro.core.popularity.OnlineProfile` tracks the routing
  distribution the orchestrator actually observes (EWMA per layer, fed
  from every forward/serving step);
* a :class:`Rebalancer` periodically re-runs the paper's
  popularity-greedy placement (§3.1) against the live profile and emits a
  *bounded* :class:`MigrationPlan` — at most ``k`` expert swaps per
  interval, chosen by expected fast-tier hit-rate gain per transferred
  byte from the cost model (§3.3) — instead of a full re-place;
* the engine applies the plan incrementally: promotions ride the
  existing FAST_STREAM ``device_put`` path (paper Fig. 3b) and are
  charged to the simulated-seconds ledger at ``transfer_lat()`` each;
  demotions just drop fast-tier residency (freeing HBM costs nothing).

With the engine's ``async_prefetch`` mode (default when overlap is on),
promotion transfers are not charged serially between steps: they enter a
:class:`PrefetchQueue` and ride the host link while it would otherwise
sit idle under fast-tier compute (the paper's idle-GPU observation,
applied to the link).  Only the remainder that cannot hide — a promoted
expert routed before its transfer finished, or a flush — is charged to
``sim_time`` (``Ledger.migration_exposed``); the hidden part accrues to
``Ledger.migration_overlapped``.

The swap budget ``k`` bounds the per-interval transfer burst so
rebalancing never stalls serving; the hit-rate-gain threshold keeps the
placement stable when the live distribution matches the calibration one
(no churn in the steady state).
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import List, Optional, Tuple

import numpy as np

from repro.core.placement import (
    DevicePlacement,
    Placement,
    place_by_popularity,
)
from repro.core.popularity import OnlineProfile


@dataclass(frozen=True)
class MigrationPlan:
    """A bounded set of expert swaps: ``promotes[i]`` moves slow→fast
    (streamed over the host link), ``demotes[i]`` drops fast-tier
    residency.  ``est_gain`` is the expected fast-tier hit-rate gain
    (mean over layers) under the live profile; ``transfer_bytes`` /
    ``est_transfer_s`` are the promotion cost the ledger must be charged
    (demotions are free).  ``devices[i]`` names the fast device promotion
    ``i`` streams to (empty = everything to device 0, the single-device
    plan shape)."""

    promotes: Tuple[Tuple[int, int], ...]   # (layer, expert) slow → fast
    demotes: Tuple[Tuple[int, int], ...]    # (layer, expert) fast → slow
    est_gain: float
    transfer_bytes: int
    est_transfer_s: float
    devices: Tuple[int, ...] = ()           # target fast device per promote

    def device_of(self, i: int) -> int:
        return self.devices[i] if self.devices else 0

    @property
    def n_swaps(self) -> int:
        return len(self.promotes)

    @property
    def gain_per_byte(self) -> float:
        return self.est_gain / self.transfer_bytes if self.transfer_bytes \
            else 0.0


@dataclass
class Rebalancer:
    """Periodic bounded re-placement against an :class:`OnlineProfile`.

    ``tick()`` is called once per serving step (the engines call it
    between decode steps); every ``interval`` ticks it diffs the current
    placement against the popularity-greedy target for the live profile
    and returns a plan of at most ``k`` swaps — the top candidates by
    hit-rate gain per transferred byte (every expert transfers
    ``expert_bytes``, so within one model this ranks by gain; the
    per-byte framing is what makes budgets comparable across
    heterogeneous expert sizes).  Swaps whose per-layer probability gain
    is ≤ ``min_gain`` are dropped, so a placement already matching the
    live distribution is left alone.
    """

    profile: OnlineProfile
    budget: int                   # fast-tier expert budget (placement size)
    expert_bytes: int             # bytes streamed per promotion
    transfer_lat: float           # seconds per promotion (cost model)
    interval: int = 32            # ticks between re-plans
    k: int = 4                    # max swaps per re-plan
    min_gain: float = 1e-4        # min per-layer probability gain per swap
    ticks: int = field(default=0, init=False)
    plans: int = field(default=0, init=False)
    swaps: int = field(default=0, init=False)

    def __post_init__(self):
        assert self.interval >= 1 and self.k >= 1, (self.interval, self.k)

    def observe(self, layer: int, counts: np.ndarray) -> None:
        self.profile.observe(layer, counts)

    def tick(self, placement: Placement) -> Optional[MigrationPlan]:
        """Advance the interval clock; on expiry, plan against the live
        profile.  Returns None when it is not time yet or no swap clears
        ``min_gain``."""
        self.ticks += 1
        if self.ticks % self.interval != 0:
            return None
        plan = self.plan(placement)
        if plan is None:
            return None
        self.plans += 1
        self.swaps += plan.n_swaps
        return plan

    def plan(self, placement: Placement) -> Optional[MigrationPlan]:
        p = self.profile.probabilities()          # (L, E) live routing
        current = placement.on_fast
        target = place_by_popularity(self.profile.snapshot(),
                                     self.budget).on_fast
        # candidate promotions: in the live-optimal target, not resident —
        # most popular first; demotions: resident but not in the target —
        # least popular first.  Pairing i-th with i-th maximises the gain
        # of each swap.
        promos = sorted(zip(*np.nonzero(target & ~current)),
                        key=lambda le: -p[le])
        demos = sorted(zip(*np.nonzero(current & ~target)),
                       key=lambda le: p[le])
        L = p.shape[0]
        promotes: List[Tuple[int, int]] = []
        demotes: List[Tuple[int, int]] = []
        gain = 0.0
        for pr, de in zip(promos[: self.k], demos[: self.k]):
            # expected hit-rate gain of this swap: each layer contributes
            # 1/L to the mean hit rate (every token visits every layer)
            g = (p[pr] - p[de]) / L
            if g <= self.min_gain / L:
                break  # candidates are sorted: later swaps gain even less
            promotes.append((int(pr[0]), int(pr[1])))
            demotes.append((int(de[0]), int(de[1])))
            gain += g
        if not promotes:
            return None
        n = len(promotes)
        # devices × tiers: each promotion streams to the device its paired
        # demotion vacates, so per-device budgets are invariant under the
        # swap (two-tier placements put everything on device 0)
        devices: Tuple[int, ...] = ()
        if isinstance(placement, DevicePlacement):
            devices = tuple(int(placement.device[de]) for de in demotes)
        return MigrationPlan(
            promotes=tuple(promotes), demotes=tuple(demotes),
            est_gain=gain, transfer_bytes=n * self.expert_bytes,
            est_transfer_s=n * self.transfer_lat, devices=devices)


@dataclass
class _Pending:
    """One in-flight promotion transfer: ``remaining`` link-seconds until
    expert ``expert`` of layer ``layer`` is actually resident.
    ``weight`` is the expert's live routing popularity — the transmission
    priority.  ``total`` is the transfer's original full length — what a
    verification failure must requeue (core/faults.py)."""

    layer: int
    expert: int
    remaining: float
    weight: float = 0.0
    total: float = 0.0
    link: int = 0          # host↔device link (fast device) transmitting it

    def __post_init__(self):
        if self.total <= 0.0:
            self.total = self.remaining


class PrefetchQueue:
    """Popularity-ordered queue of promotion transfers riding idle link
    time.

    ``apply_migrations`` pushes each promotion's ``transfer_lat()`` here
    instead of charging it to ``sim_time``; the engine's per-layer charge
    then (a) *forces* any transfer whose target expert is about to
    execute — the remainder serialises, i.e. is exposed — and (b)
    *drains* the queue with the layer's idle link seconds (layer
    wall-clock minus the time FAST_STREAM transfers keep the link busy) —
    that part is overlapped, hidden under compute the clock already
    charged.

    The link is a single serial resource, so entries transmit in queue
    order — but the *order* is ours to choose: entries are kept sorted by
    ``weight`` (the promoted expert's ``OnlineProfile`` popularity),
    descending, so the promotion most likely to be routed next lands
    first and is least likely to be forced into exposed serial time.
    Equal weights (and the default ``weight=0``) preserve FIFO.

    **Per-link accounting** (``n_links > 1``): every fast device has its
    own host↔device DMA link, so a mesh engine runs one serial queue per
    link and drains them *concurrently* — one layer's idle window hides
    up to ``n_links × idle`` link-seconds.  ``push(..., link=d)`` routes
    a promotion onto its target device's link; forcing a transfer only
    serialises the entries ahead of it on the *same* link.  The default
    ``n_links=1`` is byte-for-byte the single-device queue.
    """

    def __init__(self, n_links: int = 1) -> None:
        assert n_links >= 1, n_links
        self.n_links = n_links
        self._links: List[List[_Pending]] = [[] for _ in range(n_links)]
        # transfers completed since the last pop_completed() — the
        # engine's post-transfer verification hook (docs/resilience.md)
        self.completed: List[_Pending] = []

    @property
    def _q(self) -> List[_Pending]:
        """Flattened in-flight view (link-major), for introspection."""
        return [p for q in self._links for p in q]

    def __len__(self) -> int:
        return sum(len(q) for q in self._links)

    @property
    def backlog(self) -> float:
        """Link-seconds of transfer still in flight."""
        return sum(p.remaining for q in self._links for p in q)

    def push(self, layer: int, expert: int, seconds: float,
             weight: float = 0.0, link: int = 0) -> None:
        link = int(link) % self.n_links
        item = _Pending(int(layer), int(expert), float(seconds),
                        float(weight), link=link)
        # stable descending insert: after every entry with weight >= ours,
        # so equal weights (including the default 0) keep arrival order.
        # A part-sent head that gets displaced is simply paused — the
        # remaining link-seconds are conserved, so the ledger accounting
        # is unchanged.
        q = self._links[link]
        i = len(q)
        while i > 0 and q[i - 1].weight < item.weight:
            i -= 1
        q.insert(i, item)

    def force(self, layer: int, used) -> float:
        """Complete every pending transfer targeting ``layer`` whose
        expert is in ``used`` (it executes *now*, so the rest of its
        transfer serialises).  FIFO ordering per link: everything queued
        ahead of a forced transfer on its own link must finish first —
        each link is serial.  Returns the exposed link-seconds (summed
        over links, so ``overlapped + exposed == pushed`` stays exact)."""
        exposed = 0.0
        for q in self._links:
            last = -1
            for i, p in enumerate(q):
                if p.layer == layer and p.expert in used:
                    last = i
            if last < 0:
                continue
            exposed += sum(p.remaining for p in q[: last + 1])
            self.completed.extend(q[: last + 1])
            del q[: last + 1]
        return exposed

    def drain(self, idle: float) -> float:
        """Consume up to ``idle`` link-seconds on *each* link (the links
        transmit concurrently under the same idle window); returns the
        overlapped link-seconds actually hidden."""
        overlapped = 0.0
        for q in self._links:
            budget = idle
            while q and budget > 0.0:
                p = q[0]
                d = min(p.remaining, budget)
                p.remaining -= d
                budget -= d
                overlapped += d
                if p.remaining <= 1e-15:
                    self.completed.append(q.pop(0))
        return overlapped

    def flush(self) -> float:
        """Complete everything now (serialising); returns exposed
        link-seconds."""
        exposed = self.backlog
        for q in self._links:
            self.completed.extend(q)
            q.clear()
        return exposed

    def pop_completed(self) -> List[_Pending]:
        """Hand over (and clear) the transfers completed since the last
        call — the engine verifies each one and requeues failures."""
        done = self.completed
        self.completed = []
        return done


def apply_plan(placement: Placement, plan: MigrationPlan) -> Placement:
    """The placement after ``plan``'s swaps (pure; engines charge the
    transfer cost separately).  Device placements keep their device map:
    each promotion lands on ``plan.device_of(i)``."""
    on = placement.on_fast.copy()
    for le in plan.demotes:
        assert on[le], f"demote of non-resident expert {le}"
        on[le] = False
    for le in plan.promotes:
        assert not on[le], f"promote of already-resident expert {le}"
        on[le] = True
    if isinstance(placement, DevicePlacement):
        dev = placement.device.copy()
        for le in plan.demotes:
            dev[le] = -1
        for i, le in enumerate(plan.promotes):
            dev[le] = plan.device_of(i)
        return DevicePlacement(on, dev)
    return Placement(on)
