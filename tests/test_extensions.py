"""Beyond-paper orchestrator extensions: LRU expert cache, adaptive
placement, int8 slow tier (core/expert_cache.py)."""
import jax
import jax.numpy as jnp
import numpy as np
from hypothesis import given, settings, strategies as st

from conftest import reduced_model
from repro.configs import get_config
from repro.core import FiddlerEngine, HardwareSpec
from repro.core.expert_cache import (
    LRUExpertCache,
    QuantizedHostExpert,
    dequantize_expert,
    quantize_expert,
)
from repro.core.popularity import synthetic_profile


@given(st.integers(1, 8), st.lists(
    st.tuples(st.integers(0, 3), st.integers(0, 7)), min_size=1, max_size=64))
@settings(max_examples=100, deadline=None)
def test_lru_never_exceeds_capacity(cap, accesses):
    lru = LRUExpertCache(cap)
    for (l, e) in accesses:
        if not lru.lookup(l, e):
            lru.insert(l, e)
        assert lru.occupancy <= cap


def test_lru_eviction_order():
    lru = LRUExpertCache(2)
    lru.insert(0, 0)
    lru.insert(0, 1)
    assert lru.lookup(0, 0)          # touch 0 → 1 is now LRU
    evicted = lru.insert(0, 2)
    assert evicted == (0, 1)
    assert (0, 0) in lru and (0, 2) in lru


def test_zero_capacity_cache_is_noop():
    lru = LRUExpertCache(0)
    assert lru.insert(0, 0) is None
    assert not lru.lookup(0, 0)
    assert lru.occupancy == 0


@given(st.integers(2, 64), st.integers(2, 64), st.integers(0, 2 ** 31 - 1))
@settings(max_examples=50, deadline=None)
def test_int8_roundtrip_error_bounded(din, dout, seed):
    rng = np.random.default_rng(seed)
    w = rng.standard_normal((din, dout)).astype(np.float32)
    q, s = quantize_expert(w)
    back = dequantize_expert(q, s)
    # per-channel symmetric int8: error ≤ scale/2 per element
    assert np.all(np.abs(back - w) <= s / 2 + 1e-7)


def test_quantized_host_expert_close():
    rng = np.random.default_rng(0)
    d, f = 64, 128
    wg, wu = [rng.standard_normal((d, f)).astype(np.float32) * 0.05
              for _ in range(2)]
    wd = rng.standard_normal((f, d)).astype(np.float32) * 0.05
    from repro.kernels.host_expert import HostExpert

    x = rng.standard_normal((4, d)).astype(np.float32) * 0.3
    exact = HostExpert(wg, wu, wd, precision="fp32")(x)
    quant = QuantizedHostExpert(wg, wu, wd)(x)
    assert np.abs(quant - exact).max() < 0.05
    assert QuantizedHostExpert(wg, wu, wd).nbytes() < 0.6 * (3 * d * f * 2)


def test_lru_improves_offload_decode():
    full = get_config("mixtral-8x7b")
    kw = dict(policy="offload", hw=HardwareSpec.paper_env1(), seed=0)
    base = FiddlerEngine(full, **kw).simulate_generate(64, 64)
    lru = FiddlerEngine(full, **kw, lru_cache_experts=64) \
        .simulate_generate(64, 64)
    assert lru["tokens_per_s"] > base["tokens_per_s"] * 1.1


def test_int8_improves_fiddler_decode():
    full = get_config("mixtral-8x7b")
    kw = dict(policy="fiddler", hw=HardwareSpec.paper_env1(), seed=0)
    base = FiddlerEngine(full, **kw).simulate_generate(64, 64)
    q = FiddlerEngine(full, **kw, quantize_slow=True) \
        .simulate_generate(64, 64)
    assert q["tokens_per_s"] > base["tokens_per_s"] * 1.3


def test_adaptive_placement_tracks_shift():
    full = get_config("mixtral-8x7b")
    serve = synthetic_profile(full.n_layers, full.moe.n_experts, seed=123,
                              concentration=3.0)
    kw = dict(policy="fiddler", hw=HardwareSpec.paper_env1(), seed=0,
              profile=synthetic_profile(full.n_layers, full.moe.n_experts,
                                        seed=0))
    static = FiddlerEngine(full, **kw)
    static.profile = serve
    adapt = FiddlerEngine(full, **kw, adaptive=True)
    adapt.profile = serve
    r_static = static.simulate_generate(64, 384)
    r_adapt = adapt.simulate_generate(64, 384)
    assert adapt.adaptive.swapped_experts > 0
    assert r_adapt["tokens_per_s"] > r_static["tokens_per_s"]


def test_real_mode_lru_and_int8_numerics():
    cfg, model, params = reduced_model("mixtral-8x7b")
    tokens = jax.random.randint(jax.random.PRNGKey(1), (2, 12), 3,
                                cfg.vocab_size)
    ref, _ = model.prefill(params, tokens, max_seq=32,
                           cache_dtype=jnp.float32)
    eng = FiddlerEngine(cfg, params, policy="offload", expert_budget=2,
                        host_precision="fp32", lru_cache_experts=6)
    lg, caches = eng.prefill(tokens, max_seq=32)
    np.testing.assert_allclose(np.asarray(lg), np.asarray(ref), rtol=3e-4,
                               atol=3e-4)
    for i in range(4):
        lg, caches = eng.decode_step(caches, tokens[:, :1], pos=12 + i,
                                     max_seq=32)
    assert eng.lru.hits > 0

    engq = FiddlerEngine(cfg, params, policy="fiddler", expert_budget=2,
                         quantize_slow=True)
    lgq, _ = engq.prefill(tokens, max_seq=32)
    err = float(jnp.abs(lgq - jnp.asarray(ref)).max())
    assert err < 0.5  # int8-level, not garbage
