"""FID006 unwatched-future / blanket-handler.

The chaos layer (core/faults.py, docs/resilience.md) only works if the
hot path *observes* failures instead of hanging on them or eating them.
Two patterns:

* **future awaited without a timeout** — ``fut.result()`` with neither a
  positional timeout nor ``timeout=``, inside a function that submits
  work to an executor (contains a ``.submit(`` call) or is reachable
  from the configured hot roots.  A stalled host-pool worker then hangs
  the scheduler thread forever; the watchdog idiom is
  ``fut.result(timeout=...)`` with bounded retry/backoff and an inline
  fallback (``FiddlerEngine._await_host``).  The awaited method names
  are configurable (``future_await_methods``, default ``["result"]``).
* **blanket exception handler on the hot path** — ``except Exception:``
  / ``except BaseException:`` / bare ``except:`` without a re-raise, in
  a hot-reachable function.  Injected faults are recoverable *by type*
  (``FaultError``, ``KVPoolExhausted``); a blanket handler silently
  converts real bugs into "recovered" faults.  Handlers that re-raise
  (including ``raise X from e``) pass — they narrate, not swallow.

The ``.submit(``-containing criterion exists because the call graph
resolves attribute calls by method name and misses calls through local
variables — the dispatch closure handed to ``_run_moe_layer`` — so an
awaiting function can be hot in fact yet unreachable in the graph.
Submitting work is itself the evidence that futures are awaited here.
"""
from __future__ import annotations

import ast
from typing import List, Set

from repro.analysis.config import FiddlintConfig
from repro.analysis.core import Finding, relpath
from repro.analysis.project import FunctionInfo, Project


def _calls_submit(fn: FunctionInfo) -> bool:
    for node in ast.walk(fn.node):
        if (isinstance(node, ast.Call)
                and isinstance(node.func, ast.Attribute)
                and node.func.attr == "submit"):
            return True
    return False


def _broad_exc_name(node: ast.ExceptHandler) -> str:
    """"Exception"/"BaseException"/"" (bare) when the handler is blanket,
    else None.  Tuples count if any member is blanket."""
    if node.type is None:
        return ""
    names = (node.type.elts if isinstance(node.type, ast.Tuple)
             else [node.type])
    for t in names:
        if isinstance(t, ast.Name) and t.id in ("Exception", "BaseException"):
            return t.id
    return None


def _check_awaits(fn: FunctionInfo, methods: Set[str], path: str,
                  via: str, out: List[Finding]) -> None:
    for node in ast.walk(fn.node):
        if not (isinstance(node, ast.Call)
                and isinstance(node.func, ast.Attribute)
                and node.func.attr in methods):
            continue
        if node.args or any(kw.arg == "timeout" for kw in node.keywords):
            continue  # positional or keyword timeout: watchdogged
        out.append(Finding(
            "FID006", path, node.lineno, node.col_offset,
            f"future awaited without a timeout: `.{node.func.attr}()` "
            f"hangs the scheduler forever on a stalled host worker{via}; "
            f"pass `timeout=` and retry/fall back on expiry (the watchdog "
            f"idiom — docs/resilience.md)", fn.qualname))


def _check_handlers(fn: FunctionInfo, path: str, via: str,
                    out: List[Finding]) -> None:
    for node in ast.walk(fn.node):
        if not isinstance(node, ast.ExceptHandler):
            continue
        name = _broad_exc_name(node)
        if name is None:
            continue
        if any(isinstance(n, ast.Raise) for n in ast.walk(node)):
            continue  # re-raises: narrates the failure, doesn't swallow it
        label = f"`except {name}`" if name else "bare `except:`"
        out.append(Finding(
            "FID006", path, node.lineno, node.col_offset,
            f"blanket {label} on the serving hot path{via} swallows real "
            f"bugs alongside recoverable faults; catch the specific types "
            f"(FaultError, KVPoolExhausted) or re-raise", fn.qualname))


def check_watchdog(project: Project,
                   config: FiddlintConfig) -> List[Finding]:
    out: List[Finding] = []
    methods = set(config.future_await_methods)
    hot = project.reachable_from(project.resolve_roots(config.hot_roots))
    for qual, fn in project.functions.items():
        root = hot.get(qual)
        submitter = _calls_submit(fn)
        if root is None and not submitter:
            continue
        via = ("" if root is None or qual == root
               else f" (reachable from {root})")
        path = relpath(fn.file.path)
        _check_awaits(fn, methods, path, via, out)
        if root is not None:
            _check_handlers(fn, path, via, out)
    return out
