"""Pallas TPU kernel: fused gated-SiLU expert MLP.

TPU-native rethinking of the paper's AVX512_BF16 CPU expert kernel (§3.4).
The role is the same — a hand-tiled bf16 GEMM pipeline for a single expert —
but the tiling targets the TPU memory hierarchy instead of x86 cache lines:

* the (s, d_ff) intermediate activations never round-trip to HBM — the
  kernel accumulates ``(silu(xWg) ⊙ xWu) Wd`` into a VMEM fp32 scratch
  block while streaming d_ff-tiles of the three weight matrices HBM→VMEM;
* block shapes are MXU-aligned (multiples of (8×128 lanes); defaults
  128×512) and sized so the working set fits VMEM (~16 MB);
* the d_ff grid axis is the innermost (sequential) loop → revisiting the
  same output block lets Mosaic keep the accumulator resident.

Grid: (s / block_s, d_ff / block_f); the second axis is a reduction.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

# TPU-specific VMEM hints only matter on real hardware; keep import soft so
# the interpret-mode path works on any backend.
try:  # pragma: no cover
    from jax.experimental.pallas import tpu as pltpu
    _HAS_PLTPU = True
except ImportError:  # pragma: no cover
    import warnings

    _HAS_PLTPU = False
    warnings.warn(
        "jax.experimental.pallas.tpu unavailable; expert-MLP kernels use "
        "generic pallas memory spaces (interpret mode only)",
        RuntimeWarning, stacklevel=2)


def _expert_mlp_kernel(x_ref, wg_ref, wu_ref, wd_ref, o_ref, acc_ref):
    """One (block_s, block_f) step of the fused gated MLP."""
    jf = pl.program_id(1)

    @pl.when(jf == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    x = x_ref[...].astype(jnp.float32)            # (bs, d)
    g = jnp.dot(x, wg_ref[...].astype(jnp.float32),
                preferred_element_type=jnp.float32)      # (bs, bf)
    u = jnp.dot(x, wu_ref[...].astype(jnp.float32),
                preferred_element_type=jnp.float32)
    h = jax.nn.silu(g) * u
    acc_ref[...] += jnp.dot(h, wd_ref[...].astype(jnp.float32),
                            preferred_element_type=jnp.float32)  # (bs, d)

    @pl.when(jf == pl.num_programs(1) - 1)
    def _done():
        o_ref[...] = acc_ref[...].astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("block_s", "block_f", "interpret"))
def expert_mlp(x: jnp.ndarray, w_gate: jnp.ndarray, w_up: jnp.ndarray,
               w_down: jnp.ndarray, *, block_s: int = 128,
               block_f: int = 512, interpret: bool = True) -> jnp.ndarray:
    """x: (s, d); w_gate/w_up: (d, f); w_down: (f, d) → (s, d).

    ``interpret=True`` executes the kernel body in Python on CPU (how this
    container validates it); on a TPU runtime pass ``interpret=False``.
    """
    s, d = x.shape
    f = w_gate.shape[1]
    block_s = min(block_s, s)
    block_f = min(block_f, f)
    pad_s = (-s) % block_s
    pad_f = (-f) % block_f
    if pad_s:
        x = jnp.pad(x, ((0, pad_s), (0, 0)))
    if pad_f:
        w_gate = jnp.pad(w_gate, ((0, 0), (0, pad_f)))
        w_up = jnp.pad(w_up, ((0, 0), (0, pad_f)))
        w_down = jnp.pad(w_down, ((0, pad_f), (0, 0)))
    sp, fp = s + pad_s, f + pad_f
    grid = (sp // block_s, fp // block_f)

    out = pl.pallas_call(
        _expert_mlp_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((block_s, d), lambda i, j: (i, 0)),
            pl.BlockSpec((d, block_f), lambda i, j: (0, j)),
            pl.BlockSpec((d, block_f), lambda i, j: (0, j)),
            pl.BlockSpec((block_f, d), lambda i, j: (j, 0)),
        ],
        out_specs=pl.BlockSpec((block_s, d), lambda i, j: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((sp, d), x.dtype),
        scratch_shapes=[_scratch((block_s, d))],
        interpret=interpret,
    )(x, w_gate, w_up, w_down)
    return out[:s]


def _scratch(shape):
    """fp32 VMEM scratch accumulator (backend-portable)."""
    if _HAS_PLTPU:
        return pltpu.VMEM(shape, jnp.float32)
    import jax.experimental.pallas as _pl
    return _pl.MemoryRef(shape, jnp.float32)  # pragma: no cover
