"""jit'd public wrappers around the kernels.

``expert_mlp_op`` picks the Pallas kernel when it is profitable/available
and falls back to the jnp reference otherwise; both share the oracle
semantics in ref.py.  The Fiddler orchestrator calls these for fast-tier
expert execution; ``host_expert.HostExpert`` is the slow-tier path.
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

from repro.kernels import ref
from repro.kernels.expert_mlp import expert_mlp
from repro.kernels.moe_gmm import moe_gmm, moe_gmm_mlp

# On this container Pallas runs in interpret mode (Python) — correct but
# slow, so the jitted reference is the default execution path and the
# Pallas kernels are exercised by tests/benchmarks.  On a TPU runtime flip
# USE_PALLAS=True / INTERPRET=False.
USE_PALLAS = False
INTERPRET = True


@jax.jit
def _expert_mlp_jnp(x, w_gate, w_up, w_down):
    return ref.expert_mlp_ref(x, w_gate, w_up, w_down)


def expert_mlp_op(x: jnp.ndarray, w_gate: jnp.ndarray, w_up: jnp.ndarray,
                  w_down: jnp.ndarray, *, use_pallas: Optional[bool] = None
                  ) -> jnp.ndarray:
    """Fast-tier single-expert gated MLP. x: (s, d) → (s, d)."""
    if use_pallas is None:
        use_pallas = USE_PALLAS
    if use_pallas:
        return expert_mlp(x, w_gate, w_up, w_down, interpret=INTERPRET)
    return _expert_mlp_jnp(x, w_gate, w_up, w_down)


@jax.jit
def _moe_gmm_jnp(xs, ws, counts):
    return ref.moe_gmm_ref(xs, ws, counts)


def moe_gmm_op(xs: jnp.ndarray, ws: jnp.ndarray, counts: jnp.ndarray, *,
               use_pallas: Optional[bool] = None) -> jnp.ndarray:
    """Grouped per-expert matmul over capacity buckets."""
    if use_pallas is None:
        use_pallas = USE_PALLAS
    if use_pallas:
        return moe_gmm(xs, ws, counts, interpret=INTERPRET)
    return _moe_gmm_jnp(xs, ws, counts)


@jax.jit
def _grouped_gated_mlp_jnp(xs, w_gate, w_up, w_down, counts):
    return ref.grouped_gated_mlp_ref(xs, w_gate, w_up, w_down, counts)


@jax.jit
def _grouped_uniform_mlp_jnp(xs, w_gate, w_up, w_down):
    return ref.grouped_gated_mlp_ref(xs, w_gate, w_up, w_down, None)


def grouped_gated_mlp_op(xs: jnp.ndarray, w_gate: jnp.ndarray,
                         w_up: jnp.ndarray, w_down: jnp.ndarray,
                         counts: Optional[jnp.ndarray], *,
                         use_pallas: Optional[bool] = None) -> jnp.ndarray:
    """Fast-tier grouped gated MLP over a capacity-bucketed dispatch
    buffer: one kernel launch for a whole expert group instead of one
    ``expert_mlp_op`` per expert.  xs: (E, C, d); counts: (E,) int32 →
    (E, C, d) with rows ≥ counts[e] zeroed; ``counts=None`` means every
    expert uses all C rows (single compiled branch — the cheap form for
    large uniform row counts).  Per-expert slices are bit-identical to
    ``expert_mlp_op`` on fp32 (exact-row-count GEMMs, see ref.py) — the
    orchestrator's grouped/eager equivalence relies on this."""
    if use_pallas is None:
        use_pallas = USE_PALLAS
    if use_pallas:
        if counts is None:
            counts = jnp.full(xs.shape[0], xs.shape[1], jnp.int32)
        return moe_gmm_mlp(xs, w_gate, w_up, w_down, counts,
                           interpret=INTERPRET)
    if counts is None:
        return _grouped_uniform_mlp_jnp(xs, w_gate, w_up, w_down)
    return _grouped_gated_mlp_jnp(xs, w_gate, w_up, w_down, counts)


@jax.jit
def _grouped_gather_mlp_jnp(xs, slots, w_gate, w_up, w_down, counts):
    return ref.grouped_gated_mlp_ref(xs, w_gate[slots], w_up[slots],
                                     w_down[slots], counts)


@jax.jit
def _grouped_gather_uniform_jnp(xs, slots, w_gate, w_up, w_down):
    return ref.grouped_gated_mlp_ref(xs, w_gate[slots], w_up[slots],
                                     w_down[slots], None)


def grouped_gather_mlp_op(xs: jnp.ndarray, slots: jnp.ndarray,
                          w_gate: jnp.ndarray, w_up: jnp.ndarray,
                          w_down: jnp.ndarray,
                          counts: Optional[jnp.ndarray], *,
                          use_pallas: Optional[bool] = None) -> jnp.ndarray:
    """``grouped_gated_mlp_op`` with the expert-weight gather fused into
    the same launch: ``slots`` (G,) int32 indexes rows of the per-layer
    *stacked* fast-pool arrays ``w_gate/w_up/w_down`` (E_fast, d, f), so
    dispatching G active experts out of a larger resident stack is still
    one kernel call with FLOPs proportional to the active group."""
    if use_pallas is None:
        use_pallas = USE_PALLAS
    if use_pallas:
        if counts is None:
            counts = jnp.full(xs.shape[0], xs.shape[1], jnp.int32)
        return moe_gmm_mlp(xs, w_gate[slots], w_up[slots], w_down[slots],
                           counts, interpret=INTERPRET)
    if counts is None:
        return _grouped_gather_uniform_jnp(xs, slots, w_gate, w_up, w_down)
    return _grouped_gather_mlp_jnp(xs, slots, w_gate, w_up, w_down, counts)
