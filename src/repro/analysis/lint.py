"""fiddlint CLI.

Usage::

    python -m repro.analysis.lint [paths...] [--select FID001,FID003]
        [--no-baseline | --baseline FILE] [--update-baseline]
        [--format text|json] [--output FILE] [--hot-root QUALNAME ...]

Exit status 0 when every finding is suppressed or baselined, 1 when
actionable findings remain, 2 on usage errors.  Output is ruff-style::

    src/repro/core/orchestrator.py:812: FID001 `.item()` forces a host sync ...
"""
from __future__ import annotations

import argparse
import json
import sys
from pathlib import Path
from typing import List, Optional

from repro.analysis.config import RULE_IDS, load_config
from repro.analysis.core import Baseline, LintResult, run_lint


def _parse_args(argv: Optional[List[str]]) -> argparse.Namespace:
    ap = argparse.ArgumentParser(
        prog="python -m repro.analysis.lint",
        description="fiddlint: Fiddler hot-path invariant checks "
                    "(FID001-FID005)")
    ap.add_argument("paths", nargs="*",
                    help="files/dirs to lint (default: [tool.fiddlint] "
                         "paths from pyproject.toml)")
    ap.add_argument("--select", default=None,
                    help="comma-separated rule ids (default: all)")
    ap.add_argument("--baseline", default=None,
                    help="baseline JSON path (default from config)")
    ap.add_argument("--no-baseline", action="store_true",
                    help="report baselined findings as actionable")
    ap.add_argument("--update-baseline", action="store_true",
                    help="rewrite the baseline from current findings "
                         "and exit 0")
    ap.add_argument("--hot-root", action="append", default=None,
                    dest="hot_roots", metavar="QUALNAME",
                    help="override FID001/FID002 call-graph roots "
                         "(repeatable)")
    ap.add_argument("--format", choices=("text", "json"), default="text")
    ap.add_argument("--output", default=None,
                    help="write the report here as well as stdout")
    ap.add_argument("--stats", action="store_true",
                    help="append a summary line (counts by disposition)")
    return ap.parse_args(argv)


def _render(result: LintResult, fmt: str, stats: bool) -> str:
    if fmt == "json":
        payload = {
            "findings": [vars(f) for f in result.findings],
            "suppressed": [vars(f) for f in result.suppressed],
            "baselined": [vars(f) for f in result.baselined],
        }
        return json.dumps(payload, indent=2)
    lines = [f.render() for f in result.findings]
    if stats or not lines:
        lines.append(
            f"fiddlint: {len(result.findings)} actionable, "
            f"{len(result.suppressed)} suppressed, "
            f"{len(result.baselined)} baselined")
    return "\n".join(lines)


def main(argv: Optional[List[str]] = None) -> int:
    ns = _parse_args(argv)
    cfg = load_config()
    select = ([s.strip() for s in ns.select.split(",") if s.strip()]
              if ns.select else None)
    if select:
        bad = [s for s in select if s not in RULE_IDS]
        if bad:
            print(f"fiddlint: unknown rule id(s): {', '.join(bad)}",
                  file=sys.stderr)
            return 2
    cfg = cfg.with_overrides(
        paths=ns.paths or None, select=select,
        baseline=ns.baseline, hot_roots=ns.hot_roots)

    if ns.update_baseline:
        result = run_lint(cfg, use_baseline=False)
        target = Path(cfg.baseline or "fiddlint-baseline.json")
        keep = result.findings  # suppressions still apply; baseline the rest
        Baseline.write(target, keep)
        print(f"fiddlint: wrote {len(keep)} finding(s) to {target}")
        return 0

    result = run_lint(cfg, use_baseline=not ns.no_baseline)
    report = _render(result, ns.format, ns.stats)
    print(report)
    if ns.output:
        Path(ns.output).write_text(report + "\n")
    return result.exit_code


if __name__ == "__main__":
    raise SystemExit(main())
