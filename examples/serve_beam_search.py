"""Serving + beam-search demo (the paper's scenario ⓒ, 11.57× result).

Serves batched requests through the ServingEngine, runs a **gang-scheduled
beam group** through the continuous engine — the group claims its slots
atomically, the beams share their prompt-prefix KV blocks (paged layout,
models/paged_kv.py) and every reshuffle is a zero-copy block-table
permutation — then sweeps beam widths over the orchestrator to show how
the planner's decisions shift from slow-tier execution to weight
streaming as per-expert input sizes grow (paper §3.2).

    PYTHONPATH=src python examples/serve_beam_search.py [--smoke]
"""
import argparse

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config
from repro.core import FiddlerEngine, HardwareSpec
from repro.data.tokenizer import ByteTokenizer
from repro.models import Model
from repro.serving.backend import FiddlerBackend
from repro.serving.beam_search import beam_search_fiddler
from repro.serving.continuous import ContinuousEngine
from repro.serving.engine import Request, ServingEngine


def main(smoke: bool = False):
    cfg = get_config("mixtral-8x7b").reduced()
    full = get_config("mixtral-8x7b")
    model = Model(cfg, param_dtype=jnp.float32)
    params = model.init(jax.random.PRNGKey(0))
    tok = ByteTokenizer(cfg.vocab_size)
    n_new = 4 if smoke else 8

    # --- batched serving --------------------------------------------------
    print("== batched serving through the orchestrator ==")
    fe = FiddlerEngine(cfg, params, policy="fiddler", expert_budget=40,
                       timing_cfg=full, hw=HardwareSpec.paper_env1())
    eng = ServingEngine(fe, mode="fiddler", max_batch=4, max_seq=96)
    texts = ["USER: hi", "USER: what is moe?"] if smoke else [
        "USER: hi", "USER: what is moe?", "USER: explain experts",
        "USER: fast inference", "USER: how to serve?"]
    for i, text in enumerate(texts):
        eng.submit(Request(rid=f"r{i}", prompt=tok.encode(text),
                           max_new_tokens=n_new))
    for r in eng.run():
        print(f"  {r.rid}: ttft={r.ttft*1e3:7.1f}ms "
              f"latency={r.latency*1e3:7.1f}ms (simulated) "
              f"out={tok.decode(r.output)!r}")

    # --- gang-scheduled beam group in the continuous engine ----------------
    print("== beam group + interactive traffic, continuous engine ==")
    width = 2 if smoke else 4
    fe = FiddlerEngine(cfg, params, policy="fiddler", expert_budget=40,
                       timing_cfg=full, hw=HardwareSpec.paper_env1())
    backend = FiddlerBackend(fe, max_seq=96)
    ceng = ContinuousEngine(backend, n_slots=width + 2, max_seq=96,
                            prefill_chunk=8)
    ceng.submit(Request(rid="beam", prompt=tok.encode("USER: tell me about"),
                        beam_width=width, max_new_tokens=n_new))
    ceng.submit(Request(rid="chat", prompt=tok.encode("USER: hello"),
                        max_new_tokens=n_new, slo_class="interactive"))
    done = {r.rid: r for r in ceng.run(max_steps=400)}
    b = done["beam"]
    stats_src = ceng.cache[0].meta
    print(f"  beam({width}): best score={b.beam_scores[0]:.3f} "
          f"latency={b.latency*1e3:.1f}ms(sim) "
          f"out={tok.decode(b.output)!r}")
    print(f"  chat: out={tok.decode(done['chat'].output)!r}")
    print(f"  block pool after drain: {stats_src.blocks_in_use()} in use "
          f"(gang retired → all blocks returned)")

    # --- beam search, width sweep ------------------------------------------
    print("== beam search: planner decisions vs width ==")
    prompt = np.asarray([tok.encode("USER: tell me about")], np.int32)
    n_total = cfg.n_layers * cfg.moe.n_experts
    for width in ((1, 4) if smoke else (1, 4, 8, 16)):
        # small fast-tier budget (1/4 of experts) so the planner has real
        # choices; latency constants come from the FULL-size model
        fe = FiddlerEngine(cfg, params, policy="fiddler",
                           expert_budget=n_total // 4,
                           timing_cfg=full, hw=HardwareSpec.paper_env1())
        res = beam_search_fiddler(fe, prompt, width=width, n_new=n_new,
                                  max_seq=96)
        led = fe.ledger
        total = max(led.fast_hits + led.streams + led.slow_runs, 1)
        blocks = ""
        if res.block_stats:
            blocks = (f"  kv_blocks unique={res.block_stats['unique_blocks']}"
                      f"/dense={res.block_stats['dense_blocks']}")
        print(f"  width={width:2d}  best={res.scores[0]:8.3f} "
              f"sim={led.sim_time*1e3:8.1f}ms  "
              f"decisions: resident={led.fast_hits/total:.0%} "
              f"stream={led.streams/total:.0%} "
              f"slow={led.slow_runs/total:.0%}{blocks}")


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="smallest configuration (CI)")
    main(smoke=ap.parse_args().smoke)
