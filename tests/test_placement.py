"""Property tests for popularity profiling and expert placement."""
import numpy as np
from hypothesis import given, settings, strategies as st

from repro.core.placement import (
    PlacementReport,
    hit_rate,
    place_by_popularity,
    place_random,
    place_static_split,
    place_worst,
)
from repro.core.popularity import ExpertProfile, synthetic_profile


@given(st.integers(2, 8), st.integers(2, 16), st.integers(0, 64),
       st.integers(0, 2**31 - 1))
@settings(max_examples=100, deadline=None)
def test_greedy_placement_is_optimal(L, E, budget, seed):
    rng = np.random.default_rng(seed)
    prof = ExpertProfile(rng.random((L, E)) * 100)
    best = place_by_popularity(prof, budget)
    assert best.n_resident == min(budget, L * E)
    hr_best = hit_rate(prof, best)
    # no random placement of the same budget beats greedy
    for s in range(5):
        hr_rand = hit_rate(prof, place_random(L, E, budget, seed=s))
        assert hr_best >= hr_rand - 1e-12
    assert hr_best >= hit_rate(prof, place_worst(prof, budget)) - 1e-12


@given(st.integers(2, 6), st.integers(2, 12))
@settings(max_examples=50, deadline=None)
def test_hit_rate_bounds(L, E):
    prof = synthetic_profile(L, E, seed=1)
    assert hit_rate(prof, place_by_popularity(prof, 0)) == 0.0
    assert abs(hit_rate(prof, place_by_popularity(prof, L * E)) - 1.0) < 1e-9


def test_profile_update_and_normalize():
    prof = ExpertProfile.empty(2, 4)
    prof.update(0, np.array([0, 0, 1, 3]))
    prof.update(1, np.array([2, 2, 2, 2]))
    assert prof.counts[0, 0] == 2
    assert prof.normalized().max() == 1.0
    p = prof.probabilities()
    np.testing.assert_allclose(p.sum(axis=1), [1.0, 1.0])


def test_paper_appendix_c_regime():
    """Paper App. C (Mixtral-8x7B, 32 layers × 8 experts): with 56/256
    experts resident, best ≈ 25.2%, random ≈ 21.9%, worst ≈ 18.7% —
    popularity placement buys ~3–5pp.  Our synthetic ShareGPT-like profile
    reproduces that ordering and magnitude."""
    prof = synthetic_profile(32, 8, seed=0, concentration=12.0)
    rep = PlacementReport.build(prof, budget=56)
    assert rep.best > rep.random > rep.worst
    assert 0.01 < rep.best - rep.random < 0.10
    assert abs(rep.random - 56 / 256) < 1e-9


def test_static_split_shape():
    p = place_static_split(8, 4, 3)
    assert p.on_fast[:3].all() and not p.on_fast[3:].any()


def test_profile_save_load(tmp_path):
    prof = synthetic_profile(4, 8, seed=3)
    path = str(tmp_path / "prof.npz")
    prof.save(path)
    loaded = ExpertProfile.load(path)
    np.testing.assert_array_equal(prof.counts, loaded.counts)
